//! Kernel-backend parity suite.
//!
//! The AVX2 backend must be **bit-identical** to the scalar reference on
//! every kernel family and every tile-edge shape — it vectorizes across
//! independent output entries / dot lanes, never within an entry's
//! reduction, so there is nothing to tolerate. The FMA tier contracts
//! each multiply-add to one rounding and is therefore compared with an
//! analytic tolerance instead (and asserted to actually differ, so a
//! build that silently compiles FMA out of the tier is caught).
//!
//! Everything here uses the `*_with` kernel entry points, which take an
//! explicit backend and never touch the process-wide selection — except
//! `forced_backend_resolution`, which exercises `force_backend` itself
//! (and restores the environment's selection before returning).

use pas::tensor::gemm::{
    backend, force_backend, gemm_nn_acc_with, gemm_nn_into_with, gemm_nt_dot_acc_with,
    gemm_nt_dot_into_with, gemm_nt_seq_into_with, gemm_tn_acc_with, simd_available, Backend, KC,
    MR, NR,
};
use pas::util::rng::Pcg64;

/// Tile-boundary values for the row/column dimensions: 1, MR±1, MR,
/// NR±1, NR, and a couple of multi-tile-plus-remainder sizes.
const MNS: &[usize] = &[1, 3, 4, 5, 7, 8, 9, 13];

/// Reduction depths straddling the 4-lane dot width and the KC k-panel:
/// 1, MR−1, MR, MR+1, NR±1, NR, KC−1, KC, KC+1 and 3·KC+2.
const KS: &[usize] = &[1, 3, 4, 5, 7, 8, 9, KC - 1, KC, KC + 1, 3 * KC + 2];

/// True (with a notice) when the SIMD backends cannot run here — each
/// test degrades to a skip instead of a failure on pre-AVX2 hardware.
fn skip_without_simd(test: &str) -> bool {
    if simd_available() {
        return false;
    }
    eprintln!("notice: skipping {test}: CPU lacks avx2+fma");
    true
}

struct Case {
    m: usize,
    n: usize,
    k: usize,
    a_nn: Vec<f64>,  // (m, k) row-major
    b_nn: Vec<f64>,  // (k, n) row-major
    a_tn: Vec<f64>,  // (k, m) row-major
    b_nt: Vec<f64>,  // (n, k) row-major
    init: Vec<f64>,  // (m, n) initial c for the accumulate kernels
}

fn cases(seed: u64) -> Vec<Case> {
    let mut rng = Pcg64::seed(seed);
    let mut out = Vec::new();
    for &m in MNS {
        for &n in MNS {
            for &k in KS {
                out.push(Case {
                    m,
                    n,
                    k,
                    a_nn: (0..m * k).map(|_| rng.normal()).collect(),
                    b_nn: (0..k * n).map(|_| rng.normal()).collect(),
                    a_tn: (0..k * m).map(|_| rng.normal()).collect(),
                    b_nt: (0..n * k).map(|_| rng.normal()).collect(),
                    init: (0..m * n).map(|_| rng.normal()).collect(),
                });
            }
        }
    }
    // A few larger-than-one-register-block m/n probes so multi-tile row
    // and column loops (and the KC panel restart) are crossed at once.
    for (m, n, k) in [(2 * MR + 1, 2 * NR + 1, KC + 1), (17, 19, 3 * KC + 2)] {
        out.push(Case {
            m,
            n,
            k,
            a_nn: (0..m * k).map(|_| rng.normal()).collect(),
            b_nn: (0..k * n).map(|_| rng.normal()).collect(),
            a_tn: (0..k * m).map(|_| rng.normal()).collect(),
            b_nt: (0..n * k).map(|_| rng.normal()).collect(),
            init: (0..m * n).map(|_| rng.normal()).collect(),
        });
    }
    out
}

/// Run every kernel family on one backend; returns the six result
/// matrices in a fixed order.
fn run_all(be: Backend, c: &Case) -> [Vec<f64>; 6] {
    let (m, n, k) = (c.m, c.n, c.k);
    let mut nn_acc = c.init.clone();
    gemm_nn_acc_with(be, &c.a_nn, m, k, &c.b_nn, n, &mut nn_acc);
    let mut nn_into = vec![f64::NAN; m * n]; // _into must overwrite NaNs
    gemm_nn_into_with(be, &c.a_nn, m, k, &c.b_nn, n, &mut nn_into);
    let mut dot_acc = c.init.clone();
    gemm_nt_dot_acc_with(be, &c.a_nn, m, &c.b_nt, n, k, &mut dot_acc);
    let mut dot_into = vec![f64::NAN; m * n];
    gemm_nt_dot_into_with(be, &c.a_nn, m, &c.b_nt, n, k, &mut dot_into);
    let mut seq_into = vec![f64::NAN; m * n];
    gemm_nt_seq_into_with(be, &c.a_nn, m, &c.b_nt, n, k, &mut seq_into);
    let mut tn_acc = c.init.clone();
    gemm_tn_acc_with(be, &c.a_tn, k, m, &c.b_nn, n, &mut tn_acc);
    [nn_acc, nn_into, dot_acc, dot_into, seq_into, tn_acc]
}

const FAMILIES: [&str; 6] = [
    "nn_acc",
    "nn_into",
    "nt_dot_acc",
    "nt_dot_into",
    "nt_seq_into",
    "tn_acc",
];

#[test]
fn avx2_is_bitwise_identical_to_scalar() {
    if skip_without_simd("avx2_is_bitwise_identical_to_scalar") {
        return;
    }
    for c in cases(11) {
        let want = run_all(Backend::Scalar, &c);
        let got = run_all(Backend::Avx2, &c);
        for (f, (w, g)) in FAMILIES.iter().zip(want.iter().zip(got.iter())) {
            // Bitwise, not ==: asserts -0.0 vs 0.0 and NaN payloads too.
            let wb: Vec<u64> = w.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u64> = g.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wb, gb, "{f} ({},{},{}) diverged", c.m, c.k, c.n);
        }
    }
}

/// Per-entry absolute-value products `Σ_p |a·b|` — the scale of the
/// worst-case rounding difference between the 2-rounding scalar chain and
/// the 1-rounding FMA chain (both are bounded by ~k·eps·this).
fn abs_bound_nn(a: &[f64], m: usize, k: usize, b: &[f64], n: usize) -> Vec<f64> {
    let mut out = vec![0.0; m * n];
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p].abs();
            for j in 0..n {
                out[i * n + j] += av * b[p * n + j].abs();
            }
        }
    }
    out
}

fn abs_bound_nt(a: &[f64], m: usize, k: usize, b: &[f64], n: usize) -> Vec<f64> {
    let mut out = vec![0.0; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for p in 0..k {
                s += (a[i * k + p] * b[j * k + p]).abs();
            }
            out[i * n + j] = s;
        }
    }
    out
}

fn abs_bound_tn(a: &[f64], k: usize, m: usize, b: &[f64], n: usize) -> Vec<f64> {
    let mut out = vec![0.0; m * n];
    for p in 0..k {
        for i in 0..m {
            let av = a[p * m + i].abs();
            for j in 0..n {
                out[i * n + j] += av * b[p * n + j].abs();
            }
        }
    }
    out
}

#[test]
fn fma_tier_is_within_reduction_tolerance_of_scalar() {
    if skip_without_simd("fma_tier_is_within_reduction_tolerance_of_scalar") {
        return;
    }
    for c in cases(12) {
        let (m, n, k) = (c.m, c.n, c.k);
        let want = run_all(Backend::Scalar, &c);
        let got = run_all(Backend::Avx2Fma, &c);
        let bound_nn = abs_bound_nn(&c.a_nn, m, k, &c.b_nn, n);
        let bound_nt = abs_bound_nt(&c.a_nn, m, k, &c.b_nt, n);
        let bound_tn = abs_bound_tn(&c.a_tn, k, m, &c.b_nn, n);
        let bounds: [&Vec<f64>; 6] = [
            &bound_nn, &bound_nn, &bound_nt, &bound_nt, &bound_nt, &bound_tn,
        ];
        for ((f, bound), (w, g)) in FAMILIES
            .iter()
            .zip(bounds.iter())
            .zip(want.iter().zip(got.iter()))
        {
            for (e, ((wv, gv), bv)) in w.iter().zip(g.iter()).zip(bound.iter()).enumerate() {
                // Each chain's rounding error is ≤ ~k·eps·Σ|a·b| (the
                // accumulate variants add one more term for the initial
                // c); 4·(k+2) leaves comfortable slack while still
                // scaling with the reduction, not the magnitude.
                let tol = 4.0 * (k as f64 + 2.0)
                    * f64::EPSILON
                    * (bv + c.init.get(e).map_or(0.0, |v| v.abs()) + f64::MIN_POSITIVE);
                assert!(
                    (wv - gv).abs() <= tol,
                    "{f} ({m},{k},{n}) entry {e}: scalar {wv} vs fma {gv} (tol {tol})"
                );
            }
        }
    }
}

#[test]
fn fma_tier_actually_changes_bits() {
    if skip_without_simd("fma_tier_actually_changes_bits") {
        return;
    }
    // On a deep-reduction shape the odds of every FMA rounding matching
    // the 2-rounding chain are nil; if all six families agree bitwise,
    // the tier silently lost its fmadd (e.g. a bad dispatch edit).
    let c = cases(13)
        .into_iter()
        .find(|c| c.m == 13 && c.n == 13 && c.k == KC)
        .expect("case grid must contain (13, KC, 13)");
    let want = run_all(Backend::Scalar, &c);
    let got = run_all(Backend::Avx2Fma, &c);
    let differs = want
        .iter()
        .zip(got.iter())
        .any(|(w, g)| w.iter().zip(g.iter()).any(|(a, b)| a.to_bits() != b.to_bits()));
    assert!(differs, "avx2fma produced scalar-identical bits everywhere");
}

#[test]
fn unavailable_simd_requests_degrade_to_scalar() {
    // `*_with` on a SIMD backend must fall back to scalar (same bits)
    // when the hardware lacks the features, rather than crash. On AVX2
    // hardware this arm is vacuous, but the dispatch guard it exercises
    // is the same one `force_backend` relies on.
    if simd_available() {
        return;
    }
    let all = cases(14);
    let c = &all[0];
    let want = run_all(Backend::Scalar, c);
    for be in [Backend::Avx2, Backend::Avx2Fma] {
        let got = run_all(be, c);
        assert_eq!(want, got, "{:?} without hardware support", be);
    }
}

#[test]
fn forced_backend_resolution() {
    // force_backend resolves requests against the hardware and reports
    // what it installed; the process-wide `backend()` must follow.
    assert_eq!(force_backend(Backend::Scalar), Backend::Scalar);
    assert_eq!(backend(), Backend::Scalar);
    let got = force_backend(Backend::Avx2);
    if simd_available() {
        assert_eq!(got, Backend::Avx2);
    } else {
        assert_eq!(got, Backend::Scalar);
    }
    assert_eq!(backend(), got);
    // Restore the environment's selection for any test scheduled after
    // us in this binary (auto = what force(Avx2) resolves to, so only an
    // explicit PAS_KERNEL needs re-applying).
    match std::env::var("PAS_KERNEL").ok().and_then(|v| Backend::parse(v.trim())) {
        Some(b) => force_backend(b),
        None => force_backend(Backend::Avx2),
    };
}
