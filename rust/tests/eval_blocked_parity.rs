//! Blocked-eval parity: the sample-blocked GEMM pipeline behind
//! `AnalyticEps::eval_batch` must be **bit-identical** to the scalar
//! per-sample path (`eval_batch_per_sample`, one `eval_one` per row) —
//! for all three internal mode representations (Iso / LowRank / Full),
//! for batch sizes that straddle every tile boundary, and for arbitrary
//! sub-range chunkings (neither the eval tile grid nor the pool's chunk
//! boundaries may be observable in the output). CI runs this under both
//! `PAS_THREADS` matrix legs, so the inline and pooled fan-out paths are
//! both pinned.

use pas::data::Mode;
use pas::score::analytic::{AnalyticEps, EVAL_TILE};
use pas::score::EpsModel;
use pas::util::rng::Pcg64;

/// Rank-4 + flat-floor covariances: engages `ModeEval::LowRank`.
fn lowrank_modes(rng: &mut Pcg64, d: usize, n_modes: usize) -> Vec<Mode> {
    (0..n_modes)
        .map(|_| {
            let mut cov = vec![0.0; d * d];
            for j in 0..d {
                cov[j * d + j] = 0.05;
            }
            for _ in 0..4 {
                let v = rng.normal_vec(d);
                for a in 0..d {
                    for b in 0..d {
                        cov[a * d + b] += 0.6 * v[a] * v[b] / d as f64;
                    }
                }
            }
            let mu: Vec<f64> = rng.normal_vec(d).iter().map(|z| 2.0 * z).collect();
            Mode::full(mu, &cov, 1.0, 0)
        })
        .collect()
}

/// Full-rank Wishart-style covariances with an everywhere-distinct
/// spectrum (no flat tail): engages `ModeEval::Full`.
fn full_modes(rng: &mut Pcg64, d: usize, n_modes: usize) -> Vec<Mode> {
    (0..n_modes)
        .map(|_| {
            let b: Vec<f64> = (0..d * d).map(|_| rng.normal()).collect();
            let mut cov = vec![0.0; d * d];
            for i in 0..d {
                for j in 0..d {
                    let mut s = 0.0;
                    for k in 0..d {
                        s += b[i * d + k] * b[j * d + k];
                    }
                    cov[i * d + j] = s / d as f64;
                }
            }
            for j in 0..d {
                cov[j * d + j] += 0.01 * (j + 1) as f64;
            }
            Mode::full(rng.normal_vec(d), &cov, 1.0, 0)
        })
        .collect()
}

fn iso_modes(rng: &mut Pcg64, d: usize, n_modes: usize) -> Vec<Mode> {
    (0..n_modes)
        .map(|i| {
            let mu: Vec<f64> = rng.normal_vec(d).iter().map(|z| 3.0 * z).collect();
            Mode::isotropic(mu, 0.1 + 0.2 * i as f64, 1.0, 0)
        })
        .collect()
}

/// Batch sizes straddling the tile grid: 1, B−1, B, B+1, 3B+2.
fn tile_boundary_sizes() -> [usize; 5] {
    let b = EVAL_TILE;
    [1, b - 1, b, b + 1, 3 * b + 2]
}

fn assert_blocked_matches_scalar(m: &AnalyticEps, d: usize, label: &str) {
    let mut rng = Pcg64::seed(0xB10C);
    for t in [0.05, 1.0, 7.5] {
        for n in tile_boundary_sizes() {
            let x = rng.normal_vec(n * d);
            let mut blocked = vec![0.0; n * d];
            m.eval_batch(&x, n, t, &mut blocked);
            let mut scalar = vec![0.0; n * d];
            m.eval_batch_per_sample(&x, n, t, &mut scalar);
            assert_eq!(
                blocked, scalar,
                "{label}: blocked != per-sample at n={n}, t={t}"
            );
            // Single-row calls are the scalar anchor's anchor: evaluating
            // each row alone must reproduce the same bits too.
            for i in 0..n {
                let one = m.eval(&x[i * d..(i + 1) * d], 1, t);
                assert_eq!(
                    &blocked[i * d..(i + 1) * d],
                    one.as_slice(),
                    "{label}: row {i} differs from its single-row eval (n={n}, t={t})"
                );
            }
        }
    }
}

#[test]
fn iso_blocked_bitwise() {
    let mut rng = Pcg64::seed(11);
    let d = 64;
    let m = AnalyticEps::new("iso64", iso_modes(&mut rng, d, 5));
    assert!(m.mode_kinds().iter().all(|k| *k == "iso"));
    assert_blocked_matches_scalar(&m, d, "iso64");
}

#[test]
fn lowrank_blocked_bitwise() {
    let mut rng = Pcg64::seed(12);
    let d = 64;
    let m = AnalyticEps::new("lr64", lowrank_modes(&mut rng, d, 4));
    assert!(
        m.mode_kinds().iter().all(|k| *k == "lowrank"),
        "construction must engage the Woodbury fast path: {:?}",
        m.mode_kinds()
    );
    assert_blocked_matches_scalar(&m, d, "lr64");
}

#[test]
fn full_blocked_bitwise() {
    let mut rng = Pcg64::seed(13);
    let d = 32;
    let m = AnalyticEps::new("full32", full_modes(&mut rng, d, 3));
    assert!(
        m.mode_kinds().iter().all(|k| *k == "full"),
        "construction must engage the dense path: {:?}",
        m.mode_kinds()
    );
    assert_blocked_matches_scalar(&m, d, "full32");
}

/// One mixture containing all three representations at once: the blocked
/// pipeline stages every variant's s_k rows through the same tile
/// scratch before the softmax combine.
#[test]
fn mixed_variant_mixture_blocked_bitwise() {
    let mut rng = Pcg64::seed(14);
    let d = 32;
    let mut modes = iso_modes(&mut rng, d, 2);
    modes.extend(lowrank_modes(&mut rng, d, 2));
    modes.extend(full_modes(&mut rng, d, 2));
    let m = AnalyticEps::new("mixed32", modes);
    let kinds = m.mode_kinds();
    for want in ["iso", "lowrank", "full"] {
        assert!(kinds.contains(&want), "missing variant {want}: {kinds:?}");
    }
    assert_blocked_matches_scalar(&m, d, "mixed32");
}

/// Dimension 2 (the golden-fixture dataset family): the blocked path must
/// not disturb a single bit at tiny dimensions either.
#[test]
fn tiny_dim_blocked_bitwise() {
    let ds = pas::data::registry::get("gmm2d").unwrap();
    let m = AnalyticEps::from_dataset(&ds);
    assert_blocked_matches_scalar(&m, 2, "gmm2d");
}

/// Evaluating any partition of the batch piecewise must reproduce the
/// full-batch bits exactly — this is what makes the engine's chunk
/// layout and the pool's shard boundaries unobservable.
#[test]
fn chunk_boundaries_are_unobservable() {
    let mut rng = Pcg64::seed(15);
    let d = 64;
    let m = AnalyticEps::new("lr64-chunks", lowrank_modes(&mut rng, d, 6));
    let n = 3 * EVAL_TILE + 2;
    let t = 1.3;
    let x = rng.normal_vec(n * d);
    let mut full = vec![0.0; n * d];
    m.eval_batch(&x, n, t, &mut full);
    // Several split layouts, including splits inside a tile and chunks
    // smaller than one tile.
    let splits: [&[usize]; 4] = [
        &[0, n],
        &[0, 1, n],
        &[0, 7, 23, n],
        &[0, EVAL_TILE - 1, EVAL_TILE + 1, 2 * EVAL_TILE, n],
    ];
    for cuts in splits {
        let mut piecewise = vec![0.0; n * d];
        for w in cuts.windows(2) {
            let (r0, r1) = (w[0], w[1]);
            m.eval_batch(
                &x[r0 * d..r1 * d],
                r1 - r0,
                t,
                &mut piecewise[r0 * d..r1 * d],
            );
        }
        assert_eq!(full, piecewise, "split {cuts:?} changed output bits");
    }
}

/// Large batch: the pool fan-out engages (when PAS_THREADS > 1) and must
/// agree bitwise with the per-sample path under the same fan-out.
#[test]
fn pooled_fanout_bitwise() {
    let mut rng = Pcg64::seed(16);
    let d = 64;
    let m = AnalyticEps::new("lr64-pool", lowrank_modes(&mut rng, d, 6));
    let n = 256;
    let x = rng.normal_vec(n * d);
    for t in [0.1, 2.0] {
        let mut blocked = vec![0.0; n * d];
        m.eval_batch(&x, n, t, &mut blocked);
        let mut scalar = vec![0.0; n * d];
        m.eval_batch_per_sample(&x, n, t, &mut scalar);
        assert_eq!(blocked, scalar, "pooled fan-out diverged at t={t}");
    }
}

/// `log_density` (now routed through the shared thread-local scratch)
/// must agree with what `eval_one` reported before the rerouting — pin
/// it against a fresh finite-difference-free recomputation via the
/// public eval, which shares every internal.
#[test]
fn log_density_consistent_across_variants() {
    let mut rng = Pcg64::seed(17);
    let d = 32;
    let mut modes = iso_modes(&mut rng, d, 1);
    modes.extend(lowrank_modes(&mut rng, d, 1));
    modes.extend(full_modes(&mut rng, d, 1));
    let m = AnalyticEps::new("mixed-ld", modes);
    for trial in 0..5 {
        let x = rng.normal_vec(d);
        let t = 0.2 + trial as f64;
        let a = m.log_density(&x, t);
        let b = m.log_density(&x, t);
        assert!(a.is_finite());
        assert_eq!(a, b, "log_density must be deterministic");
    }
}
