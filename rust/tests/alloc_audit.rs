//! Allocation audit: the engine's zero-allocation claim, promoted from a
//! bench-only, single-solver check (`benches/pas_overhead.rs`) to an
//! enforced test over the **whole registry**.
//!
//! A counting global allocator measures heap allocations performed while
//! a warmed [`SamplerEngine`] runs each registry solver in both
//! [`Record`] modes. After warm-up (which sizes the node stores, the
//! solver scratch arena, and every pool worker's thread-local eval
//! scratch), the steady state must perform **zero** allocations — the
//! scratch-arena redesign extends this guarantee to the multi-eval
//! (Heun, DPM-Solver-2) and history-hungry (DPM++, UniPC, DEIS) solvers
//! that previously allocated inside `step`.
//!
//! Also audited here (same single test, same counter): the sample-blocked
//! GEMM eval pipeline of `AnalyticEps::eval_batch` on its own, the
//! register-tiled matmul kernels (`pas::tensor::gemm`), which work
//! entirely in caller-owned buffers and must never allocate, and the
//! **PAS training inner loop** — with a warmed `TrainSession`, every
//! `train_step` (per-sample basis extraction, the full SGD epoch stack,
//! the adaptive decision and the rollout advance) must be zero-allocation.
//!
//! This file contains exactly one `#[test]` so the process-wide
//! allocation counter is never polluted by a concurrently running test.

#[path = "support/counting_alloc.rs"]
mod counting_alloc;

use counting_alloc::{CountingAlloc, ALLOC_COUNT};
use pas::pas::train::{TrainConfig, TrainSession};
use pas::schedule::default_schedule;
use pas::score::analytic::AnalyticEps;
use pas::score::EpsModel;
use pas::solvers::engine::{EngineConfig, Record, SamplerEngine};
use pas::solvers::registry;
use pas::tensor::gemm::{self, gemm_nn_acc, gemm_nt_dot_into, gemm_nt_seq_into, gemm_tn_acc};
use pas::traj::sample_prior;
use pas::util::rng::Pcg64;
use std::sync::atomic::Ordering;

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Allocations observed across `runs` engine runs after warm-up.
fn measure(
    engine: &mut SamplerEngine,
    solver: &dyn pas::solvers::Solver,
    model: &dyn pas::score::EpsModel,
    x_t: &[f64],
    n: usize,
    sched: &pas::schedule::Schedule,
    x0: &mut [f64],
    runs: usize,
) -> u64 {
    let before = ALLOC_COUNT.load(Ordering::SeqCst);
    for _ in 0..runs {
        engine.run_into(solver, model, x_t, n, sched, None, x0);
    }
    ALLOC_COUNT.load(Ordering::SeqCst) - before
}

#[test]
fn zero_steady_state_allocs_every_solver_both_record_modes() {
    let ds = pas::data::registry::get("gmm-hd64").unwrap();
    let model = AnalyticEps::from_dataset(&ds);
    let n = 64;
    let dim = 64; // n * dim = 4096: the sharded stepping path engages
    let sched = default_schedule(6);
    let mut rng = Pcg64::seed(21);
    let x_t = sample_prior(&mut rng, n, dim, sched.t_max());
    let mut x0 = vec![0.0; n * dim];
    let mut failures: Vec<String> = Vec::new();
    for record in [Record::Full, Record::None] {
        // One engine per mode, reused across the registry — the
        // production pattern the reuse guarantee is about.
        let mut engine = SamplerEngine::new(EngineConfig { record, threads: 0 });
        for name in registry::ALL {
            let solver = registry::get(name).unwrap();
            // Warm-up: sizes the node stores and scratch arena for this
            // solver and lets every pool worker initialize its
            // thread-local eval scratch.
            for _ in 0..3 {
                engine.run_into(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None, &mut x0);
            }
            let mut allocs = measure(
                &mut engine,
                solver.as_ref(),
                model.as_ref(),
                &x_t,
                n,
                &sched,
                &mut x0,
                5,
            );
            if allocs > 0 {
                // One retry shields against a stray lazy initialization
                // (e.g. a pool worker that raced out of every warm-up
                // dispatch) landing inside the measured window; a real
                // per-step allocation re-fires deterministically.
                allocs = measure(
                    &mut engine,
                    solver.as_ref(),
                    model.as_ref(),
                    &x_t,
                    n,
                    &sched,
                    &mut x0,
                    5,
                );
            }
            if allocs > 0 {
                failures.push(format!("{name} ({record:?}): {allocs} allocs over 5 runs"));
            }
        }
    }
    // The sample-blocked eval pipeline on its own (the tentpole path):
    // after warm-up sizes every pool worker's thread-local tile scratch,
    // repeated batch evaluations must allocate nothing.
    {
        let ds = pas::data::registry::get("latent256").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let n = 256;
        let dim = ds.dim();
        let x = sample_prior(&mut rng, n, dim, 10.0);
        let mut out = vec![0.0; n * dim];
        for _ in 0..3 {
            model.eval_batch(&x, n, 2.0, &mut out);
        }
        let mut allocs = {
            let before = ALLOC_COUNT.load(Ordering::SeqCst);
            for _ in 0..5 {
                model.eval_batch(&x, n, 2.0, &mut out);
            }
            ALLOC_COUNT.load(Ordering::SeqCst) - before
        };
        if allocs > 0 {
            // Same one-retry shield as above (a pool worker that raced
            // out of every warm-up dispatch initializes its scratch once).
            let before = ALLOC_COUNT.load(Ordering::SeqCst);
            for _ in 0..5 {
                model.eval_batch(&x, n, 2.0, &mut out);
            }
            allocs = ALLOC_COUNT.load(Ordering::SeqCst) - before;
        }
        if allocs > 0 {
            failures.push(format!(
                "blocked eval_batch (latent256 b256): {allocs} allocs over 5 runs"
            ));
        }

        // `log_density` rides the same thread-local scratch (its output
        // row included): after one warm call sizes the buffer, repeated
        // calls must not allocate either.
        let mut acc = model.log_density(&x[..dim], 2.0);
        let before = ALLOC_COUNT.load(Ordering::SeqCst);
        for _ in 0..5 {
            acc += model.log_density(&x[..dim], 2.0);
        }
        let ld_allocs = ALLOC_COUNT.load(Ordering::SeqCst) - before;
        std::hint::black_box(acc);
        if ld_allocs > 0 {
            failures.push(format!("log_density: {ld_allocs} allocs over 5 calls"));
        }
    }

    // The PAS training inner loop: a warmed TrainSession must run every
    // train_step — basis extraction into the BasisStore, all SGD epochs
    // (permutation draws included), the adaptive decision and the rollout
    // advance — without a single heap allocation. `begin`/`finish` are
    // run-level and may allocate (curves, dict, result); they stay
    // outside the measured window.
    {
        let ds = pas::data::registry::get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let solver = registry::get("ddim").unwrap();
        let sched = default_schedule(5);
        let cfg = TrainConfig {
            n_traj: 48,
            epochs: 8,
            minibatch: 16,
            teacher_nfe: 60,
            ..TrainConfig::default()
        };
        let mut session = TrainSession::new(cfg);
        // Warm-up: one full run sizes every workspace (engine node
        // stores, basis store, per-chunk PCA scratch at its deepest
        // trajectory, SGD staging, permutation buffer).
        session
            .train(solver.as_ref(), model.as_ref(), &sched, "gmm-hd64", false, None)
            .unwrap();
        let measure_steps = |session: &mut TrainSession| {
            session
                .begin(solver.as_ref(), model.as_ref(), &sched, "gmm-hd64", false, None)
                .unwrap();
            let before = ALLOC_COUNT.load(Ordering::SeqCst);
            for j in 0..session.n_steps() {
                session
                    .train_step(solver.as_ref(), model.as_ref(), &sched, j)
                    .unwrap();
            }
            let allocs = ALLOC_COUNT.load(Ordering::SeqCst) - before;
            let _ = session.finish();
            allocs
        };
        let mut allocs = measure_steps(&mut session);
        if allocs > 0 {
            // Same one-retry shield as above (a pool worker that raced
            // out of every warm-up dispatch initializes its thread-local
            // scratch once).
            allocs = measure_steps(&mut session);
        }
        if allocs > 0 {
            failures.push(format!(
                "training inner loop (gmm-hd64, ddim@5): {allocs} allocs across 5 train_steps"
            ));
        }
    }

    // The serving metrics histograms sit on the hot retire path, which
    // carries the scheduler's zero-alloc claim: `observe` is three
    // relaxed fetch-adds per series, allocation-free from the first call.
    {
        use pas::server::metrics_export::ServeHistograms;
        let hist = ServeHistograms::default();
        hist.observe(0.5, 1.0, 1.5); // no warm-up needed; symmetry with above
        let before = ALLOC_COUNT.load(Ordering::SeqCst);
        for i in 0..100u32 {
            let ms = f64::from(i) * 0.37;
            hist.observe(ms, ms * 2.0, ms * 3.0);
        }
        let hist_allocs = ALLOC_COUNT.load(Ordering::SeqCst) - before;
        std::hint::black_box(hist.latency_ms.count());
        if hist_allocs > 0 {
            failures.push(format!(
                "ServeHistograms::observe allocated: {hist_allocs} over 100 observations"
            ));
        }
    }

    // The tiled matmul kernels work entirely in caller-owned buffers:
    // zero allocations once the one-time backend selection has run
    // (reading `PAS_KERNEL` from the environment may allocate; the
    // steady-state dispatch is a relaxed atomic load). Audited on the
    // active backend through the dispatching entry points AND on every
    // hardware-supported backend through the explicit `_with` variants,
    // so the SIMD kernels carry the same guarantee as scalar.
    {
        // One-time selection + feature detection, outside the window.
        std::hint::black_box(gemm::backend());
        std::hint::black_box(gemm::simd_available());
        let (m, k, n2) = (13usize, 37usize, 11usize);
        let a: Vec<f64> = (0..m * k).map(|i| i as f64 * 0.25).collect();
        let bt: Vec<f64> = (0..n2 * k).map(|i| 1.0 - i as f64 * 0.125).collect();
        let b: Vec<f64> = (0..k * n2).map(|i| 0.5 + i as f64 * 0.0625).collect();
        let mut c = vec![0.0; m * n2];
        let mut c2 = vec![0.0; n2 * n2];
        let before = ALLOC_COUNT.load(Ordering::SeqCst);
        gemm_nn_acc(&a, m, k, &b, n2, &mut c);
        gemm_nt_dot_into(&a, m, &bt, n2, k, &mut c);
        gemm_nt_seq_into(&a, m, &bt, n2, k, &mut c);
        gemm_tn_acc(&b, k, n2, &b, n2, &mut c2);
        for be in gemm::Backend::ALL {
            if be != gemm::Backend::Scalar && !gemm::simd_available() {
                continue;
            }
            gemm::gemm_nn_acc_with(be, &a, m, k, &b, n2, &mut c);
            gemm::gemm_nt_dot_acc_with(be, &a, m, &bt, n2, k, &mut c);
            gemm::gemm_nt_dot_into_with(be, &a, m, &bt, n2, k, &mut c);
            gemm::gemm_nt_seq_into_with(be, &a, m, &bt, n2, k, &mut c);
            gemm::gemm_tn_acc_with(be, &b, k, n2, &b, n2, &mut c2);
        }
        let kernel_allocs = ALLOC_COUNT.load(Ordering::SeqCst) - before;
        std::hint::black_box(&c);
        std::hint::black_box(&c2);
        if kernel_allocs > 0 {
            failures.push(format!("tiled kernels allocated: {kernel_allocs}"));
        }
    }

    assert!(
        failures.is_empty(),
        "steady-state heap allocations detected:\n  {}",
        failures.join("\n  ")
    );
}
