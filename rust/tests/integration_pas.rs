//! Integration tests: the full PAS pipeline over the analytic substrate —
//! train → save → load → correct fresh samples → metric improvements, plus
//! the paper's qualitative orderings at small scale.

use pas::experiments::common::{default_train, eval_cell, Bench, Cell};
use pas::experiments::ExpOpts;
use pas::metrics::gfid;
use pas::pas::coords::CoordinateDict;
use pas::pas::correct::CorrectedSampler;
use pas::pas::train::PasTrainer;
use pas::schedule::default_schedule;
use pas::solvers::run_solver;
use pas::traj::sample_prior;
use pas::util::rng::Pcg64;

fn quick_opts() -> ExpOpts {
    ExpOpts {
        n_samples: 512,
        n_ref: 2048,
        n_traj: 64,
        epochs: 24,
        ..ExpOpts::quick()
    }
}

#[test]
fn full_pipeline_with_save_load_roundtrip() {
    let opts = quick_opts();
    let bench = Bench::new("gmm2d", 0.0, &opts);
    let solver = pas::solvers::registry::get("ddim").unwrap();
    let sched = default_schedule(8);
    let tr = PasTrainer::new(default_train(&opts, "ddim"))
        .train(solver.as_ref(), bench.model.as_ref(), &sched, "gmm2d", false)
        .unwrap();
    assert!(!tr.dict.steps.is_empty());

    // Save + reload the artifact (what `pas train` writes).
    let dir = std::env::temp_dir().join("pas_it_coords");
    let path = dir.join("ddim_gmm2d_8.json");
    tr.dict.save(&path).unwrap();
    let dict = CoordinateDict::load(&path).unwrap();
    assert_eq!(dict.n_params(), tr.dict.n_params());

    // Correct fresh samples with the reloaded dict.
    let n = opts.n_samples;
    let mut rng = Pcg64::seed(31337);
    let x_t = sample_prior(&mut rng, n, 2, sched.t_max());
    let plain = run_solver(solver.as_ref(), bench.model.as_ref(), &x_t, n, &sched, None);
    let corr = CorrectedSampler::sample(&dict, solver.as_ref(), bench.model.as_ref(), &x_t, n, &sched);
    let f0 = gfid(&plain.x0, n, &bench.reference, bench.n_ref, 2);
    let f1 = gfid(&corr.x0, n, &bench.reference, bench.n_ref, 2);
    assert!(f1 < f0, "reloaded dict must still improve: {f0} -> {f1}");
    let _ = std::fs::remove_dir_all(dir);
}

/// The paper's headline ordering on the CIFAR10 stand-in at NFE 10:
/// DDIM ≫ DDIM+PAS, iPNDM < DDIM (gFID, lower better). Needs enough
/// samples that the gFID estimator floor (~0.75 at n=2048) doesn't drown
/// the truncation-error signal.
#[test]
fn paper_orderings_hold_on_cifar_standin() {
    let mut opts = quick_opts();
    opts.n_samples = 2048;
    opts.n_ref = 8192;
    let bench = Bench::new("gmm-hd64", 0.0, &opts);
    // NFE 5: large truncation error → the paper's dramatic-gain regime.
    let ddim5 = eval_cell(&bench, &Cell::plain("ddim", 5), &opts).unwrap().gfid;
    let ddim5_pas = eval_cell(&bench, &Cell::pas("ddim", 5), &opts).unwrap().gfid;
    assert!(
        ddim5_pas < ddim5 * 0.8,
        "PAS must substantially improve DDIM@5: {ddim5} -> {ddim5_pas}"
    );
    // NFE 10: DDIM is already near the gFID estimator floor (~0.75 at
    // n=2048), so require improvement but not a fixed factor.
    let ddim = eval_cell(&bench, &Cell::plain("ddim", 10), &opts).unwrap().gfid;
    let ddim_pas = eval_cell(&bench, &Cell::pas("ddim", 10), &opts).unwrap().gfid;
    let ipndm = eval_cell(&bench, &Cell::plain("ipndm", 10), &opts).unwrap().gfid;
    assert!(
        ddim_pas < ddim,
        "PAS must improve DDIM@10: {ddim} -> {ddim_pas}"
    );
    assert!(ipndm < ddim, "iPNDM should beat DDIM: {ipndm} vs {ddim}");
}

/// Teleportation alone helps DDIM at low NFE, and TP+PAS stacks.
#[test]
fn teleport_improves_and_stacks_with_pas() {
    let opts = quick_opts();
    let bench = Bench::new("gmm-hd64", 0.0, &opts);
    let base = eval_cell(&bench, &Cell::plain("ddim", 5), &opts).unwrap().gfid;
    let tp = eval_cell(
        &bench,
        &Cell {
            tp: true,
            ..Cell::plain("ddim", 5)
        },
        &opts,
    )
    .unwrap()
    .gfid;
    let tp_pas = eval_cell(
        &bench,
        &Cell {
            tp: true,
            ..Cell::pas("ddim", 5)
        },
        &opts,
    )
    .unwrap()
    .gfid;
    assert!(tp < base, "TP should help at NFE 5: {base} -> {tp}");
    assert!(tp_pas < tp, "PAS should stack on TP: {tp} -> {tp_pas}");
}

/// Adaptive search stores strictly fewer parameters than correct-everything
/// while staying competitive. (The paper's Table 7 finds PAS(-AS) actively
/// *harmful*; with our denser Adam-trained coordinates the forced
/// corrections are better behaved, so the robust invariant is the
/// parameter saving — see EXPERIMENTS.md "Divergences".)
#[test]
fn pas_without_adaptive_search_is_harmful() {
    let opts = quick_opts();
    let bench = Bench::new("gmm-hd64", 0.0, &opts);
    let solver = pas::solvers::registry::get("ddim").unwrap();
    let sched = default_schedule(8);
    let trainer = PasTrainer::new(default_train(&opts, "ddim"));
    let all = trainer
        .train(solver.as_ref(), bench.model.as_ref(), &sched, "gmm-hd64", true)
        .unwrap();
    assert_eq!(all.dict.steps.len(), 8, "force_all must store every step");
    let adaptive = trainer
        .train(solver.as_ref(), bench.model.as_ref(), &sched, "gmm-hd64", false)
        .unwrap();
    assert!(
        adaptive.dict.steps.len() < 8,
        "adaptive must skip some steps"
    );
    // Evaluate both.
    let n = opts.n_samples;
    let mut rng = Pcg64::seed(5150);
    let x_t = sample_prior(&mut rng, n, 64, sched.t_max());
    let f = |dict: &CoordinateDict| {
        let run = CorrectedSampler::sample(dict, solver.as_ref(), bench.model.as_ref(), &x_t, n, &sched);
        gfid(&run.x0, n, &bench.reference, bench.n_ref, 64)
    };
    let f_all = f(&all.dict);
    let f_adp = f(&adaptive.dict);
    assert!(
        adaptive.dict.n_params() < all.dict.n_params(),
        "adaptive must store fewer parameters"
    );
    assert!(
        f_adp < f_all * 1.5,
        "adaptive ({f_adp}) must stay competitive with correct-everything ({f_all})"
    );
}

/// PAS trained on iPNDM must respect the multistep history (corrected
/// directions feed the AB combination) and still help.
#[test]
fn pas_on_ipndm_multistep() {
    let mut opts = quick_opts();
    opts.epochs = 32;
    let bench = Bench::new("gmm-hd64", 0.0, &opts);
    let ipndm = eval_cell(&bench, &Cell::plain("ipndm", 6), &opts).unwrap().gfid;
    let ipndm_pas = eval_cell(&bench, &Cell::pas("ipndm", 6), &opts).unwrap().gfid;
    // iPNDM already has small error; PAS must not make it meaningfully worse.
    assert!(
        ipndm_pas <= ipndm * 1.1,
        "PAS on iPNDM regressed: {ipndm} -> {ipndm_pas}"
    );
}

/// Fault injection: a dictionary with mismatched basis count or absurd
/// coordinates must not crash sampling (robust serving path).
#[test]
fn corrupt_dict_does_not_crash() {
    let opts = quick_opts();
    let bench = Bench::new("gmm2d", 0.0, &opts);
    let solver = pas::solvers::registry::get("ddim").unwrap();
    let sched = default_schedule(6);
    let mut dict = CoordinateDict::new(
        8, // more basis vectors than the trajectory can span
        pas::pas::coords::ScaleMode::Absolute,
        "ddim",
        "gmm2d",
        6,
    );
    dict.steps.insert(3, vec![1e6, -1e6, 0.0, 0.0, 1.0, 2.0, 3.0, 4.0]);
    let mut rng = Pcg64::seed(99);
    let x_t = sample_prior(&mut rng, 8, 2, sched.t_max());
    let run = CorrectedSampler::sample(&dict, solver.as_ref(), bench.model.as_ref(), &x_t, 8, &sched);
    assert_eq!(run.x0.len(), 16); // completes; output may be garbage but sized
}

/// Conditional + guidance path end to end.
#[test]
fn guided_conditional_pipeline() {
    let mut opts = quick_opts();
    opts.n_samples = 256;
    let bench = Bench::new("cond-gmm64", 7.5, &opts);
    let base = eval_cell(&bench, &Cell::plain("ddim", 8), &opts).unwrap().gfid;
    let pas = eval_cell(&bench, &Cell::pas("ddim", 8), &opts).unwrap().gfid;
    assert!(
        pas < base,
        "PAS must improve guided DDIM: {base} -> {pas}"
    );
}
