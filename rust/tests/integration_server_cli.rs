//! Integration: serving path with a PAS dictionary registered, TCP
//! protocol round-trips, and the CLI surface driven in-process.

use pas::experiments::common::default_train;
use pas::experiments::ExpOpts;
use pas::pas::train::PasTrainer;
use pas::schedule::default_schedule;
use pas::score::analytic::AnalyticEps;
use pas::server::{SamplingRequest, Service, ServiceConfig};
use std::io::{BufRead, BufReader, Write};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;

fn train_quick_dict() -> pas::pas::coords::CoordinateDict {
    let opts = ExpOpts {
        n_traj: 48,
        epochs: 16,
        ..ExpOpts::quick()
    };
    let ds = pas::data::registry::get("gmm2d").unwrap();
    let model = AnalyticEps::from_dataset(&ds);
    let solver = pas::solvers::registry::get("ddim").unwrap();
    let sched = default_schedule(8);
    PasTrainer::new(default_train(&opts, "ddim"))
        .train(solver.as_ref(), model.as_ref(), &sched, "gmm2d", false)
        .unwrap()
        .dict
}

#[test]
fn service_applies_registered_pas_dict() {
    let dict = train_quick_dict();
    assert!(!dict.steps.is_empty());
    let svc = Service::start(ServiceConfig::default(), vec![dict]);
    let req = |use_pas: bool| SamplingRequest {
        id: 0,
        dataset: "gmm2d".into(),
        solver: "ddim".into(),
        nfe: 8,
        n_samples: 64,
        seed: 7,
        use_pas,
        deadline_ms: None,
        priority: 0,
    };
    let plain = svc.call(req(false)).unwrap();
    let pas_r = svc.call(req(true)).unwrap();
    assert!(plain.error.is_none() && pas_r.error.is_none());
    // Same seed → same prior; PAS must change the outputs.
    assert_ne!(plain.samples, pas_r.samples);
    svc.shutdown();
}

#[test]
fn tcp_roundtrip_with_pas_flag() {
    let dict = train_quick_dict();
    let svc = Arc::new(Service::start(ServiceConfig::default(), vec![dict]));
    let stop = Arc::new(AtomicBool::new(false));
    let addr = pas::server::protocol::serve(svc, "127.0.0.1:0", stop.clone()).unwrap();
    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    conn.write_all(
        b"{\"dataset\":\"gmm2d\",\"solver\":\"ddim\",\"nfe\":8,\"n\":4,\"seed\":1,\"pas\":true}\n",
    )
    .unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let j = pas::util::json::Json::parse(line.trim()).unwrap();
    assert!(j.get("error").is_none(), "{line}");
    assert_eq!(j.get("samples").unwrap().as_arr().unwrap().len(), 8);
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
}

/// Drive the CLI in-process: train → sample with coords → dump-data.
#[test]
fn cli_train_sample_dump_flow() {
    let dir = std::env::temp_dir().join("pas_cli_it");
    std::fs::create_dir_all(&dir).unwrap();
    let coords = dir.join("c.json");
    let argv = |s: &str| -> Vec<String> { s.split_whitespace().map(|x| x.to_string()).collect() };

    let code = pas::cli::main(argv(&format!(
        "train --dataset gmm2d --solver ddim --nfe 6 --n-traj 32 --epochs 8 --out {}",
        coords.display()
    )));
    assert_eq!(code, 0);
    assert!(coords.exists());

    let out = dir.join("samples.json");
    let code = pas::cli::main(argv(&format!(
        "sample --dataset gmm2d --solver ddim --nfe 6 --n 16 --coords {} --out {}",
        coords.display(),
        out.display()
    )));
    assert_eq!(code, 0);
    let j = pas::util::json::Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
    assert_eq!(j.get("samples").unwrap().as_arr().unwrap().len(), 32);

    let data = dir.join("d");
    let code = pas::cli::main(argv(&format!(
        "dump-data --dataset gmm2d --n 100 --out {}",
        data.display()
    )));
    assert_eq!(code, 0);
    let bin = std::fs::read(data.with_extension("bin")).unwrap();
    assert_eq!(bin.len(), 100 * 2 * 4);

    // Error paths return nonzero.
    assert_eq!(pas::cli::main(argv("sample --dataset nope")), 1);
    assert_eq!(pas::cli::main(argv("train --solver heun --dataset gmm2d")), 1);
    let _ = std::fs::remove_dir_all(dir);
}

/// The quick fig3 experiment end to end through the public runner API.
#[test]
fn repro_fig3_quick_runs() {
    let mut opts = ExpOpts::quick();
    opts.n_traj = 48;
    opts.epochs = 16;
    opts.out_dir = std::env::temp_dir().join("pas_results_it");
    let tables = pas::experiments::run_and_save("fig3", &opts).unwrap();
    assert_eq!(tables.len(), 2);
    assert!(opts.out_dir.join("fig3.md").exists());
    // The S-shape statistic row exists and the corrected curve endpoint is
    // no worse than the uncorrected one.
    let unc: f64 = tables[0].rows[0].1.last().unwrap().parse().unwrap();
    let cor: f64 = tables[0].rows[1].1.last().unwrap().parse().unwrap();
    assert!(cor <= unc, "fig3: corrected {cor} vs uncorrected {unc}");
    let _ = std::fs::remove_dir_all(&opts.out_dir);
}
