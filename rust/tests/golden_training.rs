//! Golden training fixture: a fixed-seed trained [`CoordinateDict`] pinned
//! **bitwise** against a checked-in fixture, across shard caps {1, 2, 16}
//! — so refactors of the training stack cannot silently move a single bit
//! of the learned coordinates, and the sharded `TrainSession` stays
//! exactly deterministic for every thread count.
//!
//! Three pins, one config (DDIM @ 6 steps on gmm-hd64, quick
//! hyperparameters):
//!
//! 1. **Thread invariance:** `TrainSession::with_threads(cfg, t)` for
//!    t ∈ {1, 2, 16} produces identical dicts (coordinates compared by
//!    f64 bits) and identical curves.
//! 2. **Oracle parity:** the session reproduces
//!    `PasTrainer::train_tp_reference` — the pre-refactor sequential
//!    monolith — bit for bit.
//! 3. **Fixture stability:** the dict matches
//!    `tests/fixtures/golden_training.txt`. Like
//!    `golden_trajectories.rs`, the fixture **self-bootstraps**: when the
//!    file is missing it is written from the oracle and a reminder to
//!    commit it is printed. Delete the file to intentionally re-pin.

use pas::pas::coords::{CoordinateDict, ScaleMode};
use pas::pas::train::{PasTrainer, TrainConfig, TrainSession};
use pas::schedule::default_schedule;
use pas::score::analytic::AnalyticEps;
use pas::solvers::registry;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::PathBuf;

const DATASET: &str = "gmm-hd64";
const SOLVER: &str = "ddim";
const N_STEPS: usize = 6;

fn golden_cfg() -> TrainConfig {
    TrainConfig {
        n_traj: 48,
        epochs: 24,
        minibatch: 16,
        teacher_nfe: 60,
        lr: 5e-2,
        scale_mode: ScaleMode::Relative,
        seed: 424242,
        ..TrainConfig::default()
    }
}

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join("golden_training.txt")
}

/// Dict coordinates as per-step f64 bit patterns.
fn dict_bits(dict: &CoordinateDict) -> BTreeMap<usize, Vec<u64>> {
    dict.steps
        .iter()
        .map(|(i, c)| (*i, c.iter().map(|v| v.to_bits()).collect()))
        .collect()
}

fn render(bits: &BTreeMap<usize, Vec<u64>>) -> String {
    let mut out = String::from(
        "# Golden trained coordinates (bitwise): `step_i hex(coord f64 bits)...`\n\
         # Written by tests/golden_training.rs; delete to regenerate.\n",
    );
    for (i, coords) in bits {
        let mut line = format!("{i}");
        for b in coords {
            write!(line, " {b:016x}").unwrap();
        }
        out.push_str(&line);
        out.push('\n');
    }
    out
}

fn parse_fixture(text: &str) -> BTreeMap<usize, Vec<u64>> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let i: usize = it.next().expect("step index").parse().expect("step index");
        let bits: Vec<u64> = it
            .map(|h| u64::from_str_radix(h, 16).expect("fixture hex"))
            .collect();
        out.insert(i, bits);
    }
    out
}

#[test]
fn trained_dict_is_bitwise_stable_across_thread_caps() {
    // Bitwise fixture: exclude the reduced-rounding FMA kernel tier (see
    // golden_trajectories.rs; tolerances live in backend_parity.rs).
    {
        use pas::tensor::gemm::{backend, force_backend, Backend};
        if !backend().bit_identical() {
            eprintln!(
                "notice: golden fixtures exclude the {} tier; pinning avx2",
                backend().name()
            );
            force_backend(Backend::Avx2);
        }
    }
    let ds = pas::data::registry::get(DATASET).unwrap();
    let model = AnalyticEps::from_dataset(&ds);
    let solver = registry::get(SOLVER).unwrap();
    let sched = default_schedule(N_STEPS);
    let cfg = golden_cfg();

    // Oracle: the sequential pre-refactor path.
    let oracle = PasTrainer::new(cfg.clone())
        .train_tp_reference(solver.as_ref(), model.as_ref(), &sched, DATASET, false, None)
        .unwrap();
    assert!(
        !oracle.dict.steps.is_empty(),
        "golden config must correct at least one step for the pin to be meaningful"
    );
    let want = dict_bits(&oracle.dict);

    // Sessions at every shard cap must reproduce the oracle exactly.
    for threads in [1usize, 2, 16] {
        let got = TrainSession::with_threads(cfg.clone(), threads)
            .train(solver.as_ref(), model.as_ref(), &sched, DATASET, false, None)
            .unwrap();
        assert_eq!(
            dict_bits(&got.dict),
            want,
            "trained dict diverged from the sequential oracle at threads={threads}"
        );
        assert_eq!(
            got.curve_corrected, oracle.curve_corrected,
            "corrected curve diverged at threads={threads}"
        );
        assert_eq!(
            got.curve_uncorrected, oracle.curve_uncorrected,
            "uncorrected curve diverged at threads={threads}"
        );
    }

    // Fixture pin (self-bootstrapping, like golden_trajectories.rs).
    let path = fixture_path();
    match std::fs::read_to_string(&path) {
        Ok(text) => {
            let fixture = parse_fixture(&text);
            assert_eq!(
                want,
                fixture,
                "trained coordinates drifted bitwise from the fixture \
                 (delete {} to intentionally re-pin)",
                path.display()
            );
        }
        Err(_) => {
            std::fs::create_dir_all(path.parent().unwrap()).expect("create fixtures dir");
            std::fs::write(&path, render(&want)).expect("write fixture");
            eprintln!(
                "golden_training: bootstrapped fixture ({} corrected steps) — commit {}",
                want.len(),
                path.display()
            );
        }
    }
}
