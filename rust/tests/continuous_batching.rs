//! Integration: step-level continuous batching through the public
//! service API.
//!
//! The scheduler's determinism contract makes these tests timing-proof:
//! whatever admission interleaving the threaded service actually
//! produces, every response must be bit-identical to running that request
//! alone — so we stagger submissions with real sleeps (forcing genuine
//! mid-flight admissions most of the time) and still assert exact bits.

use pas::pas::coords::{CoordinateDict, ScaleMode};
use pas::pas::correct::CorrectedSampler;
use pas::schedule::default_schedule;
use pas::score::analytic::AnalyticEps;
use pas::server::{Batching, SamplingRequest, Service, ServiceConfig};
use pas::solvers::engine::{Record, SamplerEngine};
use pas::traj::sample_prior_stream;
use std::time::Duration;

/// Run `req` alone through a fresh serving-configuration engine — the
/// right-hand side of the determinism contract.
fn solo_run(req: &SamplingRequest, id: u64, dict: Option<&CoordinateDict>) -> Vec<f64> {
    let ds = pas::data::registry::get(&req.dataset).unwrap();
    let model = AnalyticEps::from_dataset(&ds);
    let solver = pas::solvers::registry::get(&req.solver).unwrap();
    let steps = solver.steps_for_nfe(req.nfe).unwrap();
    let sched = default_schedule(steps);
    let dim = model.dim();
    let x_t = sample_prior_stream(req.seed, id, req.n_samples, dim, sched.t_max());
    let mut x0 = vec![0.0; req.n_samples * dim];
    let mut engine = SamplerEngine::with_record(Record::None);
    match dict {
        Some(d) => {
            let mut hook = CorrectedSampler::new(d, dim);
            engine.run_into(
                solver.as_ref(),
                model.as_ref(),
                &x_t,
                req.n_samples,
                &sched,
                Some(&mut hook),
                &mut x0,
            );
        }
        None => {
            engine.run_into(
                solver.as_ref(),
                model.as_ref(),
                &x_t,
                req.n_samples,
                &sched,
                None,
                &mut x0,
            );
        }
    }
    x0
}

fn request(dataset: &str, solver: &str, nfe: usize, n: usize, seed: u64) -> SamplingRequest {
    SamplingRequest {
        id: 0,
        dataset: dataset.into(),
        solver: solver.into(),
        nfe,
        n_samples: n,
        seed,
        use_pas: false,
    }
}

/// Staggered arrivals into one compatibility key: every response must
/// match its solo run bitwise, across engine thread caps.
#[test]
fn staggered_arrivals_match_solo_runs_bitwise() {
    for engine_threads in [1usize, 4, 16] {
        let svc = Service::start(
            ServiceConfig {
                workers: 2,
                engine_threads,
                ..ServiceConfig::default()
            },
            Vec::new(),
        );
        // Mixed solvers (two keys) with staggered submission so later
        // requests usually land while earlier ones are mid-flight.
        let reqs: Vec<SamplingRequest> = (0..10)
            .map(|i| {
                let (solver, nfe) = if i % 3 == 0 { ("dpmpp3m", 12) } else { ("ddim", 12) };
                request("gmm-hd64", solver, nfe, 8 + (i as usize % 5), i)
            })
            .collect();
        let mut rxs = Vec::new();
        for r in &reqs {
            rxs.push(svc.submit(r.clone()).unwrap());
            std::thread::sleep(Duration::from_micros(300));
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
            assert_eq!(resp.n, reqs[i].n_samples);
            let want = solo_run(&reqs[i], resp.id, None);
            assert_eq!(
                resp.samples, want,
                "request {i} (engine_threads={engine_threads}) diverged from its solo run"
            );
        }
        svc.shutdown();
    }
}

/// Same through the PAS correction path with a registered dictionary.
#[test]
fn corrected_staggered_arrivals_match_solo_runs() {
    let mut dict = CoordinateDict::new(4, ScaleMode::Relative, "ddim", "gmm2d", 6);
    dict.steps.insert(4, vec![0.95, 0.02, 0.0, 0.0]);
    dict.steps.insert(1, vec![1.0, 0.0, -0.05, 0.0]);
    let svc = Service::start(ServiceConfig::default(), vec![dict.clone()]);
    let reqs: Vec<SamplingRequest> = (0..6)
        .map(|i| {
            let mut r = request("gmm2d", "ddim", 6, 4 + i as usize, 100 + i);
            r.use_pas = true;
            r
        })
        .collect();
    let mut rxs = Vec::new();
    for r in &reqs {
        rxs.push(svc.submit(r.clone()).unwrap());
        std::thread::sleep(Duration::from_micros(200));
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none());
        let want = solo_run(&reqs[i], resp.id, Some(&dict));
        assert_eq!(
            resp.samples, want,
            "corrected request {i} diverged from its solo run"
        );
    }
    svc.shutdown();
}

/// The collect-then-run baseline stays available and bit-compatible: its
/// responses match the same solo runs the continuous scheduler matches.
#[test]
fn collect_then_run_baseline_matches_same_contract() {
    let svc = Service::start(
        ServiceConfig {
            batching: Batching::CollectThenRun,
            batch_window: Duration::from_millis(5),
            ..ServiceConfig::default()
        },
        Vec::new(),
    );
    let reqs: Vec<SamplingRequest> =
        (0..5).map(|i| request("gmm2d", "ipndm", 8, 6, 40 + i)).collect();
    let rxs: Vec<_> = reqs.iter().map(|r| svc.submit(r.clone()).unwrap()).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none());
        let want = solo_run(&reqs[i], resp.id, None);
        assert_eq!(resp.samples, want, "collect-then-run request {i}");
    }
    svc.shutdown();
}

/// Protocol-level errors surface as structured error responses over the
/// full stack (strict parsing feeds the service the validated request).
#[test]
fn service_reports_structured_errors() {
    let svc = Service::start(ServiceConfig::default(), Vec::new());
    for line in [
        r#"{"dataset":"not-a-dataset","solver":"ddim","nfe":6,"n":2}"#,
        r#"{"dataset":"gmm2d","solver":"not-a-solver","nfe":6,"n":2}"#,
        r#"{"dataset":"gmm2d","solver":"ddim","nfe":6,"n":9999}"#,
        r#"{"dataset":"gmm2d","solver":"ddim","nfe":6,"n":2,"seed":-3}"#,
    ] {
        let err = pas::server::protocol::parse_request(line);
        assert!(err.is_err(), "{line} must be rejected at the protocol layer");
    }
    // A valid request still flows end to end.
    let ok = pas::server::protocol::parse_request(
        r#"{"dataset":"gmm2d","solver":"ddim","nfe":6,"n":2,"seed":18446744073709551615}"#,
    )
    .unwrap();
    assert_eq!(ok.seed, u64::MAX);
    let resp = svc.call(ok).unwrap();
    assert!(resp.error.is_none());
    svc.shutdown();
}
