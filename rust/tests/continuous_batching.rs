//! Integration: step-level continuous batching through the public
//! service API.
//!
//! The scheduler's determinism contract makes these tests timing-proof:
//! whatever admission interleaving the threaded service actually
//! produces, every response must be bit-identical to running that request
//! alone — so we stagger submissions with real sleeps (forcing genuine
//! mid-flight admissions most of the time) and still assert exact bits.

use pas::pas::coords::{CoordinateDict, ScaleMode};
use pas::pas::correct::CorrectedSampler;
use pas::schedule::default_schedule;
use pas::score::analytic::AnalyticEps;
use pas::server::{Batching, SamplingRequest, Service, ServiceConfig};
use pas::solvers::engine::{Record, SamplerEngine};
use pas::traj::sample_prior_stream;
use std::sync::atomic::Ordering;
use std::time::Duration;

/// Run `req` alone through a fresh serving-configuration engine — the
/// right-hand side of the determinism contract.
fn solo_run(req: &SamplingRequest, id: u64, dict: Option<&CoordinateDict>) -> Vec<f64> {
    let ds = pas::data::registry::get(&req.dataset).unwrap();
    let model = AnalyticEps::from_dataset(&ds);
    let solver = pas::solvers::registry::get(&req.solver).unwrap();
    let steps = solver.steps_for_nfe(req.nfe).unwrap();
    let sched = default_schedule(steps);
    let dim = model.dim();
    let x_t = sample_prior_stream(req.seed, id, req.n_samples, dim, sched.t_max());
    let mut x0 = vec![0.0; req.n_samples * dim];
    let mut engine = SamplerEngine::with_record(Record::None);
    match dict {
        Some(d) => {
            let mut hook = CorrectedSampler::new(d, dim);
            engine.run_into(
                solver.as_ref(),
                model.as_ref(),
                &x_t,
                req.n_samples,
                &sched,
                Some(&mut hook),
                &mut x0,
            );
        }
        None => {
            engine.run_into(
                solver.as_ref(),
                model.as_ref(),
                &x_t,
                req.n_samples,
                &sched,
                None,
                &mut x0,
            );
        }
    }
    x0
}

fn request(dataset: &str, solver: &str, nfe: usize, n: usize, seed: u64) -> SamplingRequest {
    SamplingRequest {
        id: 0,
        dataset: dataset.into(),
        solver: solver.into(),
        nfe,
        n_samples: n,
        seed,
        use_pas: false,
        deadline_ms: None,
        priority: 0,
    }
}

/// Staggered arrivals into one compatibility key: every response must
/// match its solo run bitwise, across engine thread caps.
#[test]
fn staggered_arrivals_match_solo_runs_bitwise() {
    for engine_threads in [1usize, 4, 16] {
        let svc = Service::start(
            ServiceConfig {
                workers: 2,
                engine_threads,
                ..ServiceConfig::default()
            },
            Vec::new(),
        );
        // Mixed solvers (two keys) with staggered submission so later
        // requests usually land while earlier ones are mid-flight.
        let reqs: Vec<SamplingRequest> = (0..10)
            .map(|i| {
                let (solver, nfe) = if i % 3 == 0 { ("dpmpp3m", 12) } else { ("ddim", 12) };
                request("gmm-hd64", solver, nfe, 8 + (i as usize % 5), i)
            })
            .collect();
        let mut rxs = Vec::new();
        for r in &reqs {
            rxs.push(svc.submit(r.clone()).unwrap());
            std::thread::sleep(Duration::from_micros(300));
        }
        for (i, rx) in rxs.into_iter().enumerate() {
            let resp = rx.recv().unwrap();
            assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
            assert_eq!(resp.n, reqs[i].n_samples);
            let want = solo_run(&reqs[i], resp.id, None);
            assert_eq!(
                resp.samples, want,
                "request {i} (engine_threads={engine_threads}) diverged from its solo run"
            );
        }
        svc.shutdown();
    }
}

/// Same through the PAS correction path with a registered dictionary.
#[test]
fn corrected_staggered_arrivals_match_solo_runs() {
    let mut dict = CoordinateDict::new(4, ScaleMode::Relative, "ddim", "gmm2d", 6);
    dict.steps.insert(4, vec![0.95, 0.02, 0.0, 0.0]);
    dict.steps.insert(1, vec![1.0, 0.0, -0.05, 0.0]);
    let svc = Service::start(ServiceConfig::default(), vec![dict.clone()]);
    let reqs: Vec<SamplingRequest> = (0..6)
        .map(|i| {
            let mut r = request("gmm2d", "ddim", 6, 4 + i as usize, 100 + i);
            r.use_pas = true;
            r
        })
        .collect();
    let mut rxs = Vec::new();
    for r in &reqs {
        rxs.push(svc.submit(r.clone()).unwrap());
        std::thread::sleep(Duration::from_micros(200));
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none());
        let want = solo_run(&reqs[i], resp.id, Some(&dict));
        assert_eq!(
            resp.samples, want,
            "corrected request {i} diverged from its solo run"
        );
    }
    svc.shutdown();
}

/// The collect-then-run baseline stays available and bit-compatible: its
/// responses match the same solo runs the continuous scheduler matches.
#[test]
fn collect_then_run_baseline_matches_same_contract() {
    let svc = Service::start(
        ServiceConfig {
            batching: Batching::CollectThenRun,
            batch_window: Duration::from_millis(5),
            ..ServiceConfig::default()
        },
        Vec::new(),
    );
    let reqs: Vec<SamplingRequest> =
        (0..5).map(|i| request("gmm2d", "ipndm", 8, 6, 40 + i)).collect();
    let rxs: Vec<_> = reqs.iter().map(|r| svc.submit(r.clone()).unwrap()).collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none());
        let want = solo_run(&reqs[i], resp.id, None);
        assert_eq!(resp.samples, want, "collect-then-run request {i}");
    }
    svc.shutdown();
}

/// Hot-reload mid-flight: publishing a new dict version while a cohort is
/// in flight must leave that cohort on its admission-time snapshot
/// (bit-identical to a solo run with the old dict) while requests
/// admitted after the publish use the new version — and the published
/// versions must survive a restart through the artifact store.
#[test]
fn hot_reload_mid_flight_swaps_dicts_per_cohort() {
    let dir = std::env::temp_dir().join(format!("pas_hot_reload_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = || ServiceConfig {
        workers: 1,
        artifact_root: Some(dir.clone()),
        ..ServiceConfig::default()
    };
    let svc = Service::start(cfg(), Vec::new());
    let (nfe, n) = (2000usize, 32usize); // long rollout: publish lands mid-flight
    let mut dict_a = CoordinateDict::new(4, ScaleMode::Relative, "ddim", "gmm2d", nfe);
    dict_a.steps.insert(4, vec![0.9, 0.05, 0.0, 0.0]);
    let mut dict_b = dict_a.clone();
    dict_b.steps.insert(4, vec![1.1, -0.08, 0.02, 0.0]);
    dict_b.steps.insert(2, vec![1.0, 0.0, -0.1, 0.0]);
    assert_eq!(
        svc.publish_dict("gmm2d", "ddim", nfe, dict_a.clone()).unwrap(),
        Some(1)
    );

    let mut req1 = request("gmm2d", "ddim", nfe, n, 7);
    req1.use_pas = true;
    let rx1 = svc.submit(req1.clone()).unwrap();
    // Wait for req1's cohort to form. Its dict snapshot is taken before
    // the `batches` counter increments, so batches >= 1 proves the
    // snapshot (of A) predates the publish of B below. And because the
    // scheduler always ticks between admission phases, a request
    // submitted after this point can never merge into req1's cohort.
    let t0 = std::time::Instant::now();
    while svc.metrics.batches.load(Ordering::Relaxed) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "cohort never formed");
        std::thread::sleep(Duration::from_micros(200));
    }
    assert_eq!(
        svc.publish_dict("gmm2d", "ddim", nfe, dict_b.clone()).unwrap(),
        Some(2)
    );
    let mut req2 = request("gmm2d", "ddim", nfe, 8, 8);
    req2.use_pas = true;
    let rx2 = svc.submit(req2.clone()).unwrap();

    let resp1 = rx1.recv().unwrap();
    let resp2 = rx2.recv().unwrap();
    assert!(resp1.error.is_none(), "{:?}", resp1.error);
    assert!(resp2.error.is_none(), "{:?}", resp2.error);
    // The in-flight cohort finished on its snapshot (A), bitwise...
    assert_eq!(resp1.samples, solo_run(&req1, resp1.id, Some(&dict_a)));
    assert_ne!(resp1.samples, solo_run(&req1, resp1.id, Some(&dict_b)));
    // ...while the post-publish admission used B.
    assert_eq!(resp2.samples, solo_run(&req2, resp2.id, Some(&dict_b)));
    assert_ne!(resp2.samples, solo_run(&req2, resp2.id, Some(&dict_a)));
    assert_eq!(svc.metrics.dicts_published.load(Ordering::Relaxed), 2);
    let snap = svc.dict_snapshot("gmm2d", "ddim", nfe).unwrap();
    assert_eq!(snap.to_json().to_string(), dict_b.to_json().to_string());
    svc.shutdown();

    // Restart: the store hands back exactly the last published version.
    let svc2 = Service::start(cfg(), Vec::new());
    assert_eq!(svc2.metrics.artifacts_loaded.load(Ordering::Relaxed), 1);
    let snap2 = svc2.dict_snapshot("gmm2d", "ddim", nfe).unwrap();
    assert_eq!(snap2.to_json().to_string(), dict_b.to_json().to_string());
    svc2.shutdown();
    let _ = std::fs::remove_dir_all(dir);
}

/// SLO admission end to end: under a long-running cohort, a request whose
/// deadline cannot cover its rollout is shed with a structured `deadline`
/// error carrying real timing, while a feasible request admitted to the
/// same busy key still matches its solo run bitwise — shedding changes
/// scheduling, never numerics. The operator surfaces see all of it.
#[test]
fn deadline_shedding_preserves_determinism_and_shows_in_metrics() {
    let svc = Service::start(
        ServiceConfig {
            workers: 1,
            max_batch: 8,
            ..ServiceConfig::default()
        },
        Vec::new(),
    );
    // Long rollout holds the key busy while the SLO requests arrive.
    let blocker = request("gmm2d", "ddim", 2000, 8, 1);
    let rx_blocker = svc.submit(blocker.clone()).unwrap();
    let t0 = std::time::Instant::now();
    while svc.metrics.ticks.load(Ordering::Relaxed) < 2 {
        assert!(t0.elapsed() < Duration::from_secs(10), "blocker never started");
        std::thread::sleep(Duration::from_micros(200));
    }
    let mut hopeless = request("gmm2d", "ddim", 2000, 4, 2);
    hopeless.deadline_ms = Some(0.01);
    let rx_hopeless = svc.submit(hopeless).unwrap();
    let mut feasible = request("gmm2d", "ddim", 2000, 4, 3);
    feasible.deadline_ms = Some(120_000.0);
    feasible.priority = 5;
    let rx_feasible = svc.submit(feasible.clone()).unwrap();

    let shed = rx_hopeless.recv().unwrap();
    let err = shed.error.as_deref().expect("hopeless request must be shed");
    assert!(err.contains("deadline"), "unexpected error: {err}");
    assert!(shed.latency_ms > 0.0, "shed reply must carry real latency");
    assert_eq!(shed.queue_ms, shed.latency_ms);
    assert_eq!(shed.run_ms, 0.0);

    let done = rx_feasible.recv().unwrap();
    assert!(done.error.is_none(), "{:?}", done.error);
    assert_eq!(done.samples, solo_run(&feasible, done.id, None));
    let b = rx_blocker.recv().unwrap();
    assert!(b.error.is_none());
    assert_eq!(b.samples, solo_run(&blocker, b.id, None));

    // Operator surfaces account for every request.
    let text = svc.metrics_text();
    assert!(text.contains("pas_shed_total 1"), "metrics text:\n{text}");
    assert!(text.contains("pas_failed_total 1"), "metrics text:\n{text}");
    assert!(text.contains("pas_completed_total 2"), "metrics text:\n{text}");
    let health = svc.health_json();
    assert_eq!(health.get("in_flight").unwrap().as_f64().unwrap(), 0.0);
    assert_eq!(
        svc.metrics.requests.load(Ordering::Relaxed),
        svc.metrics.completed.load(Ordering::Relaxed)
            + svc.metrics.rejected.load(Ordering::Relaxed)
            + svc.metrics.failed.load(Ordering::Relaxed)
    );
    svc.shutdown();
}

/// Protocol-level errors surface as structured error responses over the
/// full stack (strict parsing feeds the service the validated request).
#[test]
fn service_reports_structured_errors() {
    let svc = Service::start(ServiceConfig::default(), Vec::new());
    for line in [
        r#"{"dataset":"not-a-dataset","solver":"ddim","nfe":6,"n":2}"#,
        r#"{"dataset":"gmm2d","solver":"not-a-solver","nfe":6,"n":2}"#,
        r#"{"dataset":"gmm2d","solver":"ddim","nfe":6,"n":9999}"#,
        r#"{"dataset":"gmm2d","solver":"ddim","nfe":6,"n":2,"seed":-3}"#,
    ] {
        let err = pas::server::protocol::parse_request(line);
        assert!(err.is_err(), "{line} must be rejected at the protocol layer");
    }
    // A valid request still flows end to end.
    let ok = pas::server::protocol::parse_request(
        r#"{"dataset":"gmm2d","solver":"ddim","nfe":6,"n":2,"seed":18446744073709551615}"#,
    )
    .unwrap();
    assert_eq!(ok.seed, u64::MAX);
    let resp = svc.call(ok).unwrap();
    assert!(resp.error.is_none());
    svc.shutdown();
}
