//! Golden-trajectory snapshots: fixed-seed low-NFE runs for every
//! registry solver, pinned **bitwise** against checked-in fixtures so
//! refactors of the solver/engine stack cannot silently move a single
//! bit of output.
//!
//! Budget: NFE = 5 for single-eval solvers; the 2-eval solvers (Heun,
//! DPM-Solver-2) cannot represent 5 (`steps_for_nfe(5) == None` — the
//! paper's "\\" cells), so they snapshot the nearest representable
//! budget, NFE = 6.
//!
//! # Fixture lifecycle
//!
//! `tests/fixtures/golden_trajectories.txt` holds one line per solver:
//! `name n_steps hex(x0_bits)...`. On a machine/toolchain where the file
//! does not yet exist (or misses newly registered solvers), the test
//! **bootstraps** it from [`run_solver_legacy`] — the bit-exactness
//! oracle — and prints a reminder to commit it. Once present, every
//! entry is asserted bit-for-bit against both the legacy driver and the
//! engine. Fixtures pin stability per platform/libm; regenerate (delete
//! the file) when intentionally changing numerics.

use pas::schedule::default_schedule;
use pas::score::analytic::AnalyticEps;
use pas::solvers::engine::{EngineConfig, Record, SamplerEngine};
use pas::solvers::{registry, run_solver_legacy};
use pas::traj::sample_prior;
use pas::util::rng::Pcg64;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::path::PathBuf;

const N: usize = 2;
const DIM: usize = 2; // gmm2d
const SEED: u64 = 424242;

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("fixtures")
        .join("golden_trajectories.txt")
}

/// Deterministic final sample for one solver, via the legacy oracle.
fn golden_run(name: &str) -> (usize, Vec<f64>) {
    let ds = pas::data::registry::get("gmm2d").unwrap();
    let model = AnalyticEps::from_dataset(&ds);
    let solver = registry::get(name).unwrap();
    // NFE 5 where representable, else 6 (2-eval solvers).
    let steps = solver
        .steps_for_nfe(5)
        .or_else(|| solver.steps_for_nfe(6))
        .expect("no representable low-NFE budget");
    let sched = default_schedule(steps);
    let mut rng = Pcg64::seed(SEED);
    let x_t = sample_prior(&mut rng, N, DIM, sched.t_max());
    let run = run_solver_legacy(solver.as_ref(), model.as_ref(), &x_t, N, &sched, None);

    // The engine must agree with the oracle before anything is pinned.
    let mut eng = SamplerEngine::new(EngineConfig {
        record: Record::Full,
        threads: 0,
    });
    let eng_run = eng.run(solver.as_ref(), model.as_ref(), &x_t, N, &sched, None);
    assert_eq!(run.x0, eng_run.x0, "{name}: engine diverges from oracle");

    (steps, run.x0)
}

fn parse_fixtures(text: &str) -> BTreeMap<String, (usize, Vec<u64>)> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut it = line.split_whitespace();
        let name = it.next().expect("fixture name").to_string();
        let steps: usize = it.next().expect("fixture steps").parse().expect("steps");
        let bits: Vec<u64> = it
            .map(|h| u64::from_str_radix(h, 16).expect("fixture hex"))
            .collect();
        out.insert(name, (steps, bits));
    }
    out
}

#[test]
fn golden_trajectories_are_bitwise_stable() {
    // The fixtures pin bits, so the reduced-rounding FMA kernel tier is
    // excluded by contract: if PAS_KERNEL selected it, pin the nearest
    // bit-identical backend instead (tolerances live in
    // tests/backend_parity.rs).
    {
        use pas::tensor::gemm::{backend, force_backend, Backend};
        if !backend().bit_identical() {
            eprintln!(
                "notice: golden fixtures exclude the {} tier; pinning avx2",
                backend().name()
            );
            force_backend(Backend::Avx2);
        }
    }
    let path = fixture_path();
    let existing = std::fs::read_to_string(&path)
        .map(|t| parse_fixtures(&t))
        .unwrap_or_default();

    let mut regenerated = String::from(
        "# Golden low-NFE trajectories (bitwise): `solver n_steps hex(x0 f64 bits)...`\n\
         # Written by tests/golden_trajectories.rs; delete to regenerate.\n",
    );
    let mut missing: Vec<&str> = Vec::new();
    let mut mismatches: Vec<String> = Vec::new();

    for name in registry::ALL {
        let (steps, x0) = golden_run(name);
        let bits: Vec<u64> = x0.iter().map(|v| v.to_bits()).collect();
        let mut line = format!("{name} {steps}");
        for b in &bits {
            write!(line, " {b:016x}").unwrap();
        }
        regenerated.push_str(&line);
        regenerated.push('\n');
        match existing.get(*name) {
            None => missing.push(*name),
            Some((fsteps, fbits)) => {
                if *fsteps != steps || *fbits != bits {
                    mismatches.push(format!(
                        "{name}: fixture ({fsteps} steps, {fbits:x?}) vs run ({steps} steps, {bits:x?})"
                    ));
                }
            }
        }
    }

    assert!(
        mismatches.is_empty(),
        "golden trajectories drifted bitwise:\n  {}\n\
         (delete {} to intentionally re-pin)",
        mismatches.join("\n  "),
        path.display()
    );

    if !missing.is_empty() {
        std::fs::create_dir_all(path.parent().unwrap()).expect("create fixtures dir");
        std::fs::write(&path, regenerated).expect("write fixtures");
        eprintln!(
            "golden_trajectories: bootstrapped {} fixture entr{} ({:?}) — commit {}",
            missing.len(),
            if missing.len() == 1 { "y" } else { "ies" },
            missing,
            path.display()
        );
    }
}
