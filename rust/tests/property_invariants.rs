//! Property-based tests (hand-rolled sweeps — the offline vendor set has
//! no proptest): randomized inputs over many seeds asserting structural
//! invariants of the core machinery.

use pas::data::Mode;
use pas::linalg::{eigh, gram_schmidt, solve_linear, svd_right_vectors};
use pas::pas::pca::{pca_basis, TrajBuffer};
use pas::schedule::Schedule;
use pas::score::analytic::AnalyticEps;
use pas::score::EpsModel;
use pas::solvers::{NodeView, StepCtx, StepScratch};
use pas::tensor::dot;
use pas::util::json::Json;
use pas::util::rng::Pcg64;

const TRIALS: usize = 40;

/// PCA basis: orthonormal, first row pinned to d/||d||, k <= n_basis, for
/// random buffer shapes and dimensions.
#[test]
fn prop_pca_basis_invariants() {
    let mut rng = Pcg64::seed(1);
    for trial in 0..TRIALS {
        let dim = 2 + rng.below(96);
        let rows = rng.below(12);
        let n_basis = 1 + rng.below(4);
        let mut q = TrajBuffer::new(dim);
        for _ in 0..rows {
            q.push(&rng.normal_vec(dim));
        }
        let d = rng.normal_vec(dim);
        let b = pca_basis(&q, &d, n_basis);
        assert!(b.k >= 1 && b.k <= n_basis, "trial {trial}: k={}", b.k);
        let dn = pas::tensor::norm2(&d);
        for j in 0..dim {
            assert!((b.row(0)[j] - d[j] / dn).abs() < 1e-9, "trial {trial}");
        }
        for a in 0..b.k {
            for c in 0..b.k {
                let g = dot(b.row(a), b.row(c));
                let want = if a == c { 1.0 } else { 0.0 };
                assert!((g - want).abs() < 1e-7, "trial {trial}: g[{a}{c}]={g}");
            }
        }
    }
}

/// Analytic eps == -t * (finite-difference gradient of log density) for
/// random mixtures, points and times.
#[test]
fn prop_analytic_eps_is_score() {
    let mut rng = Pcg64::seed(2);
    for trial in 0..20 {
        let dim = 2 + rng.below(4);
        let k = 1 + rng.below(4);
        let modes: Vec<Mode> = (0..k)
            .map(|_| {
                Mode::isotropic(
                    rng.normal_vec(dim),
                    0.2 + rng.uniform(),
                    0.2 + rng.uniform(),
                    0,
                )
            })
            .collect();
        let m = AnalyticEps::new("prop", modes);
        let x = rng.normal_vec(dim);
        let t = 0.2 + 3.0 * rng.uniform();
        let eps = m.eval(&x, 1, t);
        let h = 1e-5;
        for j in 0..dim {
            let mut xp = x.clone();
            xp[j] += h;
            let mut xm = x.clone();
            xm[j] -= h;
            let g = (m.log_density(&xp, t) - m.log_density(&xm, t)) / (2.0 * h);
            assert!(
                (eps[j] + t * g).abs() < 1e-4 * (1.0 + (t * g).abs()),
                "trial {trial} dim {j}: {} vs {}",
                eps[j],
                -t * g
            );
        }
    }
}

/// Schedules: strictly descending, exact endpoints, refinement shares nodes.
#[test]
fn prop_schedule_invariants() {
    let mut rng = Pcg64::seed(3);
    for _ in 0..TRIALS {
        let n = 2 + rng.below(30);
        let t_min = 1e-3 + rng.uniform() * 0.1;
        let t_max = 1.0 + rng.uniform() * 100.0;
        let rho = 1.0 + rng.uniform() * 9.0;
        let s = Schedule::polynomial(n, t_min, t_max, rho);
        assert_eq!(s.ts.len(), n + 1);
        assert!((s.t_max() - t_max).abs() < 1e-9 * t_max);
        assert!((s.t_min() - t_min).abs() < 1e-12 + 1e-9 * t_min);
        for w in s.ts.windows(2) {
            assert!(w[0] > w[1]);
        }
        let m = rng.below(5);
        let r = s.refine(m);
        for (j, &t) in s.ts.iter().enumerate() {
            let tr = r.ts[j * (m + 1)];
            assert!((t - tr).abs() < 1e-8 * t.max(1e-3), "{t} vs {tr}");
        }
    }
}

/// Every PAS-supported solver is affine in the current direction with
/// slope gamma: step(d1) - step(d0) == gamma * (d1 - d0), for random
/// histories and grids.
#[test]
fn prop_solver_affine_in_direction() {
    let mut rng = Pcg64::seed(4);
    for name in ["ddim", "ipndm2", "ipndm3", "ipndm4", "deis-tab3", "dpmpp3m"] {
        let solver = pas::solvers::registry::get(name).unwrap();
        for trial in 0..12 {
            let n_steps = 4 + rng.below(6);
            let sched = Schedule::polynomial(n_steps, 0.01, 10.0, 3.0 + rng.uniform() * 6.0);
            let j = 2 + rng.below(n_steps - 3);
            let xs: Vec<Vec<f64>> = (0..=j).map(|_| vec![rng.normal()]).collect();
            let ds: Vec<Vec<f64>> = (0..j).map(|_| vec![rng.normal()]).collect();
            let ctx = StepCtx {
                j,
                i_paper: n_steps - j,
                t: sched.ts[j],
                t_next: sched.ts[j + 1],
                sched: &sched,
                xs: NodeView::nested(&xs),
                ds: NodeView::nested(&ds),
            };
            let gamma = solver.gamma(&ctx).unwrap();
            let x = vec![xs[j][0]];
            let (d0, d1) = (rng.normal(), rng.normal());
            let model = DummyEps;
            let mut o0 = vec![0.0];
            let mut o1 = vec![0.0];
            let mut buf = vec![0.0; solver.scratch_spec(1, 1).len_for(1)];
            let mut s0 = StepScratch::new(&mut buf);
            solver.step(&model, &ctx, &x, &[d0], 1, &mut o0, &mut s0);
            let mut s1 = StepScratch::new(&mut buf);
            solver.step(&model, &ctx, &x, &[d1], 1, &mut o1, &mut s1);
            let lhs = o1[0] - o0[0];
            let rhs = gamma * (d1 - d0);
            assert!(
                (lhs - rhs).abs() < 1e-9 * (1.0 + rhs.abs()),
                "{name} trial {trial}: {lhs} vs {rhs}"
            );
        }
    }
}

/// Scratch specs: `len_for` is the declared arithmetic, and every
/// registry solver completes full runs with an arena sized *exactly* by
/// its spec (`run_solver_legacy` sizes exactly, so an underdeclared spec
/// would panic in `StepScratch::take`), across batch shapes including
/// the degenerate n = 1.
#[test]
fn prop_scratch_spec_sufficient_for_every_registry_solver() {
    let ds = pas::data::registry::get("gmm2d").unwrap();
    let model = AnalyticEps::from_dataset(&ds);
    let sched = pas::schedule::default_schedule(6);
    let mut rng = Pcg64::seed(12);
    for name in pas::solvers::registry::ALL {
        let solver = pas::solvers::registry::get(name).unwrap();
        for n in [1usize, 3, 8] {
            let spec = solver.scratch_spec(2, n);
            assert_eq!(spec.len_for(n), spec.per_row * n + spec.flat, "{name}");
            let x_t = pas::traj::sample_prior(&mut rng, n, 2, sched.t_max());
            let run = pas::solvers::run_solver_legacy(
                solver.as_ref(),
                model.as_ref(),
                &x_t,
                n,
                &sched,
                None,
            );
            assert!(
                run.x0.iter().all(|v| v.is_finite()),
                "{name} n={n}: non-finite output"
            );
        }
    }
}

struct DummyEps;
impl EpsModel for DummyEps {
    fn dim(&self) -> usize {
        1
    }
    fn eval_batch(&self, _x: &[f64], _n: usize, _t: f64, out: &mut [f64]) {
        out.fill(0.0);
    }
    fn name(&self) -> &str {
        "dummy"
    }
}

/// eigh: eigenvector orthonormality + reconstruction for random PSD
/// matrices of varied size.
#[test]
fn prop_eigh_reconstruction() {
    let mut rng = Pcg64::seed(5);
    for _ in 0..12 {
        let n = 2 + rng.below(24);
        let b = rng.normal_vec(n * n);
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                a[i * n + j] = dot(&b[i * n..(i + 1) * n], &b[j * n..(j + 1) * n]);
            }
        }
        let orig = a.clone();
        let (vals, vecs) = eigh(&mut a, n);
        assert!(vals.windows(2).all(|w| w[0] >= w[1] - 1e-9));
        let mut rec = vec![0.0; n * n];
        for k in 0..n {
            let v = &vecs[k * n..(k + 1) * n];
            for i in 0..n {
                for j in 0..n {
                    rec[i * n + j] += vals[k] * v[i] * v[j];
                }
            }
        }
        let scale = 1.0 + orig.iter().map(|v| v.abs()).fold(0.0, f64::max);
        for i in 0..n * n {
            assert!((rec[i] - orig[i]).abs() < 1e-7 * scale);
        }
    }
}

/// SVD energy conservation: sum of squared singular values == ||X||_F².
#[test]
fn prop_svd_energy() {
    let mut rng = Pcg64::seed(6);
    for _ in 0..TRIALS {
        let r = 1 + rng.below(10);
        let d = r + rng.below(60);
        let x = rng.normal_vec(r * d);
        let (svals, _) = svd_right_vectors(&x, r, d, r);
        let e: f64 = svals.iter().map(|s| s * s).sum();
        let f = dot(&x, &x);
        assert!((e - f).abs() < 1e-7 * (1.0 + f), "{e} vs {f}");
    }
}

/// solve_linear solves random well-conditioned systems.
#[test]
fn prop_solve_linear() {
    let mut rng = Pcg64::seed(7);
    for _ in 0..TRIALS {
        let n = 1 + rng.below(5);
        // Diagonally dominant → well-conditioned.
        let mut a = rng.normal_vec(n * n);
        for i in 0..n {
            a[i * n + i] += 5.0;
        }
        let x_true = rng.normal_vec(n);
        let mut b = vec![0.0; n];
        for i in 0..n {
            b[i] = dot(&a[i * n..(i + 1) * n], &x_true);
        }
        let mut a2 = a.clone();
        solve_linear(&mut a2, &mut b, n).unwrap();
        for i in 0..n {
            assert!((b[i] - x_true[i]).abs() < 1e-8, "{:?} vs {:?}", b, x_true);
        }
    }
}

/// Gram–Schmidt output is always orthonormal and spans no more than the
/// input set.
#[test]
fn prop_gram_schmidt() {
    let mut rng = Pcg64::seed(8);
    for _ in 0..TRIALS {
        let d = 3 + rng.below(40);
        let k = 1 + rng.below(6);
        let cands: Vec<Vec<f64>> = (0..k).map(|_| rng.normal_vec(d)).collect();
        let basis = gram_schmidt(&cands, 4, 1e-8);
        assert!(basis.len() <= k.min(4));
        for i in 0..basis.len() {
            for j in 0..basis.len() {
                let g = dot(&basis[i], &basis[j]);
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g - want).abs() < 1e-7);
            }
        }
    }
}

/// JSON roundtrip for random numeric documents.
#[test]
fn prop_json_roundtrip() {
    let mut rng = Pcg64::seed(9);
    for _ in 0..TRIALS {
        let n = rng.below(20);
        let mut o = Json::obj();
        for i in 0..n {
            let v = match rng.below(4) {
                0 => Json::Num((rng.normal() * 1e3).round() / 16.0),
                1 => Json::Bool(rng.below(2) == 0),
                2 => Json::Str(format!("k{}-\"quote\"\n", rng.below(100))),
                _ => {
                    let len = rng.below(6);
                    Json::from_f64_slice(&rng.normal_vec(len))
                }
            };
            o.set(&format!("key{i}"), v);
        }
        let s = o.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(o, back, "{s}");
    }
}

/// Teleportation is exact on single Gaussians for random anisotropies.
#[test]
fn prop_teleport_matches_ode() {
    let mut rng = Pcg64::seed(10);
    for trial in 0..6 {
        let d = 2 + rng.below(4);
        let mu = rng.normal_vec(d);
        let mut cov = vec![0.0; d * d];
        for j in 0..d {
            cov[j * d + j] = 0.1 + rng.uniform() * 2.0;
        }
        let tp = pas::pas::teleport::Teleporter::from_moments(mu.clone(), &cov);
        let model = AnalyticEps::new("g", vec![Mode::full(mu, &cov, 1.0, 0)]);
        let (hi, lo) = (40.0, 8.0);
        let x0: Vec<f64> = rng.normal_vec(d).iter().map(|z| z * hi).collect();
        let sched = Schedule::log_snr(600, lo, hi);
        let ode = pas::solvers::run_solver(
            pas::solvers::registry::get("heun").unwrap().as_ref(),
            model.as_ref(),
            &x0,
            1,
            &sched,
            None,
        );
        let mut xt = x0.clone();
        tp.teleport(&mut xt, 1, hi, lo);
        for j in 0..d {
            assert!(
                (ode.x0[j] - xt[j]).abs() < 1e-3 * (1.0 + xt[j].abs()),
                "trial {trial} dim {j}: {} vs {}",
                ode.x0[j],
                xt[j]
            );
        }
    }
}
