//! Shared counting global allocator for the allocation-audit targets
//! (`tests/alloc_audit.rs` and `benches/pas_overhead.rs` include this via
//! `#[path]` so both enforce the *same* definition of "zero steady-state
//! allocations"). Each including target declares its own
//! `#[global_allocator] static ALLOCATOR: CountingAlloc = CountingAlloc;`.
//!
//! Counts every heap allocation (alloc / alloc_zeroed / realloc) made by
//! any thread; frees are not counted — the audits only care that the
//! steady state performs none.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

pub struct CountingAlloc;

pub static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, s: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, s)
    }
}
