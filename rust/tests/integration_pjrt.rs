//! Integration tests across the runtime boundary: rust loads and executes
//! the AOT-compiled JAX denoiser. Skipped gracefully (with a loud message)
//! when `make artifacts` hasn't run. The whole file needs the `pjrt`
//! feature (vendored xla crate).

#![cfg(feature = "pjrt")]

use pas::score::pjrt::PjrtEps;
use pas::score::EpsModel;
use pas::util::rng::Pcg64;

fn artifacts_present() -> bool {
    let dir = pas::runtime::artifacts_dir();
    let ok = dir.join("eps_gmm-hd64.hlo.txt").exists();
    if !ok {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
    }
    ok
}

#[test]
fn load_and_execute_both_artifacts() {
    if !artifacts_present() {
        return;
    }
    let rt = pas::runtime::Runtime::cpu().unwrap();
    for (name, dim) in [("eps_spiral2d", 2usize), ("eps_gmm-hd64", 64)] {
        let exe = rt.load_artifact(&pas::runtime::artifacts_dir(), name).unwrap();
        assert_eq!(exe.meta.dim, dim);
        let b = exe.meta.batch;
        let x = vec![0.25f32; b * dim];
        let t = vec![1.5f32; b];
        let y = exe.eval_eps(&x, &t).unwrap();
        assert_eq!(y.len(), b * dim);
        assert!(y.iter().all(|v| v.is_finite()));
    }
}

/// Padding path: evaluating n < batch must equal the head of a full-batch
/// evaluation with identical rows.
#[test]
fn padded_eval_matches_full_batch() {
    if !artifacts_present() {
        return;
    }
    let rt = pas::runtime::Runtime::cpu().unwrap();
    let exe = rt
        .load_artifact(&pas::runtime::artifacts_dir(), "eps_gmm-hd64")
        .unwrap();
    let model = PjrtEps::new(exe);
    let d = model.dim();
    let b = model.batch();
    let mut rng = Pcg64::seed(12);
    let rows = rng.normal_vec(10 * d);
    // Full batch: repeat rows cyclically (matching the padding scheme).
    let mut full = vec![0.0; b * d];
    for i in 0..b * d {
        full[i] = rows[i % (10 * d)];
    }
    let out_small = model.eval(&rows, 10, 2.0);
    let out_full = model.eval(&full, b, 2.0);
    for i in 0..10 * d {
        assert!(
            (out_small[i] - out_full[i]).abs() < 1e-5,
            "row mismatch at {i}: {} vs {}",
            out_small[i],
            out_full[i]
        );
    }
}

/// The denoiser must behave like an eps-model: at large t, eps(x, t) ≈ x/t
/// for x drawn from the prior (EDM preconditioning sanity).
#[test]
fn pjrt_model_eps_large_t_structure() {
    if !artifacts_present() {
        return;
    }
    let rt = pas::runtime::Runtime::cpu().unwrap();
    let exe = rt
        .load_artifact(&pas::runtime::artifacts_dir(), "eps_gmm-hd64")
        .unwrap();
    let model = PjrtEps::new(exe);
    let d = model.dim();
    let n = model.batch();
    let t = 80.0;
    let mut rng = Pcg64::seed(13);
    let x: Vec<f64> = rng.normal_vec(n * d).iter().map(|z| z * t).collect();
    let eps = model.eval(&x, n, t);
    // Correlation between eps and x/t should be high.
    let (mut dot, mut na, mut nb) = (0.0, 0.0, 0.0);
    for i in 0..n * d {
        let want = x[i] / t;
        dot += eps[i] * want;
        na += eps[i] * eps[i];
        nb += want * want;
    }
    let corr = dot / (na.sqrt() * nb.sqrt());
    assert!(corr > 0.95, "eps/prior correlation too low: {corr}");
}

/// Full sampling run + PAS training on the PJRT model (miniature version
/// of examples/paper_pipeline.rs, kept fast for CI).
#[test]
fn pas_trains_against_pjrt_model() {
    if !artifacts_present() {
        return;
    }
    let rt = pas::runtime::Runtime::cpu().unwrap();
    let exe = rt
        .load_artifact(&pas::runtime::artifacts_dir(), "eps_gmm-hd64")
        .unwrap();
    let model = PjrtEps::new(exe);
    let solver = pas::solvers::registry::get("ddim").unwrap();
    let sched = pas::schedule::default_schedule(8);
    let cfg = pas::pas::train::TrainConfig {
        n_traj: 16,
        epochs: 12,
        minibatch: 16,
        teacher_nfe: 32,
        lr: 2e-2,
        scale_mode: pas::pas::coords::ScaleMode::Relative,
        ..Default::default()
    };
    let tr = pas::pas::train::PasTrainer::new(cfg)
        .train(solver.as_ref(), &model, &sched, "gmm-hd64", false)
        .unwrap();
    // The corrected training rollout must not be worse than uncorrected.
    let before = tr.curve_uncorrected.last().unwrap();
    let after = tr.curve_corrected.last().unwrap();
    assert!(
        after <= before,
        "PAS on PJRT model regressed: {before} -> {after}"
    );
}
