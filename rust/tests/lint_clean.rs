//! `pas lint` gate: the tree itself must be clean, and every rule must
//! demonstrably fire on the seeded fixture crate under
//! `tests/fixtures/lint/violations/` (exact rule id, file, and line, so
//! a rule that silently stops matching fails here, not in review).

use pas::analysis::{run_lint, LintReport, RuleId};
use pas::util::json::Json;
use std::path::Path;

fn crate_root() -> &'static Path {
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

fn fixture_root() -> std::path::PathBuf {
    crate_root().join("tests/fixtures/lint/violations")
}

fn has(report: &LintReport, rule: RuleId, file: &str, line: usize) -> bool {
    report
        .findings
        .iter()
        .any(|f| f.rule == rule && f.file == file && f.line == line)
}

#[test]
fn tree_is_lint_clean() {
    let report = run_lint(crate_root());
    let rendered: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    assert!(
        report.clean(),
        "pas lint found {} violation(s) in the tree:\n{}",
        report.findings.len(),
        rendered.join("\n")
    );
    assert!(
        report.malformed.is_empty(),
        "reason-less lint:allow comments in the tree: {:?}",
        report
            .malformed
            .iter()
            .map(|s| format!("{}:{}", s.file, s.line))
            .collect::<Vec<_>>()
    );
    let stale: Vec<String> = report
        .suppressions
        .iter()
        .filter(|s| !s.used)
        .map(|s| format!("{}:{} lint:allow({})", s.file, s.line, s.rule))
        .collect();
    assert!(stale.is_empty(), "stale suppressions (nothing to absorb): {stale:?}");
}

#[test]
fn tree_scan_reaches_every_rule() {
    let report = run_lint(crate_root());
    assert!(report.files_scanned > 40, "only {} files scanned", report.files_scanned);
    for r in &report.rules {
        assert!(
            r.sites_scanned > 0,
            "rule {} scanned zero sites — the pass is not running",
            r.rule
        );
    }
    // The tree carries deliberate, reasoned suppressions (gemm closures,
    // lock-free constructors, chaos failpoint); they must all be in use.
    assert!(!report.suppressions.is_empty());
}

#[test]
fn fixture_every_rule_fires_at_pinned_site() {
    let report = run_lint(&fixture_root());
    let rendered: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
    let ctx = rendered.join("\n");
    assert!(
        has(&report, RuleId::SafetyComment, "src/lib.rs", 8),
        "safety-comment did not fire at src/lib.rs:8:\n{ctx}"
    );
    assert!(
        has(&report, RuleId::SimdGating, "src/simd.rs", 4),
        "simd-gating (ungated intrinsic) did not fire at src/simd.rs:4:\n{ctx}"
    );
    assert!(
        has(&report, RuleId::SimdGating, "src/simd.rs", 12),
        "simd-gating (fmadd containment) did not fire at src/simd.rs:12:\n{ctx}"
    );
    assert!(
        has(&report, RuleId::HotPathAlloc, "src/solvers/engine.rs", 4),
        "hot-path-alloc did not fire at src/solvers/engine.rs:4:\n{ctx}"
    );
    assert!(
        has(&report, RuleId::ServerPanic, "src/server/service.rs", 6),
        "server-panic did not fire at src/server/service.rs:6:\n{ctx}"
    );
    assert!(
        has(&report, RuleId::RegistryCoverage, "src/solvers/registry.rs", 1),
        "registry-coverage (hist_depth gap) did not fire:\n{ctx}"
    );
    assert!(
        has(&report, RuleId::RegistryCoverage, "tests/golden_trajectories.rs", 1),
        "registry-coverage (consumer gap) did not fire:\n{ctx}"
    );
    assert!(
        has(&report, RuleId::DependencyFree, "Cargo.toml", 7),
        "dependency-free did not fire at Cargo.toml:7:\n{ctx}"
    );
    // The lock-poisoning unwrap (service.rs:7) and the cfg(test) alloc
    // (engine.rs:17) are exempt by design — no findings there.
    assert!(!has(&report, RuleId::ServerPanic, "src/server/service.rs", 7));
    assert!(!has(&report, RuleId::HotPathAlloc, "src/solvers/engine.rs", 17));
    // The bench consumer sweeps registry::ALL, so it covers every name.
    assert!(!report
        .findings
        .iter()
        .any(|f| f.file == "benches/solver_step.rs"));
}

#[test]
fn fixture_suppression_roundtrip() {
    let report = run_lint(&fixture_root());
    // A matching allow absorbs its finding and is marked used.
    assert!(
        !has(&report, RuleId::SafetyComment, "src/lib.rs", 14),
        "suppressed unsafe at src/lib.rs:14 still reported"
    );
    assert!(report
        .suppressions
        .iter()
        .any(|s| s.file == "src/lib.rs" && s.line == 13 && s.rule == "safety-comment" && s.used));
    // A fn-head allow covers the body.
    assert!(!has(&report, RuleId::HotPathAlloc, "src/solvers/engine.rs", 11));
    assert!(report
        .suppressions
        .iter()
        .any(|s| s.file == "src/solvers/engine.rs" && s.line == 9 && s.used));
    // A wrong rule id does NOT absorb: the finding stands, the allow is
    // reported unused.
    assert!(has(&report, RuleId::SafetyComment, "src/lib.rs", 20));
    assert!(report
        .suppressions
        .iter()
        .any(|s| s.file == "src/lib.rs" && s.line == 19 && s.rule == "hot-path-alloc" && !s.used));
    // A reason-less allow is malformed and does not suppress.
    assert!(has(&report, RuleId::SafetyComment, "src/lib.rs", 26));
    assert!(report
        .malformed
        .iter()
        .any(|s| s.file == "src/lib.rs" && s.line == 25));
}

#[test]
fn fixture_report_json_roundtrip() {
    let report = run_lint(&fixture_root());
    let text = report.to_json().to_string();
    let parsed = Json::parse(&text).expect("LINT_report.json payload parses");
    let Json::Obj(m) = parsed else {
        panic!("report is a JSON object")
    };
    assert_eq!(m["tool"], Json::Str("pas lint".to_string()));
    assert_eq!(
        m["total_findings"],
        Json::UInt(report.findings.len() as u64)
    );
    assert!(matches!(&m["rules"], Json::Arr(a) if a.len() == 6));
    let Json::Arr(findings) = &m["findings"] else {
        panic!("findings is an array")
    };
    assert_eq!(findings.len(), report.findings.len());
    assert!(matches!(&m["malformed_suppressions"], Json::Arr(a) if a.len() == 1));
}
