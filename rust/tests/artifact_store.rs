//! Fault-injection suite for the durable dict artifact store.
//!
//! Drives the *production* write path (the failpoints are compiled in,
//! not a test double) through every crash window the protocol has —
//! truncated blobs, bit-flipped checksums, torn manifests (killed between
//! the tmp-write and the rename, and between the two manifest renames),
//! duplicate and concurrent publishes — and asserts the recovery
//! invariant throughout: the loader falls back to the last good version,
//! never panics, never serves corrupt bits, and a publish → kill →
//! restart → load round-trip yields a `CoordinateDict` bit-identical to
//! the published one. "Bit-identical" is asserted as canonical-JSON byte
//! equality: the serializer is deterministic (sorted keys, exact integer
//! tokens), so equal strings ⇔ equal bits.

use pas::artifact::{self, ArtifactKey, ArtifactStore, FailPoint, ManifestSource, VersionRecord};
use pas::pas::coords::{CoordinateDict, ScaleMode};
use pas::pas::train::TrainConfig;
use pas::server::{Service, ServiceConfig};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

fn unique_dir(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let d = std::env::temp_dir().join(format!(
        "pas_artifact_it_{tag}_{}_{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn dict(nfe: usize, v: f64) -> CoordinateDict {
    let mut d = CoordinateDict::new(4, ScaleMode::Absolute, "ddim", "gmm2d", nfe);
    d.steps.insert(4, vec![v, 0.1, -0.2, 0.0]);
    d.steps.insert(2, vec![1.0, v * 0.5, 0.0, 0.05]);
    d
}

fn bits(d: &CoordinateDict) -> String {
    d.to_json().to_string()
}

fn key() -> ArtifactKey {
    ArtifactKey::new("gmm2d", "ddim", 8)
}

/// A missing or empty store directory is a clean cold start, not an
/// error — for the raw store and for a service configured with one.
#[test]
fn empty_store_is_a_clean_cold_start() {
    let dir = unique_dir("cold");
    let mut store = ArtifactStore::open(&dir).unwrap();
    let rep = artifact::load_all(&mut store);
    assert_eq!(rep.source, Some(ManifestSource::Empty));
    assert!(rep.loaded.is_empty() && rep.failed.is_empty());
    assert!(artifact::verify(&store).ok());

    let svc = Service::start(
        ServiceConfig {
            workers: 1,
            artifact_root: Some(dir.clone()),
            ..ServiceConfig::default()
        },
        Vec::new(),
    );
    assert_eq!(svc.metrics.artifacts_loaded.load(Ordering::Relaxed), 0);
    svc.shutdown();
    let _ = std::fs::remove_dir_all(dir);
}

/// The core durability round-trip: publish, drop every handle, reopen,
/// load — the dict must come back bit-identical, across multiple keys.
#[test]
fn publish_reopen_load_is_bit_identical() {
    let dir = unique_dir("roundtrip");
    let keys = [
        ArtifactKey::new("gmm2d", "ddim", 8),
        ArtifactKey::new("gmm2d", "heun", 8),
        ArtifactKey::new("gmm-hd64", "ddim", 12),
    ];
    let dicts: Vec<CoordinateDict> = (0..3).map(|i| dict(12, 1.0 + i as f64)).collect();
    {
        let mut store = ArtifactStore::open(&dir).unwrap();
        for (k, d) in keys.iter().zip(&dicts) {
            let out = store.publish(k, d).unwrap();
            assert_eq!(out.version, 1);
            assert!(!out.deduplicated);
        }
    }
    let mut store = ArtifactStore::open(&dir).unwrap();
    let rep = artifact::load_all(&mut store);
    assert_eq!(rep.source, Some(ManifestSource::Current));
    assert_eq!(rep.loaded.len(), 3);
    assert!(rep.failed.is_empty());
    for (k, d) in keys.iter().zip(&dicts) {
        let l = rep.loaded.iter().find(|l| &l.key == k).unwrap();
        assert!(!l.healed);
        assert_eq!(bits(&l.dict), bits(d), "{} corrupted in round-trip", k.id());
    }
    let _ = std::fs::remove_dir_all(dir);
}

/// Re-publishing byte-identical content is a no-op: no version consumed,
/// no new manifest generation, content-addressing shares the blob.
#[test]
fn duplicate_publish_deduplicates() {
    let dir = unique_dir("dedup");
    let mut store = ArtifactStore::open(&dir).unwrap();
    let d = dict(8, 1.5);
    assert_eq!(store.publish(&key(), &d).unwrap().version, 1);
    let gen_before = store.load_manifest().0.generation;
    let again = store.publish(&key(), &d).unwrap();
    assert!(again.deduplicated);
    assert_eq!(again.version, 1);
    assert_eq!(store.load_manifest().0.generation, gen_before);
    // Different content does consume a version.
    let out = store.publish(&key(), &dict(8, 2.5)).unwrap();
    assert_eq!((out.version, out.deduplicated), (2, false));
    let _ = std::fs::remove_dir_all(dir);
}

/// Truncated current blob: verify flags it, the loader quarantines it and
/// falls back to the previous version, and the heal persists — a fresh
/// process sees a clean store.
#[test]
fn truncated_blob_falls_back_and_heals() {
    let dir = unique_dir("truncate");
    let (d1, d2) = (dict(8, 1.0), dict(8, 2.0));
    let v2_checksum = {
        let mut store = ArtifactStore::open(&dir).unwrap();
        store.publish(&key(), &d1).unwrap();
        store.publish(&key(), &d2).unwrap().checksum
    };
    let mut store = ArtifactStore::open(&dir).unwrap();
    let blob = store.blob_path(&v2_checksum);
    let bytes = std::fs::read(&blob).unwrap();
    std::fs::write(&blob, &bytes[..bytes.len() / 2]).unwrap();

    assert!(!artifact::verify(&store).ok());
    let l = artifact::load_dict(&mut store, &key()).unwrap();
    assert!(l.healed);
    assert_eq!(l.version, 1);
    assert_eq!(bits(&l.dict), bits(&d1));
    assert!(store.quarantine_path(&v2_checksum).exists());

    let store2 = ArtifactStore::open(&dir).unwrap();
    let rep = artifact::verify(&store2);
    assert!(rep.ok(), "heal must persist: {:?}", rep.errors);
    let _ = std::fs::remove_dir_all(dir);
}

/// Bit-flipped blob with no older version: the key loads nothing — no
/// panic, no corrupt dict served — and other keys are unaffected.
#[test]
fn bit_flipped_only_version_loads_nothing() {
    let dir = unique_dir("bitflip");
    let other = ArtifactKey::new("gmm2d", "ipndm", 8);
    let (d_bad, d_ok) = (dict(8, 1.0), dict(8, 3.0));
    let sum = {
        let mut store = ArtifactStore::open(&dir).unwrap();
        let sum = store.publish(&key(), &d_bad).unwrap().checksum;
        store.publish(&other, &d_ok).unwrap();
        sum
    };
    let mut store = ArtifactStore::open(&dir).unwrap();
    let blob = store.blob_path(&sum);
    let mut bytes = std::fs::read(&blob).unwrap();
    bytes[8] ^= 0x40;
    std::fs::write(&blob, &bytes).unwrap();

    assert!(artifact::load_dict(&mut store, &key()).is_none());
    let rep = artifact::load_all(&mut store);
    assert_eq!(rep.failed.len(), 1);
    assert_eq!(rep.loaded.len(), 1);
    assert_eq!(bits(&rep.loaded[0].dict), bits(&d_ok));
    assert!(store.quarantine_path(&sum).exists());
    let _ = std::fs::remove_dir_all(dir);
}

/// The torn-manifest crash windows, via injected failpoints in the real
/// write path. Either side of the rename pair, a restart recovers a
/// consistent generation and the loaded dict is bit-identical to a
/// version that was once current.
#[test]
fn torn_manifest_recovers_previous_generation() {
    for fp in [FailPoint::ManifestBeforeRename, FailPoint::ManifestBetweenRenames] {
        let dir = unique_dir("torn");
        let (d1, d2) = (dict(8, 1.0), dict(8, 2.0));
        {
            let mut store = ArtifactStore::open(&dir).unwrap();
            store.publish(&key(), &d1).unwrap();
            store.inject_failpoint(fp);
            let err = store.publish(&key(), &d2).unwrap_err();
            assert!(err.contains("injected crash"), "{fp:?}: {err}");
        }
        // "Restart": a fresh handle sweeps orphans and walks the
        // manifest recovery ladder.
        let mut store = ArtifactStore::open(&dir).unwrap();
        let (manifest, source) = store.load_manifest();
        match fp {
            // Crash before any rename: manifest.json untouched.
            FailPoint::ManifestBeforeRename => assert_eq!(source, ManifestSource::Current),
            // Crash between the renames: no manifest.json; recovered
            // from the demoted previous generation.
            _ => assert_eq!(source, ManifestSource::Previous),
        }
        let entry = manifest.get(&key()).unwrap();
        assert_eq!(entry.current.version, 1, "{fp:?}: v2 must not be visible");
        let l = artifact::load_dict(&mut store, &key()).unwrap();
        assert_eq!(bits(&l.dict), bits(&d1), "{fp:?}");
        assert!(!l.healed);
        // The interrupted publish retries cleanly afterwards.
        let out = store.publish(&key(), &d2).unwrap();
        assert_eq!(out.version, 2);
        assert_eq!(
            bits(&artifact::load_dict(&mut store, &key()).unwrap().dict),
            bits(&d2)
        );
        let _ = std::fs::remove_dir_all(dir);
    }
}

/// A scribbled (not just torn) manifest.json: parse-level self-checksum
/// rejects it, the previous generation serves, and the next publish
/// discards the corpse without clobbering the good recovery copy.
#[test]
fn scribbled_manifest_falls_back_and_is_replaced() {
    let dir = unique_dir("scribble");
    let (d1, d2, d3) = (dict(8, 1.0), dict(8, 2.0), dict(8, 3.0));
    let mut store = ArtifactStore::open(&dir).unwrap();
    store.publish(&key(), &d1).unwrap();
    store.publish(&key(), &d2).unwrap(); // current gen 2, prev gen 1
    std::fs::write(dir.join("manifest.json"), b"{\"half a manifest").unwrap();

    let (manifest, source) = store.load_manifest();
    assert_eq!(source, ManifestSource::Previous);
    // One generation lost: prev knows v1 only.
    assert_eq!(manifest.get(&key()).unwrap().current.version, 1);
    assert_eq!(bits(&artifact::load_dict(&mut store, &key()).unwrap().dict), bits(&d1));
    // Publishing on top of the recovered generation drops the corpse.
    let out = store.publish(&key(), &d3).unwrap();
    assert_eq!(out.version, 2);
    let (manifest, source) = store.load_manifest();
    assert_eq!(source, ManifestSource::Current);
    assert_eq!(manifest.get(&key()).unwrap().current.version, 2);
    assert_eq!(bits(&artifact::load_dict(&mut store, &key()).unwrap().dict), bits(&d3));
    let _ = std::fs::remove_dir_all(dir);
}

/// Crash between a blob's tmp-write and its rename: the publish fails,
/// the store is untouched (old version still current and loadable), and
/// the orphaned temp file is swept on reopen.
#[test]
fn blob_crash_leaves_store_intact_and_sweeps_orphan() {
    let dir = unique_dir("blobcrash");
    let (d1, d2) = (dict(8, 1.0), dict(8, 2.0));
    let mut store = ArtifactStore::open(&dir).unwrap();
    store.publish(&key(), &d1).unwrap();
    store.inject_failpoint(FailPoint::BlobBeforeRename);
    assert!(store.publish(&key(), &d2).is_err());
    let orphans = |dir: &PathBuf| -> usize {
        std::fs::read_dir(dir.join("blobs"))
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .count()
    };
    assert_eq!(orphans(&dir), 1, "simulated kill leaves the temp file");
    let mut store = ArtifactStore::open(&dir).unwrap();
    assert_eq!(orphans(&dir), 0, "reopen sweeps it");
    let l = artifact::load_dict(&mut store, &key()).unwrap();
    assert_eq!((l.version, bits(&l.dict) == bits(&d1)), (1, true));
    assert!(artifact::verify(&store).ok());
    let _ = std::fs::remove_dir_all(dir);
}

/// Concurrent publishes through one shared handle: versions are strictly
/// sequential with no gaps or duplicates, and the final state is one of
/// the published dicts, bit-identical.
#[test]
fn concurrent_publishes_are_strictly_versioned() {
    let dir = unique_dir("concurrent");
    let store = Arc::new(Mutex::new(ArtifactStore::open(&dir).unwrap()));
    let n_threads = 4;
    let per_thread = 5;
    let mut handles = Vec::new();
    for t in 0..n_threads {
        let store = store.clone();
        handles.push(std::thread::spawn(move || {
            let mut versions = Vec::new();
            for i in 0..per_thread {
                let d = dict(8, 1.0 + (t * per_thread + i) as f64 * 0.125);
                let out = store.lock().unwrap().publish(&key(), &d).unwrap();
                assert!(!out.deduplicated, "all payloads are distinct");
                versions.push(out.version);
            }
            versions
        }));
    }
    let mut all: Vec<u64> = handles
        .into_iter()
        .flat_map(|h| h.join().unwrap())
        .collect();
    all.sort_unstable();
    let expect: Vec<u64> = (1..=(n_threads * per_thread) as u64).collect();
    assert_eq!(all, expect, "versions must be gap-free and duplicate-free");

    let mut store = Arc::try_unwrap(store)
        .map_err(|_| ())
        .unwrap()
        .into_inner()
        .unwrap();
    let l = artifact::load_dict(&mut store, &key()).unwrap();
    assert_eq!(l.version, (n_threads * per_thread) as u64);
    assert!(artifact::verify(&store).ok());
    // History is capped; blobs for dropped records stay on disk.
    let entry_hist = store.load_manifest().0.get(&key()).unwrap().history.len();
    assert_eq!(entry_hist, pas::artifact::store::HISTORY_KEEP);
    let _ = std::fs::remove_dir_all(dir);
}

/// A blob whose checksum is fine but whose *content* fails dict
/// validation (the hardened `from_json`): quarantined and healed around,
/// same as bit rot — checksums alone don't make an artifact servable.
#[test]
fn semantically_invalid_blob_is_quarantined() {
    let dir = unique_dir("semantic");
    let d1 = dict(8, 1.0);
    let mut store = ArtifactStore::open(&dir).unwrap();
    store.publish(&key(), &d1).unwrap();
    // Valid JSON, not a valid dict (missing fields): write it as a blob
    // and hand-promote it to current, as a buggy publisher would.
    let bad_sum = store.write_blob(b"{\"not\":\"a dict\"}").unwrap();
    let (mut manifest, source) = store.load_manifest();
    {
        let e = manifest.entry_mut(&key());
        let old = e.current.clone();
        e.history.push(old);
        e.current = VersionRecord {
            version: 2,
            checksum: bad_sum.clone(),
        };
    }
    manifest.generation += 1;
    store
        .write_manifest(&manifest, source == ManifestSource::Current)
        .unwrap();

    let rep = artifact::verify(&store);
    assert!(!rep.ok());
    assert!(rep.errors[0].contains("gmm2d/ddim/8 v2"), "{:?}", rep.errors);
    let l = artifact::load_dict(&mut store, &key()).unwrap();
    assert!(l.healed);
    assert_eq!(bits(&l.dict), bits(&d1));
    assert!(store.quarantine_path(&bad_sum).exists());
    assert!(artifact::verify(&store).ok(), "heal persisted");
    let _ = std::fs::remove_dir_all(dir);
}

/// Full service loop: train online (which publishes), restart the
/// service, and the registry is rebuilt from disk bit-identically —
/// ROADMAP open item 1's exact failure mode, closed.
#[test]
fn service_training_survives_restart_bit_identically() {
    let dir = unique_dir("svc_restart");
    let cfg = || ServiceConfig {
        workers: 1,
        artifact_root: Some(dir.clone()),
        ..ServiceConfig::default()
    };
    let trained = {
        let svc = Service::start(cfg(), Vec::new());
        let stats = svc
            .train_pas(
                "gmm2d",
                "ddim",
                8,
                Some(TrainConfig {
                    n_traj: 48,
                    epochs: 16,
                    minibatch: 16,
                    teacher_nfe: 60,
                    lr: 5e-2,
                    scale_mode: ScaleMode::Relative,
                    ..TrainConfig::default()
                }),
            )
            .unwrap();
        assert_eq!(stats.published_version, Some(1));
        assert_eq!(svc.metrics.dicts_published.load(Ordering::Relaxed), 1);
        let snap = svc.dict_snapshot("gmm2d", "ddim", 8).unwrap();
        svc.shutdown();
        snap
    };
    let svc = Service::start(cfg(), Vec::new());
    assert_eq!(svc.metrics.artifacts_loaded.load(Ordering::Relaxed), 1);
    let reloaded = svc.dict_snapshot("gmm2d", "ddim", 8).unwrap();
    assert_eq!(
        bits(&reloaded),
        bits(&trained),
        "restart must reproduce the trained dict bit-for-bit"
    );
    // And it actually serves.
    let resp = svc
        .call(pas::server::SamplingRequest {
            id: 0,
            dataset: "gmm2d".into(),
            solver: "ddim".into(),
            nfe: 8,
            n_samples: 4,
            seed: 11,
            use_pas: true,
            deadline_ms: None,
            priority: 0,
        })
        .unwrap();
    assert!(resp.error.is_none());
    svc.shutdown();
    let _ = std::fs::remove_dir_all(dir);
}

/// The admin rollback path: registry swaps to the re-verified previous
/// version, the counter ticks, and rolling back past the retained
/// history is a clean error.
#[test]
fn service_rollback_swaps_registry() {
    let dir = unique_dir("svc_rollback");
    let svc = Service::start(
        ServiceConfig {
            workers: 1,
            artifact_root: Some(dir.clone()),
            ..ServiceConfig::default()
        },
        Vec::new(),
    );
    let (da, db) = (dict(8, 1.0), dict(8, 2.0));
    assert_eq!(svc.publish_dict("gmm2d", "ddim", 8, da.clone()).unwrap(), Some(1));
    assert_eq!(svc.publish_dict("gmm2d", "ddim", 8, db.clone()).unwrap(), Some(2));
    assert_eq!(bits(&svc.dict_snapshot("gmm2d", "ddim", 8).unwrap()), bits(&db));

    assert_eq!(svc.rollback("gmm2d", "ddim", 8).unwrap(), 1);
    assert_eq!(bits(&svc.dict_snapshot("gmm2d", "ddim", 8).unwrap()), bits(&da));
    assert_eq!(svc.metrics.rollbacks.load(Ordering::Relaxed), 1);
    let status = svc.status_json();
    assert_eq!(status.get("rollbacks").unwrap().as_u64(), Some(1));
    assert_eq!(status.get("dicts_published").unwrap().as_u64(), Some(2));
    // No retained history left for this key.
    assert!(svc.rollback("gmm2d", "ddim", 8).is_err());
    assert!(svc.rollback("gmm2d", "nope", 8).is_err());
    svc.shutdown();
    let _ = std::fs::remove_dir_all(dir);
}

/// Operator surface end to end through the CLI exit codes — the same
/// sequence the CI crash-recovery smoke step runs: publish two versions,
/// corrupt the current blob, `verify` fails, `load` heals, `verify`
/// passes again.
#[test]
fn cli_artifact_flow_exit_codes() {
    let dir = unique_dir("cli");
    let store_dir = dir.join("store").display().to_string();
    let run = |args: &[&str]| -> i32 { pas::cli::main(args.iter().map(|s| s.to_string()).collect()) };

    let c1 = dir.join("c1.json");
    let c2 = dir.join("c2.json");
    dict(8, 1.0).save(&c1).unwrap();
    dict(8, 2.0).save(&c2).unwrap();
    assert_eq!(run(&["artifact", "publish", "--store", &store_dir, "--coords", &c1.display().to_string()]), 0);
    assert_eq!(run(&["artifact", "publish", "--store", &store_dir, "--coords", &c2.display().to_string()]), 0);
    assert_eq!(run(&["artifact", "list", "--store", &store_dir]), 0);
    assert_eq!(run(&["artifact", "verify", "--store", &store_dir]), 0);

    // Corrupt the current version's blob.
    let store = ArtifactStore::open(&PathBuf::from(&store_dir)).unwrap();
    let cur = store
        .load_manifest()
        .0
        .get(&key())
        .unwrap()
        .current
        .clone();
    let blob = store.blob_path(&cur.checksum);
    let mut bytes = std::fs::read(&blob).unwrap();
    bytes[8] ^= 0x01;
    std::fs::write(&blob, &bytes).unwrap();
    drop(store);

    assert_eq!(run(&["artifact", "verify", "--store", &store_dir]), 1, "corruption must fail verify");
    assert_eq!(run(&["artifact", "load", "--store", &store_dir]), 0, "load heals to the previous version");
    assert_eq!(run(&["artifact", "verify", "--store", &store_dir]), 0, "store converges back to clean");
    // Rollback now has no retained history (the heal consumed it).
    assert_eq!(
        run(&["artifact", "rollback", "--store", &store_dir, "--dataset", "gmm2d", "--solver", "ddim", "--nfe", "8"]),
        1
    );
    // Bad usage is exit 1, not a panic.
    assert_eq!(run(&["artifact", "frobnicate", "--store", &store_dir]), 1);
    assert_eq!(run(&["artifact", "verify"]), 1, "missing --store");
    let _ = std::fs::remove_dir_all(dir);
}
