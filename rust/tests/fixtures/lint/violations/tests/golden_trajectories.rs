//! Fixture consumer: covers only one solver by name, not the full set.

pub const COVERED: &[&str] = &["ddim"];
