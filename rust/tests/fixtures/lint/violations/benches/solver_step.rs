//! Fixture consumer that sweeps registry::ALL — covers every name.

pub fn sweep() {
    for _name in registry::ALL {}
}
