//! Registry with a solver ("ghost") missing from the consumers.

pub const ALL: &[&str] = &["ddim", "ghost"];

#[cfg(test)]
mod tests {
    #[test]
    fn hist_depth_table_pinned() {
        let table = [("ddim", 0usize)];
        assert_eq!(table.len(), 1);
    }
}
