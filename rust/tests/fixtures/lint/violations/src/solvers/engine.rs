//! Hot-path allocation violations.

pub fn leaks_per_step(n: usize) -> Vec<f64> {
    let mut v = Vec::new();
    v.resize(n, 0.0);
    v
}

// lint:allow(hot-path-alloc, fixture: fn-head suppression covers the body)
pub fn suppressed_alloc(n: usize) -> Vec<f64> {
    vec![0.0; n]
}

#[cfg(test)]
mod tests {
    pub fn test_only_alloc() -> Vec<u8> {
        vec![1, 2, 3]
    }
}
