//! Server request-path panic violations.
use std::collections::HashMap;
use std::sync::Mutex;

pub fn handle(map: &HashMap<u32, u32>, mu: &Mutex<u32>) -> u32 {
    let v = map.get(&1).unwrap();
    let g = mu.lock().unwrap();
    *v + *g
}
