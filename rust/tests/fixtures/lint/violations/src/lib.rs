//! Seeded lint violations: `tests/lint_clean.rs` asserts each rule
//! fires here with the exact rule id, file, and line.

pub mod simd;

pub fn unsafe_without_justification() -> u8 {
    let x = [1u8, 2];
    unsafe { *x.as_ptr() }
}

pub fn unsafe_suppressed() -> u8 {
    let x = [3u8, 4];
    // lint:allow(safety-comment, fixture: suppression roundtrip)
    unsafe { *x.as_ptr() }
}

pub fn unsafe_wrong_rule_suppression() -> u8 {
    let x = [5u8, 6];
    // lint:allow(hot-path-alloc, fixture: wrong rule id must not absorb)
    unsafe { *x.as_ptr() }
}

pub fn unsafe_reasonless_allow() -> u8 {
    let x = [7u8, 8];
    // lint:allow(safety-comment)
    unsafe { *x.as_ptr() }
}
