//! SIMD gating violations.

pub fn ungated_intrinsic(a: f64) -> f64 {
    let v = _mm256_set1_pd(a);
    v
}

/// # Safety
/// Fixture only; never called.
#[target_feature(enable = "avx2,fma")]
pub unsafe fn fma_outside_gemm(a: f64) -> f64 {
    let v = _mm256_fmadd_pd(a, a, a);
    v
}
