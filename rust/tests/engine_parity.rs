//! Engine ⇔ legacy-driver parity: for every solver in the registry, the
//! workspace-pooled [`SamplerEngine`] must produce **bit-identical**
//! samples to the seed's allocate-per-step driver
//! ([`pas::solvers::run_solver_legacy`]) — with and without a
//! [`CorrectedSampler`] hook, across thread counts {1, 2, 5, 16}, and in
//! both [`Record`] modes. Row-sharding (now including the multi-eval
//! Heun/DPM-Solver-2, whose internal model evaluations become per-chunk
//! calls) preserves per-row f64 operation order, which is the whole
//! determinism argument; these tests enforce it.
//!
//! NFE is checked through [`CountingEps::nfe_rows`], the
//! sharding-invariant row-based account: per-chunk internal evals change
//! the *call* count but never the number of row evaluations.

use pas::pas::coords::{CoordinateDict, ScaleMode};
use pas::pas::correct::CorrectedSampler;
use pas::schedule::default_schedule;
use pas::score::analytic::AnalyticEps;
use pas::score::counting::CountingEps;
use pas::solvers::engine::{EngineConfig, Record, SamplerEngine};
use pas::solvers::registry;
use pas::solvers::run_solver_legacy;
use pas::traj::sample_prior;
use pas::util::rng::Pcg64;

const STEPS: usize = 6;
const N: usize = 64; // n * dim = 4096: large enough to engage sharding
const DIM: usize = 64;
/// Shard caps exercised everywhere: sequential, even split, a count that
/// leaves a ragged tail chunk, and more shards than most pools have.
const THREADS: [usize; 4] = [1, 2, 5, 16];

fn setup(seed: u64) -> (Box<AnalyticEps>, pas::schedule::Schedule, Vec<f64>) {
    let ds = pas::data::registry::get("gmm-hd64").unwrap();
    let model = AnalyticEps::from_dataset(&ds);
    let sched = default_schedule(STEPS);
    let mut rng = Pcg64::seed(seed);
    let x_t = sample_prior(&mut rng, N, DIM, sched.t_max());
    (model, sched, x_t)
}

/// A small synthetic dictionary exercising the PCA correction path at two
/// time points (no training needed; parity only cares about the code
/// path, not sample quality).
fn toy_dict() -> CoordinateDict {
    let mut dict = CoordinateDict::new(4, ScaleMode::Relative, "any", "gmm-hd64", STEPS);
    dict.steps.insert(2, vec![1.0, 0.05, 0.0, 0.0]);
    dict.steps.insert(4, vec![0.9, -0.1, 0.02, 0.0]);
    dict
}

#[test]
fn full_record_bitwise_parity_every_solver() {
    let (model, sched, x_t) = setup(100);
    for name in registry::ALL {
        let solver = registry::get(name).unwrap();
        let legacy = run_solver_legacy(solver.as_ref(), model.as_ref(), &x_t, N, &sched, None);
        for threads in THREADS {
            let mut eng = SamplerEngine::new(EngineConfig {
                record: Record::Full,
                threads,
            });
            let run = eng.run(solver.as_ref(), model.as_ref(), &x_t, N, &sched, None);
            assert_eq!(legacy.x0, run.x0, "{name} x0 (threads={threads})");
            assert_eq!(legacy.xs, run.xs, "{name} xs (threads={threads})");
            assert_eq!(legacy.ds, run.ds, "{name} ds (threads={threads})");
            assert_eq!(legacy.nfe, run.nfe, "{name} nfe (threads={threads})");
        }
    }
}

#[test]
fn hooked_parity_every_solver() {
    let (model, sched, x_t) = setup(101);
    let dict = toy_dict();
    for name in registry::ALL {
        let solver = registry::get(name).unwrap();
        let mut legacy_hook = CorrectedSampler::new(&dict, DIM);
        let legacy = run_solver_legacy(
            solver.as_ref(),
            model.as_ref(),
            &x_t,
            N,
            &sched,
            Some(&mut legacy_hook),
        );
        for threads in THREADS {
            let mut engine_hook = CorrectedSampler::new(&dict, DIM);
            let mut eng = SamplerEngine::new(EngineConfig {
                record: Record::Full,
                threads,
            });
            let run = eng.run(
                solver.as_ref(),
                model.as_ref(),
                &x_t,
                N,
                &sched,
                Some(&mut engine_hook),
            );
            assert_eq!(legacy.x0, run.x0, "{name} hooked x0 (threads={threads})");
            assert_eq!(legacy.ds, run.ds, "{name} hooked ds (threads={threads})");
            assert_eq!(
                legacy_hook.corrections_applied, engine_hook.corrections_applied,
                "{name} corrections applied"
            );
            assert_eq!(engine_hook.corrections_applied, 2, "{name} dict steps hit");
        }
    }
}

#[test]
fn record_none_parity_and_nfe_every_solver() {
    let (model, sched, x_t) = setup(102);
    for name in registry::ALL {
        let solver = registry::get(name).unwrap();
        let legacy = run_solver_legacy(solver.as_ref(), model.as_ref(), &x_t, N, &sched, None);
        for threads in THREADS {
            let counting = CountingEps::new(model.as_ref());
            let mut eng = SamplerEngine::new(EngineConfig {
                record: Record::None,
                threads,
            });
            let mut x0 = vec![0.0; N * DIM];
            let nfe = eng.run_into(
                solver.as_ref(),
                &counting,
                &x_t,
                N,
                &sched,
                None,
                &mut x0,
            );
            assert_eq!(legacy.x0, x0, "{name} Record::None x0 (threads={threads})");
            assert_eq!(legacy.nfe, nfe, "{name} Record::None nfe (threads={threads})");
            assert_eq!(
                nfe,
                STEPS * solver.evals_per_step(),
                "{name} NFE accounting in Record::None"
            );
            assert_eq!(
                counting.nfe_rows(N),
                nfe,
                "{name} model actually evaluated nfe × N rows (threads={threads})"
            );
        }
    }
}

#[test]
fn record_none_with_hook_matches_full() {
    let (model, sched, x_t) = setup(103);
    let dict = toy_dict();
    for name in ["ddim", "ipndm4", "dpmpp3m", "unipc3m", "deis-tab3", "heun", "dpm2"] {
        let solver = registry::get(name).unwrap();
        for threads in [1usize, 5] {
            let mut hook_full = CorrectedSampler::new(&dict, DIM);
            let mut full = SamplerEngine::new(EngineConfig {
                record: Record::Full,
                threads,
            });
            let run = full.run(
                solver.as_ref(),
                model.as_ref(),
                &x_t,
                N,
                &sched,
                Some(&mut hook_full),
            );
            let mut hook_none = CorrectedSampler::new(&dict, DIM);
            let mut none = SamplerEngine::new(EngineConfig {
                record: Record::None,
                threads,
            });
            let mut x0 = vec![0.0; N * DIM];
            let nfe = none.run_into(
                solver.as_ref(),
                model.as_ref(),
                &x_t,
                N,
                &sched,
                Some(&mut hook_none),
                &mut x0,
            );
            assert_eq!(run.x0, x0, "{name} hooked Record::None x0 (threads={threads})");
            assert_eq!(run.nfe, nfe, "{name} hooked Record::None nfe (threads={threads})");
        }
    }
}

/// The engine-backed `run_solver` wrapper is the drop-in default path.
#[test]
fn run_solver_wrapper_is_engine_backed_and_identical() {
    let (model, sched, x_t) = setup(104);
    let solver = registry::get("ipndm").unwrap();
    let legacy = run_solver_legacy(solver.as_ref(), model.as_ref(), &x_t, N, &sched, None);
    let run = pas::solvers::run_solver(solver.as_ref(), model.as_ref(), &x_t, N, &sched, None);
    assert_eq!(legacy.x0, run.x0);
    assert_eq!(legacy.xs, run.xs);
    assert_eq!(legacy.ds, run.ds);
    assert_eq!(legacy.nfe, run.nfe);
}

/// Engine workspaces (including the scratch arena) are safely reusable
/// across *different* solvers — the production registry-serving pattern:
/// one engine, whatever solver the request names.
#[test]
fn one_engine_across_the_whole_registry() {
    let (model, sched, x_t) = setup(105);
    let mut eng = SamplerEngine::new(EngineConfig {
        record: Record::None,
        threads: 0,
    });
    let mut x0 = vec![0.0; N * DIM];
    for _round in 0..2 {
        for name in registry::ALL {
            let solver = registry::get(name).unwrap();
            let legacy =
                run_solver_legacy(solver.as_ref(), model.as_ref(), &x_t, N, &sched, None);
            eng.run_into(solver.as_ref(), model.as_ref(), &x_t, N, &sched, None, &mut x0);
            assert_eq!(legacy.x0, x0, "{name} after engine reuse");
        }
    }
}
