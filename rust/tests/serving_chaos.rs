//! Serving-path chaos suite: drives the compiled-in fail points
//! (`pas::util::failpoint`) and a deliberately poisonous dictionary
//! through the *production* serving stack, asserting the containment
//! contract end to end:
//!
//! * every submitted request gets **exactly one** structured reply —
//!   eval panics, injected NaNs, and reply-write failures included;
//! * faults are contained to the poisoned rows / the failing connection:
//!   cohort-mates and later requests keep serving, and surviving rows
//!   stay **bit-identical** to their solo runs;
//! * the per-key numeric circuit breaker degrades a key to uncorrected
//!   sampling after repeated corrected-path blow-ups, quarantines the
//!   offending dict version in the artifact store, and recovers full
//!   corrected serving after `rollback`;
//! * nothing hangs: connection threads join, counters balance.
//!
//! Global fail points are process-wide one-shots, so every test here
//! serializes on one mutex (the integration binary runs tests in
//! parallel) and disarms on entry and exit.

use pas::pas::coords::{CoordinateDict, ScaleMode};
use pas::pas::correct::CorrectedSampler;
use pas::schedule::default_schedule;
use pas::score::analytic::AnalyticEps;
use pas::server::protocol::{serve_with, ServerConfig};
use pas::server::{SamplingRequest, SamplingResponse, Service, ServiceConfig};
use pas::solvers::engine::{Record, SamplerEngine};
use pas::traj::sample_prior_stream;
use pas::util::failpoint;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Receiver;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// One chaos scenario at a time: global fail points are process-wide.
static CHAOS: Mutex<()> = Mutex::new(());

fn chaos_lock() -> MutexGuard<'static, ()> {
    // A prior test failing while holding the lock must not cascade.
    let g = CHAOS.lock().unwrap_or_else(|e| e.into_inner());
    failpoint::disarm_all();
    g
}

fn unique_dir(tag: &str) -> PathBuf {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let d = std::env::temp_dir().join(format!(
        "pas_chaos_{tag}_{}_{}",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let _ = std::fs::remove_dir_all(&d);
    d
}

fn request(dataset: &str, solver: &str, nfe: usize, n: usize, seed: u64) -> SamplingRequest {
    SamplingRequest {
        id: 0,
        dataset: dataset.into(),
        solver: solver.into(),
        nfe,
        n_samples: n,
        seed,
        use_pas: false,
        deadline_ms: None,
        priority: 0,
    }
}

/// Exactly-one-reply receive: fails loudly instead of hanging, and
/// asserts no second reply ever lands on the channel.
fn recv_one(rx: Receiver<SamplingResponse>) -> SamplingResponse {
    let resp = rx
        .recv_timeout(Duration::from_secs(120))
        .expect("request must get exactly one reply (got none)");
    assert!(
        rx.recv_timeout(Duration::from_millis(100)).is_err(),
        "request must get exactly one reply (got a second)"
    );
    resp
}

/// The determinism contract's right-hand side: `req` alone through a
/// fresh serving-configuration engine.
fn solo_run(req: &SamplingRequest, id: u64, dict: Option<&CoordinateDict>) -> Vec<f64> {
    let ds = pas::data::registry::get(&req.dataset).unwrap();
    let model = AnalyticEps::from_dataset(&ds);
    let solver = pas::solvers::registry::get(&req.solver).unwrap();
    let steps = solver.steps_for_nfe(req.nfe).unwrap();
    let sched = default_schedule(steps);
    let dim = model.dim();
    let x_t = sample_prior_stream(req.seed, id, req.n_samples, dim, sched.t_max());
    let mut x0 = vec![0.0; req.n_samples * dim];
    let mut engine = SamplerEngine::with_record(Record::None);
    match dict {
        Some(d) => {
            let mut hook = CorrectedSampler::new(d, dim);
            engine.run_into(
                solver.as_ref(),
                model.as_ref(),
                &x_t,
                req.n_samples,
                &sched,
                Some(&mut hook),
                &mut x0,
            );
        }
        None => {
            engine.run_into(
                solver.as_ref(),
                model.as_ref(),
                &x_t,
                req.n_samples,
                &sched,
                None,
                &mut x0,
            );
        }
    }
    x0
}

fn assert_counters_balance(svc: &Service) {
    let m = &svc.metrics;
    assert_eq!(
        m.requests.load(Ordering::Relaxed),
        m.completed.load(Ordering::Relaxed)
            + m.rejected.load(Ordering::Relaxed)
            + m.failed.load(Ordering::Relaxed),
        "requests == completed + rejected + failed"
    );
}

/// An eval panic mid-cohort is contained: the resident request fails
/// with a structured error (not a dropped channel), the worker rebuilds
/// its engine, and the key keeps serving.
#[test]
fn eval_panic_mid_cohort_is_contained() {
    let _g = chaos_lock();
    let svc = Service::start(
        ServiceConfig {
            workers: 1,
            ..ServiceConfig::default()
        },
        Vec::new(),
    );
    failpoint::arm(failpoint::SERVICE_EVAL_PANIC, 2);
    let rx = svc.submit(request("gmm2d", "ddim", 12, 4, 1)).unwrap();
    let resp = recv_one(rx);
    let err = resp
        .error
        .as_deref()
        .expect("the panicked cohort's request must fail, not vanish");
    assert!(err.contains("panic"), "structured panic error, got: {err}");
    // The key recovers: the next request on the same key succeeds and
    // matches its solo run bitwise (fresh engine, clean state).
    let req = request("gmm2d", "ddim", 12, 4, 2);
    let ok = recv_one(svc.submit(req.clone()).unwrap());
    assert!(ok.error.is_none(), "{:?}", ok.error);
    assert_eq!(ok.samples, solo_run(&req, ok.id, None), "post-panic run diverged");
    assert_counters_balance(&svc);
    svc.shutdown();
    failpoint::disarm_all();
}

/// An injected NaN at a chosen tick fails only the poisoned member;
/// cohort-mates keep stepping and retire bit-identical to their solo
/// runs.
#[test]
fn nan_tick_fails_poisoned_rows_and_spares_cohort_mates() {
    let _g = chaos_lock();
    let svc = Service::start(
        ServiceConfig {
            workers: 1,
            ..ServiceConfig::default()
        },
        Vec::new(),
    );
    // Poison row 0 at step j=3 of the first cohort to reach it — request
    // A's first row (admitted first, ticked first).
    failpoint::arm(failpoint::ENGINE_NAN_TICK, 3);
    let req_a = request("gmm2d", "ddim", 12, 2, 10);
    let req_b = request("gmm2d", "ddim", 12, 3, 11);
    let rx_a = svc.submit(req_a).unwrap();
    let rx_b = svc.submit(req_b.clone()).unwrap();
    let resp_a = recv_one(rx_a);
    let err = resp_a
        .error
        .as_deref()
        .expect("the poisoned request must fail with a structured error");
    assert!(err.starts_with("numeric:"), "{err}");
    let resp_b = recv_one(rx_b);
    assert!(resp_b.error.is_none(), "cohort-mate must survive: {:?}", resp_b.error);
    assert_eq!(
        resp_b.samples,
        solo_run(&req_b, resp_b.id, None),
        "surviving rows must stay bit-identical to the solo run"
    );
    assert!(svc.metrics.numeric_failures.load(Ordering::Relaxed) >= 1);
    assert_counters_balance(&svc);
    svc.shutdown();
    failpoint::disarm_all();
}

/// A reply write that fails (client vanished) tears down only that
/// connection; the service and the front-end keep serving.
#[test]
fn reply_write_failure_is_contained_to_the_connection() {
    let _g = chaos_lock();
    let svc = Arc::new(Service::start(
        ServiceConfig {
            workers: 1,
            ..ServiceConfig::default()
        },
        Vec::new(),
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let server = serve_with(svc.clone(), "127.0.0.1:0", stop, ServerConfig::default()).unwrap();
    let mut doomed = TcpStream::connect(server.local_addr()).unwrap();
    failpoint::arm(failpoint::PROTOCOL_WRITE_FAIL, 0);
    doomed
        .write_all(b"{\"dataset\":\"gmm2d\",\"solver\":\"ddim\",\"nfe\":6,\"n\":2,\"seed\":1}\n")
        .unwrap();
    // The injected broken pipe closes the connection without a reply.
    let mut reader = BufReader::new(doomed.try_clone().unwrap());
    let mut line = String::new();
    assert_eq!(
        reader.read_line(&mut line).unwrap(),
        0,
        "failed-write connection must close, got: {line}"
    );
    // The request itself completed at the service layer (the fault was
    // on the wire, after sampling) and a fresh connection still serves.
    assert!(svc.metrics.completed.load(Ordering::Relaxed) >= 1);
    let mut conn = TcpStream::connect(server.local_addr()).unwrap();
    conn.write_all(b"{\"dataset\":\"gmm2d\",\"solver\":\"ddim\",\"nfe\":6,\"n\":2,\"seed\":2}\n")
        .unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut ok = String::new();
    reader.read_line(&mut ok).unwrap();
    assert!(
        !ok.contains("\"error\"") && ok.contains("samples"),
        "front-end must keep serving after a write failure: {ok}"
    );
    assert!(
        server.join(Duration::from_secs(10)),
        "no leaked connection threads"
    );
    assert_counters_balance(&svc);
    svc.shutdown();
    failpoint::disarm_all();
}

/// A half-open client (partial frame, then silence) cannot hold drain
/// hostage: the read timeout cuts it off and `join` completes.
#[test]
fn stalled_socket_does_not_block_drain() {
    let _g = chaos_lock();
    let svc = Arc::new(Service::start(
        ServiceConfig {
            workers: 1,
            ..ServiceConfig::default()
        },
        Vec::new(),
    ));
    let stop = Arc::new(AtomicBool::new(false));
    let server = serve_with(
        svc.clone(),
        "127.0.0.1:0",
        stop,
        ServerConfig {
            read_timeout: Duration::from_millis(200),
            ..ServerConfig::default()
        },
    )
    .unwrap();
    let mut half_open = TcpStream::connect(server.local_addr()).unwrap();
    half_open.write_all(b"{\"dataset\":").unwrap(); // never finishes the frame
    // Give the accept loop time to register the connection, then drain.
    std::thread::sleep(Duration::from_millis(50));
    let t0 = Instant::now();
    server.begin_drain();
    svc.shutdown();
    assert!(
        server.join(Duration::from_secs(10)),
        "drain must reap the stalled connection"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "drain must be bounded by the read timeout"
    );
    drop(half_open);
}

/// The acceptance scenario for the numeric circuit breaker: a dict whose
/// corrections blow up the solver gets its key degraded to uncorrected
/// sampling after repeated failures, the poisonous blob is quarantined
/// in the artifact store, and `rollback` restores corrected serving on
/// the previous good version.
#[test]
fn breaker_quarantines_bad_dict_and_recovers_after_rollback() {
    let _g = chaos_lock();
    let dir = unique_dir("breaker");
    let svc = Service::start(
        ServiceConfig {
            workers: 1,
            artifact_root: Some(dir.clone()),
            ..ServiceConfig::default()
        },
        Vec::new(),
    );
    let (dataset, solver, nfe) = ("gmm2d", "ddim", 6);
    let corrected_req = |seed: u64| {
        let mut r = request(dataset, solver, nfe, 4, seed);
        r.use_pas = true;
        r
    };

    // v1: a benign dict. Corrected serving works and matches the solo
    // corrected run bitwise.
    let mut good = CoordinateDict::new(4, ScaleMode::Relative, solver, dataset, nfe);
    good.steps.insert(4, vec![0.95, 0.02, 0.0, 0.0]);
    good.steps.insert(2, vec![1.0, 0.0, -0.05, 0.0]);
    let v1 = svc.publish_dict(dataset, solver, nfe, good.clone()).unwrap();
    assert_eq!(v1, Some(1));
    let req = corrected_req(1);
    let resp = recv_one(svc.submit(req.clone()).unwrap());
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(resp.samples, solo_run(&req, resp.id, Some(&good)));

    // v2: huge-but-finite coordinates. They pass serialization and
    // checksums, then overflow to inf/NaN during corrected sampling.
    let mut bad = CoordinateDict::new(4, ScaleMode::Relative, solver, dataset, nfe);
    for step in 0..=nfe {
        bad.steps.insert(step, vec![1e300; 4]);
    }
    let v2 = svc.publish_dict(dataset, solver, nfe, bad).unwrap();
    assert_eq!(v2, Some(2));

    // Three consecutive corrected cohorts blow up -> breaker opens.
    for i in 0..3u64 {
        let resp = recv_one(svc.submit(corrected_req(100 + i)).unwrap());
        let err = resp
            .error
            .as_deref()
            .unwrap_or_else(|| panic!("bad-dict request {i} must fail"));
        assert!(err.starts_with("numeric:"), "{err}");
    }
    // The breaker opens (and containment runs) just after the third
    // failure's reply is sent; wait for the observable effects.
    let t0 = Instant::now();
    let quarantine = dir.join("quarantine");
    loop {
        let open = svc.metrics.breaker_open.load(Ordering::Relaxed) == 1;
        let quarantined = std::fs::read_dir(&quarantine)
            .map(|d| d.count() > 0)
            .unwrap_or(false);
        if open && quarantined {
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "breaker must open and quarantine the bad blob (open={open}, quarantined={quarantined})"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    // Containment also drops the poisonous dict from the live registry.
    assert!(svc.dict_snapshot(dataset, solver, nfe).is_none());
    assert_eq!(
        svc.health_json().get("status").and_then(|s| s.as_str()),
        Some("degraded")
    );

    // Degraded serving: pas-requests succeed *uncorrected* while the
    // breaker is open (bit-identical to an uncorrected solo run).
    let req = corrected_req(200);
    let resp = recv_one(svc.submit(req.clone()).unwrap());
    assert!(resp.error.is_none(), "degraded serving must succeed: {:?}", resp.error);
    assert_eq!(
        resp.samples,
        solo_run(&req, resp.id, None),
        "breaker-open serving must be the uncorrected path"
    );

    // Rollback to v1 closes the breaker and corrected serving resumes.
    let restored = svc.rollback(dataset, solver, nfe).unwrap();
    assert_eq!(restored, 1);
    assert_eq!(svc.metrics.breaker_open.load(Ordering::Relaxed), 0);
    assert_eq!(
        svc.health_json().get("status").and_then(|s| s.as_str()),
        Some("ok")
    );
    let req = corrected_req(300);
    let resp = recv_one(svc.submit(req.clone()).unwrap());
    assert!(resp.error.is_none(), "{:?}", resp.error);
    assert_eq!(
        resp.samples,
        solo_run(&req, resp.id, Some(&good)),
        "corrected serving must resume on the rolled-back dict"
    );
    assert_counters_balance(&svc);
    svc.shutdown();
    let _ = std::fs::remove_dir_all(dir);
}
