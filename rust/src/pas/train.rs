//! PAS training — Algorithm 1, as an engine-backed, workspace-pooled
//! [`TrainSession`].
//!
//! Time points are trained **sequentially** (correcting step `i` shifts
//! every later state), sharing one coordinate vector `C` across all
//! training trajectories while the basis `U^k` is per-sample. Because every
//! PAS-supported solver is *affine in the current direction*
//! (`x' = base + gamma · d`, with `gamma` from [`crate::solvers::Solver::gamma`]),
//! the coordinate gradient is analytic — no autodiff anywhere:
//!
//! ```text
//! x'_k(C)  = base_k + gamma · s_k · U_kᵀ C      (s_k = 1 or ||d_k||)
//! ∇_C loss = gamma · s_k · U_k · ∇_{x'} loss
//! ```
//!
//! Losses are evaluated **per dimension** (mean, not sum) so the tolerance
//! `tau` transfers across datasets of different dimension; this is the one
//! normalization choice we add on top of the paper (documented in
//! DESIGN.md §3).
//!
//! # TrainSession architecture
//!
//! [`TrainSession`] owns every workspace the whole run needs and reuses it
//! across runs (nothing is ever shrunk), mirroring the sampling engine's
//! lifecycle:
//!
//! * **Flat trajectory state.** The corrected rollout (`xs`, `ds`) and the
//!   teacher ground truth live in [`NodeStore`]s — one flat `(node, n·dim)`
//!   row per node — read back through [`crate::solvers::NodeView`]s. The
//!   teacher and the uncorrected student both roll out through one reused
//!   [`SamplerEngine`] (`Record::Full`); no nested `Vec<Vec<f64>>` anywhere.
//! * **Pooled basis extraction.** Per-sample bases live in one
//!   [`BasisStore`] (`n × n_basis × dim` flat + per-sample `k`/`d_norm`);
//!   extraction shards samples over the process [`Pool`], each chunk
//!   working in its own [`PcaScratch`] — zero heap allocations per
//!   training step in steady state (`tests/alloc_audit.rs`).
//! * **Sharded coordinate optimization.** The minibatch gradient is
//!   computed as independent per-sample terms in parallel, then reduced
//!   **sequentially in minibatch order** — so the trained coordinates are
//!   bit-identical to the sequential reference path for every thread
//!   count (`tests/golden_training.rs` pins this for caps {1, 2, 16}).
//!   The affine-base and uncorrected solver steps go through the engine's
//!   row-sharded dispatch, and the adaptive-decision losses are computed
//!   per sample in parallel with a sequential ascending-`k` reduction.
//!
//! [`PasTrainer::train_tp_reference`] keeps the pre-session sequential
//! monolith as the bitwise oracle (the same role
//! [`crate::solvers::run_solver_legacy`] plays for the engine);
//! `benches/train_time.rs` reports the session's speedup against it.

use super::adaptive::{decide, AdaptiveDecision, AdaptiveTrace};
use super::coords::{CoordinateDict, ScaleMode};
use super::pca::{pca_basis, pca_basis_into, Basis, BasisStore, PcaScratch, TrajBuffer};
use crate::schedule::Schedule;
use crate::score::EpsModel;
use crate::solvers::engine::{step_rows, EngineConfig, NodeStore, Record, SamplerEngine};
use crate::solvers::{NodeView, Solver, StepCtx, StepScratch};
use crate::traj::{
    ground_truth, ground_truth_into, sample_prior, sample_prior_into, truncation_error_curve,
    GroundTruth,
};
use crate::util::pool::{Pool, SendPtr};
use crate::util::rng::Pcg64;
use crate::util::timer::Timer;

/// Training loss functions (Fig. 6b ablation).
#[derive(Clone, Debug)]
pub enum Loss {
    L1,
    L2,
    /// Pseudo-Huber with softening constant `c` (Song & Dhariwal 2024).
    PseudoHuber { c: f64 },
    /// Random-projection feature loss — our offline stand-in for LPIPS
    /// (frozen random features as an untrained perceptual proxy).
    RpFeat { proj_dim: usize, seed: u64 },
}

impl Loss {
    pub fn name(&self) -> &'static str {
        match self {
            Loss::L1 => "l1",
            Loss::L2 => "l2",
            Loss::PseudoHuber { .. } => "pseudo-huber",
            Loss::RpFeat { .. } => "rpfeat",
        }
    }

    pub fn parse(s: &str) -> Option<Loss> {
        match s {
            "l1" => Some(Loss::L1),
            "l2" => Some(Loss::L2),
            "pseudo-huber" => Some(Loss::PseudoHuber { c: 0.03 }),
            "rpfeat" => Some(Loss::RpFeat {
                proj_dim: 16,
                seed: 7,
            }),
            _ => None,
        }
    }
}

/// Loss evaluator with optional fixed random projection.
struct LossEval {
    loss: Loss,
    /// (proj_dim, d) row-major projection for RpFeat.
    proj: Option<(usize, Vec<f64>)>,
}

impl LossEval {
    fn new(loss: &Loss, dim: usize) -> LossEval {
        let proj = if let Loss::RpFeat { proj_dim, seed } = loss {
            let mut rng = Pcg64::seed_stream(*seed, 0x9f);
            let scale = 1.0 / (dim as f64).sqrt();
            let p: Vec<f64> = (0..proj_dim * dim).map(|_| rng.normal() * scale).collect();
            Some((*proj_dim, p))
        } else {
            None
        };
        LossEval {
            loss: loss.clone(),
            proj,
        }
    }

    /// Per-sample loss (mean per dimension) of residual `r`.
    fn value(&self, r: &[f64]) -> f64 {
        let d = r.len() as f64;
        match &self.loss {
            Loss::L1 => r.iter().map(|v| v.abs()).sum::<f64>() / d,
            Loss::L2 => r.iter().map(|v| v * v).sum::<f64>() / d,
            Loss::PseudoHuber { c } => {
                r.iter().map(|v| (v * v + c * c).sqrt() - c).sum::<f64>() / d
            }
            Loss::RpFeat { .. } => {
                let (p_dim, p) = self.proj.as_ref().unwrap();
                let mut s = 0.0;
                for row in 0..*p_dim {
                    let pr = crate::tensor::dot(&p[row * r.len()..(row + 1) * r.len()], r);
                    s += pr * pr;
                }
                s / *p_dim as f64
            }
        }
    }

    /// Gradient of the per-sample loss w.r.t. the residual, into `out`.
    fn grad(&self, r: &[f64], out: &mut [f64]) {
        let d = r.len() as f64;
        match &self.loss {
            Loss::L1 => {
                for (o, &v) in out.iter_mut().zip(r.iter()) {
                    *o = v.signum() / d;
                }
            }
            Loss::L2 => {
                for (o, &v) in out.iter_mut().zip(r.iter()) {
                    *o = 2.0 * v / d;
                }
            }
            Loss::PseudoHuber { c } => {
                for (o, &v) in out.iter_mut().zip(r.iter()) {
                    *o = v / (v * v + c * c).sqrt() / d;
                }
            }
            Loss::RpFeat { .. } => {
                let (p_dim, p) = self.proj.as_ref().unwrap();
                out.fill(0.0);
                let dl = r.len();
                for row in 0..*p_dim {
                    let prow = &p[row * dl..(row + 1) * dl];
                    let pr = crate::tensor::dot(prow, r);
                    let c = 2.0 * pr / *p_dim as f64;
                    for (o, &pv) in out.iter_mut().zip(prow.iter()) {
                        *o += c * pv;
                    }
                }
            }
        }
    }
}

/// Coordinate optimizer (the paper uses SGD; Adam is sturdier across our
/// dataset scales and is the default — `repro fig7` sweeps the lr either way).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Optimizer {
    Sgd,
    Adam,
}

/// Full training configuration (defaults follow the paper's recommended
/// settings, §4.1 and Appendix B).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub n_basis: usize,
    pub lr: f64,
    pub epochs: usize,
    pub minibatch: usize,
    /// Number of ground-truth trajectories (paper: 5k; our datasets
    /// saturate far earlier — Fig. 6d analog sweeps this).
    pub n_traj: usize,
    pub tau: f64,
    pub loss: Loss,
    pub scale_mode: ScaleMode,
    pub optimizer: Optimizer,
    /// Teacher solver name (paper: Heun's 2nd).
    pub teacher: String,
    /// Teacher NFE budget (paper: 100).
    pub teacher_nfe: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            n_basis: 4,
            lr: 1e-2,
            epochs: 48,
            minibatch: 32,
            n_traj: 256,
            tau: 1e-2,
            loss: Loss::L1,
            scale_mode: ScaleMode::Absolute,
            optimizer: Optimizer::Adam,
            teacher: "heun".into(),
            teacher_nfe: 100,
            seed: 0,
        }
    }
}

/// Everything `PasTrainer::train` produces.
#[derive(Debug)]
pub struct TrainResult {
    pub dict: CoordinateDict,
    pub trace: AdaptiveTrace,
    /// Truncation-error curve of the *uncorrected* student vs ground truth
    /// (Figure 3a) on the training trajectories.
    pub curve_uncorrected: Vec<f64>,
    /// Truncation-error curve of the PAS-corrected student (Figure 3b).
    pub curve_corrected: Vec<f64>,
    pub train_seconds: f64,
    pub teacher_nfe_spent: usize,
}

/// Per-sample gradient work below this many `f64` elements per shard runs
/// inline — pool dispatch would outweigh the math (cf. the engine's
/// `MIN_SHARD_ELEMS`).
const MIN_SGD_SHARD_ELEMS: usize = 2048;

/// Reusable, workspace-pooled Algorithm-1 driver. Create once, call
/// [`TrainSession::train`] per (solver, schedule, dataset) — after the
/// first run of a shape, a training step performs **zero** heap
/// allocations (basis extraction + SGD epochs included).
///
/// The phase methods ([`TrainSession::begin`] /
/// [`TrainSession::train_step`] / [`TrainSession::finish`]) are public so
/// the allocation audit and the training bench can instrument individual
/// time points; `train` is the composition every product caller uses.
pub struct TrainSession {
    pub cfg: TrainConfig,
    /// Row-shard cap for every parallel phase (0 = pool size). Outputs
    /// are bit-identical for any value — `tests/golden_training.rs`.
    threads: usize,
    engine: SamplerEngine,
    gt: GroundTruth,
    xs: NodeStore,
    ds: NodeStore,
    bases: BasisStore,
    pca: Vec<PcaScratch>,
    rng: Pcg64,
    timer: Timer,
    le: Option<LossEval>,
    trace: AdaptiveTrace,
    curve_uncorrected: Vec<f64>,
    // Run shape (set by `begin`).
    n: usize,
    dim: usize,
    n_steps: usize,
    force_all: bool,
    dataset: String,
    solver_name: String,
    // Flat step workspaces, all `n * dim`.
    x_t: Vec<f64>,
    x0_tmp: Vec<f64>,
    d_all: Vec<f64>,
    base: Vec<f64>,
    x_next_unc: Vec<f64>,
    x_next_cor: Vec<f64>,
    d_used: Vec<f64>,
    zeros: Vec<f64>,
    step_scratch: Vec<f64>,
    // SGD workspaces.
    perm: Vec<usize>,
    terms: Vec<f64>,
    term_k: Vec<usize>,
    /// Per-chunk `[dtilde | resid | gx | proj]` rows, one per shard slot.
    chunk_scratch: Vec<f64>,
    c: Vec<f64>,
    grad: Vec<f64>,
    adam_m: Vec<f64>,
    adam_v: Vec<f64>,
    // Per-sample loss staging for the adaptive decision.
    l_unc_s: Vec<f64>,
    l_cor_s: Vec<f64>,
    // Per-step outcome, assembled into the dict at `finish`.
    kept: Vec<bool>,
    kept_coords: Vec<f64>,
    // Partitions fixed per run: (chunk_rows, n_chunks) over the batch for
    // the PCA pass (min 1 row) and the light per-sample passes.
    part_pca: (usize, usize),
    part_light: (usize, usize),
}

impl TrainSession {
    pub fn new(cfg: TrainConfig) -> TrainSession {
        TrainSession::with_threads(cfg, 0)
    }

    /// Session with an explicit shard cap (`0` = pool size, `1` = fully
    /// sequential). Any value produces bit-identical results; the cap
    /// exists for the determinism tests and for capacity isolation.
    pub fn with_threads(cfg: TrainConfig, threads: usize) -> TrainSession {
        TrainSession {
            cfg,
            threads,
            engine: SamplerEngine::new(EngineConfig {
                record: Record::Full,
                threads,
            }),
            gt: GroundTruth::empty(),
            xs: NodeStore::new(),
            ds: NodeStore::new(),
            bases: BasisStore::new(),
            pca: Vec::new(),
            rng: Pcg64::seed(0),
            timer: Timer::start(),
            le: None,
            trace: AdaptiveTrace::default(),
            curve_uncorrected: Vec::new(),
            n: 0,
            dim: 0,
            n_steps: 0,
            force_all: false,
            dataset: String::new(),
            solver_name: String::new(),
            x_t: Vec::new(),
            x0_tmp: Vec::new(),
            d_all: Vec::new(),
            base: Vec::new(),
            x_next_unc: Vec::new(),
            x_next_cor: Vec::new(),
            d_used: Vec::new(),
            zeros: Vec::new(),
            step_scratch: Vec::new(),
            perm: Vec::new(),
            terms: Vec::new(),
            term_k: Vec::new(),
            chunk_scratch: Vec::new(),
            c: Vec::new(),
            grad: Vec::new(),
            adam_m: Vec::new(),
            adam_v: Vec::new(),
            l_unc_s: Vec::new(),
            l_cor_s: Vec::new(),
            kept: Vec::new(),
            kept_coords: Vec::new(),
            part_pca: (0, 0),
            part_light: (0, 0),
        }
    }

    /// Steps of the schedule `begin` was called with.
    pub fn n_steps(&self) -> usize {
        self.n_steps
    }

    fn max_parts(&self) -> usize {
        if self.threads == 0 {
            Pool::global().size()
        } else {
            self.threads
        }
    }

    /// Run Algorithm 1 end to end: [`Self::begin`], one
    /// [`Self::train_step`] per time point, [`Self::finish`].
    #[allow(clippy::too_many_arguments)]
    pub fn train(
        &mut self,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        sched: &Schedule,
        dataset_name: &str,
        force_all_steps: bool,
        teleport: Option<(&crate::pas::teleport::Teleporter, f64)>,
    ) -> Result<TrainResult, String> {
        self.begin(solver, model, sched, dataset_name, force_all_steps, teleport)?;
        for j in 0..sched.n_steps() {
            self.train_step(solver, model, sched, j)?;
        }
        Ok(self.finish())
    }

    /// Phase 1: draw (and optionally teleport) priors, roll out the
    /// teacher ground truth and the uncorrected student through the
    /// reused engine, and (re)shape every workspace. Allocates only on
    /// shape growth; the per-step phases after it allocate nothing.
    #[allow(clippy::too_many_arguments)]
    pub fn begin(
        &mut self,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        sched: &Schedule,
        dataset_name: &str,
        force_all_steps: bool,
        teleport: Option<(&crate::pas::teleport::Teleporter, f64)>,
    ) -> Result<(), String> {
        let cfg = &self.cfg;
        if cfg.minibatch == 0 {
            // The epoch loop advances by whole minibatches; 0 would spin
            // forever (the pre-session path panicked in `chunks(0)`).
            return Err("minibatch must be >= 1".into());
        }
        let dim = model.dim();
        let n = cfg.n_traj;
        let n_basis = cfg.n_basis;
        let n_steps = sched.n_steps();
        self.timer = Timer::start();
        self.rng = Pcg64::seed_stream(cfg.seed, 0x7a5);
        self.n = n;
        self.dim = dim;
        self.n_steps = n_steps;
        self.force_all = force_all_steps;
        self.dataset.clear();
        self.dataset.push_str(dataset_name);
        self.solver_name.clear();
        self.solver_name.push_str(solver.name());

        // Priors (teleportation warm start draws at t_gen and transports
        // analytically to the schedule's t_max — the `+TP+PAS` rows).
        resize_min(&mut self.x_t, n * dim);
        match teleport {
            None => sample_prior_into(&mut self.rng, sched.t_max(), &mut self.x_t[..n * dim]),
            Some((tp, t_gen)) => {
                sample_prior_into(&mut self.rng, t_gen, &mut self.x_t[..n * dim]);
                tp.teleport(&mut self.x_t[..n * dim], n, t_gen, sched.t_max());
            }
        }

        // Teacher ground truth through the reused engine.
        let teacher = crate::solvers::registry::get(&cfg.teacher)
            .ok_or_else(|| format!("unknown teacher solver {}", cfg.teacher))?;
        ground_truth_into(
            &mut self.gt,
            &mut self.engine,
            teacher.as_ref(),
            model,
            &self.x_t[..n * dim],
            n,
            sched,
            cfg.teacher_nfe,
        );

        // Uncorrected student run for the Figure-3a curve.
        resize_min(&mut self.x0_tmp, n * dim);
        self.engine.run_into(
            solver,
            model,
            &self.x_t[..n * dim],
            n,
            sched,
            None,
            &mut self.x0_tmp[..n * dim],
        );
        self.curve_uncorrected = truncation_error_curve(self.engine.xs().view(), &self.gt);

        // Rollout stores: node 0 is the prior draw.
        self.xs.reset(n * dim, n_steps + 1);
        self.xs.push_row(&self.x_t[..n * dim]);
        self.ds.reset(n * dim, n_steps.max(1));

        // Basis storage + per-chunk PCA scratch.
        self.bases.reset(n, dim, n_basis);
        let pool = Pool::global();
        let max_parts = self.max_parts();
        self.part_pca = pool.partition(n, max_parts, 1);
        let light_rows = (MIN_SGD_SHARD_ELEMS / dim.max(1)).max(1);
        self.part_light = pool.partition(n, max_parts, light_rows);
        while self.pca.len() < self.part_pca.1 {
            self.pca.push(PcaScratch::new());
        }

        // Step workspaces.
        for buf in [
            &mut self.d_all,
            &mut self.base,
            &mut self.x_next_unc,
            &mut self.x_next_cor,
            &mut self.d_used,
        ] {
            resize_min(buf, n * dim);
        }
        resize_min(&mut self.zeros, n * dim);
        self.zeros[..n * dim].fill(0.0);
        let spec = solver.scratch_spec(dim, n);
        resize_min(
            &mut self.step_scratch,
            spec.per_row * n + spec.flat * max_parts.max(1),
        );

        // SGD + decision workspaces.
        let mb_max = cfg.minibatch.min(n).max(1);
        resize_min(&mut self.terms, mb_max * n_basis);
        if self.term_k.len() < mb_max {
            self.term_k.resize(mb_max, 0);
        }
        resize_min(&mut self.chunk_scratch, max_parts.max(1) * (3 * dim + n_basis));
        for buf in [
            &mut self.c,
            &mut self.grad,
            &mut self.adam_m,
            &mut self.adam_v,
        ] {
            resize_min(buf, n_basis);
        }
        resize_min(&mut self.l_unc_s, n);
        resize_min(&mut self.l_cor_s, n);
        if self.kept.len() < n_steps {
            self.kept.resize(n_steps, false);
        }
        self.kept[..n_steps].fill(false);
        resize_min(&mut self.kept_coords, n_steps.max(1) * n_basis);

        self.le = Some(LossEval::new(&cfg.loss, dim));
        self.trace.reset_with_capacity(n_steps);
        Ok(())
    }

    /// Phase 2: train time point `j` (0-based; paper index `N - j`) and
    /// advance the rollout. Zero heap allocations in steady state.
    pub fn train_step(
        &mut self,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        sched: &Schedule,
        j: usize,
    ) -> Result<(), String> {
        let (n, dim, n_steps) = (self.n, self.dim, self.n_steps);
        assert_eq!(
            self.xs.len(),
            j + 1,
            "train_step({j}) called out of order (rollout at node {})",
            self.xs.len()
        );
        let n_basis = self.cfg.n_basis;
        let scale_mode = self.cfg.scale_mode;
        let i_paper = n_steps - j;
        let t = sched.ts[j];
        let t_next = sched.ts[j + 1];
        let pool = Pool::global();

        // Primary evaluation at the current (corrected) rollout state.
        let x_cur = self.xs.view().row(j);
        model.eval_batch(x_cur, n, t, &mut self.d_all[..n * dim]);
        let ctx = StepCtx {
            j,
            i_paper,
            t,
            t_next,
            sched,
            xs: self.xs.view(),
            ds: self.ds.view(),
        };
        let gamma = solver
            .gamma(&ctx)
            .ok_or_else(|| format!("solver {} does not support PAS", solver.name()))?;
        let spec = solver.scratch_spec(dim, n);
        // Affine base (step with d = 0) and uncorrected next state, both
        // through the engine's row-sharded dispatch.
        step_rows(
            self.threads,
            solver,
            model,
            &ctx,
            x_cur,
            &self.zeros[..n * dim],
            n,
            dim,
            spec,
            &mut self.step_scratch,
            &mut self.base[..n * dim],
        );
        step_rows(
            self.threads,
            solver,
            model,
            &ctx,
            x_cur,
            &self.d_all[..n * dim],
            n,
            dim,
            spec,
            &mut self.step_scratch,
            &mut self.x_next_unc[..n * dim],
        );

        // Per-sample bases into the store, sharded over the pool with
        // per-chunk scratch (samples are independent: bit-identical to
        // the sequential loop for every thread count).
        let (pchunk, pchunks) = self.part_pca;
        {
            let xs_view = self.xs.view();
            let ds_view = self.ds.view();
            let d_all = &self.d_all[..n * dim];
            let stride = self.bases.stride();
            let (u, ks, dns) = self.bases.raw_parts_mut();
            let u_ptr = SendPtr::new(u.as_mut_ptr());
            let k_ptr = SendPtr::new(ks.as_mut_ptr());
            let dn_ptr = SendPtr::new(dns.as_mut_ptr());
            let pca_ptr = SendPtr::new(self.pca.as_mut_ptr());
            pool.run(pchunks, &|ci| {
                let r0 = ci * pchunk;
                let r1 = ((ci + 1) * pchunk).min(n);
                // SAFETY: chunk indices are distinct, so the scratch slot
                // and every per-sample output range are touched by this
                // task only.
                let scratch = unsafe { &mut *pca_ptr.get().add(ci) };
                for s in r0..r1 {
                    scratch.clear_q(dim);
                    scratch.push_q_row(&xs_view.row(0)[s * dim..(s + 1) * dim]);
                    for jj in 0..j {
                        scratch.push_q_row(&ds_view.row(jj)[s * dim..(s + 1) * dim]);
                    }
                    // SAFETY: sample `s` lies in this chunk's [r0, r1)
                    // range only, so its stride-sized U row is written by
                    // this task alone.
                    let u_row = unsafe {
                        std::slice::from_raw_parts_mut(u_ptr.get().add(s * stride), stride)
                    };
                    let (kk, dn) =
                        pca_basis_into(scratch, &d_all[s * dim..(s + 1) * dim], n_basis, u_row);
                    // SAFETY: same disjointness — per-sample k/d_norm
                    // slots are owned by this chunk.
                    unsafe {
                        *k_ptr.get().add(s) = kk;
                        *dn_ptr.get().add(s) = dn;
                    }
                }
            });
        }

        // Initialize coordinates (Eq. 15): c1 anchors the identity
        // reconstruction; shared across samples, so absolute mode uses
        // the mean direction norm.
        self.c[..n_basis].fill(0.0);
        self.c[0] = match scale_mode {
            ScaleMode::Absolute => {
                let mut s = 0.0;
                for i in 0..n {
                    s += self.bases.basis(i).d_norm;
                }
                s / n as f64
            }
            ScaleMode::Relative => 1.0,
        };

        // SGD/Adam over shared coordinates. Per-sample gradient terms are
        // computed in parallel, then reduced sequentially in minibatch
        // order — the reduction is the exact floating-point sum the
        // sequential reference performs.
        let gt_node = self.gt.node(j + 1);
        let slot_len = 3 * dim + n_basis;
        let sgd_rows = (MIN_SGD_SHARD_ELEMS / dim.max(1)).max(1);
        let max_parts = self.max_parts();
        self.adam_m[..n_basis].fill(0.0);
        self.adam_v[..n_basis].fill(0.0);
        let mut step_count = 0usize;
        let (lr, tau) = (self.cfg.lr, self.cfg.tau);
        let (epochs, minibatch, optimizer) = (self.cfg.epochs, self.cfg.minibatch, self.cfg.optimizer);
        for _epoch in 0..epochs {
            self.rng.permutation_into(n, &mut self.perm);
            let mut mb0 = 0usize;
            while mb0 < n {
                let mb1 = (mb0 + minibatch).min(n);
                let mb = &self.perm[mb0..mb1];
                let mb_len = mb.len();
                // Parallel phase: independent per-sample terms
                // `gs · (U ∇_x loss)` into the staging buffer.
                {
                    let le = self.le.as_ref().unwrap();
                    let bases = &self.bases;
                    let coords = &self.c[..n_basis];
                    let base = &self.base[..n * dim];
                    let terms_ptr = SendPtr::new(self.terms.as_mut_ptr());
                    let termk_ptr = SendPtr::new(self.term_k.as_mut_ptr());
                    let slot_ptr = SendPtr::new(self.chunk_scratch.as_mut_ptr());
                    let (mchunk, mchunks) = pool.partition(mb_len, max_parts, sgd_rows);
                    pool.run(mchunks, &|ci| {
                        let r0 = ci * mchunk;
                        let r1 = ((ci + 1) * mchunk).min(mb_len);
                        // SAFETY: chunk indices are distinct → disjoint
                        // scratch slots and term rows.
                        let slot = unsafe {
                            std::slice::from_raw_parts_mut(
                                slot_ptr.get().add(ci * slot_len),
                                slot_len,
                            )
                        };
                        let (dtilde, rest) = slot.split_at_mut(dim);
                        let (resid, rest) = rest.split_at_mut(dim);
                        let (gx, rest) = rest.split_at_mut(dim);
                        let proj = &mut rest[..n_basis];
                        for idx in r0..r1 {
                            let sk = mb[idx];
                            let b = bases.basis(sk);
                            // SAFETY: idx ∈ [r0, r1) — this chunk owns
                            // the per-index term_k slot.
                            unsafe { *termk_ptr.get().add(idx) = b.k };
                            if b.k == 0 {
                                continue;
                            }
                            let s = match scale_mode {
                                ScaleMode::Absolute => 1.0,
                                ScaleMode::Relative => b.d_norm,
                            };
                            b.direction_into(coords, dtilde);
                            for v in dtilde.iter_mut() {
                                *v *= s;
                            }
                            // x' = base + gamma d~ ; residual vs ground truth.
                            let bk = &base[sk * dim..(sk + 1) * dim];
                            let gk = &gt_node[sk * dim..(sk + 1) * dim];
                            for m in 0..dim {
                                resid[m] = bk[m] + gamma * dtilde[m] - gk[m];
                            }
                            le.grad(resid, gx);
                            // ∇_C = gamma · s · U ∇_x loss — the U·g
                            // matvec goes through the tiled projection
                            // kernel.
                            let gs = gamma * s / mb_len as f64;
                            b.project_into(gx, proj);
                            // SAFETY: idx ∈ [r0, r1) — the n_basis-sized
                            // term row is written by this chunk alone.
                            let trow = unsafe {
                                std::slice::from_raw_parts_mut(
                                    terms_ptr.get().add(idx * n_basis),
                                    n_basis,
                                )
                            };
                            for (m, p) in proj.iter().take(b.k).enumerate() {
                                trow[m] = gs * p;
                            }
                        }
                    });
                }
                // Sequential reduction in minibatch order: identical
                // addition chain to the reference inner loop.
                self.grad[..n_basis].fill(0.0);
                for idx in 0..mb_len {
                    let kk = self.term_k[idx];
                    for m in 0..kk {
                        self.grad[m] += self.terms[idx * n_basis + m];
                    }
                }
                step_count += 1;
                match optimizer {
                    Optimizer::Sgd => {
                        for (cm, g) in self.c[..n_basis].iter_mut().zip(self.grad.iter()) {
                            *cm -= lr * g;
                        }
                    }
                    Optimizer::Adam => {
                        let (b1, b2, eps) = (0.9, 0.999, 1e-8);
                        let t_ = step_count as f64;
                        for m in 0..n_basis {
                            self.adam_m[m] = b1 * self.adam_m[m] + (1.0 - b1) * self.grad[m];
                            self.adam_v[m] =
                                b2 * self.adam_v[m] + (1.0 - b2) * self.grad[m] * self.grad[m];
                            let mh = self.adam_m[m] / (1.0 - b1.powf(t_));
                            let vh = self.adam_v[m] / (1.0 - b2.powf(t_));
                            self.c[m] -= lr * mh / (vh.sqrt() + eps);
                        }
                    }
                }
                mb0 = mb1;
            }
        }

        // Adaptive decision (Eq. 20): per-sample losses in parallel, mean
        // reduced sequentially in ascending sample order.
        let (lchunk, lchunks) = self.part_light;
        {
            let le = self.le.as_ref().unwrap();
            let bases = &self.bases;
            let coords = &self.c[..n_basis];
            let base = &self.base[..n * dim];
            let x_unc = &self.x_next_unc[..n * dim];
            let xc_ptr = SendPtr::new(self.x_next_cor.as_mut_ptr());
            let lu_ptr = SendPtr::new(self.l_unc_s.as_mut_ptr());
            let lc_ptr = SendPtr::new(self.l_cor_s.as_mut_ptr());
            let slot_ptr = SendPtr::new(self.chunk_scratch.as_mut_ptr());
            pool.run(lchunks, &|ci| {
                let r0 = ci * lchunk;
                let r1 = ((ci + 1) * lchunk).min(n);
                // SAFETY: disjoint chunk → disjoint scratch slot and
                // per-sample output ranges.
                let slot = unsafe {
                    std::slice::from_raw_parts_mut(slot_ptr.get().add(ci * slot_len), slot_len)
                };
                let (dtilde, rest) = slot.split_at_mut(dim);
                let resid = &mut rest[..dim];
                for s in r0..r1 {
                    let b = bases.basis(s);
                    let sc = match scale_mode {
                        ScaleMode::Absolute => 1.0,
                        ScaleMode::Relative => b.d_norm,
                    };
                    b.direction_into(coords, dtilde);
                    for v in dtilde.iter_mut() {
                        *v *= sc;
                    }
                    let bk = &base[s * dim..(s + 1) * dim];
                    let gk = &gt_node[s * dim..(s + 1) * dim];
                    // SAFETY: sample `s` is in this chunk's [r0, r1) only
                    // — its corrected-x row has a single writer.
                    let xc = unsafe {
                        std::slice::from_raw_parts_mut(xc_ptr.get().add(s * dim), dim)
                    };
                    for m in 0..dim {
                        xc[m] = bk[m] + gamma * dtilde[m];
                        resid[m] = xc[m] - gk[m];
                    }
                    let lc = le.value(resid);
                    let xu = &x_unc[s * dim..(s + 1) * dim];
                    for m in 0..dim {
                        resid[m] = xu[m] - gk[m];
                    }
                    let lu = le.value(resid);
                    // SAFETY: same per-sample disjointness for the loss
                    // slots.
                    unsafe {
                        *lc_ptr.get().add(s) = lc;
                        *lu_ptr.get().add(s) = lu;
                    }
                }
            });
        }
        let mut l_unc = 0.0;
        let mut l_cor = 0.0;
        for s in 0..n {
            l_cor += self.l_cor_s[s];
            l_unc += self.l_unc_s[s];
        }
        l_unc /= n as f64;
        l_cor /= n as f64;
        let keep = if self.force_all {
            // PAS(-AS): always store unless training completely diverged
            // into non-finite territory.
            self.c[..n_basis].iter().all(|v| v.is_finite())
        } else {
            decide(l_unc, l_cor, tau)
        };
        self.trace
            .decisions
            .push(AdaptiveDecision::evaluate(i_paper, l_unc, l_cor, tau));
        if self.force_all {
            self.trace.decisions.last_mut().unwrap().corrected = keep;
        }

        // Advance the rollout with the kept direction (Alg 1 lines 16–19).
        if keep {
            self.kept[j] = true;
            self.kept_coords[j * n_basis..(j + 1) * n_basis].copy_from_slice(&self.c[..n_basis]);
            {
                let bases = &self.bases;
                let coords = &self.c[..n_basis];
                let d_all = &self.d_all[..n * dim];
                let du_ptr = SendPtr::new(self.d_used.as_mut_ptr());
                let slot_ptr = SendPtr::new(self.chunk_scratch.as_mut_ptr());
                pool.run(lchunks, &|ci| {
                    let r0 = ci * lchunk;
                    let r1 = ((ci + 1) * lchunk).min(n);
                    // SAFETY: disjoint chunk → disjoint scratch slot and
                    // direction rows.
                    let slot = unsafe {
                        std::slice::from_raw_parts_mut(slot_ptr.get().add(ci * slot_len), slot_len)
                    };
                    let dtilde = &mut slot[..dim];
                    for s in r0..r1 {
                        let b = bases.basis(s);
                        let sc = match scale_mode {
                            ScaleMode::Absolute => 1.0,
                            ScaleMode::Relative => b.d_norm,
                        };
                        b.direction_into(coords, dtilde);
                        // SAFETY: sample `s` is in this chunk's [r0, r1)
                        // only — the d_used row has a single writer.
                        let du = unsafe {
                            std::slice::from_raw_parts_mut(du_ptr.get().add(s * dim), dim)
                        };
                        for (m, v) in dtilde.iter().enumerate() {
                            du[m] = sc * v;
                        }
                        // Guard: an empty basis falls back to the raw
                        // direction.
                        if b.k == 0 {
                            du.copy_from_slice(&d_all[s * dim..(s + 1) * dim]);
                        }
                    }
                });
            }
            self.xs.push_row(&self.x_next_cor[..n * dim]);
            self.ds.push_row(&self.d_used[..n * dim]);
        } else {
            // Revert to the plain solver step; discard trained coords.
            self.xs.push_row(&self.x_next_unc[..n * dim]);
            self.ds.push_row(&self.d_all[..n * dim]);
        }
        Ok(())
    }

    /// Phase 3: materialize the [`TrainResult`] (dict, curves, trace).
    pub fn finish(&mut self) -> TrainResult {
        let (n_steps, n_basis) = (self.n_steps, self.cfg.n_basis);
        let curve_corrected = truncation_error_curve(self.xs.view(), &self.gt);
        let mut dict = CoordinateDict::new(
            n_basis,
            self.cfg.scale_mode,
            &self.solver_name,
            &self.dataset,
            n_steps,
        );
        for j in 0..n_steps {
            if self.kept[j] {
                dict.steps.insert(
                    n_steps - j,
                    self.kept_coords[j * n_basis..(j + 1) * n_basis].to_vec(),
                );
            }
        }
        TrainResult {
            dict,
            trace: std::mem::take(&mut self.trace),
            curve_uncorrected: std::mem::take(&mut self.curve_uncorrected),
            curve_corrected,
            train_seconds: self.timer.elapsed_s(),
            teacher_nfe_spent: self.gt.teacher_nfe,
        }
    }
}

/// Grow-only resize (the session's workspace discipline).
fn resize_min(buf: &mut Vec<f64>, len: usize) {
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
}

pub struct PasTrainer {
    pub cfg: TrainConfig,
}

impl PasTrainer {
    pub fn new(cfg: TrainConfig) -> PasTrainer {
        PasTrainer { cfg }
    }

    /// Run Algorithm 1 for `solver` on `model` over `sched`.
    ///
    /// `force_all_steps` disables the adaptive rule and stores every step
    /// (the PAS(-AS) ablation, Table 7 / Fig. 6a).
    pub fn train(
        &self,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        sched: &Schedule,
        dataset_name: &str,
        force_all_steps: bool,
    ) -> Result<TrainResult, String> {
        self.train_tp(solver, model, sched, dataset_name, force_all_steps, None)
    }

    /// [`Self::train`] with an optional teleportation warm start: priors
    /// are drawn at `t_gen` and transported analytically to the schedule's
    /// `t_max` (= `sigma_skip`) before training — the `+TP+PAS` rows.
    ///
    /// One-shot wrapper over [`TrainSession`]; long-lived callers (the
    /// serving-side online trainer, sweeps) hold a session to reuse its
    /// workspaces across runs.
    pub fn train_tp(
        &self,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        sched: &Schedule,
        dataset_name: &str,
        force_all_steps: bool,
        teleport: Option<(&crate::pas::teleport::Teleporter, f64)>,
    ) -> Result<TrainResult, String> {
        TrainSession::new(self.cfg.clone()).train(
            solver,
            model,
            sched,
            dataset_name,
            force_all_steps,
            teleport,
        )
    }

    /// The pre-`TrainSession` sequential monolith, kept verbatim as the
    /// **bitwise oracle**: `tests/golden_training.rs` asserts the session
    /// reproduces its trained dict and curves exactly (for every thread
    /// cap), and `benches/train_time.rs` reports the session's speedup
    /// over it. Allocates per sample per step (nested rollout rows,
    /// `TrajBuffer`s, a fresh `Basis` per extraction) — do not use on a
    /// hot path.
    pub fn train_tp_reference(
        &self,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        sched: &Schedule,
        dataset_name: &str,
        force_all_steps: bool,
        teleport: Option<(&crate::pas::teleport::Teleporter, f64)>,
    ) -> Result<TrainResult, String> {
        let cfg = &self.cfg;
        let dim = model.dim();
        let n = cfg.n_traj;
        let n_steps = sched.n_steps();
        let timer = Timer::start();
        let mut rng = Pcg64::seed_stream(cfg.seed, 0x7a5);

        // Ground truth (teacher trajectories on the shared grid),
        // optionally warm-started via teleportation.
        let x_t = match teleport {
            None => sample_prior(&mut rng, n, dim, sched.t_max()),
            Some((tp, t_gen)) => {
                let mut x = sample_prior(&mut rng, n, dim, t_gen);
                tp.teleport(&mut x, n, t_gen, sched.t_max());
                x
            }
        };
        let teacher = crate::solvers::registry::get(&cfg.teacher)
            .ok_or_else(|| format!("unknown teacher solver {}", cfg.teacher))?;
        let gt: GroundTruth =
            ground_truth(teacher.as_ref(), model, &x_t, n, sched, cfg.teacher_nfe);

        // Uncorrected student run for the Figure-3a curve.
        let unc = crate::solvers::run_solver(solver, model, &x_t, n, sched, None);
        let curve_uncorrected = truncation_error_curve(NodeView::nested(&unc.xs), &gt);

        // Live (corrected) rollout state.
        let mut xs: Vec<Vec<f64>> = vec![x_t.clone()];
        let mut ds: Vec<Vec<f64>> = Vec::new();
        let mut buffers: Vec<TrajBuffer> = (0..n)
            .map(|k| {
                let mut b = TrajBuffer::with_capacity(dim, n_steps + 2);
                b.push(&x_t[k * dim..(k + 1) * dim]);
                b
            })
            .collect();

        let le = LossEval::new(&cfg.loss, dim);
        let mut dict = CoordinateDict::new(
            cfg.n_basis,
            cfg.scale_mode,
            solver.name(),
            dataset_name,
            n_steps,
        );
        let mut trace = AdaptiveTrace::default();

        let mut d_all = vec![0.0; n * dim];
        let mut base = vec![0.0; n * dim];
        let mut x_next_unc = vec![0.0; n * dim];
        let zeros = vec![0.0; n * dim];
        // One arena reused by both per-step solver calls (gamma path).
        let mut step_scratch = vec![0.0; solver.scratch_spec(dim, n).len_for(n)];

        for j in 0..n_steps {
            let i_paper = n_steps - j;
            model.eval_batch(&xs[j], n, sched.ts[j], &mut d_all);
            let ctx = StepCtx {
                j,
                i_paper,
                t: sched.ts[j],
                t_next: sched.ts[j + 1],
                sched,
                xs: NodeView::nested(&xs),
                ds: NodeView::nested(&ds),
            };
            let gamma = solver
                .gamma(&ctx)
                .ok_or_else(|| format!("solver {} does not support PAS", solver.name()))?;
            // Affine base: step with d = 0.
            let mut sc = StepScratch::new(&mut step_scratch);
            solver.step(model, &ctx, &xs[j], &zeros, n, &mut base, &mut sc);
            // Uncorrected next state (for the adaptive decision).
            let mut sc = StepScratch::new(&mut step_scratch);
            solver.step(model, &ctx, &xs[j], &d_all, n, &mut x_next_unc, &mut sc);

            // Per-sample bases (sequential allocating path — the oracle).
            let bases: Vec<Basis> = (0..n)
                .map(|k| pca_basis(&buffers[k], &d_all[k * dim..(k + 1) * dim], cfg.n_basis))
                .collect();
            let scale_of = |b: &Basis| match cfg.scale_mode {
                ScaleMode::Absolute => 1.0,
                ScaleMode::Relative => b.d_norm,
            };

            // Initialize coordinates (Eq. 15).
            let mut c = vec![0.0; cfg.n_basis];
            c[0] = match cfg.scale_mode {
                ScaleMode::Absolute => {
                    bases.iter().map(|b| b.d_norm).sum::<f64>() / n as f64
                }
                ScaleMode::Relative => 1.0,
            };

            // SGD/Adam over shared coordinates.
            let gt_node = gt.node(j + 1);
            let mut adam_m = vec![0.0; cfg.n_basis];
            let mut adam_v = vec![0.0; cfg.n_basis];
            let mut step_count = 0usize;
            let mut grad = vec![0.0; cfg.n_basis];
            let mut proj = vec![0.0; cfg.n_basis];
            let mut dtilde = vec![0.0; dim];
            let mut resid = vec![0.0; dim];
            let mut gx = vec![0.0; dim];
            for _epoch in 0..cfg.epochs {
                let perm = rng.permutation(n);
                for chunk in perm.chunks(cfg.minibatch) {
                    grad.fill(0.0);
                    for &k in chunk {
                        let b = &bases[k];
                        if b.k == 0 {
                            continue;
                        }
                        let s = scale_of(b);
                        b.direction_into(&c, &mut dtilde);
                        for v in dtilde.iter_mut() {
                            *v *= s;
                        }
                        // x' = base + gamma d~ ; residual vs ground truth.
                        let bk = &base[k * dim..(k + 1) * dim];
                        let gk = &gt_node[k * dim..(k + 1) * dim];
                        for m in 0..dim {
                            resid[m] = bk[m] + gamma * dtilde[m] - gk[m];
                        }
                        le.grad(&resid, &mut gx);
                        // ∇_C = gamma · s · U ∇_x loss.
                        let gs = gamma * s / chunk.len() as f64;
                        b.project_into(&gx, &mut proj);
                        for (m, g) in grad.iter_mut().take(b.k).enumerate() {
                            *g += gs * proj[m];
                        }
                    }
                    step_count += 1;
                    match cfg.optimizer {
                        Optimizer::Sgd => {
                            for (cm, g) in c.iter_mut().zip(grad.iter()) {
                                *cm -= cfg.lr * g;
                            }
                        }
                        Optimizer::Adam => {
                            let (b1, b2, eps) = (0.9, 0.999, 1e-8);
                            let t_ = step_count as f64;
                            for m in 0..cfg.n_basis {
                                adam_m[m] = b1 * adam_m[m] + (1.0 - b1) * grad[m];
                                adam_v[m] = b2 * adam_v[m] + (1.0 - b2) * grad[m] * grad[m];
                                let mh = adam_m[m] / (1.0 - b1.powf(t_));
                                let vh = adam_v[m] / (1.0 - b2.powf(t_));
                                c[m] -= cfg.lr * mh / (vh.sqrt() + eps);
                            }
                        }
                    }
                }
            }

            // Adaptive decision (Eq. 20): mean per-sample losses.
            let mut x_next_cor = vec![0.0; n * dim];
            let mut l_unc = 0.0;
            let mut l_cor = 0.0;
            for k in 0..n {
                let b = &bases[k];
                let s = scale_of(b);
                b.direction_into(&c, &mut dtilde);
                for v in dtilde.iter_mut() {
                    *v *= s;
                }
                let bk = &base[k * dim..(k + 1) * dim];
                let gk = &gt_node[k * dim..(k + 1) * dim];
                let xc = &mut x_next_cor[k * dim..(k + 1) * dim];
                for m in 0..dim {
                    xc[m] = bk[m] + gamma * dtilde[m];
                    resid[m] = xc[m] - gk[m];
                }
                l_cor += le.value(&resid);
                let xu = &x_next_unc[k * dim..(k + 1) * dim];
                for m in 0..dim {
                    resid[m] = xu[m] - gk[m];
                }
                l_unc += le.value(&resid);
            }
            l_unc /= n as f64;
            l_cor /= n as f64;
            let keep = if force_all_steps {
                c.iter().all(|v| v.is_finite())
            } else {
                decide(l_unc, l_cor, cfg.tau)
            };
            trace
                .decisions
                .push(AdaptiveDecision::evaluate(i_paper, l_unc, l_cor, cfg.tau));
            if force_all_steps {
                trace.decisions.last_mut().unwrap().corrected = keep;
            }

            // Advance the rollout with the kept direction (Alg 1 lines 16–19).
            if keep {
                dict.steps.insert(i_paper, c.clone());
                let mut d_used = vec![0.0; n * dim];
                for k in 0..n {
                    let b = &bases[k];
                    let s = scale_of(b);
                    b.direction_into(&c, &mut dtilde);
                    for (m, v) in dtilde.iter().enumerate() {
                        d_used[k * dim + m] = s * v;
                    }
                    // Guard: an empty basis falls back to the raw direction.
                    if b.k == 0 {
                        d_used[k * dim..(k + 1) * dim]
                            .copy_from_slice(&d_all[k * dim..(k + 1) * dim]);
                    }
                }
                xs.push(x_next_cor);
                for k in 0..n {
                    buffers[k].push(&d_used[k * dim..(k + 1) * dim]);
                }
                ds.push(d_used);
            } else {
                xs.push(x_next_unc.clone());
                for k in 0..n {
                    buffers[k].push(&d_all[k * dim..(k + 1) * dim]);
                }
                ds.push(d_all.clone());
            }
        }

        let curve_corrected = truncation_error_curve(NodeView::nested(&xs), &gt);
        Ok(TrainResult {
            dict,
            trace,
            curve_uncorrected,
            curve_corrected,
            train_seconds: timer.elapsed_s(),
            teacher_nfe_spent: gt.teacher_nfe,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry::get;
    use crate::schedule::default_schedule;
    use crate::score::analytic::AnalyticEps;
    use crate::solvers::registry as solvers;

    fn quick_cfg() -> TrainConfig {
        TrainConfig {
            n_traj: 48,
            epochs: 24,
            minibatch: 16,
            teacher_nfe: 60,
            lr: 5e-2,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn training_reduces_final_truncation_error() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(8);
        let solver = solvers::get("ddim").unwrap();
        let tr = PasTrainer::new(TrainConfig {
            scale_mode: ScaleMode::Relative,
            ..quick_cfg()
        })
        .train(solver.as_ref(), model.as_ref(), &sched, "gmm2d", false)
        .unwrap();
        let before = *tr.curve_uncorrected.last().unwrap();
        let after = *tr.curve_corrected.last().unwrap();
        assert!(
            after < before * 0.9,
            "PAS must cut final truncation error: {before} -> {after}"
        );
        assert!(!tr.dict.steps.is_empty(), "no steps corrected");
    }

    #[test]
    fn adaptive_search_skips_some_steps() {
        let ds = get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(8);
        let solver = solvers::get("ddim").unwrap();
        let tr = PasTrainer::new(quick_cfg())
            .train(solver.as_ref(), model.as_ref(), &sched, "gmm-hd64", false)
            .unwrap();
        let corrected = tr.dict.steps.len();
        assert!(corrected < 8, "adaptive search must not correct all steps");
        // The "~10 parameters" property.
        assert!(tr.dict.n_params() <= 8 * 4);
    }

    #[test]
    fn unsupported_solver_errors() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(4);
        let heun = solvers::get("heun").unwrap();
        let err = PasTrainer::new(quick_cfg())
            .train(heun.as_ref(), model.as_ref(), &sched, "gmm2d", false)
            .unwrap_err();
        assert!(err.contains("does not support PAS"), "{err}");
    }

    /// The session must reproduce the sequential reference monolith
    /// bitwise — dict coordinates, adaptive trace, and both curves — and
    /// its workspaces must be cleanly reusable across runs (second run of
    /// a different shape still matches).
    #[test]
    fn session_matches_reference_bitwise_and_reuses_cleanly() {
        let ds = get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let solver = solvers::get("ddim").unwrap();
        let mut session = TrainSession::new(quick_cfg());
        for (steps, force_all) in [(6usize, false), (4, true), (6, false)] {
            let sched = default_schedule(steps);
            let got = session
                .train(solver.as_ref(), model.as_ref(), &sched, "gmm-hd64", force_all, None)
                .unwrap();
            let want = PasTrainer::new(quick_cfg())
                .train_tp_reference(
                    solver.as_ref(),
                    model.as_ref(),
                    &sched,
                    "gmm-hd64",
                    force_all,
                    None,
                )
                .unwrap();
            assert_eq!(
                got.dict.steps, want.dict.steps,
                "dict mismatch (steps={steps}, force_all={force_all})"
            );
            assert_eq!(got.curve_uncorrected, want.curve_uncorrected);
            assert_eq!(got.curve_corrected, want.curve_corrected);
            assert_eq!(got.trace.corrected_steps(), want.trace.corrected_steps());
            assert_eq!(got.teacher_nfe_spent, want.teacher_nfe_spent);
        }
    }

    #[test]
    fn losses_have_consistent_gradients() {
        // Finite-difference check for each loss.
        let dim = 12;
        let mut rng = Pcg64::seed(5);
        let r = rng.normal_vec(dim);
        for loss in [
            Loss::L2,
            Loss::PseudoHuber { c: 0.1 },
            Loss::RpFeat {
                proj_dim: 6,
                seed: 3,
            },
        ] {
            let le = LossEval::new(&loss, dim);
            let mut g = vec![0.0; dim];
            le.grad(&r, &mut g);
            for m in 0..dim {
                let h = 1e-6;
                let mut rp = r.clone();
                rp[m] += h;
                let mut rm = r.clone();
                rm[m] -= h;
                let fd = (le.value(&rp) - le.value(&rm)) / (2.0 * h);
                assert!(
                    (fd - g[m]).abs() < 1e-5 * (1.0 + fd.abs()),
                    "{}: fd {fd} vs {}",
                    loss.name(),
                    g[m]
                );
            }
        }
    }
}
