//! PAS training — Algorithm 1.
//!
//! Time points are trained **sequentially** (correcting step `i` shifts
//! every later state), sharing one coordinate vector `C` across all
//! training trajectories while the basis `U^k` is per-sample. Because every
//! PAS-supported solver is *affine in the current direction*
//! (`x' = base + gamma · d`, with `gamma` from [`crate::solvers::Solver::gamma`]),
//! the coordinate gradient is analytic — no autodiff anywhere:
//!
//! ```text
//! x'_k(C)  = base_k + gamma · s_k · U_kᵀ C      (s_k = 1 or ||d_k||)
//! ∇_C loss = gamma · s_k · U_k · ∇_{x'} loss
//! ```
//!
//! Losses are evaluated **per dimension** (mean, not sum) so the tolerance
//! `tau` transfers across datasets of different dimension; this is the one
//! normalization choice we add on top of the paper (documented in
//! DESIGN.md §3).

use super::adaptive::{decide, AdaptiveDecision, AdaptiveTrace};
use super::coords::{CoordinateDict, ScaleMode};
use super::pca::{pca_basis, Basis, TrajBuffer};
use crate::schedule::Schedule;
use crate::score::EpsModel;
use crate::solvers::{NodeView, Solver, StepCtx, StepScratch};
use crate::traj::{ground_truth, sample_prior, truncation_error_curve, GroundTruth};
use crate::util::pool::{Pool, SendPtr};
use crate::util::rng::Pcg64;
use crate::util::timer::Timer;

/// Training loss functions (Fig. 6b ablation).
#[derive(Clone, Debug)]
pub enum Loss {
    L1,
    L2,
    /// Pseudo-Huber with softening constant `c` (Song & Dhariwal 2024).
    PseudoHuber { c: f64 },
    /// Random-projection feature loss — our offline stand-in for LPIPS
    /// (frozen random features as an untrained perceptual proxy).
    RpFeat { proj_dim: usize, seed: u64 },
}

impl Loss {
    pub fn name(&self) -> &'static str {
        match self {
            Loss::L1 => "l1",
            Loss::L2 => "l2",
            Loss::PseudoHuber { .. } => "pseudo-huber",
            Loss::RpFeat { .. } => "rpfeat",
        }
    }

    pub fn parse(s: &str) -> Option<Loss> {
        match s {
            "l1" => Some(Loss::L1),
            "l2" => Some(Loss::L2),
            "pseudo-huber" => Some(Loss::PseudoHuber { c: 0.03 }),
            "rpfeat" => Some(Loss::RpFeat {
                proj_dim: 16,
                seed: 7,
            }),
            _ => None,
        }
    }
}

/// Loss evaluator with optional fixed random projection.
struct LossEval {
    loss: Loss,
    /// (proj_dim, d) row-major projection for RpFeat.
    proj: Option<(usize, Vec<f64>)>,
}

impl LossEval {
    fn new(loss: &Loss, dim: usize) -> LossEval {
        let proj = if let Loss::RpFeat { proj_dim, seed } = loss {
            let mut rng = Pcg64::seed_stream(*seed, 0x9f);
            let scale = 1.0 / (dim as f64).sqrt();
            let p: Vec<f64> = (0..proj_dim * dim).map(|_| rng.normal() * scale).collect();
            Some((*proj_dim, p))
        } else {
            None
        };
        LossEval {
            loss: loss.clone(),
            proj,
        }
    }

    /// Per-sample loss (mean per dimension) of residual `r`.
    fn value(&self, r: &[f64]) -> f64 {
        let d = r.len() as f64;
        match &self.loss {
            Loss::L1 => r.iter().map(|v| v.abs()).sum::<f64>() / d,
            Loss::L2 => r.iter().map(|v| v * v).sum::<f64>() / d,
            Loss::PseudoHuber { c } => {
                r.iter().map(|v| (v * v + c * c).sqrt() - c).sum::<f64>() / d
            }
            Loss::RpFeat { .. } => {
                let (p_dim, p) = self.proj.as_ref().unwrap();
                let mut s = 0.0;
                for row in 0..*p_dim {
                    let pr = crate::tensor::dot(&p[row * r.len()..(row + 1) * r.len()], r);
                    s += pr * pr;
                }
                s / *p_dim as f64
            }
        }
    }

    /// Gradient of the per-sample loss w.r.t. the residual, into `out`.
    fn grad(&self, r: &[f64], out: &mut [f64]) {
        let d = r.len() as f64;
        match &self.loss {
            Loss::L1 => {
                for (o, &v) in out.iter_mut().zip(r.iter()) {
                    *o = v.signum() / d;
                }
            }
            Loss::L2 => {
                for (o, &v) in out.iter_mut().zip(r.iter()) {
                    *o = 2.0 * v / d;
                }
            }
            Loss::PseudoHuber { c } => {
                for (o, &v) in out.iter_mut().zip(r.iter()) {
                    *o = v / (v * v + c * c).sqrt() / d;
                }
            }
            Loss::RpFeat { .. } => {
                let (p_dim, p) = self.proj.as_ref().unwrap();
                out.fill(0.0);
                let dl = r.len();
                for row in 0..*p_dim {
                    let prow = &p[row * dl..(row + 1) * dl];
                    let pr = crate::tensor::dot(prow, r);
                    let c = 2.0 * pr / *p_dim as f64;
                    for (o, &pv) in out.iter_mut().zip(prow.iter()) {
                        *o += c * pv;
                    }
                }
            }
        }
    }
}

/// Coordinate optimizer (the paper uses SGD; Adam is sturdier across our
/// dataset scales and is the default — `repro fig7` sweeps the lr either way).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Optimizer {
    Sgd,
    Adam,
}

/// Full training configuration (defaults follow the paper's recommended
/// settings, §4.1 and Appendix B).
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub n_basis: usize,
    pub lr: f64,
    pub epochs: usize,
    pub minibatch: usize,
    /// Number of ground-truth trajectories (paper: 5k; our datasets
    /// saturate far earlier — Fig. 6d analog sweeps this).
    pub n_traj: usize,
    pub tau: f64,
    pub loss: Loss,
    pub scale_mode: ScaleMode,
    pub optimizer: Optimizer,
    /// Teacher solver name (paper: Heun's 2nd).
    pub teacher: String,
    /// Teacher NFE budget (paper: 100).
    pub teacher_nfe: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            n_basis: 4,
            lr: 1e-2,
            epochs: 48,
            minibatch: 32,
            n_traj: 256,
            tau: 1e-2,
            loss: Loss::L1,
            scale_mode: ScaleMode::Absolute,
            optimizer: Optimizer::Adam,
            teacher: "heun".into(),
            teacher_nfe: 100,
            seed: 0,
        }
    }
}

/// Everything `PasTrainer::train` produces.
#[derive(Debug)]
pub struct TrainResult {
    pub dict: CoordinateDict,
    pub trace: AdaptiveTrace,
    /// Truncation-error curve of the *uncorrected* student vs ground truth
    /// (Figure 3a) on the training trajectories.
    pub curve_uncorrected: Vec<f64>,
    /// Truncation-error curve of the PAS-corrected student (Figure 3b).
    pub curve_corrected: Vec<f64>,
    pub train_seconds: f64,
    pub teacher_nfe_spent: usize,
}

pub struct PasTrainer {
    pub cfg: TrainConfig,
}

impl PasTrainer {
    pub fn new(cfg: TrainConfig) -> PasTrainer {
        PasTrainer { cfg }
    }

    /// Run Algorithm 1 for `solver` on `model` over `sched`.
    ///
    /// `force_all_steps` disables the adaptive rule and stores every step
    /// (the PAS(-AS) ablation, Table 7 / Fig. 6a).
    pub fn train(
        &self,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        sched: &Schedule,
        dataset_name: &str,
        force_all_steps: bool,
    ) -> Result<TrainResult, String> {
        self.train_tp(solver, model, sched, dataset_name, force_all_steps, None)
    }

    /// [`Self::train`] with an optional teleportation warm start: priors
    /// are drawn at `t_gen` and transported analytically to the schedule's
    /// `t_max` (= `sigma_skip`) before training — the `+TP+PAS` rows.
    pub fn train_tp(
        &self,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        sched: &Schedule,
        dataset_name: &str,
        force_all_steps: bool,
        teleport: Option<(&crate::pas::teleport::Teleporter, f64)>,
    ) -> Result<TrainResult, String> {
        let cfg = &self.cfg;
        let dim = model.dim();
        let n = cfg.n_traj;
        let n_steps = sched.n_steps();
        let timer = Timer::start();
        let mut rng = Pcg64::seed_stream(cfg.seed, 0x7a5);

        // Ground truth (teacher trajectories on the shared grid),
        // optionally warm-started via teleportation.
        let x_t = match teleport {
            None => sample_prior(&mut rng, n, dim, sched.t_max()),
            Some((tp, t_gen)) => {
                let mut x = sample_prior(&mut rng, n, dim, t_gen);
                tp.teleport(&mut x, n, t_gen, sched.t_max());
                x
            }
        };
        let teacher = crate::solvers::registry::get(&cfg.teacher)
            .ok_or_else(|| format!("unknown teacher solver {}", cfg.teacher))?;
        let gt: GroundTruth =
            ground_truth(teacher.as_ref(), model, &x_t, n, sched, cfg.teacher_nfe);

        // Uncorrected student run for the Figure-3a curve.
        let unc = crate::solvers::run_solver(solver, model, &x_t, n, sched, None);
        let curve_uncorrected = truncation_error_curve(&unc.xs, &gt);

        // Live (corrected) rollout state.
        let mut xs: Vec<Vec<f64>> = vec![x_t.clone()];
        let mut ds: Vec<Vec<f64>> = Vec::new();
        let mut buffers: Vec<TrajBuffer> = (0..n)
            .map(|k| {
                let mut b = TrajBuffer::with_capacity(dim, n_steps + 2);
                b.push(&x_t[k * dim..(k + 1) * dim]);
                b
            })
            .collect();

        let le = LossEval::new(&cfg.loss, dim);
        let mut dict = CoordinateDict::new(
            cfg.n_basis,
            cfg.scale_mode,
            solver.name(),
            dataset_name,
            n_steps,
        );
        let mut trace = AdaptiveTrace::default();

        let mut d_all = vec![0.0; n * dim];
        let mut base = vec![0.0; n * dim];
        let mut x_next_unc = vec![0.0; n * dim];
        let zeros = vec![0.0; n * dim];
        // One arena reused by both per-step solver calls (gamma path).
        let mut step_scratch = vec![0.0; solver.scratch_spec(dim, n).len_for(n)];

        for j in 0..n_steps {
            let i_paper = n_steps - j;
            model.eval_batch(&xs[j], n, sched.ts[j], &mut d_all);
            let ctx = StepCtx {
                j,
                i_paper,
                t: sched.ts[j],
                t_next: sched.ts[j + 1],
                sched,
                xs: NodeView::nested(&xs),
                ds: NodeView::nested(&ds),
            };
            let gamma = solver
                .gamma(&ctx)
                .ok_or_else(|| format!("solver {} does not support PAS", solver.name()))?;
            // Affine base: step with d = 0.
            let mut sc = StepScratch::new(&mut step_scratch);
            solver.step(model, &ctx, &xs[j], &zeros, n, &mut base, &mut sc);
            // Uncorrected next state (for the adaptive decision).
            let mut sc = StepScratch::new(&mut step_scratch);
            solver.step(model, &ctx, &xs[j], &d_all, n, &mut x_next_unc, &mut sc);

            // Per-sample bases, sharded row-wise over the pool (samples
            // are independent; same values as the sequential loop).
            let mut bases: Vec<Option<Basis>> = vec![None; n];
            {
                let out = SendPtr::new(bases.as_mut_ptr());
                let bufs = &buffers;
                let d_ref = &d_all;
                Pool::global().par_rows(n, usize::MAX, 1, |r0, r1| {
                    for k in r0..r1 {
                        let b = pca_basis(&bufs[k], &d_ref[k * dim..(k + 1) * dim], cfg.n_basis);
                        // SAFETY: pool row ranges are disjoint.
                        unsafe { *out.get().add(k) = Some(b) };
                    }
                });
            }
            let bases: Vec<Basis> = bases.into_iter().map(|b| b.unwrap()).collect();
            let scale_of = |b: &Basis| match cfg.scale_mode {
                ScaleMode::Absolute => 1.0,
                ScaleMode::Relative => b.d_norm,
            };

            // Initialize coordinates (Eq. 15): c1 anchors the identity
            // reconstruction; shared across samples, so absolute mode uses
            // the mean direction norm.
            let mut c = vec![0.0; cfg.n_basis];
            c[0] = match cfg.scale_mode {
                ScaleMode::Absolute => {
                    bases.iter().map(|b| b.d_norm).sum::<f64>() / n as f64
                }
                ScaleMode::Relative => 1.0,
            };
            let c_init = c.clone();

            // SGD/Adam over shared coordinates.
            let gt_node = &gt.xs[j + 1];
            let mut adam_m = vec![0.0; cfg.n_basis];
            let mut adam_v = vec![0.0; cfg.n_basis];
            let mut step_count = 0usize;
            let mut grad = vec![0.0; cfg.n_basis];
            let mut proj = vec![0.0; cfg.n_basis];
            let mut dtilde = vec![0.0; dim];
            let mut resid = vec![0.0; dim];
            let mut gx = vec![0.0; dim];
            for _epoch in 0..cfg.epochs {
                let perm = rng.permutation(n);
                for chunk in perm.chunks(cfg.minibatch) {
                    grad.fill(0.0);
                    for &k in chunk {
                        let b = &bases[k];
                        if b.k == 0 {
                            continue;
                        }
                        let s = scale_of(b);
                        b.direction_into(&c, &mut dtilde);
                        for v in dtilde.iter_mut() {
                            *v *= s;
                        }
                        // x' = base + gamma d~ ; residual vs ground truth.
                        let bk = &base[k * dim..(k + 1) * dim];
                        let gk = &gt_node[k * dim..(k + 1) * dim];
                        for m in 0..dim {
                            resid[m] = bk[m] + gamma * dtilde[m] - gk[m];
                        }
                        le.grad(&resid, &mut gx);
                        // ∇_C = gamma · s · U ∇_x loss — the U·g matvec
                        // goes through the tiled projection kernel
                        // (bit-identical to the former per-row dots).
                        let gs = gamma * s / chunk.len() as f64;
                        b.project_into(&gx, &mut proj);
                        for (m, g) in grad.iter_mut().take(b.k).enumerate() {
                            *g += gs * proj[m];
                        }
                    }
                    step_count += 1;
                    match cfg.optimizer {
                        Optimizer::Sgd => {
                            for (cm, g) in c.iter_mut().zip(grad.iter()) {
                                *cm -= cfg.lr * g;
                            }
                        }
                        Optimizer::Adam => {
                            let (b1, b2, eps) = (0.9, 0.999, 1e-8);
                            let t_ = step_count as f64;
                            for m in 0..cfg.n_basis {
                                adam_m[m] = b1 * adam_m[m] + (1.0 - b1) * grad[m];
                                adam_v[m] = b2 * adam_v[m] + (1.0 - b2) * grad[m] * grad[m];
                                let mh = adam_m[m] / (1.0 - b1.powf(t_));
                                let vh = adam_v[m] / (1.0 - b2.powf(t_));
                                c[m] -= cfg.lr * mh / (vh.sqrt() + eps);
                            }
                        }
                    }
                }
            }

            // Adaptive decision (Eq. 20): mean per-sample losses.
            let mut x_next_cor = vec![0.0; n * dim];
            let mut l_unc = 0.0;
            let mut l_cor = 0.0;
            for k in 0..n {
                let b = &bases[k];
                let s = scale_of(b);
                b.direction_into(&c, &mut dtilde);
                for v in dtilde.iter_mut() {
                    *v *= s;
                }
                let bk = &base[k * dim..(k + 1) * dim];
                let gk = &gt_node[k * dim..(k + 1) * dim];
                let xc = &mut x_next_cor[k * dim..(k + 1) * dim];
                for m in 0..dim {
                    xc[m] = bk[m] + gamma * dtilde[m];
                    resid[m] = xc[m] - gk[m];
                }
                l_cor += le.value(&resid);
                let xu = &x_next_unc[k * dim..(k + 1) * dim];
                for m in 0..dim {
                    resid[m] = xu[m] - gk[m];
                }
                l_unc += le.value(&resid);
            }
            l_unc /= n as f64;
            l_cor /= n as f64;
            let keep = if force_all_steps {
                // PAS(-AS): always store unless training completely
                // diverged into non-finite territory.
                c.iter().all(|v| v.is_finite())
            } else {
                decide(l_unc, l_cor, cfg.tau)
            };
            trace
                .decisions
                .push(AdaptiveDecision::evaluate(i_paper, l_unc, l_cor, cfg.tau));
            if force_all_steps {
                trace.decisions.last_mut().unwrap().corrected = keep;
            }

            // Advance the rollout with the kept direction (Alg 1 lines 16–19).
            if keep {
                dict.steps.insert(i_paper, c.clone());
                let mut d_used = vec![0.0; n * dim];
                for k in 0..n {
                    let b = &bases[k];
                    let s = scale_of(b);
                    b.direction_into(&c, &mut dtilde);
                    for (m, v) in dtilde.iter().enumerate() {
                        d_used[k * dim + m] = s * v;
                    }
                    // Guard: an empty basis falls back to the raw direction.
                    if b.k == 0 {
                        d_used[k * dim..(k + 1) * dim]
                            .copy_from_slice(&d_all[k * dim..(k + 1) * dim]);
                    }
                }
                xs.push(x_next_cor);
                for k in 0..n {
                    buffers[k].push(&d_used[k * dim..(k + 1) * dim]);
                }
                ds.push(d_used);
            } else {
                // Revert to the plain solver step; discard trained coords.
                let _ = c_init;
                xs.push(x_next_unc.clone());
                for k in 0..n {
                    buffers[k].push(&d_all[k * dim..(k + 1) * dim]);
                }
                ds.push(d_all.clone());
            }
        }

        let curve_corrected = truncation_error_curve(&xs, &gt);
        Ok(TrainResult {
            dict,
            trace,
            curve_uncorrected,
            curve_corrected,
            train_seconds: timer.elapsed_s(),
            teacher_nfe_spent: gt.teacher_nfe,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry::get;
    use crate::schedule::default_schedule;
    use crate::score::analytic::AnalyticEps;
    use crate::solvers::registry as solvers;

    fn quick_cfg() -> TrainConfig {
        TrainConfig {
            n_traj: 48,
            epochs: 24,
            minibatch: 16,
            teacher_nfe: 60,
            lr: 5e-2,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn training_reduces_final_truncation_error() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(8);
        let solver = solvers::get("ddim").unwrap();
        let tr = PasTrainer::new(TrainConfig {
            scale_mode: ScaleMode::Relative,
            ..quick_cfg()
        })
        .train(solver.as_ref(), model.as_ref(), &sched, "gmm2d", false)
        .unwrap();
        let before = *tr.curve_uncorrected.last().unwrap();
        let after = *tr.curve_corrected.last().unwrap();
        assert!(
            after < before * 0.9,
            "PAS must cut final truncation error: {before} -> {after}"
        );
        assert!(!tr.dict.steps.is_empty(), "no steps corrected");
    }

    #[test]
    fn adaptive_search_skips_some_steps() {
        let ds = get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(8);
        let solver = solvers::get("ddim").unwrap();
        let tr = PasTrainer::new(quick_cfg())
            .train(solver.as_ref(), model.as_ref(), &sched, "gmm-hd64", false)
            .unwrap();
        let corrected = tr.dict.steps.len();
        assert!(corrected < 8, "adaptive search must not correct all steps");
        // The "~10 parameters" property.
        assert!(tr.dict.n_params() <= 8 * 4);
    }

    #[test]
    fn unsupported_solver_errors() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(4);
        let heun = solvers::get("heun").unwrap();
        let err = PasTrainer::new(quick_cfg())
            .train(heun.as_ref(), model.as_ref(), &sched, "gmm2d", false)
            .unwrap_err();
        assert!(err.contains("does not support PAS"), "{err}");
    }

    #[test]
    fn losses_have_consistent_gradients() {
        // Finite-difference check for each loss.
        let dim = 12;
        let mut rng = Pcg64::seed(5);
        let r = rng.normal_vec(dim);
        for loss in [
            Loss::L2,
            Loss::PseudoHuber { c: 0.1 },
            Loss::RpFeat {
                proj_dim: 6,
                seed: 3,
            },
        ] {
            let le = LossEval::new(&loss, dim);
            let mut g = vec![0.0; dim];
            le.grad(&r, &mut g);
            for m in 0..dim {
                let h = 1e-6;
                let mut rp = r.clone();
                rp[m] += h;
                let mut rm = r.clone();
                rm[m] -= h;
                let fd = (le.value(&rp) - le.value(&rm)) / (2.0 * h);
                assert!(
                    (fd - g[m]).abs() < 1e-5 * (1.0 + fd.abs()),
                    "{}: fd {fd} vs {}",
                    loss.name(),
                    g[m]
                );
            }
        }
    }
}
