//! PCA-based basis extraction over the sampling-trajectory buffer
//! (paper §3.1, Algorithm 1 lines 2–6).
//!
//! At step `t_i` the buffer holds `Q = {x_T, d_{t_N}, ..., d_{t_{i+1}}}`.
//! Following the paper's fast path, we skip the explicit projection
//! (Eq. 12) and instead append the current direction before the SVD
//! (Eq. 13): `X' = Concat(Q, d_{t_i})`, take the top `k-1` right singular
//! vectors, pin `v_1 = d_{t_i}/||d_{t_i}||`, and Gram–Schmidt
//! `(v_1, v'_1, ..., v'_{k-1})` into at most `k` orthonormal basis vectors
//! `U` (Eq. 14). The first basis vector is always the normalized current
//! direction, so the first learned coordinate is a pure rescaling of
//! `d_{t_i}` (Eq. 15).
//!
//! SVD uses the Gram trick ([`crate::linalg::svd_right_vectors_into`]):
//! the buffer is short-fat (≤ NFE+2 rows, D columns), so the cost is
//! `O(r² D)` with r ≈ 12 — the "negligible vs one NFE" cost claim of
//! §3.5, which `benches/pas_overhead.rs` measures.
//!
//! # Allocation discipline
//!
//! The hot entry point is [`pca_basis_into`]: candidate matrix, Gram
//! temporaries and Gram–Schmidt residuals all live in a caller-owned
//! [`PcaScratch`] (grown on first use, never shrunk), and the basis rows
//! are written into caller-owned storage — a [`BasisStore`] row in the
//! trainer, a thread-local buffer in the corrected sampler. In steady
//! state one basis extraction performs **zero** heap allocations
//! (`tests/alloc_audit.rs` pins this across a full training step). The
//! allocating [`pca_basis`] / [`Basis`] forms remain as thin conveniences
//! for tests and benches.

use crate::linalg::{gram_schmidt_into, svd_right_vectors_into, SvdScratch};
use crate::tensor::norm2;

/// Per-sample trajectory buffer: row 0 is `x_T`, then one row per used
/// (possibly corrected) direction.
#[derive(Clone, Debug)]
pub struct TrajBuffer {
    pub dim: usize,
    rows: Vec<f64>,
    n_rows: usize,
}

impl TrajBuffer {
    // lint:allow(hot-path-alloc, empty constructor; with_capacity / push own the one-time growth)
    pub fn new(dim: usize) -> TrajBuffer {
        TrajBuffer {
            dim,
            rows: Vec::new(),
            n_rows: 0,
        }
    }

    /// Buffer with room for `rows` rows reserved up front, so a sampling
    /// run pushing one row per step (plus `x_T`) never reallocates. The
    /// corrected sampler reserves `nfe + 2` rows this way.
    pub fn with_capacity(dim: usize, rows: usize) -> TrajBuffer {
        TrajBuffer {
            dim,
            rows: Vec::with_capacity(dim * rows),
            n_rows: 0,
        }
    }

    pub fn push(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.dim);
        self.rows.extend_from_slice(row);
        self.n_rows += 1;
    }

    pub fn len(&self) -> usize {
        self.n_rows
    }

    pub fn is_empty(&self) -> bool {
        self.n_rows == 0
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.rows
    }
}

/// Borrowed view of one sample's correction subspace: `k` orthonormal
/// rows of length `dim` (row 0 is `d/||d||`) living in caller-owned
/// storage — a [`BasisStore`] row or a scratch buffer. All hot-path
/// consumers (trainer SGD, corrected sampler) work through this.
#[derive(Clone, Copy)]
pub struct BasisRef<'a> {
    pub dim: usize,
    /// `k * dim` row-major basis rows.
    pub u: &'a [f64],
    pub k: usize,
    /// `||d_{t_i}||` — used to initialize `c_1` (absolute mode) or to
    /// rescale learned coordinates (relative mode).
    pub d_norm: f64,
}

impl BasisRef<'_> {
    pub fn row(&self, k: usize) -> &[f64] {
        &self.u[k * self.dim..(k + 1) * self.dim]
    }

    /// Reconstruct a direction from coordinates into `out`: `d = Uᵀ C`
    /// (uses the first `min(k, coords.len())` coordinates).
    pub fn direction_into(&self, coords: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        for (k, &c) in coords.iter().take(self.k).enumerate() {
            if c == 0.0 {
                continue;
            }
            let row = self.row(k);
            for (o, &r) in out.iter_mut().zip(row.iter()) {
                *o += c * r;
            }
        }
    }

    /// Project a vector onto the basis, writing the `k` coordinates into
    /// `out[..self.k]`. Routed through the register-tiled dot-order
    /// kernel ([`crate::tensor::gemm::gemm_nt_dot_into`]) — bit-identical
    /// to a per-row [`crate::tensor::dot`] loop, with the basis panel
    /// loaded once per tile instead of once per coordinate.
    pub fn project_into(&self, v: &[f64], out: &mut [f64]) {
        debug_assert_eq!(v.len(), self.dim);
        debug_assert!(out.len() >= self.k);
        crate::tensor::gemm::gemm_nt_dot_into(
            &self.u[..self.k * self.dim],
            self.k,
            v,
            1,
            self.dim,
            &mut out[..self.k],
        );
    }
}

/// Owning orthonormal basis for one sample's correction subspace.
///
/// The owning form (and its allocating [`Basis::direction`] /
/// [`Basis::project`] helpers) is a **test/bench convenience** — every
/// hot path holds bases in a [`BasisStore`] and works on [`BasisRef`]s.
#[derive(Clone, Debug)]
pub struct Basis {
    pub dim: usize,
    /// `k * dim` row-major; row 0 is `d/||d||`.
    pub u: Vec<f64>,
    pub k: usize,
    /// See [`BasisRef::d_norm`].
    pub d_norm: f64,
}

impl Basis {
    /// Borrowed view (the form the hot-path kernels take).
    pub fn as_basis_ref(&self) -> BasisRef<'_> {
        BasisRef {
            dim: self.dim,
            u: &self.u,
            k: self.k,
            d_norm: self.d_norm,
        }
    }

    pub fn row(&self, k: usize) -> &[f64] {
        &self.u[k * self.dim..(k + 1) * self.dim]
    }

    /// Allocating [`BasisRef::direction_into`] (test convenience).
    // lint:allow(hot-path-alloc, test/bench convenience; serving uses direction_into)
    pub fn direction(&self, coords: &[f64]) -> Vec<f64> {
        let mut d = vec![0.0; self.dim];
        self.direction_into(coords, &mut d);
        d
    }

    pub fn direction_into(&self, coords: &[f64], out: &mut [f64]) {
        self.as_basis_ref().direction_into(coords, out);
    }

    pub fn project_into(&self, v: &[f64], out: &mut [f64]) {
        self.as_basis_ref().project_into(v, out);
    }

    /// Allocating [`BasisRef::project_into`] (test convenience).
    // lint:allow(hot-path-alloc, test/bench convenience; serving uses project_into)
    pub fn project(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.k];
        self.project_into(v, &mut out);
        out
    }
}

/// Preallocated per-sample basis storage for a whole training batch: one
/// flat `n × n_basis × dim` row-major buffer plus per-sample `k` / `d_norm`
/// metadata. Rows are written in place by [`pca_basis_into`] (disjoint
/// per sample, so the trainer shards extraction over the pool) and read
/// back as [`BasisRef`]s.
#[derive(Default)]
pub struct BasisStore {
    dim: usize,
    n_basis: usize,
    n: usize,
    u: Vec<f64>,
    k: Vec<usize>,
    d_norm: Vec<f64>,
}

impl BasisStore {
    pub fn new() -> BasisStore {
        BasisStore::default()
    }

    /// Re-shape for a batch of `n` samples; never shrinks the backing
    /// buffers, so repeated training runs of one shape allocate nothing.
    pub fn reset(&mut self, n: usize, dim: usize, n_basis: usize) {
        assert!(dim > 0 && n_basis >= 1);
        self.dim = dim;
        self.n_basis = n_basis;
        self.n = n;
        let need = n * n_basis * dim;
        if self.u.len() < need {
            self.u.resize(need, 0.0);
        }
        if self.k.len() < n {
            self.k.resize(n, 0);
        }
        if self.d_norm.len() < n {
            self.d_norm.resize(n, 0.0);
        }
    }

    /// Samples currently stored.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Elements per sample row in the flat `u` buffer.
    pub fn stride(&self) -> usize {
        self.n_basis * self.dim
    }

    /// Basis view for sample `i`.
    pub fn basis(&self, i: usize) -> BasisRef<'_> {
        assert!(i < self.n);
        let s = self.stride();
        let k = self.k[i];
        BasisRef {
            dim: self.dim,
            u: &self.u[i * s..i * s + k * self.dim],
            k,
            d_norm: self.d_norm[i],
        }
    }

    /// Mutable flat parts `(u, k, d_norm)` for parallel per-sample fills:
    /// sample `i` owns `u[i*stride .. (i+1)*stride]`, `k[i]`, `d_norm[i]`.
    pub fn raw_parts_mut(&mut self) -> (&mut [f64], &mut [usize], &mut [f64]) {
        let need = self.n * self.n_basis * self.dim;
        (
            &mut self.u[..need],
            &mut self.k[..self.n],
            &mut self.d_norm[..self.n],
        )
    }
}

/// Reusable workspace for [`pca_basis_into`]: the gathered candidate
/// matrix `X' = Concat(Q, d)`, the SVD temporaries, the singular-vector
/// staging rows and the Gram–Schmidt residual. Grows on demand, never
/// shrinks.
#[derive(Default)]
pub struct PcaScratch {
    dim: usize,
    q: Vec<f64>,
    q_rows: usize,
    svd: SvdScratch,
    svals: Vec<f64>,
    vt: Vec<f64>,
    cands: Vec<f64>,
    gs_work: Vec<f64>,
}

impl PcaScratch {
    pub fn new() -> PcaScratch {
        PcaScratch::default()
    }

    /// Start gathering a fresh `Q` of `dim`-length rows.
    pub fn clear_q(&mut self, dim: usize) {
        assert!(dim > 0);
        self.dim = dim;
        self.q.clear();
        self.q_rows = 0;
    }

    /// Append one row of `Q` (amortized allocation-free).
    pub fn push_q_row(&mut self, row: &[f64]) {
        debug_assert_eq!(row.len(), self.dim);
        self.q.extend_from_slice(row);
        self.q_rows += 1;
    }

    /// Append `n_rows` contiguous rows at once (e.g. a whole
    /// [`TrajBuffer`]).
    pub fn extend_q(&mut self, rows: &[f64], n_rows: usize) {
        debug_assert_eq!(rows.len(), n_rows * self.dim);
        self.q.extend_from_slice(rows);
        self.q_rows += n_rows;
    }
}

/// The paper's `PCA(Q, d_{t_i})` routine, zero-allocation form: `Q` was
/// gathered into `scratch` (see [`PcaScratch::push_q_row`]); the up-to
/// `n_basis` orthonormal rows are written into `u_out` (≥ n_basis · dim).
/// Returns `(k, ||d||)`. Bit-identical to the original allocating
/// routine: same candidate matrix, same Gram-trick SVD, same pinned-`v1`
/// Gram–Schmidt with tolerance 1e-7.
pub fn pca_basis_into(
    scratch: &mut PcaScratch,
    d: &[f64],
    n_basis: usize,
    u_out: &mut [f64],
) -> (usize, f64) {
    let dim = scratch.dim;
    assert_eq!(d.len(), dim);
    assert!(n_basis >= 1);
    assert!(u_out.len() >= n_basis * dim);
    let d_norm = norm2(d);
    if d_norm == 0.0 {
        // Degenerate: no direction to correct; an empty basis
        // reconstructs the zero vector.
        return (0, d_norm);
    }
    if n_basis == 1 || scratch.q_rows == 0 {
        for (o, &x) in u_out.iter_mut().zip(d.iter()) {
            *o = x / d_norm;
        }
        return (1, d_norm);
    }
    // X' = Concat(Q, d)  (Eq. 13) — `d` appended in place.
    scratch.q.extend_from_slice(d);
    let r = scratch.q_rows + 1;
    let keep_max = r.min(n_basis - 1);
    if scratch.svals.len() < keep_max {
        scratch.svals.resize(keep_max, 0.0);
    }
    if scratch.vt.len() < keep_max * dim {
        scratch.vt.resize(keep_max * dim, 0.0);
    }
    let n_sv = svd_right_vectors_into(
        &scratch.q[..r * dim],
        r,
        dim,
        n_basis - 1,
        &mut scratch.svd,
        &mut scratch.svals,
        &mut scratch.vt,
    );
    // Undo the append so the scratch can be regathered cleanly.
    scratch.q.truncate(scratch.q_rows * dim);
    // Candidates: v1 first (pinned), then the singular vectors.
    let n_cands = 1 + n_sv;
    if scratch.cands.len() < n_cands * dim {
        scratch.cands.resize(n_cands * dim, 0.0);
    }
    for (o, &x) in scratch.cands[..dim].iter_mut().zip(d.iter()) {
        *o = x / d_norm;
    }
    scratch.cands[dim..n_cands * dim].copy_from_slice(&scratch.vt[..n_sv * dim]);
    if scratch.gs_work.len() < dim {
        scratch.gs_work.resize(dim, 0.0);
    }
    let k = gram_schmidt_into(
        &scratch.cands[..n_cands * dim],
        n_cands,
        dim,
        n_basis,
        1e-7,
        u_out,
        &mut scratch.gs_work,
    );
    (k, d_norm)
}

/// Allocating convenience over [`pca_basis_into`] (tests, benches, and
/// the legacy-oracle training path). `n_basis` is the total number of
/// basis vectors wanted (paper default 4, ablated 1–4 in Fig. 6c).
// lint:allow(hot-path-alloc, allocating oracle/test wrapper; the hot path calls pca_basis_into with pooled scratch)
pub fn pca_basis(q: &TrajBuffer, d: &[f64], n_basis: usize) -> Basis {
    let dim = q.dim;
    assert_eq!(d.len(), dim);
    let mut scratch = PcaScratch::new();
    scratch.clear_q(dim);
    scratch.extend_q(q.as_slice(), q.len());
    let mut u = vec![0.0; n_basis * dim];
    let (k, d_norm) = pca_basis_into(&mut scratch, d, n_basis, &mut u);
    u.truncate(k * dim);
    Basis { dim, u, k, d_norm }
}

/// Cumulative percent variance of the top principal components of a row
/// matrix (used by the Figure 2 experiment). Returns one entry per
/// component: `cum_var[k] = (Σ_{j<=k} s_j²) / (Σ_j s_j²) * 100`.
// lint:allow(hot-path-alloc, offline Figure 2 analysis helper; never on the sampling path)
pub fn cumulative_percent_variance(x: &[f64], rows: usize, dim: usize, top_k: usize) -> Vec<f64> {
    // Center rows (classical PCA).
    let mu = crate::tensor::col_means(x, rows, dim);
    let mut c = x.to_vec();
    for i in 0..rows {
        for j in 0..dim {
            c[i * dim + j] -= mu[j];
        }
    }
    let total: f64 = crate::tensor::dot(&c, &c);
    if total == 0.0 {
        return vec![100.0; top_k];
    }
    let (svals, _) = crate::linalg::svd_right_vectors(&c, rows, dim, top_k.min(rows));
    let mut out = Vec::with_capacity(top_k);
    let mut acc = 0.0;
    for k in 0..top_k {
        if k < svals.len() {
            acc += svals[k] * svals[k];
        }
        out.push(acc / total * 100.0);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dot;
    use crate::util::rng::Pcg64;

    #[test]
    fn basis_is_orthonormal_and_pinned() {
        let dim = 16;
        let mut rng = Pcg64::seed(1);
        let mut q = TrajBuffer::new(dim);
        for _ in 0..5 {
            q.push(&rng.normal_vec(dim));
        }
        let d = rng.normal_vec(dim);
        let b = pca_basis(&q, &d, 4);
        assert!(b.k >= 2 && b.k <= 4, "k = {}", b.k);
        // Row 0 is d / ||d||.
        let dn = norm2(&d);
        for j in 0..dim {
            assert!((b.row(0)[j] - d[j] / dn).abs() < 1e-12);
        }
        // Orthonormal.
        for a in 0..b.k {
            for c in 0..b.k {
                let g = dot(b.row(a), b.row(c));
                let want = if a == c { 1.0 } else { 0.0 };
                assert!((g - want).abs() < 1e-8, "g[{a}{c}]={g}");
            }
        }
    }

    /// A reused scratch + store must reproduce the one-shot allocating
    /// path bit for bit, including across samples of varying `k`.
    #[test]
    fn store_extraction_matches_allocating_bitwise() {
        let dim = 24;
        let n_basis = 4;
        let n = 6;
        let mut rng = Pcg64::seed(77);
        let mut bufs: Vec<TrajBuffer> = Vec::new();
        let mut ds: Vec<Vec<f64>> = Vec::new();
        for i in 0..n {
            let mut q = TrajBuffer::new(dim);
            for _ in 0..(i % 4) {
                // varying row counts, incl. empty
                q.push(&rng.normal_vec(dim));
            }
            bufs.push(q);
            if i == 3 {
                ds.push(vec![0.0; dim]); // degenerate direction
            } else {
                ds.push(rng.normal_vec(dim));
            }
        }
        let mut store = BasisStore::new();
        store.reset(n, dim, n_basis);
        let mut scratch = PcaScratch::new();
        let stride = store.stride();
        {
            let (u, ks, dns) = store.raw_parts_mut();
            for i in 0..n {
                scratch.clear_q(dim);
                scratch.extend_q(bufs[i].as_slice(), bufs[i].len());
                let (k, dn) =
                    pca_basis_into(&mut scratch, &ds[i], n_basis, &mut u[i * stride..(i + 1) * stride]);
                ks[i] = k;
                dns[i] = dn;
            }
        }
        for i in 0..n {
            let want = pca_basis(&bufs[i], &ds[i], n_basis);
            let got = store.basis(i);
            assert_eq!(got.k, want.k, "sample {i}");
            assert_eq!(got.d_norm.to_bits(), want.d_norm.to_bits(), "sample {i}");
            assert_eq!(got.u, &want.u[..], "sample {i}");
        }
    }

    #[test]
    fn direction_roundtrip_via_initial_coords() {
        // With C = [||d||, 0, 0, 0] the reconstruction is exactly d (Eq. 15).
        let dim = 8;
        let mut rng = Pcg64::seed(2);
        let mut q = TrajBuffer::new(dim);
        q.push(&rng.normal_vec(dim));
        q.push(&rng.normal_vec(dim));
        let d = rng.normal_vec(dim);
        let b = pca_basis(&q, &d, 4);
        let mut coords = vec![0.0; 4];
        coords[0] = b.d_norm;
        let rec = b.direction(&coords);
        for j in 0..dim {
            assert!((rec[j] - d[j]).abs() < 1e-10);
        }
    }

    #[test]
    fn trajectory_in_plane_recovered() {
        // Rows spanning a 2-plane in R^32: basis must cover that plane and
        // k must not exceed 3 (plane + numerical dust dropped).
        let dim = 32;
        let mut e1 = vec![0.0; dim];
        e1[0] = 1.0;
        let mut e2 = vec![0.0; dim];
        e2[1] = 1.0;
        let mut q = TrajBuffer::new(dim);
        for i in 0..6 {
            let a = 1.0 + i as f64;
            let row: Vec<f64> = (0..dim)
                .map(|j| a * e1[j] + (2.0 - 0.3 * a) * e2[j])
                .collect();
            q.push(&row);
        }
        let d: Vec<f64> = (0..dim).map(|j| 0.5 * e1[j] - 0.2 * e2[j]).collect();
        let b = pca_basis(&q, &d, 4);
        assert!(
            b.k <= 3,
            "plane data must not produce >3 basis vectors, k={}",
            b.k
        );
        // Any vector in the plane reconstructs exactly from its projection.
        let v: Vec<f64> = (0..dim).map(|j| -1.3 * e1[j] + 0.7 * e2[j]).collect();
        let coords = b.project(&v);
        let rec = b.direction(&coords);
        for j in 0..dim {
            assert!((rec[j] - v[j]).abs() < 1e-8);
        }
    }

    #[test]
    fn n_basis_1_is_pure_rescaling() {
        let dim = 4;
        let q = TrajBuffer::new(dim);
        let d = vec![2.0, 0.0, 0.0, 0.0];
        let b = pca_basis(&q, &d, 1);
        assert_eq!(b.k, 1);
        assert_eq!(b.row(0), &[1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn cumulative_variance_of_low_rank_data() {
        // 20 rows in a 2-D subspace of R^50: two PCs reach ~100 %.
        let dim = 50;
        let mut rng = Pcg64::seed(3);
        let b1 = rng.normal_vec(dim);
        let b2 = rng.normal_vec(dim);
        let mut x = Vec::new();
        for _ in 0..20 {
            let (a, c) = (rng.normal(), rng.normal());
            for j in 0..dim {
                x.push(a * b1[j] + c * b2[j]);
            }
        }
        let cv = cumulative_percent_variance(&x, 20, dim, 5);
        assert!(cv[1] > 99.9, "{cv:?}");
        assert!(cv[0] < 100.0);
    }
}
