//! PCA-based basis extraction over the sampling-trajectory buffer
//! (paper §3.1, Algorithm 1 lines 2–6).
//!
//! At step `t_i` the buffer holds `Q = {x_T, d_{t_N}, ..., d_{t_{i+1}}}`.
//! Following the paper's fast path, we skip the explicit projection
//! (Eq. 12) and instead append the current direction before the SVD
//! (Eq. 13): `X' = Concat(Q, d_{t_i})`, take the top `k-1` right singular
//! vectors, pin `v_1 = d_{t_i}/||d_{t_i}||`, and Gram–Schmidt
//! `(v_1, v'_1, ..., v'_{k-1})` into at most `k` orthonormal basis vectors
//! `U` (Eq. 14). The first basis vector is always the normalized current
//! direction, so the first learned coordinate is a pure rescaling of
//! `d_{t_i}` (Eq. 15).
//!
//! SVD uses the Gram trick ([`crate::linalg::svd_right_vectors`]):
//! the buffer is short-fat (≤ NFE+2 rows, D columns), so the cost is
//! `O(r² D)` with r ≈ 12 — the "negligible vs one NFE" cost claim of
//! §3.5, which `benches/pas_overhead.rs` measures.

use crate::linalg::{gram_schmidt, svd_right_vectors};
use crate::tensor::norm2;

/// Per-sample trajectory buffer: row 0 is `x_T`, then one row per used
/// (possibly corrected) direction.
#[derive(Clone, Debug)]
pub struct TrajBuffer {
    pub dim: usize,
    rows: Vec<f64>,
    n_rows: usize,
}

impl TrajBuffer {
    pub fn new(dim: usize) -> TrajBuffer {
        TrajBuffer {
            dim,
            rows: Vec::new(),
            n_rows: 0,
        }
    }

    /// Buffer with room for `rows` rows reserved up front, so a sampling
    /// run pushing one row per step (plus `x_T`) never reallocates. The
    /// corrected sampler reserves `nfe + 2` rows this way.
    pub fn with_capacity(dim: usize, rows: usize) -> TrajBuffer {
        TrajBuffer {
            dim,
            rows: Vec::with_capacity(dim * rows),
            n_rows: 0,
        }
    }

    pub fn push(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.dim);
        self.rows.extend_from_slice(row);
        self.n_rows += 1;
    }

    pub fn len(&self) -> usize {
        self.n_rows
    }

    pub fn is_empty(&self) -> bool {
        self.n_rows == 0
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.rows
    }
}

/// Orthonormal basis for one sample's correction subspace.
#[derive(Clone, Debug)]
pub struct Basis {
    pub dim: usize,
    /// `k * dim` row-major; row 0 is `d/||d||`.
    pub u: Vec<f64>,
    pub k: usize,
    /// `||d_{t_i}||` — used to initialize `c_1` (absolute mode) or to
    /// rescale learned coordinates (relative mode).
    pub d_norm: f64,
}

impl Basis {
    pub fn row(&self, k: usize) -> &[f64] {
        &self.u[k * self.dim..(k + 1) * self.dim]
    }

    /// Reconstruct a direction from coordinates: `d = Uᵀ C` (uses the
    /// first `min(k, coords.len())` coordinates).
    pub fn direction(&self, coords: &[f64]) -> Vec<f64> {
        let mut d = vec![0.0; self.dim];
        self.direction_into(coords, &mut d);
        d
    }

    pub fn direction_into(&self, coords: &[f64], out: &mut [f64]) {
        out.fill(0.0);
        for (k, &c) in coords.iter().take(self.k).enumerate() {
            if c == 0.0 {
                continue;
            }
            let row = self.row(k);
            for (o, &r) in out.iter_mut().zip(row.iter()) {
                *o += c * r;
            }
        }
    }

    /// Project a vector onto the basis, writing the `k` coordinates into
    /// `out[..self.k]`. Routed through the register-tiled dot-order
    /// kernel ([`crate::tensor::gemm::gemm_nt_dot_into`]) — bit-identical
    /// to a per-row [`crate::tensor::dot`] loop, with the basis panel
    /// loaded once per tile instead of once per coordinate.
    pub fn project_into(&self, v: &[f64], out: &mut [f64]) {
        debug_assert_eq!(v.len(), self.dim);
        debug_assert!(out.len() >= self.k);
        crate::tensor::gemm::gemm_nt_dot_into(&self.u, self.k, v, 1, self.dim, &mut out[..self.k]);
    }

    /// Project a vector onto the basis: returns the `k` coordinates.
    pub fn project(&self, v: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.k];
        self.project_into(v, &mut out);
        out
    }
}

/// The paper's `PCA(Q, d_{t_i})` routine. `n_basis` is the total number of
/// basis vectors wanted (paper default 4, ablated 1–4 in Fig. 6c).
pub fn pca_basis(q: &TrajBuffer, d: &[f64], n_basis: usize) -> Basis {
    let dim = q.dim;
    assert_eq!(d.len(), dim);
    assert!(n_basis >= 1);
    let d_norm = norm2(d);
    if d_norm == 0.0 {
        // Degenerate: no direction to correct; return an empty basis that
        // reconstructs the zero vector.
        return Basis {
            dim,
            u: Vec::new(),
            k: 0,
            d_norm,
        };
    }
    let v1: Vec<f64> = d.iter().map(|x| x / d_norm).collect();
    if n_basis == 1 || q.is_empty() {
        return Basis {
            dim,
            u: v1,
            k: 1,
            d_norm,
        };
    }
    // X' = Concat(Q, d)  (Eq. 13).
    let r = q.len() + 1;
    let mut x = Vec::with_capacity(r * dim);
    x.extend_from_slice(q.as_slice());
    x.extend_from_slice(d);
    let (_svals, vt) = svd_right_vectors(&x, r, dim, n_basis - 1);
    let n_sv = vt.len() / dim;
    // Candidates: v1 first (pinned), then the singular vectors.
    let mut cands: Vec<Vec<f64>> = Vec::with_capacity(1 + n_sv);
    cands.push(v1);
    for k in 0..n_sv {
        cands.push(vt[k * dim..(k + 1) * dim].to_vec());
    }
    let basis = gram_schmidt(&cands, n_basis, 1e-7);
    let k = basis.len();
    let mut u = Vec::with_capacity(k * dim);
    for b in basis {
        u.extend_from_slice(&b);
    }
    Basis { dim, u, k, d_norm }
}

/// Cumulative percent variance of the top principal components of a row
/// matrix (used by the Figure 2 experiment). Returns one entry per
/// component: `cum_var[k] = (Σ_{j<=k} s_j²) / (Σ_j s_j²) * 100`.
pub fn cumulative_percent_variance(x: &[f64], rows: usize, dim: usize, top_k: usize) -> Vec<f64> {
    // Center rows (classical PCA).
    let mu = crate::tensor::col_means(x, rows, dim);
    let mut c = x.to_vec();
    for i in 0..rows {
        for j in 0..dim {
            c[i * dim + j] -= mu[j];
        }
    }
    let total: f64 = crate::tensor::dot(&c, &c);
    if total == 0.0 {
        return vec![100.0; top_k];
    }
    let (svals, _) = svd_right_vectors(&c, rows, dim, top_k.min(rows));
    let mut out = Vec::with_capacity(top_k);
    let mut acc = 0.0;
    for k in 0..top_k {
        if k < svals.len() {
            acc += svals[k] * svals[k];
        }
        out.push(acc / total * 100.0);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dot;
    use crate::util::rng::Pcg64;

    #[test]
    fn basis_is_orthonormal_and_pinned() {
        let dim = 16;
        let mut rng = Pcg64::seed(1);
        let mut q = TrajBuffer::new(dim);
        for _ in 0..5 {
            q.push(&rng.normal_vec(dim));
        }
        let d = rng.normal_vec(dim);
        let b = pca_basis(&q, &d, 4);
        assert!(b.k >= 2 && b.k <= 4, "k = {}", b.k);
        // Row 0 is d / ||d||.
        let dn = norm2(&d);
        for j in 0..dim {
            assert!((b.row(0)[j] - d[j] / dn).abs() < 1e-12);
        }
        // Orthonormal.
        for a in 0..b.k {
            for c in 0..b.k {
                let g = dot(b.row(a), b.row(c));
                let want = if a == c { 1.0 } else { 0.0 };
                assert!((g - want).abs() < 1e-8, "g[{a}{c}]={g}");
            }
        }
    }

    #[test]
    fn direction_roundtrip_via_initial_coords() {
        // With C = [||d||, 0, 0, 0] the reconstruction is exactly d (Eq. 15).
        let dim = 8;
        let mut rng = Pcg64::seed(2);
        let mut q = TrajBuffer::new(dim);
        q.push(&rng.normal_vec(dim));
        q.push(&rng.normal_vec(dim));
        let d = rng.normal_vec(dim);
        let b = pca_basis(&q, &d, 4);
        let mut coords = vec![0.0; 4];
        coords[0] = b.d_norm;
        let rec = b.direction(&coords);
        for j in 0..dim {
            assert!((rec[j] - d[j]).abs() < 1e-10);
        }
    }

    #[test]
    fn trajectory_in_plane_recovered() {
        // Rows spanning a 2-plane in R^32: basis must cover that plane and
        // k must not exceed 3 (plane + numerical dust dropped).
        let dim = 32;
        let mut e1 = vec![0.0; dim];
        e1[0] = 1.0;
        let mut e2 = vec![0.0; dim];
        e2[1] = 1.0;
        let mut q = TrajBuffer::new(dim);
        for i in 0..6 {
            let a = 1.0 + i as f64;
            let row: Vec<f64> = (0..dim)
                .map(|j| a * e1[j] + (2.0 - 0.3 * a) * e2[j])
                .collect();
            q.push(&row);
        }
        let d: Vec<f64> = (0..dim).map(|j| 0.5 * e1[j] - 0.2 * e2[j]).collect();
        let b = pca_basis(&q, &d, 4);
        assert!(
            b.k <= 3,
            "plane data must not produce >3 basis vectors, k={}",
            b.k
        );
        // Any vector in the plane reconstructs exactly from its projection.
        let v: Vec<f64> = (0..dim).map(|j| -1.3 * e1[j] + 0.7 * e2[j]).collect();
        let coords = b.project(&v);
        let rec = b.direction(&coords);
        for j in 0..dim {
            assert!((rec[j] - v[j]).abs() < 1e-8);
        }
    }

    #[test]
    fn n_basis_1_is_pure_rescaling() {
        let dim = 4;
        let q = TrajBuffer::new(dim);
        let d = vec![2.0, 0.0, 0.0, 0.0];
        let b = pca_basis(&q, &d, 1);
        assert_eq!(b.k, 1);
        assert_eq!(b.row(0), &[1.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn cumulative_variance_of_low_rank_data() {
        // 20 rows in a 2-D subspace of R^50: two PCs reach ~100 %.
        let dim = 50;
        let mut rng = Pcg64::seed(3);
        let b1 = rng.normal_vec(dim);
        let b2 = rng.normal_vec(dim);
        let mut x = Vec::new();
        for _ in 0..20 {
            let (a, c) = (rng.normal(), rng.normal());
            for j in 0..dim {
                x.push(a * b1[j] + c * b2[j]);
            }
        }
        let cv = cumulative_percent_variance(&x, 20, dim, 5);
        assert!(cv[1] > 99.9, "{cv:?}");
        assert!(cv[0] < 100.0);
    }
}
