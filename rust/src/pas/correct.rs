//! Sampling correction — Algorithm 2.
//!
//! [`CorrectedSampler`] is a [`DirectionHook`]: at every step it maintains
//! the per-sample trajectory buffer `Q`; at time points present in the
//! trained [`CoordinateDict`] it recomputes the PCA basis from the live
//! buffer and substitutes `d = U Cᵀ` (optionally rescaled by `||d||` in
//! relative mode). The corrected direction is what enters both the solver
//! update *and* the buffer / multistep history (Alg. 2 line 9).

use super::coords::{CoordinateDict, ScaleMode};
use super::pca::{pca_basis_into, BasisRef, PcaScratch, TrajBuffer};
use crate::schedule::Schedule;
use crate::score::EpsModel;
use crate::solvers::{run_solver, DirectionHook, SolveRun, Solver, StepCtx};
use crate::util::pool::{Pool, SendPtr};
use std::borrow::Cow;

thread_local! {
    /// Per-worker PCA workspace for the correction hot path: the scratch
    /// holds the candidate/Gram temporaries, the `Vec` the transient
    /// basis rows. Sized on first use per thread; afterwards a correction
    /// step performs zero heap allocations per sample.
    static PCA_TLS: std::cell::RefCell<(PcaScratch, Vec<f64>)> =
        // lint:allow(hot-path-alloc, empty one-time thread-local init; steady-state corrections reuse it)
        std::cell::RefCell::new((PcaScratch::new(), Vec::new()));
}

/// The correction state — one trajectory buffer `Q` per batch row — is
/// **per slot**: rows are seeded together at the run's first step and
/// advance in lockstep, so one hook serves a whole engine batch (or a
/// continuous-batching cohort, which is admitted and retired as a unit).
///
/// The dictionary is held as a [`Cow`]: experiment/test call sites borrow
/// a caller-owned dict ([`Self::new`]); the serving scheduler snapshots
/// the live registry per cohort and hands the hook its own copy
/// ([`Self::owned`]) so corrections stay self-contained while the
/// registry keeps retraining underneath.
pub struct CorrectedSampler<'a> {
    pub dict: Cow<'a, CoordinateDict>,
    buffers: Vec<TrajBuffer>,
    dim: usize,
    /// Number of corrections applied so far (for tests / stats).
    pub corrections_applied: usize,
}

impl<'a> CorrectedSampler<'a> {
    // lint:allow(hot-path-alloc, empty constructor; buffers grow once when the first step seeds them)
    pub fn new(dict: &'a CoordinateDict, dim: usize) -> CorrectedSampler<'a> {
        CorrectedSampler {
            dict: Cow::Borrowed(dict),
            buffers: Vec::new(),
            dim,
            corrections_applied: 0,
        }
    }

    /// Hook that owns its dictionary snapshot (no borrow to keep alive) —
    /// the continuous scheduler's per-cohort form.
    // lint:allow(hot-path-alloc, empty constructor; buffers grow once when the first step seeds them)
    pub fn owned(dict: CoordinateDict, dim: usize) -> CorrectedSampler<'static> {
        CorrectedSampler {
            dict: Cow::Owned(dict),
            buffers: Vec::new(),
            dim,
            corrections_applied: 0,
        }
    }

    /// Convenience: run a full corrected sampling pass.
    pub fn sample(
        dict: &CoordinateDict,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        x_t: &[f64],
        n: usize,
        sched: &Schedule,
    ) -> SolveRun {
        let mut hook = CorrectedSampler::new(dict, model.dim());
        run_solver(solver, model, x_t, n, sched, Some(&mut hook))
    }
}

impl DirectionHook for CorrectedSampler<'_> {
    fn correct(&mut self, ctx: &StepCtx<'_>, x: &[f64], n: usize, d: &mut [f64]) -> bool {
        let dim = self.dim;
        // First step: seed per-sample buffers with x_T, each reserved to
        // `nfe + 2` rows so the whole run never reallocates them.
        if ctx.j == 0 {
            let cap_rows = ctx.sched.n_steps() + 2;
            self.buffers.clear();
            self.buffers.extend((0..n).map(|k| {
                let mut b = TrajBuffer::with_capacity(dim, cap_rows);
                b.push(&x[k * dim..(k + 1) * dim]);
                b
            }));
        }
        debug_assert_eq!(self.buffers.len(), n);
        let coords = self.dict.steps.get(&ctx.i_paper);
        let n_basis = self.dict.n_basis;
        let scale_mode = self.dict.scale_mode;
        // Samples are independent: shard the per-sample PCA + coordinate
        // reconstruction (and the buffer push) row-wise over the pool.
        // Per-row work is the sequential code verbatim, so the result is
        // bit-identical for any thread count. The PCA itself is the §3.5
        // "negligible vs one NFE" cost; pushes alone are cheap, hence the
        // larger min chunk when no correction fires at this step.
        let bufs = SendPtr::new(self.buffers.as_mut_ptr());
        let d_ptr = SendPtr::new(d.as_mut_ptr());
        let min_rows = if coords.is_some() { 1 } else { 64 };
        Pool::global().par_rows(n, usize::MAX, min_rows, |r0, r1| {
            // Basis extraction works entirely in this worker's
            // thread-local scratch (candidate matrix, Gram temporaries,
            // transient basis rows) — zero allocations per sample once
            // the workspace is warm. Bit-identical to the former
            // allocate-a-`Basis`-per-sample path.
            PCA_TLS.with(|tls| {
                let (scratch, u_buf) = &mut *tls.borrow_mut();
                if coords.is_some() && u_buf.len() < n_basis * dim {
                    u_buf.resize(n_basis * dim, 0.0);
                }
                for k in r0..r1 {
                    // SAFETY: pool row ranges are disjoint, so each
                    // sample's buffer and direction row are touched by
                    // one task only.
                    let buf = unsafe { &mut *bufs.get().add(k) };
                    let dk = unsafe {
                        std::slice::from_raw_parts_mut(d_ptr.get().add(k * dim), dim)
                    };
                    if let Some(c) = coords {
                        scratch.clear_q(dim);
                        scratch.extend_q(buf.as_slice(), buf.len());
                        let (bk, d_norm) = pca_basis_into(scratch, dk, n_basis, u_buf);
                        if bk > 0 {
                            let basis = BasisRef {
                                dim,
                                u: &u_buf[..bk * dim],
                                k: bk,
                                d_norm,
                            };
                            let scale = match scale_mode {
                                ScaleMode::Absolute => 1.0,
                                ScaleMode::Relative => basis.d_norm,
                            };
                            // `d = U Cᵀ` reconstructed straight into the
                            // direction row (same f64 op order as the
                            // legacy allocate-and-copy path).
                            basis.direction_into(c, dk);
                            for v in dk.iter_mut() {
                                *v *= scale;
                            }
                        }
                    }
                    // Buffer the direction as used (corrected or not).
                    buf.push(dk);
                }
            });
        });
        if coords.is_some() {
            self.corrections_applied += 1;
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry::get;
    use crate::pas::train::{PasTrainer, TrainConfig};
    use crate::schedule::default_schedule;
    use crate::score::analytic::AnalyticEps;
    use crate::solvers::{registry as solvers, NodeView};
    use crate::traj::{ground_truth, sample_prior, truncation_error_curve};
    use crate::util::rng::Pcg64;

    #[test]
    fn empty_dict_is_identity() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(6);
        let mut rng = Pcg64::seed(1);
        let x_t = sample_prior(&mut rng, 8, 2, sched.t_max());
        let solver = solvers::get("ddim").unwrap();
        let dict = CoordinateDict::new(4, ScaleMode::Absolute, "ddim", "gmm2d", 6);
        let plain = run_solver(solver.as_ref(), model.as_ref(), &x_t, 8, &sched, None);
        let corr =
            CorrectedSampler::sample(&dict, solver.as_ref(), model.as_ref(), &x_t, 8, &sched);
        assert_eq!(plain.x0, corr.x0);
    }

    /// Train on one set of trajectories, correct a *fresh* set — the
    /// generalization claim at the heart of the paper (§3.4).
    #[test]
    fn trained_dict_generalizes_to_fresh_samples() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(8);
        let solver = solvers::get("ddim").unwrap();
        let cfg = TrainConfig {
            n_traj: 64,
            epochs: 24,
            minibatch: 16,
            teacher_nfe: 60,
            lr: 5e-2,
            scale_mode: ScaleMode::Relative,
            ..TrainConfig::default()
        };
        let tr = PasTrainer::new(cfg)
            .train(solver.as_ref(), model.as_ref(), &sched, "gmm2d", false)
            .unwrap();
        assert!(!tr.dict.steps.is_empty());

        // Fresh prior draws (different stream than training seed 0).
        let mut rng = Pcg64::seed(999);
        let n = 64;
        let x_t = sample_prior(&mut rng, n, 2, sched.t_max());
        let teacher = solvers::get("heun").unwrap();
        let gt = ground_truth(teacher.as_ref(), model.as_ref(), &x_t, n, &sched, 60);
        let plain = run_solver(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None);
        let corr =
            CorrectedSampler::sample(&tr.dict, solver.as_ref(), model.as_ref(), &x_t, n, &sched);
        let e_plain = *truncation_error_curve(NodeView::nested(&plain.xs), &gt)
            .last()
            .unwrap();
        let e_corr = *truncation_error_curve(NodeView::nested(&corr.xs), &gt)
            .last()
            .unwrap();
        assert!(
            e_corr < e_plain,
            "correction must generalize: plain {e_plain} vs corrected {e_corr}"
        );
    }

    #[test]
    fn corrections_applied_matches_dict() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(6);
        let mut dict = CoordinateDict::new(4, ScaleMode::Relative, "ddim", "gmm2d", 6);
        dict.steps.insert(4, vec![1.0, 0.0, 0.0, 0.0]);
        dict.steps.insert(2, vec![1.0, 0.0, 0.0, 0.0]);
        let mut rng = Pcg64::seed(2);
        let x_t = sample_prior(&mut rng, 4, 2, sched.t_max());
        let solver = solvers::get("ddim").unwrap();
        let mut hook = CorrectedSampler::new(&dict, 2);
        let _ = run_solver(
            solver.as_ref(),
            model.as_ref(),
            &x_t,
            4,
            &sched,
            Some(&mut hook),
        );
        assert_eq!(hook.corrections_applied, 2);
    }

    /// In relative mode, coords [1, 0, 0, 0] reconstruct the original
    /// direction exactly, so correction is a no-op.
    #[test]
    fn identity_coords_are_noop() {
        let ds = get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(5);
        let mut dict = CoordinateDict::new(4, ScaleMode::Relative, "ddim", "gmm-hd64", 5);
        for i in 1..=5 {
            dict.steps.insert(i, vec![1.0, 0.0, 0.0, 0.0]);
        }
        let mut rng = Pcg64::seed(3);
        let x_t = sample_prior(&mut rng, 6, 64, sched.t_max());
        let solver = solvers::get("ddim").unwrap();
        let plain = run_solver(solver.as_ref(), model.as_ref(), &x_t, 6, &sched, None);
        let corr =
            CorrectedSampler::sample(&dict, solver.as_ref(), model.as_ref(), &x_t, 6, &sched);
        for (a, b) in plain.x0.iter().zip(corr.x0.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }
}
