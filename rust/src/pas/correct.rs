//! Sampling correction — Algorithm 2.
//!
//! [`CorrectedSampler`] is a [`DirectionHook`]: at every step it maintains
//! the per-sample trajectory buffer `Q`; at time points present in the
//! trained [`CoordinateDict`] it recomputes the PCA basis from the live
//! buffer and substitutes `d = U Cᵀ` (optionally rescaled by `||d||` in
//! relative mode). The corrected direction is what enters both the solver
//! update *and* the buffer / multistep history (Alg. 2 line 9).

use super::coords::{CoordinateDict, ScaleMode};
use super::pca::{pca_basis, TrajBuffer};
use crate::schedule::Schedule;
use crate::score::EpsModel;
use crate::solvers::{run_solver, DirectionHook, SolveRun, Solver, StepCtx};

pub struct CorrectedSampler<'a> {
    pub dict: &'a CoordinateDict,
    buffers: Vec<TrajBuffer>,
    dim: usize,
    /// Number of corrections applied so far (for tests / stats).
    pub corrections_applied: usize,
}

impl<'a> CorrectedSampler<'a> {
    pub fn new(dict: &'a CoordinateDict, dim: usize) -> CorrectedSampler<'a> {
        CorrectedSampler {
            dict,
            buffers: Vec::new(),
            dim,
            corrections_applied: 0,
        }
    }

    /// Convenience: run a full corrected sampling pass.
    pub fn sample(
        dict: &CoordinateDict,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        x_t: &[f64],
        n: usize,
        sched: &Schedule,
    ) -> SolveRun {
        let mut hook = CorrectedSampler::new(dict, model.dim());
        run_solver(solver, model, x_t, n, sched, Some(&mut hook))
    }
}

impl DirectionHook for CorrectedSampler<'_> {
    fn correct(&mut self, ctx: &StepCtx<'_>, x: &[f64], n: usize, d: &mut [f64]) -> bool {
        let dim = self.dim;
        // First step: seed per-sample buffers with x_T.
        if ctx.j == 0 {
            self.buffers = (0..n)
                .map(|k| {
                    let mut b = TrajBuffer::new(dim);
                    b.push(&x[k * dim..(k + 1) * dim]);
                    b
                })
                .collect();
        }
        debug_assert_eq!(self.buffers.len(), n);
        let mut applied = false;
        if let Some(c) = self.dict.steps.get(&ctx.i_paper) {
            for k in 0..n {
                let dk = &mut d[k * dim..(k + 1) * dim];
                let basis = pca_basis(&self.buffers[k], dk, self.dict.n_basis);
                if basis.k == 0 {
                    continue;
                }
                let scale = match self.dict.scale_mode {
                    ScaleMode::Absolute => 1.0,
                    ScaleMode::Relative => basis.d_norm,
                };
                let mut nd = basis.direction(c);
                for v in nd.iter_mut() {
                    *v *= scale;
                }
                dk.copy_from_slice(&nd);
            }
            self.corrections_applied += 1;
            applied = true;
        }
        // Buffer the direction as used (corrected or not).
        for k in 0..n {
            self.buffers[k].push(&d[k * dim..(k + 1) * dim]);
        }
        applied
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry::get;
    use crate::pas::train::{PasTrainer, TrainConfig};
    use crate::schedule::default_schedule;
    use crate::score::analytic::AnalyticEps;
    use crate::solvers::registry as solvers;
    use crate::traj::{ground_truth, sample_prior, truncation_error_curve};
    use crate::util::rng::Pcg64;

    #[test]
    fn empty_dict_is_identity() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(6);
        let mut rng = Pcg64::seed(1);
        let x_t = sample_prior(&mut rng, 8, 2, sched.t_max());
        let solver = solvers::get("ddim").unwrap();
        let dict = CoordinateDict::new(4, ScaleMode::Absolute, "ddim", "gmm2d", 6);
        let plain = run_solver(solver.as_ref(), model.as_ref(), &x_t, 8, &sched, None);
        let corr =
            CorrectedSampler::sample(&dict, solver.as_ref(), model.as_ref(), &x_t, 8, &sched);
        assert_eq!(plain.x0, corr.x0);
    }

    /// Train on one set of trajectories, correct a *fresh* set — the
    /// generalization claim at the heart of the paper (§3.4).
    #[test]
    fn trained_dict_generalizes_to_fresh_samples() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(8);
        let solver = solvers::get("ddim").unwrap();
        let cfg = TrainConfig {
            n_traj: 64,
            epochs: 24,
            minibatch: 16,
            teacher_nfe: 60,
            lr: 5e-2,
            scale_mode: ScaleMode::Relative,
            ..TrainConfig::default()
        };
        let tr = PasTrainer::new(cfg)
            .train(solver.as_ref(), model.as_ref(), &sched, "gmm2d", false)
            .unwrap();
        assert!(!tr.dict.steps.is_empty());

        // Fresh prior draws (different stream than training seed 0).
        let mut rng = Pcg64::seed(999);
        let n = 64;
        let x_t = sample_prior(&mut rng, n, 2, sched.t_max());
        let teacher = solvers::get("heun").unwrap();
        let gt = ground_truth(teacher.as_ref(), model.as_ref(), &x_t, n, &sched, 60);
        let plain = run_solver(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None);
        let corr =
            CorrectedSampler::sample(&tr.dict, solver.as_ref(), model.as_ref(), &x_t, n, &sched);
        let e_plain = *truncation_error_curve(&plain.xs, &gt).last().unwrap();
        let e_corr = *truncation_error_curve(&corr.xs, &gt).last().unwrap();
        assert!(
            e_corr < e_plain,
            "correction must generalize: plain {e_plain} vs corrected {e_corr}"
        );
    }

    #[test]
    fn corrections_applied_matches_dict() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(6);
        let mut dict = CoordinateDict::new(4, ScaleMode::Relative, "ddim", "gmm2d", 6);
        dict.steps.insert(4, vec![1.0, 0.0, 0.0, 0.0]);
        dict.steps.insert(2, vec![1.0, 0.0, 0.0, 0.0]);
        let mut rng = Pcg64::seed(2);
        let x_t = sample_prior(&mut rng, 4, 2, sched.t_max());
        let solver = solvers::get("ddim").unwrap();
        let mut hook = CorrectedSampler::new(&dict, 2);
        let _ = run_solver(
            solver.as_ref(),
            model.as_ref(),
            &x_t,
            4,
            &sched,
            Some(&mut hook),
        );
        assert_eq!(hook.corrections_applied, 2);
    }

    /// In relative mode, coords [1, 0, 0, 0] reconstruct the original
    /// direction exactly, so correction is a no-op.
    #[test]
    fn identity_coords_are_noop() {
        let ds = get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(5);
        let mut dict = CoordinateDict::new(4, ScaleMode::Relative, "ddim", "gmm-hd64", 5);
        for i in 1..=5 {
            dict.steps.insert(i, vec![1.0, 0.0, 0.0, 0.0]);
        }
        let mut rng = Pcg64::seed(3);
        let x_t = sample_prior(&mut rng, 6, 64, sched.t_max());
        let solver = solvers::get("ddim").unwrap();
        let plain = run_solver(solver.as_ref(), model.as_ref(), &x_t, 6, &sched, None);
        let corr =
            CorrectedSampler::sample(&dict, solver.as_ref(), model.as_ref(), &x_t, 6, &sched);
        for (a, b) in plain.x0.iter().zip(corr.x0.iter()) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }
}
