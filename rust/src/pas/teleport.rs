//! Teleportation (TP) warm start — Wang & Vastola's analytic Gaussian
//! score solution, used by the `+TP` / `+TP+PAS` rows of Table 2.
//!
//! Fit a single Gaussian `N(mu, Sigma)` to the data distribution; under
//! the EDM PF-ODE with a Gaussian score the exact solution decouples in
//! Sigma's eigenbasis:
//!
//! ```text
//! y_j(s) = y_j(T) * sqrt((lam_j + s²) / (lam_j + T²)),   y = U (x − mu)
//! ```
//!
//! so the whole stretch from `sigma = T` down to `sigma_skip` (paper:
//! 10.0) costs *zero NFE*, and the solver spends its entire budget on the
//! curved low-noise region.

use crate::data::Dataset;
use crate::linalg::eigh;
use crate::schedule::{Schedule, ScheduleKind};

pub const SIGMA_SKIP_DEFAULT: f64 = 10.0;

pub struct Teleporter {
    pub mu: Vec<f64>,
    /// Eigenvalues of the fitted covariance (descending).
    pub lam: Vec<f64>,
    /// Eigenvector rows (d, d).
    pub u: Vec<f64>,
    pub dim: usize,
}

impl Teleporter {
    /// Fit to a dataset's exact mixture moments.
    pub fn from_dataset(ds: &Dataset) -> Teleporter {
        let (mu, cov) = ds.spec.mixture_moments();
        Self::from_moments(mu, &cov)
    }

    /// Fit to empirical moments of a sample set.
    pub fn from_samples(x: &[f64], n: usize, dim: usize) -> Teleporter {
        let mu = crate::tensor::col_means(x, n, dim);
        let cov = crate::tensor::covariance(x, n, dim);
        Self::from_moments(mu, &cov)
    }

    pub fn from_moments(mu: Vec<f64>, cov: &[f64]) -> Teleporter {
        let dim = mu.len();
        let mut work = cov.to_vec();
        let (lam, u) = eigh(&mut work, dim);
        let lam = lam.into_iter().map(|v| v.max(0.0)).collect();
        Teleporter { mu, lam, u, dim }
    }

    /// Exact Gaussian-score PF-ODE transport of a batch from time
    /// `from_t` to `to_t` (in place). Works in either direction.
    ///
    /// Batched through the register-tiled kernels: one `R Uᵀ` projection
    /// and one `Y U` back-projection for the whole batch instead of 2·n
    /// per-sample matvecs — the same blocking win as the model-eval
    /// pipeline, which matters for the d=256 `+TP` rows. Called once per
    /// training/sampling run (not per step), so the transient `R`/`Y`
    /// staging buffers are allocated per call.
    ///
    /// Numerics note: the projection now reduces each entry in the
    /// 4-lane `dot` order (and the back-projection no longer zero-skips),
    /// so teleported outputs differ from the pre-kernel loop in the last
    /// bits. No fixture pins `+TP` outputs — the golden trajectory and
    /// golden training pins are TP-free — and every TP consumer is
    /// tolerance-based; if a `+TP` fixture is ever added, it pins *this*
    /// kernel order.
    pub fn teleport(&self, x: &mut [f64], n: usize, from_t: f64, to_t: f64) {
        let d = self.dim;
        assert_eq!(x.len(), n * d);
        // Per-eigendirection scaling factors.
        let scale: Vec<f64> = self
            .lam
            .iter()
            .map(|&l| ((l + to_t * to_t) / (l + from_t * from_t)).sqrt())
            .collect();
        // R = X − mu (n, d).
        let mut r = vec![0.0; n * d];
        for k in 0..n {
            let xk = &x[k * d..(k + 1) * d];
            let rk = &mut r[k * d..(k + 1) * d];
            for j in 0..d {
                rk[j] = xk[j] - self.mu[j];
            }
        }
        // Y = R Uᵀ (row-eigvec convention), then scale per eigendirection.
        let mut y = vec![0.0; n * d];
        crate::tensor::gemm::gemm_nt_dot_into(&r, n, &self.u, d, d, &mut y);
        for k in 0..n {
            let yk = &mut y[k * d..(k + 1) * d];
            for (yc, &s) in yk.iter_mut().zip(scale.iter()) {
                *yc *= s;
            }
        }
        // X = mu + Y U (ascending-eigendirection accumulation, the order
        // of the former per-sample back-projection loop).
        for k in 0..n {
            x[k * d..(k + 1) * d].copy_from_slice(&self.mu);
        }
        crate::tensor::gemm::gemm_nn_acc(&y, n, d, &self.u, d, x);
    }
}

/// Build the post-teleport sampling schedule: the full NFE budget is spent
/// between `t_min` and `sigma_skip` with the same generator as `base`.
pub fn teleported_schedule(base: &Schedule, sigma_skip: f64) -> Schedule {
    match base.kind {
        ScheduleKind::Polynomial { rho } => {
            Schedule::polynomial(base.n_steps(), base.t_min(), sigma_skip, rho)
        }
        ScheduleKind::Uniform => Schedule::uniform(base.n_steps(), base.t_min(), sigma_skip),
        ScheduleKind::LogSnr => Schedule::log_snr(base.n_steps(), base.t_min(), sigma_skip),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Mode;
    use crate::schedule::Schedule;
    use crate::score::analytic::AnalyticEps;
    use crate::solvers::{euler::Euler, run_solver};
    use crate::util::rng::Pcg64;

    /// For a single Gaussian the teleport must match a finely-integrated
    /// PF-ODE run of the analytic score.
    #[test]
    fn matches_fine_ode_on_single_gaussian() {
        let d = 6;
        let mut rng = Pcg64::seed(1);
        let mu: Vec<f64> = rng.normal_vec(d);
        // Anisotropic diagonal covariance.
        let mut cov = vec![0.0; d * d];
        for j in 0..d {
            cov[j * d + j] = 0.2 + 0.4 * j as f64;
        }
        let tp = Teleporter::from_moments(mu.clone(), &cov);
        let model = AnalyticEps::new("g", vec![Mode::full(mu, &cov, 1.0, 0)]);
        let (t_hi, t_lo) = (80.0, 10.0);
        let x0: Vec<f64> = rng.normal_vec(d).iter().map(|z| z * t_hi).collect();
        // Fine ODE integration 80 -> 10.
        let sched = Schedule::log_snr(800, t_lo, t_hi);
        let run = run_solver(&Euler, model.as_ref(), &x0, 1, &sched, None);
        // Teleport.
        let mut xt = x0.clone();
        tp.teleport(&mut xt, 1, t_hi, t_lo);
        for j in 0..d {
            assert!(
                (run.x0[j] - xt[j]).abs() < 2e-2 * (1.0 + xt[j].abs()),
                "dim {j}: ode {} vs tp {}",
                run.x0[j],
                xt[j]
            );
        }
    }

    #[test]
    fn teleport_roundtrip_is_identity() {
        let ds = crate::data::registry::get("gmm-hd64").unwrap();
        let tp = Teleporter::from_dataset(&ds);
        let mut rng = Pcg64::seed(2);
        let x0 = rng.normal_vec(3 * 64);
        let mut x = x0.clone();
        tp.teleport(&mut x, 3, 80.0, 10.0);
        tp.teleport(&mut x, 3, 10.0, 80.0);
        for (a, b) in x.iter().zip(x0.iter()) {
            assert!((a - b).abs() < 1e-8);
        }
    }

    #[test]
    fn teleport_shrinks_scale() {
        let ds = crate::data::registry::get("gmm-hd64").unwrap();
        let tp = Teleporter::from_dataset(&ds);
        let mut rng = Pcg64::seed(3);
        let mut x: Vec<f64> = rng.normal_vec(8 * 64).iter().map(|z| z * 80.0).collect();
        let before = crate::tensor::norm2(&x);
        tp.teleport(&mut x, 8, 80.0, 10.0);
        let after = crate::tensor::norm2(&x);
        assert!(after < before * 0.5, "{before} -> {after}");
    }

    #[test]
    fn teleported_schedule_caps_at_sigma_skip() {
        let base = crate::schedule::default_schedule(10);
        let s = teleported_schedule(&base, 10.0);
        assert_eq!(s.n_steps(), 10);
        assert!((s.t_max() - 10.0).abs() < 1e-9);
        assert!((s.t_min() - base.t_min()).abs() < 1e-12);
    }
}
