//! **PAS** — PCA-based Adaptive Search (the paper's contribution).
//!
//! * [`pca`] — trajectory buffers and the pinned-first-vector PCA basis
//!   (Algorithm 1 lines 2–6).
//! * [`coords`] — the learned "~10 parameters" and their on-disk format.
//! * [`train`] — Algorithm 1 as the engine-backed, workspace-pooled
//!   [`train::TrainSession`]: sequential per-time-point coordinate
//!   training against teacher trajectories with analytic gradients, flat
//!   node-store rollouts, pooled basis extraction and sharded (but
//!   bit-deterministic) minibatch gradients.
//! * [`adaptive`] — the tolerance rule that keeps only high-curvature
//!   steps (§3.3).
//! * [`correct`] — Algorithm 2: the corrected sampler as a
//!   [`crate::solvers::DirectionHook`].
//! * [`teleport`] — the TP warm start from the analytic Gaussian score
//!   (Wang & Vastola), used by the `+TP+PAS` rows of Table 2.

pub mod pca;
pub mod coords;
pub mod train;
pub mod adaptive;
pub mod correct;
pub mod teleport;
