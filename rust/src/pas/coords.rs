//! Learned coordinate storage — the "approximately 10 parameters".
//!
//! A [`CoordinateDict`] maps corrected time points (paper index `i`, from
//! NFE down to 1) to their learned coordinate vectors, plus the metadata
//! needed to reproduce the correction at sampling time. JSON on disk.

use crate::util::json::Json;
use std::collections::BTreeMap;

/// How learned coordinates relate to the per-sample basis scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleMode {
    /// Paper-literal: `d~ = U Cᵀ` with `c_1` initialized at the mean
    /// `||d_{t_i}||` over training samples.
    Absolute,
    /// Scale-relative extension: `d~ = ||d|| · U Cᵀ` with `c_1` initialized
    /// at 1 — generalizes better when direction norms vary across samples
    /// (low-D datasets). Ablated by `repro ablate-param`.
    Relative,
}

impl ScaleMode {
    pub fn as_str(&self) -> &'static str {
        match self {
            ScaleMode::Absolute => "absolute",
            ScaleMode::Relative => "relative",
        }
    }

    pub fn parse(s: &str) -> Option<ScaleMode> {
        match s {
            "absolute" => Some(ScaleMode::Absolute),
            "relative" => Some(ScaleMode::Relative),
            _ => None,
        }
    }
}

/// Trained PAS artifact for one (dataset, solver, NFE) combination.
#[derive(Clone, Debug)]
pub struct CoordinateDict {
    /// Paper time-point index `i` (N..1) → learned coordinates (len == n_basis).
    pub steps: BTreeMap<usize, Vec<f64>>,
    pub n_basis: usize,
    pub scale_mode: ScaleMode,
    pub solver: String,
    pub dataset: String,
    pub nfe: usize,
}

impl CoordinateDict {
    pub fn new(
        n_basis: usize,
        scale_mode: ScaleMode,
        solver: &str,
        dataset: &str,
        nfe: usize,
    ) -> CoordinateDict {
        CoordinateDict {
            steps: BTreeMap::new(),
            n_basis,
            scale_mode,
            solver: solver.to_string(),
            dataset: dataset.to_string(),
            nfe,
        }
    }

    /// Total stored learnable parameters — the paper's headline "~10".
    pub fn n_params(&self) -> usize {
        self.steps.values().map(|c| c.len()).sum()
    }

    /// Corrected time points, descending (the paper's Table 1/6 rows).
    pub fn corrected_steps(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.steps.keys().copied().collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        v
    }

    pub fn to_json(&self) -> Json {
        let mut steps = Json::obj();
        for (i, c) in &self.steps {
            steps.set(&i.to_string(), Json::from_f64_slice(c));
        }
        let mut o = Json::obj();
        o.set("n_basis", Json::Num(self.n_basis as f64))
            .set("scale_mode", Json::Str(self.scale_mode.as_str().into()))
            .set("solver", Json::Str(self.solver.clone()))
            .set("dataset", Json::Str(self.dataset.clone()))
            .set("nfe", Json::Num(self.nfe as f64))
            .set("steps", steps);
        o
    }

    pub fn from_json(j: &Json) -> Result<CoordinateDict, String> {
        let n_basis = j
            .get("n_basis")
            .and_then(|v| v.as_usize())
            .ok_or("missing n_basis")?;
        let scale_mode = j
            .get("scale_mode")
            .and_then(|v| v.as_str())
            .and_then(ScaleMode::parse)
            .ok_or("bad scale_mode")?;
        let solver = j
            .get("solver")
            .and_then(|v| v.as_str())
            .ok_or("missing solver")?
            .to_string();
        let dataset = j
            .get("dataset")
            .and_then(|v| v.as_str())
            .ok_or("missing dataset")?
            .to_string();
        let nfe = j
            .get("nfe")
            .and_then(|v| v.as_usize())
            .ok_or("missing nfe")?;
        let mut steps = BTreeMap::new();
        if let Some(Json::Obj(m)) = j.get("steps") {
            for (k, v) in m {
                let i: usize = k.parse().map_err(|_| format!("bad step key {k}"))?;
                // Paper index i runs N..1; training emits at most one
                // entry per solver step, so anything outside 1..=nfe is a
                // corrupt or mismatched artifact.
                if i == 0 || i > nfe {
                    return Err(format!("step key {i} out of range 1..={nfe}"));
                }
                let raw = v.as_arr().ok_or("bad coords")?;
                let c = v.to_f64_vec().ok_or("bad coords")?;
                // `to_f64_vec` drops non-numeric elements, so check the
                // raw array length too: a vector that only reaches
                // n_basis after dropping garbage is still corrupt.
                if raw.len() != n_basis || c.len() != raw.len() {
                    return Err(format!(
                        "step {i}: coord vector len {} != n_basis {n_basis}",
                        raw.len()
                    ));
                }
                if c.iter().any(|x| !x.is_finite()) {
                    return Err(format!("step {i}: non-finite coordinate"));
                }
                steps.insert(i, c);
            }
        }
        Ok(CoordinateDict {
            steps,
            n_basis,
            scale_mode,
            solver,
            dataset,
            nfe,
        })
    }

    /// Durable save: temp file + fsync + atomic rename (via the artifact
    /// store's helper), so a crash mid-save can never leave a torn dict.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        crate::artifact::store::write_atomic(path, self.to_json().to_string().as_bytes())
    }

    pub fn load(path: &std::path::Path) -> Result<CoordinateDict, String> {
        let s = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::from_json(&Json::parse(&s)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut d = CoordinateDict::new(4, ScaleMode::Absolute, "ddim", "gmm2d", 10);
        d.steps.insert(6, vec![1.5, 0.1, -0.2, 0.0]);
        d.steps.insert(4, vec![1.1, 0.0, 0.3, 0.05]);
        let j = d.to_json();
        let back = CoordinateDict::from_json(&j).unwrap();
        assert_eq!(back.steps, d.steps);
        assert_eq!(back.scale_mode, d.scale_mode);
        assert_eq!(back.n_params(), 8);
        assert_eq!(back.corrected_steps(), vec![6, 4]);
    }

    #[test]
    fn file_roundtrip() {
        let mut d = CoordinateDict::new(4, ScaleMode::Relative, "ipndm3", "gmm-hd64", 8);
        d.steps.insert(3, vec![1.0, 0.0, 0.0, -0.01]);
        // Per-test unique directory: a fixed path collides when two test
        // runs (or PAS_THREADS legs in CI) execute concurrently.
        let dir = std::env::temp_dir().join(format!(
            "pas_test_coords_{}_{:p}",
            std::process::id(),
            &d as *const _
        ));
        let path = dir.join("c.json");
        d.save(&path).unwrap();
        let back = CoordinateDict::load(&path).unwrap();
        assert_eq!(back.steps, d.steps);
        assert_eq!(back.solver, "ipndm3");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn from_json_rejects_corrupt_dicts() {
        let mut d = CoordinateDict::new(4, ScaleMode::Absolute, "ddim", "gmm2d", 10);
        d.steps.insert(6, vec![1.5, 0.1, -0.2, 0.0]);
        let good = d.to_json();
        assert!(CoordinateDict::from_json(&good).is_ok());

        // Coord vector shorter than n_basis.
        let mut j = good.clone();
        let mut steps = Json::obj();
        steps.set("6", Json::from_f64_slice(&[1.5, 0.1]));
        j.set("steps", steps);
        let e = CoordinateDict::from_json(&j).unwrap_err();
        assert!(e.contains("n_basis"), "{e}");

        // Step key 0 and key beyond nfe.
        for bad_key in ["0", "11"] {
            let mut j = good.clone();
            let mut steps = Json::obj();
            steps.set(bad_key, Json::from_f64_slice(&[1.0, 0.0, 0.0, 0.0]));
            j.set("steps", steps);
            let e = CoordinateDict::from_json(&j).unwrap_err();
            assert!(e.contains("out of range"), "key {bad_key}: {e}");
        }
        // Key == nfe is legitimate: training emits it at j = 0.
        let mut j = good.clone();
        let mut steps = Json::obj();
        steps.set("10", Json::from_f64_slice(&[1.0, 0.0, 0.0, 0.0]));
        j.set("steps", steps);
        assert!(CoordinateDict::from_json(&j).is_ok());

        // Non-numeric garbage inside an otherwise right-length vector.
        let mut j = good.clone();
        let mut steps = Json::obj();
        steps.set(
            "6",
            Json::Arr(vec![
                Json::Num(1.0),
                Json::Str("oops".into()),
                Json::Num(0.0),
                Json::Num(0.0),
            ]),
        );
        j.set("steps", steps);
        assert!(CoordinateDict::from_json(&j).is_err());
    }

    #[test]
    fn approximately_10_parameters() {
        // The paper's headline: 1–3 corrected steps × 4 coords ≈ 4–12.
        let mut d = CoordinateDict::new(4, ScaleMode::Absolute, "ddim", "cifar", 10);
        for i in [6, 4, 2] {
            d.steps.insert(i, vec![0.0; 4]);
        }
        assert_eq!(d.n_params(), 12);
    }
}
