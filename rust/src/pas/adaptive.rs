//! Adaptive search (paper §3.3): decide per time point whether the trained
//! correction is worth keeping.
//!
//! The cumulative truncation error of fast solvers is "S"-shaped: linear
//! trajectory segments accumulate negligible error, so correcting them only
//! injects bias along the extra basis vectors (the paper's PAS(-AS)
//! ablation, Table 7, is *worse than DDIM*). The rule keeps a step's
//! coordinates only when
//!
//! ```text
//! L2 - (L1 + tau) > 0
//! ```
//!
//! where `L2` is the uncorrected loss, `L1` the corrected loss (Eq. 20),
//! and `tau > 0` a tolerance (1e-2 for high-error solvers like DDIM,
//! 1e-4 for iPNDM — Table 8 shows the method is insensitive in between).

/// Outcome of the adaptive decision at one time point.
#[derive(Clone, Debug)]
pub struct AdaptiveDecision {
    /// Paper time-point index `i` (N..1).
    pub step_i: usize,
    /// Mean per-dimension loss without correction (paper's `L_2`).
    pub loss_uncorrected: f64,
    /// Mean per-dimension loss with the trained correction (paper's `L_1`).
    pub loss_corrected: f64,
    pub tau: f64,
    pub corrected: bool,
}

/// The tolerance rule (Algorithm 1 line 15).
pub fn decide(loss_uncorrected: f64, loss_corrected: f64, tau: f64) -> bool {
    loss_uncorrected - (loss_corrected + tau) > 0.0
}

impl AdaptiveDecision {
    pub fn evaluate(step_i: usize, loss_uncorrected: f64, loss_corrected: f64, tau: f64) -> Self {
        AdaptiveDecision {
            step_i,
            loss_uncorrected,
            loss_corrected,
            tau,
            corrected: decide(loss_uncorrected, loss_corrected, tau),
        }
    }
}

/// Summary over a whole training run (printed by `pas train`, used by the
/// Table 1/6 experiment).
#[derive(Clone, Debug, Default)]
pub struct AdaptiveTrace {
    pub decisions: Vec<AdaptiveDecision>,
}

impl AdaptiveTrace {
    /// Clear for a new run, pre-reserving one decision slot per time
    /// point so the per-step `push` never allocates (the training
    /// session's zero-steady-state-allocation discipline).
    pub fn reset_with_capacity(&mut self, n_steps: usize) {
        self.decisions.clear();
        self.decisions.reserve(n_steps);
    }

    pub fn corrected_steps(&self) -> Vec<usize> {
        self.decisions
            .iter()
            .filter(|d| d.corrected)
            .map(|d| d.step_i)
            .collect()
    }

    /// Render "6,4,2"-style list as in Tables 1 and 6.
    pub fn corrected_steps_str(&self) -> String {
        let steps = self.corrected_steps();
        if steps.is_empty() {
            "-".to_string()
        } else {
            steps
                .iter()
                .map(|s| s.to_string())
                .collect::<Vec<_>>()
                .join(",")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_matches_paper_inequality() {
        assert!(decide(1.0, 0.5, 0.1)); // clear win
        assert!(!decide(1.0, 0.95, 0.1)); // within tolerance → skip
        assert!(!decide(0.5, 1.0, 0.0)); // correction made things worse
        assert!(!decide(1.0, 1.0, 0.0)); // strict inequality
    }

    #[test]
    fn trace_formats_steps_descending() {
        let mut tr = AdaptiveTrace::default();
        for (i, l2, l1) in [(6, 1.0, 0.2), (5, 0.5, 0.49), (4, 0.8, 0.3)] {
            tr.decisions
                .push(AdaptiveDecision::evaluate(i, l2, l1, 1e-2));
        }
        assert_eq!(tr.corrected_steps(), vec![6, 4]);
        assert_eq!(tr.corrected_steps_str(), "6,4");
    }

    #[test]
    fn empty_trace_renders_dash() {
        assert_eq!(AdaptiveTrace::default().corrected_steps_str(), "-");
    }
}
