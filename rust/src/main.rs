//! `pas` binary — leader entrypoint. See `pas help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(pas::cli::main(argv));
}
