//! Exact analytic score / noise prediction for Gaussian-mixture data.
//!
//! For data `q(x0) = Σ_k w_k N(mu_k, Sigma_k)` the noised marginal at EDM
//! time `t` is `q_t(x) = Σ_k w_k N(mu_k, Sigma_k + t² I)` and
//!
//! ```text
//! score(x,t) = Σ_k r_k(x,t) (Sigma_k + t² I)^{-1} (mu_k − x)
//! eps(x,t)   = −t · score(x,t)
//! ```
//!
//! with softmax responsibilities `r_k`. Per-mode covariances are stored by
//! eigendecomposition, so `(Sigma_k + t² I)^{-1} v = Uᵀ diag(1/(lam+t²)) U v`
//! costs two (d×d)·(d) products per mode — the analytic-model hot path that
//! the `solver_step` bench profiles.
//!
//! # Sample-blocked evaluation
//!
//! `eval_batch` is **sample-blocked**: a tile of [`EVAL_TILE`] states is
//! evaluated together, so the per-mode eigenbasis pass becomes matrix–
//! matrix work (`Y = U_r Rᵀ` through the register-tiled kernels of
//! [`crate::tensor::gemm`], then a tiled back-projection
//! `S -= Uᵀ (Δ·Y)`) instead of one memory-bound O(r·d) dot-product sweep
//! per sample. Each streamed row of `U` is amortized across the whole
//! tile, which is where the throughput comes from
//! (`benches/eval_throughput.rs` tracks it as rows/sec).
//!
//! **Determinism:** blocking only regroups *samples*; every per-sample
//! reduction keeps the exact operation order of the scalar `eval_one`
//! path (4-lane [`crate::tensor::dot`] order for the low-rank eigenbasis
//! pass, single ascending chains elsewhere), so the blocked pipeline is
//! bit-identical to the per-sample path for every batch size, tile
//! alignment and pool thread count — enforced by
//! `tests/eval_blocked_parity.rs`, `tests/engine_parity.rs` and the
//! golden-trajectory fixtures.
//!
//! This is the same Gaussian(-score) family the paper's theory section
//! (§3.4, Wang & Vastola 2023/2024) uses; it reproduces exactly the
//! geometric trajectory structure PAS exploits.

use super::EpsModel;
use crate::data::{Dataset, GmmSpec, Mode};
use crate::tensor::gemm::{gemm_nt_dot_into, gemm_nt_seq_into};

/// Samples per evaluation tile of the blocked pipeline ([`AnalyticEps`]'s
/// `eval_batch`). Each streamed eigenbasis panel (a row of `U_r`, the
/// memory-bound operand of the eval) is reused across `EVAL_TILE` samples
/// instead of once per sample, so the panel traffic per sample drops by
/// the tile factor; 16 keeps the per-thread tile scratch
/// (`modes × EVAL_TILE × d` for the per-mode precision-weighted
/// residuals) within ~200 KiB for the largest registered dataset
/// (latent256: 6 × 16 × 256 f64) — L2-resident, far from evicting the
/// eigenbases it amortizes. Purely a throughput knob: per-sample results
/// are bit-identical for every tile size and tile alignment
/// (`tests/eval_blocked_parity.rs`).
pub const EVAL_TILE: usize = 16;

/// Internal per-mode evaluation representation. Dense covariances whose
/// eigen-spectrum ends in a flat isotropic tail (all our synthetic
/// datasets: low-rank structure + `floor * I`) are evaluated in truncated
/// form via the Woodbury split
///
/// `(Sigma + t²I)^{-1} = (tail+t²)^{-1} I  +  U_rᵀ [diag(1/(lam+t²)) − (tail+t²)^{-1}] U_r`
///
/// which costs O(r·d) per sample instead of O(d²) — the headline §Perf
/// optimization (16x on latent256).
enum ModeEval {
    /// `Sigma = var * I`.
    Iso { var: f64 },
    /// Flat tail + r significant eigenpairs (rows of `u_r`).
    LowRank {
        tail: f64,
        lam: Vec<f64>,
        u_r: Vec<f64>,
        r: usize,
    },
    /// Full eigendecomposition (no exploitable tail).
    Full { lam: Vec<f64>, u: Vec<f64> },
}

impl ModeEval {
    fn build(mode: &Mode) -> ModeEval {
        let d = mode.dim();
        match &mode.u {
            None => ModeEval::Iso { var: mode.lam[0] },
            Some(u) => {
                // Detect a flat isotropic tail in the (descending) spectrum.
                let lam_min = *mode.lam.last().unwrap();
                let scale = mode.lam[0].abs() + 1.0;
                let mut r = d;
                while r > 0 && (mode.lam[r - 1] - lam_min).abs() <= 1e-9 * scale {
                    r -= 1;
                }
                if r <= d / 2 {
                    let tail = mode.lam[r..].iter().sum::<f64>() / (d - r).max(1) as f64;
                    ModeEval::LowRank {
                        tail,
                        lam: mode.lam[..r].to_vec(),
                        u_r: u[..r * d].to_vec(),
                        r,
                    }
                } else {
                    ModeEval::Full {
                        lam: mode.lam.clone(),
                        u: u.clone(),
                    }
                }
            }
        }
    }
}

/// Analytic GMM eps-model over a subset of modes.
pub struct AnalyticEps {
    modes: Vec<Mode>,
    evals: Vec<ModeEval>,
    /// Precomputed log-weights.
    logw: Vec<f64>,
    d: usize,
    name: String,
}

impl AnalyticEps {
    pub fn new(name: impl Into<String>, modes: Vec<Mode>) -> Box<AnalyticEps> {
        assert!(!modes.is_empty());
        let d = modes[0].dim();
        let wsum: f64 = modes.iter().map(|m| m.weight).sum();
        let logw = modes.iter().map(|m| (m.weight / wsum).ln()).collect();
        let evals = modes.iter().map(ModeEval::build).collect();
        Box::new(AnalyticEps {
            modes,
            evals,
            logw,
            d,
            name: name.into(),
        })
    }

    /// Unconditional model over all modes of the dataset.
    pub fn from_dataset(ds: &Dataset) -> Box<AnalyticEps> {
        Self::from_spec(&ds.spec)
    }

    pub fn from_spec(spec: &GmmSpec) -> Box<AnalyticEps> {
        Self::new(spec.name.clone(), spec.modes.clone())
    }

    /// Conditional model restricted to modes with `label`.
    pub fn conditional(spec: &GmmSpec, label: usize) -> Box<AnalyticEps> {
        let modes: Vec<Mode> = spec
            .modes
            .iter()
            .filter(|m| m.label == label)
            .cloned()
            .collect();
        assert!(!modes.is_empty(), "no modes for label {label}");
        Self::new(format!("{}[class={label}]", spec.name), modes)
    }

    /// Per-sample eps evaluation into `out` (length d); returns log q_t(x)
    /// up to the dimension-independent constant (useful for tests).
    fn eval_one(&self, x: &[f64], t: f64, out: &mut [f64], scratch: &mut Scratch) -> f64 {
        let d = self.d;
        let t2 = t * t;
        let k_modes = self.modes.len();
        // Pass 1: per-mode log densities and the precision-weighted
        // residuals s_k = (Sigma_k + t²I)^{-1} (mu_k − x).
        scratch.ensure(k_modes, d);
        let mut max_lp = f64::NEG_INFINITY;
        for (k, mode) in self.modes.iter().enumerate() {
            let sk = &mut scratch.smat[k * d..(k + 1) * d];
            let lp = match &self.evals[k] {
                ModeEval::Iso { var } => {
                    // Isotropic: s = (mu − x)/(var+t²).
                    let denom = var + t2;
                    let mut q = 0.0;
                    for j in 0..d {
                        let r = mode.mean[j] - x[j];
                        sk[j] = r / denom;
                        q += r * r;
                    }
                    self.logw[k] - 0.5 * (q / denom + d as f64 * denom.ln())
                }
                ModeEval::LowRank { tail, lam, u_r, r } => {
                    // Woodbury split around the flat tail: only r rows of U
                    // are touched — O(r·d) per sample.
                    let base = 1.0 / (tail + t2);
                    let resid = &mut scratch.y[..d];
                    let mut q0 = 0.0;
                    for j in 0..d {
                        let rj = x[j] - mode.mean[j];
                        resid[j] = rj;
                        q0 += rj * rj;
                        sk[j] = -base * rj;
                    }
                    let mut q = base * q0;
                    let mut logdet = (d - r) as f64 * (tail + t2).ln();
                    for c in 0..*r {
                        let row = &u_r[c * d..(c + 1) * d];
                        let yc = crate::tensor::dot(row, resid);
                        let denom = lam[c] + t2;
                        let delta = 1.0 / denom - base;
                        q += yc * yc * delta;
                        logdet += denom.ln();
                        let coef = yc * delta;
                        if coef != 0.0 {
                            for j in 0..d {
                                sk[j] -= coef * row[j];
                            }
                        }
                    }
                    self.logw[k] - 0.5 * (q + logdet)
                }
                ModeEval::Full { lam, u } => {
                    // y = U (x − mu) in eigenbasis rows.
                    let y = &mut scratch.y[..d];
                    for (c, yc) in y.iter_mut().enumerate() {
                        let row = &u[c * d..(c + 1) * d];
                        let mut s = 0.0;
                        for j in 0..d {
                            s += row[j] * (x[j] - mode.mean[j]);
                        }
                        *yc = s;
                    }
                    // Quadratic form + logdet; z = y/(lam+t²).
                    let mut q = 0.0;
                    let mut logdet = 0.0;
                    let z = &mut scratch.z[..d];
                    for c in 0..d {
                        let denom = lam[c] + t2;
                        z[c] = y[c] / denom;
                        q += y[c] * z[c];
                        logdet += denom.ln();
                    }
                    // s = −Uᵀ z (note mu − x = −(x − mu)).
                    sk.fill(0.0);
                    for c in 0..d {
                        let zc = z[c];
                        if zc == 0.0 {
                            continue;
                        }
                        let row = &u[c * d..(c + 1) * d];
                        for j in 0..d {
                            sk[j] -= zc * row[j];
                        }
                    }
                    self.logw[k] - 0.5 * (q + logdet)
                }
            };
            scratch.lp[k] = lp;
            if lp > max_lp {
                max_lp = lp;
            }
        }
        // Pass 2: softmax-combine.
        let mut z = 0.0;
        for k in 0..k_modes {
            scratch.lp[k] = (scratch.lp[k] - max_lp).exp();
            z += scratch.lp[k];
        }
        out.fill(0.0);
        for k in 0..k_modes {
            let r = scratch.lp[k] / z;
            if r < 1e-300 {
                continue;
            }
            let sk = &scratch.smat[k * d..(k + 1) * d];
            for j in 0..d {
                out[j] += r * sk[j];
            }
        }
        // out currently holds score(x,t); eps = −t · score.
        for v in out.iter_mut() {
            *v *= -t;
        }
        max_lp + z.ln()
    }

    /// Internal evaluation representation chosen per mode (`"iso"`,
    /// `"lowrank"` or `"full"`). Exposed so the blocked-eval parity tests
    /// can assert a construction engages the variant it intends to
    /// exercise.
    pub fn mode_kinds(&self) -> Vec<&'static str> {
        self.evals
            .iter()
            .map(|e| match e {
                ModeEval::Iso { .. } => "iso",
                ModeEval::LowRank { .. } => "lowrank",
                ModeEval::Full { .. } => "full",
            })
            .collect()
    }

    /// Log marginal density (up to the `−d/2·log 2π` constant). Exposed for
    /// tests and for mode-interpolation experiments. Routed through the
    /// thread-local [`SCRATCH`] like `eval_range`, so repeated calls (the
    /// mode-interpolation sweeps, finite-difference tests) perform no
    /// steady-state heap allocation.
    pub fn log_density(&self, x: &[f64], t: f64) -> f64 {
        SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            scratch.ensure(self.modes.len(), self.d);
            // The output row lives in the scratch too; `take` it out so
            // `eval_one` can borrow the rest of the scratch mutably, and
            // size it here (not in `ensure`, which `eval_one` re-runs
            // while the buffer is taken out).
            let mut outbuf = std::mem::take(&mut scratch.outbuf);
            if outbuf.len() < self.d {
                outbuf.resize(self.d, 0.0);
            }
            let ld = self.eval_one(x, t, &mut outbuf[..self.d], &mut scratch);
            scratch.outbuf = outbuf;
            ld
        })
    }
}

/// Per-thread evaluation scratch. The first four buffers serve the scalar
/// `eval_one` path; the `*_tile` buffers stage one [`EVAL_TILE`]-sample
/// block of the blocked pipeline (residuals, eigen coordinates,
/// per-mode×sample coefficients/log-densities and the per-mode
/// precision-weighted residual rows awaiting the softmax combine).
struct Scratch {
    lp: Vec<f64>,
    smat: Vec<f64>,
    y: Vec<f64>,
    z: Vec<f64>,
    /// Residual tile `R = X − mu_k`, (EVAL_TILE, d).
    resid: Vec<f64>,
    /// Eigen-coordinate tile `Y`, (r, tile) with r ≤ d.
    ytile: Vec<f64>,
    /// Back-projection coefficient tile `Δ·Y` (resp. `z`), (r, tile).
    coef: Vec<f64>,
    /// Per-sample isotropic quadratic forms, (tile).
    q0: Vec<f64>,
    /// Per-mode per-sample log densities, (modes, EVAL_TILE).
    lp_tile: Vec<f64>,
    /// Per-mode `s_k` rows for the tile, (modes, EVAL_TILE, d).
    stile: Vec<f64>,
    /// Output row for the single-sample entry points (`log_density`).
    outbuf: Vec<f64>,
}

impl Scratch {
    fn new(k: usize, d: usize) -> Scratch {
        let mut s = Scratch {
            lp: Vec::new(),
            smat: Vec::new(),
            y: Vec::new(),
            z: Vec::new(),
            resid: Vec::new(),
            ytile: Vec::new(),
            coef: Vec::new(),
            q0: Vec::new(),
            lp_tile: Vec::new(),
            stile: Vec::new(),
            outbuf: Vec::new(),
        };
        s.ensure(k, d);
        s
    }

    fn ensure(&mut self, k: usize, d: usize) {
        if self.lp.len() < k {
            self.lp.resize(k, 0.0);
        }
        if self.smat.len() < k * d {
            self.smat.resize(k * d, 0.0);
        }
        if self.y.len() < d {
            self.y.resize(d, 0.0);
            self.z.resize(d, 0.0);
        }
        if self.resid.len() < EVAL_TILE * d {
            self.resid.resize(EVAL_TILE * d, 0.0);
            self.ytile.resize(EVAL_TILE * d, 0.0);
            self.coef.resize(EVAL_TILE * d, 0.0);
        }
        if self.q0.len() < EVAL_TILE {
            self.q0.resize(EVAL_TILE, 0.0);
        }
        if self.lp_tile.len() < k * EVAL_TILE {
            self.lp_tile.resize(k * EVAL_TILE, 0.0);
        }
        if self.stile.len() < k * EVAL_TILE * d {
            self.stile.resize(k * EVAL_TILE * d, 0.0);
        }
        // `outbuf` is deliberately NOT grown here: `log_density` takes it
        // out of the scratch before calling `eval_one` (which re-runs
        // `ensure`), so growing it from `ensure` would allocate a fresh
        // buffer per call only for the restore to drop it.
    }
}

thread_local! {
    /// Per-thread evaluation scratch, reused across calls so the serving
    /// path's steady state performs no heap allocation per model eval
    /// (the `pas_overhead` bench's allocation counter checks this).
    static SCRATCH: std::cell::RefCell<Scratch> =
        std::cell::RefCell::new(Scratch::new(0, 0));
}

impl AnalyticEps {
    /// Evaluate one tile of `nb <= EVAL_TILE` samples through the blocked
    /// GEMM pipeline. Per-sample operation order is **exactly** that of
    /// [`Self::eval_one`] — blocking only regroups which sample is worked
    /// on when — so outputs are bit-identical to the scalar path.
    fn eval_tile(&self, x: &[f64], nb: usize, t: f64, out: &mut [f64], scratch: &mut Scratch) {
        let d = self.d;
        let t2 = t * t;
        let k_modes = self.modes.len();
        debug_assert!(nb >= 1 && nb <= EVAL_TILE);
        debug_assert_eq!(x.len(), nb * d);
        debug_assert_eq!(out.len(), nb * d);
        let Scratch {
            lp,
            resid,
            ytile,
            coef,
            q0,
            lp_tile,
            stile,
            ..
        } = scratch;
        // Pass 1: per mode, the whole tile — log densities into `lp_tile`
        // and precision-weighted residuals s_k into `stile`.
        for (k, mode) in self.modes.iter().enumerate() {
            let sk = &mut stile[k * EVAL_TILE * d..k * EVAL_TILE * d + nb * d];
            let lps = &mut lp_tile[k * EVAL_TILE..k * EVAL_TILE + nb];
            match &self.evals[k] {
                ModeEval::Iso { var } => {
                    // Isotropic: no basis to amortize; the scalar loop per
                    // sample, verbatim.
                    let denom = var + t2;
                    for b in 0..nb {
                        let xb = &x[b * d..(b + 1) * d];
                        let skb = &mut sk[b * d..(b + 1) * d];
                        let mut q = 0.0;
                        for j in 0..d {
                            let r = mode.mean[j] - xb[j];
                            skb[j] = r / denom;
                            q += r * r;
                        }
                        lps[b] = self.logw[k] - 0.5 * (q / denom + d as f64 * denom.ln());
                    }
                }
                ModeEval::LowRank { tail, lam, u_r, r } => {
                    let base = 1.0 / (tail + t2);
                    // Residual tile R = X − mu (plus the isotropic parts
                    // of q and s, per sample as in the scalar path).
                    for b in 0..nb {
                        let xb = &x[b * d..(b + 1) * d];
                        let rb = &mut resid[b * d..(b + 1) * d];
                        let skb = &mut sk[b * d..(b + 1) * d];
                        let mut q0b = 0.0;
                        for j in 0..d {
                            let rj = xb[j] - mode.mean[j];
                            rb[j] = rj;
                            q0b += rj * rj;
                            skb[j] = -base * rj;
                        }
                        q0[b] = q0b;
                    }
                    // Y = U_r Rᵀ: each entry in the 4-lane `dot` order of
                    // the scalar pass, each U row streamed once per tile.
                    gemm_nt_dot_into(u_r, *r, &resid[..nb * d], nb, d, &mut ytile[..r * nb]);
                    // log|Sigma + t²I| is sample-independent: computed
                    // once, with the scalar pass's op order.
                    let mut logdet = (d - r) as f64 * (tail + t2).ln();
                    for c in 0..*r {
                        logdet += (lam[c] + t2).ln();
                    }
                    // Quadratic forms + back-projection coefficients.
                    for b in 0..nb {
                        let mut q = base * q0[b];
                        for c in 0..*r {
                            let yc = ytile[c * nb + b];
                            let denom = lam[c] + t2;
                            let delta = 1.0 / denom - base;
                            q += yc * yc * delta;
                            coef[c * nb + b] = yc * delta;
                        }
                        lps[b] = self.logw[k] - 0.5 * (q + logdet);
                    }
                    // Back-projection S -= U_rᵀ (Δ·Y), c-outer so each
                    // eigen row streams once per tile; per-sample update
                    // order (ascending c, sequential j, zero-coef skip)
                    // equals the scalar interleaved loop.
                    for c in 0..*r {
                        let row = &u_r[c * d..(c + 1) * d];
                        for b in 0..nb {
                            let cf = coef[c * nb + b];
                            if cf != 0.0 {
                                let skb = &mut sk[b * d..(b + 1) * d];
                                for j in 0..d {
                                    skb[j] -= cf * row[j];
                                }
                            }
                        }
                    }
                }
                ModeEval::Full { lam, u } => {
                    for b in 0..nb {
                        let xb = &x[b * d..(b + 1) * d];
                        let rb = &mut resid[b * d..(b + 1) * d];
                        for j in 0..d {
                            rb[j] = xb[j] - mode.mean[j];
                        }
                    }
                    // y = U (x − mu): the scalar Full pass reduces each
                    // coordinate with a single ascending chain, so the
                    // sequential-order kernel (not the dot-order one).
                    gemm_nt_seq_into(u, d, &resid[..nb * d], nb, d, &mut ytile[..d * nb]);
                    let mut logdet = 0.0;
                    for c in 0..d {
                        logdet += (lam[c] + t2).ln();
                    }
                    for b in 0..nb {
                        let mut q = 0.0;
                        for c in 0..d {
                            let denom = lam[c] + t2;
                            let yc = ytile[c * nb + b];
                            let zc = yc / denom;
                            coef[c * nb + b] = zc;
                            q += yc * zc;
                        }
                        lps[b] = self.logw[k] - 0.5 * (q + logdet);
                    }
                    // s = −Uᵀ z, tiled like the low-rank back-projection.
                    sk.fill(0.0);
                    for c in 0..d {
                        let row = &u[c * d..(c + 1) * d];
                        for b in 0..nb {
                            let zc = coef[c * nb + b];
                            if zc == 0.0 {
                                continue;
                            }
                            let skb = &mut sk[b * d..(b + 1) * d];
                            for j in 0..d {
                                skb[j] -= zc * row[j];
                            }
                        }
                    }
                }
            }
        }
        // Pass 2: softmax-combine, per sample, in the scalar pass's mode
        // order (running max, then exp/sum, then the r_k-weighted combine
        // with its small-responsibility skip).
        for b in 0..nb {
            let mut max_lp = f64::NEG_INFINITY;
            for k in 0..k_modes {
                let v = lp_tile[k * EVAL_TILE + b];
                if v > max_lp {
                    max_lp = v;
                }
            }
            let mut z = 0.0;
            for k in 0..k_modes {
                lp[k] = (lp_tile[k * EVAL_TILE + b] - max_lp).exp();
                z += lp[k];
            }
            let ob = &mut out[b * d..(b + 1) * d];
            ob.fill(0.0);
            for k in 0..k_modes {
                let r = lp[k] / z;
                if r < 1e-300 {
                    continue;
                }
                let skb = &stile[k * EVAL_TILE * d + b * d..k * EVAL_TILE * d + (b + 1) * d];
                for j in 0..d {
                    ob[j] += r * skb[j];
                }
            }
            for v in ob.iter_mut() {
                *v *= -t;
            }
        }
    }

    /// Blocked evaluation of a row range: tiles of [`EVAL_TILE`] samples
    /// through [`Self::eval_tile`].
    fn eval_range(&self, x: &[f64], t: f64, out: &mut [f64]) {
        let d = self.d;
        let n = x.len() / d;
        SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            scratch.ensure(self.modes.len(), d);
            let mut i = 0;
            while i < n {
                let nb = EVAL_TILE.min(n - i);
                self.eval_tile(
                    &x[i * d..(i + nb) * d],
                    nb,
                    t,
                    &mut out[i * d..(i + nb) * d],
                    &mut scratch,
                );
                i += nb;
            }
        });
    }

    /// The pre-blocking per-sample path (one [`Self::eval_one`] per row,
    /// same pool fan-out as `eval_batch`). Kept as the bit-exactness
    /// oracle for `tests/eval_blocked_parity.rs` and the baseline that
    /// `benches/eval_throughput.rs` reports speedups against.
    pub fn eval_batch_per_sample(&self, x: &[f64], n: usize, t: f64, out: &mut [f64]) {
        assert_eq!(x.len(), n * self.d);
        assert_eq!(out.len(), n * self.d);
        let pool = crate::util::pool::Pool::global();
        let threads = pool.size();
        if threads > 1 && n >= 4 * threads && n * self.d >= 4096 {
            let d = self.d;
            let out_ptr = crate::util::pool::SendPtr::new(out.as_mut_ptr());
            pool.par_rows(n, threads, 1, |r0, r1| {
                // SAFETY: pool row ranges are disjoint.
                let o = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.get().add(r0 * d), (r1 - r0) * d)
                };
                self.eval_range_per_sample(&x[r0 * d..r1 * d], t, o);
            });
        } else {
            self.eval_range_per_sample(x, t, out);
        }
    }

    fn eval_range_per_sample(&self, x: &[f64], t: f64, out: &mut [f64]) {
        let d = self.d;
        let n = x.len() / d;
        SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            scratch.ensure(self.modes.len(), d);
            for i in 0..n {
                self.eval_one(
                    &x[i * d..(i + 1) * d],
                    t,
                    &mut out[i * d..(i + 1) * d],
                    &mut scratch,
                );
            }
        });
    }
}

impl EpsModel for AnalyticEps {
    fn dim(&self) -> usize {
        self.d
    }

    fn eval_batch(&self, x: &[f64], n: usize, t: f64, out: &mut [f64]) {
        assert_eq!(x.len(), n * self.d);
        assert_eq!(out.len(), n * self.d);
        // Parallel fan-out over samples when the batch is worth it
        // (perf pass, EXPERIMENTS.md §Perf: the analytic eps eval is the
        // whole-stack bottleneck on every table). Rows are independent, so
        // sharding over the persistent pool is bit-identical to the
        // sequential loop for every thread count — and per-sample results
        // do not depend on tile membership, so chunk boundaries are free
        // to fall anywhere; `EVAL_TILE` as the minimum chunk size just
        // keeps every shard's tiles full-width.
        let pool = crate::util::pool::Pool::global();
        let threads = pool.size();
        if threads > 1 && n >= 4 * threads && n * self.d >= 4096 {
            let d = self.d;
            let out_ptr = crate::util::pool::SendPtr::new(out.as_mut_ptr());
            pool.par_rows(n, threads, EVAL_TILE, |r0, r1| {
                // SAFETY: pool row ranges are disjoint.
                let o = unsafe {
                    std::slice::from_raw_parts_mut(out_ptr.get().add(r0 * d), (r1 - r0) * d)
                };
                self.eval_range(&x[r0 * d..r1 * d], t, o);
            });
        } else {
            self.eval_range(x, t, out);
        }
    }

    fn preferred_tile(&self) -> usize {
        EVAL_TILE
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Single isotropic Gaussian N(mu, v I): eps(x,t) = t (x − mu)/(v + t²).
    #[test]
    fn single_gaussian_closed_form() {
        let mu = vec![1.0, -2.0, 0.5];
        let v = 0.7;
        let m = AnalyticEps::new("g", vec![Mode::isotropic(mu.clone(), v, 1.0, 0)]);
        let x = vec![0.3, 0.1, -0.4];
        let t = 2.5;
        let eps = m.eval(&x, 1, t);
        for j in 0..3 {
            let want = t * (x[j] - mu[j]) / (v + t * t);
            assert!((eps[j] - want).abs() < 1e-12, "{} vs {}", eps[j], want);
        }
    }

    /// Full-covariance single mode must agree with the isotropic fast path
    /// when Sigma is isotropic.
    #[test]
    fn full_matches_isotropic() {
        let d = 5;
        let mu = vec![0.2; d];
        let v = 0.4;
        let mut cov = vec![0.0; d * d];
        for j in 0..d {
            cov[j * d + j] = v;
        }
        let iso = AnalyticEps::new("i", vec![Mode::isotropic(mu.clone(), v, 1.0, 0)]);
        let full = AnalyticEps::new("f", vec![Mode::full(mu, &cov, 1.0, 0)]);
        let mut rng = Pcg64::seed(4);
        let x = rng.normal_vec(d);
        let a = iso.eval(&x, 1, 1.7);
        let b = full.eval(&x, 1, 1.7);
        for j in 0..d {
            assert!((a[j] - b[j]).abs() < 1e-9);
        }
    }

    /// eps must match the finite-difference gradient of log density:
    /// eps = −t ∇ log q_t.
    #[test]
    fn matches_log_density_gradient() {
        let spec = crate::data::generators::gmm2d();
        let m = AnalyticEps::from_spec(&spec);
        let x = vec![1.3, -0.7];
        let t = 3.0;
        let eps = m.eval(&x, 1, t);
        let h = 1e-5;
        for j in 0..2 {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[j] += h;
            xm[j] -= h;
            let g = (m.log_density(&xp, t) - m.log_density(&xm, t)) / (2.0 * h);
            let want = -t * g;
            assert!(
                (eps[j] - want).abs() < 1e-5 * (1.0 + want.abs()),
                "{} vs {}",
                eps[j],
                want
            );
        }
    }

    /// At large t, eps(x,t) ≈ x/t ⋅ t²/(t²+var) ≈ (x − mu_data)/t; sanity:
    /// the prior direction dominates.
    #[test]
    fn large_t_limit() {
        let spec = crate::data::generators::gmm2d();
        let m = AnalyticEps::from_spec(&spec);
        let t = 80.0;
        let x = vec![t * 0.9, -t * 0.3];
        let eps = m.eval(&x, 1, t);
        for j in 0..2 {
            let want = x[j] / t; // mixture mean ≈ 0, var ≪ t²
            assert!((eps[j] - want).abs() < 0.05, "{} vs {}", eps[j], want);
        }
    }

    /// Responsibilities: near one mode, eps points along that mode's pull.
    #[test]
    fn near_mode_attraction() {
        let spec = crate::data::generators::gmm2d();
        let m = AnalyticEps::from_spec(&spec);
        // Mode 0 sits at (6, 0) with var 0.09.
        let x = vec![6.3, 0.0];
        let t = 0.05;
        let eps = m.eval(&x, 1, t);
        // eps ≈ t (x−mu)/(var+t²) > 0 in coordinate 0.
        let want = t * 0.3 / (0.09 + t * t);
        assert!((eps[0] - want).abs() < 1e-6);
        assert!(eps[1].abs() < 1e-9);
    }

    /// The low-rank Woodbury fast path must match the dense eigen path
    /// exactly (the latent256/gmm-hd64 modes are rank-r + floor·I by
    /// construction).
    #[test]
    fn lowrank_fast_path_matches_dense() {
        let mut rng = Pcg64::seed(21);
        let d = 32;
        // Rank-4 + floor covariance.
        let mut cov = vec![0.0; d * d];
        for j in 0..d {
            cov[j * d + j] = 0.05;
        }
        for _ in 0..4 {
            let v = rng.normal_vec(d);
            for a in 0..d {
                for b in 0..d {
                    cov[a * d + b] += 0.8 * v[a] * v[b] / d as f64 * 4.0;
                }
            }
        }
        let mu = rng.normal_vec(d);
        let mode = Mode::full(mu, &cov, 1.0, 0);
        let m = AnalyticEps::new("lr", vec![mode.clone()]);
        // Verify the fast path actually engaged.
        assert!(matches!(m.evals[0], ModeEval::LowRank { .. }));
        // Dense comparator: force Full by constructing a ModeEval manually.
        let dense = AnalyticEps {
            modes: vec![mode.clone()],
            evals: vec![ModeEval::Full {
                lam: mode.lam.clone(),
                u: mode.u.clone().unwrap(),
            }],
            logw: vec![0.0],
            d,
            name: "dense".into(),
        };
        for trial in 0..5 {
            let x = rng.normal_vec(d);
            let t = 0.1 + trial as f64;
            let fast = m.eval(&x, 1, t);
            let slow = dense.eval(&x, 1, t);
            for j in 0..d {
                assert!(
                    (fast[j] - slow[j]).abs() < 1e-9 * (1.0 + slow[j].abs()),
                    "trial {trial} dim {j}: {} vs {}",
                    fast[j],
                    slow[j]
                );
            }
            let lf = m.log_density(&x, t);
            let ls = dense.log_density(&x, t);
            assert!((lf - ls).abs() < 1e-8 * (1.0 + ls.abs()), "{lf} vs {ls}");
        }
    }

    #[test]
    fn batch_matches_single() {
        let spec = crate::data::generators::checker2d();
        let m = AnalyticEps::from_spec(&spec);
        let mut rng = Pcg64::seed(8);
        let x = rng.normal_vec(6);
        let batch = m.eval(&x, 3, 1.0);
        for i in 0..3 {
            let single = m.eval(&x[i * 2..(i + 1) * 2], 1, 1.0);
            assert_eq!(&batch[i * 2..(i + 1) * 2], single.as_slice());
        }
    }
}
