//! PJRT-backed eps model: the L3 view of the AOT-compiled JAX denoiser.
//!
//! The executable is lowered with a fixed batch `B`; calls with `n != B`
//! are padded/tiled transparently (samplers batch trajectories, so the
//! fixed shape is almost always hit exactly). f64 ↔ f32 conversion happens
//! at this boundary — the network is trained and lowered in f32.

use super::EpsModel;
use crate::runtime::Executable;

pub struct PjrtEps {
    exe: Executable,
    name: String,
}

impl PjrtEps {
    pub fn new(exe: Executable) -> PjrtEps {
        let name = format!("pjrt:{}@{}", exe.meta.name, exe.meta.dataset);
        PjrtEps { exe, name }
    }

    pub fn batch(&self) -> usize {
        self.exe.meta.batch
    }
}

impl EpsModel for PjrtEps {
    fn dim(&self) -> usize {
        self.exe.meta.dim
    }

    /// The executable is lowered at a fixed batch `B`: per-chunk calls
    /// would each pad/tile to `B` (multiplying real-model cost by the
    /// chunk count), and bitwise sub-batch identity of the f32 XLA path
    /// is not something we can promise. Keep multi-eval solvers
    /// unsharded around this model.
    fn rows_independent(&self) -> bool {
        false
    }

    fn eval_batch(&self, x: &[f64], n: usize, t: f64, out: &mut [f64]) {
        let d = self.dim();
        let b = self.batch();
        assert_eq!(x.len(), n * d);
        let mut xf = vec![0.0f32; b * d];
        let tf = vec![t as f32; b];
        let mut done = 0usize;
        while done < n {
            let take = (n - done).min(b);
            for i in 0..take * d {
                xf[i] = x[done * d + i] as f32;
            }
            // Pad the tail with copies of the last row (harmless).
            for i in take * d..b * d {
                xf[i] = xf[i % (take * d).max(1)];
            }
            let y = self
                .exe
                .eval_eps(&xf, &tf)
                .expect("PJRT execution failed");
            for i in 0..take * d {
                out[done * d + i] = y[i] as f64;
            }
            done += take;
        }
    }

    fn name(&self) -> &str {
        &self.name
    }
}
