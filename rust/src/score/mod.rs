//! Noise-prediction (`eps_theta`) model abstraction.
//!
//! Everything on the sampling path talks to an [`EpsModel`]: the analytic
//! Gaussian-mixture score (exact, used as both substrate and ground-truth
//! oracle), the classifier-free-guidance wrapper for conditional datasets,
//! and the PJRT-backed model that executes the AOT-compiled JAX denoiser
//! ([`crate::score::pjrt`]).
//!
//! EDM convention throughout: `alpha_t = 1`, `sigma_t = t`, PF-ODE
//! `dx/dt = eps(x, t)`, and `eps(x,t) = -t * score(x,t)` (Eq. 6–7).

pub mod analytic;
pub mod cfg;
pub mod counting;
#[cfg(feature = "pjrt")]
pub mod pjrt;

/// Batched noise-prediction network.
///
/// `Sync` is required so the sampling engine and the serving path can
/// shard a batch evaluation (and the row-sharded solver step, whose
/// higher-order solvers re-evaluate the model) across the thread pool.
pub trait EpsModel: Sync {
    /// Data dimension D.
    fn dim(&self) -> usize;

    /// Evaluate `eps(x, t)` for a batch: `x` and `out` are `(n, d)`
    /// row-major flat buffers; a single shared `t` (all solvers in this
    /// crate advance the whole batch on one time grid).
    fn eval_batch(&self, x: &[f64], n: usize, t: f64, out: &mut [f64]);

    /// Human-readable identifier.
    fn name(&self) -> &str;

    /// True (the default) when `eval_batch` computes each row purely from
    /// that row's slice of `x` — so evaluating any contiguous sub-batch
    /// yields bit-identical rows. The engine relies on this to route a
    /// multi-eval solver's *internal* evaluations through per-chunk
    /// `eval_batch` calls when row-sharding the step. Models that key
    /// behavior on the absolute row index within the batch (e.g.
    /// [`cfg::RowCfgEps`], which guides row `k` toward class `k %
    /// n_classes`) must return false; the engine then steps such solvers
    /// unsharded. Wrappers must delegate to their inner model.
    fn rows_independent(&self) -> bool {
        true
    }

    /// Preferred row-tile granularity of `eval_batch`: callers that split
    /// a batch into chunks (the engine's row-sharded stepping, sub-batch
    /// staging) get the best throughput when chunks are at least — ideally
    /// multiples of — this many rows, because the model's blocked
    /// evaluation pipeline amortizes streamed operands across tiles of
    /// this size ([`analytic::EVAL_TILE`]). Purely a performance hint:
    /// for a rows-independent model, results are bit-identical for every
    /// chunking. Wrappers should delegate to their inner model(s).
    fn preferred_tile(&self) -> usize {
        1
    }

    /// Convenience: allocate-and-return variant.
    fn eval(&self, x: &[f64], n: usize, t: f64) -> Vec<f64> {
        let mut out = vec![0.0; x.len()];
        self.eval_batch(x, n, t, &mut out);
        out
    }

    /// Data prediction `x0(x,t) = x - t * eps(x,t)` (Eq. 6 with EDM
    /// parameterization), used by data-prediction solvers (DPM-Solver++,
    /// UniPC).
    fn data_prediction(&self, x: &[f64], n: usize, t: f64) -> Vec<f64> {
        let mut out = self.eval(x, n, t);
        for i in 0..x.len() {
            out[i] = x[i] - t * out[i];
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Zero {
        d: usize,
    }

    impl EpsModel for Zero {
        fn dim(&self) -> usize {
            self.d
        }
        fn eval_batch(&self, _x: &[f64], n: usize, _t: f64, out: &mut [f64]) {
            assert_eq!(out.len(), n * self.d);
            out.fill(0.0);
        }
        fn name(&self) -> &str {
            "zero"
        }
    }

    #[test]
    fn data_prediction_identity_for_zero_eps() {
        let m = Zero { d: 3 };
        let x = vec![1.0, 2.0, 3.0];
        assert_eq!(m.data_prediction(&x, 1, 5.0), x);
    }
}
