//! Classifier-free guidance (CFG) wrapper.
//!
//! Used for the conditional experiments (ImageNet 64×64 analog, Table 2;
//! Stable Diffusion v1.4 analog with guidance scale 7.5, Table 3):
//!
//! ```text
//! eps_cfg(x, t) = eps_uncond(x, t) + s · (eps_cond(x, t) − eps_uncond(x, t))
//! ```
//!
//! Guidance is what blows up truncation error at low NFE in latent-space
//! models — exactly the regime where the paper shows PAS helps DDIM most.

use super::EpsModel;
use std::cell::RefCell;

thread_local! {
    /// Per-thread staging for the CFG wrappers: the conditional/
    /// unconditional eval buffers plus [`RowCfgEps`]'s class-grouped
    /// gather/scatter rows. Buffers are `take`n out of the cell for the
    /// duration of a call and restored afterwards, so a wrapper whose
    /// submodels are plain eps models (every construction in this crate)
    /// performs **zero steady-state heap allocations** per call. A CFG
    /// wrapper nested inside another CFG wrapper stays *correct* — the
    /// inner call just finds an empty slot and sizes its own buffer,
    /// which the outer restore then drops — so the zero-alloc guarantee
    /// is scoped to non-nested wrappers.
    static CFG_SCRATCH: RefCell<CfgScratch> = RefCell::new(CfgScratch::default());
}

#[derive(Default)]
struct CfgScratch {
    /// Conditional eps staging ([`CfgEps`]).
    ec: Vec<f64>,
    /// Unconditional eps staging ([`RowCfgEps`]).
    eu: Vec<f64>,
    /// Gathered per-class input rows ([`RowCfgEps`]).
    x_gather: Vec<f64>,
    /// Per-class eval output rows ([`RowCfgEps`]).
    e_gather: Vec<f64>,
}

pub struct CfgEps {
    pub cond: Box<dyn EpsModel>,
    pub uncond: Box<dyn EpsModel>,
    pub scale: f64,
    name: String,
}

impl CfgEps {
    pub fn new(cond: Box<dyn EpsModel>, uncond: Box<dyn EpsModel>, scale: f64) -> Box<CfgEps> {
        assert_eq!(cond.dim(), uncond.dim());
        let name = format!("cfg({}, s={})", cond.name(), scale);
        Box::new(CfgEps {
            cond,
            uncond,
            scale,
            name,
        })
    }
}

impl EpsModel for CfgEps {
    fn dim(&self) -> usize {
        self.cond.dim()
    }

    fn rows_independent(&self) -> bool {
        self.cond.rows_independent() && self.uncond.rows_independent()
    }

    fn preferred_tile(&self) -> usize {
        self.cond.preferred_tile().max(self.uncond.preferred_tile())
    }

    fn eval_batch(&self, x: &[f64], n: usize, t: f64, out: &mut [f64]) {
        // eps_u + s (eps_c − eps_u). Both nets evaluated per call — in NFE
        // accounting terms this is the standard "1 NFE = 1 guided eval"
        // convention the paper's Stable Diffusion tables use. The staging
        // buffer comes from the thread-local scratch (no per-call alloc).
        let mut ec = CFG_SCRATCH.with(|c| std::mem::take(&mut c.borrow_mut().ec));
        if ec.len() < out.len() {
            ec.resize(out.len(), 0.0);
        }
        self.cond.eval_batch(x, n, t, &mut ec[..out.len()]);
        self.uncond.eval_batch(x, n, t, out);
        let s = self.scale;
        for i in 0..out.len() {
            out[i] += s * (ec[i] - out[i]);
        }
        CFG_SCRATCH.with(|c| c.borrow_mut().ec = ec);
    }

    fn name(&self) -> &str {
        &self.name
    }
}

/// Batch-conditional CFG model: row `k` of every batch is guided toward
/// class `k % n_classes`. Rows keep their identity across a sampling run
/// (all solvers here advance a fixed batch), so this models a mixed-class
/// guided batch — the shape of the paper's Stable-Diffusion workload —
/// without per-row label plumbing in the `EpsModel` trait.
pub struct RowCfgEps {
    pub class_models: Vec<Box<dyn EpsModel>>,
    pub uncond: Box<dyn EpsModel>,
    pub scale: f64,
    name: String,
}

impl RowCfgEps {
    pub fn from_spec(spec: &crate::data::GmmSpec, scale: f64) -> Box<RowCfgEps> {
        use crate::score::analytic::AnalyticEps;
        assert!(spec.n_classes > 1, "dataset is not conditional");
        let class_models: Vec<Box<dyn EpsModel>> = (0..spec.n_classes)
            .map(|c| AnalyticEps::conditional(spec, c) as Box<dyn EpsModel>)
            .collect();
        let uncond = AnalyticEps::from_spec(spec);
        let name = format!("rowcfg({}, s={scale})", spec.name);
        Box::new(RowCfgEps {
            class_models,
            uncond,
            scale,
            name,
        })
    }

    pub fn n_classes(&self) -> usize {
        self.class_models.len()
    }
}

impl EpsModel for RowCfgEps {
    fn dim(&self) -> usize {
        self.uncond.dim()
    }

    /// Guidance class depends on the absolute row index, so a sub-batch
    /// eval would re-number rows — the engine must not shard around this
    /// model.
    fn rows_independent(&self) -> bool {
        false
    }

    fn preferred_tile(&self) -> usize {
        self.uncond.preferred_tile()
    }

    fn eval_batch(&self, x: &[f64], n: usize, t: f64, out: &mut [f64]) {
        let d = self.dim();
        let nc = self.class_models.len();
        // Batched (tile-aware) path: gather the rows of each class into a
        // contiguous sub-batch, evaluate that class model **once**, and
        // scatter through the CFG blend. One n-row eval plus `nc` batched
        // evals replaces the former n single-row evals, so the class
        // models' sample-blocked pipelines see full tiles. Row values are
        // identical to the per-row loop because every submodel computes
        // rows independently; submodels that key on batch composition get
        // the per-row fallback.
        let batchable = self.uncond.rows_independent()
            && self.class_models.iter().all(|m| m.rows_independent());
        if !batchable {
            let mut eu = vec![0.0; n * d];
            self.uncond.eval_batch(x, n, t, &mut eu);
            let mut row = vec![0.0; d];
            for k in 0..n {
                let model = &self.class_models[k % nc];
                model.eval_batch(&x[k * d..(k + 1) * d], 1, t, &mut row);
                let o = &mut out[k * d..(k + 1) * d];
                let u = &eu[k * d..(k + 1) * d];
                for j in 0..d {
                    o[j] = u[j] + self.scale * (row[j] - u[j]);
                }
            }
            return;
        }
        let (mut eu, mut xg, mut eg) = CFG_SCRATCH.with(|c| {
            let mut s = c.borrow_mut();
            (
                std::mem::take(&mut s.eu),
                std::mem::take(&mut s.x_gather),
                std::mem::take(&mut s.e_gather),
            )
        });
        if eu.len() < n * d {
            eu.resize(n * d, 0.0);
        }
        self.uncond.eval_batch(x, n, t, &mut eu[..n * d]);
        for c in 0..nc {
            // Rows c, c + nc, c + 2·nc, … — the class-c slice of the batch.
            let cnt = if n > c { (n - c).div_ceil(nc) } else { 0 };
            if cnt == 0 {
                continue;
            }
            if xg.len() < cnt * d {
                xg.resize(cnt * d, 0.0);
                eg.resize(cnt * d, 0.0);
            }
            for (i, k) in (c..n).step_by(nc).enumerate() {
                xg[i * d..(i + 1) * d].copy_from_slice(&x[k * d..(k + 1) * d]);
            }
            self.class_models[c].eval_batch(&xg[..cnt * d], cnt, t, &mut eg[..cnt * d]);
            for (i, k) in (c..n).step_by(nc).enumerate() {
                let o = &mut out[k * d..(k + 1) * d];
                let u = &eu[k * d..(k + 1) * d];
                let e = &eg[i * d..(i + 1) * d];
                for j in 0..d {
                    o[j] = u[j] + self.scale * (e[j] - u[j]);
                }
            }
        }
        CFG_SCRATCH.with(|cell| {
            let mut s = cell.borrow_mut();
            s.eu = eu;
            s.x_gather = xg;
            s.e_gather = eg;
        });
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::cond_gmm64;
    use crate::score::analytic::AnalyticEps;
    use crate::util::rng::Pcg64;

    #[test]
    fn scale_one_equals_conditional() {
        let spec = cond_gmm64();
        let cond = AnalyticEps::conditional(&spec, 2);
        let cond2 = AnalyticEps::conditional(&spec, 2);
        let uncond = AnalyticEps::from_spec(&spec);
        let cfg = CfgEps::new(cond, uncond, 1.0);
        let mut rng = Pcg64::seed(1);
        let x = rng.normal_vec(64);
        let a = cfg.eval(&x, 1, 2.0);
        let b = cond2.eval(&x, 1, 2.0);
        for j in 0..64 {
            assert!((a[j] - b[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn scale_zero_equals_unconditional() {
        let spec = cond_gmm64();
        let cfg = CfgEps::new(
            AnalyticEps::conditional(&spec, 0),
            AnalyticEps::from_spec(&spec),
            0.0,
        );
        let uncond = AnalyticEps::from_spec(&spec);
        let mut rng = Pcg64::seed(2);
        let x = rng.normal_vec(64);
        let a = cfg.eval(&x, 1, 5.0);
        let b = uncond.eval(&x, 1, 5.0);
        for j in 0..64 {
            assert!((a[j] - b[j]).abs() < 1e-12);
        }
    }

    /// The class-grouped gather/scatter path must reproduce the per-row
    /// loop's bits exactly, for batch sizes straddling multiples of
    /// n_classes (empty classes, partial last class, single row).
    #[test]
    fn rowcfg_batched_matches_per_row() {
        let spec = cond_gmm64();
        let cfg = RowCfgEps::from_spec(&spec, 7.5);
        let nc = cfg.n_classes();
        let uncond = AnalyticEps::from_spec(&spec);
        let class_models: Vec<Box<dyn EpsModel>> = (0..nc)
            .map(|c| AnalyticEps::conditional(&spec, c) as Box<dyn EpsModel>)
            .collect();
        let d = 64;
        let mut rng = Pcg64::seed(9);
        let t = 2.3;
        for n in [1usize, nc - 1, nc, nc + 1, 3 * nc + 2] {
            let x = rng.normal_vec(n * d);
            let got = cfg.eval(&x, n, t);
            // Reference: the former per-row loop, verbatim.
            let mut eu = vec![0.0; n * d];
            uncond.eval_batch(&x, n, t, &mut eu);
            let mut row = vec![0.0; d];
            let mut want = vec![0.0; n * d];
            for k in 0..n {
                class_models[k % nc].eval_batch(&x[k * d..(k + 1) * d], 1, t, &mut row);
                for j in 0..d {
                    want[k * d + j] = eu[k * d + j] + 7.5 * (row[j] - eu[k * d + j]);
                }
            }
            assert_eq!(got, want, "batched RowCfgEps diverged at n={n}");
        }
    }

    #[test]
    fn guidance_extrapolates() {
        let spec = cond_gmm64();
        let cfg = CfgEps::new(
            AnalyticEps::conditional(&spec, 1),
            AnalyticEps::from_spec(&spec),
            7.5,
        );
        let cond = AnalyticEps::conditional(&spec, 1);
        let uncond = AnalyticEps::from_spec(&spec);
        let mut rng = Pcg64::seed(3);
        let x = rng.normal_vec(64);
        let g = cfg.eval(&x, 1, 3.0);
        let c = cond.eval(&x, 1, 3.0);
        let u = uncond.eval(&x, 1, 3.0);
        for j in 0..64 {
            let want = u[j] + 7.5 * (c[j] - u[j]);
            assert!((g[j] - want).abs() < 1e-12);
        }
    }
}
