//! Classifier-free guidance (CFG) wrapper.
//!
//! Used for the conditional experiments (ImageNet 64×64 analog, Table 2;
//! Stable Diffusion v1.4 analog with guidance scale 7.5, Table 3):
//!
//! ```text
//! eps_cfg(x, t) = eps_uncond(x, t) + s · (eps_cond(x, t) − eps_uncond(x, t))
//! ```
//!
//! Guidance is what blows up truncation error at low NFE in latent-space
//! models — exactly the regime where the paper shows PAS helps DDIM most.

use super::EpsModel;

pub struct CfgEps {
    pub cond: Box<dyn EpsModel>,
    pub uncond: Box<dyn EpsModel>,
    pub scale: f64,
    name: String,
}

impl CfgEps {
    pub fn new(cond: Box<dyn EpsModel>, uncond: Box<dyn EpsModel>, scale: f64) -> Box<CfgEps> {
        assert_eq!(cond.dim(), uncond.dim());
        let name = format!("cfg({}, s={})", cond.name(), scale);
        Box::new(CfgEps {
            cond,
            uncond,
            scale,
            name,
        })
    }
}

impl EpsModel for CfgEps {
    fn dim(&self) -> usize {
        self.cond.dim()
    }

    fn rows_independent(&self) -> bool {
        self.cond.rows_independent() && self.uncond.rows_independent()
    }

    fn eval_batch(&self, x: &[f64], n: usize, t: f64, out: &mut [f64]) {
        // eps_u + s (eps_c − eps_u). Both nets evaluated per call — in NFE
        // accounting terms this is the standard "1 NFE = 1 guided eval"
        // convention the paper's Stable Diffusion tables use.
        let mut ec = vec![0.0; out.len()];
        self.cond.eval_batch(x, n, t, &mut ec);
        self.uncond.eval_batch(x, n, t, out);
        let s = self.scale;
        for i in 0..out.len() {
            out[i] += s * (ec[i] - out[i]);
        }
    }

    fn name(&self) -> &str {
        &self.name
    }
}

/// Batch-conditional CFG model: row `k` of every batch is guided toward
/// class `k % n_classes`. Rows keep their identity across a sampling run
/// (all solvers here advance a fixed batch), so this models a mixed-class
/// guided batch — the shape of the paper's Stable-Diffusion workload —
/// without per-row label plumbing in the `EpsModel` trait.
pub struct RowCfgEps {
    pub class_models: Vec<Box<dyn EpsModel>>,
    pub uncond: Box<dyn EpsModel>,
    pub scale: f64,
    name: String,
}

impl RowCfgEps {
    pub fn from_spec(spec: &crate::data::GmmSpec, scale: f64) -> Box<RowCfgEps> {
        use crate::score::analytic::AnalyticEps;
        assert!(spec.n_classes > 1, "dataset is not conditional");
        let class_models: Vec<Box<dyn EpsModel>> = (0..spec.n_classes)
            .map(|c| AnalyticEps::conditional(spec, c) as Box<dyn EpsModel>)
            .collect();
        let uncond = AnalyticEps::from_spec(spec);
        let name = format!("rowcfg({}, s={scale})", spec.name);
        Box::new(RowCfgEps {
            class_models,
            uncond,
            scale,
            name,
        })
    }

    pub fn n_classes(&self) -> usize {
        self.class_models.len()
    }
}

impl EpsModel for RowCfgEps {
    fn dim(&self) -> usize {
        self.uncond.dim()
    }

    /// Guidance class depends on the absolute row index, so a sub-batch
    /// eval would re-number rows — the engine must not shard around this
    /// model.
    fn rows_independent(&self) -> bool {
        false
    }

    fn eval_batch(&self, x: &[f64], n: usize, t: f64, out: &mut [f64]) {
        let d = self.dim();
        let mut eu = vec![0.0; n * d];
        self.uncond.eval_batch(x, n, t, &mut eu);
        let mut row = vec![0.0; d];
        for k in 0..n {
            let model = &self.class_models[k % self.class_models.len()];
            model.eval_batch(&x[k * d..(k + 1) * d], 1, t, &mut row);
            let o = &mut out[k * d..(k + 1) * d];
            let u = &eu[k * d..(k + 1) * d];
            for j in 0..d {
                o[j] = u[j] + self.scale * (row[j] - u[j]);
            }
        }
    }

    fn name(&self) -> &str {
        &self.name
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::generators::cond_gmm64;
    use crate::score::analytic::AnalyticEps;
    use crate::util::rng::Pcg64;

    #[test]
    fn scale_one_equals_conditional() {
        let spec = cond_gmm64();
        let cond = AnalyticEps::conditional(&spec, 2);
        let cond2 = AnalyticEps::conditional(&spec, 2);
        let uncond = AnalyticEps::from_spec(&spec);
        let cfg = CfgEps::new(cond, uncond, 1.0);
        let mut rng = Pcg64::seed(1);
        let x = rng.normal_vec(64);
        let a = cfg.eval(&x, 1, 2.0);
        let b = cond2.eval(&x, 1, 2.0);
        for j in 0..64 {
            assert!((a[j] - b[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn scale_zero_equals_unconditional() {
        let spec = cond_gmm64();
        let cfg = CfgEps::new(
            AnalyticEps::conditional(&spec, 0),
            AnalyticEps::from_spec(&spec),
            0.0,
        );
        let uncond = AnalyticEps::from_spec(&spec);
        let mut rng = Pcg64::seed(2);
        let x = rng.normal_vec(64);
        let a = cfg.eval(&x, 1, 5.0);
        let b = uncond.eval(&x, 1, 5.0);
        for j in 0..64 {
            assert!((a[j] - b[j]).abs() < 1e-12);
        }
    }

    #[test]
    fn guidance_extrapolates() {
        let spec = cond_gmm64();
        let cfg = CfgEps::new(
            AnalyticEps::conditional(&spec, 1),
            AnalyticEps::from_spec(&spec),
            7.5,
        );
        let cond = AnalyticEps::conditional(&spec, 1);
        let uncond = AnalyticEps::from_spec(&spec);
        let mut rng = Pcg64::seed(3);
        let x = rng.normal_vec(64);
        let g = cfg.eval(&x, 1, 3.0);
        let c = cond.eval(&x, 1, 3.0);
        let u = uncond.eval(&x, 1, 3.0);
        for j in 0..64 {
            let want = u[j] + 7.5 * (c[j] - u[j]);
            assert!((g[j] - want).abs() < 1e-12);
        }
    }
}
