//! NFE-counting wrapper: wraps any [`EpsModel`] and counts evaluations.
//!
//! Used by tests and benches to *prove* the NFE accounting of every solver
//! (the paper's tables are all parameterized by NFE, so an off-by-one here
//! would silently skew every comparison).

use super::EpsModel;
use std::sync::atomic::{AtomicUsize, Ordering};

pub struct CountingEps<'a> {
    pub inner: &'a dyn EpsModel,
    count: AtomicUsize,
    rows: AtomicUsize,
}

impl<'a> CountingEps<'a> {
    pub fn new(inner: &'a dyn EpsModel) -> CountingEps<'a> {
        CountingEps {
            inner,
            count: AtomicUsize::new(0),
            rows: AtomicUsize::new(0),
        }
    }

    /// Number of `eval_batch` calls so far (batch counts as one NFE: all
    /// trajectories advance in lockstep, matching how the paper counts
    /// model invocations per sample). NOTE: the engine may shard a
    /// multi-eval solver's internal evaluations into per-chunk calls, in
    /// which case call count exceeds logical NFE — use [`Self::nfe_rows`]
    /// for a sharding-invariant count.
    pub fn nfe(&self) -> usize {
        self.count.load(Ordering::Relaxed)
    }

    /// Total batch rows evaluated so far. A full-batch eval and the same
    /// eval split into per-chunk calls contribute identically, so this is
    /// invariant under the engine's row-sharding.
    pub fn rows_evaluated(&self) -> usize {
        self.rows.load(Ordering::Relaxed)
    }

    /// Sharding-invariant logical NFE for a run whose every evaluation
    /// covered (possibly in chunks) the same `n`-row batch: total rows
    /// evaluated divided by `n`. Panics if the row total is not an exact
    /// multiple of `n` — that would mean some evaluation skipped rows.
    pub fn nfe_rows(&self, n: usize) -> usize {
        let r = self.rows.load(Ordering::Relaxed);
        assert!(
            n > 0 && r % n == 0,
            "rows evaluated ({r}) not a multiple of the batch ({n})"
        );
        r / n
    }

    pub fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
        self.rows.store(0, Ordering::Relaxed);
    }
}

impl EpsModel for CountingEps<'_> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn rows_independent(&self) -> bool {
        self.inner.rows_independent()
    }

    fn preferred_tile(&self) -> usize {
        self.inner.preferred_tile()
    }

    fn eval_batch(&self, x: &[f64], n: usize, t: f64, out: &mut [f64]) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(n, Ordering::Relaxed);
        self.inner.eval_batch(x, n, t, out);
    }

    fn name(&self) -> &str {
        self.inner.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::score::analytic::AnalyticEps;

    #[test]
    fn counts_calls() {
        let ds = registry::get("gmm2d").unwrap();
        let m = AnalyticEps::from_dataset(&ds);
        let c = CountingEps::new(m.as_ref());
        let x = vec![0.0; 4];
        let mut out = vec![0.0; 4];
        for _ in 0..5 {
            c.eval_batch(&x, 2, 1.0, &mut out);
        }
        assert_eq!(c.nfe(), 5);
        assert_eq!(c.rows_evaluated(), 10);
        assert_eq!(c.nfe_rows(2), 5);
        c.reset();
        assert_eq!(c.nfe(), 0);
        assert_eq!(c.rows_evaluated(), 0);
    }

    /// Per-chunk calls summing to the batch count the same as full-batch
    /// calls — the property the engine's multi-eval sharding relies on.
    #[test]
    fn row_accounting_is_sharding_invariant() {
        let ds = registry::get("gmm2d").unwrap();
        let m = AnalyticEps::from_dataset(&ds);
        let c = CountingEps::new(m.as_ref());
        let x = vec![0.0; 8];
        let mut out = vec![0.0; 8];
        // One full-batch eval (4 rows) + the same batch in 3 chunks.
        c.eval_batch(&x, 4, 1.0, &mut out);
        c.eval_batch(&x[..2], 1, 1.0, &mut out[..2]);
        c.eval_batch(&x[2..6], 2, 1.0, &mut out[2..6]);
        c.eval_batch(&x[6..], 1, 1.0, &mut out[6..]);
        assert_eq!(c.nfe(), 4, "call count sees the chunking");
        assert_eq!(c.nfe_rows(4), 2, "row count does not");
    }
}
