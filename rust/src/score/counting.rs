//! NFE-counting wrapper: wraps any [`EpsModel`] and counts evaluations.
//!
//! Used by tests and benches to *prove* the NFE accounting of every solver
//! (the paper's tables are all parameterized by NFE, so an off-by-one here
//! would silently skew every comparison).

use super::EpsModel;
use std::sync::atomic::{AtomicUsize, Ordering};

pub struct CountingEps<'a> {
    pub inner: &'a dyn EpsModel,
    count: AtomicUsize,
}

impl<'a> CountingEps<'a> {
    pub fn new(inner: &'a dyn EpsModel) -> CountingEps<'a> {
        CountingEps {
            inner,
            count: AtomicUsize::new(0),
        }
    }

    /// Number of `eval_batch` calls so far (batch counts as one NFE: all
    /// trajectories advance in lockstep, matching how the paper counts
    /// model invocations per sample).
    pub fn nfe(&self) -> usize {
        self.count.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
    }
}

impl EpsModel for CountingEps<'_> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn eval_batch(&self, x: &[f64], n: usize, t: f64, out: &mut [f64]) {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.inner.eval_batch(x, n, t, out);
    }

    fn name(&self) -> &str {
        self.inner.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry;
    use crate::score::analytic::AnalyticEps;

    #[test]
    fn counts_calls() {
        let ds = registry::get("gmm2d").unwrap();
        let m = AnalyticEps::from_dataset(&ds);
        let c = CountingEps::new(m.as_ref());
        let x = vec![0.0; 4];
        let mut out = vec![0.0; 4];
        for _ in 0..5 {
            c.eval_batch(&x, 2, 1.0, &mut out);
        }
        assert_eq!(c.nfe(), 5);
        c.reset();
        assert_eq!(c.nfe(), 0);
    }
}
