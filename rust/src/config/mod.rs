//! Typed run configuration + a minimal TOML-subset parser.
//!
//! The offline dependency set has no toml crate, so we parse the subset we
//! need: `[section]` headers, `key = value` with string / number / bool
//! values, and `#` comments. This covers every config shipped in
//! `configs/` and keeps the launcher (`pas run --config f.toml`)
//! self-contained.

use crate::pas::coords::ScaleMode;
use crate::pas::train::{Loss, Optimizer, TrainConfig};
use std::collections::BTreeMap;

/// Raw parsed TOML subset: section → key → value string.
#[derive(Debug, Default, Clone)]
pub struct Toml {
    pub sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl Toml {
    pub fn parse(src: &str) -> Result<Toml, String> {
        let mut t = Toml::default();
        let mut cur = String::new();
        t.sections.insert(String::new(), BTreeMap::new());
        for (lineno, raw) in src.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                cur = name.trim().to_string();
                t.sections.entry(cur.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                let key = k.trim().to_string();
                let mut val = v.trim().to_string();
                if val.starts_with('"') && val.ends_with('"') && val.len() >= 2 {
                    val = val[1..val.len() - 1].to_string();
                }
                t.sections.get_mut(&cur).unwrap().insert(key, val);
            } else {
                return Err(format!("config line {} unparseable: {raw}", lineno + 1));
            }
        }
        Ok(t)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key)?.parse().ok()
    }

    pub fn get_usize(&self, section: &str, key: &str) -> Option<usize> {
        self.get(section, key)?.parse().ok()
    }

    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        match self.get(section, key)? {
            "true" => Some(true),
            "false" => Some(false),
            _ => None,
        }
    }
}

/// A full run configuration: dataset + solver + schedule + PAS training.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub dataset: String,
    pub solver: String,
    pub nfe: usize,
    pub n_samples: usize,
    pub seed: u64,
    /// Guidance scale for conditional datasets (1.0 = conditional only).
    pub guidance: f64,
    /// Teleportation sigma_skip; 0 disables TP.
    pub sigma_skip: f64,
    pub train: TrainConfig,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "gmm-hd64".into(),
            solver: "ddim".into(),
            nfe: 10,
            n_samples: 1024,
            seed: 0,
            guidance: 0.0,
            sigma_skip: 0.0,
            train: TrainConfig::default(),
        }
    }
}

impl RunConfig {
    pub fn from_toml(t: &Toml) -> Result<RunConfig, String> {
        let mut c = RunConfig::default();
        let s = "run";
        if let Some(v) = t.get(s, "dataset") {
            c.dataset = v.to_string();
        }
        if let Some(v) = t.get(s, "solver") {
            c.solver = v.to_string();
        }
        if let Some(v) = t.get_usize(s, "nfe") {
            c.nfe = v;
        }
        if let Some(v) = t.get_usize(s, "n_samples") {
            c.n_samples = v;
        }
        if let Some(v) = t.get_f64(s, "seed") {
            c.seed = v as u64;
        }
        if let Some(v) = t.get_f64(s, "guidance") {
            c.guidance = v;
        }
        if let Some(v) = t.get_f64(s, "sigma_skip") {
            c.sigma_skip = v;
        }
        let p = "pas";
        if let Some(v) = t.get_usize(p, "n_basis") {
            c.train.n_basis = v;
        }
        if let Some(v) = t.get_f64(p, "lr") {
            c.train.lr = v;
        }
        if let Some(v) = t.get_usize(p, "epochs") {
            c.train.epochs = v;
        }
        if let Some(v) = t.get_usize(p, "minibatch") {
            c.train.minibatch = v;
        }
        if let Some(v) = t.get_usize(p, "n_traj") {
            c.train.n_traj = v;
        }
        if let Some(v) = t.get_f64(p, "tau") {
            c.train.tau = v;
        }
        if let Some(v) = t.get(p, "loss") {
            c.train.loss = Loss::parse(v).ok_or_else(|| format!("unknown loss {v}"))?;
        }
        if let Some(v) = t.get(p, "scale_mode") {
            c.train.scale_mode =
                ScaleMode::parse(v).ok_or_else(|| format!("unknown scale_mode {v}"))?;
        }
        if let Some(v) = t.get(p, "optimizer") {
            c.train.optimizer = match v {
                "sgd" => Optimizer::Sgd,
                "adam" => Optimizer::Adam,
                _ => return Err(format!("unknown optimizer {v}")),
            };
        }
        if let Some(v) = t.get(p, "teacher") {
            c.train.teacher = v.to_string();
        }
        if let Some(v) = t.get_usize(p, "teacher_nfe") {
            c.train.teacher_nfe = v;
        }
        if let Some(v) = t.get_f64(p, "train_seed") {
            c.train.seed = v as u64;
        }
        c.validate()?;
        Ok(c)
    }

    pub fn load(path: &std::path::Path) -> Result<RunConfig, String> {
        let src = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
        Self::from_toml(&Toml::parse(&src)?)
    }

    pub fn validate(&self) -> Result<(), String> {
        if crate::data::registry::get(&self.dataset).is_none() {
            return Err(format!("unknown dataset {}", self.dataset));
        }
        if crate::solvers::registry::get(&self.solver).is_none() {
            return Err(format!("unknown solver {}", self.solver));
        }
        if self.nfe == 0 || self.nfe > 1000 {
            return Err(format!("nfe {} out of range", self.nfe));
        }
        if !(1..=8).contains(&self.train.n_basis) {
            return Err(format!("n_basis {} out of range", self.train.n_basis));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# sample config
[run]
dataset = "gmm2d"
solver = "ipndm"
nfe = 8
n_samples = 512
guidance = 7.5

[pas]
lr = 0.05
loss = "l1"
tau = 1e-4
n_traj = 128
scale_mode = "relative"
"#;

    #[test]
    fn parses_sample() {
        let t = Toml::parse(SAMPLE).unwrap();
        let c = RunConfig::from_toml(&t).unwrap();
        assert_eq!(c.dataset, "gmm2d");
        assert_eq!(c.solver, "ipndm");
        assert_eq!(c.nfe, 8);
        assert_eq!(c.guidance, 7.5);
        assert_eq!(c.train.lr, 0.05);
        assert_eq!(c.train.tau, 1e-4);
        assert_eq!(c.train.n_traj, 128);
        assert_eq!(c.train.scale_mode, ScaleMode::Relative);
    }

    #[test]
    fn rejects_unknown_solver() {
        let t = Toml::parse("[run]\nsolver = \"magic\"\n").unwrap();
        assert!(RunConfig::from_toml(&t).is_err());
    }

    #[test]
    fn rejects_garbage_line() {
        assert!(Toml::parse("this is not toml").is_err());
    }

    #[test]
    fn comments_and_blank_lines_ok() {
        let t = Toml::parse("# hi\n\n[run]\nnfe = 6 # inline\n").unwrap();
        assert_eq!(t.get_usize("run", "nfe"), Some(6));
    }
}
