//! Sampling time schedules (EDM convention: `alpha_t = 1`, `sigma_t = t`).
//!
//! The paper (Eq. 19) uses the polynomial (Karras) schedule with `rho = 7`
//! for both sampling and ground-truth generation; uniform and log-SNR grids
//! are provided for ablations.

/// A descending time grid `t_N = T > t_{N-1} > ... > t_0 = eps`.
///
/// Indexing convention throughout the crate: `ts[j]` for `j = 0..=N` holds
/// `t_{N-j}`, i.e. `ts[0] = T` and `ts[N] = eps`. A solver "step i" (paper
/// notation, `i = N..1`) moves from `ts[N-i]` to `ts[N-i+1]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Schedule {
    pub ts: Vec<f64>,
    pub kind: ScheduleKind,
}

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScheduleKind {
    Polynomial { rho: f64 },
    Uniform,
    LogSnr,
}

impl Schedule {
    /// Polynomial (Karras/EDM) schedule, Eq. (19) of the paper:
    /// `t_i = (t_0^{1/rho} + i/N (t_N^{1/rho} - t_0^{1/rho}))^rho`.
    pub fn polynomial(n: usize, t_min: f64, t_max: f64, rho: f64) -> Schedule {
        assert!(n >= 1 && t_min > 0.0 && t_max > t_min);
        let a = t_min.powf(1.0 / rho);
        let b = t_max.powf(1.0 / rho);
        let ts = (0..=n)
            .map(|j| {
                // j = 0 → i = N (t_max), j = N → i = 0 (t_min).
                let i = (n - j) as f64;
                (a + i / n as f64 * (b - a)).powf(rho)
            })
            .collect();
        Schedule {
            ts,
            kind: ScheduleKind::Polynomial { rho },
        }
    }

    /// Uniform grid in t.
    pub fn uniform(n: usize, t_min: f64, t_max: f64) -> Schedule {
        // Same contract as `polynomial`: without it, n = 0 divides 0/0
        // into a NaN grid that propagates silently into solver steps.
        assert!(n >= 1 && t_min > 0.0 && t_max > t_min);
        let ts = (0..=n)
            .map(|j| t_max - (t_max - t_min) * j as f64 / n as f64)
            .collect();
        Schedule {
            ts,
            kind: ScheduleKind::Uniform,
        }
    }

    /// Uniform in log-SNR (for EDM, lambda = -log t ⇒ geometric t grid).
    pub fn log_snr(n: usize, t_min: f64, t_max: f64) -> Schedule {
        // Same contract as `polynomial`; `t_min > 0` additionally guards
        // the `ln` below (t_min = 0 would put -inf in the grid).
        assert!(n >= 1 && t_min > 0.0 && t_max > t_min);
        let (la, lb) = (t_max.ln(), t_min.ln());
        let ts = (0..=n)
            .map(|j| (la + (lb - la) * j as f64 / n as f64).exp())
            .collect();
        Schedule {
            ts,
            kind: ScheduleKind::LogSnr,
        }
    }

    /// Number of solver steps N.
    pub fn n_steps(&self) -> usize {
        self.ts.len() - 1
    }

    pub fn t_max(&self) -> f64 {
        self.ts[0]
    }

    pub fn t_min(&self) -> f64 {
        *self.ts.last().unwrap()
    }

    /// Refine this schedule by inserting `m` extra points per interval
    /// following the *same* generator (paper §3.3): the teacher schedule of
    /// `N(M+1)` steps shares every student node, so ground-truth states can
    /// be read off by indexing every `(M+1)`-th teacher state.
    pub fn refine(&self, m: usize) -> Schedule {
        let n = self.n_steps() * (m + 1);
        let refined = match self.kind {
            ScheduleKind::Polynomial { rho } => {
                Schedule::polynomial(n, self.t_min(), self.t_max(), rho)
            }
            ScheduleKind::Uniform => Schedule::uniform(n, self.t_min(), self.t_max()),
            ScheduleKind::LogSnr => Schedule::log_snr(n, self.t_min(), self.t_max()),
        };
        refined
    }

    /// Smallest `m` such that `N(m+1) >= n_teacher` (paper §3.3), then the
    /// actual refined teacher schedule.
    pub fn teacher_for(&self, n_teacher: usize) -> (usize, Schedule) {
        let n = self.n_steps();
        let m = n_teacher.div_ceil(n).saturating_sub(1);
        (m, self.refine(m))
    }
}

/// EDM defaults used across the paper's experiments.
pub const T_MIN_DEFAULT: f64 = 0.002;
pub const T_MAX_DEFAULT: f64 = 80.0;
pub const RHO_DEFAULT: f64 = 7.0;

/// Convenience: the paper's polynomial-rho-7 grid for a given NFE-step count.
pub fn default_schedule(n: usize) -> Schedule {
    Schedule::polynomial(n, T_MIN_DEFAULT, T_MAX_DEFAULT, RHO_DEFAULT)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn polynomial_endpoints() {
        let s = Schedule::polynomial(10, 0.002, 80.0, 7.0);
        assert_eq!(s.ts.len(), 11);
        assert!((s.t_max() - 80.0).abs() < 1e-9);
        assert!((s.t_min() - 0.002).abs() < 1e-12);
        for w in s.ts.windows(2) {
            assert!(w[0] > w[1], "must be strictly descending: {:?}", w);
        }
    }

    #[test]
    fn polynomial_matches_formula() {
        let (n, rho, t0, tn) = (8, 7.0, 0.002f64, 80.0f64);
        let s = Schedule::polynomial(n, t0, tn, rho);
        for i in 0..=n {
            let want =
                (t0.powf(1.0 / rho) + i as f64 / n as f64 * (tn.powf(1.0 / rho) - t0.powf(1.0 / rho)))
                    .powf(rho);
            let got = s.ts[n - i];
            assert!((got - want).abs() < 1e-9 * want.max(1.0), "i={i}");
        }
    }

    #[test]
    fn refine_shares_nodes() {
        let s = Schedule::polynomial(5, 0.002, 80.0, 7.0);
        let r = s.refine(9); // teacher with 50 steps
        assert_eq!(r.n_steps(), 50);
        for (j, &t) in s.ts.iter().enumerate() {
            let tr = r.ts[j * 10];
            assert!(
                (t - tr).abs() < 1e-9 * t.max(1e-3),
                "node {j}: {t} vs {tr}"
            );
        }
    }

    #[test]
    fn teacher_for_covers_requested_nfe() {
        let s = default_schedule(6);
        let (m, teacher) = s.teacher_for(100);
        assert!(6 * (m + 1) >= 100);
        assert_eq!(teacher.n_steps(), 6 * (m + 1));
        // m is minimal.
        assert!(6 * m < 100);
    }

    #[test]
    #[should_panic]
    fn uniform_rejects_zero_steps() {
        let _ = Schedule::uniform(0, 0.002, 80.0);
    }

    #[test]
    #[should_panic]
    fn log_snr_rejects_zero_t_min() {
        let _ = Schedule::log_snr(4, 0.0, 80.0);
    }

    #[test]
    #[should_panic]
    fn uniform_rejects_inverted_range() {
        let _ = Schedule::uniform(4, 80.0, 0.002);
    }

    #[test]
    fn uniform_and_logsnr() {
        let u = Schedule::uniform(4, 1.0, 9.0);
        assert_eq!(u.ts, vec![9.0, 7.0, 5.0, 3.0, 1.0]);
        let g = Schedule::log_snr(2, 1.0, 100.0);
        assert!((g.ts[1] - 10.0).abs() < 1e-9);
    }
}
