//! # PAS — PCA-based Adaptive Search for diffusion sampling correction
//!
//! Full-system reproduction of *"Diffusion Sampling Correction via
//! Approximately 10 Parameters"* (ICML 2025) as a three-layer
//! rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: solvers, the PAS trainer and
//!   corrected sampler, trajectory/ground-truth generation, metrics, the
//!   experiment harness that regenerates every table and figure of the
//!   paper, a threaded batching sampling server, and the PJRT runtime that
//!   loads the AOT-compiled denoiser. Python is never on the request path.
//! * **L2** — a JAX MLP denoiser (`python/compile/model.py`), trained at
//!   build time and lowered to HLO text artifacts.
//! * **L1** — the denoiser hot-spot as a Pallas kernel
//!   (`python/compile/kernels/fused_resblock.py`, interpret=True).
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for measured results.

pub mod analysis;
pub mod util;
pub mod tensor;
pub mod linalg;
pub mod schedule;
pub mod data;
pub mod score;
pub mod solvers;
pub mod traj;
pub mod pas;
pub mod artifact;
pub mod metrics;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod server;
pub mod config;
pub mod experiments;
pub mod cli;

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::artifact::{ArtifactKey, ArtifactStore};
    pub use crate::data::Dataset;
    pub use crate::pas::coords::CoordinateDict;
    pub use crate::pas::correct::CorrectedSampler;
    pub use crate::pas::train::{PasTrainer, TrainConfig, TrainSession};
    pub use crate::schedule::Schedule;
    pub use crate::score::EpsModel;
    pub use crate::solvers::engine::{EngineConfig, Record, SamplerEngine, SlotEngine};
    pub use crate::solvers::{SolveRun, Solver};
    pub use crate::util::rng::Pcg64;
}
