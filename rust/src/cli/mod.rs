//! Command-line interface (hand-rolled: the offline vendor set has no
//! clap). `pas help` prints the full usage.

use crate::config::RunConfig;
use crate::experiments::{self, ExpOpts};
use crate::metrics::gfid;
use crate::pas::coords::CoordinateDict;
use crate::pas::correct::CorrectedSampler;
use crate::pas::train::PasTrainer;
use crate::schedule::default_schedule;
use crate::score::analytic::AnalyticEps;
use crate::score::cfg::RowCfgEps;
use crate::score::EpsModel;
use crate::solvers::run_solver;
use crate::traj::sample_prior;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use std::collections::HashMap;
use std::path::PathBuf;

/// Parsed flags: `--key value` and bare positionals.
pub struct Args {
    pub positional: Vec<String>,
    pub flags: HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

const USAGE: &str = "pas — PCA-based Adaptive Search for diffusion sampling (paper reproduction)

USAGE:
  pas list                                  list datasets, solvers, experiments
  pas sample  --dataset D --solver S --nfe N --n K [--coords f.json]
              [--guidance G] [--seed X] [--out samples.json] [--gfid]
  pas train   --dataset D --solver S --nfe N [--config f.toml]
              [--n-traj K] [--epochs E] [--lr L] [--tau T] [--loss l1|l2|...]
              --out coords.json
  pas repro   <id>|all [--quick] [--out results/] [--n-samples K]
  pas serve   [--addr 127.0.0.1:7777] [--workers W] [--artifacts DIR]
              [--drain-ms MS]        (SIGTERM/SIGINT drain deadline, default 5000)
  pas client  --addr HOST:PORT --dataset D --solver S --nfe N --n K
              [--seed X] [--pas] [--deadline-ms MS] [--priority P]
  pas client  --addr HOST:PORT --cmd status|metrics|health
  pas client  --addr HOST:PORT --cmd rollback --dataset D --solver S --nfe N
  pas artifact list     --store DIR
  pas artifact publish  --store DIR --coords f.json
              [--dataset D] [--solver S] [--nfe N]   (defaults: dict fields)
  pas artifact verify   --store DIR                  (exit 1 on corruption)
  pas artifact load     --store DIR                  (quarantine + heal)
  pas artifact rollback --store DIR --dataset D --solver S --nfe N
  pas pjrt-check [--artifacts DIR] [--name eps_spiral2d]
  pas lint    [--root DIR] [--json] [--report PATH] [--no-report]
              (source-contract checks; exit 1 on findings; writes LINT_report.json)
  pas help

Experiments (pas repro): fig2 fig3 table2 table3 table5 table6 fig6a fig6b
fig6c fig6d fig7 table8 table9 table11 ablate-param
";

/// Entry point; returns a process exit code.
pub fn main(argv: Vec<String>) -> i32 {
    let args = Args::parse(&argv);
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let r = match cmd {
        "help" | "-h" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        "list" => cmd_list(),
        "sample" => cmd_sample(&args),
        "train" => cmd_train(&args),
        "repro" => cmd_repro(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        "artifact" => cmd_artifact(&args),
        "pjrt-check" => cmd_pjrt_check(&args),
        "lint" => cmd_lint(&args),
        "dump-data" => cmd_dump_data(&args),
        other => Err(format!("unknown command {other}\n{USAGE}")),
    };
    match r {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn cmd_list() -> Result<(), String> {
    println!("datasets:");
    for name in crate::data::registry::ALL {
        let ds = crate::data::registry::get(name).unwrap();
        println!("  {name:<12} dim={:<4} {} (stands in for {})", ds.dim(), ds.about, ds.stands_in_for);
    }
    println!("solvers:");
    for name in crate::solvers::registry::ALL {
        let pas = if crate::solvers::registry::supports_pas(name) { " [PAS]" } else { "" };
        println!("  {name}{pas}");
    }
    println!("experiments: {}", experiments::ALL.join(" "));
    Ok(())
}

fn build_model(dataset: &str, guidance: f64) -> Result<(crate::data::Dataset, Box<dyn EpsModel>), String> {
    let ds = crate::data::registry::get(dataset).ok_or_else(|| format!("unknown dataset {dataset}"))?;
    let model: Box<dyn EpsModel> = if guidance > 0.0 {
        if !ds.is_conditional() {
            return Err(format!("{dataset} is not conditional; drop --guidance"));
        }
        RowCfgEps::from_spec(&ds.spec, guidance)
    } else {
        AnalyticEps::from_dataset(&ds)
    };
    Ok((ds, model))
}

fn cmd_sample(args: &Args) -> Result<(), String> {
    let dataset = args.get("dataset").unwrap_or("gmm-hd64");
    let solver_name = args.get("solver").unwrap_or("ddim");
    let nfe = args.get_usize("nfe", 10);
    let n = args.get_usize("n", 64);
    let seed = args.get_usize("seed", 0) as u64;
    let guidance = args.get_f64("guidance", 0.0);
    let (ds, model) = build_model(dataset, guidance)?;
    let solver = crate::solvers::registry::get(solver_name)
        .ok_or_else(|| format!("unknown solver {solver_name}"))?;
    let steps = solver
        .steps_for_nfe(nfe)
        .ok_or_else(|| format!("{solver_name} cannot hit NFE={nfe} exactly"))?;
    let sched = default_schedule(steps);
    let mut rng = Pcg64::seed(seed);
    let x_t = sample_prior(&mut rng, n, ds.dim(), sched.t_max());
    let (run, corrected) = if let Some(path) = args.get("coords") {
        let dict = CoordinateDict::load(&PathBuf::from(path))?;
        (
            CorrectedSampler::sample(&dict, solver.as_ref(), model.as_ref(), &x_t, n, &sched),
            true,
        )
    } else {
        (
            run_solver(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None),
            false,
        )
    };
    println!(
        "sampled n={n} dim={} solver={solver_name} nfe={} pas={corrected}",
        ds.dim(),
        run.nfe
    );
    if args.has("gfid") {
        let mut rref = Pcg64::seed(seed ^ 0xfade);
        let n_ref = 8192;
        let reference = ds.spec.sample(&mut rref, n_ref);
        let f = gfid(&run.x0, n, &reference, n_ref, ds.dim());
        println!("gFID = {f:.4}");
    }
    if let Some(out) = args.get("out") {
        let mut o = Json::obj();
        o.set("dataset", Json::Str(dataset.into()))
            .set("solver", Json::Str(solver_name.into()))
            .set("nfe", Json::Num(run.nfe as f64))
            .set("dim", Json::Num(ds.dim() as f64))
            .set("n", Json::Num(n as f64))
            .set("samples", Json::from_f64_slice(&run.x0));
        std::fs::write(out, o.to_string()).map_err(|e| e.to_string())?;
        println!("wrote {out}");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> Result<(), String> {
    let mut rc = if let Some(cfg_path) = args.get("config") {
        RunConfig::load(&PathBuf::from(cfg_path))?
    } else {
        RunConfig::default()
    };
    if let Some(d) = args.get("dataset") {
        rc.dataset = d.into();
    }
    if let Some(s) = args.get("solver") {
        rc.solver = s.into();
    }
    if args.has("nfe") {
        rc.nfe = args.get_usize("nfe", rc.nfe);
    }
    if args.has("n-traj") {
        rc.train.n_traj = args.get_usize("n-traj", rc.train.n_traj);
    }
    if args.has("epochs") {
        rc.train.epochs = args.get_usize("epochs", rc.train.epochs);
    }
    if args.has("lr") {
        rc.train.lr = args.get_f64("lr", rc.train.lr);
    }
    if args.has("tau") {
        rc.train.tau = args.get_f64("tau", rc.train.tau);
    }
    if let Some(l) = args.get("loss") {
        rc.train.loss = crate::pas::train::Loss::parse(l).ok_or_else(|| format!("unknown loss {l}"))?;
    }
    rc.validate()?;
    let (ds, model) = build_model(&rc.dataset, rc.guidance)?;
    let solver = crate::solvers::registry::get(&rc.solver).unwrap();
    let steps = solver
        .steps_for_nfe(rc.nfe)
        .ok_or_else(|| format!("{} cannot hit NFE={}", rc.solver, rc.nfe))?;
    let sched = default_schedule(steps);
    let trainer = PasTrainer::new(rc.train.clone());
    let tr = trainer.train(solver.as_ref(), model.as_ref(), &sched, ds.name(), false)?;
    println!(
        "trained PAS for {}@{} nfe={}: corrected steps [{}], {} parameters, {:.2}s",
        rc.solver,
        rc.dataset,
        rc.nfe,
        tr.trace.corrected_steps_str(),
        tr.dict.n_params(),
        tr.train_seconds
    );
    let out = args.get("out").unwrap_or("coords.json");
    tr.dict.save(&PathBuf::from(out)).map_err(|e| e.to_string())?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_repro(args: &Args) -> Result<(), String> {
    let id = args
        .positional
        .get(1)
        .ok_or("usage: pas repro <id>|all [--quick]")?
        .clone();
    let mut opts = if args.has("quick") {
        ExpOpts::quick()
    } else {
        ExpOpts::default()
    };
    if args.has("n-samples") {
        opts.n_samples = args.get_usize("n-samples", opts.n_samples);
    }
    if args.has("n-traj") {
        opts.n_traj = args.get_usize("n-traj", opts.n_traj);
    }
    if let Some(o) = args.get("out") {
        opts.out_dir = PathBuf::from(o);
    }
    let ids: Vec<&str> = if id == "all" {
        experiments::ALL.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        let t = crate::util::timer::Timer::start();
        let tables = experiments::run_and_save(id, &opts)?;
        for table in &tables {
            print!("{}", table.markdown());
        }
        eprintln!(
            "[{id}] done in {} -> {}",
            crate::util::timer::fmt_duration(t.elapsed_s()),
            opts.out_dir.join(format!("{id}.md")).display()
        );
    }
    Ok(())
}

/// Dependency-free POSIX signal latch: `pas serve` drains on
/// SIGTERM/SIGINT instead of dying mid-cohort. Declares the libc
/// `signal` symbol the std runtime already links, so no crate is pulled
/// in; the handler is async-signal-safe (one atomic store).
#[cfg(unix)]
mod signals {
    use std::sync::atomic::{AtomicBool, Ordering};

    static SHUTDOWN: AtomicBool = AtomicBool::new(false);

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" fn on_signal(_sig: i32) {
        SHUTDOWN.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: extern "C" fn(i32)) -> isize;
        }
        // SAFETY: `signal` is the POSIX libc symbol std already links;
        // the handler only performs an atomic store (async-signal-safe)
        // and matches the required `extern "C" fn(i32)` ABI.
        unsafe {
            signal(SIGTERM, on_signal);
            signal(SIGINT, on_signal);
        }
    }

    pub fn requested() -> bool {
        SHUTDOWN.load(Ordering::SeqCst)
    }
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    use crate::server::protocol::{serve_with, ServerConfig};
    use crate::server::{Service, ServiceConfig};
    use std::time::Duration;
    let addr = args.get("addr").unwrap_or("127.0.0.1:7777").to_string();
    let drain_ms = args.get_usize("drain-ms", 5_000) as u64;
    let cfg = ServiceConfig {
        workers: args.get_usize("workers", 4),
        artifact_root: args.get("artifacts").map(PathBuf::from),
        drain_deadline: Duration::from_millis(drain_ms),
        ..ServiceConfig::default()
    };
    let svc = std::sync::Arc::new(Service::start(cfg, Vec::new()));
    let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
    let server = serve_with(svc.clone(), &addr, stop, ServerConfig::default())
        .map_err(|e| e.to_string())?;
    println!(
        "pas server listening on {} (line-delimited JSON; kernel backend {}; \
         SIGTERM/Ctrl-C drains, --drain-ms {drain_ms})",
        server.local_addr(),
        crate::tensor::gemm::backend_name()
    );
    #[cfg(unix)]
    {
        signals::install();
        while !signals::requested() {
            std::thread::sleep(Duration::from_millis(50));
        }
        // Two-phase drain: stop accepting, fail queued work with
        // structured errors, let residents retire under the deadline,
        // then join connection threads so replies flush before exit.
        eprintln!("draining: stopped accepting; waiting up to {drain_ms} ms for in-flight work");
        server.begin_drain();
        svc.shutdown();
        let join_window = Duration::from_millis(drain_ms).max(Duration::from_secs(1));
        if !server.join(join_window) {
            eprintln!("drain: some connection threads did not exit in time; detaching them");
        }
        eprintln!("pas server stopped");
        Ok(())
    }
    #[cfg(not(unix))]
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

/// One round trip against a running `pas serve`. With `--cmd` this sends
/// an admin command (`status`/`metrics`/`health`/`rollback`); otherwise a
/// sampling request built from the flags. A reply carrying a `"text"`
/// string field (the metrics page) is printed decoded — the operator
/// wants the exposition text, not a JSON-escaped blob.
fn cmd_client(args: &Args) -> Result<(), String> {
    use std::io::{BufRead, BufReader, Write};
    let addr = args.get("addr").unwrap_or("127.0.0.1:7777");
    let mut req = Json::obj();
    if let Some(cmd) = args.get("cmd") {
        req.set("cmd", Json::Str(cmd.into()));
        if cmd == "rollback" {
            req.set("dataset", Json::Str(args.get("dataset").unwrap_or("gmm-hd64").into()))
                .set("solver", Json::Str(args.get("solver").unwrap_or("ddim").into()))
                .set("nfe", Json::Num(args.get_usize("nfe", 10) as f64));
        }
    } else {
        req.set("dataset", Json::Str(args.get("dataset").unwrap_or("gmm-hd64").into()))
            .set("solver", Json::Str(args.get("solver").unwrap_or("ddim").into()))
            .set("nfe", Json::Num(args.get_usize("nfe", 10) as f64))
            .set("n", Json::Num(args.get_usize("n", 4) as f64))
            .set("seed", Json::Num(args.get_usize("seed", 0) as f64));
        if args.has("pas") {
            req.set("pas", Json::Bool(true));
        }
        if let Some(d) = args.get("deadline-ms") {
            let d: f64 = d.parse().map_err(|_| "--deadline-ms must be a number")?;
            req.set("deadline_ms", Json::Num(d));
        }
        if let Some(p) = args.get("priority") {
            let p: i64 = p.parse().map_err(|_| "--priority must be an integer")?;
            req.set("priority", Json::Num(p as f64));
        }
    }
    let mut conn = std::net::TcpStream::connect(addr).map_err(|e| e.to_string())?;
    conn.write_all(format!("{}\n", req.to_string()).as_bytes())
        .map_err(|e| e.to_string())?;
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    reader.read_line(&mut line).map_err(|e| e.to_string())?;
    let decoded_text = Json::parse(line.trim())
        .ok()
        .and_then(|j| j.get("text").and_then(|t| t.as_str()).map(String::from));
    match decoded_text {
        Some(text) => print!("{text}"),
        None => println!("{}", line.trim()),
    }
    Ok(())
}

/// Operator surface over the durable dict store ([`crate::artifact`]).
/// `verify` and `load` communicate through the exit code so CI and deploy
/// scripts can gate on store health: `verify` is read-only diagnosis
/// (exit 1 on any corrupt record), `load` is the healing counterpart
/// (quarantines corrupt blobs, falls back to the last good version,
/// persists the demotion; exit 1 only when a key has no usable version).
fn cmd_artifact(args: &Args) -> Result<(), String> {
    use crate::artifact::{self, ArtifactKey, ArtifactStore};
    let sub = args
        .positional
        .get(1)
        .map(|s| s.as_str())
        .ok_or("usage: pas artifact <list|publish|verify|load|rollback> --store DIR")?;
    let store_dir = args.get("store").ok_or("need --store DIR")?;
    let mut store = ArtifactStore::open(&PathBuf::from(store_dir))?;
    match sub {
        "list" => {
            let (manifest, source) = store.load_manifest();
            println!(
                "{}: generation {} ({:?}), {} key(s)",
                store_dir,
                manifest.generation,
                source,
                manifest.entries.len()
            );
            for (id, e) in &manifest.entries {
                println!(
                    "  {id:<28} v{:<3} {}  ({} retained)",
                    e.current.version,
                    e.current.checksum,
                    e.history.len()
                );
            }
            Ok(())
        }
        "publish" => {
            let coords = args.get("coords").ok_or("need --coords f.json")?;
            let dict = CoordinateDict::load(&PathBuf::from(coords))?;
            // The serving key defaults to the dict's own fields but can be
            // overridden — for multi-eval solvers the requested NFE (the
            // serving key) differs from the dict's solver-step count.
            let dataset = args
                .get("dataset")
                .map(str::to_string)
                .unwrap_or_else(|| dict.dataset.clone());
            let solver = args
                .get("solver")
                .map(str::to_string)
                .unwrap_or_else(|| dict.solver.clone());
            let nfe = args.get_usize("nfe", dict.nfe);
            let key = ArtifactKey::new(&dataset, &solver, nfe);
            let out = store.publish(&key, &dict)?;
            println!(
                "published {} v{} checksum {}{}",
                key.id(),
                out.version,
                out.checksum,
                if out.deduplicated { " (deduplicated, already current)" } else { "" }
            );
            Ok(())
        }
        "verify" => {
            let rep = artifact::verify(&store);
            println!(
                "checked {} record(s), generation {} ({:?})",
                rep.checked, rep.generation, rep.source
            );
            for e in &rep.errors {
                eprintln!("  BAD {e}");
            }
            if rep.ok() {
                println!("store OK");
                Ok(())
            } else {
                Err(format!("{} corrupt record(s)", rep.errors.len()))
            }
        }
        "load" => {
            let rep = artifact::load_all(&mut store);
            for l in &rep.loaded {
                println!(
                    "  {} v{} ({} params){}",
                    l.key.id(),
                    l.version,
                    l.dict.n_params(),
                    if l.healed { "  [healed]" } else { "" }
                );
            }
            for (k, why) in &rep.failed {
                eprintln!("  FAILED {}: {why}", k.id());
            }
            println!(
                "loaded {} dict(s), {} unusable",
                rep.loaded.len(),
                rep.failed.len()
            );
            if rep.failed.is_empty() {
                Ok(())
            } else {
                Err(format!("{} key(s) have no usable version", rep.failed.len()))
            }
        }
        "rollback" => {
            let dataset = args.get("dataset").ok_or("need --dataset")?;
            let solver = args.get("solver").ok_or("need --solver")?;
            let nfe = args
                .get("nfe")
                .and_then(|v| v.parse().ok())
                .ok_or("need --nfe N")?;
            let key = ArtifactKey::new(dataset, solver, nfe);
            let rec = store.rollback(&key)?;
            println!("rolled {} back to v{} ({})", key.id(), rec.version, rec.checksum);
            Ok(())
        }
        other => Err(format!(
            "unknown artifact subcommand {other}\n\
             usage: pas artifact <list|publish|verify|load|rollback> --store DIR"
        )),
    }
}

/// Export dataset samples for the build-time Python denoiser training
/// (little-endian f32 `.bin` + `.meta.json`). The data distribution is
/// *defined* in rust; Python only consumes it.
fn cmd_dump_data(args: &Args) -> Result<(), String> {
    let dataset = args.get("dataset").ok_or("need --dataset")?;
    let n = args.get_usize("n", 20_000);
    let seed = args.get_usize("seed", 0) as u64;
    let out = args.get("out").ok_or("need --out (path prefix)")?;
    let ds = crate::data::registry::get(dataset).ok_or_else(|| format!("unknown dataset {dataset}"))?;
    let mut rng = Pcg64::seed_stream(seed, 0xda7a);
    let x = ds.spec.sample(&mut rng, n);
    let mut bytes = Vec::with_capacity(x.len() * 4);
    for v in &x {
        bytes.extend_from_slice(&(*v as f32).to_le_bytes());
    }
    let prefix = PathBuf::from(out);
    if let Some(dir) = prefix.parent() {
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
    }
    std::fs::write(prefix.with_extension("bin"), &bytes).map_err(|e| e.to_string())?;
    let mut meta = Json::obj();
    meta.set("dataset", Json::Str(dataset.into()))
        .set("n", Json::Num(n as f64))
        .set("dim", Json::Num(ds.dim() as f64))
        .set("seed", Json::Num(seed as f64));
    std::fs::write(prefix.with_extension("meta.json"), meta.to_string()).map_err(|e| e.to_string())?;
    println!("wrote {} samples of {dataset} (dim {}) to {out}.bin", n, ds.dim());
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_pjrt_check(_args: &Args) -> Result<(), String> {
    Err("this binary was built without the `pjrt` feature; rebuild with \
         `--features pjrt` (requires the vendored xla crate)"
        .into())
}

#[cfg(feature = "pjrt")]
fn cmd_pjrt_check(args: &Args) -> Result<(), String> {
    let dir = args
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(crate::runtime::artifacts_dir);
    let name = args.get("name").unwrap_or("eps_spiral2d");
    let rt = crate::runtime::Runtime::cpu().map_err(|e| format!("{e:#}"))?;
    println!("PJRT platform: {}", rt.platform());
    let exe = rt.load_artifact(&dir, name).map_err(|e| format!("{e:#}"))?;
    let (b, d) = (exe.meta.batch, exe.meta.dim);
    println!("loaded {name}: batch={b} dim={d} dataset={}", exe.meta.dataset);
    let x = vec![0.5f32; b * d];
    let t = vec![1.0f32; b];
    let y = exe.eval_eps(&x, &t).map_err(|e| format!("{e:#}"))?;
    let finite = y.iter().all(|v| v.is_finite());
    println!(
        "executed: out len={} finite={finite} first={:?}",
        y.len(),
        &y[..d.min(4)]
    );
    if !finite {
        return Err("non-finite output".into());
    }
    println!("pjrt-check OK");
    Ok(())
}

/// `pas lint`: run the source-contract checks (see `crate::analysis`).
/// Exits nonzero iff findings exist. Writes `LINT_report.json` next to
/// the crate root unless `--no-report`.
fn cmd_lint(args: &Args) -> Result<(), String> {
    let root = match args.get("root") {
        Some(r) => PathBuf::from(r),
        None => discover_crate_root()?,
    };
    if !root.join("Cargo.toml").is_file() || !root.join("src").is_dir() {
        return Err(format!(
            "{} is not a crate root (need Cargo.toml and src/); pass --root",
            root.display()
        ));
    }
    let report = crate::analysis::run_lint(&root);

    if !args.has("no-report") {
        let path = args
            .get("report")
            .map(PathBuf::from)
            .unwrap_or_else(|| root.join("LINT_report.json"));
        std::fs::write(&path, report.to_json().to_string())
            .map_err(|e| format!("write {}: {e}", path.display()))?;
        if !args.has("json") {
            println!("report: {}", path.display());
        }
    }

    if args.has("json") {
        let rendered = report.to_json().to_string();
        println!("{rendered}");
    } else {
        for f in &report.findings {
            println!("{f}");
        }
        for s in &report.malformed {
            println!(
                "malformed-suppression {}:{} lint:allow({}) is missing a reason",
                s.file, s.line, s.rule
            );
        }
        for s in report.suppressions.iter().filter(|s| !s.used) {
            println!(
                "unused-suppression {}:{} lint:allow({}, {})",
                s.file, s.line, s.rule, s.reason
            );
        }
        let suppressed: usize = report.rules.iter().map(|r| r.suppressed).sum();
        let sites: usize = report.rules.iter().map(|r| r.sites_scanned).sum();
        println!(
            "pas lint: {} findings, {} suppressed, {} sites across {} files",
            report.findings.len(),
            suppressed,
            sites,
            report.files_scanned
        );
    }
    if report.clean() {
        Ok(())
    } else {
        Err(format!("{} lint finding(s)", report.findings.len()))
    }
}

/// Find the crate root: `./Cargo.toml + ./src`, else `./rust/…` (repo
/// root invocation), else walk up from the current directory.
fn discover_crate_root() -> Result<PathBuf, String> {
    let cwd = std::env::current_dir().map_err(|e| e.to_string())?;
    let mut candidates = vec![cwd.clone(), cwd.join("rust")];
    let mut up = cwd.as_path();
    while let Some(parent) = up.parent() {
        candidates.push(parent.to_path_buf());
        candidates.push(parent.join("rust"));
        up = parent;
    }
    candidates
        .into_iter()
        .find(|c| c.join("Cargo.toml").is_file() && c.join("src").is_dir())
        .ok_or_else(|| "no crate root found; pass --root DIR".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn args_parse_flags_and_positionals() {
        let argv: Vec<String> = ["repro", "fig2", "--quick", "--n-samples", "64"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let a = Args::parse(&argv);
        assert_eq!(a.positional, vec!["repro", "fig2"]);
        assert!(a.has("quick"));
        assert_eq!(a.get_usize("n-samples", 0), 64);
    }

    #[test]
    fn list_runs() {
        assert!(cmd_list().is_ok());
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(main(vec!["frobnicate".into()]), 1);
    }
}
