//! On-disk layout and write path of the artifact store.
//!
//! Everything durable goes through [`write_atomic`]: temp file in the
//! same directory, `fsync`, atomic rename over the target, `fsync` of the
//! parent directory. Blobs are content-addressed (file name = FNV-1a 64
//! checksum of the bytes), so a blob write is idempotent and two
//! publishes of identical content share one file. The manifest publish
//! protocol on top (demote current to `manifest.prev.json`, then rename
//! the new generation into place) is documented on [`super`].

use super::manifest::{ArtifactKey, Manifest, ManifestSource, VersionRecord};
use crate::pas::coords::CoordinateDict;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Older versions retained per key for rollback/fallback. Oldest records
/// beyond this are dropped from the manifest (their blobs stay on disk —
/// a dict blob is a few hundred bytes, and content-addressing means they
/// can be shared; nothing ever deletes a blob except quarantine's move).
pub const HISTORY_KEEP: usize = 8;

/// 64-bit FNV-1a over `bytes` — the store's integrity checksum. Not
/// cryptographic (the threat model is torn writes and bit rot, not an
/// adversary); dependency-free and byte-order independent.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    const BASIS: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = BASIS;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    h
}

/// Checksum as the fixed-width lower-hex string used for blob file names
/// and manifest records.
pub fn checksum_hex(bytes: &[u8]) -> String {
    format!("{:016x}", fnv1a64(bytes))
}

/// Simulated crash sites in the write path, for fault-injection tests.
/// Injected via [`ArtifactStore::inject_failpoint`]; the next write that
/// reaches the site returns an error *without executing the rest of the
/// protocol* — exactly the state a `kill -9` at that instant leaves.
/// Backed by the store-instance-scoped one-shot set in
/// [`crate::util::failpoint`] (the same infrastructure the serving-path
/// chaos suite arms globally).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailPoint {
    /// Blob temp file written + synced; crash before the rename makes it
    /// live. Leaves an orphaned temp file, no visible blob, old manifest.
    BlobBeforeRename,
    /// New manifest temp written + synced; crash before anything is
    /// renamed. Old `manifest.json` still live — the publish never
    /// happened (the new blob is an orphan).
    ManifestBeforeRename,
    /// Crash after `manifest.json` was demoted to `manifest.prev.json`
    /// but before the new generation was renamed into place: the classic
    /// torn-manifest window. No `manifest.json` exists; the loader must
    /// recover from the previous generation.
    ManifestBetweenRenames,
}

impl FailPoint {
    fn site(self) -> &'static str {
        match self {
            FailPoint::BlobBeforeRename => "artifact.blob_before_rename",
            FailPoint::ManifestBeforeRename => "artifact.manifest_before_rename",
            FailPoint::ManifestBetweenRenames => "artifact.manifest_between_renames",
        }
    }
}

/// Unique-ish suffix counter for temp files (plus the pid, so two test
/// processes sharing a tree cannot collide).
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

fn tmp_sibling(path: &Path) -> PathBuf {
    let file = path
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "file".to_string());
    let n = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    path.with_file_name(format!("{file}.tmp.{}.{n}", std::process::id()))
}

fn sync_dir(dir: &Path) {
    // Directory fsync is best-effort (not all filesystems support it);
    // the rename itself is what provides atomicity.
    if let Ok(d) = std::fs::File::open(dir) {
        let _ = d.sync_all();
    }
}

/// Write `bytes` to `path` durably and atomically: temp sibling → write →
/// `fsync` → rename → parent-dir `fsync`. A crash leaves either the old
/// file or the new one, never a torn mix. Creates parent directories.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = tmp_sibling(path);
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e);
    }
    if let Some(dir) = path.parent() {
        sync_dir(dir);
    }
    Ok(())
}

/// What [`ArtifactStore::publish`] did.
#[derive(Clone, Debug)]
pub struct PublishOutcome {
    /// The key's now-current version.
    pub version: u64,
    /// Checksum (= blob name) of the published content.
    pub checksum: String,
    /// True when the key's current version already had byte-identical
    /// content: nothing was written, no version was consumed.
    pub deduplicated: bool,
}

/// Handle on one artifact store directory. See [`super`] for the layout
/// and durability protocol. Methods taking `&mut self` are the write
/// path; callers serialize writers per directory (the server wraps the
/// store in a `Mutex`).
pub struct ArtifactStore {
    root: PathBuf,
    fail: crate::util::failpoint::FailPoints,
}

impl ArtifactStore {
    /// Open (creating if absent) the store at `root`. A missing or empty
    /// directory is a clean cold start. Sweeps `*.tmp.*` orphans left by
    /// crashed writers — they were never renamed live, so removing them
    /// is always safe.
    pub fn open(root: &Path) -> Result<ArtifactStore, String> {
        for sub in ["blobs", "quarantine"] {
            std::fs::create_dir_all(root.join(sub))
                .map_err(|e| format!("artifact store {}: {e}", root.display()))?;
        }
        let store = ArtifactStore {
            root: root.to_path_buf(),
            fail: crate::util::failpoint::FailPoints::new(),
        };
        store.sweep_tmp(&store.root);
        store.sweep_tmp(&store.root.join("blobs"));
        Ok(store)
    }

    fn sweep_tmp(&self, dir: &Path) {
        let Ok(entries) = std::fs::read_dir(dir) else {
            return;
        };
        for e in entries.flatten() {
            let name = e.file_name();
            if name.to_string_lossy().contains(".tmp.") {
                let _ = std::fs::remove_file(e.path());
            }
        }
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn blob_path(&self, checksum: &str) -> PathBuf {
        self.root.join("blobs").join(format!("{checksum}.json"))
    }

    pub fn quarantine_path(&self, checksum: &str) -> PathBuf {
        self.root.join("quarantine").join(format!("{checksum}.json"))
    }

    fn manifest_path(&self) -> PathBuf {
        self.root.join("manifest.json")
    }

    fn manifest_prev_path(&self) -> PathBuf {
        self.root.join("manifest.prev.json")
    }

    /// Arm a one-shot simulated crash at `fp`; the next write reaching
    /// that site errors out mid-protocol. Test-only by intent, but always
    /// compiled: the fault-injection suite runs against the exact
    /// production write path, not a test double.
    pub fn inject_failpoint(&mut self, fp: FailPoint) {
        self.fail.arm(fp.site());
    }

    /// Fire (and disarm) the injected failpoint if it matches this site.
    fn crash_if_armed(&mut self, fp: FailPoint) -> Result<(), String> {
        if self.fail.take(fp.site()).is_some() {
            return Err(format!("injected crash at {fp:?}"));
        }
        Ok(())
    }

    /// Atomic write with a simulated-crash site between the synced temp
    /// file and the rename. On a (real or injected) failure the target is
    /// untouched.
    fn write_atomic_at(
        &mut self,
        path: &Path,
        bytes: &[u8],
        fp: FailPoint,
    ) -> Result<(), String> {
        let tmp = tmp_sibling(path);
        let write = || -> std::io::Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(bytes)?;
            f.sync_all()?;
            Ok(())
        };
        write().map_err(|e| format!("write {}: {e}", tmp.display()))?;
        // Simulated kill: the temp file stays behind (as it would after a
        // real crash) for `open`'s sweep to collect.
        self.crash_if_armed(fp)?;
        std::fs::rename(&tmp, path).map_err(|e| format!("rename to {}: {e}", path.display()))?;
        if let Some(dir) = path.parent() {
            sync_dir(dir);
        }
        Ok(())
    }

    /// Write `bytes` as a content-addressed blob; returns its checksum
    /// (= file name). Idempotent: identical content lands on the same
    /// path, and the rename makes the last writer win with identical
    /// bytes.
    pub fn write_blob(&mut self, bytes: &[u8]) -> Result<String, String> {
        let sum = checksum_hex(bytes);
        let path = self.blob_path(&sum);
        self.write_atomic_at(&path, bytes, FailPoint::BlobBeforeRename)?;
        Ok(sum)
    }

    /// Read a blob and verify its content against `checksum`. `Ok(None)`
    /// when the file is missing; `Err` distinguishes corruption (checksum
    /// mismatch) so callers can quarantine.
    pub fn read_blob(&self, checksum: &str) -> Result<Option<Vec<u8>>, String> {
        let path = self.blob_path(checksum);
        let bytes = match std::fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(format!("read {}: {e}", path.display())),
        };
        let actual = checksum_hex(&bytes);
        if actual != checksum {
            return Err(format!(
                "blob {checksum} corrupt: content hashes to {actual}"
            ));
        }
        Ok(Some(bytes))
    }

    /// Move a blob into `quarantine/` for post-mortem instead of deleting
    /// it. Returns whether a file was actually moved.
    pub fn quarantine_blob(&self, checksum: &str) -> bool {
        let from = self.blob_path(checksum);
        let to = self.quarantine_path(checksum);
        let moved = std::fs::rename(&from, &to).is_ok();
        if moved {
            sync_dir(&self.root.join("blobs"));
            sync_dir(&self.root.join("quarantine"));
        }
        moved
    }

    /// Load the manifest, falling back per the recovery ladder: a
    /// missing, torn, or checksum-failing `manifest.json` falls back to
    /// `manifest.prev.json`; if both are unusable the store cold-starts
    /// empty. Never errors, never panics — the worst corruption costs one
    /// generation, not availability.
    pub fn load_manifest(&self) -> (Manifest, ManifestSource) {
        match read_manifest_file(&self.manifest_path()) {
            Some(m) => (m, ManifestSource::Current),
            None => match read_manifest_file(&self.manifest_prev_path()) {
                Some(m) => (m, ManifestSource::Previous),
                None => (Manifest::default(), ManifestSource::Empty),
            },
        }
    }

    /// Publish `manifest` as the next live generation. When
    /// `demote_current` (the live `manifest.json` was readable), it is
    /// first renamed to `manifest.prev.json` so the previous generation
    /// stays recoverable; a torn current is deleted instead, preserving
    /// the good `manifest.prev.json` it was recovered from.
    pub fn write_manifest(
        &mut self,
        manifest: &Manifest,
        demote_current: bool,
    ) -> Result<(), String> {
        let bytes = manifest.serialize().into_bytes();
        let tmp = tmp_sibling(&self.manifest_path());
        let write = || -> std::io::Result<()> {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&bytes)?;
            f.sync_all()?;
            Ok(())
        };
        write().map_err(|e| format!("write {}: {e}", tmp.display()))?;
        self.crash_if_armed(FailPoint::ManifestBeforeRename)?;
        let cur = self.manifest_path();
        if cur.exists() {
            if demote_current {
                std::fs::rename(&cur, self.manifest_prev_path())
                    .map_err(|e| format!("demote manifest: {e}"))?;
            } else {
                // The current manifest is torn; renaming it over the good
                // previous generation would destroy the recovery copy.
                std::fs::remove_file(&cur).map_err(|e| format!("drop torn manifest: {e}"))?;
            }
        }
        self.crash_if_armed(FailPoint::ManifestBetweenRenames)?;
        std::fs::rename(&tmp, &cur).map_err(|e| format!("rename manifest: {e}"))?;
        sync_dir(&self.root);
        Ok(())
    }

    /// Publish `dict` as the new current version of `key`: write the blob
    /// (content-addressed, atomic), then publish a new manifest
    /// generation whose entry for `key` bumps the version and retains the
    /// old current in `history` (up to [`HISTORY_KEEP`]). Re-publishing
    /// byte-identical content is a no-op ([`PublishOutcome::deduplicated`]).
    ///
    /// The key is explicit — not derived from the dict — because serving
    /// keys use the *requested* NFE while `dict.nfe` records solver
    /// steps; the two differ for multi-eval solvers.
    pub fn publish(
        &mut self,
        key: &ArtifactKey,
        dict: &CoordinateDict,
    ) -> Result<PublishOutcome, String> {
        let bytes = dict.to_json().to_string().into_bytes();
        let sum = checksum_hex(&bytes);
        let (mut manifest, source) = self.load_manifest();
        if let Some(entry) = manifest.entries.get(&key.id()) {
            if entry.current.checksum == sum {
                return Ok(PublishOutcome {
                    version: entry.current.version,
                    checksum: sum,
                    deduplicated: true,
                });
            }
        }
        let written = self.write_blob(&bytes)?;
        debug_assert_eq!(written, sum);
        let entry = manifest.entry_mut(key);
        let version = if entry.current.version == 0 {
            1
        } else {
            let old = entry.current.clone();
            entry.history.push(old);
            if entry.history.len() > HISTORY_KEEP {
                let drop_n = entry.history.len() - HISTORY_KEEP;
                entry.history.drain(..drop_n);
            }
            entry.current.version + 1
        };
        entry.current = VersionRecord {
            version,
            checksum: sum.clone(),
        };
        manifest.generation += 1;
        self.write_manifest(&manifest, source == ManifestSource::Current)?;
        Ok(PublishOutcome {
            version,
            checksum: sum,
            deduplicated: false,
        })
    }

    /// Roll `key` back to its newest retained previous version: the
    /// current record is dropped from the manifest (its blob stays on
    /// disk), the newest history record becomes current, and a new
    /// manifest generation is published atomically. Errors when the key
    /// is unknown or has no retained history.
    pub fn rollback(&mut self, key: &ArtifactKey) -> Result<VersionRecord, String> {
        let (mut manifest, source) = self.load_manifest();
        let entry = manifest
            .entries
            .get_mut(&key.id())
            .ok_or_else(|| format!("no artifact for {}", key.id()))?;
        let prev = entry
            .history
            .pop()
            .ok_or_else(|| format!("{}: no previous version to roll back to", key.id()))?;
        entry.current = prev.clone();
        manifest.generation += 1;
        self.write_manifest(&manifest, source == ManifestSource::Current)?;
        Ok(prev)
    }
}

fn read_manifest_file(path: &Path) -> Option<Manifest> {
    let s = std::fs::read_to_string(path).ok()?;
    match Manifest::parse(&s) {
        Ok(m) => Some(m),
        Err(e) => {
            crate::warn_!("unusable manifest {}: {e}", path.display());
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unique_dir(tag: &str) -> PathBuf {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "pas_store_{tag}_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn fnv1a64_reference_vectors() {
        // Published FNV-1a 64 test vectors: the empty string hashes to
        // the offset basis; "a" to 0xaf63dc4c8601ec8c.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(checksum_hex(b"a"), "af63dc4c8601ec8c");
        assert_ne!(fnv1a64(b"ab"), fnv1a64(b"ba"));
    }

    #[test]
    fn write_atomic_replaces_and_survives() {
        let dir = unique_dir("atomic");
        let path = dir.join("f.json");
        write_atomic(&path, b"one").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"one");
        write_atomic(&path, b"two").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"two");
        // No temp litter after successful writes.
        let litter: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .flatten()
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(litter.is_empty(), "{litter:?}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn open_sweeps_orphaned_tmp_files() {
        let dir = unique_dir("sweep");
        std::fs::create_dir_all(dir.join("blobs")).unwrap();
        std::fs::write(dir.join("manifest.json.tmp.1.2"), b"orphan").unwrap();
        std::fs::write(dir.join("blobs/aa.json.tmp.3.4"), b"orphan").unwrap();
        let _store = ArtifactStore::open(&dir).unwrap();
        assert!(!dir.join("manifest.json.tmp.1.2").exists());
        assert!(!dir.join("blobs/aa.json.tmp.3.4").exists());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn blob_roundtrip_and_corruption_detection() {
        let dir = unique_dir("blob");
        let mut store = ArtifactStore::open(&dir).unwrap();
        let sum = store.write_blob(b"{\"x\":1}").unwrap();
        assert_eq!(store.read_blob(&sum).unwrap().unwrap(), b"{\"x\":1}");
        assert_eq!(store.read_blob("0000000000000000").unwrap(), None);
        // Flip a byte in place: the checksum no longer matches the name.
        std::fs::write(store.blob_path(&sum), b"{\"x\":2}").unwrap();
        assert!(store.read_blob(&sum).is_err());
        assert!(store.quarantine_blob(&sum));
        assert!(store.quarantine_path(&sum).exists());
        assert_eq!(store.read_blob(&sum).unwrap(), None, "moved aside");
        let _ = std::fs::remove_dir_all(dir);
    }
}
