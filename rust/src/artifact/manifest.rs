//! Versioned, self-checksummed manifest: the store's source of truth.
//!
//! `manifest.json` maps artifact keys to their current [`VersionRecord`]
//! plus retained history, under a **monotonically increasing
//! `generation`** bumped by every publish/rollback/heal. The serialized
//! form embeds a checksum of its own body, so a torn write is detected at
//! parse time (and the loader falls back to `manifest.prev.json`). The
//! JSON writer is canonical (sorted object keys, integer tokens), so
//! serialize → parse → serialize is byte-stable and the self-checksum is
//! well-defined.

use super::store::checksum_hex;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Manifest format version — bump on incompatible layout changes.
pub const FORMAT: u64 = 1;

/// Identity of one artifact slot: the serving-registry key. `nfe` is the
/// *requested* NFE (the serving key), which for multi-eval solvers
/// differs from the solver-step count a dict's own `nfe` field records.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct ArtifactKey {
    pub dataset: String,
    pub solver: String,
    pub nfe: usize,
}

impl ArtifactKey {
    pub fn new(dataset: &str, solver: &str, nfe: usize) -> ArtifactKey {
        ArtifactKey {
            dataset: dataset.to_string(),
            solver: solver.to_string(),
            nfe,
        }
    }

    /// Manifest map key, `dataset/solver/nfe`.
    pub fn id(&self) -> String {
        format!("{}/{}/{}", self.dataset, self.solver, self.nfe)
    }
}

/// One published version of one key: its number and blob checksum.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VersionRecord {
    /// Per-key version, starting at 1 and strictly increasing.
    pub version: u64,
    /// Blob checksum (= blob file name, sans extension).
    pub checksum: String,
}

/// Manifest entry for one key: the current version plus retained older
/// versions (oldest first) available for rollback/fallback.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ManifestEntry {
    pub key: ArtifactKey,
    pub current: VersionRecord,
    pub history: Vec<VersionRecord>,
}

/// Which file the manifest was loaded from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ManifestSource {
    /// `manifest.json`, the healthy case.
    Current,
    /// `manifest.json` was missing or torn; recovered from
    /// `manifest.prev.json` (one generation old).
    Previous,
    /// Neither file was usable: clean cold start.
    Empty,
}

/// In-memory manifest. `Default` is the empty generation-0 store.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Manifest {
    pub generation: u64,
    /// [`ArtifactKey::id`] → entry. BTreeMap for canonical serialization.
    pub entries: BTreeMap<String, ManifestEntry>,
}

impl Manifest {
    /// Entry for `key`, created empty (version-0 sentinel current) if
    /// absent — `publish` replaces the sentinel before writing, and
    /// `parse` rejects version 0, so a sentinel can never be persisted.
    pub fn entry_mut(&mut self, key: &ArtifactKey) -> &mut ManifestEntry {
        self.entries
            .entry(key.id())
            .or_insert_with(|| ManifestEntry {
                key: key.clone(),
                current: VersionRecord {
                    version: 0,
                    checksum: String::new(),
                },
                history: Vec::new(),
            })
    }

    pub fn get(&self, key: &ArtifactKey) -> Option<&ManifestEntry> {
        self.entries.get(&key.id())
    }

    fn body_json(&self) -> Json {
        let mut entries = Json::obj();
        for (id, e) in &self.entries {
            let mut o = Json::obj();
            o.set("dataset", Json::Str(e.key.dataset.clone()))
                .set("solver", Json::Str(e.key.solver.clone()))
                .set("nfe", Json::UInt(e.key.nfe as u64))
                .set("current", record_json(&e.current))
                .set(
                    "history",
                    Json::Arr(e.history.iter().map(record_json).collect()),
                );
            entries.set(id, o);
        }
        let mut o = Json::obj();
        o.set("format", Json::UInt(FORMAT))
            .set("generation", Json::UInt(self.generation))
            .set("entries", entries);
        o
    }

    /// Canonical serialization with the embedded self-checksum.
    pub fn serialize(&self) -> String {
        let mut j = self.body_json();
        let sum = checksum_hex(self.body_json().to_string().as_bytes());
        j.set("checksum", Json::Str(sum));
        j.to_string()
    }

    /// Parse and fully validate a serialized manifest: the embedded
    /// checksum must match the body (torn-write detection), and every
    /// entry must be internally consistent (id matches its key fields,
    /// versions start at 1, history strictly ascending below current).
    pub fn parse(s: &str) -> Result<Manifest, String> {
        let mut j = Json::parse(s)?;
        let declared = j
            .take("checksum")
            .and_then(|v| v.as_str().map(|s| s.to_string()))
            .ok_or("manifest missing checksum")?;
        let actual = checksum_hex(j.to_string().as_bytes());
        if actual != declared {
            return Err(format!(
                "manifest checksum mismatch: declared {declared}, body hashes to {actual} (torn write?)"
            ));
        }
        let format = j
            .get("format")
            .and_then(|v| v.as_u64())
            .ok_or("manifest missing format")?;
        if format != FORMAT {
            return Err(format!("unsupported manifest format {format}"));
        }
        let generation = j
            .get("generation")
            .and_then(|v| v.as_u64())
            .ok_or("manifest missing generation")?;
        let mut entries = BTreeMap::new();
        if let Some(em) = j.get("entries") {
            let em = em.as_obj().ok_or("manifest entries must be an object")?;
            for (id, v) in em {
                let entry = parse_entry(id, v)?;
                entries.insert(id.clone(), entry);
            }
        }
        Ok(Manifest {
            generation,
            entries,
        })
    }
}

fn record_json(r: &VersionRecord) -> Json {
    let mut o = Json::obj();
    o.set("version", Json::UInt(r.version))
        .set("checksum", Json::Str(r.checksum.clone()));
    o
}

fn parse_record(j: &Json, what: &str) -> Result<VersionRecord, String> {
    let version = j
        .get("version")
        .and_then(|v| v.as_u64())
        .ok_or_else(|| format!("{what}: missing version"))?;
    if version == 0 {
        return Err(format!("{what}: version 0 is invalid"));
    }
    let checksum = j
        .get("checksum")
        .and_then(|v| v.as_str())
        .ok_or_else(|| format!("{what}: missing checksum"))?
        .to_string();
    if checksum.len() != 16 || !checksum.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(format!("{what}: malformed checksum \"{checksum}\""));
    }
    Ok(VersionRecord { version, checksum })
}

fn parse_entry(id: &str, j: &Json) -> Result<ManifestEntry, String> {
    let dataset = j
        .get("dataset")
        .and_then(|v| v.as_str())
        .ok_or_else(|| format!("entry {id}: missing dataset"))?;
    let solver = j
        .get("solver")
        .and_then(|v| v.as_str())
        .ok_or_else(|| format!("entry {id}: missing solver"))?;
    let nfe = j
        .get("nfe")
        .and_then(|v| v.as_usize())
        .ok_or_else(|| format!("entry {id}: missing nfe"))?;
    let key = ArtifactKey::new(dataset, solver, nfe);
    if key.id() != id {
        return Err(format!("entry {id}: key fields disagree ({})", key.id()));
    }
    let current = parse_record(
        j.get("current").ok_or_else(|| format!("entry {id}: missing current"))?,
        &format!("entry {id} current"),
    )?;
    let mut history = Vec::new();
    if let Some(h) = j.get("history") {
        for (k, r) in h
            .as_arr()
            .ok_or_else(|| format!("entry {id}: history must be an array"))?
            .iter()
            .enumerate()
        {
            history.push(parse_record(r, &format!("entry {id} history[{k}]"))?);
        }
    }
    let mut last = 0u64;
    for r in &history {
        if r.version <= last {
            return Err(format!("entry {id}: history versions not ascending"));
        }
        last = r.version;
    }
    if current.version <= last {
        return Err(format!(
            "entry {id}: current version {} not above history",
            current.version
        ));
    }
    Ok(ManifestEntry {
        key,
        current,
        history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        let mut m = Manifest::default();
        let key = ArtifactKey::new("gmm2d", "ddim", 10);
        let e = m.entry_mut(&key);
        e.current = VersionRecord {
            version: 2,
            checksum: "00112233445566aa".into(),
        };
        e.history.push(VersionRecord {
            version: 1,
            checksum: "ffeeddccbbaa0099".into(),
        });
        m.generation = 2;
        m
    }

    #[test]
    fn serialize_parse_roundtrip_is_byte_stable() {
        let m = sample();
        let s = m.serialize();
        let back = Manifest::parse(&s).unwrap();
        assert_eq!(back, m);
        assert_eq!(back.serialize(), s, "canonical form must be stable");
        let e = back.get(&ArtifactKey::new("gmm2d", "ddim", 10)).unwrap();
        assert_eq!(e.current.version, 2);
        assert_eq!(e.history.len(), 1);
    }

    #[test]
    fn tampered_or_torn_manifest_is_rejected() {
        let s = sample().serialize();
        // Torn tail.
        assert!(Manifest::parse(&s[..s.len() / 2]).is_err());
        // Bit flip in the body breaks the self-checksum.
        let flipped = s.replace("\"generation\":2", "\"generation\":3");
        assert_ne!(flipped, s);
        let e = Manifest::parse(&flipped).unwrap_err();
        assert!(e.contains("checksum mismatch"), "{e}");
        // Missing checksum field.
        assert!(Manifest::parse("{\"format\":1,\"generation\":0}").is_err());
    }

    #[test]
    fn invalid_entries_are_rejected() {
        let mut m = sample();
        // Version 0 sentinel must never persist.
        m.entry_mut(&ArtifactKey::new("gmm2d", "heun", 8));
        assert!(Manifest::parse(&m.serialize()).is_err());

        let mut m = sample();
        // Non-ascending history.
        let key = ArtifactKey::new("gmm2d", "ddim", 10);
        m.entry_mut(&key).history.push(VersionRecord {
            version: 1,
            checksum: "ffeeddccbbaa0099".into(),
        });
        assert!(Manifest::parse(&m.serialize()).is_err());

        // Current must sit above history.
        let mut m = sample();
        m.entry_mut(&key).current.version = 1;
        assert!(Manifest::parse(&m.serialize()).is_err());
    }

    #[test]
    fn key_id_roundtrip() {
        let k = ArtifactKey::new("gmm-hd64", "dpmpp3m", 12);
        assert_eq!(k.id(), "gmm-hd64/dpmpp3m/12");
    }
}
