//! Verified read side of the artifact store: checksum + semantic
//! validation on every load, quarantine of corrupt blobs, and fallback to
//! the newest remaining good version ("heal") instead of panicking.
//!
//! The loader's contract mirrors serving's availability bias: corruption
//! costs versions, never the process. A key whose every retained version
//! is corrupt simply loads nothing — serving cold-starts that key
//! uncorrected — and the corrupt blobs sit in `quarantine/` for
//! post-mortem. Healing persists a new manifest generation so a
//! subsequent [`verify`] converges back to clean.

use super::manifest::{ArtifactKey, ManifestEntry, ManifestSource, VersionRecord};
use super::store::ArtifactStore;
use crate::pas::coords::CoordinateDict;
use crate::util::json::Json;

/// One successfully loaded artifact.
#[derive(Clone, Debug)]
pub struct LoadedDict {
    pub key: ArtifactKey,
    /// Version actually served (the manifest current, unless healing fell
    /// back to an older one).
    pub version: u64,
    pub checksum: String,
    /// True when the manifest's current version was unusable and the
    /// loader fell back to (and re-promoted) an older good version.
    pub healed: bool,
    pub dict: CoordinateDict,
}

/// Result of [`load_all`].
#[derive(Debug, Default)]
pub struct LoadAllReport {
    /// Which manifest file the load started from.
    pub source: Option<ManifestSource>,
    pub loaded: Vec<LoadedDict>,
    /// Keys where every retained version was unusable, with the
    /// per-version reasons.
    pub failed: Vec<(ArtifactKey, String)>,
}

/// Result of [`verify`] — read-only integrity sweep over every record
/// (current and history) in the manifest.
#[derive(Debug)]
pub struct VerifyReport {
    pub source: ManifestSource,
    pub generation: u64,
    /// Number of (key, version) records checked.
    pub checked: usize,
    /// Human-readable description per bad record; empty means clean.
    pub errors: Vec<String>,
}

impl VerifyReport {
    pub fn ok(&self) -> bool {
        self.errors.is_empty()
    }
}

/// Load + validate the blob behind one version record. On corruption
/// (checksum mismatch, invalid JSON, or a dict that fails
/// [`CoordinateDict::from_json`]'s validation) the blob is quarantined
/// before the error is returned; a missing blob is an error without
/// quarantine.
fn try_load_record(
    store: &ArtifactStore,
    key: &ArtifactKey,
    rec: &VersionRecord,
) -> Result<CoordinateDict, String> {
    let bytes = match store.read_blob(&rec.checksum) {
        Ok(Some(b)) => b,
        Ok(None) => return Err("blob missing".to_string()),
        Err(e) => {
            store.quarantine_blob(&rec.checksum);
            return Err(e);
        }
    };
    let parsed = String::from_utf8(bytes)
        .map_err(|e| format!("blob not utf-8: {e}"))
        .and_then(|s| Json::parse(&s))
        .and_then(|j| CoordinateDict::from_json(&j));
    match parsed {
        Ok(dict) => {
            if dict.dataset != key.dataset || dict.solver != key.solver {
                // Keyed under one name, trained under another: suspicious
                // but not corrupt (keys carry the serving identity, the
                // dict its training provenance) — serve it, loudly.
                crate::warn_!(
                    "artifact {} v{}: dict provenance is {}/{}",
                    key.id(),
                    rec.version,
                    dict.dataset,
                    dict.solver
                );
            }
            Ok(dict)
        }
        Err(e) => {
            // Checksum matched but the content is not a valid dict: the
            // published artifact itself was bad. Same treatment.
            store.quarantine_blob(&rec.checksum);
            Err(format!("invalid dict: {e}"))
        }
    }
}

/// Load one entry, walking current → history newest-to-oldest until a
/// version validates. On fallback the entry is mutated in place (the
/// chosen record becomes current, newer corpses are dropped); the caller
/// persists the healed manifest.
fn load_entry(store: &ArtifactStore, entry: &mut ManifestEntry) -> Result<LoadedDict, String> {
    let mut candidates = vec![entry.current.clone()];
    candidates.extend(entry.history.iter().rev().cloned());
    let mut errs = Vec::new();
    for (idx, rec) in candidates.iter().enumerate() {
        match try_load_record(store, &entry.key, rec) {
            Ok(dict) => {
                let healed = idx > 0;
                if healed {
                    crate::warn_!(
                        "artifact {}: v{} unusable, healed to v{}",
                        entry.key.id(),
                        entry.current.version,
                        rec.version
                    );
                    entry.history.retain(|r| r.version < rec.version);
                    entry.current = rec.clone();
                }
                return Ok(LoadedDict {
                    key: entry.key.clone(),
                    version: rec.version,
                    checksum: rec.checksum.clone(),
                    healed,
                    dict,
                });
            }
            Err(e) => errs.push(format!("v{}: {e}", rec.version)),
        }
    }
    Err(errs.join("; "))
}

/// Load every key in the store. Corrupt versions are quarantined and
/// healed around; if any entry healed, the demotion is persisted as a new
/// manifest generation so the store converges back to a verified state.
/// Never panics; a completely unusable store returns an empty report.
pub fn load_all(store: &mut ArtifactStore) -> LoadAllReport {
    let (mut manifest, source) = store.load_manifest();
    let mut report = LoadAllReport {
        source: Some(source),
        ..LoadAllReport::default()
    };
    let mut healed_any = false;
    for entry in manifest.entries.values_mut() {
        match load_entry(store, entry) {
            Ok(l) => {
                healed_any |= l.healed;
                report.loaded.push(l);
            }
            Err(e) => report.failed.push((entry.key.clone(), e)),
        }
    }
    if healed_any {
        manifest.generation += 1;
        if let Err(e) = store.write_manifest(&manifest, source == ManifestSource::Current) {
            crate::warn_!("could not persist healed manifest: {e}");
        }
    }
    report
}

/// Load a single key (same heal semantics as [`load_all`]). `None` when
/// the key is unknown or every retained version is unusable.
pub fn load_dict(store: &mut ArtifactStore, key: &ArtifactKey) -> Option<LoadedDict> {
    let (mut manifest, source) = store.load_manifest();
    let entry = manifest.entries.get_mut(&key.id())?;
    match load_entry(store, entry) {
        Ok(l) => {
            if l.healed {
                manifest.generation += 1;
                if let Err(e) =
                    store.write_manifest(&manifest, source == ManifestSource::Current)
                {
                    crate::warn_!("could not persist healed manifest: {e}");
                }
            }
            Some(l)
        }
        Err(e) => {
            crate::warn_!("artifact {}: no usable version ({e})", key.id());
            None
        }
    }
}

/// Read-only integrity sweep: checks every record (current and history)
/// of every key against its checksum and dict validation. Mutates
/// nothing — no quarantine, no heal — so operators can diagnose before
/// acting; `artifact load` is the healing counterpart.
pub fn verify(store: &ArtifactStore) -> VerifyReport {
    let (manifest, source) = store.load_manifest();
    let mut checked = 0usize;
    let mut errors = Vec::new();
    for entry in manifest.entries.values() {
        for rec in std::iter::once(&entry.current).chain(entry.history.iter()) {
            checked += 1;
            let res = match store.read_blob(&rec.checksum) {
                Ok(Some(b)) => String::from_utf8(b)
                    .map_err(|e| format!("blob not utf-8: {e}"))
                    .and_then(|s| Json::parse(&s))
                    .and_then(|j| CoordinateDict::from_json(&j).map(|_| ())),
                Ok(None) => Err("blob missing".to_string()),
                Err(e) => Err(e),
            };
            if let Err(e) = res {
                errors.push(format!("{} v{}: {e}", entry.key.id(), rec.version));
            }
        }
    }
    VerifyReport {
        source,
        generation: manifest.generation,
        checked,
        errors,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pas::coords::ScaleMode;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn unique_dir(tag: &str) -> PathBuf {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let d = std::env::temp_dir().join(format!(
            "pas_loader_{tag}_{}_{}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn dict(v: f64) -> CoordinateDict {
        let mut d = CoordinateDict::new(4, ScaleMode::Absolute, "ddim", "gmm2d", 10);
        d.steps.insert(6, vec![v, 0.1, -0.2, 0.0]);
        d
    }

    #[test]
    fn load_roundtrip_and_verify_clean() {
        let dir = unique_dir("roundtrip");
        let mut store = ArtifactStore::open(&dir).unwrap();
        let key = ArtifactKey::new("gmm2d", "ddim", 10);
        let d = dict(1.5);
        let out = store.publish(&key, &d).unwrap();
        assert_eq!(out.version, 1);

        let loaded = load_dict(&mut store, &key).unwrap();
        assert!(!loaded.healed);
        assert_eq!(loaded.version, 1);
        // Bit-identical: canonical JSON equality is byte equality.
        assert_eq!(loaded.dict.to_json().to_string(), d.to_json().to_string());

        let rep = verify(&store);
        assert!(rep.ok(), "{:?}", rep.errors);
        assert_eq!(rep.checked, 1);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn corrupt_current_heals_to_previous() {
        let dir = unique_dir("heal");
        let mut store = ArtifactStore::open(&dir).unwrap();
        let key = ArtifactKey::new("gmm2d", "ddim", 10);
        let d1 = dict(1.0);
        let d2 = dict(2.0);
        store.publish(&key, &d1).unwrap();
        let out2 = store.publish(&key, &d2).unwrap();
        assert_eq!(out2.version, 2);

        // Truncate v2's blob: checksum no longer matches.
        let p = store.blob_path(&out2.checksum);
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();

        assert!(!verify(&store).ok());
        let loaded = load_dict(&mut store, &key).unwrap();
        assert!(loaded.healed);
        assert_eq!(loaded.version, 1);
        assert_eq!(loaded.dict.to_json().to_string(), d1.to_json().to_string());
        assert!(store.quarantine_path(&out2.checksum).exists());
        // Heal persisted: a fresh handle verifies clean.
        let store2 = ArtifactStore::open(&dir).unwrap();
        let rep = verify(&store2);
        assert!(rep.ok(), "{:?}", rep.errors);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn all_versions_corrupt_loads_nothing() {
        let dir = unique_dir("dead");
        let mut store = ArtifactStore::open(&dir).unwrap();
        let key = ArtifactKey::new("gmm2d", "ddim", 10);
        let out = store.publish(&key, &dict(1.0)).unwrap();
        std::fs::write(store.blob_path(&out.checksum), b"garbage").unwrap();

        assert!(load_dict(&mut store, &key).is_none());
        let rep = load_all(&mut store);
        assert!(rep.loaded.is_empty());
        assert_eq!(rep.failed.len(), 1);
        let _ = std::fs::remove_dir_all(dir);
    }
}
