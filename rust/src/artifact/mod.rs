//! Durable, checksummed artifact store for trained [`CoordinateDict`]s.
//!
//! PAS's whole premise is that a trained sampler correction is ~10
//! parameters — cheap to train, trivial to store, and exactly the kind of
//! state that must *not* evaporate on a process restart. This module is
//! the gap between "an in-process `RwLock` registry" and "a deployable
//! service": a content-addressed, checksummed on-disk store keyed by
//! `(dataset, solver, nfe)` with monotonically increasing per-key
//! versions, atomic publish, corruption quarantine, and rollback.
//!
//! # Layout
//!
//! ```text
//! <root>/
//!   blobs/<fnv1a64-hex>.json    one artifact per file, named by checksum
//!   quarantine/<hex>.json       corrupt blobs moved aside, never deleted
//!   manifest.json               current generation (self-checksummed)
//!   manifest.prev.json          previous generation, kept for recovery
//! ```
//!
//! # Durability protocol
//!
//! Every file is written **temp-file → fsync → atomic rename** (then the
//! parent directory is fsynced), never in place — a crash at any point
//! leaves either the old file or the new one, plus at worst an orphaned
//! `*.tmp.*` file that [`ArtifactStore::open`] sweeps. The manifest adds
//! one more rung: publishing generation *G+1* first renames the live
//! `manifest.json` (generation *G*) to `manifest.prev.json`, then renames
//! the new temp file into place, so the torn-manifest crash window (kill
//! between the two renames) leaves a store whose loader recovers from the
//! previous generation instead of panicking. The manifest body carries its
//! own checksum, so a partially written (torn) `manifest.json` is detected
//! on parse and likewise falls back.
//!
//! # Read-side integrity
//!
//! [`loader`] verifies every blob's checksum (and semantic validity, via
//! the hardened [`CoordinateDict::from_json`]) on read. A corrupt blob is
//! **quarantined** — renamed into `quarantine/` for post-mortem — and the
//! loader falls back to the newest remaining good version of that key,
//! persisting the demotion so the store converges back to a verified
//! state ("heal"). A key whose every version is corrupt simply loads
//! nothing: serving cold-starts that key uncorrected rather than
//! panicking or serving corrupt coordinates.
//!
//! # Fault injection
//!
//! [`store::FailPoint`] lets tests kill the write path between the
//! temp-file write and the rename (blob or manifest, and between the two
//! manifest renames) — `tests/artifact_store.rs` drives the full
//! crash-recovery matrix with it.
//!
//! Writers are expected to serialize per store directory (the server
//! wraps its store in a `Mutex`; the CLI is one-shot). Concurrent
//! publishes through one handle are safe and strictly versioned; separate
//! processes racing on one directory can lose a manifest update but can
//! never corrupt published state, because nothing is written in place.
//!
//! This store is also the cache target for future solver/schedule
//! auto-search recipes (ROADMAP item on USF-style search): any artifact
//! that serializes to JSON can ride the same blob + manifest machinery.

pub mod loader;
pub mod manifest;
pub mod store;

pub use loader::{load_all, load_dict, verify, LoadAllReport, LoadedDict, VerifyReport};
pub use manifest::{ArtifactKey, Manifest, ManifestEntry, ManifestSource, VersionRecord};
pub use store::{ArtifactStore, FailPoint, PublishOutcome};

#[cfg(doc)]
use crate::pas::coords::CoordinateDict;
