//! Trajectory generation: prior draws, teacher (ground-truth) runs, and
//! the truncation-error analysis behind Figure 3 ("S"-shaped error).

use crate::schedule::Schedule;
use crate::score::EpsModel;
use crate::solvers::{run_solver, Solver};
use crate::tensor::l2_dist_sq;
use crate::util::rng::Pcg64;

/// Draw `n` prior samples `x_T ~ N(0, T^2 I)` (EDM prior).
pub fn sample_prior(rng: &mut Pcg64, n: usize, dim: usize, t_max: f64) -> Vec<f64> {
    let mut x = rng.normal_vec(n * dim);
    crate::tensor::scale(t_max, &mut x);
    x
}

/// Ground-truth trajectories for a student schedule (paper §3.3).
///
/// The teacher runs `teacher_nfe` model evaluations on the refined grid
/// that shares every student node; the ground-truth states are read off by
/// indexing every `(M+1)`-th teacher state.
pub struct GroundTruth {
    /// Per student node `ts[0..=N]`: states (n, d) flattened.
    pub xs: Vec<Vec<f64>>,
    pub n: usize,
    pub dim: usize,
    /// NFE the teacher actually spent.
    pub teacher_nfe: usize,
}

/// Generate ground-truth trajectories with an arbitrary teacher solver.
///
/// `teacher_nfe` is a *budget* in model evaluations: the refined grid gets
/// `N(M+1)` steps with `M` minimal so that `N(M+1) * evals_per_step >=
/// teacher_nfe` is representable — in practice Heun/100 on a 10-step
/// student grid refines by M=4 (50 steps × 2 evals).
pub fn ground_truth(
    teacher: &dyn Solver,
    model: &dyn EpsModel,
    x_t: &[f64],
    n: usize,
    student: &Schedule,
    teacher_nfe: usize,
) -> GroundTruth {
    let steps_budget = teacher_nfe / teacher.evals_per_step();
    assert!(steps_budget >= student.n_steps(), "teacher budget too small");
    let (m, fine) = student.teacher_for(steps_budget);
    let run = run_solver(teacher, model, x_t, n, &fine, None);
    let stride = m + 1;
    let xs = (0..=student.n_steps())
        .map(|j| run.xs[j * stride].clone())
        .collect();
    GroundTruth {
        xs,
        n,
        dim: model.dim(),
        teacher_nfe: run.nfe,
    }
}

/// Per-node mean L2 distance between a student run's states and the ground
/// truth — the cumulative truncation-error curve of Figure 3. Entry `j`
/// corresponds to node `ts[j]` (entry 0 is always 0: shared prior draw).
pub fn truncation_error_curve(student_xs: &[Vec<f64>], gt: &GroundTruth) -> Vec<f64> {
    assert_eq!(student_xs.len(), gt.xs.len());
    let (n, d) = (gt.n, gt.dim);
    student_xs
        .iter()
        .zip(gt.xs.iter())
        .map(|(a, b)| {
            let mut s = 0.0;
            for i in 0..n {
                s += l2_dist_sq(&a[i * d..(i + 1) * d], &b[i * d..(i + 1) * d]).sqrt();
            }
            s / n as f64
        })
        .collect()
}

/// Quantify the "S"-shape of a cumulative error curve: returns
/// `(max_step_increase_position_fraction, early_fraction, late_fraction)`
/// where early/late fractions are the share of total error growth in the
/// first/last third of steps. An S-shape has a mid-trajectory bulge:
/// `early + late < ~0.6` of total growth.
pub fn s_shape_stats(curve: &[f64]) -> (f64, f64, f64) {
    let n = curve.len() - 1;
    let total = curve[n] - curve[0];
    if total <= 0.0 {
        return (0.0, 0.0, 0.0);
    }
    let mut max_inc = 0.0;
    let mut max_pos = 0;
    for j in 0..n {
        let inc = curve[j + 1] - curve[j];
        if inc > max_inc {
            max_inc = inc;
            max_pos = j;
        }
    }
    let third = n / 3;
    let early = (curve[third] - curve[0]) / total;
    let late = (curve[n] - curve[n - third]) / total;
    (max_pos as f64 / n as f64, early, late)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry::get;
    use crate::schedule::default_schedule;
    use crate::score::analytic::AnalyticEps;
    use crate::solvers::registry as solvers;

    #[test]
    fn prior_scale() {
        let mut rng = Pcg64::seed(1);
        let x = sample_prior(&mut rng, 2000, 2, 80.0);
        let sd = crate::util::std_dev(&x);
        assert!((sd - 80.0).abs() < 2.0, "{sd}");
    }

    #[test]
    fn ground_truth_shares_prior_and_is_finer() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(5);
        let mut rng = Pcg64::seed(2);
        let x_t = sample_prior(&mut rng, 8, 2, sched.t_max());
        let heun = solvers::get("heun").unwrap();
        let gt = ground_truth(heun.as_ref(), model.as_ref(), &x_t, 8, &sched, 100);
        assert_eq!(gt.xs.len(), 6);
        assert_eq!(gt.xs[0], x_t);
        assert!(gt.teacher_nfe >= 100);
    }

    #[test]
    fn student_error_grows_then_gt_matches_itself() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(8);
        let mut rng = Pcg64::seed(3);
        let x_t = sample_prior(&mut rng, 16, 2, sched.t_max());
        let heun = solvers::get("heun").unwrap();
        let gt = ground_truth(heun.as_ref(), model.as_ref(), &x_t, 16, &sched, 100);
        // Student: Euler on the same grid.
        let ddim = solvers::get("ddim").unwrap();
        let run = run_solver(ddim.as_ref(), model.as_ref(), &x_t, 16, &sched, None);
        let curve = truncation_error_curve(&run.xs, &gt);
        assert_eq!(curve[0], 0.0);
        assert!(curve.last().unwrap() > &0.01, "{curve:?}");
        // GT vs itself is identically zero.
        let zero = truncation_error_curve(&gt.xs, &gt);
        assert!(zero.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn s_shape_detects_mid_bulge() {
        // Synthetic S-curve (logistic-ish cumulative).
        let curve: Vec<f64> = (0..=10)
            .map(|j| 1.0 / (1.0 + (-((j as f64) - 5.0)).exp()))
            .collect();
        let (pos, early, late) = s_shape_stats(&curve);
        assert!((0.25..=0.75).contains(&pos), "{pos}");
        assert!(early < 0.3 && late < 0.3, "{early} {late}");
    }
}
