//! Trajectory generation: prior draws, teacher (ground-truth) runs, and
//! the truncation-error analysis behind Figure 3 ("S"-shaped error).
//!
//! Since the training-stack refactor, trajectories live in **flat**
//! `(node, n·dim)` storage: [`GroundTruth`] keeps its per-node states in a
//! [`NodeStore`] and teacher rollouts run through a caller-reused
//! [`SamplerEngine`] (`Record::Full`) instead of materializing a nested
//! [`crate::solvers::SolveRun`] per call. [`truncation_error_curve`] reads
//! any trajectory — flat store or legacy nested rows — through a
//! [`NodeView`].

use crate::schedule::Schedule;
use crate::score::EpsModel;
use crate::solvers::engine::{NodeStore, Record, SamplerEngine};
use crate::solvers::{NodeView, Solver};
use crate::tensor::l2_dist_sq;
use crate::util::rng::Pcg64;

/// Draw `n` prior samples `x_T ~ N(0, T^2 I)` (EDM prior).
pub fn sample_prior(rng: &mut Pcg64, n: usize, dim: usize, t_max: f64) -> Vec<f64> {
    let mut x = vec![0.0; n * dim];
    sample_prior_into(rng, t_max, &mut x);
    x
}

/// [`sample_prior`] into a caller-owned buffer (already sized `n * dim`):
/// the training session's zero-steady-state-allocation entry point.
/// Consumes the RNG stream identically to the allocating form.
pub fn sample_prior_into(rng: &mut Pcg64, t_max: f64, out: &mut [f64]) {
    rng.fill_normal(out);
    crate::tensor::scale(t_max, out);
}

/// The serving layer's per-request prior convention: request `(seed,
/// stream)` — the stream is the request id — draws from its own
/// deterministic [`Pcg64`] stream, independent of batch composition or
/// admission order. Both service schedulers and every solo-run parity
/// check draw through this one function, so "the same request" always
/// means "the same prior rows" by construction.
pub fn sample_prior_stream(seed: u64, stream: u64, n: usize, dim: usize, t_max: f64) -> Vec<f64> {
    let mut rng = Pcg64::seed_stream(seed, stream);
    sample_prior(&mut rng, n, dim, t_max)
}

/// Ground-truth trajectories for a student schedule (paper §3.3).
///
/// The teacher runs `teacher_nfe` model evaluations on the refined grid
/// that shares every student node; the ground-truth states are read off by
/// indexing every `(M+1)`-th teacher state. States are stored flat, one
/// `(n, dim)` row per student node.
pub struct GroundTruth {
    /// Per student node `ts[0..=N]`: states `(n, dim)` flattened, one
    /// [`NodeStore`] row per node.
    pub xs: NodeStore,
    pub n: usize,
    pub dim: usize,
    /// NFE the teacher actually spent.
    pub teacher_nfe: usize,
}

impl GroundTruth {
    /// Empty shell to be filled by [`ground_truth_into`] (lets a training
    /// session own and reuse the storage across runs).
    pub fn empty() -> GroundTruth {
        GroundTruth {
            xs: NodeStore::new(),
            n: 0,
            dim: 0,
            teacher_nfe: 0,
        }
    }

    /// Number of stored student nodes (`n_steps + 1`).
    pub fn n_nodes(&self) -> usize {
        self.xs.len()
    }

    /// Flat `(n, dim)` ground-truth state at student node `j`.
    pub fn node(&self, j: usize) -> &[f64] {
        self.xs.row(j)
    }

    /// View over all stored nodes.
    pub fn view(&self) -> NodeView<'_> {
        self.xs.view()
    }
}

/// Generate ground-truth trajectories with an arbitrary teacher solver.
///
/// Convenience wrapper over [`ground_truth_into`] that allocates a
/// one-shot engine and store; long-lived callers (the PAS
/// [`crate::pas::train::TrainSession`]) reuse both across runs.
pub fn ground_truth(
    teacher: &dyn Solver,
    model: &dyn EpsModel,
    x_t: &[f64],
    n: usize,
    student: &Schedule,
    teacher_nfe: usize,
) -> GroundTruth {
    let mut gt = GroundTruth::empty();
    let mut engine = SamplerEngine::with_record(Record::Full);
    ground_truth_into(&mut gt, &mut engine, teacher, model, x_t, n, student, teacher_nfe);
    gt
}

/// Fill `gt` with ground-truth trajectories, running the teacher through
/// `engine` (`Record::Full`; its workspace is reused — after the first
/// run of a given shape the rollout performs no per-step allocation).
///
/// `teacher_nfe` is a *budget* in model evaluations: the refined grid gets
/// `N(M+1)` steps with `M` minimal so that `N(M+1) * evals_per_step >=
/// teacher_nfe` is representable — in practice Heun/100 on a 10-step
/// student grid refines by M=4 (50 steps × 2 evals). Bit-identical to the
/// seed's nested-rows path (the engine is pinned to the legacy driver by
/// `tests/engine_parity.rs`).
#[allow(clippy::too_many_arguments)]
pub fn ground_truth_into(
    gt: &mut GroundTruth,
    engine: &mut SamplerEngine,
    teacher: &dyn Solver,
    model: &dyn EpsModel,
    x_t: &[f64],
    n: usize,
    student: &Schedule,
    teacher_nfe: usize,
) {
    let steps_budget = teacher_nfe / teacher.evals_per_step();
    assert!(steps_budget >= student.n_steps(), "teacher budget too small");
    assert_eq!(
        engine.config().record,
        Record::Full,
        "ground truth needs the full teacher trajectory"
    );
    let (m, fine) = student.teacher_for(steps_budget);
    let dim = model.dim();
    let mut x0 = vec![0.0; n * dim];
    let nfe = engine.run_into(teacher, model, x_t, n, &fine, None, &mut x0);
    let stride = m + 1;
    let teacher_xs = engine.xs().view();
    gt.xs.reset(n * dim, student.n_steps() + 1);
    for j in 0..=student.n_steps() {
        gt.xs.push_row(teacher_xs.row(j * stride));
    }
    gt.n = n;
    gt.dim = dim;
    gt.teacher_nfe = nfe;
}

/// Per-node mean L2 distance between a student run's states and the ground
/// truth — the cumulative truncation-error curve of Figure 3. Entry `j`
/// corresponds to node `ts[j]` (entry 0 is always 0: shared prior draw).
///
/// `student_xs` is any node-indexed trajectory: wrap legacy nested rows
/// with [`NodeView::nested`], or pass a flat store's
/// [`NodeStore::view`] directly.
pub fn truncation_error_curve(student_xs: NodeView<'_>, gt: &GroundTruth) -> Vec<f64> {
    assert_eq!(student_xs.len(), gt.n_nodes());
    let (n, d) = (gt.n, gt.dim);
    (0..student_xs.len())
        .map(|j| {
            let a = student_xs.row(j);
            let b = gt.node(j);
            let mut s = 0.0;
            for i in 0..n {
                s += l2_dist_sq(&a[i * d..(i + 1) * d], &b[i * d..(i + 1) * d]).sqrt();
            }
            s / n as f64
        })
        .collect()
}

/// Quantify the "S"-shape of a cumulative error curve: returns
/// `(max_step_increase_position_fraction, early_fraction, late_fraction)`
/// where early/late fractions are the share of total error growth in the
/// first/last third of steps. An S-shape has a mid-trajectory bulge:
/// `early + late < ~0.6` of total growth.
pub fn s_shape_stats(curve: &[f64]) -> (f64, f64, f64) {
    let n = curve.len() - 1;
    let total = curve[n] - curve[0];
    if total <= 0.0 {
        return (0.0, 0.0, 0.0);
    }
    let mut max_inc = 0.0;
    let mut max_pos = 0;
    for j in 0..n {
        let inc = curve[j + 1] - curve[j];
        if inc > max_inc {
            max_inc = inc;
            max_pos = j;
        }
    }
    let third = n / 3;
    let early = (curve[third] - curve[0]) / total;
    let late = (curve[n] - curve[n - third]) / total;
    (max_pos as f64 / n as f64, early, late)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry::get;
    use crate::schedule::default_schedule;
    use crate::score::analytic::AnalyticEps;
    use crate::solvers::{registry as solvers, run_solver};

    #[test]
    fn prior_scale() {
        let mut rng = Pcg64::seed(1);
        let x = sample_prior(&mut rng, 2000, 2, 80.0);
        let sd = crate::util::std_dev(&x);
        assert!((sd - 80.0).abs() < 2.0, "{sd}");
    }

    #[test]
    fn prior_into_matches_allocating_form() {
        let mut a = Pcg64::seed(7);
        let mut b = Pcg64::seed(7);
        let x = sample_prior(&mut a, 5, 3, 80.0);
        let mut y = vec![0.0; 15];
        sample_prior_into(&mut b, 80.0, &mut y);
        assert_eq!(x, y);
        // RNG streams advanced identically.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn prior_stream_matches_manual_stream() {
        let mut rng = Pcg64::seed_stream(5, 9);
        let a = sample_prior(&mut rng, 3, 2, 80.0);
        assert_eq!(a, sample_prior_stream(5, 9, 3, 2, 80.0));
    }

    #[test]
    fn ground_truth_shares_prior_and_is_finer() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(5);
        let mut rng = Pcg64::seed(2);
        let x_t = sample_prior(&mut rng, 8, 2, sched.t_max());
        let heun = solvers::get("heun").unwrap();
        let gt = ground_truth(heun.as_ref(), model.as_ref(), &x_t, 8, &sched, 100);
        assert_eq!(gt.n_nodes(), 6);
        assert_eq!(gt.node(0), &x_t[..]);
        assert!(gt.teacher_nfe >= 100);
    }

    #[test]
    fn ground_truth_store_reuse_matches_fresh() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let heun = solvers::get("heun").unwrap();
        let mut gt = GroundTruth::empty();
        let mut engine = SamplerEngine::with_record(Record::Full);
        let mut rng = Pcg64::seed(8);
        // Two runs of different shapes through the same store + engine:
        // each must match a fresh one-shot computation exactly.
        for (n, steps) in [(8usize, 5usize), (4, 7)] {
            let sched = default_schedule(steps);
            let x_t = sample_prior(&mut rng, n, 2, sched.t_max());
            ground_truth_into(
                &mut gt, &mut engine, heun.as_ref(), model.as_ref(), &x_t, n, &sched, 100,
            );
            let fresh = ground_truth(heun.as_ref(), model.as_ref(), &x_t, n, &sched, 100);
            assert_eq!(gt.n_nodes(), fresh.n_nodes());
            for j in 0..gt.n_nodes() {
                assert_eq!(gt.node(j), fresh.node(j), "node {j} (n={n})");
            }
        }
    }

    #[test]
    fn student_error_grows_then_gt_matches_itself() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(8);
        let mut rng = Pcg64::seed(3);
        let x_t = sample_prior(&mut rng, 16, 2, sched.t_max());
        let heun = solvers::get("heun").unwrap();
        let gt = ground_truth(heun.as_ref(), model.as_ref(), &x_t, 16, &sched, 100);
        // Student: Euler on the same grid.
        let ddim = solvers::get("ddim").unwrap();
        let run = run_solver(ddim.as_ref(), model.as_ref(), &x_t, 16, &sched, None);
        let curve = truncation_error_curve(NodeView::nested(&run.xs), &gt);
        assert_eq!(curve[0], 0.0);
        assert!(curve.last().unwrap() > &0.01, "{curve:?}");
        // GT vs itself is identically zero.
        let zero = truncation_error_curve(gt.view(), &gt);
        assert!(zero.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn s_shape_detects_mid_bulge() {
        // Synthetic S-curve (logistic-ish cumulative).
        let curve: Vec<f64> = (0..=10)
            .map(|j| 1.0 / (1.0 + (-((j as f64) - 5.0)).exp()))
            .collect();
        let (pos, early, late) = s_shape_stats(&curve);
        assert!((0.25..=0.75).contains(&pos), "{pos}");
        assert!(early < 0.3 && late < 0.3, "{early} {late}");
    }
}
