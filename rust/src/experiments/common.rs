//! Shared machinery for the experiment runners: model construction,
//! reference sets, PAS training, and gFID evaluation of a
//! (solver, NFE, PAS?, TP?) configuration.

use super::ExpOpts;
use crate::data::Dataset;
use crate::metrics::gfid;
use crate::pas::coords::{CoordinateDict, ScaleMode};
use crate::pas::correct::CorrectedSampler;
use crate::pas::teleport::{teleported_schedule, Teleporter};
use crate::pas::train::{PasTrainer, TrainConfig, TrainResult};
use crate::schedule::{default_schedule, Schedule};
use crate::score::analytic::AnalyticEps;
use crate::score::cfg::RowCfgEps;
use crate::score::EpsModel;
use crate::solvers::{run_solver, Solver};
use crate::traj::sample_prior;
use crate::util::rng::Pcg64;

/// Everything needed to evaluate one dataset.
pub struct Bench {
    pub ds: Dataset,
    pub model: Box<dyn EpsModel>,
    pub reference: Vec<f64>,
    pub n_ref: usize,
    /// Teleporter fitted to the data moments (for +TP rows).
    pub tp: Teleporter,
    pub guidance: f64,
}

impl Bench {
    /// Build a bench for `dataset`; `guidance > 0` selects the guided
    /// conditional model (cond datasets only).
    pub fn new(dataset: &str, guidance: f64, opts: &ExpOpts) -> Bench {
        let ds = crate::data::registry::get(dataset)
            .unwrap_or_else(|| panic!("unknown dataset {dataset}"));
        let model: Box<dyn EpsModel> = if guidance > 0.0 {
            RowCfgEps::from_spec(&ds.spec, guidance)
        } else {
            AnalyticEps::from_dataset(&ds)
        };
        let mut rng = Pcg64::seed_stream(opts.seed, 0x4ef0);
        let reference = ds.spec.sample(&mut rng, opts.n_ref);
        let tp = Teleporter::from_dataset(&ds);
        Bench {
            ds,
            model,
            reference,
            n_ref: opts.n_ref,
            tp,
            guidance,
        }
    }

    pub fn dim(&self) -> usize {
        self.ds.dim()
    }
}

/// One evaluation configuration (a cell of Table 2/3/5).
#[derive(Clone, Debug)]
pub struct Cell {
    pub solver: String,
    pub nfe: usize,
    pub pas: bool,
    pub tp: bool,
    /// Override default PAS hyperparameters.
    pub train_overrides: Option<TrainConfig>,
}

impl Cell {
    pub fn plain(solver: &str, nfe: usize) -> Cell {
        Cell {
            solver: solver.into(),
            nfe,
            pas: false,
            tp: false,
            train_overrides: None,
        }
    }

    pub fn pas(solver: &str, nfe: usize) -> Cell {
        Cell {
            pas: true,
            ..Cell::plain(solver, nfe)
        }
    }
}

/// Default PAS training config scaled by ExpOpts. Tau follows the paper's
/// two-tier recommendation (larger for high-error DDIM, smaller for
/// iPNDM), rescaled because our losses are per-dimension means rather
/// than raw sums (DESIGN.md §3): 1e-2 / 1e-3.
pub fn default_train(opts: &ExpOpts, solver: &str) -> TrainConfig {
    let tau = if solver.starts_with("ddim") { 1e-2 } else { 1e-3 };
    TrainConfig {
        n_traj: opts.n_traj,
        epochs: opts.epochs,
        tau,
        lr: 2e-2,
        scale_mode: ScaleMode::Relative,
        seed: opts.seed,
        ..TrainConfig::default()
    }
}

/// Outcome of evaluating one cell.
pub struct CellResult {
    pub gfid: f64,
    pub dict: Option<CoordinateDict>,
    pub train: Option<TrainResult>,
}

/// Evaluate a cell: train PAS if requested, sample `opts.n_samples`,
/// return gFID vs the bench reference. Returns None for non-representable
/// NFE (the paper's "\\" cells).
pub fn eval_cell(bench: &Bench, cell: &Cell, opts: &ExpOpts) -> Option<CellResult> {
    let solver: Box<dyn Solver> = crate::solvers::registry::get(&cell.solver)?;
    let steps = solver.steps_for_nfe(cell.nfe)?;
    let base_sched = default_schedule(steps);
    let sched: Schedule = if cell.tp {
        teleported_schedule(&base_sched, crate::pas::teleport::SIGMA_SKIP_DEFAULT)
    } else {
        base_sched
    };
    let t_gen = crate::schedule::T_MAX_DEFAULT;

    // Optional PAS training.
    let mut dict = None;
    let mut train_res = None;
    if cell.pas {
        let cfg = cell
            .train_overrides
            .clone()
            .unwrap_or_else(|| default_train(opts, &cell.solver));
        let trainer = PasTrainer::new(cfg);
        let tp_arg = cell.tp.then_some((&bench.tp, t_gen));
        match trainer.train_tp(
            solver.as_ref(),
            bench.model.as_ref(),
            &sched,
            bench.ds.name(),
            false,
            tp_arg,
        ) {
            Ok(tr) => {
                dict = Some(tr.dict.clone());
                train_res = Some(tr);
            }
            Err(e) => {
                crate::util::log::log(
                    crate::util::log::Level::Warn,
                    format_args!("PAS training failed for {}: {e}", cell.solver),
                );
                return None;
            }
        }
    }

    // Sampling. One shared prior stream across ALL cells of a table so
    // method comparisons are paired (same noise draws), not confounded by
    // gFID estimator variance.
    let n = opts.n_samples;
    let dim = bench.dim();
    let mut rng = Pcg64::seed_stream(opts.seed ^ 0xe7a1, 1);
    let mut x_t = sample_prior(&mut rng, n, dim, t_gen);
    if cell.tp {
        bench.tp.teleport(&mut x_t, n, t_gen, sched.t_max());
    }
    let run = match &dict {
        Some(d) => CorrectedSampler::sample(d, solver.as_ref(), bench.model.as_ref(), &x_t, n, &sched),
        None => run_solver(solver.as_ref(), bench.model.as_ref(), &x_t, n, &sched, None),
    };
    let f = gfid(&run.x0, n, &bench.reference, bench.n_ref, dim);
    Some(CellResult {
        gfid: f,
        dict,
        train: train_res,
    })
}

/// Format a gFID value the way the paper's tables do.
pub fn fmt_gfid(v: Option<f64>) -> String {
    match v {
        None => "\\".to_string(),
        Some(f) if f >= 100.0 => format!("{f:.1}"),
        Some(f) => format!("{f:.3}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_builds_and_cell_evaluates() {
        let opts = ExpOpts::quick();
        let bench = Bench::new("gmm2d", 0.0, &opts);
        let r = eval_cell(&bench, &Cell::plain("ddim", 6), &opts).unwrap();
        assert!(r.gfid.is_finite() && r.gfid >= 0.0);
        // Heun at odd NFE is not representable.
        assert!(eval_cell(&bench, &Cell::plain("heun", 5), &opts).is_none());
    }

    #[test]
    fn pas_cell_trains_and_improves_ddim() {
        let mut opts = ExpOpts::quick();
        opts.n_samples = 512;
        let bench = Bench::new("gmm2d", 0.0, &opts);
        let plain = eval_cell(&bench, &Cell::plain("ddim", 8), &opts).unwrap();
        let pas = eval_cell(&bench, &Cell::pas("ddim", 8), &opts).unwrap();
        assert!(pas.dict.is_some());
        assert!(
            pas.gfid < plain.gfid,
            "PAS should improve DDIM: {} -> {}",
            plain.gfid,
            pas.gfid
        );
    }
}
