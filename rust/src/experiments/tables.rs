//! Main result tables: Table 2 (unconditional + conditional main grid),
//! Table 3 (Stable-Diffusion analog, guided), Table 5 (NFE 4–10 sweep),
//! Table 6 (corrected time points, covers Table 1).

use super::common::{eval_cell, fmt_gfid, Bench, Cell};
use super::{ExpOpts, Table};

const NFE_GRID: [usize; 4] = [5, 6, 8, 10];

fn grid_row(bench: &Bench, label: &str, mk: impl Fn(usize) -> Cell, opts: &ExpOpts) -> (String, Vec<String>) {
    let cells: Vec<String> = NFE_GRID
        .iter()
        .map(|&nfe| fmt_gfid(eval_cell(bench, &mk(nfe), opts).map(|r| r.gfid)))
        .collect();
    (label.to_string(), cells)
}

/// Table 2: the main gFID grid across the four paper-dataset stand-ins.
pub fn table2(opts: &ExpOpts) -> Vec<Table> {
    let mut out = Vec::new();
    for ds_name in crate::data::registry::MAIN_TABLE {
        let guidance = if *ds_name == "cond-gmm64" { 2.0 } else { 0.0 };
        let bench = Bench::new(ds_name, guidance, opts);
        let mut t = Table::new(
            "table2",
            &format!(
                "gFID on {ds_name} (stands in for {}), NFE grid",
                bench.ds.stands_in_for
            ),
            &["5", "6", "8", "10"],
        );
        let methods: Vec<(&str, Box<dyn Fn(usize) -> Cell>)> = vec![
            ("ddim", Box::new(|n| Cell::plain("ddim", n))),
            ("ddim + TP", Box::new(|n| Cell { tp: true, ..Cell::plain("ddim", n) })),
            ("ddim + PAS", Box::new(|n| Cell::pas("ddim", n))),
            ("ddim + TP + PAS", Box::new(|n| Cell { tp: true, ..Cell::pas("ddim", n) })),
            ("heun", Box::new(|n| Cell::plain("heun", n))),
            ("dpm2", Box::new(|n| Cell::plain("dpm2", n))),
            ("dpmpp3m", Box::new(|n| Cell::plain("dpmpp3m", n))),
            ("deis-tab3", Box::new(|n| Cell::plain("deis-tab3", n))),
            ("unipc3m", Box::new(|n| Cell::plain("unipc3m", n))),
            ("ipndm", Box::new(|n| Cell::plain("ipndm", n))),
            ("ipndm + TP", Box::new(|n| Cell { tp: true, ..Cell::plain("ipndm", n) })),
            ("ipndm + PAS", Box::new(|n| Cell::pas("ipndm", n))),
            ("ipndm + TP + PAS", Box::new(|n| Cell { tp: true, ..Cell::pas("ipndm", n) })),
        ];
        for (label, mk) in methods {
            let (l, cells) = grid_row(&bench, label, mk, opts);
            t.row(l, cells);
        }
        out.push(t);
    }
    out
}

/// Table 3: the Stable-Diffusion analog — guided conditional sampling at
/// guidance 7.5, DDIM ± PAS vs the multistep state of the art.
pub fn table3(opts: &ExpOpts) -> Vec<Table> {
    let bench = Bench::new("cond-gmm64", 7.5, opts);
    let mut t = Table::new(
        "table3",
        "gFID on cond-gmm64 with guidance scale 7.5 (stands in for Stable Diffusion v1.4)",
        &["5", "6", "8", "10"],
    );
    let methods: Vec<(&str, Box<dyn Fn(usize) -> Cell>)> = vec![
        ("ddim", Box::new(|n| Cell::plain("ddim", n))),
        ("dpmpp2m", Box::new(|n| Cell::plain("dpmpp2m", n))),
        ("unipc2m", Box::new(|n| Cell::plain("unipc2m", n))),
        ("ddim + PAS", Box::new(|n| Cell::pas("ddim", n))),
    ];
    for (label, mk) in methods {
        let (l, cells) = grid_row(&bench, label, mk, opts);
        t.row(l, cells);
    }
    vec![t]
}

/// Table 5: NFE 4–10 sweep on the CIFAR10 and FFHQ stand-ins.
pub fn table5(opts: &ExpOpts) -> Vec<Table> {
    let nfes = [4usize, 5, 6, 7, 8, 9, 10];
    let cols: Vec<String> = nfes.iter().map(|n| n.to_string()).collect();
    let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut out = Vec::new();
    for ds_name in ["gmm-hd64", "shells64"] {
        let bench = Bench::new(ds_name, 0.0, opts);
        let mut t = Table::new(
            "table5",
            &format!("gFID vs NFE on {ds_name} ({})", bench.ds.stands_in_for),
            &cols_ref,
        );
        let methods: Vec<(&str, Box<dyn Fn(usize) -> Cell>)> = vec![
            ("ddim", Box::new(|n| Cell::plain("ddim", n))),
            ("ddim + PAS", Box::new(|n| Cell::pas("ddim", n))),
            ("heun", Box::new(|n| Cell::plain("heun", n))),
            ("dpm2", Box::new(|n| Cell::plain("dpm2", n))),
            ("dpmpp3m", Box::new(|n| Cell::plain("dpmpp3m", n))),
            ("deis-tab3", Box::new(|n| Cell::plain("deis-tab3", n))),
            ("unipc3m", Box::new(|n| Cell::plain("unipc3m", n))),
            ("ipndm", Box::new(|n| Cell::plain("ipndm", n))),
            ("ipndm + PAS", Box::new(|n| Cell::pas("ipndm", n))),
        ];
        for (label, mk) in methods {
            let cells: Vec<String> = nfes
                .iter()
                .map(|&nfe| fmt_gfid(eval_cell(&bench, &mk(nfe), opts).map(|r| r.gfid)))
                .collect();
            t.row(label, cells);
        }
        out.push(t);
    }
    out
}

/// Table 6 (and Table 1): the corrected time points chosen by adaptive
/// search per dataset, solver and NFE.
pub fn table6(opts: &ExpOpts) -> Vec<Table> {
    let mut out = Vec::new();
    for ds_name in crate::data::registry::MAIN_TABLE {
        let guidance = if *ds_name == "cond-gmm64" { 2.0 } else { 0.0 };
        let bench = Bench::new(ds_name, guidance, opts);
        let mut t = Table::new(
            "table6",
            &format!("time points corrected by adaptive search on {ds_name}"),
            &["5", "6", "8", "10"],
        );
        for solver in ["ddim", "ipndm"] {
            let cells: Vec<String> = NFE_GRID
                .iter()
                .map(|&nfe| {
                    eval_cell(&bench, &Cell::pas(solver, nfe), opts)
                        .and_then(|r| r.train)
                        .map(|tr| {
                            let s = tr.trace.corrected_steps_str();
                            format!("{s} ({}p)", tr.dict.n_params())
                        })
                        .unwrap_or_else(|| "\\".into())
                })
                .collect();
            t.row(format!("{solver} + PAS"), cells);
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A miniature Table-2 sanity check on one dataset: the paper's
    /// ordering DDIM > DDIM+PAS (gFID, lower better) must hold.
    #[test]
    fn table2_ordering_holds_on_gmm2d() {
        let mut opts = ExpOpts::quick();
        opts.n_samples = 512;
        let bench = Bench::new("gmm2d", 0.0, &opts);
        let ddim = eval_cell(&bench, &Cell::plain("ddim", 8), &opts).unwrap().gfid;
        let pas = eval_cell(&bench, &Cell::pas("ddim", 8), &opts).unwrap().gfid;
        let ipndm = eval_cell(&bench, &Cell::plain("ipndm", 8), &opts).unwrap().gfid;
        assert!(pas < ddim, "ddim {ddim} vs +pas {pas}");
        assert!(ipndm < ddim, "ipndm {ipndm} vs ddim {ddim}");
    }

    #[test]
    fn table6_reports_steps() {
        let mut opts = ExpOpts::quick();
        opts.n_samples = 128;
        let bench = Bench::new("gmm2d", 0.0, &opts);
        let r = eval_cell(&bench, &Cell::pas("ddim", 6), &opts).unwrap();
        let tr = r.train.unwrap();
        // At least one corrected step, each storing <= 4 coords.
        assert!(!tr.dict.steps.is_empty());
        assert!(tr.dict.n_params() <= 24);
    }
}
