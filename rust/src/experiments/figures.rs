//! Figure runners: Fig. 2 (PCA cumulative variance) and Fig. 3
//! (S-shaped truncation error, before/after PAS).

use super::common::{default_train, Bench};
use super::{ExpOpts, Table};
use crate::pas::pca::cumulative_percent_variance;
use crate::pas::train::PasTrainer;
use crate::schedule::default_schedule;
use crate::solvers::run_solver;
use crate::traj::{s_shape_stats, sample_prior};
use crate::util::rng::Pcg64;

/// Figure 2: cumulative percent variance vs number of principal
/// components, for (a) single-trajectory matrices `{x_T, d_N..d_1}`
/// averaged over samples, and (b) the stacked endpoints of K
/// trajectories `{x^k_{t_i}}`.
pub fn fig2(opts: &ExpOpts) -> Vec<Table> {
    let datasets = ["gmm-hd64", "shells64", "latent256"];
    let top_k = 8;
    let nfe = 100usize;
    let n_traj = 64.min(opts.n_traj);
    let cols: Vec<String> = (1..=top_k).map(|k| format!("{k} PC")).collect();
    let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut ta = Table::new(
        "fig2a",
        "cumulative % variance of a single trajectory {x_T, d_i} (mean over trajectories), Euler 100 NFE",
        &cols_ref,
    );
    let mut tb = Table::new(
        "fig2b",
        "cumulative % variance across K trajectories {x^k_{t_i}} stacked",
        &cols_ref,
    );
    for name in datasets {
        let bench = Bench::new(name, 0.0, opts);
        let dim = bench.dim();
        let sched = default_schedule(nfe);
        let mut rng = Pcg64::seed_stream(opts.seed, 0xf16);
        let x_t = sample_prior(&mut rng, n_traj, dim, sched.t_max());
        let solver = crate::solvers::registry::get("ddim").unwrap();
        let run = run_solver(solver.as_ref(), bench.model.as_ref(), &x_t, n_traj, &sched, None);

        // (a) per-trajectory matrix {x_T, d_N, ..., d_1}: rows = NFE + 1.
        // Raw rows (paper-literal; x_T's norm dominates) and unit-norm rows
        // (scale-free subspace dimension — the informative view).
        let mut acc = vec![0.0; top_k];
        let mut acc_unit = vec![0.0; top_k];
        for k in 0..n_traj {
            let mut m = Vec::with_capacity((nfe + 1) * dim);
            m.extend_from_slice(&x_t[k * dim..(k + 1) * dim]);
            for d in &run.ds {
                m.extend_from_slice(&d[k * dim..(k + 1) * dim]);
            }
            let cv = cumulative_percent_variance(&m, nfe + 1, dim, top_k);
            for (a, v) in acc.iter_mut().zip(cv.iter()) {
                *a += v;
            }
            // Unit-normalize rows.
            let mut mu = m.clone();
            for r in 0..=nfe {
                let row = &mut mu[r * dim..(r + 1) * dim];
                let n2 = crate::tensor::norm2(row);
                if n2 > 0.0 {
                    for v in row.iter_mut() {
                        *v /= n2;
                    }
                }
            }
            let cvu = cumulative_percent_variance(&mu, nfe + 1, dim, top_k);
            for (a, v) in acc_unit.iter_mut().zip(cvu.iter()) {
                *a += v;
            }
        }
        let row_a: Vec<String> = acc
            .iter()
            .map(|v| format!("{:.2}", v / n_traj as f64))
            .collect();
        ta.row(name, row_a);
        let row_u: Vec<String> = acc_unit
            .iter()
            .map(|v| format!("{:.2}", v / n_traj as f64))
            .collect();
        ta.row(format!("{name} (unit rows)"), row_u);

        // (b) stack the K trajectories' states at all nodes: rows = K*(N+1)
        // — we subsample nodes to keep the Gram matrix small.
        let stride = 10;
        let mut m = Vec::new();
        let mut rows = 0usize;
        for (j, xs) in run.xs.iter().enumerate() {
            if j % stride != 0 {
                continue;
            }
            for k in 0..n_traj {
                m.extend_from_slice(&xs[k * dim..(k + 1) * dim]);
                rows += 1;
            }
        }
        let cv = cumulative_percent_variance(&m, rows, dim, top_k);
        tb.row(name, cv.iter().map(|v| format!("{v:.2}")).collect());
    }
    vec![ta, tb]
}

/// Figure 3: the per-node truncation-error curve of Euler/DDIM at 10 NFE
/// vs the teacher, before (a) and after (b) PAS, plus the S-shape
/// statistics used to justify adaptive search.
pub fn fig3(opts: &ExpOpts) -> Vec<Table> {
    let bench = Bench::new("gmm-hd64", 0.0, opts);
    let sched = default_schedule(10);
    let solver = crate::solvers::registry::get("ddim").unwrap();
    let trainer = PasTrainer::new(default_train(opts, "ddim"));
    let tr = trainer
        .train(solver.as_ref(), bench.model.as_ref(), &sched, "gmm-hd64", false)
        .expect("training");
    let cols: Vec<String> = (0..=10).map(|j| format!("t{}", 10 - j)).collect();
    let cols_ref: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(
        "fig3",
        "mean L2 truncation error per node (DDIM 10 NFE vs Heun teacher), before/after PAS",
        &cols_ref,
    );
    t.row(
        "ddim (a)",
        tr.curve_uncorrected.iter().map(|v| format!("{v:.4}")).collect(),
    );
    t.row(
        "ddim + PAS (b)",
        tr.curve_corrected.iter().map(|v| format!("{v:.4}")).collect(),
    );
    let (pos, early, late) = s_shape_stats(&tr.curve_uncorrected);
    let mut s = Table::new(
        "fig3-sshape",
        "S-shape statistics of the uncorrected curve (max-growth position as step fraction; error-growth share in first/last third)",
        &["max-growth pos", "early third", "late third", "corrected steps"],
    );
    s.row(
        "ddim@10",
        vec![
            format!("{pos:.2}"),
            format!("{:.2}", early),
            format!("{:.2}", late),
            tr.trace.corrected_steps_str(),
        ],
    );
    vec![t, s]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_shapes() {
        let mut opts = ExpOpts::quick();
        opts.n_traj = 8;
        opts.n_ref = 64;
        let tables = fig2(&opts);
        assert_eq!(tables.len(), 2);
        // 3 datasets x (raw + unit-normalized rows).
        assert_eq!(tables[0].rows.len(), 6);
        // Single-trajectory variance must be high with few PCs — for the
        // raw rows and for the scale-free unit rows.
        for row_idx in [0, 1] {
            let row = &tables[0].rows[row_idx].1;
            let three_pc: f64 = row[2].parse().unwrap();
            assert!(
                three_pc > 95.0,
                "3 PCs should capture ~all variance (row {row_idx}): {three_pc}"
            );
        }
        // ...while cross-trajectory variance must NOT saturate by 3 PCs.
        let b_row = &tables[1].rows[0].1;
        let b3: f64 = b_row[2].parse().unwrap();
        assert!(b3 < 95.0, "K-trajectory variance should not saturate: {b3}");
    }
}
