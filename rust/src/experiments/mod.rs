//! Experiment harness: one runner per table and figure of the paper.
//!
//! Every runner takes [`ExpOpts`], returns one or more [`Table`]s, and is
//! reachable via `pas repro <id>` (plus `cargo bench e2e_tables` for the
//! timed variants). The mapping from paper artifacts to runners lives in
//! DESIGN.md §5; measured outputs are curated into EXPERIMENTS.md.
//!
//! Paper datasets map onto the stand-ins of `data::registry` (DESIGN.md
//! §3): gmm-hd64 ↔ CIFAR10, shells64 ↔ FFHQ, cond-gmm64 ↔ ImageNet /
//! Stable Diffusion, latent256 ↔ LSUN Bedroom. FID ↔ gFID.

pub mod common;
pub mod figures;
pub mod tables;
pub mod ablations;

use std::path::PathBuf;

/// A rendered result table.
#[derive(Clone, Debug)]
pub struct Table {
    pub id: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<String>)>,
}

impl Table {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Table {
        Table {
            id: id.to_string(),
            title: title.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, label: impl Into<String>, cells: Vec<String>) {
        self.rows.push((label.into(), cells));
    }

    pub fn markdown(&self) -> String {
        let mut s = format!("### {} — {}\n\n", self.id, self.title);
        s.push_str("| method |");
        for c in &self.columns {
            s.push_str(&format!(" {c} |"));
        }
        s.push_str("\n|---|");
        for _ in &self.columns {
            s.push_str("---|");
        }
        s.push('\n');
        for (label, cells) in &self.rows {
            s.push_str(&format!("| {label} |"));
            for c in cells {
                s.push_str(&format!(" {c} |"));
            }
            s.push('\n');
        }
        s.push('\n');
        s
    }
}

/// Global experiment options (sizes shrink with `--quick` for CI).
#[derive(Clone, Debug)]
pub struct ExpOpts {
    /// Samples per gFID evaluation (paper: 50k; default here 2048).
    pub n_samples: usize,
    /// Reference-set size for gFID.
    pub n_ref: usize,
    /// Ground-truth trajectories for PAS training.
    pub n_traj: usize,
    /// Training epochs.
    pub epochs: usize,
    pub seed: u64,
    pub out_dir: PathBuf,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            n_samples: 2048,
            n_ref: 8192,
            n_traj: 256,
            epochs: 48,
            seed: 0,
            out_dir: PathBuf::from("results"),
        }
    }
}

impl ExpOpts {
    /// Small sizes for tests / smoke runs.
    pub fn quick() -> ExpOpts {
        ExpOpts {
            n_samples: 256,
            n_ref: 1024,
            n_traj: 64,
            epochs: 16,
            ..ExpOpts::default()
        }
    }
}

/// All experiment ids, in the order DESIGN.md lists them.
pub const ALL: &[&str] = &[
    "fig2", "fig3", "table2", "table3", "table5", "table6", "fig6a", "fig6b", "fig6c", "fig6d",
    "fig7", "table8", "table9", "table11", "ablate-param",
];

/// Run one experiment by id.
pub fn run(id: &str, opts: &ExpOpts) -> Result<Vec<Table>, String> {
    match id {
        "fig2" => Ok(figures::fig2(opts)),
        "fig3" => Ok(figures::fig3(opts)),
        "table2" => Ok(tables::table2(opts)),
        "table3" => Ok(tables::table3(opts)),
        "table5" => Ok(tables::table5(opts)),
        "table6" | "table1" => Ok(tables::table6(opts)),
        "fig6a" | "table7" => Ok(ablations::fig6a(opts)),
        "fig6b" => Ok(ablations::fig6b(opts)),
        "fig6c" => Ok(ablations::fig6c(opts)),
        "fig6d" => Ok(ablations::fig6d(opts)),
        "fig7" => Ok(ablations::fig7(opts)),
        "table8" => Ok(ablations::table8(opts)),
        "table9" => Ok(ablations::table9(opts)),
        "table11" | "table10" => Ok(ablations::table11(opts)),
        "ablate-param" => Ok(ablations::ablate_param(opts)),
        _ => Err(format!("unknown experiment {id}; known: {ALL:?}")),
    }
}

/// Run an experiment and write its markdown to `<out_dir>/<id>.md`.
pub fn run_and_save(id: &str, opts: &ExpOpts) -> Result<Vec<Table>, String> {
    let tables = run(id, opts)?;
    std::fs::create_dir_all(&opts.out_dir).map_err(|e| e.to_string())?;
    let mut md = String::new();
    for t in &tables {
        md.push_str(&t.markdown());
    }
    std::fs::write(opts.out_dir.join(format!("{id}.md")), md).map_err(|e| e.to_string())?;
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("t0", "demo", &["5", "10"]);
        t.row("ddim", vec!["49.68".into(), "15.69".into()]);
        let md = t.markdown();
        assert!(md.contains("| ddim | 49.68 | 15.69 |"));
        assert!(md.contains("### t0"));
    }

    #[test]
    fn unknown_id_errors() {
        assert!(run("nope", &ExpOpts::quick()).is_err());
    }
}
