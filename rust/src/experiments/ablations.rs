//! Ablation runners: Fig. 6a–d, Fig. 7, Table 8 (tolerance), Table 9
//! (teacher solver), Table 10/11 (iPNDM order ± PAS with L1/L2 metrics),
//! plus the parameterization ablation this reproduction adds.

use super::common::{default_train, eval_cell, fmt_gfid, Bench, Cell};
use super::{ExpOpts, Table};
use crate::metrics::{mean_l1, mean_l2};
use crate::pas::coords::ScaleMode;
use crate::pas::correct::CorrectedSampler;
use crate::pas::train::{Loss, PasTrainer};
use crate::schedule::default_schedule;
use crate::solvers::run_solver;
use crate::traj::{ground_truth, sample_prior};
use crate::util::rng::Pcg64;

const NFE_GRID: [usize; 4] = [5, 6, 8, 10];
const ABLATION_DS: &str = "gmm-hd64"; // the paper ablates on CIFAR10

fn cell_with(
    solver: &str,
    nfe: usize,
    opts: &ExpOpts,
    f: impl FnOnce(&mut crate::pas::train::TrainConfig),
) -> Cell {
    let mut cfg = default_train(opts, solver);
    f(&mut cfg);
    Cell {
        train_overrides: Some(cfg),
        ..Cell::pas(solver, nfe)
    }
}

/// Fig. 6a / Table 7: adaptive search on/off. PAS(-AS) corrects *every*
/// step and should be worse than plain DDIM.
pub fn fig6a(opts: &ExpOpts) -> Vec<Table> {
    let bench = Bench::new(ABLATION_DS, 0.0, opts);
    let mut t = Table::new(
        "fig6a",
        "adaptive search ablation (gFID; PAS(-AS) corrects every step)",
        &["5", "6", "8", "10"],
    );
    // Plain + full PAS rows via the standard path.
    for (label, mk) in [
        ("ddim", Cell::plain as fn(&str, usize) -> Cell),
        ("ddim + PAS", Cell::pas as fn(&str, usize) -> Cell),
    ] {
        let cells: Vec<String> = NFE_GRID
            .iter()
            .map(|&n| fmt_gfid(eval_cell(&bench, &mk("ddim", n), opts).map(|r| r.gfid)))
            .collect();
        t.row(label, cells);
    }
    // PAS(-AS): train with force_all_steps, then evaluate.
    let cells: Vec<String> = NFE_GRID
        .iter()
        .map(|&nfe| {
            let solver = crate::solvers::registry::get("ddim").unwrap();
            let sched = default_schedule(nfe);
            let trainer = PasTrainer::new(default_train(opts, "ddim"));
            let tr = trainer
                .train(solver.as_ref(), bench.model.as_ref(), &sched, ABLATION_DS, true)
                .unwrap();
            let mut rng = Pcg64::seed_stream(opts.seed ^ 0xa5, nfe as u64);
            let x_t = sample_prior(&mut rng, opts.n_samples, bench.dim(), sched.t_max());
            let run = CorrectedSampler::sample(
                &tr.dict,
                solver.as_ref(),
                bench.model.as_ref(),
                &x_t,
                opts.n_samples,
                &sched,
            );
            fmt_gfid(Some(crate::metrics::gfid(
                &run.x0,
                opts.n_samples,
                &bench.reference,
                bench.n_ref,
                bench.dim(),
            )))
        })
        .collect();
    t.row("ddim + PAS (-AS)", cells);
    vec![t]
}

/// Fig. 6b: loss-function ablation.
pub fn fig6b(opts: &ExpOpts) -> Vec<Table> {
    let bench = Bench::new(ABLATION_DS, 0.0, opts);
    let mut t = Table::new(
        "fig6b",
        "loss function ablation (gFID, DDIM + PAS)",
        &["5", "6", "8", "10"],
    );
    for (label, loss) in [
        ("l1", Loss::L1),
        ("l2", Loss::L2),
        ("pseudo-huber", Loss::PseudoHuber { c: 0.03 }),
        ("rpfeat (lpips stand-in)", Loss::RpFeat { proj_dim: 16, seed: 7 }),
    ] {
        let cells: Vec<String> = NFE_GRID
            .iter()
            .map(|&n| {
                let c = cell_with("ddim", n, opts, |cfg| cfg.loss = loss.clone());
                fmt_gfid(eval_cell(&bench, &c, opts).map(|r| r.gfid))
            })
            .collect();
        t.row(label, cells);
    }
    vec![t]
}

/// Fig. 6c: number of basis vectors (1–4).
pub fn fig6c(opts: &ExpOpts) -> Vec<Table> {
    let bench = Bench::new(ABLATION_DS, 0.0, opts);
    let mut t = Table::new(
        "fig6c",
        "number of orthogonal basis vectors (gFID, DDIM + PAS)",
        &["5", "6", "8", "10"],
    );
    let base: Vec<String> = NFE_GRID
        .iter()
        .map(|&n| fmt_gfid(eval_cell(&bench, &Cell::plain("ddim", n), opts).map(|r| r.gfid)))
        .collect();
    t.row("ddim (no PAS)", base);
    for k in 1..=4usize {
        let cells: Vec<String> = NFE_GRID
            .iter()
            .map(|&n| {
                let c = cell_with("ddim", n, opts, |cfg| cfg.n_basis = k);
                fmt_gfid(eval_cell(&bench, &c, opts).map(|r| r.gfid))
            })
            .collect();
        t.row(format!("{k} basis"), cells);
    }
    vec![t]
}

/// Fig. 6d: number of ground-truth trajectories.
pub fn fig6d(opts: &ExpOpts) -> Vec<Table> {
    let bench = Bench::new(ABLATION_DS, 0.0, opts);
    let mut t = Table::new(
        "fig6d",
        "number of ground-truth trajectories (gFID, DDIM + PAS; paper sweeps 500-20k, scaled here)",
        &["5", "6", "8", "10"],
    );
    for n_traj in [32usize, 64, 128, 256, 512] {
        if n_traj > opts.n_traj * 4 {
            continue;
        }
        let cells: Vec<String> = NFE_GRID
            .iter()
            .map(|&n| {
                let c = cell_with("ddim", n, opts, |cfg| cfg.n_traj = n_traj);
                fmt_gfid(eval_cell(&bench, &c, opts).map(|r| r.gfid))
            })
            .collect();
        t.row(format!("{n_traj} traj"), cells);
    }
    vec![t]
}

/// Fig. 7: learning-rate sweep for DDIM and iPNDM.
pub fn fig7(opts: &ExpOpts) -> Vec<Table> {
    let bench = Bench::new(ABLATION_DS, 0.0, opts);
    let mut out = Vec::new();
    for solver in ["ddim", "ipndm"] {
        let mut t = Table::new(
            "fig7",
            &format!("learning-rate ablation ({solver} + PAS, gFID)"),
            &["5", "6", "8", "10"],
        );
        let base: Vec<String> = NFE_GRID
            .iter()
            .map(|&n| fmt_gfid(eval_cell(&bench, &Cell::plain(solver, n), opts).map(|r| r.gfid)))
            .collect();
        t.row(format!("{solver} (no PAS)"), base);
        for lr in [1e-4, 1e-3, 1e-2, 1e-1, 1.0] {
            let cells: Vec<String> = NFE_GRID
                .iter()
                .map(|&n| {
                    let c = cell_with(solver, n, opts, |cfg| cfg.lr = lr);
                    fmt_gfid(eval_cell(&bench, &c, opts).map(|r| r.gfid))
                })
                .collect();
            t.row(format!("lr={lr:.0e}"), cells);
        }
        out.push(t);
    }
    out
}

/// Table 8: tolerance sweep.
pub fn table8(opts: &ExpOpts) -> Vec<Table> {
    let bench = Bench::new(ABLATION_DS, 0.0, opts);
    let mut t = Table::new(
        "table8",
        "tolerance tau ablation (gFID)",
        &["5", "6", "8", "10"],
    );
    for solver in ["ddim", "ipndm"] {
        let base: Vec<String> = NFE_GRID
            .iter()
            .map(|&n| fmt_gfid(eval_cell(&bench, &Cell::plain(solver, n), opts).map(|r| r.gfid)))
            .collect();
        t.row(format!("{solver} (no PAS)"), base);
        for tau in [1e-1, 1e-2, 1e-3, 1e-4] {
            let cells: Vec<String> = NFE_GRID
                .iter()
                .map(|&n| {
                    let c = cell_with(solver, n, opts, |cfg| cfg.tau = tau);
                    fmt_gfid(eval_cell(&bench, &c, opts).map(|r| r.gfid))
                })
                .collect();
            t.row(format!("{solver} tau={tau:.0e}"), cells);
        }
    }
    vec![t]
}

/// Table 9: teacher-solver ablation (Heun / DDIM / DPM-Solver-2 teachers).
pub fn table9(opts: &ExpOpts) -> Vec<Table> {
    let mut out = Vec::new();
    for ds_name in ["gmm-hd64", "shells64"] {
        let bench = Bench::new(ds_name, 0.0, opts);
        let mut t = Table::new(
            "table9",
            &format!("ground-truth teacher-solver ablation on {ds_name} (DDIM + PAS, gFID)"),
            &["5", "6", "8", "10"],
        );
        let base: Vec<String> = NFE_GRID
            .iter()
            .map(|&n| fmt_gfid(eval_cell(&bench, &Cell::plain("ddim", n), opts).map(|r| r.gfid)))
            .collect();
        t.row("ddim (no PAS)", base);
        for teacher in ["heun", "ddim", "dpm2"] {
            let cells: Vec<String> = NFE_GRID
                .iter()
                .map(|&n| {
                    let c = cell_with("ddim", n, opts, |cfg| cfg.teacher = teacher.into());
                    fmt_gfid(eval_cell(&bench, &c, opts).map(|r| r.gfid))
                })
                .collect();
            t.row(format!("teacher={teacher}"), cells);
        }
        out.push(t);
    }
    out
}

/// Table 10/11: iPNDM order 1–4 ± PAS, gFID plus L1/L2 endpoint metrics
/// against the teacher (the paper's "order-4 FID doesn't improve but
/// L1/L2 do" observation).
pub fn table11(opts: &ExpOpts) -> Vec<Table> {
    let bench = Bench::new(ABLATION_DS, 0.0, opts);
    let mut t = Table::new(
        "table11",
        "iPNDM order ablation (gFID)",
        &["5", "6", "8", "10"],
    );
    for order in 1..=4usize {
        let name = format!("ipndm{order}");
        for pas in [false, true] {
            let label = if pas {
                format!("{name} + PAS")
            } else {
                name.clone()
            };
            let cells: Vec<String> = NFE_GRID
                .iter()
                .map(|&n| {
                    let c = if pas {
                        Cell::pas(&name, n)
                    } else {
                        Cell::plain(&name, n)
                    };
                    fmt_gfid(eval_cell(&bench, &c, opts).map(|r| r.gfid))
                })
                .collect();
            t.row(label, cells);
        }
    }

    // L1/L2 endpoint metrics for order 4 (Table 11 bottom block).
    let mut t2 = Table::new(
        "table11-l1l2",
        "ipndm4 ± PAS: endpoint L2(MSE)/L1 vs teacher (per-dim)",
        &["5", "6", "8", "10"],
    );
    let solver = crate::solvers::registry::get("ipndm4").unwrap();
    let teacher = crate::solvers::registry::get("heun").unwrap();
    let mut rows: Vec<(String, Vec<String>)> = vec![
        ("ipndm4 L2".into(), vec![]),
        ("ipndm4+PAS L2".into(), vec![]),
        ("ipndm4 L1".into(), vec![]),
        ("ipndm4+PAS L1".into(), vec![]),
    ];
    for &nfe in &NFE_GRID {
        let sched = default_schedule(nfe);
        let n = opts.n_samples.min(512);
        let dim = bench.dim();
        let mut rng = Pcg64::seed_stream(opts.seed ^ 0x11, nfe as u64);
        let x_t = sample_prior(&mut rng, n, dim, sched.t_max());
        let gt = ground_truth(teacher.as_ref(), bench.model.as_ref(), &x_t, n, &sched, 100);
        let plain = run_solver(solver.as_ref(), bench.model.as_ref(), &x_t, n, &sched, None);
        let trainer = PasTrainer::new({
            let mut c = default_train(opts, "ipndm4");
            c.loss = Loss::L2;
            c
        });
        let tr = trainer
            .train(solver.as_ref(), bench.model.as_ref(), &sched, ABLATION_DS, false)
            .unwrap();
        let corr = CorrectedSampler::sample(
            &tr.dict,
            solver.as_ref(),
            bench.model.as_ref(),
            &x_t,
            n,
            &sched,
        );
        let gt0 = gt.node(gt.n_nodes() - 1);
        rows[0].1.push(format!("{:.5}", mean_l2(&plain.x0, gt0, n, dim)));
        rows[1].1.push(format!("{:.5}", mean_l2(&corr.x0, gt0, n, dim)));
        rows[2].1.push(format!("{:.5}", mean_l1(&plain.x0, gt0, n, dim)));
        rows[3].1.push(format!("{:.5}", mean_l1(&corr.x0, gt0, n, dim)));
    }
    for (l, c) in rows {
        t2.row(l, c);
    }
    vec![t, t2]
}

/// Extra ablation (ours): absolute vs relative coordinate parameterization
/// (DESIGN.md §3 documents the deviation).
pub fn ablate_param(opts: &ExpOpts) -> Vec<Table> {
    let mut out = Vec::new();
    for ds_name in ["gmm2d", "gmm-hd64"] {
        let bench = Bench::new(ds_name, 0.0, opts);
        let mut t = Table::new(
            "ablate-param",
            &format!("coordinate parameterization on {ds_name} (DDIM + PAS, gFID)"),
            &["5", "6", "8", "10"],
        );
        let base: Vec<String> = NFE_GRID
            .iter()
            .map(|&n| fmt_gfid(eval_cell(&bench, &Cell::plain("ddim", n), opts).map(|r| r.gfid)))
            .collect();
        t.row("ddim (no PAS)", base);
        for (label, mode) in [
            ("absolute (paper-literal)", ScaleMode::Absolute),
            ("relative (ours)", ScaleMode::Relative),
        ] {
            let cells: Vec<String> = NFE_GRID
                .iter()
                .map(|&n| {
                    let c = cell_with("ddim", n, opts, |cfg| cfg.scale_mode = mode);
                    fmt_gfid(eval_cell(&bench, &c, opts).map(|r| r.gfid))
                })
                .collect();
            t.row(label, cells);
        }
        out.push(t);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6c_more_bases_never_fail() {
        let mut opts = ExpOpts::quick();
        opts.n_samples = 128;
        opts.n_traj = 32;
        opts.epochs = 8;
        let bench = Bench::new("gmm2d", 0.0, &opts);
        for k in 1..=4usize {
            let c = cell_with("ddim", 6, &opts, |cfg| cfg.n_basis = k);
            let r = eval_cell(&bench, &c, &opts).unwrap();
            assert!(r.gfid.is_finite());
        }
    }

    #[test]
    fn table8_high_tau_disables_correction() {
        let mut opts = ExpOpts::quick();
        opts.n_samples = 128;
        opts.n_traj = 32;
        opts.epochs = 8;
        let bench = Bench::new("gmm2d", 0.0, &opts);
        // With an absurd tolerance nothing passes the rule → dict empty →
        // gFID equals plain DDIM.
        let c = cell_with("ddim", 6, &opts, |cfg| cfg.tau = 1e9);
        let r = eval_cell(&bench, &c, &opts).unwrap();
        let plain = eval_cell(&bench, &Cell::plain("ddim", 6), &opts).unwrap();
        assert!((r.gfid - plain.gfid).abs() < 1e-9, "{} vs {}", r.gfid, plain.gfid);
    }
}
