//! Source model for the `pas lint` scanner: comment/string masking,
//! item-scope tracking, and suppression-comment collection.
//!
//! The scanner is deliberately a *lexer*, not a parser: it understands
//! exactly enough Rust surface syntax to (a) know which bytes are code
//! versus comment versus string-literal contents, (b) know which lines sit
//! inside `#[cfg(test)]` items, (c) know which function body a line
//! belongs to and whether that function carries
//! `#[target_feature(enable = "avx2…")]`, and (d) attach
//! `lint:allow(rule, reason)` comments to the code they cover. Everything
//! heavier (type resolution, macro expansion) is out of scope by design —
//! the rules in [`super::rules`] are written so that lexical evidence is
//! sufficient, and anything the lexer cannot prove is escalated to a
//! finding that a human either fixes or suppresses with a reason.

/// One source line, split into its code and comment halves.
pub struct Line {
    /// Raw line text (attributes are matched on this, since their
    /// arguments — e.g. `enable = "avx2,fma"` — live in string literals).
    pub raw: String,
    /// Code with comments removed and string/char-literal *contents*
    /// blanked (quotes retained so token boundaries survive).
    pub code: String,
    /// Concatenated comment text on this line (line, block, and doc
    /// comments).
    pub comment: String,
}

impl Line {
    /// Comment-only or blank or attribute-only: a line that can sit
    /// between a suppression / SAFETY comment and the code it covers.
    pub fn is_annotation(&self) -> bool {
        let t = self.code.trim();
        t.is_empty() || t.starts_with("#[") || t.starts_with("#![")
    }
}

/// A function item scope, by line range.
pub struct FnScope {
    /// Line of the `fn` keyword (0-based).
    pub sig_line: usize,
    /// First line of the contiguous comment/attribute block above the
    /// signature (== `sig_line` when there is none).
    pub head_line: usize,
    /// Inclusive body line range (opening to closing brace).
    pub body: (usize, usize),
    /// Carries `#[target_feature(enable = "…avx2…")]`.
    pub target_feature_avx2: bool,
}

/// Scanned representation of one source file.
pub struct SourceFile {
    /// Path relative to the crate root, `/`-separated.
    pub rel: String,
    pub lines: Vec<Line>,
    /// Inclusive line ranges of `#[cfg(test)]`-gated items.
    pub test_regions: Vec<(usize, usize)>,
    /// Function scopes, in source order (outer before inner).
    pub fns: Vec<FnScope>,
    /// Suppression comments, in source order.
    pub allows: Vec<Allow>,
}

/// A parsed `lint:allow(rule, reason)` comment.
pub struct Allow {
    pub line: usize,
    pub rule: String,
    pub reason: String,
    /// Set by the rule passes when the suppression absorbs a finding.
    pub used: std::cell::Cell<bool>,
}

impl SourceFile {
    pub fn parse(rel: &str, src: &str) -> SourceFile {
        let lines = mask(src);
        let (test_regions, fns) = scopes(&lines);
        let allows = collect_allows(&lines);
        SourceFile {
            rel: rel.to_string(),
            lines,
            test_regions,
            fns,
            allows,
        }
    }

    /// Whether `line` is inside a `#[cfg(test)]` item.
    pub fn in_test(&self, line: usize) -> bool {
        self.test_regions.iter().any(|&(a, b)| (a..=b).contains(&line))
    }

    /// Innermost function scope containing `line`, if any.
    pub fn enclosing_fn(&self, line: usize) -> Option<&FnScope> {
        self.fns
            .iter()
            .filter(|f| (f.body.0..=f.body.1).contains(&line))
            .min_by_key(|f| f.body.1 - f.body.0)
    }

    /// Whether a finding of `rule` at `line` is covered by a suppression:
    /// on the same line, in the contiguous comment/attribute block
    /// directly above the statement, or attached to the enclosing
    /// function's head (covering the whole body). Marks the suppression
    /// used.
    pub fn suppressed(&self, rule: &str, line: usize) -> bool {
        // Same line, or the annotation block directly above it.
        let mut lo = line;
        while lo > 0 && self.lines[lo - 1].is_annotation() {
            lo -= 1;
        }
        for a in &self.allows {
            if a.rule == rule && (lo..=line).contains(&a.line) {
                a.used.set(true);
                return true;
            }
        }
        // Function-head coverage.
        if let Some(f) = self.enclosing_fn(line) {
            for a in &self.allows {
                if a.rule == rule && (f.head_line..=f.sig_line).contains(&a.line) {
                    a.used.set(true);
                    return true;
                }
            }
        }
        false
    }

    /// Whether any comment within `window` lines above `line` (or on the
    /// line itself), or in the contiguous comment/attribute block above
    /// the statement, contains `needle`.
    pub fn comment_above_contains(&self, line: usize, window: usize, needle: &str) -> bool {
        if self.lines[line].comment.contains(needle) {
            return true;
        }
        // Contiguous annotation block (doc comments over an `unsafe fn`
        // can be arbitrarily long).
        let mut l = line;
        while l > 0 && self.lines[l - 1].is_annotation() {
            l -= 1;
            if self.lines[l].comment.contains(needle) {
                return true;
            }
        }
        // Fixed window: covers one comment justifying a couple of
        // adjacent unsafe statements.
        for back in 1..=window {
            match line.checked_sub(back) {
                Some(l) if self.lines[l].comment.contains(needle) => return true,
                Some(_) => {}
                None => break,
            }
        }
        false
    }
}

/// Split source into per-line code/comment views. Handles line and
/// (nested) block comments, plain/raw/byte string literals, char
/// literals, and lifetimes.
fn mask(src: &str) -> Vec<Line> {
    #[derive(PartialEq)]
    enum St {
        Code,
        LineComment,
        BlockComment(usize),
        Str,
        RawStr(usize),
    }
    let mut st = St::Code;
    let mut out = Vec::new();
    let mut raw = String::new();
    let mut code = String::new();
    let mut comment = String::new();
    let b: Vec<char> = src.chars().collect();
    let mut i = 0;
    while i <= b.len() {
        let c = if i < b.len() { b[i] } else { '\n' };
        let at_end = i == b.len();
        if c == '\n' {
            if st == St::LineComment {
                st = St::Code;
            }
            if !(at_end && raw.is_empty()) {
                out.push(Line {
                    raw: std::mem::take(&mut raw),
                    code: std::mem::take(&mut code),
                    comment: std::mem::take(&mut comment),
                });
            }
            i += 1;
            continue;
        }
        raw.push(c);
        match st {
            St::Code => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = St::LineComment;
                    raw.push('/');
                    i += 2;
                    continue;
                }
                if c == '/' && next == Some('*') {
                    st = St::BlockComment(1);
                    raw.push('*');
                    i += 2;
                    continue;
                }
                if c == '"' {
                    code.push('"');
                    st = St::Str;
                    i += 1;
                    continue;
                }
                // Raw / byte string openers: r", r#", br", b".
                let prev_ident = i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_');
                if !prev_ident && (c == 'r' || c == 'b') {
                    let mut j = i;
                    if c == 'b' && b.get(j + 1) == Some(&'r') {
                        j += 1;
                    }
                    if c == 'b' && b.get(j + 1) == Some(&'"') {
                        // b"...": plain byte string.
                        code.push(c);
                        raw.push('"');
                        code.push('"');
                        st = St::Str;
                        i = j + 2;
                        continue;
                    }
                    let opener = (b.get(j + 1) == Some(&'#') || b.get(j + 1) == Some(&'"'))
                        && (c == 'r' || (c == 'b' && j > i));
                    if opener {
                        let mut hashes = 0;
                        let mut k = j + 1;
                        while b.get(k) == Some(&'#') {
                            hashes += 1;
                            k += 1;
                        }
                        if b.get(k) == Some(&'"') {
                            for (off, &ch) in b[i..=k].iter().enumerate() {
                                if off > 0 {
                                    raw.push(ch);
                                }
                                code.push(if ch == '"' { '"' } else { ' ' });
                            }
                            st = St::RawStr(hashes);
                            i = k + 1;
                            continue;
                        }
                    }
                }
                if c == '\'' {
                    // Char literal vs lifetime: a char literal closes with
                    // a quote after one (possibly escaped) character.
                    if let Some(&n1) = b.get(i + 1) {
                        if n1 == '\\' {
                            // Escaped char literal: consume to closing quote.
                            code.push('\'');
                            let mut k = i + 2;
                            while k < b.len() && b[k] != '\'' && b[k] != '\n' {
                                raw.push(b[k]);
                                code.push(' ');
                                k += 1;
                            }
                            if b.get(k) == Some(&'\'') {
                                raw.push('\'');
                                code.push('\'');
                                k += 1;
                            }
                            // raw already got chars above; continue after.
                            raw.push(n1);
                            i = k;
                            continue;
                        } else if b.get(i + 2) == Some(&'\'') {
                            code.push('\'');
                            code.push(' ');
                            code.push('\'');
                            raw.push(n1);
                            raw.push('\'');
                            i += 3;
                            continue;
                        }
                    }
                    // Lifetime: keep as code.
                    code.push('\'');
                    i += 1;
                    continue;
                }
                code.push(c);
                i += 1;
            }
            St::LineComment => {
                comment.push(c);
                i += 1;
            }
            St::BlockComment(depth) => {
                let next = b.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    raw.push('/');
                    if depth == 1 {
                        st = St::Code;
                    } else {
                        st = St::BlockComment(depth - 1);
                    }
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    raw.push('*');
                    st = St::BlockComment(depth + 1);
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    if let Some(&n) = b.get(i + 1) {
                        if n == '\n' {
                            // Line continuation: let the main loop flush
                            // the line so numbering stays aligned.
                            code.push(' ');
                            i += 1;
                            continue;
                        }
                        raw.push(n);
                        code.push(' ');
                        code.push(' ');
                        i += 2;
                        continue;
                    }
                }
                if c == '"' {
                    code.push('"');
                    st = St::Code;
                } else {
                    code.push(' ');
                }
                i += 1;
            }
            St::RawStr(hashes) => {
                if c == '"' {
                    let mut ok = true;
                    for h in 0..hashes {
                        if b.get(i + 1 + h) != Some(&'#') {
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        code.push('"');
                        for _ in 0..hashes {
                            raw.push('#');
                            code.push(' ');
                        }
                        st = St::Code;
                        i += 1 + hashes;
                        continue;
                    }
                }
                code.push(' ');
                i += 1;
            }
        }
    }
    out
}

/// Second pass: `#[cfg(test)]` item ranges and function scopes via brace
/// depth tracking over the masked code.
fn scopes(lines: &[Line]) -> (Vec<(usize, usize)>, Vec<FnScope>) {
    let mut test_regions = Vec::new();
    let mut fns = Vec::new();

    // Pending attribute state: set when the attribute is seen, consumed
    // by the next `{` (item body) or cancelled by a top-level `;`
    // (bodiless item, e.g. a trait method declaration).
    let mut pending_test: Option<usize> = None;
    let mut pending_tf = false;
    // Pending `fn` signature awaiting its body brace.
    let mut pending_fn: Option<(usize, bool)> = None; // (sig_line, tf)

    enum Open {
        // `fns` index, plus whether a `#[cfg(test)]` attribute was
        // pending when the body opened (a test helper fn at item level).
        Fn(usize, Option<usize>),
        Test(usize),
        Other,
    }
    let mut stack: Vec<Open> = Vec::new();
    // Paren/bracket nesting: a `;` inside `[u8; 32]` or a signature's
    // parens must not cancel the pending `fn`.
    let mut paren = 0usize;

    for (ln, line) in lines.iter().enumerate() {
        let raw = &line.raw;
        // Attribute detection on raw text (arguments live in strings).
        if raw.contains("#[cfg(test)") || raw.contains("#[cfg(all(test") {
            pending_test = Some(ln);
        }
        if raw.contains("#[target_feature") && raw.contains("avx2") {
            pending_tf = true;
        }
        // `fn` keyword detection on masked code (`fn(` type positions
        // are excluded by the keyword matcher).
        if find_fn_keyword(&line.code).is_some() && pending_fn.is_none() {
            pending_fn = Some((ln, pending_tf));
            pending_tf = false;
        }
        for c in line.code.chars() {
            match c {
                '(' | '[' => paren += 1,
                ')' | ']' => paren = paren.saturating_sub(1),
                '{' => {
                    if let Some((sig_line, tf)) = pending_fn.take() {
                        let mut head = sig_line;
                        while head > 0 && lines[head - 1].is_annotation() {
                            head -= 1;
                        }
                        fns.push(FnScope {
                            sig_line,
                            head_line: head,
                            body: (ln, ln), // end patched on close
                            target_feature_avx2: tf,
                        });
                        stack.push(Open::Fn(fns.len() - 1, pending_test.take()));
                    } else if let Some(start) = pending_test.take() {
                        stack.push(Open::Test(start));
                    } else {
                        stack.push(Open::Other);
                    }
                }
                '}' => match stack.pop() {
                    Some(Open::Test(start)) => test_regions.push((start, ln)),
                    Some(Open::Fn(idx, test_from)) => {
                        fns[idx].body.1 = ln;
                        if let Some(start) = test_from {
                            test_regions.push((start, ln));
                        }
                    }
                    _ => {}
                },
                ';' if paren == 0 => {
                    // Bodiless item ends: cancel pending attributes.
                    pending_fn = None;
                    pending_test = None;
                }
                _ => {}
            }
        }
    }
    // Unclosed scopes (truncated file): close at EOF.
    let last = lines.len().saturating_sub(1);
    while let Some(open) = stack.pop() {
        match open {
            Open::Test(start) => test_regions.push((start, last)),
            Open::Fn(idx, test_from) => {
                fns[idx].body.1 = last;
                if let Some(start) = test_from {
                    test_regions.push((start, last));
                }
            }
            Open::Other => {}
        }
    }
    (test_regions, fns)
}

/// Column of a standalone `fn` keyword in masked code, if present.
fn find_fn_keyword(code: &str) -> Option<usize> {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(pos) = code[from..].find("fn") {
        let at = from + pos;
        let before_ok = at == 0 || {
            let c = bytes[at - 1] as char;
            !(c.is_alphanumeric() || c == '_')
        };
        let after = bytes.get(at + 2).map(|&b| b as char);
        let after_ok = matches!(after, None | Some(' ') | Some('\t'));
        if before_ok && after_ok {
            return Some(at);
        }
        from = at + 2;
    }
    None
}

/// Parse `lint:allow(rule, reason)` comments. The directive must be the
/// comment's leading content (`// lint:allow(...)`) so prose that merely
/// *mentions* the syntax (docs, this file) is not treated as a
/// suppression.
fn collect_allows(lines: &[Line]) -> Vec<Allow> {
    let mut out = Vec::new();
    for (ln, line) in lines.iter().enumerate() {
        let c = line.comment.trim();
        let Some(rest) = c.strip_prefix("lint:allow(") else {
            continue;
        };
        let Some(close) = rest.find(')') else {
            continue;
        };
        let inner = &rest[..close];
        let (rule, reason) = match inner.find(',') {
            Some(comma) => (
                inner[..comma].trim().to_string(),
                inner[comma + 1..].trim().to_string(),
            ),
            None => (inner.trim().to_string(), String::new()),
        };
        out.push(Allow {
            line: ln,
            rule,
            reason,
            used: std::cell::Cell::new(false),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masking_strips_comments_and_strings() {
        let src = r#"let a = "unsafe vec![]"; // unsafe in comment
let b = 'x';
/* block unsafe */ let c = 1;
"#;
        let lines = mask(src);
        assert!(!lines[0].code.contains("unsafe"));
        assert!(lines[0].comment.contains("unsafe in comment"));
        assert!(!lines[2].code.contains("unsafe"));
        assert!(lines[2].comment.contains("block unsafe"));
        assert!(lines[2].code.contains("let c = 1;"));
    }

    #[test]
    fn masking_handles_raw_strings_and_lifetimes() {
        let src = "let s = r#\"vec![inside]\"#;\nfn f<'a>(x: &'a str) {}\n";
        let lines = mask(src);
        assert!(!lines[0].code.contains("vec!"));
        assert!(lines[1].code.contains("'a"));
    }

    #[test]
    fn cfg_test_regions_and_fn_scopes() {
        let src = "\
fn hot() {
    let x = 1;
}
#[cfg(test)]
mod tests {
    fn helper() {}
}
";
        let f = SourceFile::parse("x.rs", src);
        assert!(!f.in_test(1));
        assert!(f.in_test(5));
        let scope = f.enclosing_fn(1).unwrap();
        assert_eq!(scope.sig_line, 0);
        assert!(!scope.target_feature_avx2);
    }

    #[test]
    fn target_feature_attr_marks_fn() {
        let src = "\
#[target_feature(enable = \"avx2,fma\")]
unsafe fn kernel() {
    let v = 1;
}
fn plain() {}
";
        let f = SourceFile::parse("x.rs", src);
        assert!(f.enclosing_fn(2).unwrap().target_feature_avx2);
        assert_eq!(f.enclosing_fn(2).unwrap().head_line, 0);
    }

    #[test]
    fn fn_pointer_type_does_not_open_scope() {
        let src = "\
struct S {
    cb: fn(i32) -> i32,
}
fn real() {
    let y = 2;
}
";
        let f = SourceFile::parse("x.rs", src);
        // Line 4 must resolve to `real`, not a phantom scope from the
        // fn-pointer field.
        assert_eq!(f.enclosing_fn(4).unwrap().sig_line, 3);
    }

    #[test]
    fn allows_parse_rule_and_reason() {
        let src = "// lint:allow(hot-path-alloc, cold constructor)\nlet v = 1;\n";
        let f = SourceFile::parse("x.rs", src);
        assert_eq!(f.allows.len(), 1);
        assert_eq!(f.allows[0].rule, "hot-path-alloc");
        assert_eq!(f.allows[0].reason, "cold constructor");
        assert!(f.suppressed("hot-path-alloc", 1));
        assert!(!f.suppressed("server-panic", 1));
    }

    #[test]
    fn fn_head_suppression_covers_body() {
        let src = "\
// lint:allow(hot-path-alloc, constructor allocates once)
fn build() {
    let v = 1;
    let w = 2;
}
";
        let f = SourceFile::parse("x.rs", src);
        assert!(f.suppressed("hot-path-alloc", 3));
    }

    #[test]
    fn safety_comment_window() {
        let src = "\
// SAFETY: ranges are disjoint.
let a = 1;
let b = 2;
";
        let f = SourceFile::parse("x.rs", src);
        assert!(f.comment_above_contains(1, 6, "SAFETY"));
        assert!(f.comment_above_contains(2, 6, "SAFETY"));
        assert!(!f.comment_above_contains(2, 0, "SAFETY"));
    }
}
