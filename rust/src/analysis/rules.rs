//! The six lint rules. Each pass takes scanned sources plus whatever
//! raw auxiliary text it needs (tests, benches, Cargo.toml) and pushes
//! [`Finding`]s. Rules consult [`SourceFile::suppressed`] so a
//! `// lint:allow(rule, reason)` at the site absorbs the finding.

use super::scan::SourceFile;
use super::{Finding, RuleId};

/// Hot-path modules under the static allocation ban (the compile-time
/// complement of `tests/alloc_audit.rs`). Paths are relative to the
/// crate root, `/`-separated.
pub const HOT_PATH_MODULES: &[&str] = &[
    "src/solvers/engine.rs",
    "src/tensor/gemm.rs",
    "src/pas/pca.rs",
    "src/pas/correct.rs",
    "src/server/metrics_export.rs",
];

/// Server request-path modules under the structured-errors contract.
pub const SERVER_PATH_MODULES: &[&str] = &[
    "src/server/mod.rs",
    "src/server/service.rs",
    "src/server/protocol.rs",
    "src/server/metrics_export.rs",
];

/// Allocation tokens banned in hot-path modules outside `#[cfg(test)]`.
const ALLOC_TOKENS: &[&str] = &[
    "vec!",
    "Vec::new",
    "to_vec",
    "Box::new",
    "format!",
    ".collect",
    "String::from",
];

/// Panic tokens banned on the server request path outside `#[cfg(test)]`.
const PANIC_TOKENS: &[&str] = &[
    ".unwrap(",
    ".expect(",
    "panic!",
    "unreachable!",
    "todo!",
    "unimplemented!",
];

fn push(
    out: &mut Vec<Finding>,
    suppressed: &mut usize,
    f: &SourceFile,
    rule: RuleId,
    line: usize,
    message: String,
) {
    if f.suppressed(rule.as_str(), line) {
        *suppressed += 1;
    } else {
        out.push(Finding {
            rule,
            file: f.rel.clone(),
            line: line + 1,
            message,
        });
    }
}

/// True if `code[pos..]` starts a standalone occurrence of `tok` (no
/// identifier character hugging either side, unless the token itself
/// starts/ends with a non-identifier character).
fn standalone(code: &str, pos: usize, tok: &str) -> bool {
    let bytes = code.as_bytes();
    let ident = |b: u8| (b as char).is_alphanumeric() || b == b'_';
    let first = tok.as_bytes()[0];
    let last = tok.as_bytes()[tok.len() - 1];
    if ident(first) && pos > 0 && ident(bytes[pos - 1]) {
        return false;
    }
    if ident(last) {
        if let Some(&next) = bytes.get(pos + tok.len()) {
            if ident(next) {
                return false;
            }
        }
    }
    true
}

/// All standalone occurrences of `tok` in `code`.
fn occurrences(code: &str, tok: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = code[from..].find(tok) {
        let at = from + p;
        if standalone(code, at, tok) {
            out.push(at);
        }
        from = at + tok.len();
    }
    out
}

/// Occurrences of identifier-prefix `tok` (e.g. `_mm256_`): only the
/// left boundary must be a non-identifier character — the token is
/// expected to continue (`_mm256_add_pd`).
fn prefix_occurrences(code: &str, tok: &str) -> Vec<usize> {
    let bytes = code.as_bytes();
    let ident = |b: u8| (b as char).is_alphanumeric() || b == b'_';
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = code[from..].find(tok) {
        let at = from + p;
        if at == 0 || !ident(bytes[at - 1]) {
            out.push(at);
        }
        from = at + tok.len();
    }
    out
}

/// Rule 1 — `safety-comment`: every `unsafe` keyword (fn, impl, trait,
/// block) must be justified by a comment containing `SAFETY` (or a
/// `# Safety` doc section) on the same line, in the contiguous
/// comment/attribute block above, or within a 6-line window above — one
/// comment may cover a couple of adjacent unsafe statements.
pub fn safety_comment(f: &SourceFile, out: &mut Vec<Finding>, suppressed: &mut usize) -> usize {
    let mut sites = 0;
    for (ln, line) in f.lines.iter().enumerate() {
        for _ in occurrences(&line.code, "unsafe") {
            sites += 1;
            let ok = f.comment_above_contains(ln, 6, "SAFETY")
                || f.comment_above_contains(ln, 6, "# Safety");
            if !ok {
                push(
                    out,
                    suppressed,
                    f,
                    RuleId::SafetyComment,
                    ln,
                    "`unsafe` without a `// SAFETY:` justification".to_string(),
                );
            }
        }
    }
    sites
}

/// Rule 2 — `simd-gating`: `_mm*` / `std::arch` identifiers only inside
/// `#[target_feature(enable = "avx2…")]` functions (or `use` items);
/// `fmadd` intrinsics only in the opt-in `avx2fma` tier of
/// `tensor/gemm.rs`.
pub fn simd_gating(f: &SourceFile, out: &mut Vec<Finding>, suppressed: &mut usize) -> usize {
    let mut sites = 0;
    for (ln, line) in f.lines.iter().enumerate() {
        let code = &line.code;
        let is_use = code.trim_start().starts_with("use ")
            || code.trim_start().starts_with("pub use ");
        let in_tf = f.enclosing_fn(ln).is_some_and(|s| s.target_feature_avx2);
        let mut flagged_gating = false;
        for tok in ["_mm256_", "_mm_", "std::arch"] {
            let hits = if tok.ends_with('_') {
                prefix_occurrences(code, tok)
            } else {
                occurrences(code, tok)
            };
            for _ in hits {
                sites += 1;
                if is_use || in_tf || flagged_gating {
                    continue;
                }
                flagged_gating = true; // one finding per line
                push(
                    out,
                    suppressed,
                    f,
                    RuleId::SimdGating,
                    ln,
                    format!(
                        "`{tok}` outside a #[target_feature(enable = \"avx2\")] function"
                    ),
                );
            }
        }
        // FMA containment: contraction changes the reduction order, so
        // fmadd intrinsics are confined to gemm.rs's opt-in tier. Plain
        // substring match: the token sits mid-identifier
        // (`_mm256_fmadd_pd`).
        let fma_hits = code.match_indices("fmadd").count();
        for _ in 0..fma_hits {
            sites += 1;
            let in_gemm = f.rel == "src/tensor/gemm.rs";
            let near_fma_tier = in_gemm
                && (0..=2).any(|back| {
                    ln.checked_sub(back)
                        .is_some_and(|l| f.lines[l].raw.contains("avx2_variant!(fma"))
                });
            if !(is_use && in_gemm) && !near_fma_tier {
                push(
                    out,
                    suppressed,
                    f,
                    RuleId::SimdGating,
                    ln,
                    "`fmadd` outside the opt-in `avx2fma` tier of tensor/gemm.rs \
                     (FMA contraction breaks bit-exactness)"
                        .to_string(),
                );
            }
        }
    }
    sites
}

/// Rule 3 — `hot-path-alloc`: allocation tokens banned in
/// [`HOT_PATH_MODULES`] outside `#[cfg(test)]`.
pub fn hot_path_alloc(f: &SourceFile, out: &mut Vec<Finding>, suppressed: &mut usize) -> usize {
    if !HOT_PATH_MODULES.contains(&f.rel.as_str()) {
        return 0;
    }
    let mut sites = 0;
    for (ln, line) in f.lines.iter().enumerate() {
        if f.in_test(ln) {
            continue;
        }
        for tok in ALLOC_TOKENS {
            for _ in occurrences(&line.code, tok) {
                sites += 1;
                push(
                    out,
                    suppressed,
                    f,
                    RuleId::HotPathAlloc,
                    ln,
                    format!("allocation `{tok}` in pinned hot-path module"),
                );
            }
        }
    }
    sites
}

/// Rule 4 — `server-panic`: no `unwrap`/`expect`/`panic!` on the server
/// request path outside `#[cfg(test)]`. Mutex/RwLock poisoning unwraps
/// (`lock().unwrap()`, `read().unwrap()`, `write().unwrap()`) are exempt
/// by policy: a poisoned lock means a panic already escaped on another
/// thread, and crashing beats serving from torn state.
pub fn server_panic(f: &SourceFile, out: &mut Vec<Finding>, suppressed: &mut usize) -> usize {
    if !SERVER_PATH_MODULES.contains(&f.rel.as_str()) {
        return 0;
    }
    let mut sites = 0;
    for (ln, line) in f.lines.iter().enumerate() {
        if f.in_test(ln) {
            continue;
        }
        for tok in PANIC_TOKENS {
            for at in occurrences(&line.code, tok) {
                sites += 1;
                if tok.starts_with('.') && lock_poison_exempt(f, ln, at) {
                    continue;
                }
                push(
                    out,
                    suppressed,
                    f,
                    RuleId::ServerPanic,
                    ln,
                    format!("`{tok}` on the server request path (structured-errors contract)"),
                );
            }
        }
    }
    sites
}

/// Whether the `.unwrap(`/`.expect(` at `(ln, col)` is immediately
/// chained onto `lock()` / `read()` / `write()` — possibly across a line
/// break from rustfmt chain wrapping.
fn lock_poison_exempt(f: &SourceFile, ln: usize, col: usize) -> bool {
    let before = f.lines[ln].code[..col].trim_end();
    for callee in ["lock()", "read()", "write()"] {
        if before.ends_with(callee) {
            return true;
        }
    }
    // Chain wrapped: `.unwrap()` begins the line; look at the previous
    // code line's tail.
    if before.is_empty() && ln > 0 {
        let mut l = ln - 1;
        loop {
            let prev = f.lines[l].code.trim_end();
            if !prev.is_empty() {
                return ["lock()", "read()", "write()"]
                    .iter()
                    .any(|c| prev.ends_with(c));
            }
            if l == 0 {
                return false;
            }
            l -= 1;
        }
    }
    false
}

/// Rule 5 — `registry-coverage`: every solver name in
/// `solvers/registry.rs :: ALL` must appear in the pinned `hist_depth`
/// table test, the golden-trajectory suite, and the bench sweep. A
/// consumer that iterates `registry::ALL` directly covers all names at
/// once.
pub fn registry_coverage(
    registry_src: &str,
    consumers: &[(&str, &str)], // (rel path, raw source)
    out: &mut Vec<Finding>,
) -> usize {
    let names = registry_all_names(registry_src);
    let mut sites = 0;
    // hist_depth table inside registry.rs itself: entries look like
    // `("name", depth)`.
    for name in &names {
        sites += 1;
        let entry = format!("(\"{name}\",");
        if !registry_src.contains(&entry) {
            out.push(Finding {
                rule: RuleId::RegistryCoverage,
                file: "src/solvers/registry.rs".to_string(),
                line: 1,
                message: format!(
                    "solver \"{name}\" missing from the pinned hist_depth table test"
                ),
            });
        }
    }
    for (rel, src) in consumers {
        let sweeps_all = src.contains("registry::ALL") || src.contains("::ALL");
        for name in &names {
            sites += 1;
            if sweeps_all || src.contains(&format!("\"{name}\"")) {
                continue;
            }
            out.push(Finding {
                rule: RuleId::RegistryCoverage,
                file: rel.to_string(),
                line: 1,
                message: format!("solver \"{name}\" not covered (and file does not sweep registry::ALL)"),
            });
        }
    }
    sites
}

/// Extract the string literals of `pub const ALL: &[&str] = &[ ... ];`.
pub fn registry_all_names(registry_src: &str) -> Vec<String> {
    let Some(start) = registry_src.find("const ALL") else {
        return Vec::new();
    };
    let Some(end) = registry_src[start..].find("];") else {
        return Vec::new();
    };
    let body = &registry_src[start..start + end];
    let mut names = Vec::new();
    let mut rest = body;
    while let Some(q) = rest.find('"') {
        let tail = &rest[q + 1..];
        let Some(close) = tail.find('"') else { break };
        let name = &tail[..close];
        if !name.is_empty() && !names.iter().any(|n| n == name) {
            names.push(name.to_string());
        }
        rest = &tail[close + 1..];
    }
    names
}

/// Rule 6 — `dependency-free`: `Cargo.toml` must declare no non-dev
/// dependencies. `[dev-dependencies]` stay allowed; `[dependencies]`,
/// `[build-dependencies]`, and `[target.*.dependencies]` entries are
/// findings.
pub fn dependency_free(cargo_toml: &str, out: &mut Vec<Finding>) -> usize {
    let mut sites = 0;
    let mut section = String::new();
    for (ln, raw) in cargo_toml.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.starts_with('[') && line.ends_with(']') {
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let banned = section == "dependencies"
            || section == "build-dependencies"
            || (section.starts_with("target.") && section.ends_with(".dependencies"));
        if banned && line.contains('=') {
            sites += 1;
            let dep = line.split('=').next().unwrap_or("").trim();
            out.push(Finding {
                rule: RuleId::DependencyFree,
                file: "Cargo.toml".to_string(),
                line: ln + 1,
                message: format!("non-dev dependency `{dep}` (repo is dependency-free by contract)"),
            });
        }
    }
    sites
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_on(rel: &str, src: &str, rule: fn(&SourceFile, &mut Vec<Finding>, &mut usize) -> usize)
        -> (Vec<Finding>, usize, usize)
    {
        let f = SourceFile::parse(rel, src);
        let mut out = Vec::new();
        let mut supp = 0;
        let sites = rule(&f, &mut out, &mut supp);
        (out, supp, sites)
    }

    #[test]
    fn unsafe_without_safety_flagged() {
        let (f, _, sites) = run_on("src/x.rs", "fn g() { unsafe { do_it(); } }\n", safety_comment);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 1);
        assert_eq!(sites, 1);
    }

    #[test]
    fn unsafe_with_safety_passes() {
        let src = "fn g() {\n    // SAFETY: the pointer is valid for the call.\n    unsafe { do_it(); }\n}\n";
        let (f, _, _) = run_on("src/x.rs", src, safety_comment);
        assert!(f.is_empty());
    }

    #[test]
    fn unsafe_fn_with_safety_doc_section_passes() {
        let src = "/// Does things.\n///\n/// # Safety\n/// Caller must uphold X.\npub unsafe fn k() {\n}\n";
        let (f, _, _) = run_on("src/x.rs", src, safety_comment);
        assert!(f.is_empty());
    }

    #[test]
    fn intrinsic_outside_target_feature_flagged() {
        let src = "fn g() { let v = _mm256_add_pd(a, b); }\n";
        let (f, _, _) = run_on("src/x.rs", src, simd_gating);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn intrinsic_inside_target_feature_passes() {
        let src = "#[target_feature(enable = \"avx2\")]\nunsafe fn g() { let v = _mm256_add_pd(a, b); }\nuse std::arch::x86_64::*;\n";
        let (f, _, _) = run_on("src/x.rs", src, simd_gating);
        assert!(f.is_empty());
    }

    #[test]
    fn fma_intrinsic_outside_gemm_flagged() {
        let src = "#[target_feature(enable = \"avx2,fma\")]\nunsafe fn g() { let v = _mm256_fmadd_pd(a, b, c); }\n";
        let (f, _, _) = run_on("src/solvers/x.rs", src, simd_gating);
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("avx2fma"));
    }

    #[test]
    fn alloc_in_hot_path_flagged_and_test_exempt() {
        let src = "fn g() { let v = Vec::new(); }\n#[cfg(test)]\nmod tests {\n    fn h() { let v = vec![1]; }\n}\n";
        let (f, _, _) = run_on("src/tensor/gemm.rs", src, hot_path_alloc);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 1);
    }

    #[test]
    fn alloc_outside_pinned_modules_ignored() {
        let (f, _, sites) = run_on("src/cli/mod.rs", "fn g() { let v = Vec::new(); }\n", hot_path_alloc);
        assert!(f.is_empty());
        assert_eq!(sites, 0);
    }

    #[test]
    fn suppression_absorbs_finding() {
        let src = "fn g() {\n    // lint:allow(hot-path-alloc, cold init)\n    let v = Vec::new();\n}\n";
        let (f, supp, _) = run_on("src/tensor/gemm.rs", src, hot_path_alloc);
        assert!(f.is_empty());
        assert_eq!(supp, 1);
    }

    #[test]
    fn wrong_rule_suppression_does_not_absorb() {
        let src = "fn g() {\n    // lint:allow(server-panic, wrong rule)\n    let v = Vec::new();\n}\n";
        let (f, supp, _) = run_on("src/tensor/gemm.rs", src, hot_path_alloc);
        assert_eq!(f.len(), 1);
        assert_eq!(supp, 0);
    }

    #[test]
    fn server_unwrap_flagged_lock_exempt() {
        let src = "fn g() {\n    let a = map.get(k).unwrap();\n    let b = mu.lock().unwrap();\n    let c = rw\n        .read()\n        .unwrap();\n}\n";
        let (f, _, _) = run_on("src/server/service.rs", src, server_panic);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn server_panic_macro_flagged() {
        let src = "fn g() { panic!(\"boom\"); }\n";
        let (f, _, _) = run_on("src/server/protocol.rs", src, server_panic);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn registry_names_parse() {
        let src = "pub const ALL: &[&str] = &[\n    \"ddim\",\n    \"heun\",\n];\nfn t() { [(\"ddim\", 0), (\"heun\", 1)]; }\n";
        assert_eq!(registry_all_names(src), vec!["ddim", "heun"]);
        let mut out = Vec::new();
        registry_coverage(src, &[("tests/x.rs", "for s in registry::ALL {}")], &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn registry_gap_flagged() {
        let src = "pub const ALL: &[&str] = &[\"ddim\", \"heun\"];\nfn t() { [(\"ddim\", 0)]; }\n";
        let mut out = Vec::new();
        registry_coverage(src, &[("benches/b.rs", "let s = [\"ddim\"];")], &mut out);
        // heun missing from hist table and from the bench.
        assert_eq!(out.len(), 2);
        assert!(out.iter().any(|f| f.file == "src/solvers/registry.rs"));
        assert!(out.iter().any(|f| f.file == "benches/b.rs"));
    }

    #[test]
    fn cargo_dependencies_flagged_dev_allowed() {
        let toml = "[package]\nname = \"x\"\n\n[dependencies]\nserde = \"1\"\n\n[dev-dependencies]\ncriterion = \"0.5\"\n";
        let mut out = Vec::new();
        let sites = dependency_free(toml, &mut out);
        assert_eq!(out.len(), 1);
        assert!(out[0].message.contains("serde"));
        assert_eq!(sites, 1);
    }
}
