//! `pas lint` — dependency-free source-level contract enforcement.
//!
//! The repo's correctness story (bit-exact PAS correction, "an indexing
//! change, not a numerics change") rests on invariants that runtime
//! suites (`alloc_audit`, `backend_parity`, chaos tests) only catch
//! after a violation ships into a hot path. This module is the static
//! complement: a lightweight lexer/scanner (no syn, no proc-macro — the
//! crate stays dependency-free) that walks the crate sources and fails
//! on contract violations at review time.
//!
//! # Enforced contracts
//!
//! | rule id             | contract it guards |
//! |---------------------|--------------------|
//! | `safety-comment`    | Every `unsafe` block/fn/impl carries a `// SAFETY:` justification (or an `unsafe fn` with a `# Safety` doc section). Unsafe code in this repo exists only in the AVX2 kernels, the scoped thread pool, and the libc signal shim — each site must say why it is sound. |
//! | `simd-gating`       | `_mm*` / `std::arch` intrinsics appear only inside `#[target_feature(enable = "avx2…")]` functions (runtime dispatch guarantees the feature before any call). `fmadd` intrinsics are confined to the opt-in `avx2fma` tier of `tensor/gemm.rs`: FMA contraction changes the per-lane reduction order, so it may never leak into the bit-exact `avx2` tier (see ROADMAP "Bit-exactness oracles"). |
//! | `hot-path-alloc`    | Static complement of `tests/alloc_audit.rs`: allocation tokens (`vec!`, `Vec::new`, `to_vec`, `Box::new`, `format!`, `.collect`, `String::from`) are banned outside `#[cfg(test)]` in the pinned hot-path modules (`solvers/engine.rs`, `tensor/gemm.rs`, `pas/pca.rs`, `pas/correct.rs`, `server/metrics_export.rs`). Zero steady-state allocation is a throughput contract, not a style preference. |
//! | `server-panic`      | Structured-errors contract on the serving path (`server/{mod,service,protocol,metrics_export}.rs`): no `unwrap`/`expect`/`panic!` outside tests — a bad request must become a structured error reply, never a connection-killing panic. Exemption: `lock()/read()/write().unwrap()` (lock-poisoning policy — a poisoned lock means a panic already escaped elsewhere, and crashing beats serving from torn state). |
//! | `registry-coverage` | Every solver in `solvers/registry.rs::ALL` must appear in the pinned `hist_depth` table test, the golden-trajectory suite, and the bench sweep. A consumer that iterates `registry::ALL` covers all names at once — that is the preferred form, since it can never go stale. |
//! | `dependency-free`   | `Cargo.toml` declares no non-dev dependencies. The whole stack — JSON, thread pool, HTTP-less wire protocol, benches — is hand-rolled by contract; `[dev-dependencies]` remain allowed. |
//!
//! # Suppressions
//!
//! A finding is suppressed in place with a comment:
//!
//! ```text
//! // lint:allow(<rule-id>, <reason>)
//! ```
//!
//! The suppression covers the same line, the statement directly below
//! the contiguous comment/attribute block it sits in, or — when placed
//! in the doc/attribute block above an `fn` signature — the entire
//! function body. The reason is mandatory: an allow without one is
//! reported as malformed and does **not** suppress. Unused suppressions
//! are surfaced in the report (and `LINT_report.json`) so suppression
//! creep stays visible at review time.
//!
//! # Entry points
//!
//! * `pas lint [--root DIR] [--json] [--report PATH | --no-report]` —
//!   CLI; exits nonzero iff findings exist, writes `LINT_report.json`.
//! * [`run_lint`] — library entry used by the CLI and by
//!   `tests/lint_clean.rs` (the tree self-check plus per-rule fixture
//!   tests under `tests/fixtures/lint/`).

pub mod rules;
pub mod scan;

use crate::util::json::Json;
use scan::SourceFile;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// Stable identifiers for the six rules.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RuleId {
    SafetyComment,
    SimdGating,
    HotPathAlloc,
    ServerPanic,
    RegistryCoverage,
    DependencyFree,
}

impl RuleId {
    pub const ALL: &'static [RuleId] = &[
        RuleId::SafetyComment,
        RuleId::SimdGating,
        RuleId::HotPathAlloc,
        RuleId::ServerPanic,
        RuleId::RegistryCoverage,
        RuleId::DependencyFree,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            RuleId::SafetyComment => "safety-comment",
            RuleId::SimdGating => "simd-gating",
            RuleId::HotPathAlloc => "hot-path-alloc",
            RuleId::ServerPanic => "server-panic",
            RuleId::RegistryCoverage => "registry-coverage",
            RuleId::DependencyFree => "dependency-free",
        }
    }

    pub fn description(self) -> &'static str {
        match self {
            RuleId::SafetyComment => "every `unsafe` carries a SAFETY justification",
            RuleId::SimdGating => {
                "SIMD intrinsics only in #[target_feature] fns; fmadd only in gemm's avx2fma tier"
            }
            RuleId::HotPathAlloc => "no allocation tokens in pinned hot-path modules outside tests",
            RuleId::ServerPanic => "no unwrap/expect/panic on the server request path",
            RuleId::RegistryCoverage => {
                "every registry solver covered by hist_depth table, golden suite, and bench sweep"
            }
            RuleId::DependencyFree => "Cargo.toml declares no non-dev dependencies",
        }
    }
}

impl fmt::Display for RuleId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// One lint finding: rule, crate-relative file, 1-based line.
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: RuleId,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{} {}:{} {}",
            self.rule.as_str(),
            self.file,
            self.line,
            self.message
        )
    }
}

/// A suppression in effect somewhere in the tree.
#[derive(Clone, Debug)]
pub struct Suppression {
    pub file: String,
    pub line: usize,
    pub rule: String,
    pub reason: String,
    pub used: bool,
}

/// Per-rule aggregate statistics.
#[derive(Clone, Debug)]
pub struct RuleStats {
    pub rule: RuleId,
    pub sites_scanned: usize,
    pub findings: usize,
    pub suppressed: usize,
}

/// Full lint result for one crate root.
pub struct LintReport {
    pub root: PathBuf,
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    pub suppressions: Vec<Suppression>,
    /// `lint:allow` comments with no reason — reported, never honoured.
    pub malformed: Vec<Suppression>,
    pub rules: Vec<RuleStats>,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// BENCH_*-style machine-readable report (written to
    /// `LINT_report.json` by the CLI, uploaded as a CI artifact).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("tool", Json::Str("pas lint".to_string()))
            .set("files_scanned", Json::UInt(self.files_scanned as u64))
            .set("total_findings", Json::UInt(self.findings.len() as u64))
            .set(
                "suppressions_in_effect",
                Json::UInt(self.suppressions.len() as u64),
            );
        let mut rules = Vec::new();
        for r in &self.rules {
            let mut o = Json::obj();
            o.set("id", Json::Str(r.rule.as_str().to_string()))
                .set("description", Json::Str(r.rule.description().to_string()))
                .set("sites_scanned", Json::UInt(r.sites_scanned as u64))
                .set("findings", Json::UInt(r.findings as u64))
                .set("suppressed", Json::UInt(r.suppressed as u64));
            rules.push(o);
        }
        j.set("rules", Json::Arr(rules));
        let mut findings = Vec::new();
        for f in &self.findings {
            let mut o = Json::obj();
            o.set("rule", Json::Str(f.rule.as_str().to_string()))
                .set("file", Json::Str(f.file.clone()))
                .set("line", Json::UInt(f.line as u64))
                .set("message", Json::Str(f.message.clone()));
            findings.push(o);
        }
        j.set("findings", Json::Arr(findings));
        let supp_json = |s: &Suppression| {
            let mut o = Json::obj();
            o.set("file", Json::Str(s.file.clone()))
                .set("line", Json::UInt(s.line as u64))
                .set("rule", Json::Str(s.rule.clone()))
                .set("reason", Json::Str(s.reason.clone()))
                .set("used", Json::Bool(s.used));
            o
        };
        j.set(
            "suppressions",
            Json::Arr(self.suppressions.iter().map(supp_json).collect()),
        );
        j.set(
            "malformed_suppressions",
            Json::Arr(self.malformed.iter().map(supp_json).collect()),
        );
        j
    }
}

/// Recursively collect `.rs` files under `dir`, sorted for determinism.
fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else {
        return;
    };
    let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            rs_files(&p, out);
        } else if p.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(p);
        }
    }
}

/// Run all six rules over the crate rooted at `root` (the directory
/// containing `Cargo.toml` and `src/`). IO errors on individual files
/// are surfaced as findings rather than aborting the pass.
pub fn run_lint(root: &Path) -> LintReport {
    let mut findings = Vec::new();
    let mut suppressions = Vec::new();
    let mut malformed = Vec::new();
    let mut stats: Vec<RuleStats> = RuleId::ALL
        .iter()
        .map(|&rule| RuleStats {
            rule,
            sites_scanned: 0,
            findings: 0,
            suppressed: 0,
        })
        .collect();

    let rel_of = |p: &Path| -> String {
        p.strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/")
    };

    let mut paths = Vec::new();
    rs_files(&root.join("src"), &mut paths);
    let files_scanned = paths.len();

    let mut registry_src = String::new();
    for path in &paths {
        let rel = rel_of(path);
        let src = match fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                findings.push(Finding {
                    rule: RuleId::SafetyComment,
                    file: rel,
                    line: 1,
                    message: format!("unreadable source file: {e}"),
                });
                continue;
            }
        };
        if rel == "src/solvers/registry.rs" {
            registry_src = src.clone();
        }
        let mut file = SourceFile::parse(&rel, &src);
        // An allow without a reason is malformed: report it, never
        // honour it.
        let (valid, bad): (Vec<_>, Vec<_>) =
            file.allows.drain(..).partition(|a| !a.reason.is_empty());
        file.allows = valid;
        for a in bad {
            malformed.push(Suppression {
                file: rel.clone(),
                line: a.line + 1,
                rule: a.rule,
                reason: String::new(),
                used: false,
            });
        }

        type Pass = fn(&SourceFile, &mut Vec<Finding>, &mut usize) -> usize;
        let passes: [(usize, Pass); 4] = [
            (0, rules::safety_comment),
            (1, rules::simd_gating),
            (2, rules::hot_path_alloc),
            (3, rules::server_panic),
        ];
        for (idx, pass) in passes {
            let before = findings.len();
            let mut suppressed = 0;
            let sites = pass(&file, &mut findings, &mut suppressed);
            stats[idx].sites_scanned += sites;
            stats[idx].findings += findings.len() - before;
            stats[idx].suppressed += suppressed;
        }
        for a in &file.allows {
            suppressions.push(Suppression {
                file: rel.clone(),
                line: a.line + 1,
                rule: a.rule.clone(),
                reason: a.reason.clone(),
                used: a.used.get(),
            });
        }
    }

    // Rule 5: cross-file registry coverage.
    if !registry_src.is_empty() {
        let mut consumers: Vec<(String, String)> = Vec::new();
        for rel in ["tests/golden_trajectories.rs", "benches/solver_step.rs"] {
            match fs::read_to_string(root.join(rel)) {
                Ok(src) => consumers.push((rel.to_string(), src)),
                Err(e) => findings.push(Finding {
                    rule: RuleId::RegistryCoverage,
                    file: rel.to_string(),
                    line: 1,
                    message: format!("registry consumer missing: {e}"),
                }),
            }
        }
        let refs: Vec<(&str, &str)> = consumers
            .iter()
            .map(|(r, s)| (r.as_str(), s.as_str()))
            .collect();
        let before = findings.len();
        let sites = rules::registry_coverage(&registry_src, &refs, &mut findings);
        stats[4].sites_scanned += sites;
        stats[4].findings += findings.len() - before;
    }

    // Rule 6: Cargo.toml dependency ban.
    match fs::read_to_string(root.join("Cargo.toml")) {
        Ok(toml) => {
            let before = findings.len();
            let sites = rules::dependency_free(&toml, &mut findings);
            stats[5].sites_scanned += sites;
            stats[5].findings += findings.len() - before;
        }
        Err(e) => findings.push(Finding {
            rule: RuleId::DependencyFree,
            file: "Cargo.toml".to_string(),
            line: 1,
            message: format!("unreadable: {e}"),
        }),
    }

    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule.as_str()).cmp(&(b.file.as_str(), b.line, b.rule.as_str()))
    });
    LintReport {
        root: root.to_path_buf(),
        files_scanned,
        findings,
        suppressions,
        malformed,
        rules: stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rule_ids_roundtrip() {
        for &r in RuleId::ALL {
            assert!(!r.as_str().is_empty());
            assert!(!r.description().is_empty());
        }
    }

    #[test]
    fn report_json_shape() {
        let report = LintReport {
            root: PathBuf::from("."),
            files_scanned: 2,
            findings: vec![Finding {
                rule: RuleId::HotPathAlloc,
                file: "src/x.rs".to_string(),
                line: 7,
                message: "m".to_string(),
            }],
            suppressions: vec![Suppression {
                file: "src/y.rs".to_string(),
                line: 3,
                rule: "server-panic".to_string(),
                reason: "r".to_string(),
                used: true,
            }],
            malformed: Vec::new(),
            rules: RuleId::ALL
                .iter()
                .map(|&rule| RuleStats {
                    rule,
                    sites_scanned: 1,
                    findings: 0,
                    suppressed: 0,
                })
                .collect(),
        };
        let s = report.to_json().to_string();
        let parsed = Json::parse(&s).expect("report JSON parses");
        if let Json::Obj(m) = parsed {
            assert_eq!(m["total_findings"], Json::UInt(1));
            assert_eq!(m["suppressions_in_effect"], Json::UInt(1));
            assert!(matches!(&m["rules"], Json::Arr(a) if a.len() == 6));
        } else {
            unreachable!("report is an object");
        }
    }
}
