//! Batched sampling service (L3 "serving" path).
//!
//! A threaded coordinator in the vLLM-router mold, scaled to this system:
//! clients submit sampling requests (`dataset, solver, nfe, n, pas?`);
//! a **dynamic batcher** groups compatible requests (same model/solver/
//! schedule/correction) into worker batches up to `max_batch`, bounded
//! queues provide **backpressure**, and a worker pool drives the samplers.
//! The TCP front-end speaks line-delimited JSON ([`protocol`]).

pub mod protocol;
pub mod service;

pub use service::{PasTrainStats, Service, ServiceConfig, SamplingRequest, SamplingResponse};
