//! Batched sampling service (L3 "serving" path).
//!
//! A threaded coordinator in the vLLM-router mold, scaled to this system
//! — and, like vLLM, **continuously batched**: the default scheduler
//! ([`service::Batching::Continuous`]) keeps one resident engine run per
//! compatibility key (`dataset, solver, nfe, pas?`) and changes its row
//! population at **step boundaries**. Requests are admitted into free
//! slots while earlier requests are mid-flight (each row carries its own
//! step cursor into the shared schedule, with per-slot ring history so
//! multistep solvers' lookback stays correct at mixed depths), and
//! finished rows retire — and reply — the moment their last step
//! completes. Tail latency under staggered arrivals is bounded by step
//! duration instead of whole-rollout duration.
//!
//! **Admission policy:** priority-then-FIFO per key under the
//! `max_batch` residency cap (oversized requests run alone on an empty
//! engine); requests admitted at the same boundary form one lockstep
//! cohort. **Determinism contract:** every response is bit-identical to
//! running that request alone, for every admission interleaving and
//! thread count — enforced by parity tests over randomized mid-flight
//! admission × engine thread caps {1, 4, 16}. The seed's collect-then-run
//! batcher survives behind [`service::Batching::CollectThenRun`] as the
//! latency baseline (`benches/continuous_batching.rs`).
//!
//! Bounded queues provide **backpressure** (per key under the continuous
//! scheduler), and the TCP front-end speaks strictly-validated
//! line-delimited JSON ([`protocol`]): unknown datasets/solvers,
//! out-of-range `n`, and inexact or negative seeds are structured errors,
//! never silent rewrites.
//!
//! # SLO model (deadlines, priorities, shedding)
//!
//! Requests may carry two optional SLO fields, both strictly validated at
//! the protocol layer and both **scheduling-only** — neither ever changes
//! sample numerics:
//!
//! * **`deadline_ms`** — a soft end-to-end latency budget measured from
//!   submit. The continuous scheduler *sheds* a queued request the moment
//!   its budget becomes infeasible: expired outright, or smaller than
//!   `n_steps ×` the key's observed per-tick latency (an EWMA the
//!   resident run maintains from its own wall clock). Shed requests fail
//!   fast with a structured `deadline` error carrying real `latency_ms` —
//!   the alternative, queuing them to miss their deadline slowly, wastes
//!   both the client's patience and a worker's compute. A request that
//!   has already been admitted is never shed: admitted rows always run to
//!   completion, preserving the bit-exactness contract.
//! * **`priority`** — an integer (−100..=100, default 0) ordering the
//!   request *within its key's queue*: higher admits first, FIFO among
//!   equals. Priorities do not preempt resident cohorts and do not cross
//!   keys (cross-key fairness is the scheduler's weighted yield: a
//!   worker's per-key tick budget shrinks as more keys wait for
//!   dispatch).
//!
//! # Observability
//!
//! [`metrics_export`] renders the operator surface: a Prometheus-style
//! text metrics page ([`service::Service::metrics_text`], wire
//! `{"cmd":"metrics"}`) with lock-free fixed-bucket histograms of
//! `queue_ms`/`run_ms`/`latency_ms`, per-key queue-depth/residency/
//! retire/shed series and pool utilization — and a health summary
//! ([`service::Service::health_json`], wire `{"cmd":"health"}`) that
//! classifies the service `"ok"`/`"overloaded"` from key-queue
//! saturation. Recording is three relaxed atomic adds per series on the
//! retire path: no locks, no allocations, no numerics impact.
//!
//! # Dictionary lifecycle (startup → publish → rollback)
//!
//! With [`service::ServiceConfig::artifact_root`] set, the dict registry
//! is backed by the durable [`crate::artifact`] store:
//!
//! * **Startup.** [`service::Service::start`] opens the store and loads
//!   every key (checksum-verified; corrupt blobs quarantined, the loader
//!   healing back to the last good version; a torn manifest recovers from
//!   the previous generation; a missing/empty store is a clean cold
//!   start). Caller-supplied dicts override stored ones.
//! * **Publish.** [`service::Service::train_pas`] persists each newly
//!   trained dict as a new atomically-published version (failure to
//!   persist is warned, never blocks serving);
//!   [`service::Service::publish_dict`] is the explicit deploy path.
//!   Either way the registry is updated first, and serving workers pick
//!   the new dict up through the existing per-cohort snapshots — cohorts
//!   admitted before the publish finish on their snapshot bit-identically,
//!   cohorts admitted after use the new version; nothing blocks.
//! * **Rollback.** [`service::Service::rollback`] (also exposed as the
//!   wire `{"cmd":"rollback",...}` and `pas artifact rollback`) demotes
//!   the key to its previous stored version and swaps the re-verified
//!   dict into the registry under the same snapshot rules.
//!
//! Store health is observable via `{"cmd":"status"}`
//! ([`service::Service::status_json`]: `artifacts_loaded`,
//! `dicts_published`, `rollbacks`, …) and the `pas artifact
//! list/verify/load` CLI.
//!
//! # Fault containment (supervision, drain, numeric guardrails)
//!
//! The serving path is built to contain faults at the smallest scope
//! that can absorb them — a row, a request, a connection, a key — and
//! never let one fault take down its neighbours:
//!
//! * **Connection supervision** ([`protocol::serve_with`]). The TCP
//!   front-end runs a supervised connection set: a hard connection cap
//!   (structured `overloaded` reject beyond it), a frame bound enforced
//!   *while reading* (a newline-less flood is cut off, not buffered),
//!   slow-loris and dead-peer timeouts, and every connection thread
//!   tracked so shutdown can join it.
//! * **Graceful drain** ([`service::Service::shutdown`], SIGTERM in
//!   `pas serve`). Shutdown is two-phase: phase 1 stops intake — the
//!   front-end stops accepting, new submissions and queued-but-unadmitted
//!   requests fail fast with a structured `draining` error; phase 2 lets
//!   resident cohorts run to retirement under
//!   [`service::ServiceConfig::drain_deadline`] (residents that cannot
//!   finish in time fail with a structured error instead of holding
//!   shutdown hostage), then joins workers and connection threads so
//!   every reply flushes. The accounting identity `requests == completed
//!   + rejected + failed` holds through shutdown: no request ever
//!   vanishes. `shutdown` is idempotent.
//! * **Numeric guardrails.** Every scheduler tick scans the stepped
//!   rows' directions and states for non-finite values
//!   ([`crate::solvers::engine::SlotEngine::poisoned_rows`]); poisoned
//!   members fail *individually* with a structured `numeric` error while
//!   cohort-mates keep stepping (row independence makes the eviction
//!   bit-invisible to survivors). A per-key circuit breaker counts
//!   consecutive corrected-path blow-ups: at the threshold it degrades
//!   the key to **uncorrected** sampling, drops the offending dict from
//!   the registry, and quarantines its blob in the artifact store —
//!   still serving, just without the corrections that kept exploding.
//!   `rollback`/`publish_dict`/`train_pas` close the breaker and resume
//!   corrected serving. Breaker state is visible as the
//!   `pas_breaker_open` gauge, `pas_numeric_failures_total`, and the
//!   `"degraded"` health status. As a last line, the wire layer refuses
//!   to serialize a "success" with non-finite samples
//!   ([`protocol::response_json`] turns it into a `numeric` error; the
//!   JSON writer would otherwise emit `null`).
//! * **Chaos coverage.** Compiled-in fail points
//!   ([`crate::util::failpoint`]) let `tests/serving_chaos.rs` drive the
//!   production paths through eval panics mid-cohort, injected NaNs at a
//!   chosen tick, reply-write failures, and stalled sockets — asserting
//!   exactly-one-reply, survivor bit-parity with solo runs, and that
//!   drain always terminates.

pub mod metrics_export;
pub mod protocol;
pub mod service;

pub use service::{
    Batching, PasTrainStats, SamplingRequest, SamplingResponse, Service, ServiceConfig,
};
