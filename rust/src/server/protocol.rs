//! TCP front-end: line-delimited JSON over a listener socket.
//!
//! Request line:
//! `{"dataset":"gmm2d","solver":"ddim","nfe":10,"n":16,"seed":1,"pas":false,
//!   "deadline_ms":250.0,"priority":5}`
//!
//! `deadline_ms` (optional, finite, > 0) is the request's soft
//! end-to-end latency budget: the continuous scheduler sheds the request
//! with a structured `deadline` error once the budget is infeasible
//! (expired, or shorter than the key's projected run time). `priority`
//! (optional integer, [`MIN_PRIORITY`]`..=`[`MAX_PRIORITY`], default 0)
//! orders the request within its key's queue — higher admits first, FIFO
//! among equals. Both affect scheduling only, never sample numerics.
//!
//! Response line:
//! `{"id":1,"n":16,"dim":2,"nfe":10,"batched_with":3,"latency_ms":4.2,
//!   "queue_ms":0.3,"run_ms":3.9,"samples":[...]}`. Error replies carry
//! timing too (error paths are where operators most need it):
//! `{"id":1,"error":"...","latency_ms":4.2,"queue_ms":4.2,"run_ms":0}`.
//!
//! Parsing is strict where silence would mis-serve: an unknown `dataset`
//! or `solver` is an error (not a silent fall-back to the default model),
//! `n` outside `1..=MAX_N` and `nfe` outside `1..=MAX_NFE` are errors
//! (not silent clamps), and `seed`
//! must be an exact non-negative integer — it is matched against the
//! request's RNG stream bit-for-bit, so values parsed through f64 (which
//! loses precision above 2^53) or negative values are rejected. A
//! non-finite or non-positive `deadline_ms` and a fractional or
//! out-of-range `priority` are likewise errors. Absent fields still take
//! the documented defaults.
//!
//! Lines carrying a `"cmd"` key are **admin commands** instead of
//! sampling requests:
//!
//! * `{"cmd":"status"}` — the metrics/registry/store counter snapshot
//!   ([`Service::status_json`]).
//! * `{"cmd":"metrics"}` — the full text-format metrics page
//!   ([`Service::metrics_text`]: Prometheus-style exposition text with
//!   counters, `queue_ms`/`run_ms`/`latency_ms` histograms, pool gauges
//!   and per-key series), wrapped as
//!   `{"format":"prometheus-text","text":"..."}` so the reply stays one
//!   JSON line.
//! * `{"cmd":"health"}` — the one-look health summary
//!   ([`Service::health_json`]: `status` of `"ok"`/`"overloaded"`,
//!   in-flight/shed/failed counts, coarse latency quantiles,
//!   key saturation).
//! * `{"cmd":"rollback","dataset":...,"solver":...,"nfe":...}` — rolls
//!   the key's dict back to its previous stored version and replies
//!   `{"ok":true,"version":v}`.
//!
//! # Connection supervision
//!
//! The listener runs a *supervised connection set* ([`serve_with`] /
//! [`Server`]), not an unbounded thread-per-connection free-for-all:
//!
//! * **Connection cap** ([`ServerConfig::max_conns`]) — an accept beyond
//!   the cap gets a one-line structured `overloaded` error and an
//!   immediate close, so a connection flood cannot exhaust threads.
//! * **Frame bound** ([`ServerConfig::max_line_bytes`]) — enforced
//!   *while reading*: a client that streams bytes without ever sending a
//!   newline is cut off with a structured `frame too large` error once
//!   the partial frame exceeds the bound, instead of growing a buffer
//!   until the process dies.
//! * **Read/idle timeouts** — a partial frame that stalls longer than
//!   [`ServerConfig::read_timeout`] (slow-loris) gets a structured
//!   `timeout` error and a close; a connection idle between frames
//!   longer than [`ServerConfig::idle_timeout`] (dead peer) is reaped
//!   silently. Replies are bounded by
//!   [`ServerConfig::write_timeout`].
//! * **Tracked handles** — every connection thread is registered with a
//!   done-flag, so [`Server::join`] can find and join them at shutdown
//!   instead of orphaning detached threads.
//!
//! During drain ([`Server::begin_drain`]) the accept loop stops and each
//! connection closes at its next between-frames moment; in-flight
//! requests run to their (service-level) drain disposition first, so
//! every accepted request still gets exactly one reply.

use super::service::{SamplingRequest, Service};
use crate::util::failpoint;
use crate::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Largest per-request batch the front-end accepts.
pub const MAX_N: usize = 4096;

/// Largest NFE budget the front-end accepts. Unbounded `nfe` would let a
/// single request allocate an `nfe + 1`-node schedule (and spend that
/// many model evaluations) on a worker thread.
pub const MAX_NFE: usize = 10_000;

/// Lowest scheduling priority the front-end accepts.
pub const MIN_PRIORITY: i32 = -100;

/// Highest scheduling priority the front-end accepts.
pub const MAX_PRIORITY: i32 = 100;

pub fn parse_request(line: &str) -> Result<SamplingRequest, String> {
    let j = Json::parse(line)?;
    let dataset = match j.get("dataset") {
        None => "gmm-hd64".to_string(),
        Some(v) => v
            .as_str()
            .ok_or("\"dataset\" must be a string")?
            .to_string(),
    };
    // Name check only — constructing the dataset here would run its mode
    // generators (eigendecompositions) once per request just to validate
    // a string.
    if !crate::data::registry::ALL.contains(&dataset.as_str()) {
        return Err(format!("unknown dataset \"{dataset}\""));
    }
    let solver = match j.get("solver") {
        None => "ddim".to_string(),
        Some(v) => v.as_str().ok_or("\"solver\" must be a string")?.to_string(),
    };
    if crate::solvers::registry::get(&solver).is_none() {
        return Err(format!("unknown solver \"{solver}\""));
    }
    let nfe = match j.get("nfe") {
        None => 10,
        Some(v) => {
            let nfe = v.as_usize().ok_or("\"nfe\" must be a positive integer")?;
            if !(1..=MAX_NFE).contains(&nfe) {
                return Err(format!("\"nfe\" must be in 1..={MAX_NFE} (got {nfe})"));
            }
            nfe
        }
    };
    let n_samples = match j.get("n") {
        None => 1,
        Some(v) => {
            let n = v.as_usize().ok_or("\"n\" must be a positive integer")?;
            if !(1..=MAX_N).contains(&n) {
                return Err(format!("\"n\" must be in 1..={MAX_N} (got {n})"));
            }
            n
        }
    };
    // Exact u64 parse from the integer token: `as_u64` refuses negatives,
    // fractions, and float-typed values above 2^53, so a seed never loses
    // precision silently.
    let seed = match j.get("seed") {
        None => 0,
        Some(v) => v
            .as_u64()
            .ok_or("\"seed\" must be a non-negative integer (full u64 range)")?,
    };
    let use_pas = match j.get("pas") {
        None => false,
        Some(v) => v.as_bool().ok_or("\"pas\" must be a boolean")?,
    };
    // SLO fields: strict like everything above — a deadline of 0 (or a
    // negative/NaN one) and a fractional or out-of-range priority are
    // rejected, not silently clamped or ignored.
    let deadline_ms = match j.get("deadline_ms") {
        None => None,
        Some(v) => {
            let d = v
                .as_f64()
                .ok_or("\"deadline_ms\" must be a number (milliseconds)")?;
            if !d.is_finite() || d <= 0.0 {
                return Err(format!(
                    "\"deadline_ms\" must be a finite positive number of milliseconds (got {d})"
                ));
            }
            Some(d)
        }
    };
    let priority = match j.get("priority") {
        None => 0,
        Some(v) => {
            let p = v.as_f64().ok_or("\"priority\" must be an integer")?;
            if p.fract() != 0.0 {
                return Err(format!("\"priority\" must be an integer (got {p})"));
            }
            let p = p as i64;
            if p < MIN_PRIORITY as i64 || p > MAX_PRIORITY as i64 {
                return Err(format!(
                    "\"priority\" must be in {MIN_PRIORITY}..={MAX_PRIORITY} (got {p})"
                ));
            }
            p as i32
        }
    };
    Ok(SamplingRequest {
        id: 0,
        dataset,
        solver,
        nfe,
        n_samples,
        seed,
        use_pas,
        deadline_ms,
        priority,
    })
}

pub fn response_json(resp: &super::service::SamplingResponse) -> Json {
    let mut o = Json::obj();
    if let Some(e) = &resp.error {
        // Error replies keep their identity and timing: operators triage
        // failures by how long the request lived, not just why it died.
        o.set("id", Json::UInt(resp.id))
            .set("error", Json::Str(e.clone()))
            .set("latency_ms", Json::Num(resp.latency_ms))
            .set("queue_ms", Json::Num(resp.queue_ms))
            .set("run_ms", Json::Num(resp.run_ms));
        return o;
    }
    // Non-finite samples must never reach the wire as a "success": JSON
    // has no token for NaN/inf, so the writer would emit `null` and the
    // client would deserialize silent corruption. The engine fails
    // poisoned rows before they get here; this is the last-line guard in
    // case any other path leaks one through.
    if resp.samples.iter().any(|v| !v.is_finite()) {
        o.set("id", Json::UInt(resp.id))
            .set(
                "error",
                Json::Str(
                    "numeric: non-finite values in sample output; request aborted".into(),
                ),
            )
            .set("latency_ms", Json::Num(resp.latency_ms))
            .set("queue_ms", Json::Num(resp.queue_ms))
            .set("run_ms", Json::Num(resp.run_ms));
        return o;
    }
    o.set("id", Json::UInt(resp.id))
        .set("n", Json::Num(resp.n as f64))
        .set("dim", Json::Num(resp.dim as f64))
        .set("nfe", Json::Num(resp.nfe_spent as f64))
        .set("batched_with", Json::Num(resp.batched_with as f64))
        .set("latency_ms", Json::Num(resp.latency_ms))
        .set("queue_ms", Json::Num(resp.queue_ms))
        .set("run_ms", Json::Num(resp.run_ms))
        .set("samples", Json::from_f64_slice(&resp.samples));
    o
}

/// Resource bounds for the supervised connection set.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Hard cap on concurrent connections; accepts beyond it get a
    /// structured `overloaded` reject and an immediate close.
    pub max_conns: usize,
    /// Largest frame (request line) accepted, enforced while reading.
    pub max_line_bytes: usize,
    /// Longest a *partial* frame may stall before the connection is cut
    /// off with a structured `timeout` error (slow-loris bound).
    pub read_timeout: Duration,
    /// Longest a connection may sit idle *between* frames before it is
    /// reaped silently (dead-peer bound).
    pub idle_timeout: Duration,
    /// Socket write timeout for replies, so one wedged client cannot
    /// pin a connection thread forever.
    pub write_timeout: Duration,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            max_conns: 256,
            max_line_bytes: 1 << 20,
            read_timeout: Duration::from_secs(10),
            idle_timeout: Duration::from_secs(300),
            write_timeout: Duration::from_secs(10),
        }
    }
}

/// How often a blocked read wakes to check timeouts and the drain flag.
const READ_TICK: Duration = Duration::from_millis(50);

/// Tracked connection threads: `active` gates admission at the cap,
/// `handles` lets shutdown find and join every connection thread.
struct ConnRegistry {
    active: AtomicUsize,
    handles: Mutex<Vec<(Arc<AtomicBool>, JoinHandle<()>)>>,
}

impl ConnRegistry {
    /// Join (and drop) every connection thread whose done-flag is set.
    /// Called from the accept loop so the handle list tracks live
    /// connections, not the all-time total.
    fn sweep(&self) {
        let mut handles = self.handles.lock().unwrap();
        let mut i = 0;
        while i < handles.len() {
            if handles[i].0.load(Ordering::Acquire) {
                let (_, h) = handles.swap_remove(i);
                let _ = h.join();
            } else {
                i += 1;
            }
        }
    }
}

/// Handle on a running TCP front-end: the bound address plus enough
/// state to drain and join it. Dropping the handle *detaches* the
/// front-end (threads keep serving until the drain flag is set).
pub struct Server {
    local: SocketAddr,
    draining: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    conns: Arc<ConnRegistry>,
}

impl Server {
    /// The bound address (useful with port 0 in tests).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Phase 1 of shutdown: stop accepting, and have each connection
    /// close at its next between-frames moment. In-flight requests still
    /// run to a reply first. Idempotent.
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    /// Drain and join: sets the drain flag, joins the accept loop, then
    /// joins connection threads as they finish. Returns `true` if every
    /// connection thread joined within `deadline`; stragglers (e.g. a
    /// reply blocked on a wedged client socket) are left detached and
    /// `false` is returned.
    pub fn join(mut self, deadline: Duration) -> bool {
        self.begin_drain();
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        let t0 = Instant::now();
        loop {
            self.conns.sweep();
            if self.conns.handles.lock().unwrap().is_empty() {
                return true;
            }
            if t0.elapsed() >= deadline {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }
}

/// Serve until `stop` is set, with default [`ServerConfig`] bounds.
/// Binds to `addr` (e.g. "127.0.0.1:7777"); returns the bound address
/// (useful with port 0 in tests). The front-end runs detached: callers
/// that need to *join* it at shutdown use [`serve_with`].
pub fn serve(
    service: Arc<Service>,
    addr: &str,
    stop: Arc<AtomicBool>,
) -> std::io::Result<SocketAddr> {
    let server = serve_with(service, addr, stop, ServerConfig::default())?;
    Ok(server.local_addr())
}

/// Serve with explicit bounds, returning a joinable [`Server`] handle.
/// `draining` doubles as the external stop flag: setting it (directly or
/// via [`Server::begin_drain`]) stops the accept loop and closes each
/// connection at its next between-frames moment.
pub fn serve_with(
    service: Arc<Service>,
    addr: &str,
    draining: Arc<AtomicBool>,
    cfg: ServerConfig,
) -> std::io::Result<Server> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    let conns = Arc::new(ConnRegistry {
        active: AtomicUsize::new(0),
        handles: Mutex::new(Vec::new()),
    });
    let accept = {
        let draining = draining.clone();
        let conns = conns.clone();
        std::thread::spawn(move || loop {
            if draining.load(Ordering::Relaxed) {
                break;
            }
            conns.sweep();
            match listener.accept() {
                Ok((stream, _)) => {
                    if conns.active.load(Ordering::Acquire) >= cfg.max_conns {
                        // Structured reject on the wire, then close: the
                        // client learns *why* instead of seeing a RST or
                        // an accept queue that never progresses.
                        let mut s = stream;
                        let _ = s.set_write_timeout(Some(cfg.write_timeout));
                        let reply = error_json(format!(
                            "overloaded: connection limit ({}) reached, retry later",
                            cfg.max_conns
                        ));
                        let _ = s.write_all(reply.to_string().as_bytes());
                        let _ = s.write_all(b"\n");
                        continue;
                    }
                    conns.active.fetch_add(1, Ordering::AcqRel);
                    let svc = service.clone();
                    let cfg = cfg.clone();
                    let draining = draining.clone();
                    let done = Arc::new(AtomicBool::new(false));
                    let conns_in = conns.clone();
                    let done_in = done.clone();
                    let h = std::thread::spawn(move || {
                        let _ = handle_client(stream, &svc, &cfg, &draining);
                        conns_in.active.fetch_sub(1, Ordering::AcqRel);
                        done_in.store(true, Ordering::Release);
                    });
                    conns.handles.lock().unwrap().push((done, h));
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Err(_) => break,
            }
        })
    };
    Ok(Server {
        local,
        draining,
        accept: Some(accept),
        conns,
    })
}

fn error_json(msg: String) -> Json {
    let mut o = Json::obj();
    o.set("error", Json::Str(msg));
    o
}

/// Dispatch a line carrying a `"cmd"` key. `None` means the line is not
/// an admin command (no such key, or not even JSON) and should be parsed
/// as a sampling request — whose own strict errors then apply.
fn admin_reply(line: &str, svc: &Service) -> Option<Json> {
    let j = Json::parse(line).ok()?;
    let cmd = j.get("cmd")?;
    let Some(cmd) = cmd.as_str() else {
        return Some(error_json("\"cmd\" must be a string".into()));
    };
    let reply = match cmd {
        "status" => svc.status_json(),
        "metrics" => {
            // The exposition text is multi-line; the wire is one JSON
            // object per line, so it ships as a string field.
            let mut o = Json::obj();
            o.set("format", Json::Str("prometheus-text".into()))
                .set("text", Json::Str(svc.metrics_text()));
            o
        }
        "health" => svc.health_json(),
        "rollback" => {
            let args = (
                j.get("dataset").and_then(|v| v.as_str()),
                j.get("solver").and_then(|v| v.as_str()),
                j.get("nfe").and_then(|v| v.as_usize()),
            );
            match args {
                (Some(dataset), Some(solver), Some(nfe)) => {
                    match svc.rollback(dataset, solver, nfe) {
                        Ok(version) => {
                            let mut o = Json::obj();
                            o.set("ok", Json::Bool(true))
                                .set("version", Json::UInt(version));
                            o
                        }
                        Err(e) => error_json(e),
                    }
                }
                _ => error_json(
                    "rollback needs \"dataset\" (string), \"solver\" (string), \"nfe\" (integer)"
                        .into(),
                ),
            }
        }
        other => error_json(format!("unknown cmd \"{other}\"")),
    };
    Some(reply)
}

/// Write one reply line. The [`failpoint::PROTOCOL_WRITE_FAIL`] site
/// simulates a client that vanished between request and reply; the
/// resulting error unwinds `handle_client` exactly like a real broken
/// pipe, which is the path the chaos suite asserts is leak-free.
fn write_reply(writer: &mut TcpStream, reply: &Json) -> std::io::Result<()> {
    if failpoint::take(failpoint::PROTOCOL_WRITE_FAIL).is_some() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::BrokenPipe,
            "injected reply write failure",
        ));
    }
    writer.write_all(reply.to_string().as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()
}

fn dispatch_line(line: &str, svc: &Service) -> Json {
    match admin_reply(line, svc) {
        Some(r) => r,
        None => match parse_request(line) {
            Ok(req) => match svc.call(req) {
                Ok(resp) => response_json(&resp),
                Err(e) => error_json(e),
            },
            Err(e) => error_json(e),
        },
    }
}

/// Per-connection loop: a bounded line reader over a short-timeout
/// socket. Unlike `BufReader::lines`, the frame bound and the stall
/// clocks are enforced *during* the read, so a newline-less flood or a
/// slow-loris client is contained before it costs unbounded memory or a
/// pinned thread.
fn handle_client(
    stream: TcpStream,
    svc: &Service,
    cfg: &ServerConfig,
    draining: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_read_timeout(Some(READ_TICK))?;
    stream.set_write_timeout(Some(cfg.write_timeout))?;
    let mut writer = stream.try_clone()?;
    let mut stream = stream;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    let mut last_activity = Instant::now();
    loop {
        // Serve every complete frame already buffered.
        while let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            let frame: Vec<u8> = buf.drain(..=pos).collect();
            let line = String::from_utf8_lossy(&frame[..frame.len() - 1]).into_owned();
            if line.trim().is_empty() {
                continue;
            }
            let reply = dispatch_line(&line, svc);
            write_reply(&mut writer, &reply)?;
            last_activity = Instant::now();
        }
        if buf.len() > cfg.max_line_bytes {
            let _ = write_reply(
                &mut writer,
                &error_json(format!(
                    "frame too large: exceeds {} bytes without a newline",
                    cfg.max_line_bytes
                )),
            );
            return Ok(());
        }
        if draining.load(Ordering::Relaxed) && buf.is_empty() {
            // Between frames during drain: close so the client learns to
            // reconnect elsewhere. A partial frame still gets its read
            // window — its reply (likely a `draining` error from the
            // service) flushes before the close above.
            return Ok(());
        }
        match stream.read(&mut chunk) {
            Ok(0) => return Ok(()), // peer closed
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                last_activity = Instant::now();
            }
            Err(ref e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Read tick expired: check the stall clocks.
                let stalled = last_activity.elapsed();
                if !buf.is_empty() && stalled >= cfg.read_timeout {
                    let _ = write_reply(
                        &mut writer,
                        &error_json(format!(
                            "timeout: partial frame stalled longer than {:?}",
                            cfg.read_timeout
                        )),
                    );
                    return Ok(());
                }
                if buf.is_empty() && stalled >= cfg.idle_timeout {
                    return Ok(()); // dead peer: reap silently
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::service::ServiceConfig;
    use std::io::{BufRead, BufReader};

    #[test]
    fn parses_request_line() {
        let r = parse_request(r#"{"dataset":"gmm2d","solver":"ipndm","nfe":8,"n":4,"seed":3}"#)
            .unwrap();
        assert_eq!(r.dataset, "gmm2d");
        assert_eq!(r.solver, "ipndm");
        assert_eq!(r.nfe, 8);
        assert_eq!(r.n_samples, 4);
        assert!(!r.use_pas);
    }

    #[test]
    fn absent_fields_take_defaults() {
        let r = parse_request("{}").unwrap();
        assert_eq!(r.dataset, "gmm-hd64");
        assert_eq!(r.solver, "ddim");
        assert_eq!(r.nfe, 10);
        assert_eq!(r.n_samples, 1);
        assert_eq!(r.seed, 0);
        assert!(!r.use_pas);
        assert_eq!(r.deadline_ms, None);
        assert_eq!(r.priority, 0);
    }

    /// SLO fields parse with the same strictness as everything else:
    /// valid values flow through, junk is a structured error.
    #[test]
    fn slo_fields_parse_and_validate() {
        let r = parse_request(
            r#"{"dataset":"gmm2d","deadline_ms":250.5,"priority":-2}"#,
        )
        .unwrap();
        assert_eq!(r.deadline_ms, Some(250.5));
        assert_eq!(r.priority, -2);
        let r = parse_request(r#"{"dataset":"gmm2d","priority":100}"#).unwrap();
        assert_eq!(r.priority, 100);
        for (line, needle) in [
            (r#"{"deadline_ms":0}"#, "finite positive"),
            (r#"{"deadline_ms":-5}"#, "finite positive"),
            (r#"{"deadline_ms":"soon"}"#, "must be a number"),
            (r#"{"priority":1.5}"#, "must be an integer"),
            (r#"{"priority":101}"#, "must be in -100..=100"),
            (r#"{"priority":-101}"#, "must be in -100..=100"),
            (r#"{"priority":"high"}"#, "must be an integer"),
        ] {
            match parse_request(line) {
                Err(e) => assert!(e.contains(needle), "{line}: {e}"),
                Ok(r) => panic!("{line} must be rejected, parsed {r:?}"),
            }
        }
    }

    /// Seeds parse exactly from the raw integer token across the full u64
    /// range; negatives and lossy encodings are rejected.
    #[test]
    fn seed_roundtrips_exactly() {
        for seed in [0u64, (1 << 53) - 1, (1 << 53) + 1, u64::MAX - 1, u64::MAX] {
            let r = parse_request(&format!(r#"{{"dataset":"gmm2d","seed":{seed}}}"#)).unwrap();
            assert_eq!(r.seed, seed, "seed {seed} must survive parsing bit-for-bit");
        }
        for bad in ["-1", "-9007199254740993", "1.5", "\"7\"", "18446744073709551616"] {
            let e = parse_request(&format!(r#"{{"dataset":"gmm2d","seed":{bad}}}"#));
            assert!(e.is_err(), "seed {bad} must be rejected, got {e:?}");
        }
    }

    /// Mistyped or unknown dataset/solver/n values produce errors instead
    /// of silently serving the default model or a clamped batch.
    #[test]
    fn unknown_fields_error_instead_of_defaulting() {
        for (line, needle) in [
            (r#"{"dataset":"gmm2d-typo"}"#, "unknown dataset"),
            (r#"{"dataset":42}"#, "must be a string"),
            (r#"{"solver":"ddimm"}"#, "unknown solver"),
            (r#"{"solver":false}"#, "must be a string"),
            (r#"{"n":0}"#, "\"n\" must be in"),
            (r#"{"n":4097}"#, "\"n\" must be in"),
            (r#"{"n":"many"}"#, "positive integer"),
            (r#"{"nfe":0}"#, "\"nfe\" must be in"),
            (r#"{"nfe":-4}"#, "positive integer"),
            (r#"{"nfe":1000000000000000000}"#, "\"nfe\" must be in"),
            (r#"{"pas":"yes"}"#, "boolean"),
        ] {
            match parse_request(line) {
                Err(e) => assert!(e.contains(needle), "{line}: {e}"),
                Ok(r) => panic!("{line} must be rejected, parsed {r:?}"),
            }
        }
    }

    #[test]
    fn end_to_end_over_tcp() {
        let svc = Arc::new(Service::start(ServiceConfig::default(), Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let addr = serve(svc, "127.0.0.1:0", stop.clone()).unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"{\"dataset\":\"gmm2d\",\"solver\":\"ddim\",\"nfe\":6,\"n\":2,\"seed\":1}\n")
            .unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert!(j.get("error").is_none(), "{line}");
        assert_eq!(j.get("n").unwrap().as_usize().unwrap(), 2);
        assert_eq!(
            j.get("samples").unwrap().as_arr().unwrap().len(),
            4 // 2 samples x dim 2
        );
        stop.store(true, Ordering::Relaxed);
    }

    #[test]
    fn admin_status_and_rollback_over_tcp() {
        let svc = Arc::new(Service::start(ServiceConfig::default(), Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let addr = serve(svc, "127.0.0.1:0", stop.clone()).unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut ask = |line: &str| {
            conn.write_all(line.as_bytes()).unwrap();
            conn.write_all(b"\n").unwrap();
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            Json::parse(reply.trim()).unwrap()
        };
        let status = ask(r#"{"cmd":"status"}"#);
        assert!(status.get("error").is_none(), "{status:?}");
        assert_eq!(status.get("rollbacks").unwrap().as_u64(), Some(0));
        assert_eq!(status.get("failed").unwrap().as_u64(), Some(0));
        assert_eq!(status.get("shed").unwrap().as_u64(), Some(0));
        assert_eq!(status.get("artifacts_loaded").unwrap().as_u64(), Some(0));
        assert_eq!(status.get("artifact_store").unwrap(), &Json::Null);
        // One sampling request, so the observability surfaces have data.
        let sample = ask(r#"{"dataset":"gmm2d","solver":"ddim","nfe":6,"n":2,"seed":1}"#);
        assert!(sample.get("error").is_none(), "{sample:?}");
        let metrics = ask(r#"{"cmd":"metrics"}"#);
        assert_eq!(
            metrics.get("format").and_then(|v| v.as_str()),
            Some("prometheus-text")
        );
        let text = metrics.get("text").and_then(|v| v.as_str()).unwrap();
        assert!(text.contains("pas_requests_total 1"), "{text}");
        assert!(text.contains("pas_serve_latency_ms_bucket"), "{text}");
        let health = ask(r#"{"cmd":"health"}"#);
        assert_eq!(health.get("status").and_then(|v| v.as_str()), Some("ok"));
        assert_eq!(health.get("completed").and_then(|v| v.as_u64()), Some(1));
        // Rollback without a store / with bad args / unknown cmd: errors.
        for (line, needle) in [
            (
                r#"{"cmd":"rollback","dataset":"gmm2d","solver":"ddim","nfe":6}"#,
                "no artifact store",
            ),
            (r#"{"cmd":"rollback","dataset":"gmm2d"}"#, "rollback needs"),
            (r#"{"cmd":"selfdestruct"}"#, "unknown cmd"),
            (r#"{"cmd":42}"#, "must be a string"),
        ] {
            let r = ask(line);
            let e = r.get("error").and_then(|v| v.as_str()).unwrap_or_default();
            assert!(e.contains(needle), "{line}: {r:?}");
        }
        stop.store(true, Ordering::Relaxed);
    }

    #[test]
    fn malformed_request_gets_error() {
        let svc = Arc::new(Service::start(ServiceConfig::default(), Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let addr = serve(svc, "127.0.0.1:0", stop.clone()).unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"not json\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"));
        stop.store(true, Ordering::Relaxed);
    }

    /// A "success" carrying non-finite samples must become a structured
    /// `numeric` error reply — never a success whose writer silently
    /// turns NaN into `null` on the wire.
    #[test]
    fn non_finite_success_becomes_numeric_error_on_wire() {
        use crate::server::service::SamplingResponse;
        // First, the corruption this guards against is real: the JSON
        // writer has no token for NaN/inf and emits `null`.
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        for poison in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let resp = SamplingResponse {
                id: 7,
                samples: vec![1.0, poison, 3.0],
                n: 1,
                dim: 3,
                nfe_spent: 10,
                batched_with: 2,
                latency_ms: 1.5,
                queue_ms: 0.5,
                run_ms: 1.0,
                error: None,
            };
            let j = response_json(&resp);
            let err = j
                .get("error")
                .and_then(|v| v.as_str())
                .expect("non-finite samples must produce an error reply");
            assert!(err.starts_with("numeric:"), "{err}");
            assert!(j.get("samples").is_none(), "corrupt samples must not ship");
            assert_eq!(j.get("id").unwrap().as_u64(), Some(7), "identity kept");
            assert!(j.get("latency_ms").is_some(), "timing kept for triage");
            // The reply line itself round-trips as JSON.
            assert!(Json::parse(&j.to_string()).is_ok());
        }
        // Finite samples are untouched by the guard.
        let ok = SamplingResponse {
            id: 8,
            samples: vec![1.0, 2.0],
            n: 1,
            dim: 2,
            nfe_spent: 10,
            batched_with: 0,
            latency_ms: 1.0,
            queue_ms: 0.0,
            run_ms: 1.0,
            error: None,
        };
        assert!(response_json(&ok).get("samples").is_some());
    }

    /// A client streaming bytes without a newline is cut off with a
    /// structured error at the frame bound, not buffered until OOM.
    #[test]
    fn oversized_frame_is_cut_off_with_structured_error() {
        let svc = Arc::new(Service::start(ServiceConfig::default(), Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let server = serve_with(
            svc,
            "127.0.0.1:0",
            stop,
            ServerConfig {
                max_line_bytes: 256,
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let mut conn = TcpStream::connect(server.local_addr()).unwrap();
        conn.write_all(&[b'x'; 4096]).unwrap(); // never a newline
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("frame too large"), "{line}");
        let mut rest = String::new();
        assert_eq!(
            reader.read_line(&mut rest).unwrap(),
            0,
            "connection must close after the frame-bound error"
        );
        assert!(server.join(Duration::from_secs(10)), "threads must join");
    }

    /// Connections beyond the cap get a structured `overloaded` reject
    /// and a close; admitted connections keep serving.
    #[test]
    fn connection_cap_rejects_with_overloaded() {
        let svc = Arc::new(Service::start(ServiceConfig::default(), Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let server = serve_with(
            svc,
            "127.0.0.1:0",
            stop,
            ServerConfig {
                max_conns: 1,
                ..ServerConfig::default()
            },
        )
        .unwrap();
        // Prove the first connection is admitted and serving before the
        // second connects (its reply orders the accept events).
        let mut first = TcpStream::connect(server.local_addr()).unwrap();
        first.write_all(b"{\"cmd\":\"health\"}\n").unwrap();
        let mut r1 = BufReader::new(first.try_clone().unwrap());
        let mut line = String::new();
        r1.read_line(&mut line).unwrap();
        assert!(line.contains("status"), "{line}");
        let second = TcpStream::connect(server.local_addr()).unwrap();
        let mut r2 = BufReader::new(second);
        let mut reject = String::new();
        r2.read_line(&mut reject).unwrap();
        assert!(reject.contains("overloaded"), "{reject}");
        let mut rest = String::new();
        assert_eq!(r2.read_line(&mut rest).unwrap(), 0, "rejected conn closes");
        // The admitted connection still works after the reject.
        first.write_all(b"{\"cmd\":\"health\"}\n").unwrap();
        let mut again = String::new();
        r1.read_line(&mut again).unwrap();
        assert!(again.contains("status"), "{again}");
        assert!(server.join(Duration::from_secs(10)), "threads must join");
    }

    /// Slow-loris: a partial frame that stalls past the read timeout gets
    /// a structured `timeout` error and a close.
    #[test]
    fn stalled_partial_frame_times_out() {
        let svc = Arc::new(Service::start(ServiceConfig::default(), Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let server = serve_with(
            svc,
            "127.0.0.1:0",
            stop,
            ServerConfig {
                read_timeout: Duration::from_millis(120),
                ..ServerConfig::default()
            },
        )
        .unwrap();
        let mut conn = TcpStream::connect(server.local_addr()).unwrap();
        conn.write_all(b"{\"cmd\":").unwrap(); // partial frame, then stall
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("timeout"), "{line}");
        let mut rest = String::new();
        assert_eq!(reader.read_line(&mut rest).unwrap(), 0, "then closes");
        assert!(server.join(Duration::from_secs(10)), "threads must join");
    }

    /// Drain closes idle connections at their next read tick, and `join`
    /// reaps every connection thread.
    #[test]
    fn drain_closes_idle_connections_and_joins() {
        let svc = Arc::new(Service::start(ServiceConfig::default(), Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let server =
            serve_with(svc, "127.0.0.1:0", stop, ServerConfig::default()).unwrap();
        let mut conn = TcpStream::connect(server.local_addr()).unwrap();
        conn.write_all(b"{\"cmd\":\"health\"}\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("status"), "{line}");
        server.begin_drain();
        let mut rest = String::new();
        assert_eq!(
            reader.read_line(&mut rest).unwrap(),
            0,
            "drain must close idle connections"
        );
        assert!(server.join(Duration::from_secs(10)), "threads must join");
    }
}
