//! TCP front-end: line-delimited JSON over a listener socket.
//!
//! Request line:
//! `{"dataset":"gmm2d","solver":"ddim","nfe":10,"n":16,"seed":1,"pas":false,
//!   "deadline_ms":250.0,"priority":5}`
//!
//! `deadline_ms` (optional, finite, > 0) is the request's soft
//! end-to-end latency budget: the continuous scheduler sheds the request
//! with a structured `deadline` error once the budget is infeasible
//! (expired, or shorter than the key's projected run time). `priority`
//! (optional integer, [`MIN_PRIORITY`]`..=`[`MAX_PRIORITY`], default 0)
//! orders the request within its key's queue — higher admits first, FIFO
//! among equals. Both affect scheduling only, never sample numerics.
//!
//! Response line:
//! `{"id":1,"n":16,"dim":2,"nfe":10,"batched_with":3,"latency_ms":4.2,
//!   "queue_ms":0.3,"run_ms":3.9,"samples":[...]}`. Error replies carry
//! timing too (error paths are where operators most need it):
//! `{"id":1,"error":"...","latency_ms":4.2,"queue_ms":4.2,"run_ms":0}`.
//!
//! Parsing is strict where silence would mis-serve: an unknown `dataset`
//! or `solver` is an error (not a silent fall-back to the default model),
//! `n` outside `1..=MAX_N` and `nfe` outside `1..=MAX_NFE` are errors
//! (not silent clamps), and `seed`
//! must be an exact non-negative integer — it is matched against the
//! request's RNG stream bit-for-bit, so values parsed through f64 (which
//! loses precision above 2^53) or negative values are rejected. A
//! non-finite or non-positive `deadline_ms` and a fractional or
//! out-of-range `priority` are likewise errors. Absent fields still take
//! the documented defaults.
//!
//! Lines carrying a `"cmd"` key are **admin commands** instead of
//! sampling requests:
//!
//! * `{"cmd":"status"}` — the metrics/registry/store counter snapshot
//!   ([`Service::status_json`]).
//! * `{"cmd":"metrics"}` — the full text-format metrics page
//!   ([`Service::metrics_text`]: Prometheus-style exposition text with
//!   counters, `queue_ms`/`run_ms`/`latency_ms` histograms, pool gauges
//!   and per-key series), wrapped as
//!   `{"format":"prometheus-text","text":"..."}` so the reply stays one
//!   JSON line.
//! * `{"cmd":"health"}` — the one-look health summary
//!   ([`Service::health_json`]: `status` of `"ok"`/`"overloaded"`,
//!   in-flight/shed/failed counts, coarse latency quantiles,
//!   key saturation).
//! * `{"cmd":"rollback","dataset":...,"solver":...,"nfe":...}` — rolls
//!   the key's dict back to its previous stored version and replies
//!   `{"ok":true,"version":v}`.

use super::service::{SamplingRequest, Service};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Largest per-request batch the front-end accepts.
pub const MAX_N: usize = 4096;

/// Largest NFE budget the front-end accepts. Unbounded `nfe` would let a
/// single request allocate an `nfe + 1`-node schedule (and spend that
/// many model evaluations) on a worker thread.
pub const MAX_NFE: usize = 10_000;

/// Lowest scheduling priority the front-end accepts.
pub const MIN_PRIORITY: i32 = -100;

/// Highest scheduling priority the front-end accepts.
pub const MAX_PRIORITY: i32 = 100;

pub fn parse_request(line: &str) -> Result<SamplingRequest, String> {
    let j = Json::parse(line)?;
    let dataset = match j.get("dataset") {
        None => "gmm-hd64".to_string(),
        Some(v) => v
            .as_str()
            .ok_or("\"dataset\" must be a string")?
            .to_string(),
    };
    // Name check only — constructing the dataset here would run its mode
    // generators (eigendecompositions) once per request just to validate
    // a string.
    if !crate::data::registry::ALL.contains(&dataset.as_str()) {
        return Err(format!("unknown dataset \"{dataset}\""));
    }
    let solver = match j.get("solver") {
        None => "ddim".to_string(),
        Some(v) => v.as_str().ok_or("\"solver\" must be a string")?.to_string(),
    };
    if crate::solvers::registry::get(&solver).is_none() {
        return Err(format!("unknown solver \"{solver}\""));
    }
    let nfe = match j.get("nfe") {
        None => 10,
        Some(v) => {
            let nfe = v.as_usize().ok_or("\"nfe\" must be a positive integer")?;
            if !(1..=MAX_NFE).contains(&nfe) {
                return Err(format!("\"nfe\" must be in 1..={MAX_NFE} (got {nfe})"));
            }
            nfe
        }
    };
    let n_samples = match j.get("n") {
        None => 1,
        Some(v) => {
            let n = v.as_usize().ok_or("\"n\" must be a positive integer")?;
            if !(1..=MAX_N).contains(&n) {
                return Err(format!("\"n\" must be in 1..={MAX_N} (got {n})"));
            }
            n
        }
    };
    // Exact u64 parse from the integer token: `as_u64` refuses negatives,
    // fractions, and float-typed values above 2^53, so a seed never loses
    // precision silently.
    let seed = match j.get("seed") {
        None => 0,
        Some(v) => v
            .as_u64()
            .ok_or("\"seed\" must be a non-negative integer (full u64 range)")?,
    };
    let use_pas = match j.get("pas") {
        None => false,
        Some(v) => v.as_bool().ok_or("\"pas\" must be a boolean")?,
    };
    // SLO fields: strict like everything above — a deadline of 0 (or a
    // negative/NaN one) and a fractional or out-of-range priority are
    // rejected, not silently clamped or ignored.
    let deadline_ms = match j.get("deadline_ms") {
        None => None,
        Some(v) => {
            let d = v
                .as_f64()
                .ok_or("\"deadline_ms\" must be a number (milliseconds)")?;
            if !d.is_finite() || d <= 0.0 {
                return Err(format!(
                    "\"deadline_ms\" must be a finite positive number of milliseconds (got {d})"
                ));
            }
            Some(d)
        }
    };
    let priority = match j.get("priority") {
        None => 0,
        Some(v) => {
            let p = v.as_f64().ok_or("\"priority\" must be an integer")?;
            if p.fract() != 0.0 {
                return Err(format!("\"priority\" must be an integer (got {p})"));
            }
            let p = p as i64;
            if p < MIN_PRIORITY as i64 || p > MAX_PRIORITY as i64 {
                return Err(format!(
                    "\"priority\" must be in {MIN_PRIORITY}..={MAX_PRIORITY} (got {p})"
                ));
            }
            p as i32
        }
    };
    Ok(SamplingRequest {
        id: 0,
        dataset,
        solver,
        nfe,
        n_samples,
        seed,
        use_pas,
        deadline_ms,
        priority,
    })
}

pub fn response_json(resp: &super::service::SamplingResponse) -> Json {
    let mut o = Json::obj();
    if let Some(e) = &resp.error {
        // Error replies keep their identity and timing: operators triage
        // failures by how long the request lived, not just why it died.
        o.set("id", Json::UInt(resp.id))
            .set("error", Json::Str(e.clone()))
            .set("latency_ms", Json::Num(resp.latency_ms))
            .set("queue_ms", Json::Num(resp.queue_ms))
            .set("run_ms", Json::Num(resp.run_ms));
        return o;
    }
    o.set("id", Json::UInt(resp.id))
        .set("n", Json::Num(resp.n as f64))
        .set("dim", Json::Num(resp.dim as f64))
        .set("nfe", Json::Num(resp.nfe_spent as f64))
        .set("batched_with", Json::Num(resp.batched_with as f64))
        .set("latency_ms", Json::Num(resp.latency_ms))
        .set("queue_ms", Json::Num(resp.queue_ms))
        .set("run_ms", Json::Num(resp.run_ms))
        .set("samples", Json::from_f64_slice(&resp.samples));
    o
}

/// Serve until `stop` is set. Binds to `addr` (e.g. "127.0.0.1:7777");
/// returns the bound address (useful with port 0 in tests).
pub fn serve(
    service: Arc<Service>,
    addr: &str,
    stop: Arc<AtomicBool>,
) -> std::io::Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    std::thread::spawn(move || {
        loop {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    let svc = service.clone();
                    std::thread::spawn(move || {
                        let _ = handle_client(stream, &svc);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(_) => break,
            }
        }
    });
    Ok(local)
}

fn error_json(msg: String) -> Json {
    let mut o = Json::obj();
    o.set("error", Json::Str(msg));
    o
}

/// Dispatch a line carrying a `"cmd"` key. `None` means the line is not
/// an admin command (no such key, or not even JSON) and should be parsed
/// as a sampling request — whose own strict errors then apply.
fn admin_reply(line: &str, svc: &Service) -> Option<Json> {
    let j = Json::parse(line).ok()?;
    let cmd = j.get("cmd")?;
    let Some(cmd) = cmd.as_str() else {
        return Some(error_json("\"cmd\" must be a string".into()));
    };
    let reply = match cmd {
        "status" => svc.status_json(),
        "metrics" => {
            // The exposition text is multi-line; the wire is one JSON
            // object per line, so it ships as a string field.
            let mut o = Json::obj();
            o.set("format", Json::Str("prometheus-text".into()))
                .set("text", Json::Str(svc.metrics_text()));
            o
        }
        "health" => svc.health_json(),
        "rollback" => {
            let args = (
                j.get("dataset").and_then(|v| v.as_str()),
                j.get("solver").and_then(|v| v.as_str()),
                j.get("nfe").and_then(|v| v.as_usize()),
            );
            match args {
                (Some(dataset), Some(solver), Some(nfe)) => {
                    match svc.rollback(dataset, solver, nfe) {
                        Ok(version) => {
                            let mut o = Json::obj();
                            o.set("ok", Json::Bool(true))
                                .set("version", Json::UInt(version));
                            o
                        }
                        Err(e) => error_json(e),
                    }
                }
                _ => error_json(
                    "rollback needs \"dataset\" (string), \"solver\" (string), \"nfe\" (integer)"
                        .into(),
                ),
            }
        }
        other => error_json(format!("unknown cmd \"{other}\"")),
    };
    Some(reply)
}

fn handle_client(stream: TcpStream, svc: &Service) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match admin_reply(&line, svc) {
            Some(r) => r,
            None => match parse_request(&line) {
                Ok(req) => match svc.call(req) {
                    Ok(resp) => response_json(&resp),
                    Err(e) => error_json(e),
                },
                Err(e) => error_json(e),
            },
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::service::ServiceConfig;

    #[test]
    fn parses_request_line() {
        let r = parse_request(r#"{"dataset":"gmm2d","solver":"ipndm","nfe":8,"n":4,"seed":3}"#)
            .unwrap();
        assert_eq!(r.dataset, "gmm2d");
        assert_eq!(r.solver, "ipndm");
        assert_eq!(r.nfe, 8);
        assert_eq!(r.n_samples, 4);
        assert!(!r.use_pas);
    }

    #[test]
    fn absent_fields_take_defaults() {
        let r = parse_request("{}").unwrap();
        assert_eq!(r.dataset, "gmm-hd64");
        assert_eq!(r.solver, "ddim");
        assert_eq!(r.nfe, 10);
        assert_eq!(r.n_samples, 1);
        assert_eq!(r.seed, 0);
        assert!(!r.use_pas);
        assert_eq!(r.deadline_ms, None);
        assert_eq!(r.priority, 0);
    }

    /// SLO fields parse with the same strictness as everything else:
    /// valid values flow through, junk is a structured error.
    #[test]
    fn slo_fields_parse_and_validate() {
        let r = parse_request(
            r#"{"dataset":"gmm2d","deadline_ms":250.5,"priority":-2}"#,
        )
        .unwrap();
        assert_eq!(r.deadline_ms, Some(250.5));
        assert_eq!(r.priority, -2);
        let r = parse_request(r#"{"dataset":"gmm2d","priority":100}"#).unwrap();
        assert_eq!(r.priority, 100);
        for (line, needle) in [
            (r#"{"deadline_ms":0}"#, "finite positive"),
            (r#"{"deadline_ms":-5}"#, "finite positive"),
            (r#"{"deadline_ms":"soon"}"#, "must be a number"),
            (r#"{"priority":1.5}"#, "must be an integer"),
            (r#"{"priority":101}"#, "must be in -100..=100"),
            (r#"{"priority":-101}"#, "must be in -100..=100"),
            (r#"{"priority":"high"}"#, "must be an integer"),
        ] {
            match parse_request(line) {
                Err(e) => assert!(e.contains(needle), "{line}: {e}"),
                Ok(r) => panic!("{line} must be rejected, parsed {r:?}"),
            }
        }
    }

    /// Seeds parse exactly from the raw integer token across the full u64
    /// range; negatives and lossy encodings are rejected.
    #[test]
    fn seed_roundtrips_exactly() {
        for seed in [0u64, (1 << 53) - 1, (1 << 53) + 1, u64::MAX - 1, u64::MAX] {
            let r = parse_request(&format!(r#"{{"dataset":"gmm2d","seed":{seed}}}"#)).unwrap();
            assert_eq!(r.seed, seed, "seed {seed} must survive parsing bit-for-bit");
        }
        for bad in ["-1", "-9007199254740993", "1.5", "\"7\"", "18446744073709551616"] {
            let e = parse_request(&format!(r#"{{"dataset":"gmm2d","seed":{bad}}}"#));
            assert!(e.is_err(), "seed {bad} must be rejected, got {e:?}");
        }
    }

    /// Mistyped or unknown dataset/solver/n values produce errors instead
    /// of silently serving the default model or a clamped batch.
    #[test]
    fn unknown_fields_error_instead_of_defaulting() {
        for (line, needle) in [
            (r#"{"dataset":"gmm2d-typo"}"#, "unknown dataset"),
            (r#"{"dataset":42}"#, "must be a string"),
            (r#"{"solver":"ddimm"}"#, "unknown solver"),
            (r#"{"solver":false}"#, "must be a string"),
            (r#"{"n":0}"#, "\"n\" must be in"),
            (r#"{"n":4097}"#, "\"n\" must be in"),
            (r#"{"n":"many"}"#, "positive integer"),
            (r#"{"nfe":0}"#, "\"nfe\" must be in"),
            (r#"{"nfe":-4}"#, "positive integer"),
            (r#"{"nfe":1000000000000000000}"#, "\"nfe\" must be in"),
            (r#"{"pas":"yes"}"#, "boolean"),
        ] {
            match parse_request(line) {
                Err(e) => assert!(e.contains(needle), "{line}: {e}"),
                Ok(r) => panic!("{line} must be rejected, parsed {r:?}"),
            }
        }
    }

    #[test]
    fn end_to_end_over_tcp() {
        let svc = Arc::new(Service::start(ServiceConfig::default(), Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let addr = serve(svc, "127.0.0.1:0", stop.clone()).unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"{\"dataset\":\"gmm2d\",\"solver\":\"ddim\",\"nfe\":6,\"n\":2,\"seed\":1}\n")
            .unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert!(j.get("error").is_none(), "{line}");
        assert_eq!(j.get("n").unwrap().as_usize().unwrap(), 2);
        assert_eq!(
            j.get("samples").unwrap().as_arr().unwrap().len(),
            4 // 2 samples x dim 2
        );
        stop.store(true, Ordering::Relaxed);
    }

    #[test]
    fn admin_status_and_rollback_over_tcp() {
        let svc = Arc::new(Service::start(ServiceConfig::default(), Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let addr = serve(svc, "127.0.0.1:0", stop.clone()).unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut ask = |line: &str| {
            conn.write_all(line.as_bytes()).unwrap();
            conn.write_all(b"\n").unwrap();
            let mut reply = String::new();
            reader.read_line(&mut reply).unwrap();
            Json::parse(reply.trim()).unwrap()
        };
        let status = ask(r#"{"cmd":"status"}"#);
        assert!(status.get("error").is_none(), "{status:?}");
        assert_eq!(status.get("rollbacks").unwrap().as_u64(), Some(0));
        assert_eq!(status.get("failed").unwrap().as_u64(), Some(0));
        assert_eq!(status.get("shed").unwrap().as_u64(), Some(0));
        assert_eq!(status.get("artifacts_loaded").unwrap().as_u64(), Some(0));
        assert_eq!(status.get("artifact_store").unwrap(), &Json::Null);
        // One sampling request, so the observability surfaces have data.
        let sample = ask(r#"{"dataset":"gmm2d","solver":"ddim","nfe":6,"n":2,"seed":1}"#);
        assert!(sample.get("error").is_none(), "{sample:?}");
        let metrics = ask(r#"{"cmd":"metrics"}"#);
        assert_eq!(
            metrics.get("format").and_then(|v| v.as_str()),
            Some("prometheus-text")
        );
        let text = metrics.get("text").and_then(|v| v.as_str()).unwrap();
        assert!(text.contains("pas_requests_total 1"), "{text}");
        assert!(text.contains("pas_serve_latency_ms_bucket"), "{text}");
        let health = ask(r#"{"cmd":"health"}"#);
        assert_eq!(health.get("status").and_then(|v| v.as_str()), Some("ok"));
        assert_eq!(health.get("completed").and_then(|v| v.as_u64()), Some(1));
        // Rollback without a store / with bad args / unknown cmd: errors.
        for (line, needle) in [
            (
                r#"{"cmd":"rollback","dataset":"gmm2d","solver":"ddim","nfe":6}"#,
                "no artifact store",
            ),
            (r#"{"cmd":"rollback","dataset":"gmm2d"}"#, "rollback needs"),
            (r#"{"cmd":"selfdestruct"}"#, "unknown cmd"),
            (r#"{"cmd":42}"#, "must be a string"),
        ] {
            let r = ask(line);
            let e = r.get("error").and_then(|v| v.as_str()).unwrap_or_default();
            assert!(e.contains(needle), "{line}: {r:?}");
        }
        stop.store(true, Ordering::Relaxed);
    }

    #[test]
    fn malformed_request_gets_error() {
        let svc = Arc::new(Service::start(ServiceConfig::default(), Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let addr = serve(svc, "127.0.0.1:0", stop.clone()).unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"not json\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"));
        stop.store(true, Ordering::Relaxed);
    }
}
