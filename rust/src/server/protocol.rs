//! TCP front-end: line-delimited JSON over a listener socket.
//!
//! Request line:
//! `{"dataset":"gmm2d","solver":"ddim","nfe":10,"n":16,"seed":1,"pas":false}`
//!
//! Response line:
//! `{"id":1,"n":16,"dim":2,"nfe":10,"batched_with":3,"latency_ms":4.2,
//!   "samples":[...]}` or `{"error":"..."}`.

use super::service::{SamplingRequest, Service};
use crate::util::json::Json;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

pub fn parse_request(line: &str) -> Result<SamplingRequest, String> {
    let j = Json::parse(line)?;
    Ok(SamplingRequest {
        id: 0,
        dataset: j
            .get("dataset")
            .and_then(|v| v.as_str())
            .unwrap_or("gmm-hd64")
            .to_string(),
        solver: j
            .get("solver")
            .and_then(|v| v.as_str())
            .unwrap_or("ddim")
            .to_string(),
        nfe: j.get("nfe").and_then(|v| v.as_usize()).unwrap_or(10),
        n_samples: j.get("n").and_then(|v| v.as_usize()).unwrap_or(1).clamp(1, 4096),
        seed: j.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
        use_pas: j.get("pas").and_then(|v| v.as_bool()).unwrap_or(false),
    })
}

pub fn response_json(resp: &super::service::SamplingResponse) -> Json {
    let mut o = Json::obj();
    if let Some(e) = &resp.error {
        o.set("error", Json::Str(e.clone()));
        return o;
    }
    o.set("id", Json::Num(resp.id as f64))
        .set("n", Json::Num(resp.n as f64))
        .set("dim", Json::Num(resp.dim as f64))
        .set("nfe", Json::Num(resp.nfe_spent as f64))
        .set("batched_with", Json::Num(resp.batched_with as f64))
        .set("latency_ms", Json::Num(resp.latency_ms))
        .set("samples", Json::from_f64_slice(&resp.samples));
    o
}

/// Serve until `stop` is set. Binds to `addr` (e.g. "127.0.0.1:7777");
/// returns the bound address (useful with port 0 in tests).
pub fn serve(
    service: Arc<Service>,
    addr: &str,
    stop: Arc<AtomicBool>,
) -> std::io::Result<std::net::SocketAddr> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    listener.set_nonblocking(true)?;
    std::thread::spawn(move || {
        loop {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    let svc = service.clone();
                    std::thread::spawn(move || {
                        let _ = handle_client(stream, &svc);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(_) => break,
            }
        }
    });
    Ok(local)
}

fn handle_client(stream: TcpStream, svc: &Service) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let reply = match parse_request(&line) {
            Ok(req) => match svc.call(req) {
                Ok(resp) => response_json(&resp),
                Err(e) => {
                    let mut o = Json::obj();
                    o.set("error", Json::Str(e));
                    o
                }
            },
            Err(e) => {
                let mut o = Json::obj();
                o.set("error", Json::Str(e));
                o
            }
        };
        writer.write_all(reply.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        writer.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::service::ServiceConfig;

    #[test]
    fn parses_request_line() {
        let r = parse_request(r#"{"dataset":"gmm2d","solver":"ipndm","nfe":8,"n":4,"seed":3}"#)
            .unwrap();
        assert_eq!(r.dataset, "gmm2d");
        assert_eq!(r.solver, "ipndm");
        assert_eq!(r.nfe, 8);
        assert_eq!(r.n_samples, 4);
        assert!(!r.use_pas);
    }

    #[test]
    fn end_to_end_over_tcp() {
        let svc = Arc::new(Service::start(ServiceConfig::default(), Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let addr = serve(svc, "127.0.0.1:0", stop.clone()).unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"{\"dataset\":\"gmm2d\",\"solver\":\"ddim\",\"nfe\":6,\"n\":2,\"seed\":1}\n")
            .unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let j = Json::parse(line.trim()).unwrap();
        assert!(j.get("error").is_none(), "{line}");
        assert_eq!(j.get("n").unwrap().as_usize().unwrap(), 2);
        assert_eq!(
            j.get("samples").unwrap().as_arr().unwrap().len(),
            4 // 2 samples x dim 2
        );
        stop.store(true, Ordering::Relaxed);
    }

    #[test]
    fn malformed_request_gets_error() {
        let svc = Arc::new(Service::start(ServiceConfig::default(), Vec::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let addr = serve(svc, "127.0.0.1:0", stop.clone()).unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"not json\n").unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("error"));
        stop.store(true, Ordering::Relaxed);
    }
}
