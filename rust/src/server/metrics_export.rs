//! Operator observability surface for the serving stack: lock-cheap
//! fixed-bucket latency histograms, per-key gauges, and renderers for a
//! text-format metrics page plus a health summary.
//!
//! # Design
//!
//! * **Histograms are atomic bucket counters.** [`Histogram::record`] is
//!   three relaxed `fetch_add`s on a fixed array — no locks, no
//!   allocation — so the hot retire path in the continuous scheduler can
//!   observe every response without perturbing the zero-alloc discipline
//!   (`tests/alloc_audit.rs`) or serialization of workers. Bucket bounds
//!   are fixed at compile time ([`BUCKET_BOUNDS_MS`]), spanning 50µs to
//!   10s, which covers everything from a single cheap solver step to a
//!   pathological queue stall.
//! * **Quantiles are bucket upper bounds.** [`Histogram::quantile_ms`]
//!   walks the cumulative counts and returns the upper bound of the
//!   bucket containing the target rank — coarse but monotone, honest
//!   about its resolution, and computable without retaining samples.
//! * **Text format.** [`render_text`] emits Prometheus-style exposition
//!   text (`# TYPE` headers, cumulative `_bucket{le=...}` counters,
//!   `_sum`/`_count`, labeled per-key gauges) so any scrape-based
//!   collector — or a human with `pas client --cmd metrics` — can read
//!   it. [`health_json`] is the machine-readable one-look summary
//!   (status, saturation, shed/fail counts, coarse latency quantiles)
//!   behind the wire `{"cmd":"health"}` command.
//!
//! Everything here is observational: nothing in this module is on the
//! numerics path, and recording a sample never blocks a scheduler tick.

use super::service::Metrics;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Histogram bucket upper bounds, in milliseconds. The final implicit
/// bucket is `+Inf` (the overflow bucket).
pub const BUCKET_BOUNDS_MS: [f64; 16] = [
    0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0, 100.0, 250.0, 500.0, 1000.0, 2500.0,
    10_000.0,
];

/// Bucket count including the `+Inf` overflow bucket.
const N_BUCKETS: usize = BUCKET_BOUNDS_MS.len() + 1;

/// Fixed-bucket latency histogram with atomic counters. Recording is
/// lock-free and allocation-free; rendering and quantile estimation pay
/// the (cold-path) cost of a relaxed sweep over the buckets.
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    /// Sum of recorded values in integer microseconds (so the hot path
    /// needs no float atomics; 2^64 µs ≈ 585k years of accumulated
    /// latency, overflow is not a practical concern).
    sum_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl Histogram {
    /// Record one observation (milliseconds). Three relaxed atomic adds;
    /// never locks, never allocates.
    pub fn record(&self, ms: f64) {
        let ms = if ms.is_finite() && ms > 0.0 { ms } else { 0.0 };
        let idx = BUCKET_BOUNDS_MS
            .iter()
            .position(|&b| ms <= b)
            .unwrap_or(N_BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add((ms * 1e3) as u64, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum_ms(&self) -> f64 {
        self.sum_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Coarse quantile estimate: the upper bound of the bucket containing
    /// the `q`-rank observation (the overflow bucket clamps to the
    /// largest finite bound). Returns 0.0 on an empty histogram.
    pub fn quantile_ms(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if cum >= target {
                return BUCKET_BOUNDS_MS[i.min(BUCKET_BOUNDS_MS.len() - 1)];
            }
        }
        BUCKET_BOUNDS_MS[BUCKET_BOUNDS_MS.len() - 1]
    }

    /// Append this histogram in Prometheus exposition format
    /// (`<name>_bucket{le="..."}` cumulative counters, `_sum`, `_count`).
    fn render(&self, name: &str, out: &mut String) {
        use std::fmt::Write;
        let _ = writeln!(out, "# TYPE {name} histogram");
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b.load(Ordering::Relaxed);
            if i < BUCKET_BOUNDS_MS.len() {
                let _ = writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", BUCKET_BOUNDS_MS[i]);
            } else {
                let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {cum}");
            }
        }
        let _ = writeln!(out, "{name}_sum {}", self.sum_ms());
        let _ = writeln!(out, "{name}_count {}", self.count());
    }
}

/// The serving path's three end-to-end latency histograms, recorded once
/// per retired (or failed) request.
#[derive(Default)]
pub struct ServeHistograms {
    /// Submit → admission.
    pub queue_ms: Histogram,
    /// Admission → final solver step.
    pub run_ms: Histogram,
    /// Submit → response (queue + run).
    pub latency_ms: Histogram,
}

impl ServeHistograms {
    /// Record one completed request's timing triple.
    pub fn observe(&self, queue_ms: f64, run_ms: f64, latency_ms: f64) {
        self.queue_ms.record(queue_ms);
        self.run_ms.record(run_ms);
        self.latency_ms.record(latency_ms);
    }
}

/// Point-in-time view of one compatibility key, taken by
/// [`super::service::Service`] under the router's locks.
pub struct KeySnapshot {
    /// Human-readable key label (`dataset/solver/nfe[/pas]`).
    pub key: String,
    /// True while a worker owns the key's resident run.
    pub active: bool,
    /// Requests queued behind the resident run.
    pub queue_depth: usize,
    /// Trajectory rows currently resident in the key's engine run.
    pub resident_rows: usize,
    /// Requests retired (completed) on this key since startup.
    pub retired: u64,
    /// Requests shed for deadline infeasibility on this key.
    pub shed: u64,
}

/// Static + point-in-time pool facts for the gauge section.
pub struct PoolInfo {
    pub workers: usize,
    pub pool_threads: usize,
    pub engine_threads: usize,
    pub max_batch: usize,
    pub queue_depth: usize,
    /// Keys currently waiting in the dispatch queue for a worker.
    pub backlog: usize,
    pub uptime_s: f64,
    pub batching: &'static str,
    /// Active matmul kernel backend (`scalar` / `avx2` / `avx2fma`).
    pub kernel_backend: &'static str,
}

/// Escape a label value for the exposition format (backslash and quote).
fn escape_label(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

/// Render the full text-format metrics page: global counters, the serve
/// histograms, pool gauges, and per-key gauges/counters.
pub fn render_text(metrics: &Metrics, keys: &[KeySnapshot], pool: &PoolInfo) -> String {
    use std::fmt::Write;
    let mut out = String::with_capacity(4096);
    let c = |v: &AtomicU64| v.load(Ordering::Relaxed);
    let counters: [(&str, u64, &str); 14] = [
        ("pas_requests_total", c(&metrics.requests), "Requests accepted by submit"),
        ("pas_completed_total", c(&metrics.completed), "Requests answered with samples"),
        ("pas_rejected_total", c(&metrics.rejected), "Requests rejected by backpressure"),
        ("pas_failed_total", c(&metrics.failed), "Requests answered with a structured error"),
        ("pas_shed_total", c(&metrics.shed), "Requests shed for deadline infeasibility (subset of failed)"),
        ("pas_batches_total", c(&metrics.batches), "Cohorts formed / batches fused"),
        ("pas_fused_requests_total", c(&metrics.fused_requests), "Requests admitted into a shared run"),
        (
            "pas_admitted_mid_flight_total",
            c(&metrics.admitted_mid_flight),
            "Requests admitted while earlier cohorts were mid-flight",
        ),
        ("pas_ticks_total", c(&metrics.ticks), "Scheduler ticks"),
        ("pas_dicts_trained_total", c(&metrics.dicts_trained), "Online train_pas runs"),
        ("pas_artifacts_loaded_total", c(&metrics.artifacts_loaded), "Dicts loaded from the artifact store at startup"),
        ("pas_dicts_published_total", c(&metrics.dicts_published), "New dict versions persisted"),
        ("pas_rollbacks_total", c(&metrics.rollbacks), "Successful rollbacks"),
        (
            "pas_numeric_failures_total",
            c(&metrics.numeric_failures),
            "Requests failed for non-finite values during sampling",
        ),
    ];
    for (name, v, help) in counters {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {v}");
    }
    metrics.serve_hist.queue_ms.render("pas_serve_queue_ms", &mut out);
    metrics.serve_hist.run_ms.render("pas_serve_run_ms", &mut out);
    metrics.serve_hist.latency_ms.render("pas_serve_latency_ms", &mut out);

    let resident: usize = keys.iter().map(|k| k.resident_rows).sum();
    let capacity = pool.workers.max(1) * pool.max_batch.max(1);
    let gauges: [(&str, f64, &str); 9] = [
        ("pas_workers", pool.workers as f64, "Scheduler worker threads"),
        ("pas_pool_threads", pool.pool_threads as f64, "Shared compute pool threads"),
        ("pas_engine_threads", pool.engine_threads as f64, "Per-engine row-shard cap (0 = pool size)"),
        ("pas_max_batch", pool.max_batch as f64, "Residency cap per resident run"),
        ("pas_queue_depth_limit", pool.queue_depth as f64, "Per-key bounded queue depth"),
        ("pas_dispatch_backlog", pool.backlog as f64, "Keys waiting for a worker"),
        (
            "pas_pool_utilization",
            resident as f64 / capacity as f64,
            "Resident rows / (workers * max_batch)",
        ),
        ("pas_uptime_seconds", pool.uptime_s, "Seconds since Service::start"),
        // Gauge, not counter: breakers close again on rollback/republish.
        (
            "pas_breaker_open",
            c(&metrics.breaker_open) as f64,
            "Keys degraded to uncorrected sampling by the numeric circuit breaker",
        ),
    ];
    for (name, v, help) in gauges {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {v}");
    }
    let _ = writeln!(out, "# HELP pas_batching Active batching mode");
    let _ = writeln!(out, "# TYPE pas_batching gauge");
    let _ = writeln!(out, "pas_batching{{mode=\"{}\"}} 1", escape_label(pool.batching));
    let _ = writeln!(out, "# HELP pas_kernel_backend Active matmul kernel backend");
    let _ = writeln!(out, "# TYPE pas_kernel_backend gauge");
    let _ = writeln!(
        out,
        "pas_kernel_backend{{backend=\"{}\"}} 1",
        escape_label(pool.kernel_backend)
    );

    let _ = writeln!(out, "# HELP pas_keys Compatibility keys in the router table");
    let _ = writeln!(out, "# TYPE pas_keys gauge");
    let _ = writeln!(out, "pas_keys {}", keys.len());
    for (name, help) in [
        ("pas_key_queue_depth", "Requests queued on this key"),
        ("pas_key_resident_rows", "Rows resident in this key's engine run"),
        ("pas_key_active", "1 while a worker owns this key"),
        ("pas_key_retired_total", "Requests completed on this key"),
        ("pas_key_shed_total", "Requests deadline-shed on this key"),
    ] {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(
            out,
            "# TYPE {name} {}",
            if name.ends_with("_total") { "counter" } else { "gauge" }
        );
    }
    for k in keys {
        let label = escape_label(&k.key);
        let _ = writeln!(out, "pas_key_queue_depth{{key=\"{label}\"}} {}", k.queue_depth);
        let _ = writeln!(out, "pas_key_resident_rows{{key=\"{label}\"}} {}", k.resident_rows);
        let _ = writeln!(out, "pas_key_active{{key=\"{label}\"}} {}", u8::from(k.active));
        let _ = writeln!(out, "pas_key_retired_total{{key=\"{label}\"}} {}", k.retired);
        let _ = writeln!(out, "pas_key_shed_total{{key=\"{label}\"}} {}", k.shed);
    }
    out
}

/// One-look health summary as JSON: coarse status classification plus
/// the numbers an operator triages with. `status` is `"overloaded"` when
/// any key's queue is at ≥ 80% of the bounded depth, `"degraded"` when
/// a numeric circuit breaker holds any key on uncorrected sampling, else
/// `"ok"`.
pub fn health_json(
    metrics: &Metrics,
    keys: &[KeySnapshot],
    queue_depth_limit: usize,
    uptime_s: f64,
    dicts_registered: usize,
    artifact_store: Option<String>,
    kernel_backend: &str,
) -> Json {
    let requests = metrics.requests.load(Ordering::Relaxed);
    let completed = metrics.completed.load(Ordering::Relaxed);
    let rejected = metrics.rejected.load(Ordering::Relaxed);
    let failed = metrics.failed.load(Ordering::Relaxed);
    let shed = metrics.shed.load(Ordering::Relaxed);
    let numeric_failures = metrics.numeric_failures.load(Ordering::Relaxed);
    let breakers_open = metrics.breaker_open.load(Ordering::Relaxed);
    let in_flight = requests.saturating_sub(completed + rejected + failed);
    let max_queue = keys.iter().map(|k| k.queue_depth).max().unwrap_or(0);
    // "≥ 80% full" without floats: depth * 5 >= limit * 4.
    let saturated = keys
        .iter()
        .filter(|k| k.queue_depth * 5 >= queue_depth_limit.max(1) * 4)
        .count();
    let status = if saturated > 0 {
        "overloaded"
    } else if breakers_open > 0 {
        "degraded"
    } else {
        "ok"
    };
    let mut o = Json::obj();
    o.set("status", Json::Str(status.into()))
        .set("uptime_s", Json::Num(uptime_s))
        .set("requests", Json::UInt(requests))
        .set("completed", Json::UInt(completed))
        .set("rejected", Json::UInt(rejected))
        .set("failed", Json::UInt(failed))
        .set("shed", Json::UInt(shed))
        .set("numeric_failures", Json::UInt(numeric_failures))
        .set("breakers_open", Json::UInt(breakers_open))
        .set("in_flight", Json::UInt(in_flight))
        .set(
            "latency_p50_ms",
            Json::Num(metrics.serve_hist.latency_ms.quantile_ms(0.5)),
        )
        .set(
            "latency_p99_ms",
            Json::Num(metrics.serve_hist.latency_ms.quantile_ms(0.99)),
        )
        .set(
            "queue_p99_ms",
            Json::Num(metrics.serve_hist.queue_ms.quantile_ms(0.99)),
        )
        .set("keys_total", Json::UInt(keys.len() as u64))
        .set(
            "keys_active",
            Json::UInt(keys.iter().filter(|k| k.active).count() as u64),
        )
        .set("keys_saturated", Json::UInt(saturated as u64))
        .set("max_key_queue_depth", Json::UInt(max_queue as u64))
        .set("dicts_registered", Json::UInt(dicts_registered as u64))
        .set("kernel_backend", Json::Str(kernel_backend.into()));
    match artifact_store {
        Some(root) => o.set("artifact_store", Json::Str(root)),
        None => o.set("artifact_store", Json::Null),
    };
    o
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_counts_and_quantiles() {
        let h = Histogram::default();
        assert_eq!(h.quantile_ms(0.5), 0.0, "empty histogram");
        for _ in 0..90 {
            h.record(0.3); // -> le=0.5 bucket
        }
        for _ in 0..10 {
            h.record(40.0); // -> le=50 bucket
        }
        assert_eq!(h.count(), 100);
        assert!((h.sum_ms() - (90.0 * 0.3 + 10.0 * 40.0)).abs() < 0.5);
        assert_eq!(h.quantile_ms(0.5), 0.5);
        assert_eq!(h.quantile_ms(0.99), 50.0);
        // Overflow bucket clamps to the largest finite bound.
        h.record(1e9);
        assert_eq!(h.quantile_ms(1.0), 10_000.0);
        // Non-finite / negative inputs are clamped, not dropped or NaN'd.
        h.record(f64::NAN);
        h.record(-3.0);
        assert_eq!(h.count(), 103);
    }

    #[test]
    fn render_text_is_well_formed() {
        let metrics = Metrics::default();
        metrics.requests.store(7, Ordering::Relaxed);
        metrics.serve_hist.observe(0.2, 1.5, 1.7);
        let keys = [KeySnapshot {
            key: "gmm2d/ddim/6".into(),
            active: true,
            queue_depth: 3,
            resident_rows: 12,
            retired: 5,
            shed: 1,
        }];
        let pool = PoolInfo {
            workers: 4,
            pool_threads: 4,
            engine_threads: 0,
            max_batch: 256,
            queue_depth: 256,
            backlog: 0,
            uptime_s: 1.0,
            batching: "continuous",
            kernel_backend: "scalar",
        };
        let text = render_text(&metrics, &keys, &pool);
        assert!(text.contains("pas_requests_total 7"));
        assert!(text.contains("pas_kernel_backend{backend=\"scalar\"} 1"));
        assert!(text.contains("pas_serve_latency_ms_bucket{le=\"+Inf\"} 1"));
        assert!(text.contains("pas_serve_latency_ms_count 1"));
        assert!(text.contains("pas_key_queue_depth{key=\"gmm2d/ddim/6\"} 3"));
        assert!(text.contains("pas_key_shed_total{key=\"gmm2d/ddim/6\"} 1"));
        assert!(text.contains("pas_pool_utilization"));
        assert!(text.contains("pas_numeric_failures_total 0"));
        assert!(text.contains("# TYPE pas_breaker_open gauge"));
        // Every non-comment line is "name[{labels}] value".
        for line in text.lines() {
            if line.starts_with('#') || line.is_empty() {
                continue;
            }
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(
                value.parse::<f64>().is_ok(),
                "unparseable metric value in line: {line}"
            );
            assert!(parts.next().is_some(), "metric line without a name: {line}");
        }
    }

    #[test]
    fn health_flags_saturation() {
        let metrics = Metrics::default();
        metrics.requests.store(10, Ordering::Relaxed);
        metrics.completed.store(6, Ordering::Relaxed);
        metrics.failed.store(1, Ordering::Relaxed);
        let mut keys = vec![KeySnapshot {
            key: "a".into(),
            active: true,
            queue_depth: 1,
            resident_rows: 4,
            retired: 6,
            shed: 0,
        }];
        let h = health_json(&metrics, &keys, 256, 2.0, 1, None, "scalar");
        assert_eq!(h.get("status").and_then(|s| s.as_str()), Some("ok"));
        assert_eq!(h.get("in_flight").and_then(|v| v.as_u64()), Some(3));
        assert_eq!(
            h.get("kernel_backend").and_then(|s| s.as_str()),
            Some("scalar")
        );
        keys[0].queue_depth = 250; // >= 80% of 256
        let h = health_json(&metrics, &keys, 256, 2.0, 1, None, "scalar");
        assert_eq!(h.get("status").and_then(|s| s.as_str()), Some("overloaded"));
        assert_eq!(h.get("keys_saturated").and_then(|v| v.as_u64()), Some(1));
        // An open numeric breaker degrades health (overload still wins).
        keys[0].queue_depth = 1;
        metrics.breaker_open.store(1, Ordering::Relaxed);
        metrics.numeric_failures.store(3, Ordering::Relaxed);
        let h = health_json(&metrics, &keys, 256, 2.0, 1, None, "scalar");
        assert_eq!(h.get("status").and_then(|s| s.as_str()), Some("degraded"));
        assert_eq!(h.get("breakers_open").and_then(|v| v.as_u64()), Some(1));
        assert_eq!(h.get("numeric_failures").and_then(|v| v.as_u64()), Some(3));
    }

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label(r#"a"b\c"#), r#"a\"b\\c"#);
    }
}
