//! In-process sampling service: dynamic batching + worker pool +
//! backpressure + **online PAS training**. The TCP front-end in
//! [`super::protocol`] is a thin shim over this, and
//! examples/serve_batch.rs drives it directly.
//!
//! Dictionaries are held behind an `RwLock` so [`Service::train_pas`] can
//! train (or retrain) a `(dataset, solver, nfe)` correction **while
//! serving traffic** — workers take a cheap read-lock snapshot per batch
//! (a dict is ≤ ~40 f64s) and are never blocked by an in-flight training
//! run, which executes on the caller's thread against the service's
//! persistent, workspace-pooled [`TrainSession`].

use crate::pas::coords::CoordinateDict;
use crate::pas::correct::CorrectedSampler;
use crate::pas::train::{TrainConfig, TrainSession};
use crate::schedule::default_schedule;
use crate::score::analytic::AnalyticEps;
use crate::score::EpsModel;
use crate::solvers::engine::{Record, SamplerEngine};
use crate::solvers::Solver;
use crate::traj::sample_prior;
use crate::util::rng::Pcg64;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Shared dictionary registry: `(dataset, solver, nfe) -> dict`.
type DictMap = HashMap<(String, String, usize), CoordinateDict>;

/// One client request.
#[derive(Clone, Debug)]
pub struct SamplingRequest {
    pub id: u64,
    pub dataset: String,
    pub solver: String,
    pub nfe: usize,
    pub n_samples: usize,
    pub seed: u64,
    /// Apply a pre-trained PAS dictionary if the service has one registered
    /// for (dataset, solver, nfe).
    pub use_pas: bool,
}

/// Service reply.
#[derive(Clone, Debug)]
pub struct SamplingResponse {
    pub id: u64,
    pub samples: Vec<f64>,
    pub n: usize,
    pub dim: usize,
    pub nfe_spent: usize,
    pub batched_with: usize,
    pub latency_ms: f64,
    pub error: Option<String>,
}

#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub workers: usize,
    /// Max trajectories fused into one solver run.
    pub max_batch: usize,
    /// How long the batcher waits to fill a batch.
    pub batch_window: Duration,
    /// Bounded queue depth (backpressure: submit blocks / rejects beyond this).
    pub queue_depth: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 4,
            max_batch: 256,
            batch_window: Duration::from_millis(2),
            queue_depth: 256,
        }
    }
}

struct Pending {
    req: SamplingRequest,
    enqueued: Instant,
    reply: SyncSender<SamplingResponse>,
}

/// Batch key: requests sharing it can be fused into one solver run.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct BatchKey {
    dataset: String,
    solver: String,
    nfe: usize,
    use_pas: bool,
}

/// Service metrics (exposed via `stats`).
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    pub batches: AtomicU64,
    pub fused_requests: AtomicU64,
    /// Dictionaries trained online via [`Service::train_pas`].
    pub dicts_trained: AtomicU64,
}

/// Summary of one online [`Service::train_pas`] run.
#[derive(Clone, Debug)]
pub struct PasTrainStats {
    pub n_params: usize,
    pub corrected_steps: Vec<usize>,
    pub train_seconds: f64,
    /// Final-node truncation error of the uncorrected / corrected
    /// training rollout (the Figure-3 endpoints).
    pub final_error_uncorrected: f64,
    pub final_error_corrected: f64,
}

pub struct Service {
    tx: SyncSender<Pending>,
    next_id: AtomicU64,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    threads: Vec<JoinHandle<()>>,
    dicts: Arc<RwLock<DictMap>>,
    /// Persistent training session for [`Service::train_pas`]: its
    /// workspaces (engine, node stores, basis store, SGD scratch) are
    /// reused across online training runs.
    trainer: Mutex<TrainSession>,
}

impl Service {
    /// Start the service. `dicts` maps (dataset, solver, nfe) to trained
    /// PAS dictionaries for requests with `use_pas`.
    pub fn start(cfg: ServiceConfig, dicts: Vec<CoordinateDict>) -> Service {
        let (tx, rx) = sync_channel::<Pending>(cfg.queue_depth);
        let metrics = Arc::new(Metrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        // Work queue between batcher and workers.
        let (wtx, wrx) = sync_channel::<Vec<Pending>>(cfg.queue_depth);
        let wrx = Arc::new(Mutex::new(wrx));
        let mut threads = Vec::new();

        // Batcher thread.
        {
            let cfg = cfg.clone();
            let metrics = metrics.clone();
            let stop = stop.clone();
            threads.push(std::thread::spawn(move || {
                batcher_loop(rx, wtx, cfg, metrics, stop);
            }));
        }
        // Worker threads.
        let dicts = Arc::new(RwLock::new(index_dicts(dicts)));
        for w in 0..cfg.workers {
            let wrx = wrx.clone();
            let metrics = metrics.clone();
            let dicts = dicts.clone();
            let stop = stop.clone();
            threads.push(std::thread::spawn(move || {
                worker_loop(w, wrx, metrics, dicts, stop);
            }));
        }
        Service {
            tx,
            next_id: AtomicU64::new(1),
            metrics,
            stop,
            threads,
            dicts,
            trainer: Mutex::new(TrainSession::new(TrainConfig::default())),
        }
    }

    /// Train (or retrain) a PAS dictionary for `(dataset, solver, nfe)`
    /// **online** and register it for `use_pas` requests. Runs on the
    /// caller's thread against the service's persistent
    /// [`TrainSession`] — serving workers keep draining batches (they
    /// only take read-lock snapshots of the dict registry). Concurrent
    /// `train_pas` calls serialize on the session mutex.
    pub fn train_pas(
        &self,
        dataset: &str,
        solver_name: &str,
        nfe: usize,
        overrides: Option<TrainConfig>,
    ) -> Result<PasTrainStats, String> {
        let ds = crate::data::registry::get(dataset)
            .ok_or_else(|| format!("unknown dataset {dataset}"))?;
        let solver: Box<dyn Solver> = crate::solvers::registry::get(solver_name)
            .ok_or_else(|| format!("unknown solver {solver_name}"))?;
        let steps = solver
            .steps_for_nfe(nfe)
            .ok_or_else(|| format!("{solver_name} cannot hit NFE={nfe}"))?;
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(steps);
        let tr = {
            let mut session = self.trainer.lock().unwrap();
            // Overrides apply to this call only: a `None` call always
            // trains with the service default config, never a previous
            // caller's leftover overrides.
            session.cfg = overrides.unwrap_or_default();
            session.train(solver.as_ref(), model.as_ref(), &sched, ds.name(), false, None)?
        };
        let stats = PasTrainStats {
            n_params: tr.dict.n_params(),
            corrected_steps: tr.trace.corrected_steps(),
            train_seconds: tr.train_seconds,
            final_error_uncorrected: tr.curve_uncorrected.last().copied().unwrap_or(0.0),
            final_error_corrected: tr.curve_corrected.last().copied().unwrap_or(0.0),
        };
        self.dicts
            .write()
            .unwrap()
            .insert((dataset.to_string(), solver_name.to_string(), nfe), tr.dict);
        self.metrics.dicts_trained.fetch_add(1, Ordering::Relaxed);
        Ok(stats)
    }

    /// Submit a request; returns a receiver for the response, or an error
    /// when the queue is full (backpressure surfaced to the caller).
    pub fn submit(
        &self,
        mut req: SamplingRequest,
    ) -> Result<Receiver<SamplingResponse>, String> {
        if req.id == 0 {
            req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        let (rtx, rrx) = sync_channel(1);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send(Pending {
            req,
            enqueued: Instant::now(),
            reply: rtx,
        }) {
            Ok(()) => Ok(rrx),
            Err(TrySendError::Full(_)) => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err("queue full (backpressure)".into())
            }
            Err(TrySendError::Disconnected(_)) => Err("service stopped".into()),
        }
    }

    /// Convenience: submit and block for the response.
    pub fn call(&self, req: SamplingRequest) -> Result<SamplingResponse, String> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| "worker dropped".to_string())
    }

    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        drop(self.tx);
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

fn index_dicts(dicts: Vec<CoordinateDict>) -> DictMap {
    dicts
        .into_iter()
        .map(|d| ((d.dataset.clone(), d.solver.clone(), d.nfe), d))
        .collect()
}

fn batcher_loop(
    rx: Receiver<Pending>,
    wtx: SyncSender<Vec<Pending>>,
    cfg: ServiceConfig,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    let mut held: Vec<Pending> = Vec::new();
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // Block for the first request (or shutdown).
        let first = if let Some(p) = held.pop() {
            p
        } else {
            match rx.recv() {
                Ok(p) => p,
                Err(_) => break,
            }
        };
        let key = BatchKey {
            dataset: first.req.dataset.clone(),
            solver: first.req.solver.clone(),
            nfe: first.req.nfe,
            use_pas: first.req.use_pas,
        };
        let mut batch = vec![first];
        let mut total: usize = batch[0].req.n_samples;
        let deadline = Instant::now() + cfg.batch_window;
        // Gather compatible requests within the window / size budget.
        while total < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(p) => {
                    let pk = BatchKey {
                        dataset: p.req.dataset.clone(),
                        solver: p.req.solver.clone(),
                        nfe: p.req.nfe,
                        use_pas: p.req.use_pas,
                    };
                    if pk == key && total + p.req.n_samples <= cfg.max_batch {
                        total += p.req.n_samples;
                        batch.push(p);
                    } else {
                        held.push(p); // incompatible: lead the next batch
                        break;
                    }
                }
                Err(_) => break, // window elapsed or channel closed
            }
        }
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics
            .fused_requests
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        if wtx.send(batch).is_err() {
            break;
        }
    }
}

fn worker_loop(
    _id: usize,
    wrx: Arc<Mutex<Receiver<Vec<Pending>>>>,
    metrics: Arc<Metrics>,
    dicts: Arc<RwLock<DictMap>>,
    stop: Arc<AtomicBool>,
) {
    // One long-lived engine per worker: the serving path never records
    // trajectories (`Record::None`), and the workspace is reused across
    // batches, so steady-state sampling performs no per-step allocation.
    let mut engine = SamplerEngine::with_record(Record::None);
    loop {
        let batch = {
            let guard = wrx.lock().unwrap();
            match guard.recv_timeout(Duration::from_millis(50)) {
                Ok(b) => b,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    if stop.load(Ordering::Relaxed) {
                        return;
                    }
                    continue;
                }
                Err(_) => return,
            }
        };
        run_batch(batch, &metrics, &dicts, &mut engine);
    }
}

fn fail_all(batch: Vec<Pending>, msg: &str) {
    for p in batch {
        let _ = p.reply.send(SamplingResponse {
            id: p.req.id,
            samples: Vec::new(),
            n: 0,
            dim: 0,
            nfe_spent: 0,
            batched_with: 0,
            latency_ms: 0.0,
            error: Some(msg.to_string()),
        });
    }
}

fn run_batch(
    batch: Vec<Pending>,
    metrics: &Metrics,
    dicts: &RwLock<DictMap>,
    engine: &mut SamplerEngine,
) {
    let req0 = &batch[0].req;
    let ds = match crate::data::registry::get(&req0.dataset) {
        Some(d) => d,
        None => return fail_all(batch, "unknown dataset"),
    };
    let solver: Box<dyn Solver> = match crate::solvers::registry::get(&req0.solver) {
        Some(s) => s,
        None => return fail_all(batch, "unknown solver"),
    };
    let steps = match solver.steps_for_nfe(req0.nfe) {
        Some(s) => s,
        None => return fail_all(batch, "NFE not representable for this solver"),
    };
    let model = AnalyticEps::from_dataset(&ds);
    let sched = default_schedule(steps);
    let dim = model.dim();
    // Fuse priors: each request gets its own seeded stream.
    let n_total: usize = batch.iter().map(|p| p.req.n_samples).sum();
    let mut x_t = Vec::with_capacity(n_total * dim);
    for p in &batch {
        let mut rng = Pcg64::seed_stream(p.req.seed, p.req.id);
        x_t.extend(sample_prior(&mut rng, p.req.n_samples, dim, sched.t_max()));
    }
    // Snapshot the dict under a short read lock so an online `train_pas`
    // never blocks on (or is blocked by) an in-flight solver run.
    let dict = if req0.use_pas {
        dicts
            .read()
            .unwrap()
            .get(&(req0.dataset.clone(), req0.solver.clone(), req0.nfe))
            .cloned()
    } else {
        None
    };
    let mut x0 = vec![0.0; n_total * dim];
    let nfe = match &dict {
        Some(d) => {
            let mut hook = CorrectedSampler::new(d, dim);
            engine.run_into(
                solver.as_ref(),
                model.as_ref(),
                &x_t,
                n_total,
                &sched,
                Some(&mut hook),
                &mut x0,
            )
        }
        None => engine.run_into(
            solver.as_ref(),
            model.as_ref(),
            &x_t,
            n_total,
            &sched,
            None,
            &mut x0,
        ),
    };
    // Scatter results back.
    let fused = batch.len();
    let mut offset = 0usize;
    for p in batch {
        let n = p.req.n_samples;
        let samples = x0[offset * dim..(offset + n) * dim].to_vec();
        offset += n;
        metrics.completed.fetch_add(1, Ordering::Relaxed);
        let _ = p.reply.send(SamplingResponse {
            id: p.req.id,
            samples,
            n,
            dim,
            nfe_spent: nfe,
            batched_with: fused,
            latency_ms: p.enqueued.elapsed().as_secs_f64() * 1e3,
            error: None,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(n: usize, seed: u64) -> SamplingRequest {
        SamplingRequest {
            id: 0,
            dataset: "gmm2d".into(),
            solver: "ddim".into(),
            nfe: 6,
            n_samples: n,
            seed,
            use_pas: false,
        }
    }

    #[test]
    fn serves_a_request() {
        let svc = Service::start(ServiceConfig::default(), Vec::new());
        let resp = svc.call(req(16, 1)).unwrap();
        assert!(resp.error.is_none());
        assert_eq!(resp.n, 16);
        assert_eq!(resp.dim, 2);
        assert_eq!(resp.samples.len(), 32);
        svc.shutdown();
    }

    #[test]
    fn batches_concurrent_requests() {
        let svc = Service::start(
            ServiceConfig {
                batch_window: Duration::from_millis(30),
                ..ServiceConfig::default()
            },
            Vec::new(),
        );
        let rxs: Vec<_> = (0..6).map(|s| svc.submit(req(8, s)).unwrap()).collect();
        let resps: Vec<_> = rxs.into_iter().map(|r| r.recv().unwrap()).collect();
        assert!(resps.iter().all(|r| r.error.is_none()));
        // At least one response was fused with another request.
        assert!(
            resps.iter().any(|r| r.batched_with > 1),
            "batcher never fused: {:?}",
            resps.iter().map(|r| r.batched_with).collect::<Vec<_>>()
        );
        svc.shutdown();
    }

    #[test]
    fn different_seeds_give_different_samples() {
        let svc = Service::start(ServiceConfig::default(), Vec::new());
        let a = svc.call(req(4, 1)).unwrap();
        let b = svc.call(req(4, 2)).unwrap();
        assert_ne!(a.samples, b.samples);
        // Same seed + same id-independent stream? ids differ, so draws
        // differ by design; determinism is per (seed, id).
        svc.shutdown();
    }

    #[test]
    fn invalid_nfe_is_reported() {
        let svc = Service::start(ServiceConfig::default(), Vec::new());
        let mut r = req(4, 1);
        r.solver = "heun".into();
        r.nfe = 5; // odd: not representable
        let resp = svc.call(r).unwrap();
        assert!(resp.error.is_some());
        svc.shutdown();
    }

    /// Online training: an empty-dict service trains a correction while
    /// running, registers it, and subsequent `use_pas` requests pick it
    /// up (different samples than the uncorrected path, no errors).
    #[test]
    fn online_training_registers_dict_and_serves_it() {
        let svc = Service::start(ServiceConfig::default(), Vec::new());
        // use_pas before training: silently uncorrected (no dict yet).
        let mut pas_req = req(16, 9);
        pas_req.nfe = 8;
        pas_req.use_pas = true;
        let before = svc.call(pas_req.clone()).unwrap();
        assert!(before.error.is_none());

        let stats = svc
            .train_pas(
                "gmm2d",
                "ddim",
                8,
                Some(TrainConfig {
                    n_traj: 48,
                    epochs: 16,
                    minibatch: 16,
                    teacher_nfe: 60,
                    lr: 5e-2,
                    scale_mode: crate::pas::coords::ScaleMode::Relative,
                    ..TrainConfig::default()
                }),
            )
            .unwrap();
        assert!(stats.n_params > 0, "training must store parameters");
        assert!(
            stats.final_error_corrected < stats.final_error_uncorrected,
            "online training must reduce truncation error: {} -> {}",
            stats.final_error_uncorrected,
            stats.final_error_corrected
        );
        assert_eq!(svc.metrics.dicts_trained.load(Ordering::Relaxed), 1);

        let after = svc.call(pas_req).unwrap();
        assert!(after.error.is_none());
        assert_ne!(
            before.samples, after.samples,
            "registered dict must change the corrected samples"
        );
        // Unknown config still errors cleanly.
        assert!(svc.train_pas("nope", "ddim", 8, None).is_err());
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let svc = Service::start(
            ServiceConfig {
                workers: 1,
                queue_depth: 1,
                batch_window: Duration::from_millis(1),
                ..ServiceConfig::default()
            },
            Vec::new(),
        );
        // Flood; with depth 1 some submissions must be rejected.
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for s in 0..64 {
            match svc.submit(req(64, s)) {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
        }
        for rx in rxs {
            let _ = rx.recv();
        }
        assert!(rejected > 0, "expected at least one backpressure rejection");
        svc.shutdown();
    }
}
