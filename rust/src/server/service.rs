//! In-process sampling service: **step-level continuous batching** +
//! worker pool + backpressure + **online PAS training**. The TCP
//! front-end in [`super::protocol`] is a thin shim over this, and
//! examples/serve_batch.rs drives it directly.
//!
//! # Scheduling
//!
//! The default scheduler ([`Batching::Continuous`]) runs one **resident
//! engine run per compatibility key** (`dataset, solver, nfe, pas`) on a
//! [`SlotEngine`]: requests are admitted into free slots **at step
//! boundaries** while earlier requests are mid-flight, every row carries
//! its own step cursor into the shared schedule, and finished rows retire
//! — and their responses are sent — the moment their last step completes.
//! Tail latency under staggered arrivals is therefore bounded by *step*
//! duration, not whole-batch rollout duration (the vLLM-style property,
//! transplanted from token steps to solver steps).
//!
//! * **Admission policy.** Priority-then-FIFO per key: the router keeps
//!   each key's queue ordered by [`SamplingRequest::priority`]
//!   (descending; FIFO among equals), and a request is admitted when its
//!   rows fit under the `max_batch` residency cap (an oversized request
//!   is admitted alone when the engine is empty). Requests admitted at
//!   the same boundary form one *cohort* — rows in lockstep — and every
//!   cohort steps once per scheduler tick. A panicking resident run fails
//!   its queued requests and deactivates the key instead of stranding
//!   them ([`KeyGuard`]).
//! * **SLO admission (deadline shedding).** A request may carry
//!   [`SamplingRequest::deadline_ms`], a soft end-to-end latency budget
//!   measured from submit. Each admission phase first sheds queued
//!   requests whose deadline has already expired or whose remaining
//!   budget cannot cover `n_steps` ticks at the key's observed per-tick
//!   latency (an EWMA, [`TICK_EWMA_ALPHA`], warmed by the run's own
//!   non-idle ticks) — they fail fast with a structured `deadline` error
//!   carrying real `latency_ms` instead of rotting in the queue. Already
//!   admitted rows always run to completion, so shedding changes
//!   *scheduling only*, never numerics.
//! * **Weighted fair yielding.** A worker's tick budget on one key
//!   scales inversely with the dispatch backlog
//!   ([`BASE_TICK_BUDGET`]` / (1 + waiting keys)`, floored at one tick):
//!   an uncontended key keeps its worker indefinitely, while under
//!   contention hot keys rotate proportionally faster (residents drain
//!   first — their state lives in the worker's engine).
//! * **Determinism contract.** Each request's samples are bit-identical
//!   to running that request alone (same seed/id prior via
//!   [`sample_prior_stream`], same engine arithmetic), for every
//!   admission interleaving and thread count — rows are independent end
//!   to end, so continuous batching is an indexing change, not a numerics
//!   change. Enforced by this module's parity tests across randomized
//!   admission offsets × engine thread caps {1, 4, 16}.
//! * **Correction state.** `use_pas` cohorts snapshot the dictionary
//!   registry at admission into a per-cohort, owned
//!   [`CorrectedSampler`], whose per-row trajectory buffers live and die
//!   with the cohort's slots.
//!
//! The seed's collect-then-run batcher is retained behind
//! [`Batching::CollectThenRun`] as the latency baseline
//! (`benches/continuous_batching.rs` measures both under staggered
//! arrivals) and as a fallback.
//!
//! # Online training
//!
//! Dictionaries are held behind an `RwLock` so [`Service::train_pas`] can
//! train (or retrain) a `(dataset, solver, nfe)` correction **while
//! serving traffic** — schedulers take a cheap read-lock snapshot per
//! cohort (a dict is ≤ ~40 f64s) and are never blocked by an in-flight
//! training run, which executes on the caller's thread against the
//! service's persistent, workspace-pooled [`TrainSession`].

use super::metrics_export::{self, KeySnapshot, PoolInfo, ServeHistograms};
use crate::artifact::{ArtifactKey, ArtifactStore};
use crate::pas::coords::CoordinateDict;
use crate::pas::correct::CorrectedSampler;
use crate::pas::train::{TrainConfig, TrainSession};
use crate::util::json::Json;
use crate::schedule::{default_schedule, Schedule};
use crate::score::analytic::AnalyticEps;
use crate::score::EpsModel;
use crate::solvers::engine::{Record, SamplerEngine, SlotEngine};
use crate::solvers::{DirectionHook, Solver};
use crate::traj::sample_prior_stream;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Shared dictionary registry: `(dataset, solver, nfe) -> dict`.
type DictMap = HashMap<(String, String, usize), CoordinateDict>;

/// One client request.
#[derive(Clone, Debug)]
pub struct SamplingRequest {
    pub id: u64,
    pub dataset: String,
    pub solver: String,
    pub nfe: usize,
    pub n_samples: usize,
    pub seed: u64,
    /// Apply a pre-trained PAS dictionary if the service has one registered
    /// for (dataset, solver, nfe).
    pub use_pas: bool,
    /// Soft end-to-end latency budget in milliseconds, measured from
    /// submit. `None` = no deadline. The continuous scheduler sheds a
    /// queued request (structured `deadline` error) once the deadline has
    /// expired or the remaining budget cannot cover the key's projected
    /// run time; a request already admitted always runs to completion.
    pub deadline_ms: Option<f64>,
    /// Scheduling priority within a compatibility key: higher admits
    /// first, FIFO among equals. `0` is the default; the wire protocol
    /// accepts [`super::protocol::MIN_PRIORITY`] ..=
    /// [`super::protocol::MAX_PRIORITY`]. Priority affects *ordering
    /// only* — results stay bit-identical to the solo run.
    pub priority: i32,
}

/// Service reply.
#[derive(Clone, Debug)]
pub struct SamplingResponse {
    pub id: u64,
    pub samples: Vec<f64>,
    pub n: usize,
    pub dim: usize,
    pub nfe_spent: usize,
    /// Peak number of requests co-resident with this one (continuous
    /// scheduler) / fused into its batch (collect-then-run).
    pub batched_with: usize,
    /// End-to-end latency (submit → response).
    pub latency_ms: f64,
    /// Time spent queued before the scheduler admitted the request.
    pub queue_ms: f64,
    /// Time from admission to the final solver step.
    pub run_ms: f64,
    pub error: Option<String>,
}

/// How the service groups requests into solver work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Batching {
    /// Step-level continuous batching (default): per-key resident engine
    /// runs; admission/retirement at step boundaries.
    Continuous,
    /// The seed's collect-then-run batcher: gather compatible requests
    /// for `batch_window`, run the fused batch to completion.
    CollectThenRun,
}

#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub workers: usize,
    /// Residency cap: max trajectories resident in one engine run
    /// (continuous) / fused into one solver run (collect-then-run).
    pub max_batch: usize,
    /// How long the collect-then-run batcher waits to fill a batch
    /// (unused by the continuous scheduler, which admits at step
    /// boundaries instead of on a timer).
    pub batch_window: Duration,
    /// Bounded queue depth (backpressure: submit rejects beyond this).
    pub queue_depth: usize,
    pub batching: Batching,
    /// Row-shard cap for the engines (`0` = pool size). Results are
    /// bit-identical for every value; tests pin {1, 4, 16}.
    pub engine_threads: usize,
    /// Directory of the durable dict artifact store ([`crate::artifact`]).
    /// `Some`: dictionaries are loaded (checksum-verified, healed) at
    /// startup and every `train_pas`/`publish_dict` result is persisted
    /// as a new version. `None`: the registry is purely in-memory (the
    /// pre-store behavior).
    pub artifact_root: Option<std::path::PathBuf>,
    /// Upper bound on how long [`Service::shutdown`]'s drain phase lets
    /// resident cohorts run to retirement. Residents still in flight when
    /// it expires fail with a structured `draining` error instead of
    /// holding shutdown hostage.
    pub drain_deadline: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            workers: 4,
            max_batch: 256,
            batch_window: Duration::from_millis(2),
            queue_depth: 256,
            batching: Batching::Continuous,
            engine_threads: 0,
            artifact_root: None,
            drain_deadline: Duration::from_secs(5),
        }
    }
}

struct Pending {
    req: SamplingRequest,
    enqueued: Instant,
    reply: SyncSender<SamplingResponse>,
}

/// Batch key: requests sharing it can run in one resident engine run.
#[derive(Clone, PartialEq, Eq, Hash, Debug)]
struct BatchKey {
    dataset: String,
    solver: String,
    nfe: usize,
    use_pas: bool,
}

impl BatchKey {
    fn of(req: &SamplingRequest) -> BatchKey {
        BatchKey {
            dataset: req.dataset.clone(),
            solver: req.solver.clone(),
            nfe: req.nfe,
            use_pas: req.use_pas,
        }
    }
}

/// Service metrics (exposed via `stats`).
#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub rejected: AtomicU64,
    /// Requests answered with a structured error (invalid key, scheduler
    /// abort, deadline shed, ...). With `rejected` and `completed`, makes
    /// `requests == completed + rejected + failed + in-flight` hold.
    pub failed: AtomicU64,
    /// Requests shed because their deadline was infeasible (a subset of
    /// `failed`).
    pub shed: AtomicU64,
    /// Cohorts formed (continuous) / batches fused (collect-then-run).
    pub batches: AtomicU64,
    pub fused_requests: AtomicU64,
    /// Requests admitted into a resident run that already had earlier
    /// cohorts mid-flight — the continuous scheduler's reason to exist.
    pub admitted_mid_flight: AtomicU64,
    /// Scheduler ticks (one solver step for every resident cohort).
    pub ticks: AtomicU64,
    /// Dictionaries trained online via [`Service::train_pas`].
    pub dicts_trained: AtomicU64,
    /// Dictionaries loaded (checksum-verified) from the artifact store at
    /// startup.
    pub artifacts_loaded: AtomicU64,
    /// New dict versions persisted to the artifact store (deduplicated
    /// republishes of identical content are not counted).
    pub dicts_published: AtomicU64,
    /// Successful [`Service::rollback`] operations.
    pub rollbacks: AtomicU64,
    /// Requests failed with a structured `numeric` error: the engine's
    /// per-tick guardrail detected a non-finite direction or state in the
    /// request's rows (a subset of `failed`).
    pub numeric_failures: AtomicU64,
    /// Keys currently degraded to uncorrected sampling by the numeric
    /// circuit breaker (a gauge, not a counter: `rollback`/republish
    /// close the breaker and decrement it).
    pub breaker_open: AtomicU64,
    /// Fixed-bucket latency histograms (`queue_ms`/`run_ms`/`latency_ms`)
    /// recorded once per answered request; see
    /// [`super::metrics_export`]. Atomic bucket counters: recording on
    /// the hot retire path is lock-free and allocation-free.
    pub serve_hist: ServeHistograms,
}

/// Structured error text for requests refused or abandoned because the
/// service is shutting down. Clients can match on the `draining:` prefix.
const DRAINING_ERR: &str = "draining: service is shutting down";

/// Structured error text for queued requests shed by deadline admission.
/// Static (no per-request formatting) so the shed path stays
/// allocation-free; clients match on the `deadline:` prefix.
const SHED_ERR: &str = "deadline: budget infeasible for this key's load";

/// Consecutive corrected-path numeric failures on one key before its
/// breaker opens and the key degrades to uncorrected sampling.
const BREAKER_THRESHOLD: u32 = 3;

#[derive(Default)]
struct BreakerState {
    consecutive_fails: u32,
    open: bool,
}

/// Per-`(dataset, solver, nfe)` circuit breaker for corrected-path
/// numeric failures. A dictionary whose corrections repeatedly blow up
/// the solver (non-finite rows caught by the engine guardrail) is almost
/// certainly bad data, not bad luck: after [`BREAKER_THRESHOLD`]
/// consecutive failures the breaker opens, the key degrades to
/// *uncorrected* sampling (still serving, still deterministic), the dict
/// is unregistered, and its blob is quarantined through the artifact
/// store so a restart cannot reload it. [`Service::rollback`] or
/// republishing a dict closes the breaker.
struct NumericBreaker {
    states: Mutex<HashMap<(String, String, usize), BreakerState>>,
}

impl NumericBreaker {
    fn new() -> NumericBreaker {
        NumericBreaker {
            states: Mutex::new(HashMap::new()),
        }
    }

    fn dict_key(key: &BatchKey) -> (String, String, usize) {
        (key.dataset.clone(), key.solver.clone(), key.nfe)
    }

    /// True when the key is degraded to uncorrected sampling.
    fn is_open(&self, key: &BatchKey) -> bool {
        self.states
            .lock()
            .unwrap()
            .get(&Self::dict_key(key))
            .is_some_and(|s| s.open)
    }

    /// Record a corrected-path numeric failure. Returns `true` exactly
    /// when this failure opened the breaker (the caller then quarantines
    /// the dict).
    fn record_failure(&self, key: &BatchKey, metrics: &Metrics) -> bool {
        let mut m = self.states.lock().unwrap();
        let st = m.entry(Self::dict_key(key)).or_default();
        if st.open {
            return false;
        }
        st.consecutive_fails += 1;
        if st.consecutive_fails >= BREAKER_THRESHOLD {
            st.open = true;
            metrics.breaker_open.fetch_add(1, Ordering::Relaxed);
            true
        } else {
            false
        }
    }

    /// A clean corrected retire resets the consecutive-failure count.
    fn record_success(&self, key: &BatchKey) {
        let mut m = self.states.lock().unwrap();
        if let Some(st) = m.get_mut(&Self::dict_key(key)) {
            if !st.open {
                st.consecutive_fails = 0;
            }
        }
    }

    /// Close the breaker for a key — a rollback or republish deployed a
    /// (presumed good) dict, so corrected serving resumes.
    fn reset(&self, dataset: &str, solver: &str, nfe: usize, metrics: &Metrics) {
        let mut m = self.states.lock().unwrap();
        if let Some(st) = m.remove(&(dataset.to_string(), solver.to_string(), nfe)) {
            if st.open {
                metrics.breaker_open.fetch_sub(1, Ordering::Relaxed);
                crate::info!("numeric breaker closed for {dataset}/{solver}/{nfe}");
            }
        }
    }
}

/// Everything a continuous worker thread shares with the service: one
/// `Arc<WorkerShared>` per service instead of eight loose `Arc` clones
/// per worker.
struct WorkerShared {
    metrics: Arc<Metrics>,
    dicts: Arc<RwLock<DictMap>>,
    stop: Arc<AtomicBool>,
    breaker: Arc<NumericBreaker>,
    store: Option<Arc<Mutex<ArtifactStore>>>,
    backlog: Arc<AtomicUsize>,
    engine_threads: usize,
    max_rows: usize,
    drain_deadline: Duration,
}

/// Summary of one online [`Service::train_pas`] run.
#[derive(Clone, Debug)]
pub struct PasTrainStats {
    pub n_params: usize,
    pub corrected_steps: Vec<usize>,
    pub train_seconds: f64,
    /// Final-node truncation error of the uncorrected / corrected
    /// training rollout (the Figure-3 endpoints).
    pub final_error_uncorrected: f64,
    pub final_error_corrected: f64,
    /// Artifact-store version the trained dict was published as (`None`
    /// when the service runs without a store, or persistence failed —
    /// serving proceeds either way).
    pub published_version: Option<u64>,
}

/// Per-key request queue; `active` is true while some worker owns the
/// key's resident run. The queue is kept priority-ordered (descending,
/// FIFO among equals) by [`Router::route`].
struct KeyState {
    queue: VecDeque<Pending>,
    active: bool,
}

/// Lock-free per-key observability counters, updated by the key's owning
/// worker and read by the metrics/health renderers without taking the
/// key's state lock.
#[derive(Default)]
struct KeyStats {
    /// Requests completed (retired with samples) on this key.
    retired: AtomicU64,
    /// Requests shed for deadline infeasibility on this key.
    shed: AtomicU64,
    /// Rows currently resident in the key's engine run.
    resident_rows: AtomicUsize,
}

/// Router-table entry: the lockable scheduling state plus the lock-free
/// stats sidecar.
struct KeyEntry {
    state: Mutex<KeyState>,
    stats: KeyStats,
}

type KeyHandle = (BatchKey, Arc<KeyEntry>);

/// Key-table size that triggers an opportunistic sweep of idle entries
/// (inactive, empty queue) on the next new-key insertion.
const KEY_TABLE_GC_LEN: usize = 1024;

/// Continuous front-end: routes submissions into per-key queues and
/// activates a worker per key with queued work. The activation channel is
/// unbounded so `submit` never blocks: it carries at most one handle per
/// key with queued work (backpressure lives in the bounded per-key
/// queues, not here). `backlog` counts handles waiting in that channel —
/// workers consult it to decide whether yielding a hot key would actually
/// help anyone.
struct Router {
    table: Mutex<HashMap<BatchKey, Arc<KeyEntry>>>,
    ktx: Sender<KeyHandle>,
    queue_depth: usize,
    backlog: Arc<AtomicUsize>,
    /// Shutdown flag (shared with the service): consulted after queueing
    /// so a submission racing `shutdown` cannot strand without a reply.
    stop: Arc<AtomicBool>,
}

impl Router {
    fn route(&self, p: Pending, metrics: &Metrics) -> Result<(), String> {
        let key = BatchKey::of(&p.req);
        let entry = {
            let mut table = self.table.lock().unwrap();
            // Bound the table to live keys: sweep idle entries when a new
            // key would grow an already-large table. Only entries whose
            // Arc we hold the *sole* reference to are candidates — a
            // concurrent `route` that already cloned the Arc (but has not
            // locked it yet) keeps the count above 1, and no new clone
            // can appear while we hold the table lock, so a swept entry
            // can never be resurrected into a duplicate resident run.
            if table.len() >= KEY_TABLE_GC_LEN && !table.contains_key(&key) {
                table.retain(|_, e| {
                    if Arc::strong_count(e) > 1 {
                        return true;
                    }
                    match e.state.try_lock() {
                        Ok(st) => st.active || !st.queue.is_empty(),
                        Err(_) => true,
                    }
                });
            }
            table
                .entry(key.clone())
                .or_insert_with(|| {
                    Arc::new(KeyEntry {
                        state: Mutex::new(KeyState {
                            queue: VecDeque::new(),
                            active: false,
                        }),
                        stats: KeyStats::default(),
                    })
                })
                .clone()
        };
        let activate = {
            let mut st = entry.state.lock().unwrap();
            if st.queue.len() >= self.queue_depth {
                metrics.rejected.fetch_add(1, Ordering::Relaxed);
                return Err("queue full (backpressure)".into());
            }
            // Priority-then-FIFO: insert after the last queued request of
            // equal-or-higher priority, so higher priorities admit first
            // and equal priorities keep arrival order.
            let pos = st
                .queue
                .iter()
                .rposition(|q| q.req.priority >= p.req.priority)
                .map_or(0, |i| i + 1);
            st.queue.insert(pos, p);
            if st.active {
                false
            } else {
                st.active = true;
                true
            }
        };
        // Sent outside the key lock; a worker picking the key up
        // immediately can only find the request we just queued.
        if activate {
            self.backlog.fetch_add(1, Ordering::Relaxed);
            if self.ktx.send((key, entry.clone())).is_err() {
                return Err("service stopped".into());
            }
        }
        // Close the submit/shutdown race: if the stop flag went up while
        // we were queueing, the drain (workers, then the final sweep in
        // `Service::shutdown`) may already have passed this key — fail
        // anything still queued here so the caller's request cannot
        // strand without a reply.
        if self.stop.load(Ordering::Relaxed) {
            let drained: Vec<Pending> = {
                let mut st = entry.state.lock().unwrap_or_else(|p| p.into_inner());
                st.queue.drain(..).collect()
            };
            if !drained.is_empty() {
                fail_all(drained, DRAINING_ERR, metrics);
                return Err(DRAINING_ERR.into());
            }
        }
        Ok(())
    }
}

enum Front {
    Collect { tx: SyncSender<Pending> },
    Continuous { router: Arc<Router> },
}

pub struct Service {
    /// The request front-end. Taken (and its channel senders dropped) by
    /// [`Service::shutdown`] phase 1; `None` thereafter.
    front: Mutex<Option<Front>>,
    /// Continuous-mode router handle, retained outside `front` so the
    /// observability surface and shutdown's final straggler sweep survive
    /// the front teardown. `None` in collect-then-run mode.
    router: Option<Arc<Router>>,
    next_id: AtomicU64,
    /// Startup configuration, retained for the observability surface
    /// (pool gauges in [`Service::metrics_text`]).
    cfg: ServiceConfig,
    started: Instant,
    pub metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    threads: Mutex<Vec<JoinHandle<()>>>,
    dicts: Arc<RwLock<DictMap>>,
    /// Numeric circuit breaker shared with the continuous workers.
    breaker: Arc<NumericBreaker>,
    /// Persistent training session for [`Service::train_pas`]: its
    /// workspaces (engine, node stores, basis store, SGD scratch) are
    /// reused across online training runs.
    trainer: Mutex<TrainSession>,
    /// Durable dict store ([`crate::artifact`]); `None` when the service
    /// runs in-memory only. The mutex serializes the write path (publish,
    /// rollback, breaker quarantine) per the store's single-writer
    /// expectation; the `Arc` shares the handle with the workers.
    store: Option<Arc<Mutex<ArtifactStore>>>,
}

impl Service {
    /// Start the service. `dicts` maps (dataset, solver, nfe) to trained
    /// PAS dictionaries for requests with `use_pas`.
    ///
    /// With [`ServiceConfig::artifact_root`] set, the artifact store is
    /// opened first and every stored dict is loaded (checksum-verified;
    /// corrupt versions are quarantined and healed around; a torn
    /// manifest recovers from the previous generation; a missing/empty
    /// store is a clean cold start). Caller-supplied `dicts` override
    /// stored ones on key collision. A store that cannot even be opened
    /// disables persistence with a warning rather than failing startup.
    pub fn start(cfg: ServiceConfig, dicts: Vec<CoordinateDict>) -> Service {
        let metrics = Arc::new(Metrics::default());
        let stop = Arc::new(AtomicBool::new(false));
        let mut initial = DictMap::new();
        let store = match &cfg.artifact_root {
            Some(root) => match ArtifactStore::open(root) {
                Ok(mut s) => {
                    let report = crate::artifact::load_all(&mut s);
                    for l in report.loaded {
                        metrics.artifacts_loaded.fetch_add(1, Ordering::Relaxed);
                        crate::info!(
                            "loaded artifact {} v{}{}",
                            l.key.id(),
                            l.version,
                            if l.healed { " (healed)" } else { "" }
                        );
                        initial.insert((l.key.dataset, l.key.solver, l.key.nfe), l.dict);
                    }
                    for (key, why) in &report.failed {
                        crate::warn_!("artifact {} unusable, serving uncorrected: {why}", key.id());
                    }
                    Some(Arc::new(Mutex::new(s)))
                }
                Err(e) => {
                    crate::warn_!("artifact store disabled: {e}");
                    None
                }
            },
            None => None,
        };
        initial.extend(index_dicts(dicts));
        let dicts = Arc::new(RwLock::new(initial));
        let breaker = Arc::new(NumericBreaker::new());
        let mut threads = Vec::new();
        let mut router_handle: Option<Arc<Router>> = None;
        let front = match cfg.batching {
            Batching::CollectThenRun => {
                let (tx, rx) = sync_channel::<Pending>(cfg.queue_depth);
                // Work queue between batcher and workers.
                let (wtx, wrx) = sync_channel::<Vec<Pending>>(cfg.queue_depth);
                let wrx = Arc::new(Mutex::new(wrx));
                {
                    let cfg = cfg.clone();
                    let metrics = metrics.clone();
                    let stop = stop.clone();
                    threads.push(std::thread::spawn(move || {
                        batcher_loop(rx, wtx, cfg, metrics, stop);
                    }));
                }
                for _ in 0..cfg.workers {
                    let wrx = wrx.clone();
                    let metrics = metrics.clone();
                    let dicts = dicts.clone();
                    let engine_threads = cfg.engine_threads;
                    threads.push(std::thread::spawn(move || {
                        collect_worker_loop(wrx, metrics, dicts, engine_threads);
                    }));
                }
                Front::Collect { tx }
            }
            Batching::Continuous => {
                let (ktx, krx) = channel::<KeyHandle>();
                let krx = Arc::new(Mutex::new(krx));
                let backlog = Arc::new(AtomicUsize::new(0));
                let router = Arc::new(Router {
                    table: Mutex::new(HashMap::new()),
                    ktx: ktx.clone(),
                    queue_depth: cfg.queue_depth,
                    backlog: backlog.clone(),
                    stop: stop.clone(),
                });
                router_handle = Some(router.clone());
                let shared = Arc::new(WorkerShared {
                    metrics: metrics.clone(),
                    dicts: dicts.clone(),
                    stop: stop.clone(),
                    breaker: breaker.clone(),
                    store: store.clone(),
                    backlog,
                    engine_threads: cfg.engine_threads,
                    max_rows: cfg.max_batch,
                    drain_deadline: cfg.drain_deadline,
                });
                for _ in 0..cfg.workers {
                    let krx = krx.clone();
                    // Workers keep a sender too, to hand a key back after
                    // a fairness yield (see `run_key`).
                    let ktx = ktx.clone();
                    let shared = shared.clone();
                    threads.push(std::thread::spawn(move || {
                        continuous_worker_loop(krx, ktx, shared);
                    }));
                }
                Front::Continuous { router }
            }
        };
        Service {
            front: Mutex::new(Some(front)),
            router: router_handle,
            next_id: AtomicU64::new(1),
            cfg,
            started: Instant::now(),
            metrics,
            stop,
            threads: Mutex::new(threads),
            dicts,
            breaker,
            trainer: Mutex::new(TrainSession::new(TrainConfig::default())),
            store,
        }
    }

    /// Train (or retrain) a PAS dictionary for `(dataset, solver, nfe)`
    /// **online** and register it for `use_pas` requests. Runs on the
    /// caller's thread against the service's persistent
    /// [`TrainSession`] — serving workers keep draining work (they only
    /// take read-lock snapshots of the dict registry). Concurrent
    /// `train_pas` calls serialize on the session mutex.
    pub fn train_pas(
        &self,
        dataset: &str,
        solver_name: &str,
        nfe: usize,
        overrides: Option<TrainConfig>,
    ) -> Result<PasTrainStats, String> {
        let ds = crate::data::registry::get(dataset)
            .ok_or_else(|| format!("unknown dataset {dataset}"))?;
        let solver: Box<dyn Solver> = crate::solvers::registry::get(solver_name)
            .ok_or_else(|| format!("unknown solver {solver_name}"))?;
        let steps = solver
            .steps_for_nfe(nfe)
            .ok_or_else(|| format!("{solver_name} cannot hit NFE={nfe}"))?;
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(steps);
        let tr = {
            let mut session = self.trainer.lock().unwrap();
            // Overrides apply to this call only: a `None` call always
            // trains with the service default config, never a previous
            // caller's leftover overrides.
            session.cfg = overrides.unwrap_or_default();
            session.train(solver.as_ref(), model.as_ref(), &sched, ds.name(), false, None)?
        };
        let mut stats = PasTrainStats {
            n_params: tr.dict.n_params(),
            corrected_steps: tr.trace.corrected_steps(),
            train_seconds: tr.train_seconds,
            final_error_uncorrected: tr.curve_uncorrected.last().copied().unwrap_or(0.0),
            final_error_corrected: tr.curve_corrected.last().copied().unwrap_or(0.0),
            published_version: None,
        };
        self.dicts
            .write()
            .unwrap()
            .insert(
                (dataset.to_string(), solver_name.to_string(), nfe),
                tr.dict.clone(),
            );
        self.metrics.dicts_trained.fetch_add(1, Ordering::Relaxed);
        // A freshly trained dict supersedes whatever tripped the numeric
        // breaker: corrected serving resumes.
        self.breaker.reset(dataset, solver_name, nfe, &self.metrics);
        // Persist after registration: serving gains the dict even if the
        // disk publish fails (persistence failure costs durability, never
        // availability — it is warned, not propagated).
        stats.published_version = self.persist(dataset, solver_name, nfe, &tr.dict);
        Ok(stats)
    }

    /// Publish `dict` to the artifact store as a new version of
    /// `(dataset, solver, nfe)`, if a store is configured. Returns the
    /// published version; logs and returns `None` on persistence failure.
    fn persist(&self, dataset: &str, solver: &str, nfe: usize, dict: &CoordinateDict) -> Option<u64> {
        let store = self.store.as_ref()?;
        let key = ArtifactKey::new(dataset, solver, nfe);
        match store.lock().unwrap().publish(&key, dict) {
            Ok(out) => {
                if !out.deduplicated {
                    self.metrics.dicts_published.fetch_add(1, Ordering::Relaxed);
                }
                Some(out.version)
            }
            Err(e) => {
                crate::warn_!("publish {} failed (dict stays registered in-memory): {e}", key.id());
                None
            }
        }
    }

    /// Register `dict` for `(dataset, solver, nfe)` and persist it as a
    /// new artifact version. In-flight cohorts keep their admission-time
    /// snapshot; cohorts admitted after this call use `dict`. Returns the
    /// published version (`None` without a store). Unlike the passive
    /// persistence in [`Service::train_pas`], a configured store that
    /// fails to publish here is an error — this is the explicit
    /// operator/deploy path.
    pub fn publish_dict(
        &self,
        dataset: &str,
        solver: &str,
        nfe: usize,
        dict: CoordinateDict,
    ) -> Result<Option<u64>, String> {
        self.dicts
            .write()
            .unwrap()
            .insert((dataset.to_string(), solver.to_string(), nfe), dict.clone());
        // An explicit publish closes any open numeric breaker for the key.
        self.breaker.reset(dataset, solver, nfe, &self.metrics);
        let Some(store) = self.store.as_ref() else {
            return Ok(None);
        };
        let key = ArtifactKey::new(dataset, solver, nfe);
        let out = store.lock().unwrap().publish(&key, &dict)?;
        if !out.deduplicated {
            self.metrics.dicts_published.fetch_add(1, Ordering::Relaxed);
        }
        Ok(Some(out.version))
    }

    /// Roll `(dataset, solver, nfe)` back to its previous stored version:
    /// the store drops the current record, the rolled-back dict is
    /// re-verified on load and swapped into the registry (new admissions
    /// pick it up; in-flight cohorts finish on their snapshots). Returns
    /// the now-current version.
    pub fn rollback(&self, dataset: &str, solver: &str, nfe: usize) -> Result<u64, String> {
        let store = self
            .store
            .as_ref()
            .ok_or("no artifact store configured")?;
        let key = ArtifactKey::new(dataset, solver, nfe);
        let loaded = {
            let mut s = store.lock().unwrap();
            let rec = s.rollback(&key)?;
            crate::artifact::load_dict(&mut s, &key)
                .ok_or_else(|| format!("rolled {} back to v{} but it does not load", key.id(), rec.version))?
        };
        let version = loaded.version;
        self.dicts
            .write()
            .unwrap()
            .insert((dataset.to_string(), solver.to_string(), nfe), loaded.dict);
        // Rolling back to a known-good version closes any open numeric
        // breaker: corrected serving resumes on the restored dict.
        self.breaker.reset(dataset, solver, nfe, &self.metrics);
        self.metrics.rollbacks.fetch_add(1, Ordering::Relaxed);
        crate::info!("rolled {} back to v{version}", key.id());
        Ok(version)
    }

    /// Clone of the currently registered dict for a key (what the next
    /// admitted cohort would snapshot), if any.
    pub fn dict_snapshot(&self, dataset: &str, solver: &str, nfe: usize) -> Option<CoordinateDict> {
        self.dicts
            .read()
            .unwrap()
            .get(&(dataset.to_string(), solver.to_string(), nfe))
            .cloned()
    }

    /// Operational status: every metrics counter plus registry/store
    /// facts, as the JSON object the wire protocol's `status` command
    /// returns.
    pub fn status_json(&self) -> Json {
        let m = &self.metrics;
        let mut o = Json::obj();
        o.set("requests", Json::UInt(m.requests.load(Ordering::Relaxed)))
            .set("completed", Json::UInt(m.completed.load(Ordering::Relaxed)))
            .set("rejected", Json::UInt(m.rejected.load(Ordering::Relaxed)))
            .set("failed", Json::UInt(m.failed.load(Ordering::Relaxed)))
            .set("shed", Json::UInt(m.shed.load(Ordering::Relaxed)))
            .set("batches", Json::UInt(m.batches.load(Ordering::Relaxed)))
            .set(
                "fused_requests",
                Json::UInt(m.fused_requests.load(Ordering::Relaxed)),
            )
            .set(
                "admitted_mid_flight",
                Json::UInt(m.admitted_mid_flight.load(Ordering::Relaxed)),
            )
            .set("ticks", Json::UInt(m.ticks.load(Ordering::Relaxed)))
            .set(
                "dicts_trained",
                Json::UInt(m.dicts_trained.load(Ordering::Relaxed)),
            )
            .set(
                "artifacts_loaded",
                Json::UInt(m.artifacts_loaded.load(Ordering::Relaxed)),
            )
            .set(
                "dicts_published",
                Json::UInt(m.dicts_published.load(Ordering::Relaxed)),
            )
            .set("rollbacks", Json::UInt(m.rollbacks.load(Ordering::Relaxed)))
            .set(
                "numeric_failures",
                Json::UInt(m.numeric_failures.load(Ordering::Relaxed)),
            )
            .set(
                "breaker_open",
                Json::UInt(m.breaker_open.load(Ordering::Relaxed)),
            )
            .set(
                "dicts_registered",
                Json::UInt(self.dicts.read().unwrap().len() as u64),
            )
            .set(
                "kernel_backend",
                Json::Str(crate::tensor::gemm::backend_name().into()),
            );
        match self.store.as_ref() {
            Some(s) => o.set(
                "artifact_store",
                Json::Str(s.lock().unwrap().root().display().to_string()),
            ),
            None => o.set("artifact_store", Json::Null),
        };
        o
    }

    /// Point-in-time per-key snapshots for the observability renderers.
    /// Empty under [`Batching::CollectThenRun`] (that scheduler has no
    /// per-key state). Sorted by key label so the output is stable.
    fn key_snapshots(&self) -> Vec<KeySnapshot> {
        let Some(router) = &self.router else {
            return Vec::new();
        };
        let table = router.table.lock().unwrap();
        let mut out: Vec<KeySnapshot> = table
            .iter()
            .map(|(k, e)| {
                // Poisoned state (a panicked resident run) must not make
                // the operator surface panic too.
                let st = e.state.lock().unwrap_or_else(|p| p.into_inner());
                KeySnapshot {
                    key: format!(
                        "{}/{}/{}{}",
                        k.dataset,
                        k.solver,
                        k.nfe,
                        if k.use_pas { "/pas" } else { "" }
                    ),
                    active: st.active,
                    queue_depth: st.queue.len(),
                    resident_rows: e.stats.resident_rows.load(Ordering::Relaxed),
                    retired: e.stats.retired.load(Ordering::Relaxed),
                    shed: e.stats.shed.load(Ordering::Relaxed),
                }
            })
            .collect();
        drop(table);
        out.sort_by(|a, b| a.key.cmp(&b.key));
        out
    }

    /// The text-format metrics page (Prometheus exposition style):
    /// global counters, serve-latency histograms, pool gauges, per-key
    /// gauges. Wire command `{"cmd":"metrics"}`.
    pub fn metrics_text(&self) -> String {
        let keys = self.key_snapshots();
        let backlog = match &self.router {
            Some(router) => router.backlog.load(Ordering::Relaxed),
            None => 0,
        };
        let pool = PoolInfo {
            workers: self.cfg.workers,
            pool_threads: crate::util::pool::Pool::global().size(),
            engine_threads: self.cfg.engine_threads,
            max_batch: self.cfg.max_batch,
            queue_depth: self.cfg.queue_depth,
            backlog,
            uptime_s: self.started.elapsed().as_secs_f64(),
            batching: match self.cfg.batching {
                Batching::Continuous => "continuous",
                Batching::CollectThenRun => "collect-then-run",
            },
            kernel_backend: crate::tensor::gemm::backend_name(),
        };
        metrics_export::render_text(&self.metrics, &keys, &pool)
    }

    /// One-look health summary (status classification, saturation, shed
    /// and failure counts, coarse latency quantiles). Wire command
    /// `{"cmd":"health"}`.
    pub fn health_json(&self) -> Json {
        let keys = self.key_snapshots();
        let store_root = self
            .store
            .as_ref()
            .map(|s| s.lock().unwrap().root().display().to_string());
        metrics_export::health_json(
            &self.metrics,
            &keys,
            self.cfg.queue_depth,
            self.started.elapsed().as_secs_f64(),
            self.dicts.read().unwrap().len(),
            store_root,
            crate::tensor::gemm::backend_name(),
        )
    }

    /// Submit a request; returns a receiver for the response, or an error
    /// when the queue is full (backpressure surfaced to the caller) or the
    /// service is draining.
    pub fn submit(
        &self,
        mut req: SamplingRequest,
    ) -> Result<Receiver<SamplingResponse>, String> {
        if req.n_samples == 0 {
            // Rejected up front for both schedulers: a zero-row batch has
            // no rows to admit (and would trip engine shape asserts).
            return Err("n must be >= 1".into());
        }
        if self.stop.load(Ordering::Relaxed) {
            // Fast-fail before the request is accepted (not counted):
            // drain phase admits nothing new.
            return Err(DRAINING_ERR.into());
        }
        if req.id == 0 {
            req.id = self.next_id.fetch_add(1, Ordering::Relaxed);
        }
        let (rtx, rrx) = sync_channel(1);
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let p = Pending {
            req,
            enqueued: Instant::now(),
            reply: rtx,
        };
        let front = self.front.lock().unwrap();
        match front.as_ref() {
            None => {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(DRAINING_ERR.into())
            }
            Some(Front::Collect { tx }) => match tx.try_send(p) {
                Ok(()) => Ok(rrx),
                Err(TrySendError::Full(_)) => {
                    self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                    Err("queue full (backpressure)".into())
                }
                Err(TrySendError::Disconnected(_)) => Err("service stopped".into()),
            },
            Some(Front::Continuous { router }) => {
                router.route(p, &self.metrics)?;
                Ok(rrx)
            }
        }
    }

    /// Convenience: submit and block for the response.
    pub fn call(&self, req: SamplingRequest) -> Result<SamplingResponse, String> {
        let rx = self.submit(req)?;
        rx.recv().map_err(|_| "worker dropped".to_string())
    }

    /// Graceful two-phase drain. Idempotent — a second call returns
    /// immediately.
    ///
    /// Phase 1 raises the stop flag (new submissions fail fast with a
    /// structured `draining` error) and drops the front-end, so no further
    /// work can enter. Phase 2 joins the scheduler threads: each worker
    /// drains its dispatch queue, fails queued-but-unadmitted requests
    /// with the `draining` error, and lets resident cohorts run to
    /// retirement under [`ServiceConfig::drain_deadline`] (residents still
    /// in flight past the deadline fail instead of blocking exit). A final
    /// sweep over the router table fails any straggler that raced the stop
    /// flag, so **every accepted request gets exactly one structured
    /// reply** and `requests == completed + rejected + failed` balances at
    /// exit.
    pub fn shutdown(&self) {
        if self.stop.swap(true, Ordering::SeqCst) {
            return; // already shut down (or shutting down on another thread)
        }
        // Phase 1: stop admitting. Dropping the front-end disconnects the
        // channels the scheduler threads block on.
        let front = self.front.lock().unwrap().take();
        drop(front);
        // Phase 2: drain. Workers observe the stop flag, fail their queued
        // requests, retire residents under the drain deadline, then exit.
        let threads: Vec<JoinHandle<()>> = std::mem::take(&mut *self.threads.lock().unwrap());
        for t in threads {
            let _ = t.join();
        }
        // Final sweep: a submission that raced the stop flag may have
        // queued after its key's worker exited — fail stragglers so they
        // still get a structured reply.
        if let Some(router) = &self.router {
            let table = router.table.lock().unwrap();
            for entry in table.values() {
                let drained: Vec<Pending> = {
                    let mut st = entry.state.lock().unwrap_or_else(|p| p.into_inner());
                    st.active = false;
                    st.queue.drain(..).collect()
                };
                fail_all(drained, DRAINING_ERR, &self.metrics);
            }
        }
    }
}

fn index_dicts(dicts: Vec<CoordinateDict>) -> DictMap {
    dicts
        .into_iter()
        .map(|d| ((d.dataset.clone(), d.solver.clone(), d.nfe), d))
        .collect()
}

// ---------------------------------------------------------------------------
// Step-level continuous scheduler
// ---------------------------------------------------------------------------

/// One admitted request inside a cohort.
struct Member {
    p: Pending,
    admitted: Instant,
    /// First row of this request inside the cohort's slot list.
    row0: usize,
    rows: usize,
    /// Peak co-resident request count observed while this request ran.
    peak_coresident: usize,
}

/// Requests admitted at the same step boundary: their rows share a step
/// cursor and advance in lockstep, which is what lets one
/// [`CorrectedSampler`] (per-row buffers seeded at the cohort's first
/// step) serve the whole cohort.
struct Cohort {
    members: Vec<Member>,
    /// Engine slot ids, request-contiguous in member order.
    slots: Vec<usize>,
    steps_done: usize,
    hook: Option<CorrectedSampler<'static>>,
}

/// One resident engine run for one compatibility key: the step-level
/// continuous scheduler. See the module docs for the admission policy and
/// determinism contract.
struct KeyRun {
    key: BatchKey,
    solver: Box<dyn Solver>,
    model: Box<AnalyticEps>,
    sched: Schedule,
    dim: usize,
    n_steps: usize,
    cohorts: Vec<Cohort>,
    resident_rows: usize,
    /// EWMA of the observed wall-clock per non-idle scheduler tick, in
    /// milliseconds ([`TICK_EWMA_ALPHA`]). `None` until the run has timed
    /// its first tick — deadline admission only sheds on *expired*
    /// deadlines until an estimate exists.
    tick_ewma_ms: Option<f64>,
}

impl KeyRun {
    fn new(key: &BatchKey) -> Result<KeyRun, String> {
        let ds = crate::data::registry::get(&key.dataset).ok_or("unknown dataset")?;
        let solver: Box<dyn Solver> =
            crate::solvers::registry::get(&key.solver).ok_or("unknown solver")?;
        let steps = solver
            .steps_for_nfe(key.nfe)
            .ok_or("NFE not representable for this solver")?;
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(steps);
        let dim = model.dim();
        Ok(KeyRun {
            key: key.clone(),
            solver,
            model,
            sched,
            dim,
            n_steps: steps,
            cohorts: Vec::new(),
            resident_rows: 0,
            tick_ewma_ms: None,
        })
    }

    fn is_idle(&self) -> bool {
        self.cohorts.is_empty()
    }

    /// Admit one request at the current step boundary. Requests admitted
    /// at the same boundary merge into one cohort (their rows march in
    /// lockstep) when the model's rows are independent; otherwise each
    /// request gets its own cohort — either way the result bits match the
    /// solo run.
    fn admit(&mut self, engine: &mut SlotEngine, p: Pending, shared: &WorkerShared) {
        let metrics = &*shared.metrics;
        let rows = p.req.n_samples;
        let x_t = sample_prior_stream(p.req.seed, p.req.id, rows, self.dim, self.sched.t_max());
        let mid_flight = self.cohorts.iter().any(|c| c.steps_done > 0);
        // Merging rows from different requests into one eval/step is only
        // bit-preserving when *both* halves of the determinism contract
        // hold (see `SlotEngine` docs); otherwise every request steps in
        // its own cohort.
        let mergeable = self.model.rows_independent()
            && self.solver.row_independent()
            && self.cohorts.last().is_some_and(|c| c.steps_done == 0);
        if !mergeable {
            // An open numeric breaker degrades the key to uncorrected
            // sampling: still serving, still deterministic, but without
            // the dict whose corrections kept blowing up the solver.
            let hook = if self.key.use_pas && !shared.breaker.is_open(&self.key) {
                // Per-cohort dictionary snapshot under a short read lock:
                // online retraining never blocks on a resident run.
                shared
                    .dicts
                    .read()
                    .unwrap()
                    .get(&(self.key.dataset.clone(), self.key.solver.clone(), self.key.nfe))
                    .map(|d| CorrectedSampler::owned(d.clone(), self.dim))
            } else {
                None
            };
            self.cohorts.push(Cohort {
                members: Vec::new(),
                slots: Vec::new(),
                steps_done: 0,
                hook,
            });
            metrics.batches.fetch_add(1, Ordering::Relaxed);
        }
        // lint:allow(server-panic, cohort pushed just above when the list was empty; last_mut cannot miss)
        let cohort = self.cohorts.last_mut().unwrap();
        let row0 = cohort.slots.len();
        engine.admit(&x_t, &mut cohort.slots);
        cohort.members.push(Member {
            admitted: Instant::now(),
            p,
            row0,
            rows,
            peak_coresident: 1,
        });
        self.resident_rows += rows;
        metrics.fused_requests.fetch_add(1, Ordering::Relaxed);
        if mid_flight {
            metrics.admitted_mid_flight.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One scheduler tick: every resident cohort takes one solver step;
    /// cohorts that reached the end of the schedule retire immediately —
    /// samples are sent and slots freed before the next admission phase.
    ///
    /// After each cohort's step, the engine's numeric guardrail
    /// ([`SlotEngine::poisoned_rows`]) is consulted: members whose rows
    /// went non-finite fail *individually* with a structured `numeric`
    /// error while their cohort-mates keep stepping — row independence
    /// means a poisoned row never contaminates a neighbour's bits.
    fn tick(&mut self, engine: &mut SlotEngine, shared: &WorkerShared, stats: &KeyStats) {
        if self.cohorts.is_empty() {
            return;
        }
        let metrics = &*shared.metrics;
        metrics.ticks.fetch_add(1, Ordering::Relaxed);
        let live: usize = self.cohorts.iter().map(|c| c.members.len()).sum();
        for cohort in self.cohorts.iter_mut() {
            // Chaos site: simulate a model eval panicking mid-cohort at
            // the armed step index. Contained by `run_key`'s unwind
            // handling, same as a real eval panic.
            if crate::util::failpoint::peek(crate::util::failpoint::SERVICE_EVAL_PANIC)
                == Some(cohort.steps_done as u64)
            {
                crate::util::failpoint::take(crate::util::failpoint::SERVICE_EVAL_PANIC);
                // lint:allow(server-panic, chaos failpoint: the panic IS the injected fault, contained by run_key unwind handling)
                panic!("injected eval panic at step {}", cohort.steps_done);
            }
            for m in cohort.members.iter_mut() {
                m.peak_coresident = m.peak_coresident.max(live);
            }
            let hook = cohort.hook.as_mut().map(|h| h as &mut dyn DirectionHook);
            engine.step_cohort(
                self.solver.as_ref(),
                self.model.as_ref(),
                &self.sched,
                &cohort.slots,
                hook,
            );
            cohort.steps_done += 1;
            if !engine.poisoned_rows().is_empty() {
                // Copy the indices out so the engine can be borrowed
                // mutably for eviction (failure path only — the clean
                // path stays allocation-free).
                let poisoned: Vec<usize> = engine.poisoned_rows().to_vec();
                let removed =
                    fail_poisoned_members(cohort, &poisoned, engine, &self.key, shared);
                self.resident_rows -= removed;
            }
        }
        let mut i = 0;
        while i < self.cohorts.len() {
            if self.cohorts[i].members.is_empty() {
                // Every member failed the numeric guardrail: nothing left
                // to step or retire.
                self.cohorts.remove(i);
            } else if self.cohorts[i].steps_done == self.n_steps {
                let cohort = self.cohorts.remove(i);
                self.retire_cohort(engine, cohort, shared, stats);
            } else {
                i += 1;
            }
        }
    }

    /// Fail every resident member (structured error, real timing) and
    /// drop all cohorts *without* touching the engine — used when the
    /// engine workspace is unusable (unwinding out of a mid-cohort panic)
    /// or being abandoned (drain deadline exceeded); the next `run_key`
    /// on the worker resets the engine, reclaiming the slots.
    fn fail_residents(&mut self, msg: &str, metrics: &Metrics, stats: &KeyStats) {
        for cohort in std::mem::take(&mut self.cohorts) {
            for m in cohort.members {
                fail_member(m, msg, metrics);
            }
        }
        self.resident_rows = 0;
        stats.resident_rows.store(0, Ordering::Relaxed);
    }

    fn retire_cohort(
        &mut self,
        engine: &mut SlotEngine,
        cohort: Cohort,
        shared: &WorkerShared,
        stats: &KeyStats,
    ) {
        let metrics = &*shared.metrics;
        let nfe = self.n_steps * self.solver.evals_per_step();
        // A corrected cohort retiring cleanly resets the breaker's
        // consecutive-failure count for this key.
        if cohort.hook.is_some() {
            shared.breaker.record_success(&self.key);
        }
        let slots = &cohort.slots;
        for m in cohort.members {
            let mut samples = vec![0.0; m.rows * self.dim];
            for r in 0..m.rows {
                engine.retire_into(
                    slots[m.row0 + r],
                    &mut samples[r * self.dim..(r + 1) * self.dim],
                );
            }
            self.resident_rows -= m.rows;
            metrics.completed.fetch_add(1, Ordering::Relaxed);
            stats.retired.fetch_add(1, Ordering::Relaxed);
            let latency_ms = m.p.enqueued.elapsed().as_secs_f64() * 1e3;
            let queue_ms = (m.admitted - m.p.enqueued).as_secs_f64() * 1e3;
            let run_ms = m.admitted.elapsed().as_secs_f64() * 1e3;
            // Histograms before the reply: three relaxed atomic adds per
            // series, lock-free and allocation-free on this hot path.
            metrics.serve_hist.observe(queue_ms, run_ms, latency_ms);
            let _ = m.p.reply.send(SamplingResponse {
                id: m.p.req.id,
                samples,
                n: m.rows,
                dim: self.dim,
                nfe_spent: nfe,
                batched_with: m.peak_coresident,
                latency_ms,
                queue_ms,
                run_ms,
                error: None,
            });
        }
    }
}

/// Fail one *admitted* request with a structured error. Unlike
/// [`fail_one`] the request has real queue and run phases, so the reply
/// carries genuine `queue_ms`/`run_ms` splits.
fn fail_member(m: Member, msg: &str, metrics: &Metrics) {
    let latency_ms = m.p.enqueued.elapsed().as_secs_f64() * 1e3;
    let queue_ms = (m.admitted - m.p.enqueued).as_secs_f64() * 1e3;
    let run_ms = m.admitted.elapsed().as_secs_f64() * 1e3;
    metrics.failed.fetch_add(1, Ordering::Relaxed);
    metrics.serve_hist.latency_ms.record(latency_ms);
    let _ = m.p.reply.send(SamplingResponse {
        id: m.p.req.id,
        samples: Vec::new(),
        n: 0,
        dim: 0,
        nfe_spent: 0,
        batched_with: m.peak_coresident,
        latency_ms,
        queue_ms,
        run_ms,
        error: Some(msg.to_string()),
    });
}

/// Numeric-guardrail containment for one cohort: fail + evict the
/// members owning poisoned cohort-row indices; surviving members keep
/// their slots (row independence keeps their bits identical to the solo
/// run) and the cohort's row bookkeeping — member `row0` offsets, the
/// slot list — is rebuilt around the gap. Corrected-path failures feed
/// the circuit breaker; the failure that opens it also quarantines the
/// offending dict. Returns the number of rows evicted.
fn fail_poisoned_members(
    cohort: &mut Cohort,
    poisoned: &[usize],
    engine: &mut SlotEngine,
    key: &BatchKey,
    shared: &WorkerShared,
) -> usize {
    let metrics = &*shared.metrics;
    let corrected = cohort.hook.is_some();
    let old_members = std::mem::take(&mut cohort.members);
    let old_slots = std::mem::take(&mut cohort.slots);
    let mut removed_rows = 0usize;
    for mut m in old_members {
        let hit = poisoned.iter().any(|&r| r >= m.row0 && r < m.row0 + m.rows);
        if hit {
            for r in 0..m.rows {
                engine.evict(old_slots[m.row0 + r]);
            }
            removed_rows += m.rows;
            metrics.numeric_failures.fetch_add(1, Ordering::Relaxed);
            crate::warn_!(
                "numeric failure: non-finite state in request {} on {}/{}/{} — failing {} row(s)",
                m.p.req.id,
                key.dataset,
                key.solver,
                key.nfe,
                m.rows
            );
            fail_member(
                m,
                "numeric: non-finite values produced during sampling; request aborted",
                metrics,
            );
        } else {
            let new_row0 = cohort.slots.len();
            cohort
                .slots
                .extend_from_slice(&old_slots[m.row0..m.row0 + m.rows]);
            m.row0 = new_row0;
            cohort.members.push(m);
        }
    }
    if corrected && shared.breaker.record_failure(key, metrics) {
        open_breaker_containment(key, shared);
    }
    removed_rows
}

/// The breaker just opened for `key`: degrade it to uncorrected serving
/// by unregistering the dict, and quarantine the offending blob through
/// the artifact store so a restart cannot reload it. `Service::rollback`
/// (or republishing) restores corrected serving and closes the breaker.
fn open_breaker_containment(key: &BatchKey, shared: &WorkerShared) {
    shared
        .dicts
        .write()
        .unwrap()
        .remove(&(key.dataset.clone(), key.solver.clone(), key.nfe));
    crate::warn_!(
        "numeric breaker open for {}/{}/{}: serving uncorrected until rollback/republish",
        key.dataset,
        key.solver,
        key.nfe
    );
    let Some(store) = shared.store.as_ref() else {
        return;
    };
    let s = store.lock().unwrap();
    let akey = ArtifactKey::new(&key.dataset, &key.solver, key.nfe);
    let (manifest, _) = s.load_manifest();
    if let Some(entry) = manifest.entries.get(&akey.id()) {
        let sum = entry.current.checksum.clone();
        if s.quarantine_blob(&sum) {
            crate::warn_!("quarantined dict blob {sum} for {}", akey.id());
        }
    }
}

fn continuous_worker_loop(
    krx: Arc<Mutex<Receiver<KeyHandle>>>,
    ktx: Sender<KeyHandle>,
    shared: Arc<WorkerShared>,
) {
    // One long-lived slot engine per worker; its slot table, staging
    // buffers and scratch arena are reused across resident runs.
    let mut engine = SlotEngine::new(shared.engine_threads);
    loop {
        let (key, entry) = {
            let guard = krx.lock().unwrap();
            match guard.recv_timeout(Duration::from_millis(50)) {
                Ok(h) => h,
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                    // Buffered handles drain before this worker exits:
                    // recv_timeout only times out on an empty channel, so
                    // stopping here cannot strand a queued key.
                    if shared.stop.load(Ordering::Relaxed) {
                        return;
                    }
                    continue;
                }
                Err(_) => return,
            }
        };
        shared.backlog.fetch_sub(1, Ordering::Relaxed);
        // A panic inside a resident run must not kill the worker or
        // strand the key: `run_key`'s drop guard fails + deactivates the
        // key on unwind, and the engine workspace (possibly mid-step) is
        // rebuilt here.
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_key(&mut engine, key, &entry, &shared, &ktx);
        }));
        if res.is_err() {
            engine = SlotEngine::new(shared.engine_threads);
        }
    }
}

/// Base tick budget for the weighted fair yield: a worker spends at most
/// `BASE_TICK_BUDGET / (1 + dispatch backlog)` ticks (floored at one) on
/// a key before yielding it back to the dispatch queue. With no other
/// keys waiting the budget never triggers; the hotter the dispatch queue,
/// the faster keys rotate.
const BASE_TICK_BUDGET: usize = 256;

/// EWMA smoothing for the observed per-tick wall clock that drives
/// deadline admission: `ewma = (1-α)·ewma + α·sample`.
const TICK_EWMA_ALPHA: f64 = 0.2;

/// Deadline-infeasibility check for a *queued* (not yet admitted)
/// request: true when the deadline already expired, or when the key's
/// observed per-tick latency says the remaining budget cannot cover a
/// full `n_steps` rollout. With no estimate yet (`tick_ewma_ms` None),
/// only expired deadlines shed — never speculate without data.
fn past_deadline(p: &Pending, n_steps: usize, tick_ewma_ms: Option<f64>) -> bool {
    let Some(deadline_ms) = p.req.deadline_ms else {
        return false;
    };
    let remaining = deadline_ms - p.enqueued.elapsed().as_secs_f64() * 1e3;
    if remaining <= 0.0 {
        return true;
    }
    match tick_ewma_ms {
        Some(t) => remaining < n_steps as f64 * t,
        None => false,
    }
}

/// Fails + deactivates a key if its resident run unwinds, so queued
/// requests error out instead of hanging behind a permanently-`active`
/// key.
struct KeyGuard<'a> {
    state: &'a Mutex<KeyState>,
    metrics: &'a Metrics,
    defused: bool,
}

impl Drop for KeyGuard<'_> {
    fn drop(&mut self) {
        if self.defused {
            return;
        }
        let mut st = match self.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        let drained: Vec<Pending> = st.queue.drain(..).collect();
        st.active = false;
        drop(st);
        fail_all(drained, "sampling scheduler aborted on this key", self.metrics);
    }
}

/// Drive one key's resident run. Alternates admission phases with
/// scheduler ticks; deactivates the key — under the same lock the router
/// uses — only when no work remains, so no request is ever stranded.
///
/// Each admission phase first **sheds** queued requests whose deadline is
/// infeasible ([`past_deadline`]), then pops everything that fits under
/// the residency cap in the queue's priority-then-FIFO order. Once the
/// **weighted fair budget** is spent ([`BASE_TICK_BUDGET`] scaled down by
/// the dispatch backlog) — and only while other keys are actually waiting
/// for a worker — the run stops admitting, drains its residents, and
/// hands the key back to the dispatch queue.
///
/// When the service is stopping, the run enters **drain mode**: queued
/// requests fail immediately with a structured `draining` error, nothing
/// new is admitted, and residents tick to retirement until
/// `shared.drain_deadline` (measured from when this run first observed
/// the stop flag) — past the deadline the remaining residents fail
/// rather than hold shutdown hostage.
fn run_key(
    engine: &mut SlotEngine,
    key: BatchKey,
    entry: &Arc<KeyEntry>,
    shared: &WorkerShared,
    requeue: &Sender<KeyHandle>,
) {
    let metrics = &*shared.metrics;
    let state = &entry.state;
    let stats = &entry.stats;
    let mut run = match KeyRun::new(&key) {
        Ok(r) => r,
        Err(e) => {
            // The key itself is invalid: every request for it fails.
            loop {
                let drained: Vec<Pending> = {
                    let mut st = state.lock().unwrap();
                    if st.queue.is_empty() {
                        st.active = false;
                        return;
                    }
                    st.queue.drain(..).collect()
                };
                fail_all(drained, &e, metrics);
            }
        }
    };
    let mut guard = KeyGuard {
        state,
        metrics,
        defused: false,
    };
    engine.reset(run.dim, run.n_steps);
    let mut ticks = 0usize;
    let mut drain_started: Option<Instant> = None;
    loop {
        let stopping = shared.stop.load(Ordering::Relaxed);
        if stopping && drain_started.is_none() {
            drain_started = Some(Instant::now());
        }
        // Weighted fair yield: the tick budget shrinks as more keys wait
        // for a worker (floored at one tick so a run always progresses),
        // and yielding only happens when it helps someone.
        let waiting = shared.backlog.load(Ordering::Relaxed);
        let budget = (BASE_TICK_BUDGET / (waiting + 1)).max(1);
        let yielding = waiting > 0 && ticks >= budget;
        let mut to_admit: Vec<Pending> = Vec::new();
        let mut to_shed: Vec<Pending> = Vec::new();
        let mut to_fail: Vec<Pending> = Vec::new();
        let disposition = {
            let mut st = state.lock().unwrap();
            if stopping {
                // Drain mode: queued-but-unadmitted requests fail with a
                // structured error instead of waiting for an admission
                // that will never come.
                to_fail.extend(st.queue.drain(..));
            } else {
                // Deadline admission: shed infeasible queued requests
                // first, so they fail fast instead of rotting behind the
                // residents. (Admitted rows are never shed — numerics
                // stay untouched.)
                let mut i = 0;
                while i < st.queue.len() {
                    if past_deadline(&st.queue[i], run.n_steps, run.tick_ewma_ms) {
                        // lint:allow(server-panic, index i bounds-checked by the loop condition; remove(i) cannot return None)
                        to_shed.push(st.queue.remove(i).unwrap());
                    } else {
                        i += 1;
                    }
                }
                if !yielding {
                    let mut projected = run.resident_rows;
                    while let Some(front) = st.queue.front() {
                        let rows = front.req.n_samples;
                        // Priority-then-FIFO admission under the residency
                        // cap; an oversized request runs alone when the
                        // engine is empty. (rows == 0 passes the cap and is
                        // failed below.)
                        if projected + rows <= shared.max_rows || projected == 0 {
                            projected += rows;
                            // lint:allow(server-panic, front() returned Some in the loop condition; pop_front cannot return None)
                            to_admit.push(st.queue.pop_front().unwrap());
                        } else {
                            break;
                        }
                    }
                }
            }
            if run.is_idle() && to_admit.is_empty() {
                if st.queue.is_empty() {
                    st.active = false;
                    guard.defused = true;
                    1 // done: key deactivated
                } else {
                    // Fairness yield: residents drained but the queue is
                    // not empty — hand the key back (it stays `active`;
                    // exactly one handle re-enters the dispatch queue)
                    // and free this worker for other keys. If the service
                    // is stopping the guard fails the queued requests
                    // instead.
                    debug_assert!(yielding);
                    2 // requeue
                }
            } else {
                0 // keep running
            }
        };
        // Shed and drain replies go out after the state lock is released
        // (reply channels can rendezvous with slow receivers).
        for p in to_shed {
            metrics.shed.fetch_add(1, Ordering::Relaxed);
            stats.shed.fetch_add(1, Ordering::Relaxed);
            fail_one(p, SHED_ERR, metrics);
        }
        fail_all(to_fail, DRAINING_ERR, metrics);
        match disposition {
            1 => return,
            2 => {
                shared.backlog.fetch_add(1, Ordering::Relaxed);
                if requeue.send((key, entry.clone())).is_ok() {
                    guard.defused = true;
                }
                return;
            }
            _ => {}
        }
        for p in to_admit {
            if p.req.n_samples == 0 {
                fail_one(p, "n must be >= 1", metrics);
            } else {
                run.admit(engine, p, shared);
            }
        }
        // Drain deadline: residents get until the deadline to retire
        // normally; past it they fail so shutdown can complete.
        if stopping
            && drain_started.is_some_and(|t0| t0.elapsed() >= shared.drain_deadline)
            && !run.is_idle()
        {
            run.fail_residents(
                "draining: drain deadline exceeded before completion",
                metrics,
                stats,
            );
            continue; // next pass deactivates the key and returns
        }
        // Time only non-idle ticks: an empty tick returns immediately and
        // would poison the per-tick latency estimate toward zero.
        let idle = run.is_idle();
        let t0 = Instant::now();
        // An eval panic mid-cohort (or the injected chaos equivalent)
        // must not strand the residents without replies: fail them all
        // with a structured error, then resume the unwind so the KeyGuard
        // fails the queue and the worker loop rebuilds its engine.
        let ticked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run.tick(engine, shared, stats);
        }));
        if let Err(payload) = ticked {
            run.fail_residents("eval panicked mid-cohort; request aborted", metrics, stats);
            std::panic::resume_unwind(payload);
        }
        if !idle {
            let sample = t0.elapsed().as_secs_f64() * 1e3;
            run.tick_ewma_ms = Some(match run.tick_ewma_ms {
                Some(e) => (1.0 - TICK_EWMA_ALPHA) * e + TICK_EWMA_ALPHA * sample,
                None => sample,
            });
            stats
                .resident_rows
                .store(run.resident_rows, Ordering::Relaxed);
        }
        ticks += 1;
    }
}

// ---------------------------------------------------------------------------
// Collect-then-run baseline (the seed batcher)
// ---------------------------------------------------------------------------

fn batcher_loop(
    rx: Receiver<Pending>,
    wtx: SyncSender<Vec<Pending>>,
    cfg: ServiceConfig,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
) {
    // Incompatible arrivals are carried across batches in arrival order
    // (the front one leads the next batch); bounded at two by the
    // early-break below.
    let mut held: VecDeque<Pending> = VecDeque::new();
    'batching: loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        // Block for the first request (or shutdown).
        let first = if let Some(p) = held.pop_front() {
            p
        } else {
            match rx.recv() {
                Ok(p) => p,
                Err(_) => break,
            }
        };
        let key = BatchKey::of(&first.req);
        let mut batch = vec![first];
        let mut total: usize = batch[0].req.n_samples;
        // A previously-held request may be compatible with this leader
        // (it was only incompatible with the batch it arrived during).
        let mut i = 0;
        while i < held.len() {
            if BatchKey::of(&held[i].req) == key && total + held[i].req.n_samples <= cfg.max_batch
            {
                // lint:allow(server-panic, index i bounds-checked by the loop condition; remove(i) cannot return None)
                let p = held.remove(i).unwrap();
                total += p.req.n_samples;
                batch.push(p);
            } else {
                i += 1;
            }
        }
        let deadline = Instant::now() + cfg.batch_window;
        // Gather compatible requests for the *full* window / size budget.
        // One incompatible arrival is held to lead the next batch without
        // ending this one's collection (mixed-key traffic used to
        // collapse fusion here); a second incompatible arrival ends the
        // window early so the held queue stays bounded at one.
        while total < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(p) => {
                    if BatchKey::of(&p.req) == key && total + p.req.n_samples <= cfg.max_batch {
                        total += p.req.n_samples;
                        batch.push(p);
                    } else {
                        held.push_back(p);
                        if held.len() > 1 {
                            break;
                        }
                    }
                }
                Err(_) => break, // window elapsed or channel closed
            }
        }
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        metrics
            .fused_requests
            .fetch_add(batch.len() as u64, Ordering::Relaxed);
        if let Err(e) = wtx.send(batch) {
            // Workers are gone (shutdown finished racing us): the batch
            // comes back in the error — fail it rather than drop it.
            fail_all(e.0, DRAINING_ERR, &metrics);
            break 'batching;
        }
    }
    // Shutdown drain: everything queued-but-unbatched gets a structured
    // reply before the batcher exits. mpsc buffers survive sender drops,
    // so `try_recv` observes every submission that beat the stop flag.
    let mut stranded: Vec<Pending> = held.drain(..).collect();
    while let Ok(p) = rx.try_recv() {
        stranded.push(p);
    }
    fail_all(stranded, DRAINING_ERR, &metrics);
}

fn collect_worker_loop(
    wrx: Arc<Mutex<Receiver<Vec<Pending>>>>,
    metrics: Arc<Metrics>,
    dicts: Arc<RwLock<DictMap>>,
    engine_threads: usize,
) {
    // One long-lived engine per worker: the serving path never records
    // trajectories (`Record::None`), and the workspace is reused across
    // batches, so steady-state sampling performs no per-step allocation.
    let mut engine = SamplerEngine::new(crate::solvers::engine::EngineConfig {
        record: Record::None,
        threads: engine_threads,
    });
    loop {
        let batch = {
            let guard = wrx.lock().unwrap();
            match guard.recv_timeout(Duration::from_millis(50)) {
                Ok(b) => b,
                // Timeout just cycles the lock so sibling workers get a
                // turn at the receiver. Workers exit on *disconnect* (the
                // batcher dropped the sender), which mpsc only reports
                // once the buffer is empty — so a batch dispatched right
                // before shutdown is still executed, never stranded.
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => continue,
                Err(_) => return,
            }
        };
        run_batch(batch, &metrics, &dicts, &mut engine);
    }
}

/// Answer one request with a structured error. Error replies carry the
/// real elapsed latency (submit → failure) — error paths are exactly
/// where operators need timing — and count into `Metrics.failed` plus
/// the latency histogram, so `requests == completed + rejected + failed
/// + in-flight` holds.
fn fail_one(p: Pending, msg: &str, metrics: &Metrics) {
    let latency_ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
    metrics.failed.fetch_add(1, Ordering::Relaxed);
    metrics.serve_hist.latency_ms.record(latency_ms);
    let _ = p.reply.send(SamplingResponse {
        id: p.req.id,
        samples: Vec::new(),
        n: 0,
        dim: 0,
        nfe_spent: 0,
        batched_with: 0,
        latency_ms,
        // The request never ran: its whole life was queue time.
        queue_ms: latency_ms,
        run_ms: 0.0,
        error: Some(msg.to_string()),
    });
}

fn fail_all(batch: Vec<Pending>, msg: &str, metrics: &Metrics) {
    for p in batch {
        fail_one(p, msg, metrics);
    }
}

fn run_batch(
    batch: Vec<Pending>,
    metrics: &Metrics,
    dicts: &RwLock<DictMap>,
    engine: &mut SamplerEngine,
) {
    let run_start = Instant::now();
    let req0 = &batch[0].req;
    let ds = match crate::data::registry::get(&req0.dataset) {
        Some(d) => d,
        None => return fail_all(batch, "unknown dataset", metrics),
    };
    let solver: Box<dyn Solver> = match crate::solvers::registry::get(&req0.solver) {
        Some(s) => s,
        None => return fail_all(batch, "unknown solver", metrics),
    };
    let steps = match solver.steps_for_nfe(req0.nfe) {
        Some(s) => s,
        None => return fail_all(batch, "NFE not representable for this solver", metrics),
    };
    let model = AnalyticEps::from_dataset(&ds);
    let sched = default_schedule(steps);
    let dim = model.dim();
    // Fuse priors: each request gets its own seeded stream.
    let n_total: usize = batch.iter().map(|p| p.req.n_samples).sum();
    let mut x_t = Vec::with_capacity(n_total * dim);
    for p in &batch {
        x_t.extend(sample_prior_stream(
            p.req.seed,
            p.req.id,
            p.req.n_samples,
            dim,
            sched.t_max(),
        ));
    }
    // Snapshot the dict under a short read lock so an online `train_pas`
    // never blocks on (or is blocked by) an in-flight solver run.
    let dict = if req0.use_pas {
        dicts
            .read()
            .unwrap()
            .get(&(req0.dataset.clone(), req0.solver.clone(), req0.nfe))
            .cloned()
    } else {
        None
    };
    let mut x0 = vec![0.0; n_total * dim];
    let nfe = match &dict {
        Some(d) => {
            let mut hook = CorrectedSampler::new(d, dim);
            engine.run_into(
                solver.as_ref(),
                model.as_ref(),
                &x_t,
                n_total,
                &sched,
                Some(&mut hook),
                &mut x0,
            )
        }
        None => engine.run_into(
            solver.as_ref(),
            model.as_ref(),
            &x_t,
            n_total,
            &sched,
            None,
            &mut x0,
        ),
    };
    // Scatter results back.
    let fused = batch.len();
    let run_ms = run_start.elapsed().as_secs_f64() * 1e3;
    let mut offset = 0usize;
    for p in batch {
        let n = p.req.n_samples;
        let samples = x0[offset * dim..(offset + n) * dim].to_vec();
        offset += n;
        // Numeric guardrail (collect path): a non-finite result is a
        // structured per-request failure, never a "success" full of NaNs.
        if samples.iter().any(|v| !v.is_finite()) {
            metrics.numeric_failures.fetch_add(1, Ordering::Relaxed);
            fail_one(
                p,
                "numeric: non-finite values produced during sampling; request aborted",
                metrics,
            );
            continue;
        }
        metrics.completed.fetch_add(1, Ordering::Relaxed);
        let latency_ms = p.enqueued.elapsed().as_secs_f64() * 1e3;
        let queue_ms = (run_start - p.enqueued).as_secs_f64() * 1e3;
        metrics.serve_hist.observe(queue_ms, run_ms, latency_ms);
        let _ = p.reply.send(SamplingResponse {
            id: p.req.id,
            samples,
            n,
            dim,
            nfe_spent: nfe,
            batched_with: fused,
            latency_ms,
            queue_ms,
            run_ms,
            error: None,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pas::coords::ScaleMode;
    use crate::util::rng::Pcg64;

    fn req(n: usize, seed: u64) -> SamplingRequest {
        SamplingRequest {
            id: 0,
            dataset: "gmm2d".into(),
            solver: "ddim".into(),
            nfe: 6,
            n_samples: n,
            seed,
            use_pas: false,
            deadline_ms: None,
            priority: 0,
        }
    }

    #[test]
    fn serves_a_request() {
        let svc = Service::start(ServiceConfig::default(), Vec::new());
        let resp = svc.call(req(16, 1)).unwrap();
        assert!(resp.error.is_none());
        assert_eq!(resp.n, 16);
        assert_eq!(resp.dim, 2);
        assert_eq!(resp.samples.len(), 32);
        assert!(resp.queue_ms >= 0.0 && resp.run_ms > 0.0);
        svc.shutdown();
    }

    #[test]
    fn collect_then_run_batches_concurrent_requests() {
        let svc = Service::start(
            ServiceConfig {
                batching: Batching::CollectThenRun,
                batch_window: Duration::from_millis(30),
                ..ServiceConfig::default()
            },
            Vec::new(),
        );
        let rxs: Vec<_> = (0..6).map(|s| svc.submit(req(8, s)).unwrap()).collect();
        let resps: Vec<_> = rxs.into_iter().map(|r| r.recv().unwrap()).collect();
        assert!(resps.iter().all(|r| r.error.is_none()));
        // At least one response was fused with another request.
        assert!(
            resps.iter().any(|r| r.batched_with > 1),
            "batcher never fused: {:?}",
            resps.iter().map(|r| r.batched_with).collect::<Vec<_>>()
        );
        svc.shutdown();
    }

    #[test]
    fn different_seeds_give_different_samples() {
        let svc = Service::start(ServiceConfig::default(), Vec::new());
        let a = svc.call(req(4, 1)).unwrap();
        let b = svc.call(req(4, 2)).unwrap();
        assert_ne!(a.samples, b.samples);
        // Same seed + same id-independent stream? ids differ, so draws
        // differ by design; determinism is per (seed, id).
        svc.shutdown();
    }

    #[test]
    fn invalid_nfe_is_reported() {
        let svc = Service::start(ServiceConfig::default(), Vec::new());
        let mut r = req(4, 1);
        r.solver = "heun".into();
        r.nfe = 5; // odd: not representable
        let resp = svc.call(r).unwrap();
        assert!(resp.error.is_some());
        svc.shutdown();
    }

    /// Online training: an empty-dict service trains a correction while
    /// running, registers it, and subsequent `use_pas` requests pick it
    /// up (different samples than the uncorrected path, no errors).
    #[test]
    fn online_training_registers_dict_and_serves_it() {
        let svc = Service::start(ServiceConfig::default(), Vec::new());
        // use_pas before training: silently uncorrected (no dict yet).
        let mut pas_req = req(16, 9);
        pas_req.nfe = 8;
        pas_req.use_pas = true;
        let before = svc.call(pas_req.clone()).unwrap();
        assert!(before.error.is_none());

        let stats = svc
            .train_pas(
                "gmm2d",
                "ddim",
                8,
                Some(TrainConfig {
                    n_traj: 48,
                    epochs: 16,
                    minibatch: 16,
                    teacher_nfe: 60,
                    lr: 5e-2,
                    scale_mode: crate::pas::coords::ScaleMode::Relative,
                    ..TrainConfig::default()
                }),
            )
            .unwrap();
        assert!(stats.n_params > 0, "training must store parameters");
        assert!(
            stats.final_error_corrected < stats.final_error_uncorrected,
            "online training must reduce truncation error: {} -> {}",
            stats.final_error_uncorrected,
            stats.final_error_corrected
        );
        assert_eq!(svc.metrics.dicts_trained.load(Ordering::Relaxed), 1);

        let after = svc.call(pas_req).unwrap();
        assert!(after.error.is_none());
        assert_ne!(
            before.samples, after.samples,
            "registered dict must change the corrected samples"
        );
        // Unknown config still errors cleanly.
        assert!(svc.train_pas("nope", "ddim", 8, None).is_err());
        svc.shutdown();
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let svc = Service::start(
            ServiceConfig {
                workers: 1,
                queue_depth: 1,
                batch_window: Duration::from_millis(1),
                ..ServiceConfig::default()
            },
            Vec::new(),
        );
        // Flood; with depth 1 some submissions must be rejected.
        let mut rejected = 0;
        let mut rxs = Vec::new();
        for s in 0..64 {
            match svc.submit(req(64, s)) {
                Ok(rx) => rxs.push(rx),
                Err(_) => rejected += 1,
            }
        }
        for rx in rxs {
            let _ = rx.recv();
        }
        assert!(rejected > 0, "expected at least one backpressure rejection");
        assert!(svc.metrics.rejected.load(Ordering::Relaxed) > 0);
        svc.shutdown();
    }

    // -- continuous-scheduler internals -----------------------------------

    /// Worker-context bundle for driving `KeyRun` directly in tests (no
    /// threads, no store, closed breaker).
    fn test_shared(dicts: DictMap) -> WorkerShared {
        WorkerShared {
            metrics: Arc::new(Metrics::default()),
            dicts: Arc::new(RwLock::new(dicts)),
            stop: Arc::new(AtomicBool::new(false)),
            breaker: Arc::new(NumericBreaker::new()),
            store: None,
            backlog: Arc::new(AtomicUsize::new(0)),
            engine_threads: 1,
            max_rows: 256,
            drain_deadline: Duration::from_secs(5),
        }
    }

    /// Drive a `KeyRun` directly (no threads): admit `reqs` at the given
    /// tick offsets, run to drain, return the responses in request order.
    fn drive_key_run(
        key: &BatchKey,
        engine_threads: usize,
        reqs: &[(SamplingRequest, usize)],
        dicts: &RwLock<DictMap>,
    ) -> Vec<SamplingResponse> {
        let shared = test_shared(dicts.read().unwrap().clone());
        let stats = KeyStats::default();
        let mut engine = SlotEngine::new(engine_threads);
        let mut run = KeyRun::new(key).expect("valid key");
        engine.reset(run.dim, run.n_steps);
        let mut rxs = Vec::new();
        let mut waiting: Vec<(usize, Pending)> = Vec::new();
        for (r, (req, at)) in reqs.iter().enumerate() {
            let (rtx, rrx) = sync_channel(1);
            rxs.push(rrx);
            let mut req = req.clone();
            req.id = r as u64 + 1;
            waiting.push((
                *at,
                Pending {
                    req,
                    enqueued: Instant::now(),
                    reply: rtx,
                },
            ));
        }
        let mut tick = 0usize;
        while !waiting.is_empty() || !run.is_idle() {
            let mut i = 0;
            while i < waiting.len() {
                if waiting[i].0 <= tick {
                    let (_, p) = waiting.remove(i);
                    run.admit(&mut engine, p, &shared);
                } else {
                    i += 1;
                }
            }
            run.tick(&mut engine, &shared, &stats);
            tick += 1;
            assert!(tick < 10_000, "key run failed to drain");
        }
        rxs.into_iter()
            .map(|rx| rx.try_recv().expect("response must be ready"))
            .collect()
    }

    /// Solo reference: the request run alone through a fresh serving
    /// engine (the determinism contract's right-hand side).
    fn solo_run(key: &BatchKey, req: &SamplingRequest, id: u64, dicts: &RwLock<DictMap>) -> Vec<f64> {
        let ds = crate::data::registry::get(&key.dataset).unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let solver = crate::solvers::registry::get(&key.solver).unwrap();
        let steps = solver.steps_for_nfe(key.nfe).unwrap();
        let sched = default_schedule(steps);
        let dim = model.dim();
        let x_t = sample_prior_stream(req.seed, id, req.n_samples, dim, sched.t_max());
        let mut x0 = vec![0.0; req.n_samples * dim];
        let mut engine = SamplerEngine::with_record(Record::None);
        let dict = if key.use_pas {
            dicts
                .read()
                .unwrap()
                .get(&(key.dataset.clone(), key.solver.clone(), key.nfe))
                .cloned()
        } else {
            None
        };
        match &dict {
            Some(d) => {
                let mut hook = CorrectedSampler::new(d, dim);
                engine.run_into(
                    solver.as_ref(),
                    model.as_ref(),
                    &x_t,
                    req.n_samples,
                    &sched,
                    Some(&mut hook),
                    &mut x0,
                );
            }
            None => {
                engine.run_into(
                    solver.as_ref(),
                    model.as_ref(),
                    &x_t,
                    req.n_samples,
                    &sched,
                    None,
                    &mut x0,
                );
            }
        }
        x0
    }

    /// The enforced bit-exactness contract: N requests admitted at
    /// randomized step offsets, every response bitwise-equal to its solo
    /// run, across engine thread caps {1, 4, 16}, for single-step,
    /// multistep (ring lookback) and multi-eval solvers.
    #[test]
    fn continuous_parity_under_randomized_admission() {
        let mut rng = Pcg64::seed(77);
        for (solver, nfe) in [("ddim", 8usize), ("dpmpp3m", 8), ("heun", 16)] {
            let key = BatchKey {
                dataset: "gmm-hd64".into(),
                solver: solver.into(),
                nfe,
                use_pas: false,
            };
            // Randomized shapes and admission offsets, fixed across the
            // thread caps so all three run the same scenario.
            let reqs: Vec<(SamplingRequest, usize)> = (0..6)
                .map(|s| {
                    let n = 1 + (rng.next_u64() % 5) as usize;
                    let at = (rng.next_u64() % 10) as usize;
                    let mut r = req(n, s);
                    r.dataset = key.dataset.clone();
                    r.solver = key.solver.clone();
                    r.nfe = nfe;
                    (r, at)
                })
                .collect();
            let dicts = RwLock::new(DictMap::new());
            for threads in [1usize, 4, 16] {
                let resps = drive_key_run(&key, threads, &reqs, &dicts);
                for (r, resp) in resps.iter().enumerate() {
                    assert!(resp.error.is_none(), "{solver}: {:?}", resp.error);
                    let want = solo_run(&key, &reqs[r].0, resp.id, &dicts);
                    assert_eq!(
                        resp.samples, want,
                        "{solver}: request {r} (threads={threads}, admitted at tick \
                         {}) diverged from its solo run",
                        reqs[r].1
                    );
                    assert_eq!(resp.nfe_spent, nfe);
                }
            }
        }
    }

    /// Same contract through the PAS correction hook: per-cohort owned
    /// dict snapshots + per-slot trajectory buffers must reproduce the
    /// solo corrected run bitwise under mid-flight admission.
    #[test]
    fn continuous_parity_with_pas_correction() {
        let key = BatchKey {
            dataset: "gmm2d".into(),
            solver: "ddim".into(),
            nfe: 6,
            use_pas: true,
        };
        let mut dict = CoordinateDict::new(4, ScaleMode::Relative, "ddim", "gmm2d", 6);
        dict.steps.insert(4, vec![0.9, 0.05, 0.0, 0.0]);
        dict.steps.insert(2, vec![1.0, -0.1, 0.0, 0.0]);
        let dicts = RwLock::new(index_dicts(vec![dict]));
        let reqs: Vec<(SamplingRequest, usize)> = [(3usize, 0usize), (2, 0), (4, 2), (1, 3)]
            .iter()
            .enumerate()
            .map(|(s, &(n, at))| {
                let mut r = req(n, s as u64 + 10);
                r.use_pas = true;
                (r, at)
            })
            .collect();
        for threads in [1usize, 4, 16] {
            let resps = drive_key_run(&key, threads, &reqs, &dicts);
            for (r, resp) in resps.iter().enumerate() {
                assert!(resp.error.is_none());
                let want = solo_run(&key, &reqs[r].0, resp.id, &dicts);
                assert_eq!(
                    resp.samples, want,
                    "corrected request {r} (threads={threads}) diverged from its solo run"
                );
            }
        }
    }

    /// Mid-flight admission is observable: a request admitted while an
    /// earlier one is in flight is co-resident with it, both finish, and
    /// the metric records the admission.
    #[test]
    fn continuous_admits_mid_flight() {
        let key = BatchKey {
            dataset: "gmm2d".into(),
            solver: "ddim".into(),
            nfe: 6,
            use_pas: false,
        };
        let shared = test_shared(DictMap::new());
        let stats = KeyStats::default();
        let mut engine = SlotEngine::new(1);
        let mut run = KeyRun::new(&key).unwrap();
        engine.reset(run.dim, run.n_steps);
        let mk = |n: usize, id: u64| {
            let (rtx, rrx) = sync_channel(1);
            let mut r = req(n, id);
            r.id = id;
            (
                Pending {
                    req: r,
                    enqueued: Instant::now(),
                    reply: rtx,
                },
                rrx,
            )
        };
        let (pa, rxa) = mk(4, 1);
        let (pb, rxb) = mk(2, 2);
        run.admit(&mut engine, pa, &shared);
        run.tick(&mut engine, &shared, &stats);
        run.tick(&mut engine, &shared, &stats);
        // A is 2 steps deep; B joins mid-flight in its own cohort.
        run.admit(&mut engine, pb, &shared);
        assert_eq!(shared.metrics.admitted_mid_flight.load(Ordering::Relaxed), 1);
        // A retires at tick 6 (B still 2 steps behind) ...
        for _ in 0..4 {
            run.tick(&mut engine, &shared, &stats);
        }
        let ra = rxa.try_recv().expect("A must retire as soon as it finishes");
        assert!(rxb.try_recv().is_err(), "B must still be in flight");
        // ... and B follows two ticks later.
        run.tick(&mut engine, &shared, &stats);
        run.tick(&mut engine, &shared, &stats);
        let rb = rxb.try_recv().expect("B must retire two ticks after A");
        assert!(run.is_idle());
        assert_eq!(ra.batched_with, 2, "A saw B co-resident");
        assert_eq!(rb.batched_with, 2, "B saw A co-resident");
        assert_eq!(shared.metrics.batches.load(Ordering::Relaxed), 2, "two cohorts");
        assert_eq!(shared.metrics.completed.load(Ordering::Relaxed), 2);
    }

    /// End-to-end through the threaded service: whatever the real
    /// admission interleaving turned out to be, every response must match
    /// its solo run bitwise (the contract is interleaving-independent).
    #[test]
    fn continuous_service_responses_match_solo_runs() {
        for threads in [1usize, 4] {
            let svc = Service::start(
                ServiceConfig {
                    workers: 2,
                    engine_threads: threads,
                    ..ServiceConfig::default()
                },
                Vec::new(),
            );
            let reqs: Vec<SamplingRequest> = (0..8).map(|s| req(3 + s as usize % 4, s)).collect();
            let rxs: Vec<_> = reqs
                .iter()
                .map(|r| svc.submit(r.clone()).unwrap())
                .collect();
            let key = BatchKey::of(&reqs[0]);
            let dicts = RwLock::new(DictMap::new());
            for (r, rx) in rxs.into_iter().enumerate() {
                let resp = rx.recv().unwrap();
                assert!(resp.error.is_none());
                let want = solo_run(&key, &reqs[r], resp.id, &dicts);
                assert_eq!(
                    resp.samples, want,
                    "request {r} (threads={threads}) diverged from its solo run"
                );
                assert!(resp.queue_ms >= 0.0 && resp.run_ms >= 0.0);
            }
            svc.shutdown();
        }
    }

    /// An oversized request (> max_batch rows) is admitted alone instead
    /// of deadlocking the key, and later requests still complete.
    #[test]
    fn oversized_request_is_served_alone() {
        let svc = Service::start(
            ServiceConfig {
                workers: 1,
                max_batch: 8,
                ..ServiceConfig::default()
            },
            Vec::new(),
        );
        let big = svc.call(req(32, 5)).unwrap();
        assert!(big.error.is_none());
        assert_eq!(big.n, 32);
        let small = svc.call(req(2, 6)).unwrap();
        assert!(small.error.is_none());
        svc.shutdown();
    }

    // -- SLO admission + observability -------------------------------------

    /// The router keeps each key's queue priority-ordered (descending)
    /// with FIFO tie-breaks.
    #[test]
    fn priority_orders_key_queue() {
        let (ktx, _krx) = channel::<KeyHandle>();
        let router = Router {
            table: Mutex::new(HashMap::new()),
            ktx,
            queue_depth: 16,
            backlog: Arc::new(AtomicUsize::new(0)),
            stop: Arc::new(AtomicBool::new(false)),
        };
        let metrics = Metrics::default();
        let mut keep = Vec::new(); // keep reply receivers alive
        for (id, priority) in [(1u64, 0i32), (2, 0), (3, 5), (4, -3), (5, 5)] {
            let (rtx, rrx) = sync_channel(1);
            keep.push(rrx);
            let mut r = req(1, id);
            r.id = id;
            r.priority = priority;
            router
                .route(
                    Pending {
                        req: r,
                        enqueued: Instant::now(),
                        reply: rtx,
                    },
                    &metrics,
                )
                .unwrap();
        }
        let table = router.table.lock().unwrap();
        let entry = table.values().next().unwrap();
        let st = entry.state.lock().unwrap();
        let order: Vec<u64> = st.queue.iter().map(|p| p.req.id).collect();
        // Priorities [5, 5] first in arrival order, then [0, 0], then -3.
        assert_eq!(order, vec![3, 5, 1, 2, 4]);
    }

    /// Deadline shedding end-to-end: with one key saturated, an
    /// infeasible-deadline request fails fast with a structured
    /// `deadline` error carrying real latency, while an in-deadline
    /// request still completes bit-identical to its solo run.
    #[test]
    fn deadline_expired_requests_are_shed() {
        let svc = Service::start(
            ServiceConfig {
                workers: 1,
                max_batch: 8,
                ..ServiceConfig::default()
            },
            Vec::new(),
        );
        // Saturate the key: 8 rows resident for 2000 ticks (long enough
        // that the requests below always land while it is mid-flight).
        let mut blocker = req(8, 1);
        blocker.nfe = 2000;
        let rx_blocker = svc.submit(blocker.clone()).unwrap();
        // Wait until the resident run has timed at least one tick so the
        // EWMA estimate exists.
        let t0 = Instant::now();
        while svc.metrics.ticks.load(Ordering::Relaxed) < 2 {
            assert!(t0.elapsed() < Duration::from_secs(10), "run never started");
            std::thread::sleep(Duration::from_micros(100));
        }
        // Hopeless: a deadline that expires immediately. The key is full
        // (projected 8 + 4 > max_batch 8), so this queues — and must be
        // shed at the next admission phase, not after the blocker.
        let mut hopeless = req(4, 2);
        hopeless.nfe = 2000;
        hopeless.deadline_ms = Some(0.01);
        let rx_hopeless = svc.submit(hopeless).unwrap();
        // Feasible: a deadline the queue-behind-blocker easily meets.
        let mut feasible = req(4, 3);
        feasible.nfe = 2000;
        feasible.deadline_ms = Some(60_000.0);
        let rx_feasible = svc.submit(feasible.clone()).unwrap();

        let shed = rx_hopeless.recv().unwrap();
        let err = shed.error.as_deref().expect("hopeless request must be shed");
        assert!(err.contains("deadline"), "structured deadline error, got: {err}");
        assert!(shed.latency_ms > 0.0, "shed reply must carry real latency");
        assert_eq!(shed.queue_ms, shed.latency_ms, "a shed request never ran");
        assert_eq!(shed.run_ms, 0.0);

        let done = rx_feasible.recv().unwrap();
        assert!(done.error.is_none(), "{:?}", done.error);
        let key = BatchKey::of(&feasible);
        let dicts = RwLock::new(DictMap::new());
        assert_eq!(
            done.samples,
            solo_run(&key, &feasible, done.id, &dicts),
            "in-deadline request must stay bit-identical to its solo run"
        );
        let blocked = rx_blocker.recv().unwrap();
        assert!(blocked.error.is_none());
        assert_eq!(svc.metrics.shed.load(Ordering::Relaxed), 1);
        assert_eq!(svc.metrics.failed.load(Ordering::Relaxed), 1);
        svc.shutdown();
    }

    /// Satellite bugfix: error replies report real latency and count
    /// into `failed`, so the counter identity holds.
    #[test]
    fn error_replies_carry_latency_and_failed_counter() {
        let svc = Service::start(ServiceConfig::default(), Vec::new());
        let mut bad = req(4, 1);
        bad.solver = "heun".into();
        bad.nfe = 5; // odd: not representable -> invalid key
        let resp = svc.call(bad).unwrap();
        assert!(resp.error.is_some());
        assert!(
            resp.latency_ms > 0.0,
            "error replies must carry real latency, got {}",
            resp.latency_ms
        );
        assert_eq!(resp.queue_ms, resp.latency_ms);
        let m = &svc.metrics;
        assert_eq!(m.failed.load(Ordering::Relaxed), 1);
        assert_eq!(
            m.requests.load(Ordering::Relaxed),
            m.completed.load(Ordering::Relaxed)
                + m.rejected.load(Ordering::Relaxed)
                + m.failed.load(Ordering::Relaxed),
            "requests == completed + rejected + failed once drained"
        );
        svc.shutdown();
    }

    // -- graceful drain ----------------------------------------------------

    /// Two-phase drain under load: the in-flight cohort retires and
    /// replies with real samples, queued-but-unadmitted requests fail
    /// with a structured `draining` error, the counter identity balances,
    /// post-shutdown submissions are refused, and a second `shutdown`
    /// call is a no-op.
    #[test]
    fn shutdown_drains_in_flight_and_fails_queued() {
        let svc = Service::start(
            ServiceConfig {
                workers: 1,
                max_batch: 8,
                ..ServiceConfig::default()
            },
            Vec::new(),
        );
        // Long-running resident: 8 rows at NFE 2000 hold the key while
        // the requests below pile up behind the residency cap.
        let mut blocker = req(8, 1);
        blocker.nfe = 2000;
        let rx_blocker = svc.submit(blocker).unwrap();
        let t0 = Instant::now();
        while svc.metrics.ticks.load(Ordering::Relaxed) < 2 {
            assert!(t0.elapsed() < Duration::from_secs(10), "run never started");
            std::thread::sleep(Duration::from_micros(100));
        }
        // These queue behind the blocker (projected 8 + 8 > max_batch 8).
        let mut queued = Vec::new();
        for s in 0..4 {
            let mut r = req(8, 100 + s);
            r.nfe = 2000;
            queued.push(svc.submit(r).unwrap());
        }
        svc.shutdown();
        // In-flight work retired with real samples ...
        let done = rx_blocker.recv().expect("resident must get a reply");
        assert!(done.error.is_none(), "{:?}", done.error);
        assert_eq!(done.n, 8);
        // ... queued work failed with the structured draining error ...
        for rx in queued {
            let resp = rx.recv().expect("queued request must get exactly one reply");
            let err = resp.error.as_deref().expect("queued request must fail");
            assert!(
                err.starts_with("draining:"),
                "structured draining error, got: {err}"
            );
        }
        // ... and the books balance: every accepted request accounted for.
        let m = &svc.metrics;
        assert_eq!(
            m.requests.load(Ordering::Relaxed),
            m.completed.load(Ordering::Relaxed)
                + m.rejected.load(Ordering::Relaxed)
                + m.failed.load(Ordering::Relaxed),
            "requests == completed + rejected + failed after shutdown"
        );
        // New submissions are refused fast with the same structured error.
        let err = svc.submit(req(1, 9)).unwrap_err();
        assert!(err.starts_with("draining:"), "{err}");
        // Idempotent: the second call returns immediately (threads are
        // already joined and taken).
        svc.shutdown();
    }

    /// Residents that cannot finish inside the drain deadline fail with a
    /// structured error instead of holding shutdown hostage.
    #[test]
    fn shutdown_drain_deadline_bounds_exit() {
        let svc = Service::start(
            ServiceConfig {
                workers: 1,
                drain_deadline: Duration::from_millis(5),
                ..ServiceConfig::default()
            },
            Vec::new(),
        );
        let mut huge = req(64, 1);
        huge.nfe = 10_000; // far more ticks than a 5 ms deadline covers
        let rx = svc.submit(huge).unwrap();
        let t0 = Instant::now();
        while svc.metrics.ticks.load(Ordering::Relaxed) < 2 {
            assert!(t0.elapsed() < Duration::from_secs(10), "run never started");
            std::thread::sleep(Duration::from_micros(100));
        }
        let t0 = Instant::now();
        svc.shutdown();
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "shutdown must be bounded by the drain deadline"
        );
        let resp = rx.recv().expect("abandoned resident must still get a reply");
        let err = resp
            .error
            .as_deref()
            .expect("deadline-exceeded resident must fail");
        assert!(err.starts_with("draining:"), "{err}");
        let m = &svc.metrics;
        assert_eq!(
            m.requests.load(Ordering::Relaxed),
            m.completed.load(Ordering::Relaxed)
                + m.rejected.load(Ordering::Relaxed)
                + m.failed.load(Ordering::Relaxed)
        );
    }

    /// Collect-then-run drain: a submission still held by the batcher at
    /// shutdown fails with the structured draining error (not a bare
    /// disconnect), while the in-flight batch completes normally.
    #[test]
    fn shutdown_fails_queued_collect_requests() {
        let svc = Service::start(
            ServiceConfig {
                batching: Batching::CollectThenRun,
                workers: 1,
                batch_window: Duration::from_millis(200),
                ..ServiceConfig::default()
            },
            Vec::new(),
        );
        let rx_lead = svc.submit(req(4, 1)).unwrap();
        // Incompatible key: the batcher holds it for the *next* batch,
        // which shutdown ensures never forms.
        let mut other = req(4, 2);
        other.dataset = "gmm-hd64".into();
        let rx_other = svc.submit(other).unwrap();
        std::thread::sleep(Duration::from_millis(30)); // both inside the window
        svc.shutdown();
        let lead = rx_lead.recv().expect("leader must get a reply");
        assert!(lead.error.is_none(), "{:?}", lead.error);
        let held = rx_other.recv().expect("held request must get a reply");
        let err = held.error.as_deref().expect("held request must fail");
        assert!(err.starts_with("draining:"), "{err}");
        let m = &svc.metrics;
        assert_eq!(
            m.requests.load(Ordering::Relaxed),
            m.completed.load(Ordering::Relaxed)
                + m.rejected.load(Ordering::Relaxed)
                + m.failed.load(Ordering::Relaxed)
        );
    }

    /// The operator surface renders: counters and per-key series in the
    /// metrics text, coherent numbers in the health summary.
    #[test]
    fn metrics_text_and_health_render() {
        let svc = Service::start(ServiceConfig::default(), Vec::new());
        for s in 0..3 {
            let resp = svc.call(req(4, s)).unwrap();
            assert!(resp.error.is_none());
        }
        let text = svc.metrics_text();
        assert!(text.contains("pas_requests_total 3"), "{text}");
        assert!(text.contains("pas_completed_total 3"));
        assert!(text.contains("pas_serve_latency_ms_count 3"));
        assert!(text.contains("pas_key_queue_depth{key=\"gmm2d/ddim/6\"} 0"));
        assert!(text.contains("pas_key_retired_total{key=\"gmm2d/ddim/6\"} 3"));
        assert!(text.contains("pas_pool_utilization"));
        // The active kernel backend is hardware-dependent; assert the
        // series exists and carries the live selection.
        assert!(text.contains(&format!(
            "pas_kernel_backend{{backend=\"{}\"}} 1",
            crate::tensor::gemm::backend_name()
        )));
        let h = svc.health_json();
        assert_eq!(h.get("status").and_then(|s| s.as_str()), Some("ok"));
        assert_eq!(
            h.get("kernel_backend").and_then(|s| s.as_str()),
            Some(crate::tensor::gemm::backend_name())
        );
        assert_eq!(h.get("completed").and_then(|v| v.as_u64()), Some(3));
        assert_eq!(h.get("in_flight").and_then(|v| v.as_u64()), Some(0));
        assert!(h.get("latency_p50_ms").and_then(|v| v.as_f64()).unwrap() > 0.0);
        svc.shutdown();
    }
}
