//! Dense linear algebra built from scratch for the offline environment:
//! symmetric eigendecomposition (cyclic Jacobi), thin SVD via the Gram
//! trick (tailored to PAS's "few rows, huge columns" trajectory matrices),
//! modified Gram–Schmidt, Cholesky and PSD matrix square root.

use crate::tensor::gemm::{gemm_nt_dot_into, gemm_tn_acc};
use crate::tensor::{dot, norm2};

/// Symmetric eigendecomposition via cyclic Jacobi rotations.
///
/// `a` is n×n row-major symmetric (destroyed). Returns `(eigvals, eigvecs)`
/// with eigenvalues **descending** and eigenvectors as rows of the returned
/// matrix (`eigvecs[k*n..][..n]` is the k-th eigenvector).
pub fn eigh(a: &mut [f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(a.len(), n * n);
    let mut v = vec![0.0; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius norm for convergence.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[i * n + j] * a[i * n + j];
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + frob(a)) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = 0.5 * (aqq - app) / apq;
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Update rows/cols p and q of a.
                for k in 0..n {
                    let akp = a[k * n + p];
                    let akq = a[k * n + q];
                    a[k * n + p] = c * akp - s * akq;
                    a[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p * n + k];
                    let aqk = a[q * n + k];
                    a[p * n + k] = c * apk - s * aqk;
                    a[q * n + k] = s * apk + c * aqk;
                }
                // Accumulate rotations into v (rows are eigvecs^T for now).
                for k in 0..n {
                    let vkp = v[p * n + k];
                    let vkq = v[q * n + k];
                    v[p * n + k] = c * vkp - s * vkq;
                    v[q * n + k] = s * vkp + c * vkq;
                }
            }
        }
    }
    let vals: Vec<f64> = (0..n).map(|i| a[i * n + i]).collect();
    // Sort descending, carrying eigenvectors (rows of v).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| vals[j].partial_cmp(&vals[i]).unwrap());
    let mut sorted_vals = vec![0.0; n];
    let mut sorted_vecs = vec![0.0; n * n];
    for (new_i, &old_i) in order.iter().enumerate() {
        sorted_vals[new_i] = vals[old_i];
        sorted_vecs[new_i * n..(new_i + 1) * n].copy_from_slice(&v[old_i * n..(old_i + 1) * n]);
    }
    (sorted_vals, sorted_vecs)
}

fn frob(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Thin SVD of a *short-fat* row-major matrix `x` (r rows, d cols, r ≪ d)
/// via the Gram trick: eigendecompose `G = X Xᵀ` (r×r), then
/// `v_k = Xᵀ w_k / s_k`. Returns `(singular_values_desc, right_vectors)`
/// where right vectors are rows of the returned (k, d) buffer, and
/// `k = min(r, top_k)` after dropping numerically-zero singular values.
pub fn svd_right_vectors(x: &[f64], r: usize, d: usize, top_k: usize) -> (Vec<f64>, Vec<f64>) {
    assert_eq!(x.len(), r * d);
    // G = X Xᵀ, r×r: one register-tiled Gram product. Each entry is
    // reduced in `dot` order, so bits match the former per-pair loop
    // (dot is exactly symmetric, so computing both triangles directly
    // equals the old mirror-assignment).
    let mut g = vec![0.0; r * r];
    gemm_nt_dot_into(x, r, x, r, d, &mut g);
    let (vals, w) = eigh(&mut g, r);
    let smax = vals.first().copied().unwrap_or(0.0).max(0.0).sqrt();
    let tol = smax * 1e-9;
    let keep_max = r.min(top_k);
    let mut svals = Vec::new();
    // Right vectors accumulate directly into the output buffer — no
    // per-vector staging allocation; unused tail rows are truncated off.
    let mut vt = vec![0.0; keep_max * d];
    for k in 0..keep_max {
        let s = vals[k].max(0.0).sqrt();
        if s <= tol || s == 0.0 {
            break;
        }
        svals.push(s);
        // v = Xᵀ w / s : accumulate rows of X weighted by w[k].
        let wk = &w[k * r..(k + 1) * r];
        let v = &mut vt[k * d..(k + 1) * d];
        for i in 0..r {
            let c = wk[i] / s;
            if c == 0.0 {
                continue;
            }
            let row = &x[i * d..(i + 1) * d];
            for (vj, &xj) in v.iter_mut().zip(row.iter()) {
                *vj += c * xj;
            }
        }
    }
    vt.truncate(svals.len() * d);
    (svals, vt)
}

/// Modified Gram–Schmidt over row vectors of dimension `d`.
///
/// Takes candidate vectors in order, returns an orthonormal set (rows).
/// Candidates whose residual norm falls below `tol * ||candidate||` are
/// dropped (collinear with the span so far) — this mirrors Algorithm 1's
/// `Schmidt(v1, v1', v2', v3')` where `v1'` is often collinear with `v1`.
/// To always return `want` vectors, pass deterministic fallback directions;
/// here the caller (pas::pca) completes the basis with coordinate axes.
pub fn gram_schmidt(cands: &[Vec<f64>], want: usize, tol: f64) -> Vec<Vec<f64>> {
    let mut basis: Vec<Vec<f64>> = Vec::with_capacity(want);
    for cand in cands {
        if basis.len() >= want {
            break;
        }
        let cn = norm2(cand);
        if cn == 0.0 {
            continue;
        }
        let mut v = cand.clone();
        // Two MGS passes for numerical orthogonality.
        for _ in 0..2 {
            for b in &basis {
                let c = dot(&v, b);
                for (vi, bi) in v.iter_mut().zip(b.iter()) {
                    *vi -= c * bi;
                }
            }
        }
        let n = norm2(&v);
        if n > tol * cn {
            for vi in v.iter_mut() {
                *vi /= n;
            }
            basis.push(v);
        }
    }
    basis
}

/// Cholesky factorization of a PSD matrix (n×n row-major): returns lower
/// triangular L with `A = L Lᵀ`. Adds `jitter` to the diagonal as needed.
pub fn cholesky(a: &[f64], n: usize, jitter: f64) -> Result<Vec<f64>, String> {
    assert_eq!(a.len(), n * n);
    let mut l = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                let v = s + jitter;
                if v <= 0.0 {
                    return Err(format!("cholesky: non-PSD pivot {v} at {i}"));
                }
                l[i * n + i] = v.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Symmetric PSD matrix square root via eigendecomposition.
pub fn sqrtm_psd(a: &[f64], n: usize) -> Vec<f64> {
    let mut work = a.to_vec();
    let (vals, vecs) = eigh(&mut work, n);
    // sqrt(A) = Vᵀ diag(sqrt(max(vals,0))) V  with V rows = eigvecs.
    let mut scaled = vec![0.0; n * n]; // rows: sqrt(lam_k) * v_k
    for k in 0..n {
        let s = vals[k].max(0.0).sqrt();
        for j in 0..n {
            scaled[k * n + j] = s * vecs[k * n + j];
        }
    }
    // out = vecsᵀ * scaled, straight through the tiled AᵀB kernel — the
    // seed's explicit transpose staging is gone; per-entry ascending-k
    // order is unchanged, so every output bit is too.
    let mut out = vec![0.0; n * n];
    gemm_tn_acc(&vecs, n, n, &scaled, n, &mut out);
    out
}

/// Trace of a square row-major matrix.
pub fn trace(a: &[f64], n: usize) -> f64 {
    (0..n).map(|i| a[i * n + i]).sum()
}

/// Solve `A x = b` in place by Gaussian elimination with partial pivoting
/// (A destroyed, solution left in `b`). Intended for the tiny systems of
/// UniPC (n ≤ 3) but correct for any n.
pub fn solve_linear(a: &mut [f64], b: &mut [f64], n: usize) -> Result<(), String> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    for col in 0..n {
        // Pivot.
        let mut piv = col;
        for r in (col + 1)..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if a[piv * n + col].abs() < 1e-300 {
            return Err(format!("singular at column {col}"));
        }
        if piv != col {
            for c in 0..n {
                a.swap(col * n + c, piv * n + c);
            }
            b.swap(col, piv);
        }
        let diag = a[col * n + col];
        for r in (col + 1)..n {
            let f = a[r * n + col] / diag;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                a[r * n + c] -= f * a[col * n + c];
            }
            b[r] -= f * b[col];
        }
    }
    // Back substitution.
    for col in (0..n).rev() {
        let mut s = b[col];
        for c in (col + 1)..n {
            s -= a[col * n + c] * b[c];
        }
        b[col] = s / a[col * n + col];
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_into;
    use crate::util::rng::Pcg64;

    fn approx(a: f64, b: f64, eps: f64) -> bool {
        (a - b).abs() <= eps * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn eigh_diag() {
        let mut a = vec![3.0, 0.0, 0.0, 1.0];
        let (vals, vecs) = eigh(&mut a, 2);
        assert!(approx(vals[0], 3.0, 1e-12) && approx(vals[1], 1.0, 1e-12));
        // Eigvec rows orthonormal.
        assert!(approx(dot(&vecs[0..2], &vecs[0..2]), 1.0, 1e-12));
        assert!(approx(dot(&vecs[0..2], &vecs[2..4]), 0.0, 1e-12));
    }

    #[test]
    fn eigh_reconstructs() {
        let mut rng = Pcg64::seed(5);
        let n = 8;
        // Random symmetric A = B Bᵀ.
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                a[i * n + j] = dot(&b[i * n..(i + 1) * n], &b[j * n..(j + 1) * n]);
            }
        }
        let orig = a.clone();
        let (vals, vecs) = eigh(&mut a, n);
        // Reconstruct Σ_k λ_k v_k v_kᵀ.
        let mut rec = vec![0.0; n * n];
        for k in 0..n {
            let v = &vecs[k * n..(k + 1) * n];
            for i in 0..n {
                for j in 0..n {
                    rec[i * n + j] += vals[k] * v[i] * v[j];
                }
            }
        }
        for i in 0..n * n {
            assert!(approx(rec[i], orig[i], 1e-8), "{} vs {}", rec[i], orig[i]);
        }
        // Descending order.
        for k in 1..n {
            assert!(vals[k - 1] >= vals[k] - 1e-12);
        }
    }

    #[test]
    fn svd_known_rank() {
        // X rows: e1*2, e2*3, e1*2 (rank 2 in d=5).
        let d = 5;
        let mut x = vec![0.0; 3 * d];
        x[0] = 2.0;
        x[d + 1] = 3.0;
        x[2 * d] = 2.0;
        let (svals, vt) = svd_right_vectors(&x, 3, d, 3);
        assert_eq!(svals.len(), 2, "rank should be 2, got {svals:?}");
        // Singular values: 3 (the e2 row) and sqrt(2² + 2²) = sqrt(8).
        assert!(approx(svals[0], 3.0, 1e-9));
        assert!(approx(svals[1], (8.0f64).sqrt(), 1e-9));
        // Top right vector = ±e2, second = ±e1.
        assert!(vt[1].abs() > 0.999);
        assert!(vt[d].abs() > 0.999);
    }

    #[test]
    fn svd_matches_reconstruction() {
        let mut rng = Pcg64::seed(17);
        let (r, d) = (6, 40);
        let x: Vec<f64> = (0..r * d).map(|_| rng.normal()).collect();
        let (svals, vt) = svd_right_vectors(&x, r, d, r);
        assert_eq!(svals.len(), r);
        // Right vectors orthonormal.
        for i in 0..r {
            for j in 0..r {
                let g = dot(&vt[i * d..(i + 1) * d], &vt[j * d..(j + 1) * d]);
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(approx(g, want, 1e-8), "g[{i}{j}]={g}");
            }
        }
        // Energy preserved: Σ s² = ||X||_F².
        let e: f64 = svals.iter().map(|s| s * s).sum();
        assert!(approx(e, dot(&x, &x), 1e-8));
    }

    #[test]
    fn gram_schmidt_drops_collinear() {
        let v1 = vec![1.0, 0.0, 0.0];
        let v1_dup = vec![2.0, 0.0, 0.0];
        let v2 = vec![1.0, 1.0, 0.0];
        let basis = gram_schmidt(&[v1, v1_dup, v2], 4, 1e-8);
        assert_eq!(basis.len(), 2);
        assert!(approx(dot(&basis[0], &basis[1]), 0.0, 1e-12));
    }

    #[test]
    fn cholesky_roundtrip() {
        let a = vec![4.0, 2.0, 2.0, 3.0];
        let l = cholesky(&a, 2, 0.0).unwrap();
        // L Lᵀ == A
        let mut rec = vec![0.0; 4];
        for i in 0..2 {
            for j in 0..2 {
                for k in 0..2 {
                    rec[i * 2 + j] += l[i * 2 + k] * l[j * 2 + k];
                }
            }
        }
        for i in 0..4 {
            assert!(approx(rec[i], a[i], 1e-12));
        }
    }

    #[test]
    fn sqrtm_squares_back() {
        let a = vec![2.0, 1.0, 1.0, 2.0];
        let s = sqrtm_psd(&a, 2);
        let mut sq = vec![0.0; 4];
        matmul_into(&s, 2, 2, &s, 2, &mut sq);
        for i in 0..4 {
            assert!(approx(sq[i], a[i], 1e-10), "{:?}", sq);
        }
    }

    #[test]
    fn trace_works() {
        assert_eq!(trace(&[1.0, 5.0, 5.0, 2.0], 2), 3.0);
    }
}
