//! Dense linear algebra built from scratch for the offline environment:
//! symmetric eigendecomposition (cyclic Jacobi), thin SVD via the Gram
//! trick (tailored to PAS's "few rows, huge columns" trajectory matrices),
//! modified Gram–Schmidt, Cholesky and PSD matrix square root.

use crate::tensor::gemm::{gemm_nt_dot_into, gemm_tn_acc};
use crate::tensor::{dot, norm2};

/// Symmetric eigendecomposition via cyclic Jacobi rotations.
///
/// `a` is n×n row-major symmetric (destroyed). Returns `(eigvals, eigvecs)`
/// with eigenvalues **descending** and eigenvectors as rows of the returned
/// matrix (`eigvecs[k*n..][..n]` is the k-th eigenvector).
///
/// Allocating convenience over [`eigh_into`]; hot-path callers (the PAS
/// basis extraction) hold an [`EighScratch`] instead.
pub fn eigh(a: &mut [f64], n: usize) -> (Vec<f64>, Vec<f64>) {
    let mut vals = vec![0.0; n];
    let mut vecs = vec![0.0; n * n];
    let mut scratch = EighScratch::default();
    eigh_into(a, n, &mut vals, &mut vecs, &mut scratch);
    (vals, vecs)
}

/// Reusable workspace for [`eigh_into`] / [`svd_right_vectors_into`]:
/// the unsorted rotation accumulator and the sort permutation. Buffers
/// grow on demand and are never shrunk, so steady-state reuse performs
/// zero heap allocations.
#[derive(Default)]
pub struct EighScratch {
    rot: Vec<f64>,
    order: Vec<usize>,
}

impl EighScratch {
    fn ensure(&mut self, n: usize) {
        if self.rot.len() < n * n {
            self.rot.resize(n * n, 0.0);
        }
        if self.order.len() < n {
            self.order.resize(n, 0);
        }
    }
}

/// [`eigh`] into caller-owned buffers: `vals` (≥ n) and `vecs` (≥ n·n)
/// receive the descending eigenvalues / eigenvector rows; temporaries come
/// from `scratch`. Bit-identical to [`eigh`] — same rotation sequence, and
/// the descending sort is a stable insertion sort, which reproduces the
/// stable `sort_by` of the allocating form exactly (equal eigenvalues keep
/// their pre-sort order).
pub fn eigh_into(
    a: &mut [f64],
    n: usize,
    vals: &mut [f64],
    vecs: &mut [f64],
    scratch: &mut EighScratch,
) {
    assert_eq!(a.len(), n * n);
    assert!(vals.len() >= n && vecs.len() >= n * n);
    scratch.ensure(n);
    let v = &mut scratch.rot[..n * n];
    v.fill(0.0);
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    let max_sweeps = 30;
    for _sweep in 0..max_sweeps {
        // Off-diagonal Frobenius norm for convergence.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[i * n + j] * a[i * n + j];
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + frob(a)) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[p * n + q];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[p * n + p];
                let aqq = a[q * n + q];
                let theta = 0.5 * (aqq - app) / apq;
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    -1.0 / (-theta + (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Update rows/cols p and q of a.
                for k in 0..n {
                    let akp = a[k * n + p];
                    let akq = a[k * n + q];
                    a[k * n + p] = c * akp - s * akq;
                    a[k * n + q] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[p * n + k];
                    let aqk = a[q * n + k];
                    a[p * n + k] = c * apk - s * aqk;
                    a[q * n + k] = s * apk + c * aqk;
                }
                // Accumulate rotations into v (rows are eigvecs^T for now).
                for k in 0..n {
                    let vkp = v[p * n + k];
                    let vkq = v[q * n + k];
                    v[p * n + k] = c * vkp - s * vkq;
                    v[q * n + k] = s * vkp + c * vkq;
                }
            }
        }
    }
    // Sort descending, carrying eigenvectors (rows of v). Stable insertion
    // sort over the index permutation: for a total-order comparator a
    // stable sort's output is unique, so this matches the previous
    // `Vec::sort_by` bit for bit.
    let order = &mut scratch.order[..n];
    for (i, o) in order.iter_mut().enumerate() {
        *o = i;
    }
    let diag = |i: usize| a[i * n + i];
    for i in 1..n {
        let oi = order[i];
        let key = diag(oi);
        let mut j = i;
        while j > 0 && diag(order[j - 1]) < key {
            order[j] = order[j - 1];
            j -= 1;
        }
        order[j] = oi;
    }
    for (new_i, &old_i) in order.iter().enumerate() {
        vals[new_i] = diag(old_i);
        vecs[new_i * n..(new_i + 1) * n].copy_from_slice(&v[old_i * n..(old_i + 1) * n]);
    }
}

fn frob(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Thin SVD of a *short-fat* row-major matrix `x` (r rows, d cols, r ≪ d)
/// via the Gram trick: eigendecompose `G = X Xᵀ` (r×r), then
/// `v_k = Xᵀ w_k / s_k`. Returns `(singular_values_desc, right_vectors)`
/// where right vectors are rows of the returned (k, d) buffer, and
/// `k = min(r, top_k)` after dropping numerically-zero singular values.
///
/// Allocating convenience over [`svd_right_vectors_into`].
pub fn svd_right_vectors(x: &[f64], r: usize, d: usize, top_k: usize) -> (Vec<f64>, Vec<f64>) {
    let keep_max = r.min(top_k);
    let mut svals = vec![0.0; keep_max];
    let mut vt = vec![0.0; keep_max * d];
    let mut scratch = SvdScratch::default();
    let kept = svd_right_vectors_into(x, r, d, top_k, &mut scratch, &mut svals, &mut vt);
    svals.truncate(kept);
    vt.truncate(kept * d);
    (svals, vt)
}

/// Reusable workspace for [`svd_right_vectors_into`]: the Gram matrix,
/// its eigendecomposition outputs, and the [`EighScratch`] underneath.
/// Grows on demand, never shrinks — steady-state reuse allocates nothing.
#[derive(Default)]
pub struct SvdScratch {
    g: Vec<f64>,
    w: Vec<f64>,
    vals: Vec<f64>,
    eigh: EighScratch,
}

impl SvdScratch {
    fn ensure(&mut self, r: usize) {
        if self.g.len() < r * r {
            self.g.resize(r * r, 0.0);
        }
        if self.w.len() < r * r {
            self.w.resize(r * r, 0.0);
        }
        if self.vals.len() < r {
            self.vals.resize(r, 0.0);
        }
    }
}

/// [`svd_right_vectors`] into caller-owned buffers: `svals` (≥ min(r,
/// top_k)) and `vt` (≥ min(r, top_k)·d) receive the kept singular values /
/// right-vector rows; returns how many were kept. Bit-identical to the
/// allocating form (same Gram kernel, same [`eigh_into`], same per-vector
/// accumulation order).
pub fn svd_right_vectors_into(
    x: &[f64],
    r: usize,
    d: usize,
    top_k: usize,
    scratch: &mut SvdScratch,
    svals: &mut [f64],
    vt: &mut [f64],
) -> usize {
    assert_eq!(x.len(), r * d);
    let keep_max = r.min(top_k);
    assert!(svals.len() >= keep_max && vt.len() >= keep_max * d);
    scratch.ensure(r);
    // G = X Xᵀ, r×r: one register-tiled Gram product. Each entry is
    // reduced in `dot` order, so bits match the former per-pair loop
    // (dot is exactly symmetric, so computing both triangles directly
    // equals the old mirror-assignment).
    let g = &mut scratch.g[..r * r];
    gemm_nt_dot_into(x, r, x, r, d, g);
    let vals = &mut scratch.vals[..r];
    let w = &mut scratch.w[..r * r];
    eigh_into(g, r, vals, w, &mut scratch.eigh);
    let smax = vals.first().copied().unwrap_or(0.0).max(0.0).sqrt();
    let tol = smax * 1e-9;
    // Right vectors accumulate directly into the output buffer; unused
    // tail rows stay untouched (the caller sizes reads by the count).
    vt[..keep_max * d].fill(0.0);
    let mut kept = 0usize;
    for k in 0..keep_max {
        let s = vals[k].max(0.0).sqrt();
        if s <= tol || s == 0.0 {
            break;
        }
        svals[kept] = s;
        kept += 1;
        // v = Xᵀ w / s : accumulate rows of X weighted by w[k].
        let wk = &w[k * r..(k + 1) * r];
        let v = &mut vt[k * d..(k + 1) * d];
        for i in 0..r {
            let c = wk[i] / s;
            if c == 0.0 {
                continue;
            }
            let row = &x[i * d..(i + 1) * d];
            for (vj, &xj) in v.iter_mut().zip(row.iter()) {
                *vj += c * xj;
            }
        }
    }
    kept
}

/// Modified Gram–Schmidt over row vectors of dimension `d`.
///
/// Takes candidate vectors in order, returns an orthonormal set (rows).
/// Candidates whose residual norm falls below `tol * ||candidate||` are
/// dropped (collinear with the span so far) — this mirrors Algorithm 1's
/// `Schmidt(v1, v1', v2', v3')` where `v1'` is often collinear with `v1`.
/// To always return `want` vectors, pass deterministic fallback directions;
/// here the caller (pas::pca) completes the basis with coordinate axes.
///
/// Allocating convenience over [`gram_schmidt_into`].
pub fn gram_schmidt(cands: &[Vec<f64>], want: usize, tol: f64) -> Vec<Vec<f64>> {
    let d = cands.first().map_or(0, |c| c.len());
    let mut flat = Vec::with_capacity(cands.len() * d);
    for c in cands {
        assert_eq!(c.len(), d, "gram_schmidt: ragged candidates");
        flat.extend_from_slice(c);
    }
    let mut out = vec![0.0; want * d];
    let mut work = vec![0.0; d];
    let k = gram_schmidt_into(&flat, cands.len(), d, want, tol, &mut out, &mut work);
    (0..k).map(|i| out[i * d..(i + 1) * d].to_vec()).collect()
}

/// [`gram_schmidt`] over a flat `(n_cands, d)` candidate matrix, writing
/// the accepted orthonormal rows into `out` (≥ want·d) and using `work`
/// (≥ d) as the residual buffer. Returns the number of rows written.
/// Bit-identical to the allocating form: per candidate the same copy, the
/// same two MGS passes against the accepted rows in order, the same
/// norm/tolerance arithmetic.
pub fn gram_schmidt_into(
    cands: &[f64],
    n_cands: usize,
    d: usize,
    want: usize,
    tol: f64,
    out: &mut [f64],
    work: &mut [f64],
) -> usize {
    assert_eq!(cands.len(), n_cands * d);
    assert!(out.len() >= want * d && work.len() >= d);
    let v = &mut work[..d];
    let mut kb = 0usize;
    for ci in 0..n_cands {
        if kb >= want {
            break;
        }
        let cand = &cands[ci * d..(ci + 1) * d];
        let cn = norm2(cand);
        if cn == 0.0 {
            continue;
        }
        v.copy_from_slice(cand);
        // Two MGS passes for numerical orthogonality.
        for _ in 0..2 {
            for bi in 0..kb {
                let b = &out[bi * d..(bi + 1) * d];
                let c = dot(v, b);
                for (vi, bv) in v.iter_mut().zip(b.iter()) {
                    *vi -= c * bv;
                }
            }
        }
        let n = norm2(v);
        if n > tol * cn {
            for vi in v.iter_mut() {
                *vi /= n;
            }
            out[kb * d..(kb + 1) * d].copy_from_slice(v);
            kb += 1;
        }
    }
    kb
}

/// Cholesky factorization of a PSD matrix (n×n row-major): returns lower
/// triangular L with `A = L Lᵀ`. Adds `jitter` to the diagonal as needed.
pub fn cholesky(a: &[f64], n: usize, jitter: f64) -> Result<Vec<f64>, String> {
    assert_eq!(a.len(), n * n);
    let mut l = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                let v = s + jitter;
                if v <= 0.0 {
                    return Err(format!("cholesky: non-PSD pivot {v} at {i}"));
                }
                l[i * n + i] = v.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Symmetric PSD matrix square root via eigendecomposition.
pub fn sqrtm_psd(a: &[f64], n: usize) -> Vec<f64> {
    let mut work = a.to_vec();
    let (vals, vecs) = eigh(&mut work, n);
    // sqrt(A) = Vᵀ diag(sqrt(max(vals,0))) V  with V rows = eigvecs.
    let mut scaled = vec![0.0; n * n]; // rows: sqrt(lam_k) * v_k
    for k in 0..n {
        let s = vals[k].max(0.0).sqrt();
        for j in 0..n {
            scaled[k * n + j] = s * vecs[k * n + j];
        }
    }
    // out = vecsᵀ * scaled, straight through the tiled AᵀB kernel — the
    // seed's explicit transpose staging is gone; per-entry ascending-k
    // order is unchanged, so every output bit is too.
    let mut out = vec![0.0; n * n];
    gemm_tn_acc(&vecs, n, n, &scaled, n, &mut out);
    out
}

/// Trace of a square row-major matrix.
pub fn trace(a: &[f64], n: usize) -> f64 {
    (0..n).map(|i| a[i * n + i]).sum()
}

/// Solve `A x = b` in place by Gaussian elimination with partial pivoting
/// (A destroyed, solution left in `b`). Intended for the tiny systems of
/// UniPC (n ≤ 3) but correct for any n.
pub fn solve_linear(a: &mut [f64], b: &mut [f64], n: usize) -> Result<(), String> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    for col in 0..n {
        // Pivot.
        let mut piv = col;
        for r in (col + 1)..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if a[piv * n + col].abs() < 1e-300 {
            return Err(format!("singular at column {col}"));
        }
        if piv != col {
            for c in 0..n {
                a.swap(col * n + c, piv * n + c);
            }
            b.swap(col, piv);
        }
        let diag = a[col * n + col];
        for r in (col + 1)..n {
            let f = a[r * n + col] / diag;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                a[r * n + c] -= f * a[col * n + c];
            }
            b[r] -= f * b[col];
        }
    }
    // Back substitution.
    for col in (0..n).rev() {
        let mut s = b[col];
        for c in (col + 1)..n {
            s -= a[col * n + c] * b[c];
        }
        b[col] = s / a[col * n + col];
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_into;
    use crate::util::rng::Pcg64;

    fn approx(a: f64, b: f64, eps: f64) -> bool {
        (a - b).abs() <= eps * (1.0 + a.abs().max(b.abs()))
    }

    #[test]
    fn eigh_diag() {
        let mut a = vec![3.0, 0.0, 0.0, 1.0];
        let (vals, vecs) = eigh(&mut a, 2);
        assert!(approx(vals[0], 3.0, 1e-12) && approx(vals[1], 1.0, 1e-12));
        // Eigvec rows orthonormal.
        assert!(approx(dot(&vecs[0..2], &vecs[0..2]), 1.0, 1e-12));
        assert!(approx(dot(&vecs[0..2], &vecs[2..4]), 0.0, 1e-12));
    }

    #[test]
    fn eigh_reconstructs() {
        let mut rng = Pcg64::seed(5);
        let n = 8;
        // Random symmetric A = B Bᵀ.
        let b: Vec<f64> = (0..n * n).map(|_| rng.normal()).collect();
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                a[i * n + j] = dot(&b[i * n..(i + 1) * n], &b[j * n..(j + 1) * n]);
            }
        }
        let orig = a.clone();
        let (vals, vecs) = eigh(&mut a, n);
        // Reconstruct Σ_k λ_k v_k v_kᵀ.
        let mut rec = vec![0.0; n * n];
        for k in 0..n {
            let v = &vecs[k * n..(k + 1) * n];
            for i in 0..n {
                for j in 0..n {
                    rec[i * n + j] += vals[k] * v[i] * v[j];
                }
            }
        }
        for i in 0..n * n {
            assert!(approx(rec[i], orig[i], 1e-8), "{} vs {}", rec[i], orig[i]);
        }
        // Descending order.
        for k in 1..n {
            assert!(vals[k - 1] >= vals[k] - 1e-12);
        }
    }

    #[test]
    fn svd_known_rank() {
        // X rows: e1*2, e2*3, e1*2 (rank 2 in d=5).
        let d = 5;
        let mut x = vec![0.0; 3 * d];
        x[0] = 2.0;
        x[d + 1] = 3.0;
        x[2 * d] = 2.0;
        let (svals, vt) = svd_right_vectors(&x, 3, d, 3);
        assert_eq!(svals.len(), 2, "rank should be 2, got {svals:?}");
        // Singular values: 3 (the e2 row) and sqrt(2² + 2²) = sqrt(8).
        assert!(approx(svals[0], 3.0, 1e-9));
        assert!(approx(svals[1], (8.0f64).sqrt(), 1e-9));
        // Top right vector = ±e2, second = ±e1.
        assert!(vt[1].abs() > 0.999);
        assert!(vt[d].abs() > 0.999);
    }

    #[test]
    fn svd_matches_reconstruction() {
        let mut rng = Pcg64::seed(17);
        let (r, d) = (6, 40);
        let x: Vec<f64> = (0..r * d).map(|_| rng.normal()).collect();
        let (svals, vt) = svd_right_vectors(&x, r, d, r);
        assert_eq!(svals.len(), r);
        // Right vectors orthonormal.
        for i in 0..r {
            for j in 0..r {
                let g = dot(&vt[i * d..(i + 1) * d], &vt[j * d..(j + 1) * d]);
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(approx(g, want, 1e-8), "g[{i}{j}]={g}");
            }
        }
        // Energy preserved: Σ s² = ||X||_F².
        let e: f64 = svals.iter().map(|s| s * s).sum();
        assert!(approx(e, dot(&x, &x), 1e-8));
    }

    /// The `_into` forms are bit-identical to the allocating ones, and
    /// their scratch is cleanly reusable across different shapes.
    #[test]
    fn into_forms_match_allocating_bitwise() {
        let mut rng = Pcg64::seed(23);
        let mut eigh_scratch = EighScratch::default();
        let mut svd_scratch = SvdScratch::default();
        for &(r, d) in &[(6usize, 40usize), (3, 9), (8, 17)] {
            // eigh vs eigh_into on a random symmetric matrix.
            let b: Vec<f64> = (0..r * r).map(|_| rng.normal()).collect();
            let mut a = vec![0.0; r * r];
            for i in 0..r {
                for j in 0..r {
                    a[i * r + j] = dot(&b[i * r..(i + 1) * r], &b[j * r..(j + 1) * r]);
                }
            }
            let mut a2 = a.clone();
            let (vals, vecs) = eigh(&mut a, r);
            let mut vals2 = vec![0.0; r];
            let mut vecs2 = vec![0.0; r * r];
            eigh_into(&mut a2, r, &mut vals2, &mut vecs2, &mut eigh_scratch);
            assert_eq!(vals, vals2);
            assert_eq!(vecs, vecs2);

            // svd vs svd_into on a random short-fat matrix.
            let x: Vec<f64> = (0..r * d).map(|_| rng.normal()).collect();
            let (svals, vt) = svd_right_vectors(&x, r, d, r);
            let mut svals2 = vec![0.0; r];
            let mut vt2 = vec![0.0; r * d];
            let kept = svd_right_vectors_into(&x, r, d, r, &mut svd_scratch, &mut svals2, &mut vt2);
            assert_eq!(kept, svals.len());
            assert_eq!(&svals2[..kept], &svals[..]);
            assert_eq!(&vt2[..kept * d], &vt[..]);
        }
    }

    #[test]
    fn gram_schmidt_into_matches_allocating() {
        let mut rng = Pcg64::seed(29);
        let d = 12;
        let cands: Vec<Vec<f64>> = (0..5).map(|_| rng.normal_vec(d)).collect();
        let want = 4;
        let basis = gram_schmidt(&cands, want, 1e-7);
        let flat: Vec<f64> = cands.iter().flatten().copied().collect();
        let mut out = vec![0.0; want * d];
        let mut work = vec![0.0; d];
        let k = gram_schmidt_into(&flat, cands.len(), d, want, 1e-7, &mut out, &mut work);
        assert_eq!(k, basis.len());
        for (i, b) in basis.iter().enumerate() {
            assert_eq!(&out[i * d..(i + 1) * d], &b[..]);
        }
    }

    #[test]
    fn gram_schmidt_drops_collinear() {
        let v1 = vec![1.0, 0.0, 0.0];
        let v1_dup = vec![2.0, 0.0, 0.0];
        let v2 = vec![1.0, 1.0, 0.0];
        let basis = gram_schmidt(&[v1, v1_dup, v2], 4, 1e-8);
        assert_eq!(basis.len(), 2);
        assert!(approx(dot(&basis[0], &basis[1]), 0.0, 1e-12));
    }

    #[test]
    fn cholesky_roundtrip() {
        let a = vec![4.0, 2.0, 2.0, 3.0];
        let l = cholesky(&a, 2, 0.0).unwrap();
        // L Lᵀ == A
        let mut rec = vec![0.0; 4];
        for i in 0..2 {
            for j in 0..2 {
                for k in 0..2 {
                    rec[i * 2 + j] += l[i * 2 + k] * l[j * 2 + k];
                }
            }
        }
        for i in 0..4 {
            assert!(approx(rec[i], a[i], 1e-12));
        }
    }

    #[test]
    fn sqrtm_squares_back() {
        let a = vec![2.0, 1.0, 1.0, 2.0];
        let s = sqrtm_psd(&a, 2);
        let mut sq = vec![0.0; 4];
        matmul_into(&s, 2, 2, &s, 2, &mut sq);
        for i in 0..4 {
            assert!(approx(sq[i], a[i], 1e-10), "{:?}", sq);
        }
    }

    #[test]
    fn trace_works() {
        assert_eq!(trace(&[1.0, 5.0, 5.0, 2.0], 2), 3.0);
    }
}
