//! Minimal JSON reader/writer.
//!
//! The crate is built fully offline against a vendored dependency set that
//! has no serde, so we carry a small, well-tested JSON implementation of our
//! own. It covers everything the system needs: coordinate dictionaries,
//! server wire protocol, experiment result files.
//!
//! **Caveat for callers serializing floats:** JSON has no token for
//! NaN/inf, so the writer emits `null` for a non-finite [`Json::Num`].
//! That is the right call for result files (lossy but valid JSON), but on
//! the serving wire it would turn numeric corruption into a structurally
//! valid "success" — producers of wire replies must check finiteness
//! *before* building the value (see `server::protocol::response_json`).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Non-integer numbers are kept as f64; non-negative
/// integer tokens are kept exactly as [`Json::UInt`] so 64-bit payload
/// fields (request seeds, ids) survive parsing bit-for-bit — an f64 can
/// only represent integers exactly up to 2^53.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    /// A non-negative integer token, preserved exactly (full u64 range).
    UInt(u64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Numeric equality bridges the two number variants: `Num(6.0)` and
/// `UInt(6)` compare equal, so value round-trips through serialization
/// (which prints both as `6`) stay reflexive. The bridge is *exact*: a
/// `Num` only equals a `UInt` when the f64 is an integer inside f64's
/// exact range (≤ 2^53) — comparing through a lossy u64→f64 cast would
/// make distinct values above 2^53 "equal" and break transitivity.
impl PartialEq for Json {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Json::Null, Json::Null) => true,
            (Json::Bool(a), Json::Bool(b)) => a == b,
            (Json::Num(a), Json::Num(b)) => a == b,
            (Json::UInt(a), Json::UInt(b)) => a == b,
            (Json::Num(a), Json::UInt(b)) | (Json::UInt(b), Json::Num(a)) => {
                Json::Num(*a).as_u64() == Some(*b)
            }
            (Json::Str(a), Json::Str(b)) => a == b,
            (Json::Arr(a), Json::Arr(b)) => a == b,
            (Json::Obj(a), Json::Obj(b)) => a == b,
            _ => false,
        }
    }
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, v: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v);
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            // Lossy above 2^53 — callers needing exactness use `as_u64`.
            Json::UInt(u) => Some(*u as f64),
            _ => None,
        }
    }

    /// Exact non-negative integer value. `UInt` tokens return their full
    /// u64 range; `Num` qualifies only when it is integral, non-negative
    /// and within f64's exact-integer range (≤ 2^53). Negative numbers,
    /// fractions, and anything non-numeric return `None`.
    pub fn as_u64(&self) -> Option<u64> {
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
        match self {
            Json::UInt(u) => Some(*u),
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x <= MAX_EXACT => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|u| usize::try_from(u).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Remove and return a field from an object. `None` when `self` is
    /// not an object or the key is absent. Used by the manifest's
    /// self-checksum: strip the embedded checksum, re-serialize the rest
    /// canonically, compare.
    pub fn take(&mut self, key: &str) -> Option<Json> {
        match self {
            Json::Obj(m) => m.remove(key),
            _ => None,
        }
    }

    pub fn from_f64_slice(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn to_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_f64()).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{}", x);
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::UInt(u) => {
                let _ = write!(out, "{}", u);
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document. Returns Err with a byte offset on failure.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        let tok = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| format!("bad number at byte {start}"))?;
        // A pure non-negative integer token parses exactly (full u64
        // range); everything else — fractions, exponents, negatives, and
        // integers beyond u64 — falls back to f64.
        if !tok.is_empty() && tok.bytes().all(|c| c.is_ascii_digit()) {
            if let Ok(u) = tok.parse::<u64>() {
                return Ok(Json::UInt(u));
            }
        }
        tok.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                Some(_) => {
                    // Copy a UTF-8 run verbatim.
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|_| "bad utf8")?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let mut o = Json::obj();
        o.set("a", Json::Num(1.5))
            .set("b", Json::Str("hi \"x\"\n".into()))
            .set("c", Json::Arr(vec![Json::Bool(true), Json::Null]));
        let s = o.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(o, back);
    }

    #[test]
    fn parse_numbers() {
        for (s, v) in [
            ("0", 0.0),
            ("-3.5", -3.5),
            ("1e3", 1000.0),
            ("2.5E-2", 0.025),
        ] {
            assert_eq!(Json::parse(s).unwrap(), Json::Num(v));
        }
    }

    #[test]
    fn parse_nested() {
        let s = r#"{"steps": {"6": [1.0, 0.0, -0.5, 0]}, "name": "ddim"}"#;
        let j = Json::parse(s).unwrap();
        assert_eq!(j.get("name").unwrap().as_str().unwrap(), "ddim");
        let steps = j.get("steps").unwrap();
        let c = steps.get("6").unwrap().to_f64_vec().unwrap();
        assert_eq!(c, vec![1.0, 0.0, -0.5, 0.0]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(6.0).to_string(), "6");
        assert_eq!(Json::Num(6.5).to_string(), "6.5");
        assert_eq!(Json::UInt(6).to_string(), "6");
        assert_eq!(Json::UInt(u64::MAX).to_string(), "18446744073709551615");
    }

    #[test]
    fn integer_tokens_parse_exactly() {
        // Below, at, and above the f64 exact-integer boundary (2^53), up
        // to u64::MAX: every one must round-trip bit-for-bit.
        for u in [
            0u64,
            (1 << 53) - 1,
            (1 << 53) + 1,
            u64::MAX - 1,
            u64::MAX,
        ] {
            let j = Json::parse(&u.to_string()).unwrap();
            assert_eq!(j.as_u64(), Some(u), "token {u}");
            assert_eq!(Json::parse(&j.to_string()).unwrap().as_u64(), Some(u));
        }
        // Integral f64s stay usable through the exact accessor...
        assert_eq!(Json::Num(12.0).as_u64(), Some(12));
        // ...but negatives, fractions, exponents and >u64 tokens do not.
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("18446744073709551616").unwrap().as_u64(), None);
        // Exponent tokens go through f64: exact only within 2^53.
        assert_eq!(Json::parse("1e3").unwrap().as_u64(), Some(1000));
        assert_eq!(Json::parse("1e18").unwrap().as_u64(), None);
        // Cross-variant numeric equality is exact: equal only where the
        // u64 ↔ f64 mapping is injective (≤ 2^53), so PartialEq stays
        // transitive above the boundary.
        assert_eq!(Json::parse("6").unwrap(), Json::Num(6.0));
        assert_eq!(Json::UInt(1 << 53), Json::Num(9_007_199_254_740_992.0));
        assert_ne!(Json::UInt((1 << 53) + 1), Json::Num(9_007_199_254_740_992.0));
        assert_ne!(
            Json::parse("18446744073709551616").unwrap(), // 2^64: a Num
            Json::UInt(u64::MAX)
        );
    }

    #[test]
    fn obj_access_and_take() {
        let mut o = Json::obj();
        o.set("keep", Json::UInt(1)).set("drop", Json::UInt(2));
        assert_eq!(o.as_obj().unwrap().len(), 2);
        assert_eq!(o.take("drop"), Some(Json::UInt(2)));
        assert_eq!(o.take("drop"), None);
        assert_eq!(o.to_string(), "{\"keep\":1}");
        assert_eq!(Json::Null.as_obj(), None);
        assert_eq!(Json::Arr(vec![]).take("x"), None);
    }

    #[test]
    fn f64_slice_roundtrip() {
        let xs = [1.0, -2.25, 0.0, 1e-9];
        let j = Json::from_f64_slice(&xs);
        assert_eq!(j.to_f64_vec().unwrap(), xs.to_vec());
    }

    /// Documented lossy edge: non-finite floats serialize as `null`
    /// (JSON has no NaN/inf token). Wire-reply producers rely on this
    /// being *exactly* `null` — never a bare `NaN` that would corrupt
    /// the line's parseability — and guard finiteness upstream.
    #[test]
    fn non_finite_num_serializes_as_null() {
        for v in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            assert_eq!(Json::Num(v).to_string(), "null");
        }
        assert_eq!(
            Json::from_f64_slice(&[1.0, f64::NAN]).to_string(),
            "[1,null]"
        );
        // And the emitted line stays valid JSON end to end.
        assert!(Json::parse("[1,null]").is_ok());
    }
}
