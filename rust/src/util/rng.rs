//! Deterministic PCG64 RNG with Gaussian sampling.
//!
//! The whole reproduction is seed-deterministic: every experiment runner
//! derives child seeds from a root seed via `Pcg64::derive`, so tables are
//! bit-reproducible across runs without any external `rand` dependency.

/// PCG-XSL-RR 128/64 generator (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached spare normal from Box–Muller.
    spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with a 64-bit seed and the default stream.
    pub fn seed(seed: u64) -> Self {
        Self::seed_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Seed with an explicit stream id (sequence selector).
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg64 {
            state: 0,
            inc: ((stream as u128) << 1) | 1,
            spare: None,
        };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        // Warm up to decorrelate small seeds.
        for _ in 0..4 {
            rng.next_u64();
        }
        rng
    }

    /// Derive an independent child generator (used to give every
    /// trajectory/experiment its own stream).
    pub fn derive(&mut self, tag: u64) -> Pcg64 {
        let s = self.next_u64() ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        Pcg64::seed_stream(s, tag.wrapping_add(0x853c_49e6_748f_ea9b))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style unbiased bounded sampling would be overkill here:
        // n << 2^64 so modulo bias is < 2^-50.
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u1 = self.uniform();
            let u2 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let th = 2.0 * std::f64::consts::PI * u2;
            self.spare = Some(r * th.sin());
            return r * th.cos();
        }
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.normal();
        }
    }

    /// Vector of `n` standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v);
        v
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle of indices 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx = Vec::new();
        self.permutation_into(n, &mut idx);
        idx
    }

    /// [`Self::permutation`] into a reused buffer (cleared and refilled):
    /// identical RNG consumption and output, zero allocations once the
    /// buffer's capacity has reached `n`. The PAS trainer draws one of
    /// these per SGD epoch.
    pub fn permutation_into(&mut self, n: usize, out: &mut Vec<usize>) {
        out.clear();
        out.extend(0..n);
        for i in (1..n).rev() {
            let j = self.below(i + 1);
            out.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::seed(42);
        let mut b = Pcg64::seed(43);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_range_and_moments() {
        let mut r = Pcg64::seed(7);
        let n = 20_000;
        let mut s = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            s += u;
        }
        assert!((s / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let m = crate::util::mean(&xs);
        let sd = crate::util::std_dev(&xs);
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((sd - 1.0).abs() < 0.02, "sd {sd}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Pcg64::seed(11);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let frac0 = counts[0] as f64 / 40_000.0;
        assert!((frac0 - 0.25).abs() < 0.02, "{frac0}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut r = Pcg64::seed(3);
        let mut p = r.permutation(100);
        p.sort_unstable();
        assert_eq!(p, (0..100).collect::<Vec<_>>());
    }
}
