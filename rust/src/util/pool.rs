//! Dependency-free scoped thread pool for the batch-parallel hot loops.
//!
//! The sampling engine ([`crate::solvers::engine`]), the analytic score
//! ([`crate::score::analytic`]) and the PAS corrector
//! ([`crate::pas::correct`]) all shard *rows of a batch* across cores.
//! Spawning OS threads per step (what the seed code did inside
//! `AnalyticEps::eval_batch`) costs tens of microseconds per parallel
//! region; at 10 NFE × 3 regions/step that overhead rivals the math. This
//! pool keeps workers parked on a condvar instead, and a dispatch costs
//! two mutex acquisitions and **zero heap allocations** — the property the
//! `pas_overhead` bench's allocation counter verifies for the serving
//! path.
//!
//! # Semantics
//!
//! [`Pool::run`]`(total, f)` executes `f(0)`, …, `f(total - 1)` across the
//! caller plus the parked workers and returns when all indices are done —
//! the same contract as spawning inside `std::thread::scope`, which is why
//! borrowed (non-`'static`) closures are sound here: the closure pointer
//! handed to the workers never outlives the call (the lifetime is erased
//! with a `transmute`, and `run` blocks until every worker finished).
//! Panics in tasks are caught, remaining indices are drained, and the
//! panic is re-raised on the caller thread.
//!
//! Nested calls (a task calling `run` again, e.g. a sharded solver step
//! whose model eval is itself parallel) execute inline on the calling
//! thread — no deadlocks, no oversubscription.
//!
//! # Determinism
//!
//! The pool only ever hands out *index sets*; [`Pool::par_rows`] splits a
//! batch into contiguous row ranges. Since every caller in this crate
//! keeps per-row work independent and processes each row sequentially
//! inside its range, results are bit-identical for every thread count
//! (including 1) — the engine's parity tests assert exactly that.
//!
//! Sizing: `PAS_THREADS` env override, else available parallelism capped
//! at 16 (same rule the seed code used).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

thread_local! {
    /// Set while the current thread is executing pool tasks (workers
    /// always; the submitting thread during its own claim loop). Nested
    /// `run` calls from such threads execute inline.
    static IN_POOL: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// A borrowed job: raw closure pointer + task count. Only dereferenced
/// while the submitting `run` call is blocked, which keeps the borrow
/// alive.
#[derive(Clone, Copy)]
struct Job {
    f: *const (dyn Fn(usize) + Sync + 'static),
    total: usize,
}

// SAFETY: the pointee is `Sync` (shared-call safe) and outlives every
// dereference — `Pool::run` does not return before all workers are done
// with the job.
unsafe impl Send for Job {}

struct State {
    /// Bumped once per submitted job so parked workers can tell a fresh
    /// job from the one they just finished.
    epoch: u64,
    job: Option<Job>,
    /// Workers still processing the current epoch.
    active: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
    /// Next task index to claim (reset per job).
    next: AtomicUsize,
    panicked: AtomicBool,
}

/// Persistent scoped thread pool. See the module docs.
pub struct Pool {
    shared: Arc<Shared>,
    /// Serializes submissions: one job in flight at a time.
    submit: Mutex<()>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// `PAS_THREADS` env override, else available parallelism capped at 16.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("PAS_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

impl Pool {
    /// Pool with `threads` total participants (the submitting thread
    /// counts as one, so `threads - 1` workers are spawned; `threads <= 1`
    /// means fully inline execution).
    pub fn new(threads: usize) -> Pool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                active: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            next: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
        });
        let workers = (0..threads.saturating_sub(1))
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("pas-pool-{i}"))
                    .spawn(move || worker_loop(&sh))
                    .expect("spawning pool worker")
            })
            .collect();
        Pool {
            shared,
            submit: Mutex::new(()),
            workers,
        }
    }

    /// The process-wide pool every hot loop shares.
    pub fn global() -> &'static Pool {
        static POOL: OnceLock<Pool> = OnceLock::new();
        POOL.get_or_init(|| Pool::new(default_threads()))
    }

    /// Total participants (workers + the submitting thread).
    pub fn size(&self) -> usize {
        self.workers.len() + 1
    }

    /// Run `f(0..total)` across the pool; returns when every index is
    /// done. Allocation-free in steady state. Panics (on the caller) if
    /// any task panicked.
    pub fn run(&self, total: usize, f: &(dyn Fn(usize) + Sync)) {
        if total == 0 {
            return;
        }
        if self.workers.is_empty() || total == 1 || IN_POOL.with(|c| c.get()) {
            for i in 0..total {
                f(i);
            }
            return;
        }
        let _guard = self.submit.lock().unwrap();
        // SAFETY: erases the closure's borrow lifetime. Sound because this
        // function blocks (below) until `state.active == 0`, i.e. until no
        // worker can still dereference the pointer.
        let ptr = unsafe {
            std::mem::transmute::<
                &(dyn Fn(usize) + Sync),
                *const (dyn Fn(usize) + Sync + 'static),
            >(f)
        };
        self.shared.panicked.store(false, Ordering::Relaxed);
        self.shared.next.store(0, Ordering::SeqCst);
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(Job { f: ptr, total });
            st.epoch = st.epoch.wrapping_add(1);
            st.active = self.workers.len();
        }
        self.shared.work_cv.notify_all();
        // The submitting thread claims indices too.
        IN_POOL.with(|c| c.set(true));
        claim_loop(&self.shared, f, total);
        IN_POOL.with(|c| c.set(false));
        {
            let mut st = self.shared.state.lock().unwrap();
            while st.active > 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
        }
        if self.shared.panicked.load(Ordering::Relaxed) {
            panic!("pas::util::pool: a parallel task panicked");
        }
    }

    /// The contiguous partition [`Self::par_rows`] dispatches: returns
    /// `(chunk_rows, n_chunks)` for sharding `rows` into at most
    /// `min(size, max_parts)` ranges of at least `min_rows` rows; chunk
    /// `c` covers `[c * chunk_rows, min((c + 1) * chunk_rows, rows))`.
    /// Exposed so callers that attach per-chunk resources (the engine's
    /// scratch arena) can compute chunk offsets from the *same* formulas
    /// the dispatch uses.
    pub fn partition(&self, rows: usize, max_parts: usize, min_rows: usize) -> (usize, usize) {
        if rows == 0 {
            return (0, 0);
        }
        let cap = self.size().min(max_parts.max(1));
        let parts = cap.min(rows / min_rows.max(1)).max(1);
        let chunk = rows.div_ceil(parts);
        (chunk, rows.div_ceil(chunk))
    }

    /// Shard `rows` into at most `min(size, max_parts)` contiguous ranges
    /// of at least `min_rows` rows and call `f(row_start, row_end)` for
    /// each, in parallel. Bit-identical to `f(0, rows)` whenever per-row
    /// work is independent.
    pub fn par_rows(
        &self,
        rows: usize,
        max_parts: usize,
        min_rows: usize,
        f: impl Fn(usize, usize) + Sync,
    ) {
        let (chunk, n_chunks) = self.partition(rows, max_parts, min_rows);
        if n_chunks == 0 {
            return;
        }
        if n_chunks <= 1 {
            f(0, rows);
            return;
        }
        self.run(n_chunks, &|c| {
            let r0 = c * chunk;
            let r1 = ((c + 1) * chunk).min(rows);
            f(r0, r1);
        });
    }
}

fn claim_loop(shared: &Shared, f: &(dyn Fn(usize) + Sync), total: usize) {
    loop {
        let i = shared.next.fetch_add(1, Ordering::SeqCst);
        if i >= total {
            break;
        }
        if shared.panicked.load(Ordering::Relaxed) {
            continue; // drain remaining indices without running them
        }
        if catch_unwind(AssertUnwindSafe(|| f(i))).is_err() {
            shared.panicked.store(true, Ordering::Relaxed);
        }
    }
}

fn worker_loop(shared: &Shared) {
    IN_POOL.with(|c| c.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    if let Some(j) = st.job {
                        seen = st.epoch;
                        break j;
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        // SAFETY: the submitter blocks until we decrement `active` below,
        // so the borrow behind `job.f` is still live here.
        let f = unsafe { &*job.f };
        claim_loop(shared, f, job.total);
        let mut st = shared.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Raw-pointer wrapper so parallel tasks can write to *disjoint* regions
/// of one buffer (rustc cannot prove disjointness of computed row ranges).
/// Every use site derives non-overlapping slices from row arithmetic.
pub struct SendPtr<T>(*mut T);

impl<T> SendPtr<T> {
    pub fn new(p: *mut T) -> SendPtr<T> {
        SendPtr(p)
    }

    pub fn get(&self) -> *mut T {
        self.0
    }
}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T> Copy for SendPtr<T> {}

// SAFETY: a SendPtr is only a capability to *derive* disjoint &mut slices
// inside pool tasks; all call sites guarantee disjoint row ranges.
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_index_exactly_once() {
        let pool = Pool::new(4);
        let hits = AtomicU64::new(0);
        let sum = AtomicU64::new(0);
        pool.run(100, &|i| {
            hits.fetch_add(1, Ordering::Relaxed);
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 100);
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn single_thread_pool_is_inline() {
        let pool = Pool::new(1);
        assert_eq!(pool.size(), 1);
        let sum = AtomicU64::new(0);
        pool.run(10, &|i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn par_rows_covers_disjoint_ranges() {
        let pool = Pool::new(3);
        let rows = 1000;
        let dim = 3;
        let mut out = vec![0.0f64; rows * dim];
        let ptr = SendPtr::new(out.as_mut_ptr());
        pool.par_rows(rows, usize::MAX, 1, |r0, r1| {
            // SAFETY: par_rows hands each worker a disjoint [r0, r1) row
            // range, so the reconstructed sub-slices never alias.
            let o = unsafe {
                std::slice::from_raw_parts_mut(ptr.get().add(r0 * dim), (r1 - r0) * dim)
            };
            for (k, v) in o.iter_mut().enumerate() {
                *v = (r0 * dim + k) as f64;
            }
        });
        for (k, v) in out.iter().enumerate() {
            assert_eq!(*v, k as f64, "row element {k} written exactly once");
        }
    }

    #[test]
    fn nested_run_executes_inline() {
        let pool = Pool::global();
        let count = AtomicU64::new(0);
        pool.run(8, &|_| {
            // Nested dispatch from a pool task must not deadlock.
            Pool::global().run(8, &|_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(count.load(Ordering::Relaxed), 64);
    }

    #[test]
    fn task_panic_propagates_to_caller() {
        let pool = Pool::new(4);
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.run(32, &|i| {
                if i == 7 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "panic must reach the submitter");
        // Pool stays usable afterwards.
        let n = AtomicU64::new(0);
        pool.run(16, &|_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 16);
    }

    #[test]
    fn borrowed_state_is_visible_after_run() {
        let pool = Pool::new(4);
        let data: Vec<u64> = (0..64).collect();
        let sum = AtomicU64::new(0);
        pool.run(64, &|i| {
            sum.fetch_add(data[i], Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 63 * 64 / 2);
    }
}
