//! Shared utilities: deterministic RNG, minimal JSON, logging, timing,
//! compiled-in fail points, and the scoped thread pool behind every
//! batch-parallel hot loop.

pub mod rng;
pub mod json;
pub mod log;
pub mod timer;
pub mod pool;
pub mod failpoint;

/// Mean of a slice. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Unbiased sample standard deviation. Returns 0.0 for < 2 elements.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (sorts a copy). Returns 0.0 for an empty slice.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!((std_dev(&[1.0, 3.0]) - (2.0f64).sqrt()).abs() < 1e-12);
    }
}
