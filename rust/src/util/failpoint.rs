//! Compiled-in fail points for fault-injection ("chaos") tests.
//!
//! The artifact store introduced the pattern: a one-shot trip wire armed
//! by a test and checked by the *production* code path, so fault
//! injection exercises the exact protocol that runs in production rather
//! than a test double. This module lifts that infrastructure out of
//! `artifact/store.rs` so the serving path (engine tick, scheduler,
//! wire replies) can use it too. Two scopes:
//!
//! * [`FailPoints`] — an instance-scoped one-shot set. The artifact
//!   store owns one per handle, so concurrent tests against different
//!   store directories cannot interfere.
//! * A **process-global registry** ([`arm`]/[`take`]/[`peek`]) for sites
//!   buried inside the serving stack, where tests hold no handle on the
//!   component (a `SlotEngine` lives inside a worker thread). The
//!   disarmed fast path is a single relaxed atomic load — nothing is
//!   locked, nothing allocates — so the hooks stay inside the serving
//!   path's zero-allocation budget.
//!
//! Sites are `&'static str` names (constants below for the serving
//! path); each carries a `u64` payload the firing site interprets (e.g.
//! the tick index at which to inject). All failpoints are one-shot:
//! firing disarms.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

/// Inject a NaN into the first row of the cohort when the engine's step
/// counter equals the payload ([`crate::solvers::engine::SlotEngine::step_cohort`]).
pub const ENGINE_NAN_TICK: &str = "engine.nan_tick";

/// Panic inside the scheduler's tick path when the cohort's completed
/// step count equals the payload — simulates a model eval blowing up
/// mid-cohort.
pub const SERVICE_EVAL_PANIC: &str = "service.eval_panic";

/// Fail the next wire reply write with a broken-pipe error — simulates a
/// client that vanished between request and reply.
pub const PROTOCOL_WRITE_FAIL: &str = "protocol.reply_write_fail";

/// Instance-scoped one-shot fail-point set.
pub struct FailPoints {
    armed: Vec<(&'static str, u64)>,
}

impl FailPoints {
    pub const fn new() -> FailPoints {
        FailPoints { armed: Vec::new() }
    }

    /// Arm `site` (payload 0). Re-arming replaces the payload.
    pub fn arm(&mut self, site: &'static str) {
        self.arm_with(site, 0);
    }

    /// Arm `site` with a payload the firing site interprets.
    pub fn arm_with(&mut self, site: &'static str, payload: u64) {
        if let Some(slot) = self.armed.iter_mut().find(|(s, _)| *s == site) {
            slot.1 = payload;
        } else {
            self.armed.push((site, payload));
        }
    }

    /// Payload of `site` if armed, without disarming.
    pub fn peek(&self, site: &str) -> Option<u64> {
        self.armed.iter().find(|(s, _)| *s == site).map(|&(_, p)| p)
    }

    /// Fire `site`: returns its payload and disarms it, or `None`.
    pub fn take(&mut self, site: &str) -> Option<u64> {
        let i = self.armed.iter().position(|(s, _)| *s == site)?;
        Some(self.armed.swap_remove(i).1)
    }

    pub fn is_empty(&self) -> bool {
        self.armed.is_empty()
    }
}

impl Default for FailPoints {
    fn default() -> Self {
        FailPoints::new()
    }
}

/// Fast-path gate: true only while at least one global site is armed, so
/// production code pays one relaxed load when chaos is off.
static ANY_ARMED: AtomicBool = AtomicBool::new(false);
static GLOBAL: Mutex<FailPoints> = Mutex::new(FailPoints::new());

fn global() -> std::sync::MutexGuard<'static, FailPoints> {
    // A panicking failpoint site (that is the point of some of them)
    // must not poison the registry for the rest of the process.
    GLOBAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// Arm the global `site` with `payload`.
pub fn arm(site: &'static str, payload: u64) {
    let mut g = global();
    g.arm_with(site, payload);
    ANY_ARMED.store(true, Ordering::Release);
}

/// Payload of the global `site` if armed, without disarming. One relaxed
/// atomic load when nothing is armed.
pub fn peek(site: &str) -> Option<u64> {
    if !ANY_ARMED.load(Ordering::Relaxed) {
        return None;
    }
    global().peek(site)
}

/// Fire the global `site`: returns its payload and disarms it. One
/// relaxed atomic load when nothing is armed.
pub fn take(site: &str) -> Option<u64> {
    if !ANY_ARMED.load(Ordering::Relaxed) {
        return None;
    }
    let mut g = global();
    let hit = g.take(site);
    if g.is_empty() {
        ANY_ARMED.store(false, Ordering::Release);
    }
    hit
}

/// Disarm every global site (test teardown).
pub fn disarm_all() {
    let mut g = global();
    g.armed.clear();
    ANY_ARMED.store(false, Ordering::Release);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn instance_set_is_one_shot() {
        let mut fp = FailPoints::new();
        assert!(fp.is_empty());
        fp.arm_with("a", 7);
        fp.arm("b");
        assert_eq!(fp.peek("a"), Some(7));
        assert_eq!(fp.take("a"), Some(7));
        assert_eq!(fp.take("a"), None, "one-shot");
        assert_eq!(fp.take("b"), Some(0));
        assert!(fp.is_empty());
    }

    #[test]
    fn rearming_replaces_payload() {
        let mut fp = FailPoints::new();
        fp.arm_with("a", 1);
        fp.arm_with("a", 2);
        assert_eq!(fp.take("a"), Some(2));
        assert_eq!(fp.take("a"), None);
    }

    #[test]
    fn global_registry_round_trips() {
        // Unique site names: unit tests share the process-global registry.
        arm("test.failpoint.global", 42);
        assert_eq!(peek("test.failpoint.global"), Some(42));
        assert_eq!(take("test.failpoint.global"), Some(42));
        assert_eq!(take("test.failpoint.global"), None);
        arm("test.failpoint.sweep", 1);
        disarm_all();
        assert_eq!(peek("test.failpoint.sweep"), None);
    }
}
