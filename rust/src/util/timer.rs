//! Wall-clock timing helpers used by the bench harness and experiments.

use std::time::Instant;

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }
}

/// Time a closure, returning (result, seconds).
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.elapsed_s())
}

/// Human-readable duration.
pub fn fmt_duration(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.2} s", s)
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert!(fmt_duration(2e-9).ends_with("ns"));
        assert!(fmt_duration(2e-6).ends_with("µs"));
        assert!(fmt_duration(2e-3).ends_with("ms"));
        assert!(fmt_duration(2.0).ends_with(" s"));
        assert!(fmt_duration(200.0).ends_with("min"));
    }

    #[test]
    fn times_something() {
        let (v, s) = time_it(|| (0..1000).sum::<u64>());
        assert_eq!(v, 499_500);
        assert!(s >= 0.0);
    }
}
