//! PJRT runtime: loads AOT-compiled HLO artifacts (produced once at build
//! time by `python/compile/aot.py`) and executes them from the rust
//! request path. Python is never involved at runtime.
//!
//! Interchange is HLO **text**: jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md). Artifacts live in
//! `artifacts/<name>.hlo.txt` next to a `<name>.meta.json` describing the
//! example shapes.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Metadata exported alongside each HLO artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// Fixed batch the executable was lowered with.
    pub batch: usize,
    /// Data dimension D.
    pub dim: usize,
    /// Dataset the denoiser was trained on.
    pub dataset: String,
}

impl ArtifactMeta {
    pub fn load(path: &Path) -> Result<ArtifactMeta> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&s).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
        let get = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("missing {k} in {}", path.display()))
        };
        Ok(ArtifactMeta {
            name: j
                .get("name")
                .and_then(|v| v.as_str())
                .unwrap_or("model")
                .to_string(),
            batch: get("batch")? as usize,
            dim: get("dim")? as usize,
            dataset: j
                .get("dataset")
                .and_then(|v| v.as_str())
                .unwrap_or("unknown")
                .to_string(),
        })
    }
}

/// A compiled PJRT executable with its metadata.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT client wrapper (CPU). One per process; executables share it.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load_hlo(&self, hlo_path: &Path, meta: ArtifactMeta) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            hlo_path
                .to_str()
                .context("artifact path must be valid UTF-8")?,
        )
        .with_context(|| format!("parsing HLO text {}", hlo_path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", hlo_path.display()))?;
        Ok(Executable { meta, exe })
    }

    /// Load `<dir>/<name>.hlo.txt` + `<dir>/<name>.meta.json`.
    pub fn load_artifact(&self, dir: &Path, name: &str) -> Result<Executable> {
        let meta = ArtifactMeta::load(&dir.join(format!("{name}.meta.json")))?;
        self.load_hlo(&dir.join(format!("{name}.hlo.txt")), meta)
    }
}

impl Executable {
    /// Execute the denoiser on `(batch, dim)` f32 inputs plus a per-row
    /// time vector; returns the eps prediction `(batch, dim)`.
    pub fn eval_eps(&self, x: &[f32], t: &[f32]) -> Result<Vec<f32>> {
        let b = self.meta.batch;
        let d = self.meta.dim;
        anyhow::ensure!(
            x.len() == b * d,
            "x shape mismatch: {} != {}",
            x.len(),
            b * d
        );
        anyhow::ensure!(t.len() == b, "t shape mismatch");
        let lx = xla::Literal::vec1(x).reshape(&[b as i64, d as i64])?;
        let lt = xla::Literal::vec1(t);
        let result = self.exe.execute::<xla::Literal>(&[lx, lt])?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Default artifact directory: `$PAS_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("PAS_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_roundtrip() {
        let dir = std::env::temp_dir().join("pas_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.meta.json");
        std::fs::write(
            &p,
            r#"{"name":"eps","batch":64,"dim":2,"dataset":"spiral2d"}"#,
        )
        .unwrap();
        let m = ArtifactMeta::load(&p).unwrap();
        assert_eq!(m.batch, 64);
        assert_eq!(m.dim, 2);
        assert_eq!(m.dataset, "spiral2d");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn missing_meta_errors() {
        let err = ArtifactMeta::load(Path::new("/nonexistent/x.meta.json"));
        assert!(err.is_err());
    }
}
