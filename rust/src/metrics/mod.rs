//! Sample-quality and trajectory-fidelity metrics.
//!
//! The paper reports Inception-FID; offline we use **gFID** — the Fréchet
//! distance between Gaussians fit to the two sample sets *in data space*
//! (identical functional form to FID with an identity feature extractor) —
//! plus sliced 2-Wasserstein and RBF-MMD as corroborating metrics, and the
//! raw `L1`/`L2` trajectory errors the paper itself reports in Table 11.

use crate::linalg::{sqrtm_psd, trace};
use crate::tensor::{col_means, covariance, l1_dist, l2_dist_sq, matmul_into};
use crate::util::rng::Pcg64;

/// Fréchet distance between Gaussians fit to two sample sets:
/// `||mu_a - mu_b||² + tr(Sa + Sb - 2 (Sa^{1/2} Sb Sa^{1/2})^{1/2})`.
pub fn gfid(a: &[f64], na: usize, b: &[f64], nb: usize, dim: usize) -> f64 {
    let mu_a = col_means(a, na, dim);
    let mu_b = col_means(b, nb, dim);
    let sa = covariance(a, na, dim);
    let sb = covariance(b, nb, dim);
    let mean_term = l2_dist_sq(&mu_a, &mu_b);
    // (Sa^{1/2} Sb Sa^{1/2})^{1/2} via PSD square roots.
    let sa_half = sqrtm_psd(&sa, dim);
    let mut tmp = vec![0.0; dim * dim];
    matmul_into(&sa_half, dim, dim, &sb, dim, &mut tmp);
    let mut inner = vec![0.0; dim * dim];
    matmul_into(&tmp, dim, dim, &sa_half, dim, &mut inner);
    let cross = sqrtm_psd(&inner, dim);
    let cov_term = trace(&sa, dim) + trace(&sb, dim) - 2.0 * trace(&cross, dim);
    (mean_term + cov_term).max(0.0)
}

/// Sliced 2-Wasserstein distance: average over `n_proj` random 1-D
/// projections of the squared W2 between empirical distributions.
pub fn sliced_w2(a: &[f64], na: usize, b: &[f64], nb: usize, dim: usize, n_proj: usize, seed: u64) -> f64 {
    let mut rng = Pcg64::seed_stream(seed, 0x5712);
    let m = na.min(nb);
    let mut total = 0.0;
    let mut pa = vec![0.0; na];
    let mut pb = vec![0.0; nb];
    for _ in 0..n_proj {
        // Random unit direction.
        let mut dir = rng.normal_vec(dim);
        let norm = crate::tensor::norm2(&dir);
        for v in dir.iter_mut() {
            *v /= norm;
        }
        // Batch·direction matvecs through the tiled projection kernel
        // (dot-order per row — same bits, row panels amortized).
        crate::tensor::gemm::gemm_nt_dot_into(a, na, &dir, 1, dim, &mut pa);
        crate::tensor::gemm::gemm_nt_dot_into(b, nb, &dir, 1, dim, &mut pb);
        pa.sort_by(|x, y| x.partial_cmp(y).unwrap());
        pb.sort_by(|x, y| x.partial_cmp(y).unwrap());
        // Quantile-matched squared differences.
        let mut s = 0.0;
        for q in 0..m {
            let qa = pa[q * na / m];
            let qb = pb[q * nb / m];
            s += (qa - qb) * (qa - qb);
        }
        total += s / m as f64;
    }
    total / n_proj as f64
}

/// RBF-kernel MMD² with bandwidth set by the median heuristic over a
/// subsample.
pub fn mmd2_rbf(a: &[f64], na: usize, b: &[f64], nb: usize, dim: usize) -> f64 {
    // Median pairwise distance over a capped subsample for bandwidth.
    let cap = 128usize;
    let step_a = (na / cap.min(na)).max(1);
    let step_b = (nb / cap.min(nb)).max(1);
    let mut d2s = Vec::new();
    let rows_a: Vec<&[f64]> = (0..na)
        .step_by(step_a)
        .map(|i| &a[i * dim..(i + 1) * dim])
        .collect();
    let rows_b: Vec<&[f64]> = (0..nb)
        .step_by(step_b)
        .map(|i| &b[i * dim..(i + 1) * dim])
        .collect();
    for (i, ra) in rows_a.iter().enumerate() {
        for rb in rows_a.iter().skip(i + 1) {
            d2s.push(l2_dist_sq(ra, rb));
        }
    }
    for ra in &rows_a {
        for rb in &rows_b {
            d2s.push(l2_dist_sq(ra, rb));
        }
    }
    let bw = crate::util::median(&d2s).max(1e-12);
    let k = |x: &[f64], y: &[f64]| (-l2_dist_sq(x, y) / bw).exp();
    let (mut kaa, mut kbb, mut kab) = (0.0, 0.0, 0.0);
    let la = rows_a.len();
    let lb = rows_b.len();
    for i in 0..la {
        for j in 0..la {
            if i != j {
                kaa += k(rows_a[i], rows_a[j]);
            }
        }
    }
    for i in 0..lb {
        for j in 0..lb {
            if i != j {
                kbb += k(rows_b[i], rows_b[j]);
            }
        }
    }
    for ra in &rows_a {
        for rb in &rows_b {
            kab += k(ra, rb);
        }
    }
    kaa / (la * (la - 1)) as f64 + kbb / (lb * (lb - 1)) as f64 - 2.0 * kab / (la * lb) as f64
}

/// Mean per-sample L2 distance between matched sample sets (Table 11's
/// "L2 (MSE)" against the teacher endpoint). Normalized per dimension.
pub fn mean_l2(a: &[f64], b: &[f64], n: usize, dim: usize) -> f64 {
    let mut s = 0.0;
    for i in 0..n {
        s += l2_dist_sq(&a[i * dim..(i + 1) * dim], &b[i * dim..(i + 1) * dim]);
    }
    s / (n * dim) as f64
}

/// Mean per-sample L1 distance (Table 11's "L1"), normalized per dimension.
pub fn mean_l1(a: &[f64], b: &[f64], n: usize, dim: usize) -> f64 {
    let mut s = 0.0;
    for i in 0..n {
        s += l1_dist(&a[i * dim..(i + 1) * dim], &b[i * dim..(i + 1) * dim]);
    }
    s / (n * dim) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian_set(rng: &mut Pcg64, n: usize, dim: usize, mu: f64, sd: f64) -> Vec<f64> {
        (0..n * dim).map(|_| mu + sd * rng.normal()).collect()
    }

    #[test]
    fn gfid_zero_for_identical_sets() {
        let mut rng = Pcg64::seed(1);
        let a = gaussian_set(&mut rng, 500, 4, 0.0, 1.0);
        let f = gfid(&a, 500, &a, 500, 4);
        assert!(f < 1e-9, "{f}");
    }

    #[test]
    fn gfid_detects_mean_shift() {
        let mut rng = Pcg64::seed(2);
        let a = gaussian_set(&mut rng, 2000, 3, 0.0, 1.0);
        let b = gaussian_set(&mut rng, 2000, 3, 1.0, 1.0);
        let f = gfid(&a, 2000, &b, 2000, 3);
        // ||mu_a - mu_b||² = 3 exactly in expectation.
        assert!((f - 3.0).abs() < 0.3, "{f}");
    }

    #[test]
    fn gfid_detects_variance_mismatch() {
        let mut rng = Pcg64::seed(3);
        let a = gaussian_set(&mut rng, 3000, 2, 0.0, 1.0);
        let b = gaussian_set(&mut rng, 3000, 2, 0.0, 2.0);
        // tr term: 2·(1 + 4 − 2·2) = 2 per... per-dim (1+4-4)=1 → 2 total.
        let f = gfid(&a, 3000, &b, 3000, 2);
        assert!((f - 2.0).abs() < 0.4, "{f}");
    }

    #[test]
    fn gfid_is_symmetric() {
        let mut rng = Pcg64::seed(4);
        let a = gaussian_set(&mut rng, 800, 5, 0.0, 1.0);
        let b = gaussian_set(&mut rng, 800, 5, 0.3, 1.4);
        let f1 = gfid(&a, 800, &b, 800, 5);
        let f2 = gfid(&b, 800, &a, 800, 5);
        assert!((f1 - f2).abs() < 1e-6 * (1.0 + f1), "{f1} vs {f2}");
    }

    #[test]
    fn sliced_w2_orders_divergence() {
        let mut rng = Pcg64::seed(5);
        let reference = gaussian_set(&mut rng, 1000, 4, 0.0, 1.0);
        let near = gaussian_set(&mut rng, 1000, 4, 0.1, 1.0);
        let far = gaussian_set(&mut rng, 1000, 4, 2.0, 1.0);
        let wn = sliced_w2(&reference, 1000, &near, 1000, 4, 32, 9);
        let wf = sliced_w2(&reference, 1000, &far, 1000, 4, 32, 9);
        assert!(wf > wn * 5.0, "{wn} vs {wf}");
    }

    #[test]
    fn mmd_zero_for_same_distribution() {
        let mut rng = Pcg64::seed(6);
        let a = gaussian_set(&mut rng, 400, 3, 0.0, 1.0);
        let b = gaussian_set(&mut rng, 400, 3, 0.0, 1.0);
        let c = gaussian_set(&mut rng, 400, 3, 3.0, 1.0);
        let same = mmd2_rbf(&a, 400, &b, 400, 3);
        let diff = mmd2_rbf(&a, 400, &c, 400, 3);
        assert!(same.abs() < 0.02, "{same}");
        assert!(diff > 0.1, "{diff}");
    }

    #[test]
    fn mean_l1_l2_basics() {
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![0.0, 2.0, 3.0, 2.0];
        assert!((mean_l2(&a, &b, 2, 2) - (1.0 + 4.0) / 4.0).abs() < 1e-12);
        assert!((mean_l1(&a, &b, 2, 2) - 3.0 / 4.0).abs() < 1e-12);
    }
}
