//! Name → dataset registry used by the CLI, config system and experiments.

use super::{generators, Dataset};

/// All registered dataset names (stable order: the order tables print in).
pub const ALL: &[&str] = &[
    "gmm2d",
    "spiral2d",
    "checker2d",
    "gmm-hd64",
    "shells64",
    "latent256",
    "cond-gmm64",
];

/// The four unconditional "main table" datasets (Table 2 analog).
pub const MAIN_TABLE: &[&str] = &["gmm-hd64", "shells64", "cond-gmm64", "latent256"];

/// Look up a dataset by name.
pub fn get(name: &str) -> Option<Dataset> {
    let (spec, about, stands_in_for) = match name {
        "gmm2d" => (
            generators::gmm2d(),
            "8 isotropic Gaussians on a circle in R^2",
            "2-D intuition figures",
        ),
        "spiral2d" => (
            generators::spiral2d(),
            "two-arm spiral (40 modes) in R^2",
            "2-D intuition figures",
        ),
        "checker2d" => (
            generators::checker2d(),
            "4x4 checkerboard (8 cells) in R^2",
            "2-D intuition figures",
        ),
        "gmm-hd64" => (
            generators::gmm_hd64(),
            "10 anisotropic low-rank modes in R^64",
            "CIFAR10 32x32",
        ),
        "shells64" => (
            generators::shells64(),
            "24 modes on two nested spheres in R^64",
            "FFHQ 64x64",
        ),
        "latent256" => (
            generators::latent256(),
            "6 rank-16 modes in R^256",
            "LSUN Bedroom 256x256",
        ),
        "cond-gmm64" => (
            generators::cond_gmm64(),
            "8-class conditional GMM in R^64 (use with CFG)",
            "ImageNet 64x64 / Stable Diffusion v1.4",
        ),
        _ => return None,
    };
    Some(Dataset {
        spec,
        about,
        stands_in_for,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all() {
        for name in ALL {
            let ds = get(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(ds.name(), *name);
        }
        assert!(get("nope").is_none());
    }

    #[test]
    fn main_table_subset_of_all() {
        for name in MAIN_TABLE {
            assert!(ALL.contains(name));
        }
    }
}
