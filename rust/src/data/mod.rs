//! Synthetic dataset substrates.
//!
//! The paper evaluates on CIFAR10 / FFHQ / ImageNet / LSUN / Stable
//! Diffusion via *pre-trained* networks. Offline we substitute analytically
//! tractable data distributions (Gaussian mixtures, possibly derived from
//! structured generators like spirals and checkerboards) whose PF-ODE score
//! is exact — the same Gaussian(-mixture) family the paper's own theory
//! section (§3.4, Wang & Vastola) uses to explain PAS. See DESIGN.md §3 for
//! the dataset ↔ paper mapping.
//!
//! Every dataset is represented as a [`GmmSpec`] (weights, means, per-mode
//! covariance eigendecompositions), so sampling *and* exact score evaluation
//! share one code path. Conditional datasets carry per-class mode groups.

pub mod generators;
pub mod registry;

use crate::linalg::eigh;
use crate::util::rng::Pcg64;

/// One Gaussian mode, stored by its covariance eigendecomposition:
/// `Sigma = Uᵀ diag(lam) U` where rows of `u` are eigenvectors.
#[derive(Clone, Debug)]
pub struct Mode {
    pub mean: Vec<f64>,
    /// Eigenvalues of Sigma (descending, >= 0).
    pub lam: Vec<f64>,
    /// Eigenvector rows, (d, d) row-major; `None` means Sigma is isotropic
    /// `lam[0] * I` (fast path: no rotation needed).
    pub u: Option<Vec<f64>>,
    pub weight: f64,
    /// Class label for conditional datasets (0 for unconditional).
    pub label: usize,
}

impl Mode {
    /// Isotropic mode `N(mean, var * I)`.
    pub fn isotropic(mean: Vec<f64>, var: f64, weight: f64, label: usize) -> Mode {
        let d = mean.len();
        Mode {
            mean,
            lam: vec![var; d],
            u: None,
            weight,
            label,
        }
    }

    /// Full-covariance mode; `cov` is d×d row-major PSD.
    pub fn full(mean: Vec<f64>, cov: &[f64], weight: f64, label: usize) -> Mode {
        let d = mean.len();
        assert_eq!(cov.len(), d * d);
        let mut work = cov.to_vec();
        let (lam, u) = eigh(&mut work, d);
        let lam = lam.into_iter().map(|v| v.max(0.0)).collect();
        Mode {
            mean,
            lam,
            u: Some(u),
            weight,
            label,
        }
    }

    pub fn dim(&self) -> usize {
        self.mean.len()
    }

    /// Draw one sample into `out`.
    pub fn sample_into(&self, rng: &mut Pcg64, out: &mut [f64]) {
        let d = self.dim();
        debug_assert_eq!(out.len(), d);
        match &self.u {
            None => {
                let s = self.lam[0].sqrt();
                for j in 0..d {
                    out[j] = self.mean[j] + s * rng.normal();
                }
            }
            Some(u) => {
                // x = mean + Uᵀ (sqrt(lam) ⊙ z) with U rows = eigvecs.
                out.copy_from_slice(&self.mean);
                for k in 0..d {
                    let c = self.lam[k].sqrt() * rng.normal();
                    if c == 0.0 {
                        continue;
                    }
                    let row = &u[k * d..(k + 1) * d];
                    for j in 0..d {
                        out[j] += c * row[j];
                    }
                }
            }
        }
    }
}

/// A Gaussian-mixture data distribution (possibly class-conditional).
#[derive(Clone, Debug)]
pub struct GmmSpec {
    pub name: String,
    pub modes: Vec<Mode>,
    pub n_classes: usize,
}

impl GmmSpec {
    pub fn dim(&self) -> usize {
        self.modes[0].dim()
    }

    /// Draw `n` samples (row-major n×d) from the marginal data distribution.
    pub fn sample(&self, rng: &mut Pcg64, n: usize) -> Vec<f64> {
        let d = self.dim();
        let weights: Vec<f64> = self.modes.iter().map(|m| m.weight).collect();
        let mut out = vec![0.0; n * d];
        for i in 0..n {
            let k = rng.categorical(&weights);
            self.modes[k].sample_into(rng, &mut out[i * d..(i + 1) * d]);
        }
        out
    }

    /// Draw `n` samples from class `label` (conditional datasets).
    pub fn sample_class(&self, rng: &mut Pcg64, n: usize, label: usize) -> Vec<f64> {
        let d = self.dim();
        let modes: Vec<&Mode> = self.modes.iter().filter(|m| m.label == label).collect();
        assert!(!modes.is_empty(), "no modes with label {label}");
        let weights: Vec<f64> = modes.iter().map(|m| m.weight).collect();
        let mut out = vec![0.0; n * d];
        for i in 0..n {
            let k = rng.categorical(&weights);
            modes[k].sample_into(rng, &mut out[i * d..(i + 1) * d]);
        }
        out
    }

    /// Dataset-level mean and covariance **of the mixture** (used by the
    /// teleportation warm start, which fits a single Gaussian to the data).
    pub fn mixture_moments(&self) -> (Vec<f64>, Vec<f64>) {
        let d = self.dim();
        let wsum: f64 = self.modes.iter().map(|m| m.weight).sum();
        let mut mu = vec![0.0; d];
        for m in &self.modes {
            for j in 0..d {
                mu[j] += m.weight / wsum * m.mean[j];
            }
        }
        // Sigma = Σ w (Sigma_k + (mu_k-mu)(mu_k-mu)ᵀ)
        let mut cov = vec![0.0; d * d];
        for m in &self.modes {
            let w = m.weight / wsum;
            // Covariance part.
            match &m.u {
                None => {
                    for j in 0..d {
                        cov[j * d + j] += w * m.lam[j];
                    }
                }
                Some(u) => {
                    for k in 0..d {
                        if m.lam[k] == 0.0 {
                            continue;
                        }
                        let row = &u[k * d..(k + 1) * d];
                        let c = w * m.lam[k];
                        for a in 0..d {
                            let ca = c * row[a];
                            if ca == 0.0 {
                                continue;
                            }
                            for b in 0..d {
                                cov[a * d + b] += ca * row[b];
                            }
                        }
                    }
                }
            }
            // Mean-spread part.
            for a in 0..d {
                let da = m.mean[a] - mu[a];
                if da == 0.0 {
                    continue;
                }
                for b in 0..d {
                    cov[a * d + b] += w * da * (m.mean[b] - mu[b]);
                }
            }
        }
        (mu, cov)
    }
}

/// Public dataset handle used throughout the crate.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub spec: GmmSpec,
    /// Short description for docs/CLI.
    pub about: &'static str,
    /// Which paper dataset this one stands in for.
    pub stands_in_for: &'static str,
}

impl Dataset {
    pub fn name(&self) -> &str {
        &self.spec.name
    }

    pub fn dim(&self) -> usize {
        self.spec.dim()
    }

    pub fn is_conditional(&self) -> bool {
        self.spec.n_classes > 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isotropic_mode_moments() {
        let m = Mode::isotropic(vec![1.0, -2.0], 0.25, 1.0, 0);
        let mut rng = Pcg64::seed(1);
        let n = 20_000;
        let mut buf = vec![0.0; 2];
        let (mut s0, mut s1, mut v0) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            m.sample_into(&mut rng, &mut buf);
            s0 += buf[0];
            s1 += buf[1];
            v0 += (buf[0] - 1.0) * (buf[0] - 1.0);
        }
        assert!((s0 / n as f64 - 1.0).abs() < 0.02);
        assert!((s1 / n as f64 + 2.0).abs() < 0.02);
        assert!((v0 / n as f64 - 0.25).abs() < 0.01);
    }

    #[test]
    fn full_mode_recovers_covariance() {
        let cov = vec![2.0, 1.2, 1.2, 1.0];
        let m = Mode::full(vec![0.0, 0.0], &cov, 1.0, 0);
        let mut rng = Pcg64::seed(2);
        let n = 40_000;
        let mut acc = [0.0f64; 4];
        let mut buf = vec![0.0; 2];
        for _ in 0..n {
            m.sample_into(&mut rng, &mut buf);
            acc[0] += buf[0] * buf[0];
            acc[1] += buf[0] * buf[1];
            acc[2] += buf[1] * buf[0];
            acc[3] += buf[1] * buf[1];
        }
        for (i, want) in cov.iter().enumerate() {
            let got = acc[i] / n as f64;
            assert!((got - want).abs() < 0.06, "cov[{i}]: {got} vs {want}");
        }
    }

    #[test]
    fn mixture_moments_two_point() {
        // Two unit-weight point-ish modes at ±1 in 1D with var 0.
        let spec = GmmSpec {
            name: "test".into(),
            modes: vec![
                Mode::isotropic(vec![1.0], 0.0, 1.0, 0),
                Mode::isotropic(vec![-1.0], 0.0, 1.0, 0),
            ],
            n_classes: 1,
        };
        let (mu, cov) = spec.mixture_moments();
        assert!(mu[0].abs() < 1e-12);
        assert!((cov[0] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn conditional_sampling_respects_labels() {
        let spec = GmmSpec {
            name: "c".into(),
            modes: vec![
                Mode::isotropic(vec![10.0, 0.0], 0.01, 1.0, 0),
                Mode::isotropic(vec![-10.0, 0.0], 0.01, 1.0, 1),
            ],
            n_classes: 2,
        };
        let mut rng = Pcg64::seed(3);
        let xs = spec.sample_class(&mut rng, 50, 1);
        for i in 0..50 {
            assert!(xs[i * 2] < 0.0);
        }
    }
}
