//! Constructors for the concrete synthetic datasets.
//!
//! Each generator builds a [`GmmSpec`] deterministically from a fixed seed
//! so every run of the system sees the same data distribution. Structured
//! 2-D sets (spiral, checkerboard) are expressed as many small isotropic
//! modes along the structure — the analytic score stays exact while the
//! geometry (curved, multi-modal) matches what makes diffusion sampling
//! trajectories bend.

use super::{GmmSpec, Mode};
use crate::util::rng::Pcg64;
use std::f64::consts::PI;

/// 8 isotropic modes on a circle in R² — the classic "8 gaussians".
pub fn gmm2d() -> GmmSpec {
    let r = 6.0;
    let modes = (0..8)
        .map(|k| {
            let th = 2.0 * PI * k as f64 / 8.0;
            Mode::isotropic(vec![r * th.cos(), r * th.sin()], 0.09, 1.0, 0)
        })
        .collect();
    GmmSpec {
        name: "gmm2d".into(),
        modes,
        n_classes: 1,
    }
}

/// Two-arm spiral in R², expressed as 40 small modes along the arms.
pub fn spiral2d() -> GmmSpec {
    let mut modes = Vec::new();
    for arm in 0..2 {
        for k in 0..20 {
            let u = k as f64 / 19.0;
            let th = 3.0 * PI * u + arm as f64 * PI;
            let rad = 1.0 + 5.0 * u;
            modes.push(Mode::isotropic(
                vec![rad * th.cos(), rad * th.sin()],
                0.04 + 0.03 * u,
                1.0,
                0,
            ));
        }
    }
    GmmSpec {
        name: "spiral2d".into(),
        modes,
        n_classes: 1,
    }
}

/// 4×4 checkerboard in R² (8 occupied cells as flat-ish modes).
pub fn checker2d() -> GmmSpec {
    let mut modes = Vec::new();
    for i in 0..4 {
        for j in 0..4 {
            if (i + j) % 2 == 0 {
                let cx = -4.5 + 3.0 * i as f64;
                let cy = -4.5 + 3.0 * j as f64;
                // Slightly anisotropic cells.
                let cov = vec![0.55, 0.1, 0.1, 0.55];
                modes.push(Mode::full(vec![cx, cy], &cov, 1.0, 0));
            }
        }
    }
    GmmSpec {
        name: "checker2d".into(),
        modes,
        n_classes: 1,
    }
}

/// Random anisotropic low-rank covariance `V diag(s) Vᵀ + floor * I`,
/// returned as a dense d×d row-major matrix.
fn random_lowrank_cov(rng: &mut Pcg64, d: usize, rank: usize, scale: f64, floor: f64) -> Vec<f64> {
    let mut cov = vec![0.0; d * d];
    for j in 0..d {
        cov[j * d + j] = floor;
    }
    for r in 0..rank {
        // Random direction.
        let mut v = rng.normal_vec(d);
        let n = crate::tensor::norm2(&v);
        for x in v.iter_mut() {
            *x /= n;
        }
        // Power-law spectrum.
        let s = scale / (1.0 + r as f64).powf(1.2);
        for a in 0..d {
            let ca = s * v[a];
            if ca == 0.0 {
                continue;
            }
            for b in 0..d {
                cov[a * d + b] += ca * v[b];
            }
        }
    }
    cov
}

/// CIFAR10 stand-in: 10 anisotropic modes in R^64 (moderate D, multi-mode).
pub fn gmm_hd64() -> GmmSpec {
    let d = 64;
    let mut rng = Pcg64::seed_stream(0xC1FA_0010, 64);
    let mut modes = Vec::new();
    for _ in 0..10 {
        let mut mean = rng.normal_vec(d);
        crate::tensor::scale(4.0, &mut mean);
        let cov = random_lowrank_cov(&mut rng, d, 8, 1.5, 0.05);
        modes.push(Mode::full(mean, &cov, 1.0, 0));
    }
    GmmSpec {
        name: "gmm-hd64".into(),
        modes,
        n_classes: 1,
    }
}

/// FFHQ stand-in: concentric "shells" — modes arranged on two nested
/// spheres in R^64, a smooth single-family manifold.
pub fn shells64() -> GmmSpec {
    let d = 64;
    let mut rng = Pcg64::seed_stream(0xFF_80, 65);
    let mut modes = Vec::new();
    for (rad, var) in [(5.0, 0.3), (9.0, 0.5)] {
        for _ in 0..12 {
            let mut dir = rng.normal_vec(d);
            let n = crate::tensor::norm2(&dir);
            let mean: Vec<f64> = dir.iter_mut().map(|x| *x / n * rad).collect();
            modes.push(Mode::isotropic(mean, var, 1.0, 0));
        }
    }
    GmmSpec {
        name: "shells64".into(),
        modes,
        n_classes: 1,
    }
}

/// LSUN-Bedroom stand-in: D = 256 with low intrinsic rank (rank-16
/// covariances), few well-separated modes — "high-D latent" regime.
pub fn latent256() -> GmmSpec {
    let d = 256;
    let mut rng = Pcg64::seed_stream(0xBED_00, 256);
    let mut modes = Vec::new();
    for _ in 0..6 {
        let mut mean = rng.normal_vec(d);
        crate::tensor::scale(3.0, &mut mean);
        let cov = random_lowrank_cov(&mut rng, d, 16, 2.0, 0.02);
        modes.push(Mode::full(mean, &cov, 1.0, 0));
    }
    GmmSpec {
        name: "latent256".into(),
        modes,
        n_classes: 1,
    }
}

/// ImageNet / Stable-Diffusion stand-in: class-conditional GMM in R^64,
/// 8 classes × 3 modes each. Used with the CFG wrapper (guidance 7.5 for
/// the Stable-Diffusion analog, Table 3).
pub fn cond_gmm64() -> GmmSpec {
    let d = 64;
    let n_classes = 8;
    let mut rng = Pcg64::seed_stream(0x1A6E, 66);
    let mut modes = Vec::new();
    for c in 0..n_classes {
        // Class center.
        let mut center = rng.normal_vec(d);
        crate::tensor::scale(5.0, &mut center);
        for _ in 0..3 {
            let mut mean = center.clone();
            let jit = rng.normal_vec(d);
            crate::tensor::axpy(1.2, &jit, &mut mean);
            let cov = random_lowrank_cov(&mut rng, d, 6, 1.0, 0.05);
            modes.push(Mode::full(mean, &cov, 1.0, c));
        }
    }
    GmmSpec {
        name: "cond-gmm64".into(),
        modes,
        n_classes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_generators_build() {
        for (spec, d, cond) in [
            (gmm2d(), 2, false),
            (spiral2d(), 2, false),
            (checker2d(), 2, false),
            (gmm_hd64(), 64, false),
            (shells64(), 64, false),
            (latent256(), 256, false),
            (cond_gmm64(), 64, true),
        ] {
            assert_eq!(spec.dim(), d, "{}", spec.name);
            assert_eq!(spec.n_classes > 1, cond, "{}", spec.name);
            assert!(!spec.modes.is_empty());
            for m in &spec.modes {
                assert_eq!(m.dim(), d);
                assert!(m.lam.iter().all(|&l| l >= 0.0));
            }
        }
    }

    #[test]
    fn generators_are_deterministic() {
        let a = gmm_hd64();
        let b = gmm_hd64();
        assert_eq!(a.modes[3].mean, b.modes[3].mean);
        assert_eq!(a.modes[3].lam, b.modes[3].lam);
    }

    #[test]
    fn cond_gmm_has_all_classes() {
        let spec = cond_gmm64();
        for c in 0..spec.n_classes {
            assert!(spec.modes.iter().any(|m| m.label == c));
        }
    }

    #[test]
    fn checker_cells_separated() {
        let spec = checker2d();
        assert_eq!(spec.modes.len(), 8);
        // Adjacent occupied cells are 3*sqrt(2) apart at least.
        for (i, a) in spec.modes.iter().enumerate() {
            for b in spec.modes.iter().skip(i + 1) {
                let dx = a.mean[0] - b.mean[0];
                let dy = a.mean[1] - b.mean[1];
                assert!(dx * dx + dy * dy > 8.0);
            }
        }
    }
}
