//! Register-tiled, cache-blocked matmul micro-kernels.
//!
//! Every dense product on the hot paths — the analytic model's
//! sample-blocked evaluation ([`crate::score::analytic`]), the Gram
//! matrices of the thin SVD ([`crate::linalg::svd_right_vectors`]), PSD
//! square roots, batch covariances and the gFID metric — routes through
//! this family instead of per-row `dot` loops. The payoff is classical:
//! an MR×NR register tile amortizes every loaded element of one operand
//! across NR (resp. MR) multiply-adds, so arithmetic intensity rises from
//! ~1 FLOP/byte (stream one row, dot it, stream the next) to
//! ~MR·NR/(MR+NR) FLOPs per loaded element, and the k-panel loop keeps
//! the working set inside L1/L2 instead of re-streaming panels from
//! memory once per output row.
//!
//! # Determinism contract
//!
//! These kernels are **bit-compatible replacements**, not merely
//! numerically close ones. Tiling only reorders *which entry* is worked
//! on when; the reduction order *within each output entry* is pinned to
//! the exact sequence of the scalar code each kernel replaces:
//!
//! * [`gemm_nn_acc`] / [`gemm_tn_acc`] accumulate each entry strictly in
//!   ascending-k order — the order of the seed `matmul_acc` (and of every
//!   `c[i][j] += a· b` textbook loop in this crate). k-panel blocking is
//!   sound here because partial sums are carried in `c` between panels,
//!   which extends the same ascending chain.
//! * [`gemm_nt_dot_acc`] computes each entry with the 4-lane unrolled
//!   order of [`crate::tensor::dot`] (four independent accumulators over
//!   `k & !3`, combined as `(s0+s1)+(s2+s3)`, sequential tail). No
//!   k-blocking: the lane combine happens once per entry, so the lanes
//!   must span the whole reduction — our k never exceeds the data
//!   dimension (≤ a few hundred), so the a-panel stays cache-resident
//!   anyway.
//! * [`gemm_nt_seq_into`] accumulates each entry with a single
//!   ascending-k chain (the order of the dense eigenbasis pass in
//!   `ModeEval::Full`).
//!
//! The engine-parity and golden-trajectory suites (and
//! `tests/eval_blocked_parity.rs`) pin this bitwise; the in-module tests
//! below pin each kernel against a scalar reference with `assert_eq!`.
//!
//! # Tile sizes
//!
//! `MR=4 × NR=8` for the k-sequential kernels: 32 f64 accumulators fill
//! half the 16 × 256-bit vector registers of the baseline x86-64 target
//! (4 ymm), leaving room for the broadcast `a` value and a streamed `b`
//! row; the inner loop is a textbook broadcast-FMA that autovectorizes
//! over the NR columns. The dot-ordered kernel uses `MR=2 × NR=4` with a
//! 4-wide lane accumulator per entry (8 ymm total) — lanes map onto one
//! vector register each, and the per-entry horizontal combine happens
//! once at the end. `KC=256` k-panels keep an MR×KC `a` slab (8 KiB) and
//! a KC×NR `b` slab (16 KiB) simultaneously L1/L2-resident. Edge tiles
//! fall back to the same loops with clamped bounds — order per entry is
//! unchanged, only fewer entries are in flight.
//!
//! All kernels write into caller-owned output (and read caller-owned
//! inputs) with **zero heap allocations** — `tests/alloc_audit.rs`
//! asserts this under a counting global allocator.

/// Register-tile rows of the ascending-k kernels.
pub const MR: usize = 4;
/// Register-tile columns of the ascending-k kernels.
pub const NR: usize = 8;
/// k-panel depth (cache block) of the ascending-k kernels.
pub const KC: usize = 256;

/// Register-tile rows of the dot-ordered kernel.
pub const MR_DOT: usize = 2;
/// Register-tile columns of the dot-ordered kernel.
pub const NR_DOT: usize = 4;

/// `c[m,n] += a[m,k] * b[k,n]`, all row-major. Bit-identical to the seed
/// `matmul_acc` loop nest: each output entry accumulates in ascending-k
/// order.
pub fn gemm_nn_acc(a: &[f64], m: usize, k: usize, b: &[f64], n: usize, c: &mut [f64]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let mut p0 = 0;
    while p0 < k {
        let pc = KC.min(k - p0);
        let mut i0 = 0;
        while i0 < m {
            let mr = MR.min(m - i0);
            let mut j0 = 0;
            while j0 < n {
                let nr = NR.min(n - j0);
                nn_micro(a, k, b, n, c, i0, j0, p0, pc, mr, nr);
                j0 += NR;
            }
            i0 += MR;
        }
        p0 += KC;
    }
}

/// `c = a * b` (zeroes `c`, then [`gemm_nn_acc`]).
pub fn gemm_nn_into(a: &[f64], m: usize, k: usize, b: &[f64], n: usize, c: &mut [f64]) {
    c.fill(0.0);
    gemm_nn_acc(a, m, k, b, n, c);
}

/// MR×NR block of `c += a·b`, k-panel `[p0, p0+pc)`. Partial sums are
/// carried in `c` across panels, so per-entry addition order stays a
/// single ascending-k chain.
#[inline(always)]
fn nn_micro(
    a: &[f64],
    k: usize,
    b: &[f64],
    n: usize,
    c: &mut [f64],
    i0: usize,
    j0: usize,
    p0: usize,
    pc: usize,
    mr: usize,
    nr: usize,
) {
    if mr == MR && nr == NR {
        // Full tile: constant bounds so the column loop vectorizes.
        let mut acc = [[0.0f64; NR]; MR];
        for (ir, row) in acc.iter_mut().enumerate() {
            let crow = &c[(i0 + ir) * n + j0..(i0 + ir) * n + j0 + NR];
            row.copy_from_slice(crow);
        }
        for p in p0..p0 + pc {
            let brow = &b[p * n + j0..p * n + j0 + NR];
            for (ir, row) in acc.iter_mut().enumerate() {
                let av = a[(i0 + ir) * k + p];
                for (jr, cv) in row.iter_mut().enumerate() {
                    *cv += av * brow[jr];
                }
            }
        }
        for (ir, row) in acc.iter().enumerate() {
            let crow = &mut c[(i0 + ir) * n + j0..(i0 + ir) * n + j0 + NR];
            crow.copy_from_slice(row);
        }
    } else {
        // Edge tile: same loops, clamped bounds.
        let mut acc = [[0.0f64; NR]; MR];
        for ir in 0..mr {
            for jr in 0..nr {
                acc[ir][jr] = c[(i0 + ir) * n + j0 + jr];
            }
        }
        for p in p0..p0 + pc {
            let brow = &b[p * n + j0..p * n + j0 + nr];
            for (ir, row) in acc.iter_mut().enumerate().take(mr) {
                let av = a[(i0 + ir) * k + p];
                for jr in 0..nr {
                    row[jr] += av * brow[jr];
                }
            }
        }
        for ir in 0..mr {
            for jr in 0..nr {
                c[(i0 + ir) * n + j0 + jr] = acc[ir][jr];
            }
        }
    }
}

/// `c[m,n] += a[m,k] * b[n,k]ᵀ` — i.e. `c[i][j] += dot(a_i, b_j)` with
/// each entry reduced in **exactly** the 4-lane order of
/// [`crate::tensor::dot`]. This is the Gram-matrix / projection /
/// eigenbasis-forward kernel: the register tile loads each `a` panel once
/// for [`NR_DOT`] columns and each `b` panel once for [`MR_DOT`] rows.
pub fn gemm_nt_dot_acc(a: &[f64], m: usize, b: &[f64], n: usize, k: usize, c: &mut [f64]) {
    nt_dot_kernel::<true>(a, m, b, n, k, c);
}

/// `c[m,n] = a[m,k] * b[n,k]ᵀ` in [`crate::tensor::dot`] order — assign
/// semantics, bit-identical to `c[i][j] = dot(a_i, b_j)` per entry
/// (including a `-0.0` dot result, which `0.0 + s` would lose).
pub fn gemm_nt_dot_into(a: &[f64], m: usize, b: &[f64], n: usize, k: usize, c: &mut [f64]) {
    nt_dot_kernel::<false>(a, m, b, n, k, c);
}

/// Shared dot-order micro-kernel; `ACC` selects accumulate (`+=`) vs
/// assign (`=`) on the final per-entry store — everything else, including
/// the debug shape checks, lives here once.
fn nt_dot_kernel<const ACC: bool>(
    a: &[f64],
    m: usize,
    b: &[f64],
    n: usize,
    k: usize,
    c: &mut [f64],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    let k4 = k & !3;
    let mut i0 = 0;
    while i0 < m {
        let mr = MR_DOT.min(m - i0);
        let mut j0 = 0;
        while j0 < n {
            let nr = NR_DOT.min(n - j0);
            // One 4-wide lane accumulator per entry: lane l holds the
            // partial sum over indices ≡ l (mod 4), exactly dot's s0..s3.
            let mut lanes = [[[0.0f64; 4]; NR_DOT]; MR_DOT];
            let mut p = 0;
            while p < k4 {
                for (ir, lrow) in lanes.iter_mut().enumerate().take(mr) {
                    let ap = &a[(i0 + ir) * k + p..(i0 + ir) * k + p + 4];
                    for (jr, lv) in lrow.iter_mut().enumerate().take(nr) {
                        let bp = &b[(j0 + jr) * k + p..(j0 + jr) * k + p + 4];
                        for l in 0..4 {
                            lv[l] += ap[l] * bp[l];
                        }
                    }
                }
                p += 4;
            }
            for ir in 0..mr {
                let arow = &a[(i0 + ir) * k..(i0 + ir) * k + k];
                for jr in 0..nr {
                    let brow = &b[(j0 + jr) * k..(j0 + jr) * k + k];
                    let lv = &lanes[ir][jr];
                    let mut s = (lv[0] + lv[1]) + (lv[2] + lv[3]);
                    let mut p = k4;
                    while p < k {
                        s += arow[p] * brow[p];
                        p += 1;
                    }
                    if ACC {
                        c[(i0 + ir) * n + j0 + jr] += s;
                    } else {
                        c[(i0 + ir) * n + j0 + jr] = s;
                    }
                }
            }
            j0 += NR_DOT;
        }
        i0 += MR_DOT;
    }
}

/// `c[m,n] = a[m,k] * b[n,k]ᵀ` with each entry reduced by a **single
/// ascending-k chain** (`s += a[i][p] * b[j][p]`, p = 0..k) — the order
/// of the dense `ModeEval::Full` eigenbasis pass. MR×NR = 4×4 register
/// tile: 16 independent scalar chains pipeline the FP-add latency even
/// though each chain is serial.
pub fn gemm_nt_seq_into(a: &[f64], m: usize, b: &[f64], n: usize, k: usize, c: &mut [f64]) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(c.len(), m * n);
    const MS: usize = 4;
    const NS: usize = 4;
    let mut i0 = 0;
    while i0 < m {
        let mr = MS.min(m - i0);
        let mut j0 = 0;
        while j0 < n {
            let nr = NS.min(n - j0);
            let mut acc = [[0.0f64; NS]; MS];
            for p in 0..k {
                for (ir, row) in acc.iter_mut().enumerate().take(mr) {
                    let av = a[(i0 + ir) * k + p];
                    for (jr, cv) in row.iter_mut().enumerate().take(nr) {
                        *cv += av * b[(j0 + jr) * k + p];
                    }
                }
            }
            for ir in 0..mr {
                for jr in 0..nr {
                    c[(i0 + ir) * n + j0 + jr] = acc[ir][jr];
                }
            }
            j0 += NS;
        }
        i0 += MS;
    }
}

/// `c[m,n] += a[k,m]ᵀ * b[k,n]` — the rank-k update kernel (batch
/// covariance `Cᵀ C`, eigen reconstruction `Vᵀ diag(s) V`). Each entry
/// accumulates in ascending-k order; the register tile turns the
/// per-sample rank-1 update loop into MR×NR outer-product FMAs per loaded
/// panel.
pub fn gemm_tn_acc(a: &[f64], k: usize, m: usize, b: &[f64], n: usize, c: &mut [f64]) {
    debug_assert_eq!(a.len(), k * m);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    let mut p0 = 0;
    while p0 < k {
        let pc = KC.min(k - p0);
        let mut i0 = 0;
        while i0 < m {
            let mr = MR.min(m - i0);
            let mut j0 = 0;
            while j0 < n {
                let nr = NR.min(n - j0);
                let mut acc = [[0.0f64; NR]; MR];
                for ir in 0..mr {
                    for jr in 0..nr {
                        acc[ir][jr] = c[(i0 + ir) * n + j0 + jr];
                    }
                }
                for p in p0..p0 + pc {
                    let brow = &b[p * n + j0..p * n + j0 + nr];
                    for (ir, row) in acc.iter_mut().enumerate().take(mr) {
                        let av = a[p * m + i0 + ir];
                        for jr in 0..nr {
                            row[jr] += av * brow[jr];
                        }
                    }
                }
                for ir in 0..mr {
                    for jr in 0..nr {
                        c[(i0 + ir) * n + j0 + jr] = acc[ir][jr];
                    }
                }
                j0 += NR;
            }
            i0 += MR;
        }
        p0 += KC;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dot;
    use crate::util::rng::Pcg64;

    /// The seed `matmul_acc` loop nest, verbatim: the bit-exactness
    /// reference for the ascending-k kernels.
    fn ref_nn_acc(a: &[f64], m: usize, k: usize, b: &[f64], n: usize, c: &mut [f64]) {
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j];
                }
            }
        }
    }

    fn ref_tn_acc(a: &[f64], k: usize, m: usize, b: &[f64], n: usize, c: &mut [f64]) {
        for p in 0..k {
            for i in 0..m {
                let av = a[p * m + i];
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j];
                }
            }
        }
    }

    fn ref_nt_seq(a: &[f64], m: usize, b: &[f64], n: usize, k: usize, c: &mut [f64]) {
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a[i * k + p] * b[j * k + p];
                }
                c[i * n + j] = s;
            }
        }
    }

    /// Shapes straddling every tile boundary: 1, MR-1, MR, MR+1, several
    /// tiles plus a remainder, and k values around the 4-lane width and
    /// the KC panel.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 9, 3),
        (3, 7, 5),
        (4, 8, 4),
        (5, 9, 17),
        (8, 16, 64),
        (13, 11, 257),
        (16, 3, 300),
    ];

    #[test]
    fn nn_bitwise_matches_seed_order() {
        let mut rng = Pcg64::seed(1);
        for &(m, k, n) in SHAPES {
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
            let init: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
            let mut want = init.clone();
            ref_nn_acc(&a, m, k, &b, n, &mut want);
            let mut got = init.clone();
            gemm_nn_acc(&a, m, k, &b, n, &mut got);
            assert_eq!(want, got, "nn shape ({m},{k},{n})");
        }
    }

    #[test]
    fn nt_dot_bitwise_matches_dot_per_entry() {
        let mut rng = Pcg64::seed(2);
        for &(m, k, n) in SHAPES {
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
            let mut want = vec![0.0; m * n];
            for i in 0..m {
                for j in 0..n {
                    want[i * n + j] = dot(&a[i * k..(i + 1) * k], &b[j * k..(j + 1) * k]);
                }
            }
            let mut got = vec![0.0; m * n];
            gemm_nt_dot_into(&a, m, &b, n, k, &mut got);
            assert_eq!(want, got, "nt_dot shape ({m},{k},{n})");
            // The accumulate variant over a random initial c.
            let init: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
            let mut want_acc = init.clone();
            for i in 0..m {
                for j in 0..n {
                    want_acc[i * n + j] +=
                        dot(&a[i * k..(i + 1) * k], &b[j * k..(j + 1) * k]);
                }
            }
            let mut got_acc = init.clone();
            gemm_nt_dot_acc(&a, m, &b, n, k, &mut got_acc);
            assert_eq!(want_acc, got_acc, "nt_dot_acc shape ({m},{k},{n})");
        }
    }

    #[test]
    fn nt_seq_bitwise_matches_sequential_reduction() {
        let mut rng = Pcg64::seed(3);
        for &(m, k, n) in SHAPES {
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
            let mut want = vec![0.0; m * n];
            ref_nt_seq(&a, m, &b, n, k, &mut want);
            let mut got = vec![0.0; m * n];
            gemm_nt_seq_into(&a, m, &b, n, k, &mut got);
            assert_eq!(want, got, "nt_seq shape ({m},{k},{n})");
        }
    }

    #[test]
    fn tn_bitwise_matches_ascending_k() {
        let mut rng = Pcg64::seed(4);
        for &(m, k, n) in SHAPES {
            let a: Vec<f64> = (0..k * m).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
            let init: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
            let mut want = init.clone();
            ref_tn_acc(&a, k, m, &b, n, &mut want);
            let mut got = init.clone();
            gemm_tn_acc(&a, k, m, &b, n, &mut got);
            assert_eq!(want, got, "tn shape ({m},{k},{n})");
        }
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        // k = 0: products are empty sums; into-variants must still zero /
        // assign, acc-variants must leave c untouched.
        let mut c = vec![1.0, 2.0];
        gemm_nn_acc(&[], 1, 0, &[], 2, &mut c);
        assert_eq!(c, vec![1.0, 2.0]);
        gemm_nt_dot_into(&[], 1, &[], 2, 0, &mut c);
        assert_eq!(c, vec![0.0, 0.0]);
        let mut none: Vec<f64> = Vec::new();
        gemm_nn_acc(&[], 0, 3, &[0.0; 6], 2, &mut none);
        gemm_tn_acc(&[], 0, 0, &[], 4, &mut none);
        assert!(none.is_empty());
    }

    #[test]
    fn matvec_special_case_matches_dot() {
        // n = 1 is the projection path (Basis::project_into).
        let mut rng = Pcg64::seed(5);
        for k in [1usize, 3, 4, 31, 64, 130] {
            let m = 5;
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let v: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
            let mut got = vec![0.0; m];
            gemm_nt_dot_into(&a, m, &v, 1, k, &mut got);
            for i in 0..m {
                assert_eq!(got[i], dot(&a[i * k..(i + 1) * k], &v), "k={k} row {i}");
            }
        }
    }
}
