//! Register-tiled, cache-blocked matmul micro-kernels with a runtime
//! CPU-feature-dispatched SIMD backend.
//!
//! Every dense product on the hot paths — the analytic model's
//! sample-blocked evaluation ([`crate::score::analytic`]), the Gram
//! matrices of the thin SVD ([`crate::linalg::svd_right_vectors`]), PSD
//! square roots, batch covariances and the gFID metric — routes through
//! this family instead of per-row `dot` loops. The payoff is classical:
//! an MR×NR register tile amortizes every loaded element of one operand
//! across NR (resp. MR) multiply-adds, so arithmetic intensity rises from
//! ~1 FLOP/byte (stream one row, dot it, stream the next) to
//! ~MR·NR/(MR+NR) FLOPs per loaded element, and the k-panel loop keeps
//! the working set inside L1/L2 instead of re-streaming panels from
//! memory once per output row.
//!
//! # Kernel backends
//!
//! Each public kernel dispatches on a process-wide [`Backend`], selected
//! lazily on first use ([`backend`]):
//!
//! * [`Backend::Scalar`] — the portable loops in `mod scalar` below; the
//!   reference semantics every other backend must reproduce.
//! * [`Backend::Avx2`] — explicit `std::arch` x86-64 AVX2 kernels
//!   (`_mm256_*_pd`) that vectorize **across independent output entries /
//!   dot lanes, never within a single entry's reduction**. Each 64-bit
//!   vector lane carries exactly one scalar accumulator chain, advanced
//!   as `acc = add(acc, mul(a, b))` — two roundings per step, exactly
//!   like the scalar `acc += a * b` it replaces, with no FMA contraction
//!   (Rust/LLVM never contracts separate mul+add without fast-math). The
//!   four per-entry accumulator lanes of the dot-ordered kernels map onto
//!   one 256-bit vector; the ascending-k kernels spread the NR
//!   register-tile columns across vectors while each column's reduction
//!   stays a serial ascending-k chain in its own lane. The backend is
//!   therefore **bit-identical** to scalar, and the golden/parity suites
//!   (`tests/golden_trajectories.rs`, `tests/engine_parity.rs`,
//!   `tests/eval_blocked_parity.rs`, `tests/backend_parity.rs`) pin it
//!   with `assert_eq!`, not tolerances.
//! * [`Backend::Avx2Fma`] — opt-in reduced-rounding serving tier:
//!   identical loop structure, but each multiply-add contracts to
//!   `_mm256_fmadd_pd`. One rounding per madd instead of two, so results
//!   are (slightly, often *more* accurately) different bits. It is
//!   tolerance-tested in `tests/backend_parity.rs`, excluded from the
//!   golden fixtures, and never auto-selected — only
//!   `PAS_KERNEL=avx2fma` (or [`force_backend`]) turns it on.
//!
//! Selection order: `PAS_KERNEL=scalar|avx2|avx2fma` overrides
//! everything; otherwise auto-detection picks AVX2 iff the CPU reports
//! both `avx2` and `fma` (`is_x86_feature_detected!`). Requesting a SIMD
//! backend on hardware without the features logs a one-line warning and
//! falls back to scalar, so a misconfigured `PAS_KERNEL` can never
//! crash. [`force_backend`] re-pins the process-wide choice (used by the
//! bench sweeps); the `*_with` kernel variants take an explicit backend
//! argument without touching global state (used by the parity tests so
//! they can compare backends while golden tests run concurrently in the
//! same process). The active choice is observable: `pas serve` logs it at
//! startup and `{"cmd":"status"}` / health JSON report `kernel_backend`.
//!
//! # Determinism contract
//!
//! These kernels are **bit-compatible replacements**, not merely
//! numerically close ones. Tiling (and lane-level SIMD) only reorders
//! *which entry* is worked on when; the reduction order *within each
//! output entry* is pinned to the exact sequence of the scalar code each
//! kernel replaces:
//!
//! * [`gemm_nn_acc`] / [`gemm_tn_acc`] accumulate each entry strictly in
//!   ascending-k order — the order of the seed `matmul_acc` (and of every
//!   `c[i][j] += a· b` textbook loop in this crate). k-panel blocking is
//!   sound here because partial sums are carried in `c` between panels,
//!   which extends the same ascending chain.
//! * [`gemm_nt_dot_acc`] computes each entry with the 4-lane unrolled
//!   order of [`crate::tensor::dot`] (four independent accumulators over
//!   `k & !3`, combined as `(s0+s1)+(s2+s3)`, sequential tail). No
//!   k-blocking: the lane combine happens once per entry, so the lanes
//!   must span the whole reduction — our k never exceeds the data
//!   dimension (≤ a few hundred), so the a-panel stays cache-resident
//!   anyway. On AVX2 the four lanes *are* one `__m256d`; the horizontal
//!   combine is done in scalar f64 arithmetic in the exact same tree.
//! * [`gemm_nt_seq_into`] accumulates each entry with a single
//!   ascending-k chain (the order of the dense eigenbasis pass in
//!   `ModeEval::Full`).
//!
//! The engine-parity and golden-trajectory suites (and
//! `tests/eval_blocked_parity.rs`) pin this bitwise; the in-module tests
//! below pin each kernel against a scalar reference with `assert_eq!`
//! under whatever backend is active, and `tests/backend_parity.rs` pins
//! AVX2 ≡ scalar explicitly across edge tile shapes.
//!
//! # Tile sizes
//!
//! `MR=4 × NR=8` for the k-sequential kernels: 32 f64 accumulators fill
//! half the 16 × 256-bit vector registers of the baseline x86-64 target
//! (8 ymm), leaving room for the broadcast `a` value and a streamed `b`
//! row; the scalar inner loop is a textbook broadcast-multiply-add that
//! autovectorizes over the NR columns, and the AVX2 path issues the same
//! shape explicitly (two `__m256d` per tile row). The dot-ordered kernel
//! uses `MR=2 × NR=4` with a 4-wide lane accumulator per entry (8 ymm
//! total). `KC=256` k-panels keep an MR×KC `a` slab (8 KiB) and a KC×NR
//! `b` slab (16 KiB) simultaneously L1/L2-resident. Edge tiles fall back
//! to the same scalar loops with clamped bounds on every backend — order
//! per entry is unchanged, only fewer entries are in flight.
//!
//! All kernels (and the dispatch layer itself, after first selection)
//! read caller-owned inputs and write caller-owned output with **zero
//! heap allocations** — `tests/alloc_audit.rs` asserts this under a
//! counting global allocator, per backend.

use std::sync::atomic::{AtomicU8, Ordering};

/// Register-tile rows of the ascending-k kernels.
pub const MR: usize = 4;
/// Register-tile columns of the ascending-k kernels.
pub const NR: usize = 8;
/// k-panel depth (cache block) of the ascending-k kernels.
pub const KC: usize = 256;

/// Register-tile rows of the dot-ordered kernel.
pub const MR_DOT: usize = 2;
/// Register-tile columns of the dot-ordered kernel.
pub const NR_DOT: usize = 4;

/// Register-tile rows of the sequential-reduction kernel.
const MS: usize = 4;
/// Register-tile columns of the sequential-reduction kernel.
const NS: usize = 4;

// ---------------------------------------------------------------------------
// Backend selection
// ---------------------------------------------------------------------------

/// Kernel backend identifier. Discriminants are the values stored in the
/// process-wide selection atomic (0 is reserved for "not yet selected").
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(u8)]
pub enum Backend {
    /// Portable scalar loops — the reference semantics.
    Scalar = 1,
    /// Explicit AVX2, bit-identical to scalar (mul + add, no FMA).
    Avx2 = 2,
    /// Explicit AVX2 with FMA contraction — reduced-rounding, *not*
    /// bit-identical; opt-in only.
    Avx2Fma = 3,
}

impl Backend {
    /// All backends, in fallback order (used by the bench sweeps).
    pub const ALL: [Backend; 3] = [Backend::Scalar, Backend::Avx2, Backend::Avx2Fma];

    /// Stable lowercase name (the `PAS_KERNEL` vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Avx2 => "avx2",
            Backend::Avx2Fma => "avx2fma",
        }
    }

    /// Inverse of [`Backend::name`].
    pub fn parse(s: &str) -> Option<Backend> {
        match s {
            "scalar" => Some(Backend::Scalar),
            "avx2" => Some(Backend::Avx2),
            "avx2fma" => Some(Backend::Avx2Fma),
            _ => None,
        }
    }

    /// Whether this backend is bit-identical to [`Backend::Scalar`]
    /// (everything except the FMA tier). Golden-fixture suites must only
    /// run under bit-identical backends.
    pub fn bit_identical(self) -> bool {
        self != Backend::Avx2Fma
    }

    fn from_u8(v: u8) -> Backend {
        match v {
            2 => Backend::Avx2,
            3 => Backend::Avx2Fma,
            _ => Backend::Scalar,
        }
    }
}

/// Process-wide selected backend; 0 = not yet selected.
static ACTIVE: AtomicU8 = AtomicU8::new(0);

/// Whether the SIMD backends can run on this machine (x86-64 with AVX2
/// and FMA). Feature detection caches its result internally and does not
/// allocate.
#[cfg(target_arch = "x86_64")]
pub fn simd_available() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

/// Whether the SIMD backends can run on this machine (x86-64 with AVX2
/// and FMA).
#[cfg(not(target_arch = "x86_64"))]
pub fn simd_available() -> bool {
    false
}

/// The backend the hardware supports by default: AVX2 when available,
/// scalar otherwise. The FMA tier is never auto-selected — it changes
/// bits, so it must be asked for.
fn auto_backend() -> Backend {
    if simd_available() {
        Backend::Avx2
    } else {
        Backend::Scalar
    }
}

/// Clamp a requested backend to what the hardware can run, warning on
/// downgrade so a misdispatched binary is diagnosable from its logs.
fn resolve(req: Backend) -> Backend {
    match req {
        Backend::Scalar => Backend::Scalar,
        Backend::Avx2 | Backend::Avx2Fma => {
            if simd_available() {
                req
            } else {
                eprintln!(
                    "pas: kernel backend {:?} requested but CPU lacks avx2+fma; using scalar",
                    req.name()
                );
                Backend::Scalar
            }
        }
    }
}

/// First-use selection: honor `PAS_KERNEL` if set and valid, otherwise
/// auto-detect. Called at most a handful of times per process (races on
/// first use all compute the same answer); allocation here is outside
/// every steady-state window.
fn select_backend() -> Backend {
    match std::env::var("PAS_KERNEL") {
        Ok(v) => {
            let v = v.trim();
            match Backend::parse(v) {
                Some(b) => resolve(b),
                None => {
                    if !v.is_empty() {
                        eprintln!(
                            "pas: unknown PAS_KERNEL value {v:?} (expected scalar|avx2|avx2fma); auto-selecting"
                        );
                    }
                    auto_backend()
                }
            }
        }
        Err(_) => auto_backend(),
    }
}

/// The process-wide active kernel backend, selecting it on first call.
/// Steady-state this is one relaxed atomic load.
pub fn backend() -> Backend {
    let v = ACTIVE.load(Ordering::Relaxed);
    if v != 0 {
        return Backend::from_u8(v);
    }
    let b = select_backend();
    ACTIVE.store(b as u8, Ordering::Relaxed);
    b
}

/// Stable name of the active backend (for logs / status / metrics).
pub fn backend_name() -> &'static str {
    backend().name()
}

/// Re-pin the process-wide backend, clamped to hardware support; returns
/// the backend actually installed. Bench sweeps use this to exercise each
/// backend through the full (non-`_with`) call graph. Tests should prefer
/// the `*_with` kernel variants, which don't touch global state.
pub fn force_backend(req: Backend) -> Backend {
    let b = resolve(req);
    ACTIVE.store(b as u8, Ordering::Relaxed);
    b
}

/// Route one kernel call to the active backend's implementation. SIMD
/// arms are compiled only on x86-64 and guarded by runtime feature
/// detection, so reaching an `unsafe` SIMD entry point implies the
/// required CPU features are present (its only safety condition).
macro_rules! dispatch {
    ($be:expr, $scalar:expr, $avx2:expr, $fma:expr) => {
        match $be {
            #[cfg(target_arch = "x86_64")]
            // SAFETY: arm is reachable only when `simd_available()`
            // confirmed AVX2(+FMA) at runtime — the sole precondition of
            // the `#[target_feature]` kernels it calls.
            Backend::Avx2 if simd_available() => unsafe { $avx2 },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: same runtime-detection guard as the Avx2 arm.
            Backend::Avx2Fma if simd_available() => unsafe { $fma },
            _ => $scalar,
        }
    };
}

// ---------------------------------------------------------------------------
// Public dispatched kernels
// ---------------------------------------------------------------------------

/// `c[m,n] += a[m,k] * b[k,n]`, all row-major. Bit-identical to the seed
/// `matmul_acc` loop nest: each output entry accumulates in ascending-k
/// order. Dispatches on the active [`backend`].
pub fn gemm_nn_acc(a: &[f64], m: usize, k: usize, b: &[f64], n: usize, c: &mut [f64]) {
    gemm_nn_acc_with(backend(), a, m, k, b, n, c);
}

/// [`gemm_nn_acc`] on an explicit backend (no global state).
pub fn gemm_nn_acc_with(
    be: Backend,
    a: &[f64],
    m: usize,
    k: usize,
    b: &[f64],
    n: usize,
    c: &mut [f64],
) {
    dispatch!(
        be,
        scalar::gemm_nn_acc(a, m, k, b, n, c),
        avx2::exact::gemm_nn_acc(a, m, k, b, n, c),
        avx2::fma::gemm_nn_acc(a, m, k, b, n, c)
    )
}

/// `c = a * b` (zeroes `c`, then [`gemm_nn_acc`]).
pub fn gemm_nn_into(a: &[f64], m: usize, k: usize, b: &[f64], n: usize, c: &mut [f64]) {
    c.fill(0.0);
    gemm_nn_acc(a, m, k, b, n, c);
}

/// [`gemm_nn_into`] on an explicit backend (no global state).
pub fn gemm_nn_into_with(
    be: Backend,
    a: &[f64],
    m: usize,
    k: usize,
    b: &[f64],
    n: usize,
    c: &mut [f64],
) {
    c.fill(0.0);
    gemm_nn_acc_with(be, a, m, k, b, n, c);
}

/// `c[m,n] += a[m,k] * b[n,k]ᵀ` — i.e. `c[i][j] += dot(a_i, b_j)` with
/// each entry reduced in **exactly** the 4-lane order of
/// [`crate::tensor::dot`]. This is the Gram-matrix / projection /
/// eigenbasis-forward kernel: the register tile loads each `a` panel once
/// for [`NR_DOT`] columns and each `b` panel once for [`MR_DOT`] rows.
/// Dispatches on the active [`backend`].
pub fn gemm_nt_dot_acc(a: &[f64], m: usize, b: &[f64], n: usize, k: usize, c: &mut [f64]) {
    gemm_nt_dot_acc_with(backend(), a, m, b, n, k, c);
}

/// [`gemm_nt_dot_acc`] on an explicit backend (no global state).
pub fn gemm_nt_dot_acc_with(
    be: Backend,
    a: &[f64],
    m: usize,
    b: &[f64],
    n: usize,
    k: usize,
    c: &mut [f64],
) {
    dispatch!(
        be,
        scalar::nt_dot_kernel::<true>(a, m, b, n, k, c),
        avx2::exact::gemm_nt_dot(a, m, b, n, k, c, true),
        avx2::fma::gemm_nt_dot(a, m, b, n, k, c, true)
    )
}

/// `c[m,n] = a[m,k] * b[n,k]ᵀ` in [`crate::tensor::dot`] order — assign
/// semantics, bit-identical to `c[i][j] = dot(a_i, b_j)` per entry
/// (including a `-0.0` dot result, which `0.0 + s` would lose).
/// Dispatches on the active [`backend`].
pub fn gemm_nt_dot_into(a: &[f64], m: usize, b: &[f64], n: usize, k: usize, c: &mut [f64]) {
    gemm_nt_dot_into_with(backend(), a, m, b, n, k, c);
}

/// [`gemm_nt_dot_into`] on an explicit backend (no global state).
pub fn gemm_nt_dot_into_with(
    be: Backend,
    a: &[f64],
    m: usize,
    b: &[f64],
    n: usize,
    k: usize,
    c: &mut [f64],
) {
    dispatch!(
        be,
        scalar::nt_dot_kernel::<false>(a, m, b, n, k, c),
        avx2::exact::gemm_nt_dot(a, m, b, n, k, c, false),
        avx2::fma::gemm_nt_dot(a, m, b, n, k, c, false)
    )
}

/// `c[m,n] = a[m,k] * b[n,k]ᵀ` with each entry reduced by a **single
/// ascending-k chain** (`s += a[i][p] * b[j][p]`, p = 0..k) — the order
/// of the dense `ModeEval::Full` eigenbasis pass. MS×NS = 4×4 register
/// tile: 16 independent scalar chains pipeline the FP-add latency even
/// though each chain is serial. Dispatches on the active [`backend`].
pub fn gemm_nt_seq_into(a: &[f64], m: usize, b: &[f64], n: usize, k: usize, c: &mut [f64]) {
    gemm_nt_seq_into_with(backend(), a, m, b, n, k, c);
}

/// [`gemm_nt_seq_into`] on an explicit backend (no global state).
pub fn gemm_nt_seq_into_with(
    be: Backend,
    a: &[f64],
    m: usize,
    b: &[f64],
    n: usize,
    k: usize,
    c: &mut [f64],
) {
    dispatch!(
        be,
        scalar::gemm_nt_seq_into(a, m, b, n, k, c),
        avx2::exact::gemm_nt_seq_into(a, m, b, n, k, c),
        avx2::fma::gemm_nt_seq_into(a, m, b, n, k, c)
    )
}

/// `c[m,n] += a[k,m]ᵀ * b[k,n]` — the rank-k update kernel (batch
/// covariance `Cᵀ C`, eigen reconstruction `Vᵀ diag(s) V`). Each entry
/// accumulates in ascending-k order; the register tile turns the
/// per-sample rank-1 update loop into MR×NR outer-product multiply-adds
/// per loaded panel. Dispatches on the active [`backend`].
pub fn gemm_tn_acc(a: &[f64], k: usize, m: usize, b: &[f64], n: usize, c: &mut [f64]) {
    gemm_tn_acc_with(backend(), a, k, m, b, n, c);
}

/// [`gemm_tn_acc`] on an explicit backend (no global state).
pub fn gemm_tn_acc_with(
    be: Backend,
    a: &[f64],
    k: usize,
    m: usize,
    b: &[f64],
    n: usize,
    c: &mut [f64],
) {
    dispatch!(
        be,
        scalar::gemm_tn_acc(a, k, m, b, n, c),
        avx2::exact::gemm_tn_acc(a, k, m, b, n, c),
        avx2::fma::gemm_tn_acc(a, k, m, b, n, c)
    )
}

// ---------------------------------------------------------------------------
// Shared scalar micro-kernels (edge tiles on every backend)
// ---------------------------------------------------------------------------

/// MR×NR block of `c += a·b`, k-panel `[p0, p0+pc)`. Partial sums are
/// carried in `c` across panels, so per-entry addition order stays a
/// single ascending-k chain.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn nn_micro(
    a: &[f64],
    k: usize,
    b: &[f64],
    n: usize,
    c: &mut [f64],
    i0: usize,
    j0: usize,
    p0: usize,
    pc: usize,
    mr: usize,
    nr: usize,
) {
    if mr == MR && nr == NR {
        // Full tile: constant bounds so the column loop vectorizes.
        let mut acc = [[0.0f64; NR]; MR];
        for (ir, row) in acc.iter_mut().enumerate() {
            let crow = &c[(i0 + ir) * n + j0..(i0 + ir) * n + j0 + NR];
            row.copy_from_slice(crow);
        }
        for p in p0..p0 + pc {
            let brow = &b[p * n + j0..p * n + j0 + NR];
            for (ir, row) in acc.iter_mut().enumerate() {
                let av = a[(i0 + ir) * k + p];
                for (jr, cv) in row.iter_mut().enumerate() {
                    *cv += av * brow[jr];
                }
            }
        }
        for (ir, row) in acc.iter().enumerate() {
            let crow = &mut c[(i0 + ir) * n + j0..(i0 + ir) * n + j0 + NR];
            crow.copy_from_slice(row);
        }
    } else {
        // Edge tile: same loops, clamped bounds.
        let mut acc = [[0.0f64; NR]; MR];
        for ir in 0..mr {
            for jr in 0..nr {
                acc[ir][jr] = c[(i0 + ir) * n + j0 + jr];
            }
        }
        for p in p0..p0 + pc {
            let brow = &b[p * n + j0..p * n + j0 + nr];
            for (ir, row) in acc.iter_mut().enumerate().take(mr) {
                let av = a[(i0 + ir) * k + p];
                for jr in 0..nr {
                    row[jr] += av * brow[jr];
                }
            }
        }
        for ir in 0..mr {
            for jr in 0..nr {
                c[(i0 + ir) * n + j0 + jr] = acc[ir][jr];
            }
        }
    }
}

/// MR×NR block of the rank-k update `c += aᵀ·b`, k-panel `[p0, p0+pc)`,
/// clamped bounds. Ascending-k per entry, partial sums carried in `c`.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn tn_micro(
    a: &[f64],
    m: usize,
    b: &[f64],
    n: usize,
    c: &mut [f64],
    i0: usize,
    j0: usize,
    p0: usize,
    pc: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc = [[0.0f64; NR]; MR];
    for ir in 0..mr {
        for jr in 0..nr {
            acc[ir][jr] = c[(i0 + ir) * n + j0 + jr];
        }
    }
    for p in p0..p0 + pc {
        let brow = &b[p * n + j0..p * n + j0 + nr];
        for (ir, row) in acc.iter_mut().enumerate().take(mr) {
            let av = a[p * m + i0 + ir];
            for jr in 0..nr {
                row[jr] += av * brow[jr];
            }
        }
    }
    for ir in 0..mr {
        for jr in 0..nr {
            c[(i0 + ir) * n + j0 + jr] = acc[ir][jr];
        }
    }
}

/// MS×NS block of the sequential-reduction `c = a·bᵀ`, clamped bounds.
/// Single ascending-k chain per entry, assign store.
#[inline(always)]
#[allow(clippy::too_many_arguments)]
fn nt_seq_micro(
    a: &[f64],
    b: &[f64],
    n: usize,
    k: usize,
    c: &mut [f64],
    i0: usize,
    j0: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc = [[0.0f64; NS]; MS];
    for p in 0..k {
        for (ir, row) in acc.iter_mut().enumerate().take(mr) {
            let av = a[(i0 + ir) * k + p];
            for (jr, cv) in row.iter_mut().enumerate().take(nr) {
                *cv += av * b[(j0 + jr) * k + p];
            }
        }
    }
    for ir in 0..mr {
        for jr in 0..nr {
            c[(i0 + ir) * n + j0 + jr] = acc[ir][jr];
        }
    }
}

// ---------------------------------------------------------------------------
// Scalar backend — the portable reference loops
// ---------------------------------------------------------------------------

mod scalar {
    use super::{nn_micro, nt_seq_micro, tn_micro, KC, MR, MR_DOT, MS, NR, NR_DOT, NS};

    pub fn gemm_nn_acc(a: &[f64], m: usize, k: usize, b: &[f64], n: usize, c: &mut [f64]) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        let mut p0 = 0;
        while p0 < k {
            let pc = KC.min(k - p0);
            let mut i0 = 0;
            while i0 < m {
                let mr = MR.min(m - i0);
                let mut j0 = 0;
                while j0 < n {
                    let nr = NR.min(n - j0);
                    nn_micro(a, k, b, n, c, i0, j0, p0, pc, mr, nr);
                    j0 += NR;
                }
                i0 += MR;
            }
            p0 += KC;
        }
    }

    /// Shared dot-order kernel; `ACC` selects accumulate (`+=`) vs assign
    /// (`=`) on the final per-entry store — everything else, including
    /// the debug shape checks, lives here once.
    pub fn nt_dot_kernel<const ACC: bool>(
        a: &[f64],
        m: usize,
        b: &[f64],
        n: usize,
        k: usize,
        c: &mut [f64],
    ) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        debug_assert_eq!(c.len(), m * n);
        let k4 = k & !3;
        let mut i0 = 0;
        while i0 < m {
            let mr = MR_DOT.min(m - i0);
            let mut j0 = 0;
            while j0 < n {
                let nr = NR_DOT.min(n - j0);
                // One 4-wide lane accumulator per entry: lane l holds the
                // partial sum over indices ≡ l (mod 4), exactly dot's s0..s3.
                let mut lanes = [[[0.0f64; 4]; NR_DOT]; MR_DOT];
                let mut p = 0;
                while p < k4 {
                    for (ir, lrow) in lanes.iter_mut().enumerate().take(mr) {
                        let ap = &a[(i0 + ir) * k + p..(i0 + ir) * k + p + 4];
                        for (jr, lv) in lrow.iter_mut().enumerate().take(nr) {
                            let bp = &b[(j0 + jr) * k + p..(j0 + jr) * k + p + 4];
                            for l in 0..4 {
                                lv[l] += ap[l] * bp[l];
                            }
                        }
                    }
                    p += 4;
                }
                for ir in 0..mr {
                    let arow = &a[(i0 + ir) * k..(i0 + ir) * k + k];
                    for jr in 0..nr {
                        let brow = &b[(j0 + jr) * k..(j0 + jr) * k + k];
                        let lv = &lanes[ir][jr];
                        let mut s = (lv[0] + lv[1]) + (lv[2] + lv[3]);
                        let mut p = k4;
                        while p < k {
                            s += arow[p] * brow[p];
                            p += 1;
                        }
                        if ACC {
                            c[(i0 + ir) * n + j0 + jr] += s;
                        } else {
                            c[(i0 + ir) * n + j0 + jr] = s;
                        }
                    }
                }
                j0 += NR_DOT;
            }
            i0 += MR_DOT;
        }
    }

    pub fn gemm_nt_seq_into(a: &[f64], m: usize, b: &[f64], n: usize, k: usize, c: &mut [f64]) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), n * k);
        debug_assert_eq!(c.len(), m * n);
        let mut i0 = 0;
        while i0 < m {
            let mr = MS.min(m - i0);
            let mut j0 = 0;
            while j0 < n {
                let nr = NS.min(n - j0);
                nt_seq_micro(a, b, n, k, c, i0, j0, mr, nr);
                j0 += NS;
            }
            i0 += MS;
        }
    }

    pub fn gemm_tn_acc(a: &[f64], k: usize, m: usize, b: &[f64], n: usize, c: &mut [f64]) {
        debug_assert_eq!(a.len(), k * m);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        let mut p0 = 0;
        while p0 < k {
            let pc = KC.min(k - p0);
            let mut i0 = 0;
            while i0 < m {
                let mr = MR.min(m - i0);
                let mut j0 = 0;
                while j0 < n {
                    let nr = NR.min(n - j0);
                    tn_micro(a, m, b, n, c, i0, j0, p0, pc, mr, nr);
                    j0 += NR;
                }
                i0 += MR;
            }
            p0 += KC;
        }
    }
}

// ---------------------------------------------------------------------------
// AVX2 backend — lane-per-entry vectorization, stamped in two tiers
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! Explicit AVX2 kernels. The `exact` submodule advances each lane as
    //! `add(acc, mul(a, b))` — per-lane bit-identical to the scalar
    //! `acc += a * b` — while `fma` contracts to `fmadd(a, b, acc)`.
    //! Everything else (loop structure, edge-tile fallbacks to the shared
    //! scalar micro-kernels, the per-entry reduction orders) is stamped
    //! identically from one macro body.
    //!
    //! # Safety
    //!
    //! Every kernel here is `#[target_feature(enable = "avx2,fma")]` and
    //! thus `unsafe fn`: the caller must guarantee the CPU supports AVX2
    //! and FMA. The dispatch macro in the parent module guards every call
    //! with `simd_available()`. All memory accesses stay in bounds by the
    //! same tile arithmetic as the scalar loops (full tiles only where
    //! `i0+MR ≤ m` and `j0+NR ≤ n`; vector loads of 4 only where
    //! `p + 4 ≤ k4 ≤ k`).

    /// Stamp one kernel-family tier. `$madd` is the multiply-add policy:
    /// per lane, `exact` computes `acc + a*b` with two roundings (scalar
    /// order), `fma` computes `fma(a, b, acc)` with one.
    macro_rules! avx2_variant {
        ($name:ident, |$acc:ident, $av:ident, $bv:ident| $madd:expr) => {
            pub mod $name {
                use crate::tensor::gemm::{nn_micro, nt_seq_micro, tn_micro};
                use crate::tensor::gemm::{KC, MR, MR_DOT, MS, NR, NR_DOT, NS};
                use std::arch::x86_64::*;

                /// The tier's lane-wise multiply-add policy.
                ///
                /// # Safety
                /// CPU must support AVX2 and FMA (unsafe only via
                /// `#[target_feature]`; the intrinsics are pure lane math).
                #[inline]
                #[target_feature(enable = "avx2,fma")]
                unsafe fn madd($acc: __m256d, $av: __m256d, $bv: __m256d) -> __m256d {
                    $madd
                }

                /// `c += a·b`, seed ascending-k order, vectorized across
                /// the NR register-tile columns (two `__m256d` per tile
                /// row, one serial reduction chain per lane).
                ///
                /// # Safety
                /// CPU must support AVX2 and FMA.
                #[target_feature(enable = "avx2,fma")]
                pub unsafe fn gemm_nn_acc(
                    a: &[f64],
                    m: usize,
                    k: usize,
                    b: &[f64],
                    n: usize,
                    c: &mut [f64],
                ) {
                    debug_assert_eq!(a.len(), m * k);
                    debug_assert_eq!(b.len(), k * n);
                    debug_assert_eq!(c.len(), m * n);
                    let mut p0 = 0;
                    while p0 < k {
                        let pc = KC.min(k - p0);
                        let mut i0 = 0;
                        while i0 < m {
                            let mr = MR.min(m - i0);
                            let mut j0 = 0;
                            while j0 < n {
                                let nr = NR.min(n - j0);
                                if mr == MR && nr == NR {
                                    nn_tile(a, k, b, n, c, i0, j0, p0, pc);
                                } else {
                                    nn_micro(a, k, b, n, c, i0, j0, p0, pc, mr, nr);
                                }
                                j0 += NR;
                            }
                            i0 += MR;
                        }
                        p0 += KC;
                    }
                }

                /// Full MR×NR tile of [`gemm_nn_acc`].
                ///
                /// # Safety
                /// CPU must support AVX2/FMA; `i0+MR ≤ m`, `j0+NR ≤ n`.
                #[target_feature(enable = "avx2,fma")]
                #[allow(clippy::too_many_arguments)]
                unsafe fn nn_tile(
                    a: &[f64],
                    k: usize,
                    b: &[f64],
                    n: usize,
                    c: &mut [f64],
                    i0: usize,
                    j0: usize,
                    p0: usize,
                    pc: usize,
                ) {
                    let mut acc = [[_mm256_setzero_pd(); 2]; MR];
                    for (ir, row) in acc.iter_mut().enumerate() {
                        let base = (i0 + ir) * n + j0;
                        row[0] = _mm256_loadu_pd(c.as_ptr().add(base));
                        row[1] = _mm256_loadu_pd(c.as_ptr().add(base + 4));
                    }
                    for p in p0..p0 + pc {
                        let bbase = p * n + j0;
                        let b0 = _mm256_loadu_pd(b.as_ptr().add(bbase));
                        let b1 = _mm256_loadu_pd(b.as_ptr().add(bbase + 4));
                        for (ir, row) in acc.iter_mut().enumerate() {
                            let av = _mm256_set1_pd(a[(i0 + ir) * k + p]);
                            row[0] = madd(row[0], av, b0);
                            row[1] = madd(row[1], av, b1);
                        }
                    }
                    for (ir, row) in acc.iter().enumerate() {
                        let base = (i0 + ir) * n + j0;
                        _mm256_storeu_pd(c.as_mut_ptr().add(base), row[0]);
                        _mm256_storeu_pd(c.as_mut_ptr().add(base + 4), row[1]);
                    }
                }

                /// `c[i][j] (+)= dot(a_i, b_j)` in [`crate::tensor::dot`]
                /// lane order: the four per-entry accumulator lanes are
                /// one `__m256d`; the horizontal combine and the `k % 4`
                /// tail run in scalar f64 in the exact scalar tree.
                /// `acc` selects `+=` vs `=` on the final store.
                ///
                /// # Safety
                /// CPU must support AVX2 and FMA.
                #[target_feature(enable = "avx2,fma")]
                #[allow(clippy::too_many_arguments)]
                pub unsafe fn gemm_nt_dot(
                    a: &[f64],
                    m: usize,
                    b: &[f64],
                    n: usize,
                    k: usize,
                    c: &mut [f64],
                    acc: bool,
                ) {
                    debug_assert_eq!(a.len(), m * k);
                    debug_assert_eq!(b.len(), n * k);
                    debug_assert_eq!(c.len(), m * n);
                    let k4 = k & !3;
                    let mut i0 = 0;
                    while i0 < m {
                        let mr = MR_DOT.min(m - i0);
                        let mut j0 = 0;
                        while j0 < n {
                            let nr = NR_DOT.min(n - j0);
                            let mut lanes = [[_mm256_setzero_pd(); NR_DOT]; MR_DOT];
                            let mut p = 0;
                            while p < k4 {
                                for (ir, lrow) in lanes.iter_mut().enumerate().take(mr) {
                                    let ap = _mm256_loadu_pd(a.as_ptr().add((i0 + ir) * k + p));
                                    for (jr, lv) in lrow.iter_mut().enumerate().take(nr) {
                                        let bp =
                                            _mm256_loadu_pd(b.as_ptr().add((j0 + jr) * k + p));
                                        *lv = madd(*lv, ap, bp);
                                    }
                                }
                                p += 4;
                            }
                            for ir in 0..mr {
                                let arow = &a[(i0 + ir) * k..(i0 + ir) * k + k];
                                for jr in 0..nr {
                                    let brow = &b[(j0 + jr) * k..(j0 + jr) * k + k];
                                    let mut lv = [0.0f64; 4];
                                    _mm256_storeu_pd(lv.as_mut_ptr(), lanes[ir][jr]);
                                    let mut s = (lv[0] + lv[1]) + (lv[2] + lv[3]);
                                    let mut q = k4;
                                    while q < k {
                                        s += arow[q] * brow[q];
                                        q += 1;
                                    }
                                    let cv = &mut c[(i0 + ir) * n + j0 + jr];
                                    if acc {
                                        *cv += s;
                                    } else {
                                        *cv = s;
                                    }
                                }
                            }
                            j0 += NR_DOT;
                        }
                        i0 += MR_DOT;
                    }
                }

                /// `c = a·bᵀ`, single ascending-k chain per entry,
                /// vectorized across the NS tile columns (strided gather
                /// of the `b` column, broadcast `a`).
                ///
                /// # Safety
                /// CPU must support AVX2 and FMA.
                #[target_feature(enable = "avx2,fma")]
                pub unsafe fn gemm_nt_seq_into(
                    a: &[f64],
                    m: usize,
                    b: &[f64],
                    n: usize,
                    k: usize,
                    c: &mut [f64],
                ) {
                    debug_assert_eq!(a.len(), m * k);
                    debug_assert_eq!(b.len(), n * k);
                    debug_assert_eq!(c.len(), m * n);
                    let mut i0 = 0;
                    while i0 < m {
                        let mr = MS.min(m - i0);
                        let mut j0 = 0;
                        while j0 < n {
                            let nr = NS.min(n - j0);
                            if mr == MS && nr == NS {
                                nt_seq_tile(a, b, n, k, c, i0, j0);
                            } else {
                                nt_seq_micro(a, b, n, k, c, i0, j0, mr, nr);
                            }
                            j0 += NS;
                        }
                        i0 += MS;
                    }
                }

                /// Full MS×NS tile of [`gemm_nt_seq_into`].
                ///
                /// # Safety
                /// CPU must support AVX2/FMA; `i0+MS ≤ m`, `j0+NS ≤ n`.
                #[target_feature(enable = "avx2,fma")]
                unsafe fn nt_seq_tile(
                    a: &[f64],
                    b: &[f64],
                    n: usize,
                    k: usize,
                    c: &mut [f64],
                    i0: usize,
                    j0: usize,
                ) {
                    let mut acc = [_mm256_setzero_pd(); MS];
                    for p in 0..k {
                        let bcol = _mm256_setr_pd(
                            b[j0 * k + p],
                            b[(j0 + 1) * k + p],
                            b[(j0 + 2) * k + p],
                            b[(j0 + 3) * k + p],
                        );
                        for (ir, accv) in acc.iter_mut().enumerate() {
                            let av = _mm256_set1_pd(a[(i0 + ir) * k + p]);
                            *accv = madd(*accv, av, bcol);
                        }
                    }
                    for (ir, accv) in acc.iter().enumerate() {
                        _mm256_storeu_pd(c.as_mut_ptr().add((i0 + ir) * n + j0), *accv);
                    }
                }

                /// `c += aᵀ·b` rank-k update, seed ascending-k order,
                /// vectorized across the NR register-tile columns.
                ///
                /// # Safety
                /// CPU must support AVX2 and FMA.
                #[target_feature(enable = "avx2,fma")]
                pub unsafe fn gemm_tn_acc(
                    a: &[f64],
                    k: usize,
                    m: usize,
                    b: &[f64],
                    n: usize,
                    c: &mut [f64],
                ) {
                    debug_assert_eq!(a.len(), k * m);
                    debug_assert_eq!(b.len(), k * n);
                    debug_assert_eq!(c.len(), m * n);
                    let mut p0 = 0;
                    while p0 < k {
                        let pc = KC.min(k - p0);
                        let mut i0 = 0;
                        while i0 < m {
                            let mr = MR.min(m - i0);
                            let mut j0 = 0;
                            while j0 < n {
                                let nr = NR.min(n - j0);
                                if mr == MR && nr == NR {
                                    tn_tile(a, m, b, n, c, i0, j0, p0, pc);
                                } else {
                                    tn_micro(a, m, b, n, c, i0, j0, p0, pc, mr, nr);
                                }
                                j0 += NR;
                            }
                            i0 += MR;
                        }
                        p0 += KC;
                    }
                }

                /// Full MR×NR tile of [`gemm_tn_acc`].
                ///
                /// # Safety
                /// CPU must support AVX2/FMA; `i0+MR ≤ m`, `j0+NR ≤ n`.
                #[target_feature(enable = "avx2,fma")]
                #[allow(clippy::too_many_arguments)]
                unsafe fn tn_tile(
                    a: &[f64],
                    m: usize,
                    b: &[f64],
                    n: usize,
                    c: &mut [f64],
                    i0: usize,
                    j0: usize,
                    p0: usize,
                    pc: usize,
                ) {
                    let mut acc = [[_mm256_setzero_pd(); 2]; MR];
                    for (ir, row) in acc.iter_mut().enumerate() {
                        let base = (i0 + ir) * n + j0;
                        row[0] = _mm256_loadu_pd(c.as_ptr().add(base));
                        row[1] = _mm256_loadu_pd(c.as_ptr().add(base + 4));
                    }
                    for p in p0..p0 + pc {
                        let bbase = p * n + j0;
                        let b0 = _mm256_loadu_pd(b.as_ptr().add(bbase));
                        let b1 = _mm256_loadu_pd(b.as_ptr().add(bbase + 4));
                        for (ir, row) in acc.iter_mut().enumerate() {
                            let av = _mm256_set1_pd(a[p * m + i0 + ir]);
                            row[0] = madd(row[0], av, b0);
                            row[1] = madd(row[1], av, b1);
                        }
                    }
                    for (ir, row) in acc.iter().enumerate() {
                        let base = (i0 + ir) * n + j0;
                        _mm256_storeu_pd(c.as_mut_ptr().add(base), row[0]);
                        _mm256_storeu_pd(c.as_mut_ptr().add(base + 4), row[1]);
                    }
                }
            }
        };
    }

    // lint:allow(simd-gating, closure body is stamped into the tier's #[target_feature] madd fn)
    avx2_variant!(exact, |acc, av, bv| _mm256_add_pd(acc, _mm256_mul_pd(av, bv)));
    // lint:allow(simd-gating, closure body is stamped into the tier's #[target_feature] madd fn; fmadd token is the fma tier itself)
    avx2_variant!(fma, |acc, av, bv| _mm256_fmadd_pd(av, bv, acc));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dot;
    use crate::util::rng::Pcg64;

    /// The seed `matmul_acc` loop nest, verbatim: the bit-exactness
    /// reference for the ascending-k kernels.
    fn ref_nn_acc(a: &[f64], m: usize, k: usize, b: &[f64], n: usize, c: &mut [f64]) {
        for i in 0..m {
            for p in 0..k {
                let av = a[i * k + p];
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j];
                }
            }
        }
    }

    fn ref_tn_acc(a: &[f64], k: usize, m: usize, b: &[f64], n: usize, c: &mut [f64]) {
        for p in 0..k {
            for i in 0..m {
                let av = a[p * m + i];
                for j in 0..n {
                    c[i * n + j] += av * b[p * n + j];
                }
            }
        }
    }

    fn ref_nt_seq(a: &[f64], m: usize, b: &[f64], n: usize, k: usize, c: &mut [f64]) {
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a[i * k + p] * b[j * k + p];
                }
                c[i * n + j] = s;
            }
        }
    }

    /// Shapes straddling every tile boundary: 1, MR-1, MR, MR+1, several
    /// tiles plus a remainder, and k values around the 4-lane width and
    /// the KC panel.
    const SHAPES: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 9, 3),
        (3, 7, 5),
        (4, 8, 4),
        (5, 9, 17),
        (8, 16, 64),
        (13, 11, 257),
        (16, 3, 300),
    ];

    // The bitwise tests below exercise the *dispatched* public kernels, so
    // whatever backend `PAS_KERNEL` (or auto-detection) selects for this
    // test process is pinned against the scalar references. CI runs them
    // under both PAS_KERNEL=scalar and PAS_KERNEL=avx2.

    #[test]
    fn nn_bitwise_matches_seed_order() {
        let mut rng = Pcg64::seed(1);
        for &(m, k, n) in SHAPES {
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
            let init: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
            let mut want = init.clone();
            ref_nn_acc(&a, m, k, &b, n, &mut want);
            let mut got = init.clone();
            gemm_nn_acc(&a, m, k, &b, n, &mut got);
            assert_eq!(want, got, "nn shape ({m},{k},{n})");
        }
    }

    #[test]
    fn nt_dot_bitwise_matches_dot_per_entry() {
        let mut rng = Pcg64::seed(2);
        for &(m, k, n) in SHAPES {
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
            let mut want = vec![0.0; m * n];
            for i in 0..m {
                for j in 0..n {
                    want[i * n + j] = dot(&a[i * k..(i + 1) * k], &b[j * k..(j + 1) * k]);
                }
            }
            let mut got = vec![0.0; m * n];
            gemm_nt_dot_into(&a, m, &b, n, k, &mut got);
            assert_eq!(want, got, "nt_dot shape ({m},{k},{n})");
            // The accumulate variant over a random initial c.
            let init: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
            let mut want_acc = init.clone();
            for i in 0..m {
                for j in 0..n {
                    want_acc[i * n + j] +=
                        dot(&a[i * k..(i + 1) * k], &b[j * k..(j + 1) * k]);
                }
            }
            let mut got_acc = init.clone();
            gemm_nt_dot_acc(&a, m, &b, n, k, &mut got_acc);
            assert_eq!(want_acc, got_acc, "nt_dot_acc shape ({m},{k},{n})");
        }
    }

    #[test]
    fn nt_seq_bitwise_matches_sequential_reduction() {
        let mut rng = Pcg64::seed(3);
        for &(m, k, n) in SHAPES {
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
            let mut want = vec![0.0; m * n];
            ref_nt_seq(&a, m, &b, n, k, &mut want);
            let mut got = vec![0.0; m * n];
            gemm_nt_seq_into(&a, m, &b, n, k, &mut got);
            assert_eq!(want, got, "nt_seq shape ({m},{k},{n})");
        }
    }

    #[test]
    fn tn_bitwise_matches_ascending_k() {
        let mut rng = Pcg64::seed(4);
        for &(m, k, n) in SHAPES {
            let a: Vec<f64> = (0..k * m).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
            let init: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();
            let mut want = init.clone();
            ref_tn_acc(&a, k, m, &b, n, &mut want);
            let mut got = init.clone();
            gemm_tn_acc(&a, k, m, &b, n, &mut got);
            assert_eq!(want, got, "tn shape ({m},{k},{n})");
        }
    }

    #[test]
    fn empty_and_degenerate_shapes() {
        // k = 0: products are empty sums; into-variants must still zero /
        // assign, acc-variants must leave c untouched.
        let mut c = vec![1.0, 2.0];
        gemm_nn_acc(&[], 1, 0, &[], 2, &mut c);
        assert_eq!(c, vec![1.0, 2.0]);
        gemm_nt_dot_into(&[], 1, &[], 2, 0, &mut c);
        assert_eq!(c, vec![0.0, 0.0]);
        let mut none: Vec<f64> = Vec::new();
        gemm_nn_acc(&[], 0, 3, &[0.0; 6], 2, &mut none);
        gemm_tn_acc(&[], 0, 0, &[], 4, &mut none);
        assert!(none.is_empty());
    }

    #[test]
    fn matvec_special_case_matches_dot() {
        // n = 1 is the projection path (Basis::project_into).
        let mut rng = Pcg64::seed(5);
        for k in [1usize, 3, 4, 31, 64, 130] {
            let m = 5;
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let v: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
            let mut got = vec![0.0; m];
            gemm_nt_dot_into(&a, m, &v, 1, k, &mut got);
            for i in 0..m {
                assert_eq!(got[i], dot(&a[i * k..(i + 1) * k], &v), "k={k} row {i}");
            }
        }
    }

    #[test]
    fn backend_names_roundtrip() {
        for be in Backend::ALL {
            assert_eq!(Backend::parse(be.name()), Some(be));
        }
        assert_eq!(Backend::parse("sse9"), None);
        assert_eq!(Backend::parse(""), None);
        assert!(Backend::Scalar.bit_identical());
        assert!(Backend::Avx2.bit_identical());
        assert!(!Backend::Avx2Fma.bit_identical());
        // The active backend is always a valid, resolvable choice.
        assert_eq!(Backend::parse(backend_name()), Some(backend()));
    }

    #[test]
    fn avx2_with_variant_is_bit_identical_to_scalar() {
        // Explicit-backend entry points, no global state touched: safe to
        // run concurrently with every other test in this process. The
        // deep coverage lives in tests/backend_parity.rs; this is the
        // in-module smoke across the tile-boundary SHAPES.
        if !simd_available() {
            eprintln!("skipping avx2-vs-scalar smoke: CPU lacks avx2+fma");
            return;
        }
        let mut rng = Pcg64::seed(6);
        for &(m, k, n) in SHAPES {
            let a: Vec<f64> = (0..m * k).map(|_| rng.normal()).collect();
            let bn: Vec<f64> = (0..k * n).map(|_| rng.normal()).collect();
            let bt: Vec<f64> = (0..n * k).map(|_| rng.normal()).collect();
            let at: Vec<f64> = (0..k * m).map(|_| rng.normal()).collect();
            let init: Vec<f64> = (0..m * n).map(|_| rng.normal()).collect();

            let mut s = init.clone();
            let mut v = init.clone();
            gemm_nn_acc_with(Backend::Scalar, &a, m, k, &bn, n, &mut s);
            gemm_nn_acc_with(Backend::Avx2, &a, m, k, &bn, n, &mut v);
            assert_eq!(s, v, "nn ({m},{k},{n})");

            let mut s = init.clone();
            let mut v = init.clone();
            gemm_nt_dot_acc_with(Backend::Scalar, &a, m, &bt, n, k, &mut s);
            gemm_nt_dot_acc_with(Backend::Avx2, &a, m, &bt, n, k, &mut v);
            assert_eq!(s, v, "nt_dot_acc ({m},{k},{n})");

            let mut s = init.clone();
            let mut v = init.clone();
            gemm_nt_dot_into_with(Backend::Scalar, &a, m, &bt, n, k, &mut s);
            gemm_nt_dot_into_with(Backend::Avx2, &a, m, &bt, n, k, &mut v);
            assert_eq!(s, v, "nt_dot_into ({m},{k},{n})");

            let mut s = init.clone();
            let mut v = init.clone();
            gemm_nt_seq_into_with(Backend::Scalar, &a, m, &bt, n, k, &mut s);
            gemm_nt_seq_into_with(Backend::Avx2, &a, m, &bt, n, k, &mut v);
            assert_eq!(s, v, "nt_seq ({m},{k},{n})");

            let mut s = init.clone();
            let mut v = init.clone();
            gemm_tn_acc_with(Backend::Scalar, &at, k, m, &bn, n, &mut s);
            gemm_tn_acc_with(Backend::Avx2, &at, k, m, &bn, n, &mut v);
            assert_eq!(s, v, "tn ({m},{k},{n})");
        }
    }
}
