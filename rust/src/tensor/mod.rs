//! Row-major f64 batch/matrix primitives.
//!
//! Everything on the L3 coordinator path works on flat `&[f64]` buffers with
//! explicit `(rows, cols)` shapes — no generic tensor machinery, just the
//! handful of dense ops the solvers, PCA and metrics need, written so the
//! hot loops vectorize.
//!
//! Dense matrix products route through the register-tiled micro-kernel
//! family in [`gemm`] (see that module's docs for the tile-size rationale
//! and the bitwise determinism contract); the `matmul_*` entry points here
//! are kept as the crate-wide API.

pub mod gemm;

/// A dense row-major matrix / batch of row vectors.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// `self * other`, blocked ikj loop (good cache behaviour, autovectorizes).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        matmul_into(
            &self.data,
            self.rows,
            self.cols,
            &other.data,
            other.cols,
            &mut out.data,
        );
        out
    }
}

/// `c[m,n] += a[m,k] * b[k,n]` over flat row-major buffers (c must be zeroed
/// by the caller when a fresh product is wanted). Delegates to the
/// register-tiled [`gemm::gemm_nn_acc`], which accumulates every output
/// entry in the same ascending-k order as the seed loop nest — outputs are
/// bit-identical, just with MR×NR-fold register reuse per loaded panel.
#[inline]
pub fn matmul_acc(a: &[f64], m: usize, k: usize, b: &[f64], n: usize, c: &mut [f64]) {
    gemm::gemm_nn_acc(a, m, k, b, n, c);
}

/// `c = a * b` over flat buffers.
#[inline]
pub fn matmul_into(a: &[f64], m: usize, k: usize, b: &[f64], n: usize, c: &mut [f64]) {
    c.fill(0.0);
    matmul_acc(a, m, k, b, n, c);
}

/// Dot product, 4-lane unrolled: independent accumulators break the
/// serial FP-add dependency chain so the loop pipelines/vectorizes. The
/// summation order is fixed (deterministic across platforms and thread
/// counts), just not the naive left-to-right one.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let len = a.len();
    let n4 = len & !3;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut i = 0;
    while i < n4 {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < len {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// Euclidean norm (inherits the unrolled accumulation of [`dot`]).
#[inline]
pub fn norm2(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += alpha * x[i];
    }
}

/// `y = x`.
#[inline]
pub fn copy(x: &[f64], y: &mut [f64]) {
    y.copy_from_slice(x);
}

/// `x *= alpha`.
#[inline]
pub fn scale(alpha: f64, x: &mut [f64]) {
    for v in x.iter_mut() {
        *v *= alpha;
    }
}

/// Sum of |a - b| over the slice.
#[inline]
pub fn l1_dist(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        s += (a[i] - b[i]).abs();
    }
    s
}

/// Squared L2 distance.
#[inline]
pub fn l2_dist_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// Column means of an (n, d) batch.
pub fn col_means(x: &[f64], n: usize, d: usize) -> Vec<f64> {
    let mut mu = vec![0.0; d];
    for i in 0..n {
        axpy(1.0, &x[i * d..(i + 1) * d], &mut mu);
    }
    scale(1.0 / n.max(1) as f64, &mut mu);
    mu
}

/// Rows centered per block before the covariance rank-k update; bounds the
/// staging buffer while keeping each update panel cache-resident.
const COV_BLOCK: usize = 32;

/// Sample covariance (biased, 1/n) of an (n, d) batch; returns d*d row-major.
///
/// Blocked formulation: center [`COV_BLOCK`] rows at a time, then apply one
/// `cov += Cᵀ C` rank-`nb` update through [`gemm::gemm_tn_acc`]. Each entry
/// still accumulates in ascending-sample order, but the per-sample rank-1
/// loop (whose data-dependent `ca == 0.0` skip defeated autovectorization,
/// the same defect PR 1 removed from `matmul_acc`) becomes a register-tiled
/// outer-product kernel that amortizes every loaded panel across the tile.
pub fn covariance(x: &[f64], n: usize, d: usize) -> Vec<f64> {
    let mu = col_means(x, n, d);
    let mut cov = vec![0.0; d * d];
    let mut cent = vec![0.0; COV_BLOCK * d];
    let mut i = 0;
    while i < n {
        let nb = COV_BLOCK.min(n - i);
        for r in 0..nb {
            let row = &x[(i + r) * d..(i + r + 1) * d];
            let crow = &mut cent[r * d..(r + 1) * d];
            for j in 0..d {
                crow[j] = row[j] - mu[j];
            }
        }
        gemm::gemm_tn_acc(&cent[..nb * d], nb, d, &cent[..nb * d], d, &mut cov);
        i += nb;
    }
    scale(1.0 / n.max(1) as f64, &mut cov);
    cov
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_rect() {
        let a = Mat::from_vec(2, 3, vec![1.0, 0.0, 2.0, 0.0, 1.0, -1.0]);
        let b = Mat::from_vec(3, 1, vec![3.0, 4.0, 5.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![13.0, -1.0]);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn identity_is_neutral() {
        let a = Mat::from_vec(3, 3, (0..9).map(|x| x as f64).collect());
        assert_eq!(a.matmul(&Mat::eye(3)), a);
        assert_eq!(Mat::eye(3).matmul(&a), a);
    }

    #[test]
    fn norms_and_dists() {
        assert_eq!(norm2(&[3.0, 4.0]), 5.0);
        assert_eq!(l1_dist(&[1.0, -1.0], &[0.0, 1.0]), 3.0);
        assert_eq!(l2_dist_sq(&[1.0, 2.0], &[0.0, 0.0]), 5.0);
    }

    #[test]
    fn covariance_of_known_data() {
        // x = {(1,0),(−1,0),(0,2),(0,−2)} → mean 0, cov diag(0.5, 2).
        let x = vec![1.0, 0.0, -1.0, 0.0, 0.0, 2.0, 0.0, -2.0];
        let c = covariance(&x, 4, 2);
        assert!((c[0] - 0.5).abs() < 1e-12);
        assert!((c[3] - 2.0).abs() < 1e-12);
        assert!(c[1].abs() < 1e-12 && c[2].abs() < 1e-12);
    }

    #[test]
    fn col_means_works() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(col_means(&x, 2, 2), vec![2.0, 3.0]);
    }
}
