//! Euler / DDIM solver.
//!
//! In the EDM eps-parameterization (`alpha_t = 1`, `sigma_t = t`) the DDIM
//! update coincides with the Euler discretization of the PF-ODE (Eq. 8):
//! `x' = x + (t' − t) eps(x, t)`. This is the paper's primary correction
//! target ("DDIM" rows of every table).

use super::{Solver, StepCtx, StepScratch};
use crate::score::EpsModel;

pub struct Euler;

impl Solver for Euler {
    fn name(&self) -> &str {
        "ddim"
    }

    fn gamma(&self, ctx: &StepCtx<'_>) -> Option<f64> {
        Some(ctx.h())
    }

    fn hist_depth(&self) -> usize {
        0 // current x and d only
    }

    fn step(
        &self,
        _model: &dyn EpsModel,
        ctx: &StepCtx<'_>,
        x: &[f64],
        d: &[f64],
        _n: usize,
        out: &mut [f64],
        _scratch: &mut StepScratch<'_>,
    ) {
        let h = ctx.h();
        for i in 0..x.len() {
            out[i] = x[i] + h * d[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::score::EpsModel;
    use crate::solvers::run_solver;

    /// For eps(x,t) = x/t the exact PF-ODE solution is x(t') = x(t) t'/t
    /// (pure scaling). Euler over a fine grid must converge to it.
    struct LinearEps;
    impl EpsModel for LinearEps {
        fn dim(&self) -> usize {
            1
        }
        fn eval_batch(&self, x: &[f64], _n: usize, t: f64, out: &mut [f64]) {
            for i in 0..x.len() {
                out[i] = x[i] / t;
            }
        }
        fn name(&self) -> &str {
            "linear"
        }
    }

    #[test]
    fn converges_on_linear_ode() {
        let sched = Schedule::log_snr(400, 1.0, 10.0);
        let run = run_solver(&Euler, &LinearEps, &[10.0], 1, &sched, None);
        let exact = 10.0 * 1.0 / 10.0;
        assert!(
            (run.x0[0] - exact).abs() < 5e-3,
            "{} vs {exact}",
            run.x0[0]
        );
    }

    #[test]
    fn single_step_formula() {
        let sched = Schedule::uniform(1, 2.0, 4.0);
        let run = run_solver(&Euler, &LinearEps, &[8.0], 1, &sched, None);
        // x' = 8 + (2-4)*8/4 = 4.
        assert_eq!(run.x0[0], 4.0);
    }
}
