//! DPM-Solver++ multistep (Lu et al. 2022b), data-prediction form,
//! specialized to EDM (`alpha_t = 1`, `sigma_t = t`, `lambda = -ln t`).
//!
//! With `h = lambda' - lambda = ln(t/t')` and `phi_1 = e^{-h} - 1 = t'/t - 1`:
//!
//! * 1M (== DDIM):   `x' = (t'/t) x - phi_1 m0`
//! * 2M:             `x' = (t'/t) x - phi_1 (m0 + (1/(2 r0)) (m0 - m1))`
//! * 3M:             `x' = (t'/t) x - phi_1 m0 + phi_2 D1 - phi_3 D2`
//!
//! where `m_k` are data predictions `x0 = x - t eps`, `r_k` are log-SNR
//! step ratios, `phi_2 = phi_1/h + 1`, `phi_3 = phi_2/h - 0.5`, and `D1`,
//! `D2` the standard divided differences (official `dpm_solver` code,
//! `multistep_dpm_solver_third_update`, algorithm "dpmsolver++").
//!
//! Warm-up: order ramps 1 → 2 → 3 as history accumulates, as in the
//! official multistep implementation.

use super::{ScratchSpec, Solver, StepCtx, StepScratch};
use crate::score::EpsModel;

pub struct DpmPp {
    /// Private so the `new` invariant (1..=3) that sizes the scratch
    /// spec cannot be bypassed after construction.
    max_order: usize,
    name: String,
}

impl DpmPp {
    pub fn new(max_order: usize) -> DpmPp {
        assert!((1..=3).contains(&max_order));
        DpmPp {
            max_order,
            name: format!("dpmpp{max_order}m"),
        }
    }

    fn effective_order(&self, ctx: &StepCtx<'_>) -> usize {
        self.max_order.min(ctx.ds.len() + 1)
    }

    /// Data prediction for history node `k` (0-based node index into
    /// ctx), written into the scratch-carved `out`.
    fn m_hist_into(ctx: &StepCtx<'_>, node: usize, out: &mut [f64]) {
        let t = ctx.sched.ts[node];
        let x = &ctx.xs[node];
        let d = &ctx.ds[node];
        for i in 0..out.len() {
            out[i] = x[i] - t * d[i];
        }
    }

    /// Coefficient of m0 in the update (for `gamma`).
    fn m0_coef(&self, ctx: &StepCtx<'_>) -> f64 {
        let ord = self.effective_order(ctx);
        let (t, tn) = (ctx.t, ctx.t_next);
        let h = (t / tn).ln();
        let phi_1 = tn / t - 1.0;
        match ord {
            1 => -phi_1,
            2 => {
                let h0 = (ctx.sched.ts[ctx.j - 1] / t).ln();
                let r0 = h0 / h;
                -phi_1 * (1.0 + 0.5 / r0)
            }
            _ => {
                let h0 = (ctx.sched.ts[ctx.j - 1] / t).ln();
                let h1 = (ctx.sched.ts[ctx.j - 2] / ctx.sched.ts[ctx.j - 1]).ln();
                let (r0, r1) = (h0 / h, h1 / h);
                let phi_2 = phi_1 / h + 1.0;
                let phi_3 = phi_2 / h - 0.5;
                // dD1/dm0 and dD2/dm0.
                let dd1 = (1.0 / r0) * (1.0 + r0 / (r0 + r1));
                let dd2 = (1.0 / r0) / (r0 + r1);
                -phi_1 + phi_2 * dd1 - phi_3 * dd2
            }
        }
    }
}

impl Solver for DpmPp {
    fn name(&self) -> &str {
        &self.name
    }

    fn gamma(&self, ctx: &StepCtx<'_>) -> Option<f64> {
        // m0 = x - t eps ⇒ d x'/d eps = -t * (coef of m0).
        Some(-ctx.t * self.m0_coef(ctx))
    }

    fn hist_depth(&self) -> usize {
        // Deepest read: m_hist_into at node j - (max_order - 1).
        self.max_order - 1
    }

    fn scratch_spec(&self, dim: usize, _n: usize) -> ScratchSpec {
        // Data predictions m0 (always) and m1/m2 as the warm-up ramp
        // unlocks them: sized for the max order so one arena covers
        // every step of a run.
        ScratchSpec {
            per_row: self.max_order * dim,
            flat: 0,
        }
    }

    fn step(
        &self,
        _model: &dyn EpsModel,
        ctx: &StepCtx<'_>,
        x: &[f64],
        d: &[f64],
        _n: usize,
        out: &mut [f64],
        scratch: &mut StepScratch<'_>,
    ) {
        let ord = self.effective_order(ctx);
        let (t, tn) = (ctx.t, ctx.t_next);
        let ratio = tn / t;
        let h = (t / tn).ln();
        let phi_1 = ratio - 1.0;
        // m0 from the (possibly corrected) current direction.
        let m0 = scratch.take(x.len());
        for i in 0..x.len() {
            m0[i] = x[i] - t * d[i];
        }
        match ord {
            1 => {
                for i in 0..x.len() {
                    out[i] = ratio * x[i] - phi_1 * m0[i];
                }
            }
            2 => {
                let m1 = scratch.take(x.len());
                Self::m_hist_into(ctx, ctx.j - 1, m1);
                let h0 = (ctx.sched.ts[ctx.j - 1] / t).ln();
                let r0 = h0 / h;
                for i in 0..x.len() {
                    let d1 = (m0[i] - m1[i]) / r0;
                    out[i] = ratio * x[i] - phi_1 * (m0[i] + 0.5 * d1);
                }
            }
            _ => {
                let m1 = scratch.take(x.len());
                Self::m_hist_into(ctx, ctx.j - 1, m1);
                let m2 = scratch.take(x.len());
                Self::m_hist_into(ctx, ctx.j - 2, m2);
                let h0 = (ctx.sched.ts[ctx.j - 1] / t).ln();
                let h1 = (ctx.sched.ts[ctx.j - 2] / ctx.sched.ts[ctx.j - 1]).ln();
                let (r0, r1) = (h0 / h, h1 / h);
                let phi_2 = phi_1 / h + 1.0;
                let phi_3 = phi_2 / h - 0.5;
                for i in 0..x.len() {
                    let d1_0 = (m0[i] - m1[i]) / r0;
                    let d1_1 = (m1[i] - m2[i]) / r1;
                    let d1 = d1_0 + (r0 / (r0 + r1)) * (d1_0 - d1_1);
                    let d2 = (d1_0 - d1_1) / (r0 + r1);
                    out[i] = ratio * x[i] - phi_1 * m0[i] + phi_2 * d1 - phi_3 * d2;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::score::EpsModel;
    use crate::solvers::{euler::Euler, run_solver, Solver};

    struct LinearEps;
    impl EpsModel for LinearEps {
        fn dim(&self) -> usize {
            1
        }
        fn eval_batch(&self, x: &[f64], _n: usize, t: f64, out: &mut [f64]) {
            for i in 0..x.len() {
                out[i] = x[i] / t;
            }
        }
        fn name(&self) -> &str {
            "linear"
        }
    }

    /// For eps = x/t the data prediction is identically 0, so every DPM++
    /// order must give the exact solution x' = (t'/t) x.
    #[test]
    fn exact_on_pure_scaling_ode() {
        let sched = Schedule::polynomial(7, 0.5, 10.0, 7.0);
        let exact = 10.0 * 0.5 / 10.0;
        for ord in 1..=3 {
            let run = run_solver(&DpmPp::new(ord), &LinearEps, &[10.0], 1, &sched, None);
            assert!(
                (run.x0[0] - exact).abs() < 1e-12,
                "order {ord}: {} vs {exact}",
                run.x0[0]
            );
        }
    }

    #[test]
    fn order1_equals_ddim() {
        let sched = Schedule::polynomial(6, 0.5, 10.0, 7.0);
        // A non-trivial model: eps pulls toward +2.
        struct Pull;
        impl EpsModel for Pull {
            fn dim(&self) -> usize {
                1
            }
            fn eval_batch(&self, x: &[f64], _n: usize, t: f64, out: &mut [f64]) {
                for i in 0..x.len() {
                    out[i] = (x[i] - 2.0) * t / (1.0 + t * t);
                }
            }
            fn name(&self) -> &str {
                "pull"
            }
        }
        let a = run_solver(&DpmPp::new(1), &Pull, &[10.0], 1, &sched, None);
        let b = run_solver(&Euler, &Pull, &[10.0], 1, &sched, None);
        // DPM++(1M) = DDIM in the exponential-integrator sense, which for
        // EDM-eps differs from plain Euler by O(h^2); check closeness, not
        // equality, then check 1M's exactness structure on Gaussian data.
        assert!((a.x0[0] - b.x0[0]).abs() < 0.2, "{} vs {}", a.x0[0], b.x0[0]);
    }

    #[test]
    fn higher_order_converges_faster_on_gaussian() {
        // Single Gaussian N(3, 0.5): analytic eps, exact trajectory known
        // via the teacher at high NFE.
        use crate::data::Mode;
        use crate::score::analytic::AnalyticEps;
        let m = AnalyticEps::new("g", vec![Mode::isotropic(vec![3.0], 0.5, 1.0, 0)]);
        let fine = Schedule::polynomial(400, 0.002, 80.0, 7.0);
        let reference = run_solver(&Euler, m.as_ref(), &[40.0], 1, &fine, None).x0[0];
        // 16 steps: enough history for the 3M warm-up to pay off on the
        // strongly non-uniform rho-7 grid.
        let sched = Schedule::polynomial(16, 0.002, 80.0, 7.0);
        let e1 = (run_solver(&DpmPp::new(1), m.as_ref(), &[40.0], 1, &sched, None).x0[0]
            - reference)
            .abs();
        let e3 = (run_solver(&DpmPp::new(3), m.as_ref(), &[40.0], 1, &sched, None).x0[0]
            - reference)
            .abs();
        assert!(e3 < e1, "3M {e3} should beat 1M {e1}");
    }

    #[test]
    fn gamma_matches_finite_difference() {
        let sched = Schedule::polynomial(6, 0.5, 10.0, 7.0);
        let solver = DpmPp::new(3);
        let xs = vec![vec![1.0], vec![0.9], vec![0.8]];
        let ds = vec![vec![0.3], vec![-0.2]];
        let ctx = StepCtx {
            j: 2,
            i_paper: 4,
            t: sched.ts[2],
            t_next: sched.ts[3],
            sched: &sched,
            xs: crate::solvers::NodeView::nested(&xs),
            ds: crate::solvers::NodeView::nested(&ds),
        };
        let gamma = solver.gamma(&ctx).unwrap();
        let mut o0 = vec![0.0];
        let mut o1 = vec![0.0];
        let mut buf = vec![0.0; solver.scratch_spec(1, 1).len_for(1)];
        let mut s0 = crate::solvers::StepScratch::new(&mut buf);
        solver.step(&LinearEps, &ctx, &[0.8], &[0.5], 1, &mut o0, &mut s0);
        let mut s1 = crate::solvers::StepScratch::new(&mut buf);
        solver.step(&LinearEps, &ctx, &[0.5 - 0.5 + 0.8], &[0.5 + 1e-6], 1, &mut o1, &mut s1);
        let fd = (o1[0] - o0[0]) / 1e-6;
        assert!(
            (fd - gamma).abs() < 1e-5 * (1.0 + gamma.abs()),
            "fd {fd} vs gamma {gamma}"
        );
    }
}
